"""AOT artifact tests: lowering produces loadable HLO text with the
manifest's shapes, and the lowered module has the structure the rust
runtime expects (tuple root, static shapes, no custom-calls)."""

import json

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_lower_all_produces_hlo_text():
    texts = aot.lower_all()
    assert set(texts) == {"token_hist", "token_hist_topk", "hash_hist"}
    for name, text in texts.items():
        assert text.startswith("HloModule"), name
        assert "ROOT" in text, name


def test_hlo_has_no_custom_calls():
    # interpret=True must lower pallas to plain HLO; a Mosaic custom-call
    # would be unloadable by the CPU PJRT client.
    for name, text in aot.lower_all().items():
        assert "custom-call" not in text, f"{name} contains a custom-call"


def test_hlo_entry_shapes_match_manifest():
    m = aot.manifest()
    texts = aot.lower_all()
    tok = f"s32[{m['shard_tokens']}]"
    assert tok in texts["token_hist"]
    assert f"s32[{m['vocab']}]" in texts["token_hist"]
    assert f"s32[{m['hash_buckets']}]" in texts["hash_hist"]


def test_manifest_consistency():
    m = aot.manifest()
    assert m["shard_tokens"] == model.SHARD_TOKENS
    assert m["vocab"] == model.VOCAB
    assert m["hash_buckets"] == model.HASH_BUCKETS
    assert m["pad_id"] == -1
    assert json.dumps(m)  # serializable


def test_hlo_contains_mxu_shaped_reduction():
    """The kernel's one-hot matmul must survive lowering as a dot — that is
    the op the MXU would execute on real hardware (the full numeric
    round-trip through a PJRT client is exercised by the rust integration
    test `runtime_histogram_matches_serial`)."""
    text = aot.lower_all()["token_hist"]
    assert " dot(" in text or " dot." in text, "expected a dot reduction in HLO"


def test_lowering_is_deterministic():
    a = aot.lower_all()["token_hist"]
    b = aot.lower_all()["token_hist"]
    assert a == b


def test_pad_ids_counted_nowhere():
    """End-to-end L2 check that the manifest's pad_id really vanishes."""
    pad = aot.manifest()["pad_id"]
    toks = np.full(model.SHARD_TOKENS, pad, np.int32)
    toks[0] = 5
    (counts,) = model.count_shard(jnp.array(toks))
    assert int(np.asarray(counts).sum()) == 1
    assert int(counts[5]) == 1
