"""Kernel-vs-reference correctness: the core L1 signal.

hypothesis sweeps shapes, vocab sizes, id ranges (including PAD and
out-of-range ids) and asserts exact equality against the pure-jnp oracle.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.hash_bucket import HASH_MULT, bucket_ids, hash_histogram
from compile.kernels.ref import hash_histogram_ref, token_histogram_ref
from compile.kernels.token_count import token_histogram


def assert_equal(a, b, msg=""):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=msg)


# ---------------------------------------------------------------- dense ----


class TestTokenHistogramBasics:
    def test_simple_counts(self):
        toks = jnp.array([0, 1, 1, 2, 2, 2, 5, 5] + [-1] * 120, jnp.int32)
        toks = jnp.pad(toks, (0, 128 - toks.shape[0] % 128 if toks.shape[0] % 128 else 0),
                       constant_values=-1)
        # pad to one block of 128 with block_t=128
        out = token_histogram(toks, vocab=128, block_t=128, block_v=128)
        assert int(out[0]) == 1
        assert int(out[1]) == 2
        assert int(out[2]) == 3
        assert int(out[5]) == 2
        assert int(out.sum()) == 8

    def test_all_pad_is_zero(self):
        toks = jnp.full((256,), -1, jnp.int32)
        out = token_histogram(toks, vocab=128, block_t=128, block_v=64)
        assert int(out.sum()) == 0

    def test_single_hot_id(self):
        toks = jnp.full((512,), 7, jnp.int32)
        out = token_histogram(toks, vocab=128, block_t=128, block_v=32)
        assert int(out[7]) == 512
        assert int(out.sum()) == 512

    def test_multiblock_accumulation(self):
        # 4 token blocks x 4 vocab blocks: the accumulation path matters.
        toks = jnp.arange(1024, dtype=jnp.int32) % 256
        out = token_histogram(toks, vocab=256, block_t=256, block_v=64)
        assert_equal(out, np.full(256, 4, np.int32))

    def test_out_of_range_ids_ignored(self):
        toks = jnp.array([0, 1, 300, 4000, -5, 2] + [-1] * 122, jnp.int32)
        out = token_histogram(toks, vocab=128, block_t=128, block_v=128)
        assert int(out.sum()) == 3  # only 0,1,2 are in-range

    def test_rejects_misaligned_shapes(self):
        with pytest.raises(AssertionError):
            token_histogram(jnp.zeros(100, jnp.int32), vocab=128, block_t=64, block_v=64)
        with pytest.raises(AssertionError):
            token_histogram(jnp.zeros(128, jnp.int32), vocab=100, block_t=64, block_v=64)


@settings(max_examples=40, deadline=None)
@given(
    n_blocks=st.integers(1, 4),
    block_t=st.sampled_from([128, 256]),
    vocab_blocks=st.integers(1, 3),
    block_v=st.sampled_from([64, 128]),
    seed=st.integers(0, 2**31 - 1),
    pad_frac=st.floats(0.0, 0.5),
)
def test_token_histogram_matches_ref(n_blocks, block_t, vocab_blocks, block_v, seed, pad_frac):
    n = n_blocks * block_t
    vocab = vocab_blocks * block_v
    rng = np.random.default_rng(seed)
    # ids spanning PAD, valid range, and out-of-range overflow.
    toks = rng.integers(0, int(vocab * 1.25) + 1, size=n).astype(np.int32)
    pad_mask = rng.random(n) < pad_frac
    toks[pad_mask] = -1
    got = token_histogram(jnp.array(toks), vocab=vocab, block_t=block_t, block_v=block_v)
    want = token_histogram_ref(toks, vocab=vocab)
    assert_equal(got, want, f"n={n} vocab={vocab}")


# ----------------------------------------------------------------- hash ----


class TestHashBucket:
    def test_bucket_range(self):
        toks = jnp.arange(10_000, dtype=jnp.int32)
        b = np.asarray(bucket_ids(toks, buckets=1024))
        assert b.min() >= 0
        assert b.max() < 1024

    def test_pad_maps_to_minus_one(self):
        toks = jnp.array([-1, -7, 3], jnp.int32)
        b = np.asarray(bucket_ids(toks, buckets=256))
        assert b[0] == -1 and b[1] == -1 and b[2] >= 0

    def test_bucket_distribution_roughly_uniform(self):
        toks = jnp.arange(65_536, dtype=jnp.int32)
        b = np.asarray(bucket_ids(toks, buckets=256))
        counts = np.bincount(b, minlength=256)
        mean = 65_536 / 256
        assert counts.min() > mean / 3
        assert counts.max() < mean * 3

    def test_matches_known_constant(self):
        # Pin the hash so rust (runtime::histogram) and python stay in sync.
        t = np.int32(12345)
        h = (np.uint64(np.uint32(t)) * np.uint64(HASH_MULT)) % np.uint64(2**32)
        expect = int(h) >> (32 - 8)
        got = int(bucket_ids(jnp.array([t]), buckets=256)[0])
        assert got == expect

    def test_rejects_non_power_of_two(self):
        with pytest.raises(AssertionError):
            bucket_ids(jnp.zeros(4, jnp.int32), buckets=100)


@settings(max_examples=30, deadline=None)
@given(
    n_blocks=st.integers(1, 3),
    block_t=st.sampled_from([128, 256]),
    buckets=st.sampled_from([128, 256, 512]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hash_histogram_matches_ref(n_blocks, block_t, buckets, seed):
    n = n_blocks * block_t
    rng = np.random.default_rng(seed)
    toks = rng.integers(-2, 1_000_000, size=n).astype(np.int32)
    got = hash_histogram(jnp.array(toks), buckets=buckets, block_t=block_t, block_b=min(buckets, 128))
    want = hash_histogram_ref(toks, buckets=buckets)
    assert_equal(got, want)


def test_histograms_are_deterministic():
    rng = np.random.default_rng(42)
    toks = jnp.array(rng.integers(0, 500, size=512).astype(np.int32))
    a = token_histogram(toks, vocab=512, block_t=256, block_v=128)
    b = token_histogram(toks, vocab=512, block_t=256, block_v=128)
    assert_equal(a, b)
