"""L2 graph tests: export-shaped shards, top-k composition, merge."""

import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels.ref import hash_histogram_ref, token_histogram_ref


def make_shard(seed=0, hot_id=3, hot_count=1000):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, model.VOCAB, size=model.SHARD_TOKENS).astype(np.int32)
    toks[:hot_count] = hot_id
    # Pad the tail as the rust runtime does for a final partial shard.
    toks[-500:] = -1
    return toks


def test_count_shard_matches_ref():
    toks = make_shard()
    (counts,) = model.count_shard(jnp.array(toks))
    want = token_histogram_ref(toks, vocab=model.VOCAB)
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(want))


def test_count_shard_shapes_and_dtype():
    toks = jnp.zeros((model.SHARD_TOKENS,), jnp.int32)
    (counts,) = model.count_shard(toks)
    assert counts.shape == (model.VOCAB,)
    assert counts.dtype == jnp.int32


def test_topk_graph_agrees_with_counts():
    toks = make_shard(seed=1, hot_id=77, hot_count=5000)
    counts, top_counts, top_ids = model.count_shard_topk(jnp.array(toks))
    assert top_ids.shape == (model.TOP_K,)
    assert int(top_ids[0]) == 77
    assert int(top_counts[0]) == int(counts[77])
    # top-k really is the k largest.
    c = np.asarray(counts)
    np.testing.assert_array_equal(
        np.sort(np.asarray(top_counts))[::-1],
        np.sort(c)[::-1][: model.TOP_K],
    )


def test_hash_count_shard_matches_ref():
    toks = make_shard(seed=2)
    (counts,) = model.hash_count_shard(jnp.array(toks))
    want = hash_histogram_ref(toks, buckets=model.HASH_BUCKETS)
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(want))


def test_merge_shard_counts_is_sum():
    a = jnp.array([1, 2, 3], jnp.int32)
    b = jnp.array([10, 0, 5], jnp.int32)
    merged = model.merge_shard_counts([a, b, a])
    np.testing.assert_array_equal(np.asarray(merged), [12, 4, 11])


def test_shard_totals_conserved_across_shards():
    """Sharding a stream and merging histograms == one big histogram."""
    rng = np.random.default_rng(3)
    total = model.SHARD_TOKENS * 2
    toks = rng.integers(0, model.VOCAB, size=total).astype(np.int32)
    shard_counts = []
    for s in range(2):
        shard = toks[s * model.SHARD_TOKENS : (s + 1) * model.SHARD_TOKENS]
        (c,) = model.count_shard(jnp.array(shard))
        shard_counts.append(c)
    merged = model.merge_shard_counts(shard_counts)
    want = token_histogram_ref(toks, vocab=model.VOCAB)
    np.testing.assert_array_equal(np.asarray(merged), np.asarray(want))
