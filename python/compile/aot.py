"""AOT bridge: lower the L2 graphs to HLO *text* artifacts for the rust
PJRT runtime.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids, which
the published ``xla`` crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the HLO text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage::

    cd python && python -m compile.aot --out ../artifacts

Writes one ``.hlo.txt`` per exported graph plus ``manifest.json`` recording
the static shapes the rust side must honor. Incremental: `make artifacts`
only reruns this when compile/ sources change.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all():
    """Lower every exported graph; returns {name: hlo_text}."""
    tok_spec = jax.ShapeDtypeStruct((model.SHARD_TOKENS,), jnp.int32)
    graphs = {
        "token_hist": jax.jit(lambda t: model.count_shard(t, vocab=model.VOCAB)).lower(tok_spec),
        "token_hist_topk": jax.jit(
            lambda t: model.count_shard_topk(t, vocab=model.VOCAB, k=model.TOP_K)
        ).lower(tok_spec),
        "hash_hist": jax.jit(
            lambda t: model.hash_count_shard(t, buckets=model.HASH_BUCKETS)
        ).lower(tok_spec),
    }
    return {name: to_hlo_text(low) for name, low in graphs.items()}


def manifest() -> dict:
    return {
        "shard_tokens": model.SHARD_TOKENS,
        "vocab": model.VOCAB,
        "hash_buckets": model.HASH_BUCKETS,
        "top_k": model.TOP_K,
        "pad_id": -1,
        "artifacts": {
            "token_hist": "token_hist.hlo.txt",
            "token_hist_topk": "token_hist_topk.hlo.txt",
            "hash_hist": "hash_hist.hlo.txt",
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    texts = lower_all()
    for name, text in texts.items():
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest(), f, indent=2)
    print(f"wrote {mpath}")

    # Flat key=value mirror for the rust runtime (no JSON parser offline).
    m = manifest()
    tpath = os.path.join(args.out, "manifest.txt")
    with open(tpath, "w") as f:
        for key in ("shard_tokens", "vocab", "hash_buckets", "top_k", "pad_id"):
            f.write(f"{key}={m[key]}\n")
    print(f"wrote {tpath}")


if __name__ == "__main__":
    main()
