"""L2: the word-count compute graph, built on the L1 Pallas kernels.

This is the accelerated-combiner model the rust runtime executes: a shard
of dictionary-encoded tokens goes in, per-vocabulary counts come out. Two
graphs are exported:

* ``count_shard``      — dense histogram over a fixed vocab (+ top-k variant).
* ``hash_count_shard`` — hashed-bucket histogram for unbounded vocabs.

Both lower the Pallas kernel *into the same HLO module* (interpret mode →
plain HLO ops), so the AOT artifact is self-contained for the CPU PJRT
client. Shapes are static (PJRT AOT requires it); the rust side pads the
final shard with PAD (-1) ids.
"""

from functools import partial

import jax
import jax.numpy as jnp

from .kernels.hash_bucket import hash_histogram
from .kernels.token_count import token_histogram

# Export shapes — keep in sync with rust/src/runtime/histogram.rs and
# artifacts/manifest.json (written by aot.py).
SHARD_TOKENS = 65_536
VOCAB = 8_192
HASH_BUCKETS = 4_096
TOP_K = 32


@partial(jax.jit, static_argnames=("vocab",))
def count_shard(tokens, *, vocab: int = VOCAB):
    """tokens int32 (SHARD_TOKENS,) -> (counts int32 (vocab,),)."""
    return (token_histogram(tokens, vocab=vocab),)


@partial(jax.jit, static_argnames=("vocab", "k"))
def count_shard_topk(tokens, *, vocab: int = VOCAB, k: int = TOP_K):
    """Counts plus the top-k (counts, ids) — the L2 graph composes the L1
    kernel with an XLA sort-based reduction, exercising kernel+graph
    composition in one artifact.

    Implemented with ``sort_key_val`` rather than ``jax.lax.top_k``: the
    xla_extension 0.5.1 HLO-text parser predates the ``topk(..., largest=)``
    attribute, while plain ``sort`` round-trips. Stable sort on negated
    counts gives descending counts with ascending-id tie-break — the same
    order as the rust-side ``wordcount::top_k``.
    """
    counts = token_histogram(tokens, vocab=vocab)
    ids = jax.lax.broadcasted_iota(jnp.int32, (vocab,), 0)
    neg_sorted, sorted_ids = jax.lax.sort_key_val(-counts, ids)
    top_counts = -neg_sorted[:k]
    top_ids = sorted_ids[:k]
    return counts, top_counts, top_ids.astype(jnp.int32)


@partial(jax.jit, static_argnames=("buckets",))
def hash_count_shard(tokens, *, buckets: int = HASH_BUCKETS):
    """tokens int32 (SHARD_TOKENS,) -> (bucket counts int32 (buckets,),)."""
    return (hash_histogram(tokens, buckets=buckets),)


def merge_shard_counts(per_shard_counts):
    """Tree-sum of per-shard count vectors (associative reduce — the same
    contract the rust reducers rely on)."""
    acc = jnp.zeros_like(per_shard_counts[0])
    for c in per_shard_counts:
        acc = acc + c
    return acc
