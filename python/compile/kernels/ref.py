"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every kernel result is asserted (in pytest and in hypothesis sweeps)
against these reference implementations, which use only straightforward
jnp ops (bincount / where) with no tiling tricks.
"""

import jax.numpy as jnp

from .hash_bucket import bucket_ids


def token_histogram_ref(tokens, *, vocab: int):
    """Counts of ids in [0, vocab); PAD (< 0) and out-of-range ids ignored."""
    tokens = jnp.asarray(tokens, jnp.int32)
    valid = (tokens >= 0) & (tokens < vocab)
    # bincount needs non-negative input; clamp then zero out invalid weight.
    clamped = jnp.where(valid, tokens, 0)
    return jnp.bincount(clamped, weights=valid.astype(jnp.int32), length=vocab).astype(
        jnp.int32
    )


def hash_histogram_ref(tokens, *, buckets: int):
    """Counts of hashed buckets; PAD ids vanish."""
    tokens = jnp.asarray(tokens, jnp.int32)
    b = bucket_ids(tokens, buckets=buckets)
    valid = b >= 0
    clamped = jnp.where(valid, b, 0)
    return jnp.bincount(clamped, weights=valid.astype(jnp.int32), length=buckets).astype(
        jnp.int32
    )
