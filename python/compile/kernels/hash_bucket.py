"""L1 Pallas kernel: hashed-bucket histogram.

For unbounded vocabularies the dense histogram does not fit; the paper's
`DistHashMap` routes keys by a multiplicative hash, and this kernel applies
the *same trick* on the accelerator: token ids are hashed into ``buckets``
with a 32-bit golden-ratio multiplicative hash, then histogrammed with the
one-hot MXU reduction of ``token_count``. The rust runtime mirrors the hash
(``runtime::histogram::hash_bucket_of``) so both layers agree on bucket
assignment.

PAD convention: ids < 0 map to bucket -1 (no match).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 32-bit golden-ratio multiplier (2^32 / phi), the classic Fibonacci-hash
# constant; keep in sync with rust `runtime::histogram::HASH_MULT`.
HASH_MULT = 0x9E3779B9

BLOCK_T = 2048
BLOCK_B = 512


def bucket_ids(tokens, *, buckets: int):
    """Reference bucket computation (shared by kernel and oracle):
    ``((token * HASH_MULT) mod 2^32) >> (32 - log2(buckets))``.
    """
    assert buckets & (buckets - 1) == 0, "buckets must be a power of two"
    shift = 32 - buckets.bit_length() + 1  # 32 - log2(buckets)
    h = (tokens.astype(jnp.uint32) * jnp.uint32(HASH_MULT)) >> jnp.uint32(shift)
    return jnp.where(tokens < 0, jnp.int32(-1), h.astype(jnp.int32))


def _hash_hist_kernel(tok_ref, out_ref, *, block_b: int, buckets: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    toks = tok_ref[...]
    b = bucket_ids(toks, buckets=buckets)  # (block_t,) in [-1, buckets)
    base = j * block_b
    ids = base + jax.lax.broadcasted_iota(jnp.int32, (block_b,), 0)
    onehot = (b[:, None] == ids[None, :]).astype(jnp.float32)
    ones = jnp.ones((1, toks.shape[0]), jnp.float32)
    partial_counts = jnp.dot(ones, onehot)[0]

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial_counts


@partial(jax.jit, static_argnames=("buckets", "block_t", "block_b"))
def hash_histogram(tokens, *, buckets: int, block_t: int = BLOCK_T, block_b: int = BLOCK_B):
    """Histogram of hashed buckets. ``tokens`` int32 (N,), N % block_t == 0,
    ``buckets`` a power of two and a multiple of ``block_b``.
    """
    n = tokens.shape[0]
    assert n % block_t == 0
    assert buckets % block_b == 0
    grid = (n // block_t, buckets // block_b)
    out = pl.pallas_call(
        partial(_hash_hist_kernel, block_b=block_b, buckets=buckets),
        grid=grid,
        in_specs=[pl.BlockSpec((block_t,), lambda i, j: (i,))],
        out_specs=pl.BlockSpec((block_b,), lambda i, j: (j,)),
        out_shape=jax.ShapeDtypeStruct((buckets,), jnp.float32),
        interpret=True,
    )(tokens.astype(jnp.int32))
    return out.astype(jnp.int32)
