"""L1 Pallas kernel: token-id histogram via one-hot MXU matmul.

The compute hot-spot of word count, once words are dictionary-encoded, is a
histogram: ``counts[v] = sum_i [token_i == v]``. On a GPU one would use
shared-memory atomics; TPUs have no scatter-atomics in the VMEM programming
model, so the paper's "combine locally in fast memory" insight is re-thought
for the MXU (DESIGN.md §Hardware-Adaptation):

* the token stream is tiled into blocks of ``block_t`` ids resident in VMEM;
* the vocabulary axis is tiled into blocks of ``block_v``;
* for a (token-block, vocab-block) grid step the kernel materializes a
  ``(block_t, block_v)`` one-hot matrix in VMEM and reduces it with a
  ``(1, block_t) @ (block_t, block_v)`` matmul — a systolic-array-shaped
  reduction (bf16-friendly on real TPU; f32 here for integer exactness in
  interpret mode);
* grid steps over token blocks accumulate into the same vocab-block of the
  output, i.e. the HBM->VMEM schedule a GPU kernel would express with
  threadblock tiling is expressed with BlockSpecs.

Padding convention: ids < 0 (PAD) match no vocab slot and vanish; id 0 is
reserved for out-of-vocabulary words (rust side: ``corpus::Vocab::UNK``).

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU performance is *estimated* from the VMEM/MXU model
in DESIGN.md §7.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tiling (see DESIGN.md §7 for the VMEM budget arithmetic):
# one-hot tile = 2048 x 512 f32 = 4 MiB, token block 8 KiB, output block
# 2 KiB — comfortably inside a ~16 MiB VMEM with double-buffering room.
BLOCK_T = 2048
BLOCK_V = 512


def _hist_kernel(tok_ref, out_ref, *, block_v: int):
    """One grid step: accumulate token block i into vocab block j."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    toks = tok_ref[...]  # (block_t,) int32
    base = j * block_v
    ids = base + jax.lax.broadcasted_iota(jnp.int32, (block_v,), 0)
    # One-hot in VMEM; PAD ids (< 0) match nothing.
    onehot = (toks[:, None] == ids[None, :]).astype(jnp.float32)
    ones = jnp.ones((1, toks.shape[0]), jnp.float32)
    partial_counts = jnp.dot(ones, onehot)[0]  # (block_v,) MXU reduction

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial_counts


@partial(jax.jit, static_argnames=("vocab", "block_t", "block_v"))
def token_histogram(tokens, *, vocab: int, block_t: int = BLOCK_T, block_v: int = BLOCK_V):
    """Histogram of ``tokens`` (int32, shape (N,)) over ``[0, vocab)``.

    N must be a multiple of ``block_t`` and ``vocab`` of ``block_v``
    (callers pad tokens with -1). Returns int32 counts of shape (vocab,).
    """
    n = tokens.shape[0]
    assert n % block_t == 0, f"token count {n} not a multiple of {block_t}"
    assert vocab % block_v == 0, f"vocab {vocab} not a multiple of {block_v}"
    grid = (n // block_t, vocab // block_v)
    out = pl.pallas_call(
        partial(_hist_kernel, block_v=block_v),
        grid=grid,
        in_specs=[pl.BlockSpec((block_t,), lambda i, j: (i,))],
        out_specs=pl.BlockSpec((block_v,), lambda i, j: (j,)),
        out_shape=jax.ShapeDtypeStruct((vocab,), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(tokens.astype(jnp.int32))
    return out.astype(jnp.int32)
