//! End-to-end driver: the full system on a real workload, reproducing the
//! paper's headline experiment and reporting its metric (words/second).
//!
//! Pipeline exercised, all layers composing:
//!   corpus synthesis (Zipf, Bible+Shakespeare profile)
//!   → Blaze engine (DistRange → DistHashMap on the simulated cluster)
//!   → Spark-sim baseline (RDD/stages/shuffle with the JVM cost model)
//!   → XLA/PJRT accelerated combiner (AOT Pallas histogram artifact)
//!   → verification of every path against the serial reference.
//!
//! Results are recorded in EXPERIMENTS.md §E2E.
//!
//! Run: `make artifacts && cargo run --release --example e2e_wordcount`
//! Corpus size: `BLAZE_E2E_BYTES` (default 64 MB; paper used 2 GB).

use blaze::cluster::NetModel;
use blaze::corpus::{Corpus, CorpusSpec, Tokenizer, Vocab};
use blaze::metrics::ascii_bar_chart;
use blaze::util::stats::{fmt_bytes, fmt_rate, Stopwatch};
use blaze::wordcount::{serial_reference, EngineChoice, WordCountJob};

fn main() {
    let bytes = std::env::var("BLAZE_E2E_BYTES")
        .ok()
        .and_then(|s| blaze::util::cli::parse_bytes(&s))
        .unwrap_or(64 << 20);
    let nodes = 2;
    let threads = 4; // r5.xlarge = 4 vCPU

    println!("=== E2E word count (paper headline experiment) ===");
    let sw = Stopwatch::start();
    let corpus = Corpus::generate(&CorpusSpec::with_bytes(bytes));
    println!(
        "corpus: {} / {} lines / {} words (generated in {:.2}s)",
        fmt_bytes(corpus.bytes),
        corpus.num_lines(),
        corpus.words,
        sw.elapsed_secs()
    );
    println!("cluster: {nodes} nodes x {threads} threads, AWS-like network\n");

    let reference = serial_reference(&corpus, Tokenizer::Spaces);
    let mut bars = Vec::new();

    // --- the paper's three bars ---
    for engine in [EngineChoice::Spark, EngineChoice::Blaze, EngineChoice::BlazeTcm] {
        let job = WordCountJob::new(engine)
            .nodes(nodes)
            .threads_per_node(threads)
            .net(NetModel::aws_like());
        let result = job.run(&corpus).expect("engine run");
        assert_eq!(result.counts, reference, "{} diverged from reference", engine.label());
        println!("{}   [verified ✓]", result.summary());
        println!("  detail: {}\n", result.detail);
        bars.push((engine.label().to_string(), result.words_per_sec()));
    }

    // --- XLA/PJRT accelerated combiner (cross-layer path) ---
    if blaze::runtime::HistogramRuntime::available() {
        let hr = blaze::runtime::HistogramRuntime::from_env().expect("runtime");
        let vocab = Vocab::from_lines(&corpus.lines);
        let ids = vocab.encode_lines(&corpus.lines);
        let sw = Stopwatch::start();
        let counts = hr.count_tokens(&ids).expect("xla count");
        let secs = sw.elapsed_secs();
        let total: u64 = counts.iter().sum();
        // Verify against the reference (ids beyond vocab capacity fold into
        // UNK=0; with from_lines the vocab covers everything, so exact).
        let mut ok = true;
        for (k, &v) in &reference {
            let id = vocab.id_of(k);
            if id > 0 && counts[id as usize] != v {
                ok = false;
                break;
            }
        }
        println!(
            "XLA combiner      {:>12} tokens in {:>8.3}s = {:>14}   [{}]",
            total,
            secs,
            fmt_rate(total as f64 / secs, "words"),
            if ok { "verified ✓" } else { "MISMATCH ✗" }
        );
        println!("  (interpret-mode Pallas on CPU PJRT — structural path, not a TPU perf proxy)\n");
    } else {
        println!("XLA combiner: skipped (run `make artifacts`)\n");
    }

    println!(
        "{}",
        ascii_bar_chart("Words per second (reproduces the paper's figure)", &bars, "words")
    );
    let spark = bars[0].1;
    let blaze_best = bars[1..].iter().map(|(_, v)| *v).fold(0.0, f64::max);
    println!(
        "headline: best Blaze / Spark = {:.1}x   (paper claims ~10x, 'an order of magnitude')",
        blaze_best / spark
    );
}
