//! Fault-tolerance study: what failures cost each engine.
//!
//! The paper's §Conclusion argues fault tolerance is a bad trade below
//! ~1M core-hours: Spark pays FT overhead on *every* run, Blaze pays only
//! when a failure actually happens (rerun the job, "as long as it succeeds
//! before the fourth try"). This example measures all four quadrants:
//!
//!                 | no failure          | one failure injected
//!   Spark (FT on) | steady-state tax    | lineage retries one task
//!   Blaze (no FT) | no tax              | whole job reruns
//!
//! Run: `cargo run --release --example fault_tolerance`

use blaze::cluster::{FailurePlan, NetModel};
use blaze::corpus::{Corpus, CorpusSpec, Tokenizer};
use blaze::metrics::Table;
use blaze::wordcount::{serial_reference, EngineChoice, WordCountJob};

/// Run 1 warmup + 3 measured reps (fresh failure plan each rep, since
/// injections are consumed); report the best rep (least scheduler noise).
fn run(
    engine: EngineChoice,
    make_failures: impl Fn() -> FailurePlan,
    corpus: &Corpus,
) -> (f64, String) {
    let once = |failures: FailurePlan| {
        let result = WordCountJob::new(engine)
            .nodes(2)
            .threads_per_node(4)
            .net(NetModel::aws_like())
            .failures(failures)
            .run(corpus)
            .expect("job must recover");
        assert_eq!(
            result.counts,
            serial_reference(corpus, Tokenizer::Spaces),
            "results must be correct even after failures"
        );
        (result.wall_secs, result.detail.to_string())
    };
    once(FailurePlan::none()); // warmup
    let mut best = f64::INFINITY;
    let mut detail = String::new();
    for _ in 0..3 {
        let (secs, d) = once(make_failures());
        if secs < best {
            best = secs;
            detail = d;
        }
    }
    (best, detail)
}

fn main() {
    let corpus = Corpus::generate(&CorpusSpec::with_bytes(16 << 20));
    println!("corpus: {} words; every cell verified against the serial reference\n", corpus.words);

    let mut table = Table::new(
        "Failure cost per engine (seconds, lower is better)",
        &["engine", "clean run", "with one failure", "failure penalty"],
    );

    // Spark: task failure in the map stage; lineage recomputes one task.
    let (spark_clean, _) = run(EngineChoice::Spark, FailurePlan::none, &corpus);
    let (spark_fail, spark_detail) =
        run(EngineChoice::Spark, || FailurePlan::none().fail_task(0, 1), &corpus);
    table.row(&[
        "Spark (FT: lineage retry)".into(),
        format!("{spark_clean:.3}"),
        format!("{spark_fail:.3}"),
        format!("+{:.1}%", (spark_fail / spark_clean - 1.0) * 100.0),
    ]);

    // Blaze: node failure in the map phase; the whole job reruns.
    let (blaze_clean, _) = run(EngineChoice::BlazeTcm, FailurePlan::none, &corpus);
    let (blaze_fail, blaze_detail) =
        run(EngineChoice::BlazeTcm, || FailurePlan::none().fail_node(1, 0), &corpus);
    table.row(&[
        "Blaze (no FT: job rerun)".into(),
        format!("{blaze_clean:.3}"),
        format!("{blaze_fail:.3}"),
        format!("+{:.1}%", (blaze_fail / blaze_clean - 1.0) * 100.0),
    ]);

    println!("{}", table.to_markdown());
    println!("spark failure-run detail: {spark_detail}");
    println!("blaze failure-run detail: {blaze_detail}\n");

    // The paper's break-even arithmetic, evaluated on measured numbers.
    let ft_tax = spark_clean - blaze_clean; // includes all engine deltas
    let rerun_cost = blaze_fail - blaze_clean;
    println!(
        "paper's trade: Blaze's rerun penalty ({rerun_cost:.3}s, paid per failure) vs\n\
         Spark's per-run overhead ({ft_tax:.3}s, paid every run). With MTBF ~1M\n\
         core-hours, failures at this job size are ~never — the rerun side wins."
    );
}
