//! Quickstart for the iterative driver: PageRank over a small explicit
//! graph, with the partition cache serving the edge relation from memory
//! on every round after the first — and the result checked bit-for-bit
//! against the serial fixed-point oracle.
//!
//! Run with: `cargo run --release --example iterative_pagerank`

use blaze::cache::CacheBudget;
use blaze::cluster::NetModel;
use blaze::corpus::Corpus;
use blaze::engines::Engine;
use blaze::mapreduce::{run_iterative, run_iterative_serial, IterativeSpec, JobInputs, JobSpec};
use blaze::workloads::PageRank;

fn main() {
    // Each line is one adjacency fragment: `src dst...`. "hub" is linked
    // from everywhere, so it must end up with the top rank.
    let graph = "\
alpha hub beta\n\
beta hub\n\
gamma hub alpha\n\
delta hub gamma\n\
hub alpha\n";
    let corpus = Corpus::from_text(graph);
    let inputs = JobInputs::new().relation("edges", &corpus);

    let spec = JobSpec::new(Engine::BlazeTcm)
        .nodes(2)
        .threads_per_node(2)
        .net(NetModel::ideal());
    let it = IterativeSpec::new(30)
        .tolerance(1e-7)
        .cache_budget(CacheBudget::Unbounded);
    let w = PageRank::new();

    let r = run_iterative(&spec, &it, &w, &inputs).expect("pagerank run");
    println!("{}", r.summary());
    for row in &r.iters {
        println!(
            "  round {:>2}: delta {:>10.3e}   cache {}",
            row.round, row.delta, row.cache
        );
    }

    let mut ranks = PageRank::ranks_from_state(&r.state);
    ranks.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("\nranks:");
    for (node, rank) in &ranks {
        println!("  {rank:>8.4}  {node}");
    }
    assert_eq!(ranks[0].0, "hub", "everyone links to the hub");

    // The engines must reproduce the serial fixed point exactly — integer
    // fixed-point arithmetic leaves no room for float drift.
    let oracle = run_iterative_serial(&it, &w, &inputs);
    assert_eq!(r.state, oracle.state);
    println!("\nverify: bit-identical to the serial fixed-point oracle");
}
