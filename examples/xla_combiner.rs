//! XLA/PJRT-accelerated combiner: the three-layer path end to end.
//!
//! L3 (rust) tokenizes and dictionary-encodes the corpus, the AOT-compiled
//! L2/L1 artifact (JAX graph wrapping the Pallas one-hot-matmul histogram
//! kernel) counts each shard, and L3 merges shard counts. Also demonstrates
//! the hashed-bucket variant (unbounded vocab) and cross-checks both
//! against pure-rust counting — rust and kernel share the same hash.
//!
//! Run: `make artifacts && cargo run --release --example xla_combiner`

use blaze::corpus::{Corpus, CorpusSpec, Vocab};
use blaze::runtime::{hash_bucket_of, HistogramRuntime};
use blaze::util::stats::{fmt_rate, Stopwatch};

fn main() {
    if !HistogramRuntime::available() {
        eprintln!("artifacts/ not built — run `make artifacts` first");
        std::process::exit(1);
    }
    let hr = HistogramRuntime::from_env().expect("PJRT runtime");
    println!(
        "artifact spec: shard={} tokens, vocab={}, hash buckets={}, pad={}",
        hr.spec.shard_tokens, hr.spec.vocab, hr.spec.hash_buckets, hr.spec.pad_id
    );

    let corpus = Corpus::generate(&CorpusSpec::with_bytes(8 << 20));
    let vocab = Vocab::from_lines(&corpus.lines);
    println!(
        "corpus: {} words, {} distinct words (vocab capacity {})\n",
        corpus.words,
        vocab.len() - 1,
        hr.spec.vocab
    );

    // --- encode (L3) ---
    let sw = Stopwatch::start();
    let ids = vocab.encode_lines(&corpus.lines);
    println!("encode: {} ids in {:.3}s", ids.len(), sw.elapsed_secs());

    // --- dense histogram through the artifact (L1/L2) ---
    let sw = Stopwatch::start();
    let counts = hr.count_tokens(&ids).expect("count_tokens");
    let secs = sw.elapsed_secs();
    let total: u64 = counts.iter().sum();
    println!(
        "dense histogram: {total} tokens in {secs:.3}s = {}",
        fmt_rate(total as f64 / secs, "tokens")
    );
    assert_eq!(counts, hr.count_tokens_serial(&ids), "kernel vs rust serial");
    println!("  verified against rust serial count ✓");

    // --- top-k through the fused L2 graph ---
    let one_shard: Vec<i32> = {
        let mut s = ids[..ids.len().min(hr.spec.shard_tokens)].to_vec();
        s.resize(hr.spec.shard_tokens, hr.spec.pad_id);
        s
    };
    let top = hr.shard_topk(&one_shard).expect("topk artifact");
    println!("\ntop-5 of the first shard (via the AOT top-k graph):");
    for (id, c) in top.iter().take(5) {
        println!("  {c:>8}  {}", vocab.word_of(*id));
    }

    // --- hashed-bucket histogram (unbounded-vocab path) ---
    let sw = Stopwatch::start();
    let hashed = hr.count_hashed(&ids).expect("count_hashed");
    println!(
        "\nhashed histogram ({} buckets) in {:.3}s",
        hashed.len(),
        sw.elapsed_secs()
    );
    assert_eq!(hashed, hr.count_hashed_serial(&ids), "hash kernel vs rust serial");
    println!("  verified: kernel and rust agree on every bucket (shared hash) ✓");

    // Show the shared hash on a concrete word.
    let word = "the";
    let id = vocab.id_of(word);
    let bucket = hash_bucket_of(id, hr.spec.hash_buckets as u32);
    println!(
        "\nexample: word {word:?} → id {id} → bucket {bucket} (same on L1 and L3); \
         bucket count = {}",
        hashed[bucket as usize]
    );
}
