//! Quickstart: the paper's high-level API, verbatim.
//!
//! Mirrors the C++ snippet from the paper (fgpl, src/test/dist_range_test.cc):
//!
//! ```c++
//! DistRange<int> range(0, lines.size());
//! DistHashMap<std::string, int> target;
//! const auto& mapper = [&](const int i, const auto& emit) { ... emit(word, 1); };
//! range.mapreduce<std::string, int, std::hash<std::string>>(
//!     mapper, Reducer<int>::sum, target);
//! ```
//!
//! Run: `cargo run --release --example quickstart`

use blaze::cluster::{spawn_cluster, NetModel};
use blaze::corpus::{split_spaces, Corpus, CorpusSpec};
use blaze::dist::{reducer, CombineMode, DistHashMap, DistRange};
use blaze::hash::HashKind;

fn main() {
    // A small corpus in the paper's shape (Bible+Shakespeare-like, tiled).
    let corpus = Corpus::generate(&CorpusSpec::with_bytes(4 << 20));
    let lines = &corpus.lines;
    println!("corpus: {} lines, {} words", lines.len(), corpus.words);

    // A 2-node simulated cluster, 4 threads each.
    let nnodes = 2;
    let nthreads = 4;
    let results = spawn_cluster(nnodes, NetModel::aws_like(), |comm| {
        // DistRange<int> range(0, lines.size());
        let range = DistRange::new(0, lines.len() as i64);
        // DistHashMap<std::string, int> target;
        let target: DistHashMap<String, u64> =
            DistHashMap::new(comm.rank, nnodes, nthreads, HashKind::Fx, CombineMode::Eager);

        // range.mapreduce(mapper, Reducer<int>::sum, target);
        range.mapreduce(comm, nthreads, &target, reducer::sum, |i, emit| {
            for word in split_spaces(&lines[i as usize]) {
                emit(word.to_string(), 1);
            }
        });

        // Each node returns its owned shard of the result.
        target.to_vec_local()
    });

    // Merge shards (disjoint by key ownership) and show the top words.
    let mut counts: Vec<(String, u64)> = results.into_iter().flatten().collect();
    counts.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    println!("\ntop 10 words:");
    for (word, count) in counts.iter().take(10) {
        println!("  {count:>10}  {word}");
    }

    let total: u64 = counts.iter().map(|(_, c)| c).sum();
    assert_eq!(total, corpus.words, "every word must be counted exactly once");
    println!("\ntotal counted: {total} (matches corpus)  ✓");
}
