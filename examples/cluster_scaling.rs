//! Cluster-scaling study: words/sec vs node count for both engines.
//!
//! The paper evaluates on an AWS EMR cluster; this example sweeps the
//! simulated cluster size and shows how each engine scales — and how
//! shuffle volume (the thing map-side combining controls) grows with the
//! node count.
//!
//! Run: `cargo run --release --example cluster_scaling`

use blaze::cluster::NetModel;
use blaze::corpus::{Corpus, CorpusSpec};
use blaze::metrics::Table;
use blaze::util::stats::{fmt_bytes, fmt_rate};
use blaze::wordcount::{EngineChoice, WordCountJob};

fn main() {
    let bytes = std::env::var("BLAZE_SCALING_BYTES")
        .ok()
        .and_then(|s| blaze::util::cli::parse_bytes(&s))
        .unwrap_or(16 << 20);
    let corpus = Corpus::generate(&CorpusSpec::with_bytes(bytes));
    println!(
        "corpus: {} ({} words); threads/node = 4; AWS-like network\n",
        fmt_bytes(corpus.bytes),
        corpus.words
    );

    let mut table = Table::new(
        "Scaling with node count",
        &["engine", "nodes", "wall (s)", "words/s", "shuffled"],
    );
    for engine in [EngineChoice::Spark, EngineChoice::BlazeTcm] {
        let mut single_node_rate = None;
        for nodes in [1usize, 2, 4, 8] {
            let result = WordCountJob::new(engine)
                .nodes(nodes)
                .threads_per_node(4)
                .net(NetModel::aws_like())
                .run(&corpus)
                .expect("run");
            let rate = result.words_per_sec();
            let base = *single_node_rate.get_or_insert(rate);
            table.row(&[
                engine.label().to_string(),
                format!("{nodes}"),
                format!("{:.3}", result.wall_secs),
                format!("{} ({:.2}x)", fmt_rate(rate, "words"), rate / base),
                fmt_bytes(result.shuffle_bytes),
            ]);
        }
    }
    println!("{}", table.to_markdown());
    println!(
        "note: simulated nodes share one machine, so scaling flattens once\n\
         real cores are oversubscribed — the *relative* engine gap and the\n\
         shuffle-volume growth are the reproduction targets here."
    );
}
