//! Beyond word count: the framework on other MapReduce workloads.
//!
//! The paper positions its design as a general MapReduce substrate ("many
//! big data processing routines can be transformed into a series of
//! MapReduce tasks"); this example exercises the same `DistRange` →
//! `DistHashMap` machinery on three classic analytics jobs:
//!
//! 1. **Inverted index** — word → list of line ids (non-numeric reducer).
//! 2. **Line-length histogram** — length class → count (integer keys).
//! 3. **Per-word average line length** — word → (sum, count) pairs merged
//!    associatively, averaged at read time.
//!
//! Run: `cargo run --release --example analytics`

use blaze::cluster::{spawn_cluster, NetModel};
use blaze::corpus::{split_spaces, Corpus, CorpusSpec};
use blaze::dist::{reducer, CombineMode, DistHashMap, DistRange};
use blaze::hash::HashKind;

fn main() {
    let corpus = Corpus::generate(&CorpusSpec::with_bytes(2 << 20));
    let lines = &corpus.lines;
    let nnodes = 2;
    let nthreads = 4;
    println!("corpus: {} lines, {} words\n", lines.len(), corpus.words);

    // ---------------- 1. inverted index ----------------
    // Reducer: concatenate posting lists (associative, commutative up to
    // order; we sort before display).
    let postings = spawn_cluster(nnodes, NetModel::aws_like(), |comm| {
        let target: DistHashMap<String, Vec<u32>> =
            DistHashMap::new(comm.rank, nnodes, nthreads, HashKind::Fx, CombineMode::Eager);
        DistRange::new(0, lines.len() as i64).mapreduce(
            comm,
            nthreads,
            &target,
            |acc: &mut Vec<u32>, mut more: Vec<u32>| acc.append(&mut more),
            |i, emit| {
                for w in split_spaces(&lines[i as usize]) {
                    emit(w.to_string(), vec![i as u32]);
                }
            },
        );
        target.to_vec_local()
    });
    let mut index: Vec<(String, Vec<u32>)> = postings.into_iter().flatten().collect();
    index.sort_by(|a, b| b.1.len().cmp(&a.1.len()));
    println!("inverted index: {} terms", index.len());
    for (word, posts) in index.iter().take(3) {
        let mut p = posts.clone();
        p.sort_unstable();
        println!(
            "  {word:?} appears on {} lines (first: {:?}...)",
            p.len(),
            &p[..p.len().min(5)]
        );
    }
    // Sanity: total postings = total words.
    let total: usize = index.iter().map(|(_, p)| p.len()).sum();
    assert_eq!(total as u64, corpus.words);

    // ---------------- 2. line-length histogram ----------------
    let hist = spawn_cluster(nnodes, NetModel::aws_like(), |comm| {
        let target: DistHashMap<u64, u64> =
            DistHashMap::new(comm.rank, nnodes, nthreads, HashKind::Fx, CombineMode::Eager);
        DistRange::new(0, lines.len() as i64).mapreduce(
            comm,
            nthreads,
            &target,
            reducer::sum,
            |i, emit| {
                let words = split_spaces(&lines[i as usize]).count() as u64;
                emit(words, 1);
            },
        );
        target.to_vec_local()
    });
    let mut hist: Vec<(u64, u64)> = hist.into_iter().flatten().collect();
    hist.sort();
    println!("\nline-length histogram (words per line → lines):");
    for (len, n) in &hist {
        println!("  {len:>3} words: {n:>7} {}", "▪".repeat((*n * 40 / lines.len() as u64) as usize));
    }
    assert_eq!(hist.iter().map(|(_, n)| n).sum::<u64>() as usize, lines.len());

    // ---------------- 3. per-word average line length ----------------
    // Value = (sum of line lengths, occurrences): associative pair-sum.
    let sums = spawn_cluster(nnodes, NetModel::aws_like(), |comm| {
        let target: DistHashMap<String, (u64, u64)> =
            DistHashMap::new(comm.rank, nnodes, nthreads, HashKind::Fx, CombineMode::Eager);
        DistRange::new(0, lines.len() as i64).mapreduce(
            comm,
            nthreads,
            &target,
            |a: &mut (u64, u64), b: (u64, u64)| {
                a.0 += b.0;
                a.1 += b.1;
            },
            |i, emit| {
                let line = &lines[i as usize];
                let len = split_spaces(line).count() as u64;
                for w in split_spaces(line) {
                    emit(w.to_string(), (len, 1));
                }
            },
        );
        target.to_vec_local()
    });
    let mut avgs: Vec<(String, f64, u64)> = sums
        .into_iter()
        .flatten()
        .map(|(w, (sum, n))| (w, sum as f64 / n as f64, n))
        .collect();
    avgs.sort_by(|a, b| b.2.cmp(&a.2));
    println!("\naverage line length of the 5 most frequent words:");
    for (w, avg, n) in avgs.iter().take(5) {
        println!("  {w:?}: avg {avg:.2} words/line over {n} occurrences");
    }
    println!("\nall three jobs ran on the same DistRange → DistHashMap machinery ✓");
}
