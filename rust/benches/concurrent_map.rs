//! Experiment M1: the ConcurrentHashMap design against lock-based maps.
//!
//! The paper's design section argues segments + thread caches (never
//! block) + linear probing beat chained STL maps behind locks. This bench
//! measures insert/combine throughput on a Zipf word stream across thread
//! counts, for:
//!
//! * `ConcurrentHashMap` (paper design)
//! * `ShardedLockMap` (mutex per shard, chained std::HashMap)
//! * `GlobalLockMap` (one mutex, the naive baseline)
//! * serial `ProbeTable` (upper bound per thread at T=1)

use blaze::benchkit::BenchRunner;
use blaze::concurrent::{CachePolicy, ConcurrentHashMap, GlobalLockMap, ProbeTable, ShardedLockMap};
use blaze::corpus::ZipfVocab;
use blaze::hash::{fxhash, HashKind};
use blaze::runtime::executor::{ExecCtx, Executor};
use blaze::util::rng::Xoshiro256;

fn keys(n: usize) -> Vec<String> {
    let vocab = ZipfVocab::english_like(30_000);
    let mut rng = Xoshiro256::new(42);
    (0..n).map(|_| vocab.sample(&mut rng).to_string()).collect()
}

/// Run `body` over `0..n` as chunked stealable tasks on the shared
/// work-stealing pool at the given width — the same executor the engines
/// use, instead of this bench's former ad-hoc thread spawning.
/// `ctx.worker` is the thread-cache id for the map under test.
fn pool_for(threads: usize, n: usize, body: impl Fn(ExecCtx, usize) + Sync) {
    const CHUNK: usize = 1024;
    let exec = Executor::for_threads(Some(threads));
    exec.run_tasks(n.div_ceil(CHUNK), |ctx, t| {
        let lo = t * CHUNK;
        let hi = (lo + CHUNK).min(n);
        for i in lo..hi {
            body(ctx, i);
        }
    })
    .expect("bench task panicked");
}

fn main() {
    let n: usize = std::env::var("BLAZE_BENCH_MAP_OPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2_000_000);
    let keys = keys(n);
    eprintln!("M1: {n} Zipf-distributed upserts");

    let mut runner = BenchRunner::new("M1: concurrent map insert/combine throughput");

    runner.bench("ProbeTable (serial, 1 thread)", "ops", || {
        let mut t: ProbeTable<String, u64> = ProbeTable::new();
        for k in &keys {
            t.upsert_with(fxhash(k.as_bytes()), |e| e == k, || k.clone(), 1, |a, b| *a += b);
        }
        assert!(t.len() > 1000);
        n as f64
    });

    // Both cache policies (the §Perf iteration): the paper's prose default
    // (spill on contention) vs periodic cache-first flushing.
    for (policy, tag) in [
        (CachePolicy::SpillOnContention, "spill-on-contention"),
        (CachePolicy::CacheFirst { flush_at: 64 * 1024 }, "cache-first"),
    ] {
        for threads in [1usize, 2, 4, 8] {
            let keys = &keys;
            runner.bench(
                format!("ConcurrentHashMap[{tag}], {threads}T"),
                "ops",
                move || {
                    let m: ConcurrentHashMap<String, u64> = ConcurrentHashMap::with_policy(
                        blaze::concurrent::default_segments(threads),
                        threads,
                        HashKind::Fx,
                        policy,
                    );
                    pool_for(threads, keys.len(), |ctx, i| {
                        let k = &keys[i];
                        m.upsert_borrowed(
                            ctx.worker,
                            fxhash(k.as_bytes()),
                            |e: &String| e == k,
                            || k.clone(),
                            1,
                            |a, b| *a += b,
                        );
                    });
                    m.sync(threads, |a, b| *a += b);
                    keys.len() as f64
                },
            );
        }
    }

    for threads in [1usize, 4, 8] {
        let keys = &keys;
        runner.bench(format!("ShardedLockMap(64), {threads} threads"), "ops", move || {
            let m: ShardedLockMap<String, u64> = ShardedLockMap::new(64, HashKind::Fx);
            pool_for(threads, keys.len(), |_ctx, i| {
                m.upsert(keys[i].clone(), 1, |a, b| *a += b);
            });
            keys.len() as f64
        });
    }

    for threads in [1usize, 4] {
        let keys = &keys;
        runner.bench(format!("GlobalLockMap, {threads} threads"), "ops", move || {
            let m: GlobalLockMap<String, u64> = GlobalLockMap::new();
            pool_for(threads, keys.len(), |_ctx, i| {
                m.upsert(keys[i].clone(), 1, |a, b| *a += b);
            });
            keys.len() as f64
        });
    }

    runner.finish();
}
