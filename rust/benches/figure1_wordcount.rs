//! Experiment F1 (+ F1-scale): the paper's results figure.
//!
//! "I run both Spark's word count and my MPI/OpenMP implementation on
//! exactly the same hardware ... Here are the results (converted to words
//! per second)" — three bars (Spark, Blaze, Blaze TCM), which this bench
//! regenerates on the simulated cluster, plus the node-count sweep implied
//! by the EMR setup.
//!
//! Expected shape (EXPERIMENTS.md §F1): Blaze ≈ an order of magnitude over
//! Spark; Blaze TCM ≥ Blaze by a small margin.
//!
//! Since the work-stealing executor landed, the scaling figure has a
//! *real* x-axis: **F1-threads** sweeps the pool width (`--threads`)
//! across 1/2/4/8 OS threads on the word-count corpus and records the
//! wall-clock curve in `BENCH_8.json` — actual multicore speedup, not the
//! simulated `threads_per_node` cost model.
//!
//! Scale knobs: BLAZE_BENCH_BYTES (default 32MB; paper used 2GB),
//! BLAZE_BENCH_REPS.

use blaze::benchkit::{bench_corpus_bytes, BenchRunner, MachineReport};
use blaze::cluster::NetModel;
use blaze::corpus::{Corpus, CorpusSpec};
use blaze::util::stats::fmt_bytes;
use blaze::wordcount::{EngineChoice, WordCountJob};

fn main() {
    let bytes = bench_corpus_bytes();
    let corpus = Corpus::generate(&CorpusSpec::with_bytes(bytes));
    eprintln!(
        "F1 corpus: {} ({} words); r5.xlarge shape = 4 threads/node",
        fmt_bytes(corpus.bytes),
        corpus.words
    );

    // --- F1: the paper's three bars (2-node EMR-like cluster). The
    // paper-faithful Blaze bars use the paper's prose cache policy
    // (spill-on-contention); the trailing row shows this repo's optimized
    // cache-first policy (EXPERIMENTS.md §Perf).
    use blaze::concurrent::CachePolicy;
    let paper = CachePolicy::SpillOnContention;
    let ours = CachePolicy::default();
    let mut f1 = BenchRunner::new("F1: words per second — Spark vs Blaze vs Blaze TCM");
    let rows: Vec<(&str, EngineChoice, CachePolicy)> = vec![
        ("Spark", EngineChoice::Spark, paper),
        ("Blaze", EngineChoice::Blaze, paper),
        ("Blaze TCM", EngineChoice::BlazeTcm, paper),
        ("Blaze TCM + cache-first (ours)", EngineChoice::BlazeTcm, ours),
    ];
    for (label, engine, policy) in rows {
        let job = WordCountJob::new(engine)
            .nodes(2)
            .threads_per_node(4)
            .net(NetModel::aws_like())
            .cache_policy(policy);
        f1.bench(label, "words", || {
            let r = job.run(&corpus).expect("run");
            r.words as f64
        });
    }
    f1.finish();
    let spark = f1.results[0].rate();
    let faithful = f1.results[1..3].iter().map(|m| m.rate()).fold(0.0, f64::max);
    let optimized = f1.results[3].rate();
    println!(
        "F1 headline: paper-faithful Blaze/Spark = {:.1}x (paper: ~10x); \
         optimized = {:.1}x\n",
        faithful / spark,
        optimized / spark
    );

    // --- F1-scale: node-count sweep ---
    let mut scale = BenchRunner::new("F1-scale: words per second vs node count");
    for engine in [EngineChoice::Spark, EngineChoice::BlazeTcm] {
        for nodes in [1usize, 2, 4] {
            let job = WordCountJob::new(engine)
                .nodes(nodes)
                .threads_per_node(4)
                .net(NetModel::aws_like());
            scale.bench(format!("{} x{nodes} nodes", engine.label()), "words", || {
                job.run(&corpus).expect("run").words as f64
            });
        }
    }
    scale.finish();

    // --- F1-threads: real executor-width sweep (the paper's scaling
    // curve with an actual x-axis). Ideal net so the curve isolates
    // compute scaling; wall-clock per width (plus the pool's busy
    // fraction) lands in BENCH_8.json alongside the workload grid
    // (merged, not clobbered).
    let mut threads_sweep =
        BenchRunner::new("F1-threads: words per second vs real executor threads");
    let mut machine = MachineReport::new();
    for engine in [EngineChoice::Spark, EngineChoice::BlazeTcm] {
        for threads in [1usize, 2, 4, 8] {
            let job = WordCountJob::new(engine)
                .nodes(2)
                .threads_per_node(4)
                .threads(threads)
                .net(NetModel::ideal());
            threads_sweep.bench(
                format!("{} @ {threads} thread(s)", engine.label()),
                "words",
                || job.run(&corpus).expect("run").words as f64,
            );
            let r = job.run(&corpus).expect("run");
            machine.row_exec(
                "wordcount@figure1",
                engine.label(),
                threads,
                r.wall_secs,
                r.shuffle_bytes,
                r.storage.spilled_bytes,
                r.exec.utilization(r.wall_secs),
            );
        }
    }
    threads_sweep.finish();
    machine.write_merged("BENCH_8.json");
    let t1 = threads_sweep.results[4].rate(); // Blaze TCM @ 1 thread
    let t4 = threads_sweep.results[6].rate(); // Blaze TCM @ 4 threads
    println!(
        "F1-threads headline (Blaze TCM): 1 -> 4 real threads = {:.2}x words/sec",
        t4 / t1.max(1e-12)
    );
}
