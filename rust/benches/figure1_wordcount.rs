//! Experiment F1 (+ F1-scale): the paper's results figure.
//!
//! "I run both Spark's word count and my MPI/OpenMP implementation on
//! exactly the same hardware ... Here are the results (converted to words
//! per second)" — three bars (Spark, Blaze, Blaze TCM), which this bench
//! regenerates on the simulated cluster, plus the node-count sweep implied
//! by the EMR setup.
//!
//! Expected shape (EXPERIMENTS.md §F1): Blaze ≈ an order of magnitude over
//! Spark; Blaze TCM ≥ Blaze by a small margin.
//!
//! Scale knobs: BLAZE_BENCH_BYTES (default 32MB; paper used 2GB),
//! BLAZE_BENCH_REPS.

use blaze::benchkit::{bench_corpus_bytes, BenchRunner};
use blaze::cluster::NetModel;
use blaze::corpus::{Corpus, CorpusSpec};
use blaze::util::stats::fmt_bytes;
use blaze::wordcount::{EngineChoice, WordCountJob};

fn main() {
    let bytes = bench_corpus_bytes();
    let corpus = Corpus::generate(&CorpusSpec::with_bytes(bytes));
    eprintln!(
        "F1 corpus: {} ({} words); r5.xlarge shape = 4 threads/node",
        fmt_bytes(corpus.bytes),
        corpus.words
    );

    // --- F1: the paper's three bars (2-node EMR-like cluster). The
    // paper-faithful Blaze bars use the paper's prose cache policy
    // (spill-on-contention); the trailing row shows this repo's optimized
    // cache-first policy (EXPERIMENTS.md §Perf).
    use blaze::concurrent::CachePolicy;
    let paper = CachePolicy::SpillOnContention;
    let ours = CachePolicy::default();
    let mut f1 = BenchRunner::new("F1: words per second — Spark vs Blaze vs Blaze TCM");
    let rows: Vec<(&str, EngineChoice, CachePolicy)> = vec![
        ("Spark", EngineChoice::Spark, paper),
        ("Blaze", EngineChoice::Blaze, paper),
        ("Blaze TCM", EngineChoice::BlazeTcm, paper),
        ("Blaze TCM + cache-first (ours)", EngineChoice::BlazeTcm, ours),
    ];
    for (label, engine, policy) in rows {
        let job = WordCountJob::new(engine)
            .nodes(2)
            .threads_per_node(4)
            .net(NetModel::aws_like())
            .cache_policy(policy);
        f1.bench(label, "words", || {
            let r = job.run(&corpus).expect("run");
            r.words as f64
        });
    }
    f1.finish();
    let spark = f1.results[0].rate();
    let faithful = f1.results[1..3].iter().map(|m| m.rate()).fold(0.0, f64::max);
    let optimized = f1.results[3].rate();
    println!(
        "F1 headline: paper-faithful Blaze/Spark = {:.1}x (paper: ~10x); \
         optimized = {:.1}x\n",
        faithful / spark,
        optimized / spark
    );

    // --- F1-scale: node-count sweep ---
    let mut scale = BenchRunner::new("F1-scale: words per second vs node count");
    for engine in [EngineChoice::Spark, EngineChoice::BlazeTcm] {
        for nodes in [1usize, 2, 4] {
            let job = WordCountJob::new(engine)
                .nodes(nodes)
                .threads_per_node(4)
                .net(NetModel::aws_like());
            scale.bench(format!("{} x{nodes} nodes", engine.label()), "words", || {
                job.run(&corpus).expect("run").words as f64
            });
        }
    }
    scale.finish();
}
