//! Experiment SV1: open-loop arrival sweep through the job service.
//!
//! Three tenants share one service: `short` submits zero-shuffle greps,
//! `heavy-a` multi-round pageranks, `heavy-b` shuffle-heavy joins. Jobs
//! arrive open-loop (on the schedule's clock, not when the service is
//! ready) at increasing rates; every completed job's latency is
//! submit → done, queue wait included. Per rate we report jobs/sec, p50
//! and p99 latency (overall and for the short class), and the Jain
//! fairness index over per-tenant mean queue-wait per stage (1.0 = every
//! tenant waits equally for the scheduler).
//!
//! At the highest rate the sweep runs twice — weighted-fair and FIFO —
//! and asserts the headline claim: stage-granular fair scheduling beats
//! the single-queue baseline on short-job p99, because a grep no longer
//! waits for every earlier-submitted pagerank to drain. Rows land in
//! `target/bench-results/BENCH_10.json`.
//!
//! Scale knobs: BLAZE_BENCH_SVC_JOBS (default 16 arrivals per run),
//! BLAZE_BENCH_SVC_BYTES (default 48KB heavy-job corpus).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use blaze::service::{
    JobRequest, JobService, JobStatus, SchedPolicy, ServiceConf, WorkloadKind,
};

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Nearest-rank percentile of an ascending-sorted sample.
fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Jain fairness index: `(Σx)² / (n·Σx²)`; 1.0 = perfectly equal.
fn jain(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let sum: f64 = xs.iter().sum();
    let sq: f64 = xs.iter().map(|x| x * x).sum();
    if sq == 0.0 {
        return 1.0;
    }
    (sum * sum) / (xs.len() as f64 * sq)
}

/// The mixed-tenant arrival list: every other job a short grep, the rest
/// alternating pagerank / join from the two heavy tenants.
fn schedule(jobs: usize, heavy_bytes: u64) -> Vec<JobRequest> {
    (0..jobs)
        .map(|i| {
            let seed = i as u64 + 1;
            match i % 4 {
                0 | 2 => JobRequest::new("short", WorkloadKind::Grep)
                    .bytes(heavy_bytes / 4)
                    .seed(seed),
                1 => JobRequest::new("heavy-a", WorkloadKind::PageRank)
                    .bytes(heavy_bytes)
                    .rounds(3)
                    .seed(seed),
                _ => JobRequest::new("heavy-b", WorkloadKind::Join).bytes(heavy_bytes).seed(seed),
            }
        })
        .collect()
}

struct RunStats {
    policy: SchedPolicy,
    gap_ms: u64,
    completed: u64,
    preemptions: u64,
    wall_secs: f64,
    jobs_per_sec: f64,
    p50_all: f64,
    p99_all: f64,
    p50_short: f64,
    p99_short: f64,
    jain_wait: f64,
}

fn run(policy: SchedPolicy, gap_ms: u64, jobs: usize, heavy_bytes: u64) -> RunStats {
    let svc = JobService::new(
        ServiceConf::new().threads(2).slots(2).queue_cap(jobs.max(1)).policy(policy),
    );
    let start = Instant::now();
    let mut handles = Vec::new();
    for (i, req) in schedule(jobs, heavy_bytes).into_iter().enumerate() {
        let due = Duration::from_millis(i as u64 * gap_ms);
        if let Some(sleep) = due.checked_sub(start.elapsed()) {
            std::thread::sleep(sleep);
        }
        handles.push(svc.submit(req).expect("open-loop run is sized under the admission cap"));
    }
    let mut all = Vec::new();
    let mut short = Vec::new();
    for h in &handles {
        match h.wait() {
            JobStatus::Done(s) => {
                all.push(s.latency_secs);
                if h.kind().is_short() {
                    short.push(s.latency_secs);
                }
            }
            other => panic!("bench job {} ended {}", h.id(), other.label()),
        }
    }
    let report = svc.shutdown();
    assert!(report.balances(), "admission ledger must balance:\n{}", report.render());
    all.sort_by(f64::total_cmp);
    short.sort_by(f64::total_cmp);
    // Fairness of scheduler attention: each tenant's mean queue-wait per
    // completed stage.
    let waits: BTreeMap<&str, f64> = report
        .tenants
        .iter()
        .map(|t| {
            let stages = t.metrics.count("sched.stages").max(1) as f64;
            (t.name.as_str(), t.metrics.value("sched.queue_wait") / stages)
        })
        .collect();
    let per_tenant: Vec<f64> = waits.values().copied().collect();
    RunStats {
        policy,
        gap_ms,
        completed: report.completed,
        preemptions: report.preemptions,
        wall_secs: report.wall_secs,
        jobs_per_sec: report.completed as f64 / report.wall_secs.max(1e-9),
        p50_all: percentile(&all, 50.0),
        p99_all: percentile(&all, 99.0),
        p50_short: percentile(&short, 50.0),
        p99_short: percentile(&short, 99.0),
        jain_wait: jain(&per_tenant),
    }
}

fn row_json(r: &RunStats) -> String {
    format!(
        "{{\"bench\": \"service\", \"policy\": \"{}\", \"gap_ms\": {}, \"completed\": {}, \
         \"preemptions\": {}, \"wall_secs\": {:.4}, \"jobs_per_sec\": {:.4}, \
         \"p50_secs\": {:.4}, \"p99_secs\": {:.4}, \"p50_short_secs\": {:.4}, \
         \"p99_short_secs\": {:.4}, \"jain_fairness\": {:.4}}}",
        r.policy.name(),
        r.gap_ms,
        r.completed,
        r.preemptions,
        r.wall_secs,
        r.jobs_per_sec,
        r.p50_all,
        r.p99_all,
        r.p50_short,
        r.p99_short,
        r.jain_wait,
    )
}

fn print_row(r: &RunStats) {
    println!(
        "  {:<5} gap={:>3}ms  {:>5.2} jobs/s  p50 {:>7.3}s  p99 {:>7.3}s  \
         short p50 {:>7.3}s p99 {:>7.3}s  jain {:.3}  ({} preemption(s))",
        r.policy.name(),
        r.gap_ms,
        r.jobs_per_sec,
        r.p50_all,
        r.p99_all,
        r.p50_short,
        r.p99_short,
        r.jain_wait,
        r.preemptions,
    );
}

fn main() {
    let jobs = env_u64("BLAZE_BENCH_SVC_JOBS", 16) as usize;
    let heavy_bytes = env_u64("BLAZE_BENCH_SVC_BYTES", 48 << 10);
    // Arrival gaps, fastest last: the sweep tightens until the service is
    // saturated and queueing dominates.
    let gaps: [u64; 3] = [60, 25, 8];
    println!(
        "SV1: open-loop arrivals, {jobs} job(s)/run, heavy corpus {heavy_bytes} B, \
         3 tenants (grep / pagerank / join), 2 slots x 2 threads"
    );

    let mut rows = Vec::new();
    for gap in gaps {
        let r = run(SchedPolicy::Fair, gap, jobs, heavy_bytes);
        print_row(&r);
        rows.push(r);
    }
    let fifo = run(SchedPolicy::Fifo, gaps[gaps.len() - 1], jobs, heavy_bytes);
    print_row(&fifo);

    let fair_high = &rows[rows.len() - 1];
    println!(
        "\nhighest rate, short-job p99: fair {:.3}s vs fifo {:.3}s ({:.1}x)",
        fair_high.p99_short,
        fifo.p99_short,
        fifo.p99_short / fair_high.p99_short.max(1e-9),
    );
    assert!(
        fair_high.p99_short < fifo.p99_short,
        "fair scheduling must beat FIFO on short-job p99 at the highest arrival rate \
         (fair {:.3}s >= fifo {:.3}s)",
        fair_high.p99_short,
        fifo.p99_short,
    );

    rows.push(fifo);
    let json: String =
        rows.iter().map(row_json).collect::<Vec<_>>().join(",\n  ");
    let out = format!("[\n  {json}\n]\n");
    let dir = std::path::Path::new("target/bench-results");
    std::fs::create_dir_all(dir).expect("create target/bench-results");
    let path = dir.join("BENCH_10.json");
    std::fs::write(&path, out).expect("write BENCH_10.json");
    println!("wrote {}", path.display());
}
