//! Experiment I1: iterative jobs and the in-memory partition cache.
//!
//! The paper calls Spark "an in-memory implementation of MapReduce", but
//! benchmarks only a single-pass job — where caching never fires. This
//! bench asks the question the paper couldn't: on workloads that re-read
//! their input every round (PageRank, k-means), what does the cache buy,
//! and does Blaze's advantage survive? Each workload runs on both engines
//! at two cache budgets:
//!
//! * `unbounded` — parsed input splits are cached after round 0 and every
//!   later round is served from memory (Spark's persisted-RDD regime);
//! * `0` — every `put` is rejected, every round re-parses from scratch
//!   (the recompute ablation).
//!
//! Rows report map emissions/sec across the whole multi-round run; the
//! headline prints per-round wall clock (warm rounds only) and the cache
//! hit rates, which must be >0 in the unbounded rows.
//!
//! Scale knobs: BLAZE_BENCH_BYTES (default 32MB, quartered here because
//! every round re-reads it), BLAZE_BENCH_REPS.

use std::sync::Arc;

use blaze::benchkit::{bench_corpus_bytes, BenchRunner};
use blaze::cache::CacheBudget;
use blaze::cluster::NetModel;
use blaze::corpus::{Corpus, CorpusSpec};
use blaze::engines::Engine;
use blaze::mapreduce::{run_iterative, IterativeReport, IterativeSpec, JobInputs, JobSpec};
use blaze::util::stats::fmt_bytes;
use blaze::workloads::{synthesize_points, Components, KMeans, PageRank};

const ROUNDS: usize = 5;

fn spec(engine: Engine) -> JobSpec {
    JobSpec::new(engine).nodes(2).threads_per_node(4).net(NetModel::aws_like())
}

fn it_spec(budget: CacheBudget) -> IterativeSpec {
    // tolerance 0 with a fixed round count: every config does equal work.
    IterativeSpec::new(ROUNDS).tolerance(0.0).cache_budget(budget)
}

fn total_records(r: &IterativeReport) -> f64 {
    r.iters.iter().map(|i| i.records).sum::<u64>() as f64
}

/// Mean wall of the warm rounds (1..), where the cache can matter.
fn warm_round_secs(r: &IterativeReport) -> f64 {
    let warm = &r.iters[1..];
    warm.iter().map(|i| i.wall_secs).sum::<f64>() / warm.len().max(1) as f64
}

fn main() {
    let bytes = (bench_corpus_bytes() / 4).max(1 << 20);
    let corpus = Corpus::generate(&CorpusSpec {
        target_bytes: bytes,
        vocab_size: 20_000,
        ..Default::default()
    });
    let edges = JobInputs::new().relation("edges", &corpus);
    let npoints = (bytes / 64) as usize; // ~comparable parse volume
    let points =
        JobInputs::new().relation_lines("points", Arc::new(synthesize_points(npoints, 4, 8, 7)));
    eprintln!(
        "I1: {} of edges / {npoints} points x {ROUNDS} rounds; 2 nodes x 4 threads, aws-like net",
        fmt_bytes(corpus.bytes),
    );

    let engines = [Engine::Spark, Engine::BlazeTcm];
    let budgets = [("unbounded", CacheBudget::Unbounded), ("0", CacheBudget::Bytes(0))];

    let mut runner = BenchRunner::new("I1: iterative jobs — cache budget ablation");
    for engine in engines {
        for (label, budget) in budgets {
            let edges = &edges;
            runner.bench(
                format!("pagerank x{ROUNDS} / {} / cache={label}", engine.label()),
                "recs",
                move || {
                    let r = run_iterative(&spec(engine), &it_spec(budget), &PageRank::new(), edges)
                        .expect("pagerank");
                    total_records(&r)
                },
            );
        }
    }
    for engine in engines {
        for (label, budget) in budgets {
            let points = &points;
            runner.bench(
                format!("kmeans x{ROUNDS} / {} / cache={label}", engine.label()),
                "recs",
                move || {
                    let r = run_iterative(&spec(engine), &it_spec(budget), &KMeans::new(8), points)
                        .expect("kmeans");
                    total_records(&r)
                },
            );
        }
    }
    // Connected components: min-label propagation over the same edge
    // relation — the reducer is min, so warm rounds are pure lookups.
    for engine in engines {
        for (label, budget) in budgets {
            let edges = &edges;
            runner.bench(
                format!("components x{ROUNDS} / {} / cache={label}", engine.label()),
                "recs",
                move || {
                    let r = run_iterative(
                        &spec(engine),
                        &it_spec(budget),
                        &Components::new(),
                        edges,
                    )
                    .expect("components");
                    total_records(&r)
                },
            );
        }
    }
    runner.finish();

    // Headline: warm-round wall clock + hit rates, one fresh run per cell.
    println!("\nI1 headline (per warm round, cached vs recompute):");
    for engine in engines {
        let warm = run_iterative(&spec(engine), &it_spec(CacheBudget::Unbounded), &PageRank::new(), &edges)
            .expect("pagerank");
        let cold = run_iterative(&spec(engine), &it_spec(CacheBudget::Bytes(0)), &PageRank::new(), &edges)
            .expect("pagerank");
        assert_eq!(warm.state, cold.state, "cache must not change results");
        assert!(warm.cache.hit_rate() > 0.0, "unbounded run must hit");
        println!(
            "  pagerank / {:<16} warm {:>8.3}s/round vs recompute {:>8.3}s/round ({:.2}x)   cache: {}",
            engine.label(),
            warm_round_secs(&warm),
            warm_round_secs(&cold),
            warm_round_secs(&cold) / warm_round_secs(&warm).max(1e-12),
            warm.cache,
        );
    }
}
