//! Experiment M2: the "Blaze TCM" bar isolated — allocation cost in the
//! insert hot path.
//!
//! The paper's fastest configuration links TCMalloc; its benefit on word
//! count is cheaper small allocations (a `std::string` per token). We
//! isolate exactly that effect three ways:
//!
//! * engine level: `KeyPath::AllocPerToken` vs `KeyPath::ZeroAlloc`;
//! * map level: owned-key upsert vs borrowed-key upsert;
//! * arena level: per-key `String` vs `StrArena` interning.

use blaze::benchkit::{bench_corpus_bytes, BenchRunner};
use blaze::cluster::NetModel;
use blaze::concurrent::ProbeTable;
use blaze::corpus::{Corpus, CorpusSpec, ZipfVocab};
use blaze::hash::fxhash;
use blaze::util::arena::StrArena;
use blaze::util::rng::Xoshiro256;
use blaze::util::stats::fmt_bytes;
use blaze::wordcount::{EngineChoice, WordCountJob};

fn main() {
    let bytes = bench_corpus_bytes();
    let corpus = Corpus::generate(&CorpusSpec::with_bytes(bytes));
    eprintln!("M2 corpus: {} ({} words)", fmt_bytes(corpus.bytes), corpus.words);

    // --- engine level: the two Blaze bars ---
    let mut runner = BenchRunner::new("M2: allocation in the insert hot path");
    for engine in [EngineChoice::Blaze, EngineChoice::BlazeTcm] {
        let job = WordCountJob::new(engine)
            .nodes(1)
            .threads_per_node(4)
            .net(NetModel::ideal());
        let corpus = &corpus;
        let label = match engine {
            EngineChoice::Blaze => "engine: alloc-per-token (Blaze)",
            _ => "engine: zero-alloc path (Blaze TCM)",
        };
        runner.bench(label, "words", move || {
            job.run(corpus).expect("run").words as f64
        });
    }

    // --- map level micro: same stream, owned vs borrowed upsert ---
    let vocab = ZipfVocab::english_like(30_000);
    let mut rng = Xoshiro256::new(7);
    let stream: Vec<&str> = (0..2_000_000).map(|_| vocab.sample(&mut rng)).collect();

    {
        let stream = &stream;
        runner.bench("probe: upsert(owned String per op)", "ops", move || {
            let mut t: ProbeTable<String, u64> = ProbeTable::new();
            for &w in stream {
                t.upsert(fxhash(w.as_bytes()), w.to_string(), 1, |a, b| *a += b);
            }
            stream.len() as f64
        });
    }
    {
        let stream = &stream;
        runner.bench("probe: upsert_with(borrowed &str)", "ops", move || {
            let mut t: ProbeTable<String, u64> = ProbeTable::new();
            for &w in stream {
                t.upsert_with(fxhash(w.as_bytes()), |k| k == w, || w.to_string(), 1, |a, b| {
                    *a += b
                });
            }
            stream.len() as f64
        });
    }
    // --- arena level: interned keys (StrRef is Copy, 8 bytes) ---
    {
        let stream = &stream;
        runner.bench("probe: arena-interned StrRef keys", "ops", move || {
            // RefCell: the match closure reads the arena, the make-key
            // closure appends; upsert_with never calls both in one probe.
            let arena = std::cell::RefCell::new(StrArena::new());
            let mut t: ProbeTable<blaze::util::arena::StrRef, u64> = ProbeTable::new();
            for &w in stream {
                let h = fxhash(w.as_bytes());
                t.upsert_with(
                    h,
                    |r| arena.borrow().get(*r) == w,
                    || arena.borrow_mut().intern(w),
                    1,
                    |a, b| *a += b,
                );
            }
            stream.len() as f64
        });
    }
    runner.finish();
}
