//! Experiment S1: the in-memory → spill cliff the paper never measured.
//!
//! The paper's 300% MPI/OpenMP-over-Spark result is stated for jobs whose
//! working set fits in memory. This bench sweeps the bounded-memory
//! exchange's budget (`--spill-threshold`) from unbounded down to 4 KB on
//! both engines: every configuration produces bit-identical output (the
//! integration suite enforces it), so the sweep isolates exactly what the
//! storage hierarchy costs — sort-and-spill writes, loser-tree merge
//! reads — as memory shrinks.
//!
//! Experiment S2 rides along: the data-path ablation. The same sweep's
//! spill traffic is re-run under every combination of block compression
//! (`--compress`) and key dictionaries (`--dict-keys`), recording both
//! the logical spill volume and what the disk tier actually stored —
//! the compressed-vs-raw byte gap that moves the cliff. Rows land
//! merge-keyed in `BENCH_9.json`.
//!
//! Scale knobs: BLAZE_BENCH_BYTES (default 32MB), BLAZE_BENCH_REPS.

use std::sync::Arc;

use blaze::benchkit::{bench_corpus_bytes, BenchRunner, MachineReport};
use blaze::cluster::NetModel;
use blaze::corpus::{Corpus, CorpusSpec, Tokenizer};
use blaze::engines::Engine;
use blaze::mapreduce::{JobInputs, JobSpec};
use blaze::util::stats::fmt_bytes;
use blaze::workloads::{Join, WordCount};

fn spec(engine: Engine, threshold: Option<u64>) -> JobSpec {
    let s = JobSpec::new(engine).nodes(2).threads_per_node(4).net(NetModel::aws_like());
    match threshold {
        Some(t) => s.spill_threshold(t),
        None => s,
    }
}

const THRESHOLDS: [(&str, Option<u64>); 4] = [
    ("unbounded", None),
    ("1MB", Some(1 << 20)),
    ("64KB", Some(64 << 10)),
    ("4KB", Some(4 << 10)),
];

fn main() {
    let bytes = bench_corpus_bytes();
    let corpus = Corpus::generate(&CorpusSpec::with_bytes(bytes));
    eprintln!(
        "S1 corpus: {} ({} words); 2 nodes x 4 threads, aws-like net",
        fmt_bytes(corpus.bytes),
        corpus.words
    );
    let engines = [Engine::Spark, Engine::BlazeTcm];

    let mut runner = BenchRunner::new("S1: spill-threshold sweep (bounded-memory exchange)");
    let mut machine = MachineReport::new();

    let wc = Arc::new(WordCount::new(Tokenizer::Spaces));
    for engine in engines {
        for (label, threshold) in THRESHOLDS {
            {
                let corpus = &corpus;
                let wc = &wc;
                runner.bench(
                    format!("wordcount @ {label} / {}", engine.label()),
                    "recs",
                    move || {
                        spec(engine, threshold).run_str(wc, corpus).expect("wordcount").records
                            as f64
                    },
                );
            }
            let r = spec(engine, threshold).run_str(&wc, &corpus).expect("wordcount");
            eprintln!("      spilled: {}", fmt_bytes(r.storage.spilled_bytes));
            machine.row(
                format!("wordcount@{label}"),
                engine.label(),
                r.wall_secs,
                r.shuffle_bytes,
                r.storage.spilled_bytes,
            );
        }
    }

    // Join: heavier values (both sides' lines ride the shuffle), so the
    // cliff arrives at larger thresholds.
    let right = Corpus::generate(&CorpusSpec {
        target_bytes: bytes,
        seed: CorpusSpec::default().seed + 1,
        ..Default::default()
    });
    let join_inputs = JobInputs::new()
        .relation_lines("left", Arc::new(corpus.lines.clone()))
        .relation("right", &right);
    let join = Arc::new(Join::new());
    for engine in engines {
        for (label, threshold) in THRESHOLDS {
            {
                let join_inputs = &join_inputs;
                let join = &join;
                runner.bench(
                    format!("join @ {label} / {}", engine.label()),
                    "recs",
                    move || {
                        spec(engine, threshold)
                            .run_inputs(join, join_inputs)
                            .expect("join")
                            .records as f64
                    },
                );
            }
            let r = spec(engine, threshold).run_inputs(&join, &join_inputs).expect("join");
            eprintln!("      spilled: {}", fmt_bytes(r.storage.spilled_bytes));
            machine.row(
                format!("join@{label}"),
                engine.label(),
                r.wall_secs,
                r.shuffle_bytes,
                r.storage.spilled_bytes,
            );
        }
    }

    // S2: data-path ablation — compression x dictionary over the same
    // Zipf corpus. Each config replays the threshold sweep, so the rows
    // expose both the on-disk byte gap (spilled vs stored) and where
    // the wall-clock cliff lands per codec.
    const CONFIGS: [(&str, bool, bool); 4] = [
        ("lz4+dict", true, true),
        ("lz4", true, false),
        ("dict", false, true),
        ("raw", false, false),
    ];
    let mut datapath = MachineReport::new();
    eprintln!("\nS2: data-path ablation (compression x dictionary)");
    for (config, compress, dict) in CONFIGS {
        for (label, threshold) in THRESHOLDS {
            let r = spec(Engine::BlazeTcm, threshold)
                .compress(compress)
                .dict_keys(dict)
                .run_str(&wc, &corpus)
                .expect("wordcount");
            eprintln!(
                "  blaze-tcm {config:>8} @ {label:>9}: {:.3}s, spilled {} -> stored {}",
                r.wall_secs,
                fmt_bytes(r.storage.spilled_bytes),
                fmt_bytes(r.storage.disk_bytes_written),
            );
            datapath.row_datapath(
                format!("wordcount@{label}"),
                format!("blaze-tcm/{config}"),
                r.wall_secs,
                r.shuffle_bytes,
                r.storage.spilled_bytes,
                r.storage.disk_bytes_written,
            );
        }
        // Spark pays the codec on persisted shuffle blocks even before
        // anything spills; one bounded point per config records that.
        let r = spec(Engine::Spark, Some(64 << 10))
            .compress(compress)
            .dict_keys(dict)
            .run_str(&wc, &corpus)
            .expect("wordcount");
        eprintln!(
            "  spark     {config:>8} @      64KB: {:.3}s, spilled {} -> stored {}",
            r.wall_secs,
            fmt_bytes(r.storage.spilled_bytes),
            fmt_bytes(r.storage.disk_bytes_written),
        );
        datapath.row_datapath(
            "wordcount@64KB",
            format!("spark/{config}"),
            r.wall_secs,
            r.shuffle_bytes,
            r.storage.spilled_bytes,
            r.storage.disk_bytes_written,
        );
    }
    datapath.write_merged("BENCH_9.json");

    runner.finish();
    machine.write("BENCH_spill_sweep.json");
}
