//! Experiment S1: the in-memory → spill cliff the paper never measured.
//!
//! The paper's 300% MPI/OpenMP-over-Spark result is stated for jobs whose
//! working set fits in memory. This bench sweeps the bounded-memory
//! exchange's budget (`--spill-threshold`) from unbounded down to 4 KB on
//! both engines: every configuration produces bit-identical output (the
//! integration suite enforces it), so the sweep isolates exactly what the
//! storage hierarchy costs — sort-and-spill writes, loser-tree merge
//! reads — as memory shrinks.
//!
//! Scale knobs: BLAZE_BENCH_BYTES (default 32MB), BLAZE_BENCH_REPS.

use std::sync::Arc;

use blaze::benchkit::{bench_corpus_bytes, BenchRunner, MachineReport};
use blaze::cluster::NetModel;
use blaze::corpus::{Corpus, CorpusSpec, Tokenizer};
use blaze::engines::Engine;
use blaze::mapreduce::{JobInputs, JobSpec};
use blaze::util::stats::fmt_bytes;
use blaze::workloads::{Join, WordCount};

fn spec(engine: Engine, threshold: Option<u64>) -> JobSpec {
    let s = JobSpec::new(engine).nodes(2).threads_per_node(4).net(NetModel::aws_like());
    match threshold {
        Some(t) => s.spill_threshold(t),
        None => s,
    }
}

const THRESHOLDS: [(&str, Option<u64>); 4] = [
    ("unbounded", None),
    ("1MB", Some(1 << 20)),
    ("64KB", Some(64 << 10)),
    ("4KB", Some(4 << 10)),
];

fn main() {
    let bytes = bench_corpus_bytes();
    let corpus = Corpus::generate(&CorpusSpec::with_bytes(bytes));
    eprintln!(
        "S1 corpus: {} ({} words); 2 nodes x 4 threads, aws-like net",
        fmt_bytes(corpus.bytes),
        corpus.words
    );
    let engines = [Engine::Spark, Engine::BlazeTcm];

    let mut runner = BenchRunner::new("S1: spill-threshold sweep (bounded-memory exchange)");
    let mut machine = MachineReport::new();

    let wc = Arc::new(WordCount::new(Tokenizer::Spaces));
    for engine in engines {
        for (label, threshold) in THRESHOLDS {
            {
                let corpus = &corpus;
                let wc = &wc;
                runner.bench(
                    format!("wordcount @ {label} / {}", engine.label()),
                    "recs",
                    move || {
                        spec(engine, threshold).run_str(wc, corpus).expect("wordcount").records
                            as f64
                    },
                );
            }
            let r = spec(engine, threshold).run_str(&wc, &corpus).expect("wordcount");
            eprintln!("      spilled: {}", fmt_bytes(r.storage.spilled_bytes));
            machine.row(
                format!("wordcount@{label}"),
                engine.label(),
                r.wall_secs,
                r.shuffle_bytes,
                r.storage.spilled_bytes,
            );
        }
    }

    // Join: heavier values (both sides' lines ride the shuffle), so the
    // cliff arrives at larger thresholds.
    let right = Corpus::generate(&CorpusSpec {
        target_bytes: bytes,
        seed: CorpusSpec::default().seed + 1,
        ..Default::default()
    });
    let join_inputs = JobInputs::new()
        .relation_lines("left", Arc::new(corpus.lines.clone()))
        .relation("right", &right);
    let join = Arc::new(Join::new());
    for engine in engines {
        for (label, threshold) in THRESHOLDS {
            {
                let join_inputs = &join_inputs;
                let join = &join;
                runner.bench(
                    format!("join @ {label} / {}", engine.label()),
                    "recs",
                    move || {
                        spec(engine, threshold)
                            .run_inputs(join, join_inputs)
                            .expect("join")
                            .records as f64
                    },
                );
            }
            let r = spec(engine, threshold).run_inputs(&join, &join_inputs).expect("join");
            eprintln!("      spilled: {}", fmt_bytes(r.storage.spilled_bytes));
            machine.row(
                format!("join@{label}"),
                engine.label(),
                r.wall_secs,
                r.shuffle_bytes,
                r.storage.spilled_bytes,
            );
        }
    }

    runner.finish();
    machine.write("BENCH_spill_sweep.json");
}
