//! Experiment A3: the paper's third cause — "My design performs local
//! reduce during the map phase before shuffling the (key, value) pairs so
//! that the network traffic is significantly reduced."
//!
//! Blaze with eager combining (pending maps combine continuously) vs
//! `CombineMode::None` (every emission shipped raw), under a slow network
//! where shuffle bytes actually hurt; Spark's per-partition combiner
//! on/off for contrast. Reports both words/sec and bytes shuffled.

use blaze::benchkit::{bench_corpus_bytes, BenchRunner};
use blaze::cluster::NetModel;
use blaze::corpus::{Corpus, CorpusSpec, Tokenizer};
use blaze::dist::CombineMode;
use blaze::engines::spark::{word_count_lines, SparkConf, SparkContext};
use blaze::metrics::Table;
use blaze::util::stats::fmt_bytes;
use blaze::wordcount::{EngineChoice, WordCountJob};
use std::sync::Arc;

fn main() {
    let bytes = bench_corpus_bytes();
    // Tiled small-vocab corpus: heavy key repetition makes combining matter.
    let corpus = Corpus::generate(&CorpusSpec {
        target_bytes: bytes,
        base_block_bytes: Some((bytes / 32).clamp(64 << 10, 4 << 20)),
        vocab_size: 10_000,
        ..Default::default()
    });
    eprintln!("A3 corpus: {} ({} words)", fmt_bytes(corpus.bytes), corpus.words);

    let mut shuffled: Vec<(String, u64)> = Vec::new();

    let mut runner = BenchRunner::new("A3: map-side local reduce (slow network)");
    for (name, combine) in [
        ("blaze: eager combine (paper)", CombineMode::Eager),
        ("blaze: no combine (ship all pairs)", CombineMode::None),
    ] {
        let job = WordCountJob::new(EngineChoice::BlazeTcm)
            .nodes(4)
            .threads_per_node(2)
            .net(NetModel::slow()) // make shuffle volume visible in time
            .combine(combine);
        let corpus = &corpus;
        let mut last_bytes = 0u64;
        runner.bench(name, "words", || {
            let r = job.run(corpus).expect("run");
            last_bytes = r.shuffle_bytes;
            r.words as f64
        });
        shuffled.push((name.to_string(), last_bytes));
    }

    // Spark contrast: per-partition combiner on/off (records shipped).
    let lines = Arc::new(corpus.lines.clone());
    for (name, on) in [
        ("spark: map-side combine on", true),
        ("spark: map-side combine off", false),
    ] {
        let lines = Arc::clone(&lines);
        let mut last_bytes = 0u64;
        runner.bench(name, "words", || {
            let mut conf = SparkConf::emr_like(4, 2);
            conf.map_side_combine = on;
            conf.net = NetModel::slow();
            let ctx = SparkContext::new(conf);
            let total = word_count_lines(&ctx, Arc::clone(&lines), Tokenizer::Spaces)
                .expect("run")
                .values()
                .sum::<u64>() as f64;
            last_bytes = ctx
                .metrics()
                .shuffle_bytes_written
                .load(std::sync::atomic::Ordering::Relaxed);
            total
        });
        shuffled.push((name.to_string(), last_bytes));
    }
    runner.finish();

    let mut t = Table::new("A3: bytes serialized for shuffle", &["config", "bytes"]);
    for (name, b) in shuffled {
        t.row(&[name, fmt_bytes(b)]);
    }
    println!("{}", t.to_markdown());
}
