//! Experiment A2: the paper's second cause — "MPI/OpenMP is not designed
//! for fault tolerance ... Fault tolerance incurs additional overhead."
//!
//! Two measurements:
//! 1. **Steady-state tax**: Spark-sim with FT on (persisted shuffle blocks
//!    on real disk + retry bookkeeping) vs FT off, no failures injected.
//! 2. **Recovery cost**: one injected failure — Spark retries one task
//!    from lineage; Blaze reruns the whole job (the paper's "run the task
//!    multiple times" regime).

use blaze::benchkit::{bench_corpus_bytes, BenchRunner};
use blaze::cluster::{FailurePlan, NetModel};
use blaze::corpus::{Corpus, CorpusSpec, Tokenizer};
use blaze::engines::spark::{word_count_lines, SparkConf, SparkContext};
use blaze::util::stats::fmt_bytes;
use blaze::wordcount::{EngineChoice, WordCountJob};
use std::sync::Arc;

fn main() {
    let bytes = bench_corpus_bytes();
    let corpus = Corpus::generate(&CorpusSpec::with_bytes(bytes));
    let lines = Arc::new(corpus.lines.clone());
    eprintln!("A2 corpus: {} ({} words)", fmt_bytes(corpus.bytes), corpus.words);

    // --- 1. steady-state FT tax (no failures) ---
    let mut tax = BenchRunner::new("A2a: fault-tolerance steady-state tax (Spark-sim)");
    for (name, ft) in [("spark: FT on (persist+lineage)", true), ("spark: FT off", false)] {
        let lines = Arc::clone(&lines);
        tax.bench(name, "words", move || {
            let mut conf = SparkConf::emr_like(2, 4);
            conf.fault_tolerance = ft;
            conf.net = NetModel::aws_like();
            let ctx = SparkContext::new(conf);
            word_count_lines(&ctx, Arc::clone(&lines), Tokenizer::Spaces)
                .expect("run")
                .values()
                .sum::<u64>() as f64
        });
    }
    tax.finish();

    // --- 2. recovery cost under one failure ---
    let mut rec = BenchRunner::new("A2b: cost of one failure (recovery strategies)");
    let corpus_ref = &corpus;
    rec.bench("spark: 1 task fails, lineage retry", "words", || {
        let r = WordCountJob::new(EngineChoice::Spark)
            .nodes(2)
            .threads_per_node(4)
            .net(NetModel::aws_like())
            .failures(FailurePlan::none().fail_task(0, 1))
            .run(corpus_ref)
            .expect("recovers");
        r.words as f64
    });
    rec.bench("blaze: 1 node fails, whole-job rerun", "words", || {
        let r = WordCountJob::new(EngineChoice::BlazeTcm)
            .nodes(2)
            .threads_per_node(4)
            .net(NetModel::aws_like())
            .failures(FailurePlan::none().fail_node(1, 0))
            .run(corpus_ref)
            .expect("recovers");
        r.words as f64
    });
    rec.bench("blaze: clean run (baseline)", "words", || {
        WordCountJob::new(EngineChoice::BlazeTcm)
            .nodes(2)
            .threads_per_node(4)
            .net(NetModel::aws_like())
            .run(corpus_ref)
            .expect("run")
            .words as f64
    });
    rec.finish();
}
