//! Experiment C7: the trace-driven eviction-policy lab.
//!
//! Which eviction policy should the partition cache run? Synthetic
//! access patterns prove nothing about *these* workloads, so this bench
//! answers with real traces: it attaches a [`TraceRecorder`] to live
//! pagerank / kmeans / sessionize runs, captures every `get`/`put` the
//! engines issue against the shared partition cache, then replays each
//! trace through **every** [`PolicySpec`] at a sweep of byte budgets
//! (fractions of the trace's total put volume). Replay drives a real
//! `MemoryTier` — real admission, real victim selection — so the
//! reported hit-rates are exact, and identical for identical inputs.
//!
//! The interesting regime is budget < working set. Iterative rounds
//! re-read the static relations (edges, points) every round while the
//! fed-back state relation streams one-round-lived generations through
//! the cache — exactly the scan pollution LRU is worst at (cyclic
//! re-access under pressure degenerates to zero hits). The bench asserts
//! that on at least one iterative trace a scan-resistant policy (SLRU,
//! GDSF, or the TinyLFU filter) beats plain LRU.
//!
//! Artifacts: per-(trace × policy × budget) rows — hit-rate + replay
//! wall — merge into `target/bench-results/BENCH_7.json`; the raw
//! binary trace logs land next to it as `trace_<workload>.bin`.

use std::sync::Arc;
use std::time::Instant;

use blaze::benchkit::MachineReport;
use blaze::cache::{CacheBudget, PartitionCache, PolicySpec};
use blaze::cluster::NetModel;
use blaze::corpus::{Corpus, CorpusSpec};
use blaze::engines::Engine;
use blaze::mapreduce::{run_chained, run_iterative, IterativeSpec, JobInputs, JobSpec};
use blaze::metrics::Table;
use blaze::storage::trace::{replay, TraceEvent};
use blaze::storage::TraceRecorder;
use blaze::util::stats::fmt_bytes;
use blaze::workloads::{synthesize_logs, synthesize_points, KMeans, PageRank, Sessionize};

const ROUNDS: usize = 8;

/// Many nodes → many splits per relation, so the budget sweep has real
/// granularity to bite on (2 nodes would mean two huge monolithic
/// splits). Ideal net: recording wall is irrelevant here.
fn spec(rec: &Arc<TraceRecorder>) -> JobSpec {
    JobSpec::new(Engine::BlazeTcm)
        .nodes(8)
        .threads_per_node(2)
        .net(NetModel::ideal())
        .trace(Arc::clone(rec))
}

/// One recorded workload trace, ready for replay.
struct Trace {
    name: &'static str,
    /// Whether the ISSUE's "scan-resistant beats LRU" claim is asserted
    /// on this trace (the iterative ones; sessionize is single-pass).
    iterative: bool,
    events: Vec<TraceEvent>,
    put_bytes: u64,
}

fn record_pagerank() -> Trace {
    let corpus = Corpus::generate(&CorpusSpec {
        target_bytes: 1 << 20,
        vocab_size: 5_000,
        ..Default::default()
    });
    let edges = JobInputs::new().relation("edges", &corpus);
    let rec = Arc::new(TraceRecorder::new());
    let it = IterativeSpec::new(ROUNDS).tolerance(0.0).cache_budget(CacheBudget::Unbounded);
    run_iterative(&spec(&rec), &it, &PageRank::new(), &edges).expect("pagerank");
    Trace { name: "pagerank", iterative: true, events: rec.events(), put_bytes: rec.put_bytes() }
}

fn record_kmeans() -> Trace {
    let points =
        JobInputs::new().relation_lines("points", Arc::new(synthesize_points(16_384, 4, 8, 7)));
    let rec = Arc::new(TraceRecorder::new());
    let it = IterativeSpec::new(ROUNDS).tolerance(0.0).cache_budget(CacheBudget::Unbounded);
    run_iterative(&spec(&rec), &it, &KMeans::new(8), &points).expect("kmeans");
    Trace { name: "kmeans", iterative: true, events: rec.events(), put_bytes: rec.put_bytes() }
}

fn record_sessionize() -> Trace {
    let logs = JobInputs::new()
        .relation_lines("logs", Arc::new(synthesize_logs(64, 30_000, 1_800, 11)));
    let rec = Arc::new(TraceRecorder::new());
    // Chained jobs cache through an injected shared store; the recorder
    // attaches to it directly.
    let cache = Arc::new(PartitionCache::new(CacheBudget::Unbounded));
    cache.attach_recorder(Arc::clone(&rec));
    let sp = spec(&rec).shared_cache(cache);
    run_chained(&sp, &Sessionize::new(1_800), &logs).expect("sessionize");
    Trace { name: "sessionize", iterative: false, events: rec.events(), put_bytes: rec.put_bytes() }
}

fn main() {
    let traces = [record_pagerank(), record_kmeans(), record_sessionize()];
    for t in &traces {
        eprintln!(
            "C7: {} trace — {} event(s), {} put",
            t.name,
            t.events.len(),
            fmt_bytes(t.put_bytes),
        );
        assert!(!t.events.is_empty(), "{} run must touch the cache", t.name);
    }

    let mut table = Table::new(
        "C7: trace-driven hit rates (budget = fraction of trace put volume)",
        &["trace", "budget", "policy", "hit rate", "evict", "reject", "replay (s)"],
    );
    let mut report = MachineReport::new();
    let mut scan_resistant_won = false;
    for t in &traces {
        for denom in [2u64, 4, 8] {
            let budget = (t.put_bytes / denom).max(1);
            let mut lru_rate = 0.0;
            let mut best_other = 0.0;
            for policy in PolicySpec::all() {
                let t0 = Instant::now();
                let stats = replay(&t.events, CacheBudget::Bytes(budget), policy);
                let wall = t0.elapsed().as_secs_f64();
                if policy == PolicySpec::LRU {
                    lru_rate = stats.hit_rate();
                } else {
                    best_other = f64::max(best_other, stats.hit_rate());
                }
                table.row(&[
                    t.name.to_string(),
                    format!("1/{denom}"),
                    policy.to_string(),
                    format!("{:.4}", stats.hit_rate()),
                    stats.evictions.to_string(),
                    stats.rejected.to_string(),
                    format!("{wall:.4}"),
                ]);
                report.row_cache(
                    format!("{}-trace/1-{denom}", t.name),
                    policy.to_string(),
                    wall,
                    stats.hit_rate(),
                );
            }
            if t.iterative && best_other > lru_rate {
                scan_resistant_won = true;
            }
        }
    }
    println!("\n{}", table.to_markdown());
    assert!(
        scan_resistant_won,
        "expected a scan-resistant policy to beat LRU on an iterative trace at some budget"
    );
    println!("(scan-resistant > LRU confirmed on an iterative trace)");

    report.write_merged("BENCH_7.json");
    for t in &traces {
        let rec = TraceRecorder::new();
        for e in &t.events {
            rec.record(e.op, e.key, e.bytes);
        }
        let path =
            std::path::Path::new("target/bench-results").join(format!("trace_{}.bin", t.name));
        if let Some(parent) = path.parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        match std::fs::write(&path, rec.to_bytes()) {
            Ok(()) => println!("(trace written to {})", path.display()),
            Err(e) => eprintln!("warning: could not write {}: {e}", path.display()),
        }
    }
}
