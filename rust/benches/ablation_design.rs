//! Design-choice ablations (DESIGN.md §4 rows the paper leaves implicit):
//!
//! * **Hash function** — fx (default) vs fnv1a vs wyhash, both as raw
//!   throughput on word-like keys and end-to-end through the Blaze engine.
//! * **Key skew** — the map-side-combine benefit as a function of the Zipf
//!   exponent: skewed vocabularies combine well (few hot keys), flat ones
//!   don't, so shuffle volume and throughput should cross over.

use blaze::benchkit::BenchRunner;
use blaze::cluster::NetModel;
use blaze::corpus::{Corpus, CorpusSpec, ZipfVocab};
use blaze::hash::HashKind;
use blaze::metrics::Table;
use blaze::util::rng::Xoshiro256;
use blaze::util::stats::fmt_bytes;
use blaze::wordcount::{EngineChoice, WordCountJob};

fn main() {
    // ---------------- hash-kind sweep ----------------
    let vocab = ZipfVocab::english_like(30_000);
    let mut rng = Xoshiro256::new(3);
    let words: Vec<&str> = (0..2_000_000).map(|_| vocab.sample(&mut rng)).collect();

    let mut runner = BenchRunner::new("D1: hash function choice");
    for kind in [HashKind::Fx, HashKind::Fnv1a, HashKind::Wy] {
        let words = &words;
        runner.bench(format!("raw hash throughput: {kind:?}"), "keys", move || {
            let mut acc = 0u64;
            for w in words {
                acc ^= kind.hash(w.as_bytes());
            }
            std::hint::black_box(acc);
            words.len() as f64
        });
    }
    let corpus = Corpus::generate(&CorpusSpec::with_bytes(8 << 20));
    for kind in [HashKind::Fx, HashKind::Fnv1a, HashKind::Wy] {
        let mut job = WordCountJob::new(EngineChoice::BlazeTcm)
            .nodes(1)
            .threads_per_node(4)
            .net(NetModel::ideal());
        job.hash = kind;
        let corpus = &corpus;
        runner.bench(format!("blaze word count: {kind:?}"), "words", move || {
            job.run(corpus).expect("run").words as f64
        });
    }
    runner.finish();

    // ---------------- skew sweep ----------------
    let mut runner = BenchRunner::new("D2: combine benefit vs key skew (Zipf exponent)");
    let mut shuffle_rows: Vec<(String, u64, u64)> = Vec::new();
    for exponent in [0.3f64, 0.8, 1.07, 1.5] {
        let corpus = Corpus::generate(&CorpusSpec {
            target_bytes: 8 << 20,
            vocab_size: 30_000,
            exponent,
            ..Default::default()
        });
        let mut bytes = [0u64; 2];
        for (i, combine) in [blaze::dist::CombineMode::Eager, blaze::dist::CombineMode::None]
            .into_iter()
            .enumerate()
        {
            let job = WordCountJob::new(EngineChoice::BlazeTcm)
                .nodes(4)
                .threads_per_node(2)
                .net(NetModel::aws_like())
                .combine(combine);
            let corpus = &corpus;
            let mut last = 0u64;
            runner.bench(
                format!("s={exponent}, combine={combine:?}"),
                "words",
                || {
                    let r = job.run(corpus).expect("run");
                    last = r.shuffle_bytes;
                    r.words as f64
                },
            );
            bytes[i] = last;
        }
        shuffle_rows.push((format!("s={exponent}"), bytes[0], bytes[1]));
    }
    runner.finish();

    let mut t = Table::new(
        "D2: shuffle bytes — eager combine vs raw, by skew",
        &["zipf s", "eager", "raw", "reduction"],
    );
    for (s, eager, raw) in shuffle_rows {
        t.row(&[
            s,
            fmt_bytes(eager),
            fmt_bytes(raw),
            format!("{:.1}x", raw as f64 / eager.max(1) as f64),
        ]);
    }
    println!("{}", t.to_markdown());
}
