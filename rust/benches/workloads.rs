//! Experiment W1: workload diversity — the generic job layer's
//! single-pass workloads (word count, inverted index, top-k, length
//! histogram, join, distinct-count sketch, grep) plus the two-stage
//! chained `sessionize` pipeline on both engines, same corpus, same
//! cluster shape.
//!
//! The paper's comparison is word count only; related work (DataMPI,
//! arXiv:1403.3480) shows MPI-backed engines winning across a benchmark
//! *suite*. This bench regenerates that comparison shape on the simulated
//! cluster: each row reports map-phase emissions per second, so rows of
//! one workload are comparable across engines (not across workloads —
//! emission volumes differ by design).
//!
//! Scale knobs: BLAZE_BENCH_BYTES (default 32MB), BLAZE_BENCH_REPS.

use std::sync::Arc;

use blaze::benchkit::{bench_corpus_bytes, stage_table, BenchRunner, MachineReport};
use blaze::cluster::NetModel;
use blaze::corpus::{Corpus, CorpusSpec, Tokenizer};
use blaze::engines::Engine;
use blaze::mapreduce::{run_chained, JobInputs, JobReport, JobSpec};
use blaze::util::stats::fmt_bytes;
use blaze::workloads::{
    synthesize_logs, DistinctCount, Grep, InvertedIndex, Join, LengthHistogram, Sessionize,
    TopKWords, WordCount,
};

fn spec(engine: Engine) -> JobSpec {
    JobSpec::new(engine)
        .nodes(2)
        .threads_per_node(4)
        .net(NetModel::aws_like())
}

/// One machine-readable row from a single-pass job report, tagged with
/// the real executor width it ran at plus the pool's busy fraction
/// (worker utilization) over the run.
fn machine_row<O>(
    m: &mut MachineReport,
    name: &str,
    engine: Engine,
    threads: usize,
    r: &JobReport<O>,
) {
    eprintln!(
        "  {name:<14} {:<16} t={threads} busy={:>5.1}% steals={:<5} imbalance={:.2}",
        engine.label(),
        r.exec.utilization(r.wall_secs) * 100.0,
        r.exec.total_steals(),
        r.exec.steal_imbalance(),
    );
    m.row_exec(
        name,
        engine.label(),
        threads,
        r.wall_secs,
        r.shuffle_bytes,
        r.storage.spilled_bytes,
        r.exec.utilization(r.wall_secs),
    );
}

fn main() {
    let bytes = bench_corpus_bytes();
    let corpus = Corpus::generate(&CorpusSpec::with_bytes(bytes));
    eprintln!(
        "W1 corpus: {} ({} words); 2 nodes x 4 threads, aws-like net",
        fmt_bytes(corpus.bytes),
        corpus.words
    );
    let engines = [Engine::Spark, Engine::BlazeTcm];

    let mut runner = BenchRunner::new("W1: generic workloads — Spark vs Blaze TCM");

    let wc = Arc::new(WordCount::new(Tokenizer::Spaces));
    for engine in engines {
        let corpus = &corpus;
        let wc = &wc;
        runner.bench(format!("wordcount / {}", engine.label()), "recs", move || {
            spec(engine).run_str(wc, corpus).expect("wordcount").records as f64
        });
    }

    let idx = Arc::new(InvertedIndex::new(Tokenizer::Spaces));
    for engine in engines {
        let corpus = &corpus;
        let idx = &idx;
        runner.bench(format!("index / {}", engine.label()), "recs", move || {
            spec(engine).run_str(idx, corpus).expect("index").records as f64
        });
    }

    let topk = Arc::new(TopKWords::new(Tokenizer::Spaces, 20));
    for engine in engines {
        let corpus = &corpus;
        let topk = &topk;
        runner.bench(format!("top-k / {}", engine.label()), "recs", move || {
            spec(engine).run_str(topk, corpus).expect("top-k").records as f64
        });
    }

    let hist = Arc::new(LengthHistogram::new(Tokenizer::Spaces));
    for engine in engines {
        let corpus = &corpus;
        let hist = &hist;
        runner.bench(format!("length-hist / {}", engine.label()), "recs", move || {
            spec(engine).run(hist, corpus).expect("length-hist").records as f64
        });
    }

    // Join: two key-overlapping relations (same size, different seed).
    let right = Corpus::generate(&CorpusSpec {
        target_bytes: bytes,
        seed: CorpusSpec::default().seed + 1,
        ..Default::default()
    });
    let join_inputs = JobInputs::new()
        .relation_lines("left", Arc::new(corpus.lines.clone()))
        .relation("right", &right);
    let join = Arc::new(Join::new());
    for engine in engines {
        let join_inputs = &join_inputs;
        let join = &join;
        runner.bench(format!("join / {}", engine.label()), "recs", move || {
            spec(engine).run_inputs(join, join_inputs).expect("join").records as f64
        });
    }

    let distinct = Arc::new(DistinctCount::new(Tokenizer::Spaces));
    for engine in engines {
        let corpus = &corpus;
        let distinct = &distinct;
        runner.bench(format!("distinct / {}", engine.label()), "recs", move || {
            spec(engine).run(distinct, corpus).expect("distinct").records as f64
        });
    }

    // Grep rides the zero-shuffle fast path (needs_shuffle == false).
    let grep = Arc::new(Grep::new("the"));
    for engine in engines {
        let corpus = &corpus;
        let grep = &grep;
        runner.bench(format!("grep / {}", engine.label()), "recs", move || {
            spec(engine).run(grep, corpus).expect("grep").records as f64
        });
    }

    // Sessionize: the two-stage chained pipeline (two shuffle
    // boundaries; event volume scaled to the corpus byte budget).
    let gap = 1800u64;
    let events = (bytes / 16) as usize;
    let logs = JobInputs::new()
        .relation_lines("logs", Arc::new(synthesize_logs(200, events, gap, 7)));
    let sessionize = Sessionize::new(gap);
    for engine in engines {
        let logs = &logs;
        let sessionize = &sessionize;
        runner.bench(format!("sessionize / {}", engine.label()), "recs", move || {
            run_chained(&spec(engine), sessionize, logs).expect("sessionize").records as f64
        });
    }

    runner.finish();

    // Per-workload speedups (Blaze TCM over Spark).
    println!("\nW1 headline (Blaze TCM / Spark, per workload):");
    let names = [
        "wordcount",
        "index",
        "top-k",
        "length-hist",
        "join",
        "distinct",
        "grep",
        "sessionize",
    ];
    for (i, name) in names.iter().enumerate() {
        let spark = runner.results[i * 2].rate();
        let tcm = runner.results[i * 2 + 1].rate();
        println!("  {name:<12} {:.1}x", tcm / spark.max(1e-12));
    }

    // Multi-stage attribution: where sessionize's time and bytes go,
    // per engine (one fresh run per cell).
    for engine in engines {
        let r = run_chained(&spec(engine), &sessionize, &logs).expect("sessionize");
        println!(
            "\n{}",
            stage_table(format!("sessionize stages / {}", engine.label()), &r.stages)
                .to_markdown()
        );
    }

    // BENCH_8.json: the machine-readable companion (per-workload wall,
    // shuffle bytes, spilled bytes, executor busy fraction) — every
    // workload row swept across real executor widths 1/2/4/8 (the
    // `threads` axis), one fresh run per cell. Written merged so the
    // figure1_wordcount scaling sweep's
    // rows land in the same file. Default rows never spill; the
    // `@spill64k` rows (threads = 4) force the bounded-memory exchange so
    // the spill column is populated (the full threshold sweep lives in
    // `cargo bench --bench spill`).
    let mut machine = MachineReport::new();
    for engine in engines {
        for threads in [1usize, 2, 4, 8] {
            let spec = |e: Engine| spec(e).threads(threads);
            let m = &mut machine;
            machine_row(m, "wordcount", engine, threads, &spec(engine).run_str(&wc, &corpus).expect("wordcount"));
            machine_row(m, "index", engine, threads, &spec(engine).run_str(&idx, &corpus).expect("index"));
            machine_row(m, "top-k", engine, threads, &spec(engine).run_str(&topk, &corpus).expect("top-k"));
            machine_row(m, "length-hist", engine, threads, &spec(engine).run(&hist, &corpus).expect("length-hist"));
            machine_row(m, "join", engine, threads, &spec(engine).run_inputs(&join, &join_inputs).expect("join"));
            machine_row(m, "distinct", engine, threads, &spec(engine).run(&distinct, &corpus).expect("distinct"));
            machine_row(m, "grep", engine, threads, &spec(engine).run(&grep, &corpus).expect("grep"));
            let chained = run_chained(&spec(engine), &sessionize, &logs).expect("sessionize");
            machine.row_exec(
                "sessionize",
                engine.label(),
                threads,
                chained.wall_secs,
                chained.shuffle_bytes,
                chained.storage.spilled_bytes,
                chained.exec.utilization(chained.wall_secs),
            );
        }
        // The spill cliff's anchor points.
        let spill = |s: JobSpec| s.spill_threshold(64 << 10).threads(4);
        machine_row(
            &mut machine,
            "wordcount@spill64k",
            engine,
            4,
            &spill(spec(engine)).run_str(&wc, &corpus).expect("wordcount spill"),
        );
        machine_row(
            &mut machine,
            "join@spill64k",
            engine,
            4,
            &spill(spec(engine)).run_inputs(&join, &join_inputs).expect("join spill"),
        );
    }
    machine.write_merged("BENCH_8.json");
}
