//! Experiment X1 (extension): the XLA/PJRT-accelerated combiner vs the
//! hash-map combiner on dictionary-encoded token streams.
//!
//! Caveat printed with the results: the Pallas kernel runs in interpret
//! mode on the CPU PJRT client, so this measures the *integration path*
//! (shard → execute artifact → merge), not TPU performance. DESIGN.md §7
//! carries the VMEM/MXU estimate for real hardware.

use blaze::benchkit::BenchRunner;
use blaze::corpus::{Corpus, CorpusSpec, Vocab};
use blaze::runtime::{hash_bucket_of, HistogramRuntime};
use blaze::util::stats::fmt_bytes;

fn main() {
    if !HistogramRuntime::available() {
        eprintln!("X1 skipped: artifacts/ not built (run `make artifacts`)");
        return;
    }
    let bytes = std::env::var("BLAZE_BENCH_XLA_BYTES")
        .ok()
        .and_then(|s| blaze::util::cli::parse_bytes(&s))
        .unwrap_or(2 << 20);
    let corpus = Corpus::generate(&CorpusSpec::with_bytes(bytes));
    let vocab = Vocab::from_lines(&corpus.lines);
    let ids = vocab.encode_lines(&corpus.lines);
    eprintln!(
        "X1 corpus: {} = {} token ids, {} distinct",
        fmt_bytes(corpus.bytes),
        ids.len(),
        vocab.len()
    );
    let hr = HistogramRuntime::from_env().expect("runtime");

    let mut runner = BenchRunner::new("X1: combiner backends on token-id streams");
    {
        let ids = &ids;
        runner.bench("rust serial histogram (dense)", "tokens", move || {
            let counts = hr_serial_dense(ids, vocab.len().next_power_of_two());
            std::hint::black_box(&counts);
            ids.len() as f64
        });
    }
    {
        let ids = &ids;
        let hr = &hr;
        runner.bench("xla dense histogram (interpret)", "tokens", move || {
            let counts = hr.count_tokens(ids).expect("xla");
            std::hint::black_box(&counts);
            ids.len() as f64
        });
    }
    {
        let ids = &ids;
        let hr = &hr;
        runner.bench("rust serial histogram (hashed)", "tokens", move || {
            let mut counts = vec![0u64; hr.spec.hash_buckets];
            for &t in ids.iter() {
                if t >= 0 {
                    counts[hash_bucket_of(t, hr.spec.hash_buckets as u32) as usize] += 1;
                }
            }
            std::hint::black_box(&counts);
            ids.len() as f64
        });
    }
    {
        let ids = &ids;
        let hr = &hr;
        runner.bench("xla hashed histogram (interpret)", "tokens", move || {
            let counts = hr.count_hashed(ids).expect("xla");
            std::hint::black_box(&counts);
            ids.len() as f64
        });
    }
    runner.finish();
    println!(
        "note: interpret-mode Pallas on CPU — integration-path timing only.\n\
         Real-TPU estimate (DESIGN.md §7): one-hot tile 2048x512 f32 = 4 MiB VMEM,\n\
         8 vocab blocks/shard; MXU does 2048x512 MAC per step at bf16."
    );
}

fn hr_serial_dense(ids: &[i32], vocab: usize) -> Vec<u64> {
    let mut counts = vec![0u64; vocab];
    for &t in ids {
        if t >= 0 && (t as usize) < vocab {
            counts[t as usize] += 1;
        }
    }
    counts
}
