//! Experiment A1: the paper's first cause — "MPI/OpenMP uses C++ and runs
//! natively while Spark/Scala runs through a virtual machine."
//!
//! The Spark-sim models the JVM as four separable mechanisms; this bench
//! removes them one at a time (and then all at once) to attribute the gap:
//!
//!   full EMR-like  →  -serialization  →  -boxing  →  -utf16 strings+gc
//!   →  -vm execution factor  →  stripped (native hypothetical)
//!
//! Expected shape: each knob recovers part of the gap; `stripped` lands
//! within ~2x of Blaze (remaining difference = continuous combine +
//! architecture, covered by A3).

use blaze::benchkit::{bench_corpus_bytes, BenchRunner};
use blaze::cluster::NetModel;
use blaze::corpus::{Corpus, CorpusSpec, Tokenizer};
use blaze::engines::spark::{word_count_lines, SparkConf, SparkContext};
use blaze::util::stats::fmt_bytes;
use std::sync::Arc;

fn main() {
    let bytes = bench_corpus_bytes();
    let corpus = Corpus::generate(&CorpusSpec::with_bytes(bytes));
    let lines = Arc::new(corpus.lines.clone());
    eprintln!("A1 corpus: {} ({} words)", fmt_bytes(corpus.bytes), corpus.words);

    let base = || SparkConf::emr_like(2, 4);

    let variants: Vec<(&str, SparkConf)> = vec![
        ("spark: full EMR-like", base()),
        ("spark: -serialization", {
            let mut c = base();
            c.serialize_shuffle = false;
            c.fault_tolerance = false; // typed blocks can't persist to disk
            c
        }),
        ("spark: -record boxing", {
            let mut c = base();
            c.boxed_records = false;
            c
        }),
        ("spark: -utf16 strings & gc", {
            let mut c = base();
            c.jvm_strings = false;
            c.gc_model = false;
            c
        }),
        ("spark: -vm exec factor", {
            let mut c = base();
            c.vm_execution_factor = 1.0;
            c
        }),
        ("spark: stripped (native hypo)", SparkConf::stripped(2, 4)),
    ];

    let mut runner = BenchRunner::new("A1: attributing the JVM gap (Spark-sim knobs)");
    for (name, conf) in variants {
        let lines = Arc::clone(&lines);
        runner.bench(name, "words", move || {
            let mut conf = conf.clone();
            conf.net = NetModel::aws_like();
            let ctx = SparkContext::new(conf);
            let counts = word_count_lines(&ctx, Arc::clone(&lines), Tokenizer::Spaces)
                .expect("spark run");
            counts.values().sum::<u64>() as f64
        });
    }
    runner.finish();
}
