//! Cross-engine parity for the generic job layer: every workload on every
//! engine must produce exactly the serial reference's output — including
//! under injected failures (Spark recovers via lineage retries, Blaze via
//! whole-job reruns).

use std::sync::Arc;

use blaze::cluster::{FailurePlan, NetModel};
use blaze::corpus::{Corpus, CorpusSpec, Tokenizer};
use blaze::engines::Engine;
use blaze::mapreduce::{run_serial, run_serial_inputs, JobInputs, JobSpec};
use blaze::workloads::{
    DistinctCount, Grep, InvertedIndex, Join, LengthHistogram, TopKWords, WordCount,
};

const ENGINES: [Engine; 3] = [Engine::Blaze, Engine::BlazeTcm, Engine::Spark];

fn corpus(bytes: u64, seed: u64) -> Corpus {
    Corpus::generate(&CorpusSpec { target_bytes: bytes, seed, ..Default::default() })
}

fn spec(engine: Engine) -> JobSpec {
    JobSpec::new(engine).nodes(2).threads_per_node(2).net(NetModel::ideal())
}

/// A failure plan exercising the engine's recovery path: a map-phase and a
/// reduce/shuffle-phase injection.
fn failure_plan(engine: Engine) -> FailurePlan {
    match engine {
        // Node failures abort the attempt; the driver reruns the job.
        Engine::Blaze | Engine::BlazeTcm => FailurePlan::none().fail_node(0, 0).fail_node(1, 1),
        // Task failures retry from lineage (FT on in the default conf).
        Engine::Spark | Engine::SparkStripped => {
            FailurePlan::none().fail_task(0, 1).fail_task(1, 0)
        }
    }
}

#[test]
fn wordcount_parity() {
    let corpus = corpus(128 << 10, 11);
    let w = Arc::new(WordCount::new(Tokenizer::Spaces));
    let expect = run_serial(w.as_ref(), &corpus);
    assert!(!expect.is_empty());
    for engine in ENGINES {
        let r = spec(engine).run_str(&w, &corpus).unwrap();
        assert_eq!(r.output, expect, "{}", engine.label());
    }
}

#[test]
fn inverted_index_parity() {
    let corpus = corpus(96 << 10, 12);
    let w = Arc::new(InvertedIndex::new(Tokenizer::Spaces));
    let expect = run_serial(w.as_ref(), &corpus);
    for engine in ENGINES {
        let r = spec(engine).run_str(&w, &corpus).unwrap();
        assert_eq!(r.output, expect, "{}", engine.label());
    }
    // Postings are sorted line ids.
    assert!(expect.values().all(|p| p.windows(2).all(|ab| ab[0] < ab[1])));
}

#[test]
fn top_k_parity() {
    let corpus = corpus(128 << 10, 13);
    let w = Arc::new(TopKWords::new(Tokenizer::Spaces, 15));
    let expect = run_serial(w.as_ref(), &corpus);
    assert_eq!(expect.len(), 15);
    for engine in ENGINES {
        let r = spec(engine).run_str(&w, &corpus).unwrap();
        assert_eq!(r.output, expect, "{}", engine.label());
    }
}

#[test]
fn length_histogram_parity() {
    let corpus = corpus(96 << 10, 14);
    let w = Arc::new(LengthHistogram::new(Tokenizer::Spaces));
    let expect = run_serial(w.as_ref(), &corpus);
    // Integer-keyed workload: only the owned-key path exists; also cover
    // the stripped Spark floor here.
    for engine in [Engine::Blaze, Engine::BlazeTcm, Engine::Spark, Engine::SparkStripped] {
        let r = spec(engine).run(&w, &corpus).unwrap();
        assert_eq!(r.output, expect, "{}", engine.label());
    }
    // Total histogram mass = total tokens.
    let total: u64 = expect.iter().map(|(_, n)| n).sum();
    assert_eq!(total, corpus.words);
}

/// Two key-overlapping relations for the join grid (same vocab, different
/// seeds → shared keys, different lines).
fn join_inputs(bytes: u64, seed: u64) -> JobInputs {
    JobInputs::new()
        .relation("left", &corpus(bytes, seed))
        .relation("right", &corpus(bytes, seed + 1))
}

#[test]
fn join_parity() {
    let inputs = join_inputs(64 << 10, 21);
    let w = Arc::new(Join::new());
    let expect = run_serial_inputs(w.as_ref(), &inputs);
    assert!(!expect.is_empty(), "relations share a vocabulary, keys must match");
    // Inner join: every surviving key has both sides populated.
    assert!(expect.values().all(|s| !s.left.is_empty() && !s.right.is_empty()));
    for engine in [Engine::Blaze, Engine::BlazeTcm, Engine::Spark, Engine::SparkStripped] {
        let r = spec(engine).run_inputs(&w, &inputs).unwrap();
        assert_eq!(r.output, expect, "{}", engine.label());
        // Emissions came from both relations.
        let total_lines: u64 =
            inputs.relations.iter().map(|r| r.lines.len() as u64).sum();
        assert!(r.records > 0 && r.records <= total_lines, "{}", engine.label());
    }
}

#[test]
fn join_parity_under_injected_failures() {
    let inputs = join_inputs(32 << 10, 23);
    let w = Arc::new(Join::new());
    let expect = run_serial_inputs(w.as_ref(), &inputs);
    for engine in ENGINES {
        let r = spec(engine)
            .failures(failure_plan(engine))
            .run_inputs(&w, &inputs)
            .unwrap();
        assert_eq!(r.output, expect, "join {}", engine.label());
    }
}

#[test]
fn join_with_one_empty_relation_is_empty() {
    let full = corpus(32 << 10, 24);
    let empty = Corpus::from_text("");
    let w = Arc::new(Join::new());
    for (left, right) in [(&full, &empty), (&empty, &full)] {
        let inputs = JobInputs::new().relation("left", left).relation("right", right);
        let expect = run_serial_inputs(w.as_ref(), &inputs);
        assert!(expect.is_empty());
        for engine in ENGINES {
            let r = spec(engine).run_inputs(&w, &inputs).unwrap();
            assert_eq!(r.output, expect, "{}", engine.label());
        }
    }
}

#[test]
fn relation_arity_is_validated() {
    let c = Corpus::from_text("a 1\n");
    let join = Arc::new(Join::new());
    // Join through the single-input entry: 1 relation != 2.
    let err = spec(Engine::Blaze).run(&join, &c).unwrap_err();
    assert!(err.to_string().contains("expects 2 input relation(s)"), "{err}");
    // Single-input workload handed 2 relations.
    let wc = Arc::new(WordCount::new(Tokenizer::Spaces));
    let two = JobInputs::new().relation("a", &c).relation("b", &c);
    let err = spec(Engine::Spark).run_inputs(&wc, &two).unwrap_err();
    assert!(err.to_string().contains("expects 1 input relation(s)"), "{err}");
}

#[test]
fn distinct_count_parity() {
    let corpus = corpus(96 << 10, 25);
    let w = Arc::new(DistinctCount::new(Tokenizer::Spaces));
    let expect = run_serial(w.as_ref(), &corpus);
    assert!(expect > 0);
    for engine in [Engine::Blaze, Engine::BlazeTcm, Engine::Spark, Engine::SparkStripped] {
        let r = spec(engine).run(&w, &corpus).unwrap();
        assert_eq!(r.output, expect, "{}", engine.label());
    }
    // Sketch emissions are bounded by records × registers, and in practice
    // collapse to a near-constant per-node register file after combining.
    for engine in ENGINES {
        let r = spec(engine).failures(failure_plan(engine)).run(&w, &corpus).unwrap();
        assert_eq!(r.output, expect, "under failures, {}", engine.label());
    }
}

#[test]
fn grep_parity_zero_shuffle_and_forced_exchange() {
    let corpus = corpus(64 << 10, 26);
    let w = Arc::new(Grep::new("the"));
    let expect = run_serial(w.as_ref(), &corpus);
    assert!(!expect.is_empty(), "generated corpora contain 'the'");
    for engine in ENGINES {
        // Fast path: identical output, zero bytes on the wire.
        let r = spec(engine).run(&w, &corpus).unwrap();
        assert_eq!(r.output, expect, "{}", engine.label());
        assert_eq!(
            r.shuffle_bytes,
            0,
            "zero-shuffle path must not touch the exchange ({})",
            engine.label()
        );
        // Forced exchange: same output, but now bytes move.
        let r = spec(engine).force_shuffle(true).run(&w, &corpus).unwrap();
        assert_eq!(r.output, expect, "forced, {}", engine.label());
        assert!(
            r.shuffle_bytes > 0,
            "forced exchange must serialize entries ({})",
            engine.label()
        );
    }
}

#[test]
fn grep_zero_shuffle_survives_failures() {
    let corpus = corpus(32 << 10, 27);
    let w = Arc::new(Grep::new("the"));
    let expect = run_serial(w.as_ref(), &corpus);
    for engine in ENGINES {
        let r = spec(engine).failures(failure_plan(engine)).run(&w, &corpus).unwrap();
        assert_eq!(r.output, expect, "{}", engine.label());
    }
}

#[test]
fn parity_under_injected_failures() {
    let corpus = corpus(64 << 10, 15);
    let wc = Arc::new(WordCount::new(Tokenizer::Spaces));
    let idx = Arc::new(InvertedIndex::new(Tokenizer::Spaces));
    let topk = Arc::new(TopKWords::new(Tokenizer::Spaces, 10));
    let hist = Arc::new(LengthHistogram::new(Tokenizer::Spaces));
    for engine in ENGINES {
        // Fresh plan per run: injections are one-shot and consumed.
        let r = spec(engine).failures(failure_plan(engine)).run_str(&wc, &corpus).unwrap();
        assert_eq!(r.output, run_serial(wc.as_ref(), &corpus), "wc {}", engine.label());

        let r = spec(engine).failures(failure_plan(engine)).run_str(&idx, &corpus).unwrap();
        assert_eq!(r.output, run_serial(idx.as_ref(), &corpus), "idx {}", engine.label());

        let r = spec(engine).failures(failure_plan(engine)).run_str(&topk, &corpus).unwrap();
        assert_eq!(r.output, run_serial(topk.as_ref(), &corpus), "topk {}", engine.label());

        let r = spec(engine).failures(failure_plan(engine)).run(&hist, &corpus).unwrap();
        assert_eq!(r.output, run_serial(hist.as_ref(), &corpus), "hist {}", engine.label());
    }
}

#[test]
fn str_and_owned_paths_agree() {
    // `run` (owned keys) and `run_str` (borrowed keys / JvmWord modeling)
    // must be observationally identical for string workloads.
    let corpus = corpus(64 << 10, 16);
    let w = Arc::new(WordCount::new(Tokenizer::Spaces));
    for engine in ENGINES {
        let owned = spec(engine).run(&w, &corpus).unwrap();
        let borrowed = spec(engine).run_str(&w, &corpus).unwrap();
        assert_eq!(owned.output, borrowed.output, "{}", engine.label());
    }
}

#[test]
fn top_k_exact_across_cluster_shapes() {
    // The per-shard heap is a partial reduce: results must not depend on
    // how keys shard across nodes/partitions.
    let corpus = corpus(96 << 10, 17);
    let w = Arc::new(TopKWords::new(Tokenizer::Spaces, 8));
    let expect = run_serial(w.as_ref(), &corpus);
    for nodes in [1usize, 2, 4] {
        for engine in ENGINES {
            let r = JobSpec::new(engine)
                .nodes(nodes)
                .threads_per_node(2)
                .net(NetModel::ideal())
                .run_str(&w, &corpus)
                .unwrap();
            assert_eq!(r.output, expect, "{} nodes={nodes}", engine.label());
        }
    }
}

#[test]
fn normalized_tokenizer_workloads() {
    let corpus = Corpus::from_text("The CAT, the cat! THE-CAT?\nsat on THE mat.\n");
    let idx = Arc::new(InvertedIndex::new(Tokenizer::Normalized));
    let expect = run_serial(idx.as_ref(), &corpus);
    assert_eq!(expect["the"], vec![0, 1]);
    assert_eq!(expect["cat"], vec![0]);
    for engine in ENGINES {
        let r = spec(engine).run_str(&idx, &corpus).unwrap();
        assert_eq!(r.output, expect, "{}", engine.label());
    }
}

#[test]
fn degenerate_corpora_all_workloads() {
    for text in ["", "\n\n\n", "   \n  ", "word\n"] {
        let corpus = Corpus::from_text(text);
        let wc = Arc::new(WordCount::new(Tokenizer::Spaces));
        let topk = Arc::new(TopKWords::new(Tokenizer::Spaces, 3));
        let hist = Arc::new(LengthHistogram::new(Tokenizer::Spaces));
        for engine in ENGINES {
            let r = spec(engine).run_str(&wc, &corpus).unwrap();
            assert_eq!(r.output, run_serial(wc.as_ref(), &corpus), "wc {text:?}");
            let r = spec(engine).run_str(&topk, &corpus).unwrap();
            assert_eq!(r.output, run_serial(topk.as_ref(), &corpus), "topk {text:?}");
            let r = spec(engine).run(&hist, &corpus).unwrap();
            assert_eq!(r.output, run_serial(hist.as_ref(), &corpus), "hist {text:?}");
        }
    }
}

#[test]
fn report_metrics_are_sane() {
    let corpus = corpus(64 << 10, 18);
    let w = Arc::new(WordCount::new(Tokenizer::Spaces));
    for engine in ENGINES {
        let r = spec(engine).run_str(&w, &corpus).unwrap();
        assert_eq!(r.records, corpus.words, "{}", engine.label());
        assert!(r.records_per_sec() > 0.0);
        assert!(r.shuffle_bytes > 0, "{}", engine.label());
        assert!(r.summary().contains(engine.label()));
        assert_eq!(r.workload, "wordcount");
    }
}

#[test]
fn facade_matches_generic_layer() {
    // WordCountJob is a facade over JobSpec + WordCount: same counts.
    use blaze::wordcount::{serial_reference, WordCountJob};
    let corpus = corpus(64 << 10, 19);
    for engine in ENGINES {
        let facade = WordCountJob::new(engine)
            .nodes(2)
            .threads_per_node(2)
            .net(NetModel::ideal())
            .run(&corpus)
            .unwrap();
        assert_eq!(facade.counts, serial_reference(&corpus, Tokenizer::Spaces));
        let w = Arc::new(WordCount::new(Tokenizer::Spaces));
        let generic = spec(engine).run_str(&w, &corpus).unwrap();
        assert_eq!(facade.counts, generic.output, "{}", engine.label());
    }
}
