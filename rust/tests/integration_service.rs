//! The service layer's acceptance gate: a job submitted through the
//! multi-tenant [`JobService`] must be **bit-identical** to the same
//! workload run serially — under concurrent mixed-tenant load, at
//! executor widths 1 and 8, with the shared store squeezed to a 2 KB
//! budget and every exchange forced onto the spill path. Each request
//! carries `verify(true)`, so the in-job oracle check (serial
//! `run_serial*` / `run_iterative_serial` comparison inside the catalog)
//! turns any divergence into a `Failed` status; on top of that the
//! tests assert cross-tenant determinism (same request → same canonical
//! lines regardless of which tenant ran it and what ran beside it) and
//! that the admission ledger balances.

use blaze::cache::CacheBudget;
use blaze::cluster::FailurePlan;
use blaze::service::{
    JobRequest, JobService, JobStatus, SchedPolicy, ServiceConf, WorkloadKind, TENANT_NS_SPAN,
};

/// Far below every test corpus's working set: shuffles spill, the shared
/// store demotes.
const TINY: u64 = 2 << 10;

fn squeezed(threads: usize) -> ServiceConf {
    ServiceConf::new()
        .threads(threads)
        .slots(2)
        .store_budget(CacheBudget::Bytes(TINY))
        .spill_threshold(TINY)
        .tenant_quota(TINY)
}

const KINDS: [WorkloadKind; 4] =
    [WorkloadKind::Grep, WorkloadKind::WordCount, WorkloadKind::Join, WorkloadKind::PageRank];

/// N tenants × every workload kind, all in flight at once, each
/// self-verified against the serial oracle, at widths 1 and 8.
#[test]
fn concurrent_mixed_tenants_match_serial_oracle() {
    for threads in [1usize, 8] {
        let svc = JobService::new(squeezed(threads));
        let mut handles = Vec::new();
        for tenant in ["alpha", "beta", "gamma"] {
            for kind in KINDS {
                let req = JobRequest::new(tenant, kind)
                    .bytes(12 << 10)
                    .seed(41)
                    .rounds(2)
                    .verify(true);
                handles.push(svc.submit(req).expect("under the admission cap"));
            }
        }
        // Same request, different tenants: outputs must be byte-equal, so
        // collect per-kind line renderings and compare across tenants.
        let mut lines_by_kind: Vec<Vec<(String, Vec<String>)>> = vec![Vec::new(); KINDS.len()];
        for h in &handles {
            match h.wait() {
                JobStatus::Done(s) => {
                    assert!(s.verified, "job {} ({}) skipped its oracle check", h.id(), h.tenant());
                    assert!(!s.lines.is_empty(), "job {} produced no output", h.id());
                    let slot = KINDS.iter().position(|k| *k == h.kind()).unwrap();
                    lines_by_kind[slot].push((h.tenant().to_string(), s.lines));
                }
                other => panic!(
                    "@{threads}T job {} ({} {}) ended {}",
                    h.id(),
                    h.tenant(),
                    h.kind().name(),
                    other.label()
                ),
            }
        }
        for (kind, runs) in KINDS.iter().zip(&lines_by_kind) {
            let (_, first) = &runs[0];
            for (tenant, lines) in runs {
                assert_eq!(
                    lines,
                    first,
                    "@{threads}T {}: tenant {tenant} diverged from tenant {}",
                    kind.name(),
                    runs[0].0
                );
            }
        }
        let report = svc.shutdown();
        assert_eq!(report.completed, 12, "@{threads}T:\n{}", report.render());
        assert!(report.balances(), "@{threads}T:\n{}", report.render());
    }
}

/// Tenant quotas hold under load: while squeezed jobs run, no tenant's
/// resident bytes in the shared store ever exceed its quota.
#[test]
fn tenant_store_residency_stays_under_quota() {
    let svc = JobService::new(squeezed(2));
    let mut handles = Vec::new();
    for tenant in ["alpha", "beta"] {
        for _ in 0..2 {
            let req =
                JobRequest::new(tenant, WorkloadKind::PageRank).bytes(24 << 10).rounds(3);
            handles.push(svc.submit(req).expect("under the admission cap"));
        }
    }
    // Poll residency while jobs are in flight, then once more after.
    while svc.in_flight() > 0 {
        for idx in 0..2u64 {
            let base = (idx + 1) * TENANT_NS_SPAN;
            let resident = svc.store().bytes_in_namespace_range(base, base + TENANT_NS_SPAN);
            assert!(
                resident <= TINY,
                "tenant {idx} resident {resident} B exceeds quota {TINY} B"
            );
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    for h in &handles {
        assert!(matches!(h.wait(), JobStatus::Done(_)));
    }
    let report = svc.shutdown();
    assert!(report.balances(), "{}", report.render());
    for t in &report.tenants {
        assert!(
            t.metrics.count("store.resident") <= t.metrics.count("store.quota"),
            "tenant {}: {}",
            t.name,
            t.metrics
        );
    }
}

/// Failure isolation: injected failures that kill one tenant's job leave
/// every other tenant's concurrently-running verified jobs untouched.
#[test]
fn one_tenants_failure_does_not_touch_other_tenants() {
    for policy in [SchedPolicy::Fair, SchedPolicy::Fifo] {
        let svc = JobService::new(squeezed(2).policy(policy));
        // The doomed job: an unrecoverable node loss (no reruns allowed).
        let doomed = svc
            .submit(
                JobRequest::new("victim", WorkloadKind::WordCount)
                    .bytes(16 << 10)
                    .failures(FailurePlan::none().fail_node(0, 0))
                    .max_job_reruns(0),
            )
            .expect("admitted");
        let mut healthy = Vec::new();
        for tenant in ["alpha", "beta"] {
            for kind in KINDS {
                let req =
                    JobRequest::new(tenant, kind).bytes(8 << 10).rounds(2).verify(true);
                healthy.push(svc.submit(req).expect("admitted"));
            }
        }
        assert!(
            matches!(doomed.wait(), JobStatus::Failed(_)),
            "unrecoverable node loss must fail the job"
        );
        for h in &healthy {
            match h.wait() {
                JobStatus::Done(s) => assert!(s.verified),
                other => panic!(
                    "{policy:?}: healthy job {} ({} {}) ended {}",
                    h.id(),
                    h.tenant(),
                    h.kind().name(),
                    other.label()
                ),
            }
        }
        let report = svc.shutdown();
        assert_eq!((report.completed, report.failed), (8, 1), "{}", report.render());
        assert!(report.balances(), "{}", report.render());
    }
}

/// A recoverable failure inside one tenant's job is invisible at the
/// service surface: the job retries internally and still verifies.
#[test]
fn recoverable_failure_inside_a_job_still_verifies() {
    let svc = JobService::new(squeezed(2));
    let flaky = svc
        .submit(
            JobRequest::new("flaky", WorkloadKind::WordCount)
                .bytes(16 << 10)
                .failures(FailurePlan::none().fail_node(0, 0))
                .verify(true),
        )
        .expect("admitted");
    let calm = svc
        .submit(JobRequest::new("calm", WorkloadKind::Grep).bytes(8 << 10).verify(true))
        .expect("admitted");
    for h in [&flaky, &calm] {
        match h.wait() {
            JobStatus::Done(s) => assert!(s.verified),
            other => panic!("job {} ended {}", h.id(), other.label()),
        }
    }
    let report = svc.shutdown();
    assert_eq!(report.completed, 2);
    assert!(report.balances(), "{}", report.render());
}
