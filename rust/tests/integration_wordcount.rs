//! Cross-module integration: full word counts on generated corpora across
//! the engine × cluster-shape grid, all verified against the serial
//! reference; engines must also agree with each other.

use std::collections::HashMap;

use blaze::cluster::{FailurePlan, NetModel};
use blaze::corpus::{Corpus, CorpusSpec, Tokenizer};
use blaze::dist::CombineMode;
use blaze::wordcount::{serial_reference, top_k, EngineChoice, WordCountJob};

fn corpus(bytes: u64, seed: u64) -> Corpus {
    Corpus::generate(&CorpusSpec {
        target_bytes: bytes,
        seed,
        ..Default::default()
    })
}

#[test]
fn engine_grid_matches_reference() {
    let corpus = corpus(256 << 10, 1);
    let expect = serial_reference(&corpus, Tokenizer::Spaces);
    for engine in [
        EngineChoice::Blaze,
        EngineChoice::BlazeTcm,
        EngineChoice::Spark,
        EngineChoice::SparkStripped,
    ] {
        for (nodes, threads) in [(1usize, 1usize), (1, 4), (2, 2), (4, 2)] {
            let result = WordCountJob::new(engine)
                .nodes(nodes)
                .threads_per_node(threads)
                .net(NetModel::ideal())
                .run(&corpus)
                .unwrap_or_else(|e| panic!("{} {nodes}x{threads}: {e}", engine.label()));
            assert_eq!(
                result.counts,
                expect,
                "{} at {nodes}x{threads} diverged",
                engine.label()
            );
        }
    }
}

#[test]
fn engines_agree_pairwise_on_fresh_corpora() {
    for seed in [10u64, 20, 30] {
        let corpus = corpus(128 << 10, seed);
        let mut results: Vec<(String, HashMap<String, u64>)> = Vec::new();
        for engine in [EngineChoice::BlazeTcm, EngineChoice::Spark] {
            let r = WordCountJob::new(engine)
                .nodes(2)
                .threads_per_node(2)
                .net(NetModel::ideal())
                .run(&corpus)
                .unwrap();
            results.push((engine.label().to_string(), r.counts));
        }
        assert_eq!(results[0].1, results[1].1, "seed {seed}");
    }
}

#[test]
fn combine_modes_agree() {
    let corpus = corpus(128 << 10, 5);
    let expect = serial_reference(&corpus, Tokenizer::Spaces);
    for combine in [CombineMode::Eager, CombineMode::None] {
        let r = WordCountJob::new(EngineChoice::BlazeTcm)
            .nodes(3)
            .threads_per_node(2)
            .net(NetModel::ideal())
            .combine(combine)
            .run(&corpus)
            .unwrap();
        assert_eq!(r.counts, expect, "{combine:?}");
    }
}

#[test]
fn fault_recovery_preserves_exact_counts() {
    let corpus = corpus(128 << 10, 9);
    let expect = serial_reference(&corpus, Tokenizer::Spaces);

    // Spark: failures in both stages, FT on.
    let r = WordCountJob::new(EngineChoice::Spark)
        .nodes(2)
        .threads_per_node(2)
        .net(NetModel::ideal())
        .failures(FailurePlan::none().fail_task(0, 0).fail_task(1, 1))
        .run(&corpus)
        .unwrap();
    assert_eq!(r.counts, expect, "spark post-recovery counts");

    // Blaze: node failure in each phase, rerun budget covers both.
    let r = WordCountJob::new(EngineChoice::BlazeTcm)
        .nodes(2)
        .threads_per_node(2)
        .net(NetModel::ideal())
        .failures(FailurePlan::none().fail_node(0, 0).fail_node(1, 1))
        .run(&corpus)
        .unwrap();
    assert_eq!(r.counts, expect, "blaze post-rerun counts");
}

#[test]
fn network_model_does_not_change_results() {
    let corpus = corpus(64 << 10, 3);
    let expect = serial_reference(&corpus, Tokenizer::Spaces);
    for net in [NetModel::ideal(), NetModel::aws_like(), NetModel::slow()] {
        let r = WordCountJob::new(EngineChoice::BlazeTcm)
            .nodes(2)
            .threads_per_node(2)
            .net(net)
            .run(&corpus)
            .unwrap();
        assert_eq!(r.counts, expect);
    }
}

#[test]
fn normalized_tokenizer_consistent_across_engines() {
    let corpus = Corpus::from_text("The CAT, the cat! THE-CAT?\nsat.\n");
    let expect = serial_reference(&corpus, Tokenizer::Normalized);
    // "The CAT, the cat! THE-CAT?" → the×3, cat×3 (THE-CAT splits in two).
    assert_eq!(expect.get("the"), Some(&3));
    assert_eq!(expect.get("cat"), Some(&3));
    assert_eq!(expect.get("sat"), Some(&1));
    for engine in [EngineChoice::BlazeTcm, EngineChoice::Spark] {
        let r = WordCountJob::new(engine)
            .nodes(2)
            .threads_per_node(2)
            .net(NetModel::ideal())
            .tokenizer(Tokenizer::Normalized)
            .run(&corpus)
            .unwrap();
        assert_eq!(r.counts, expect, "{}", engine.label());
    }
}

#[test]
fn top_k_is_stable_across_engines() {
    let corpus = corpus(128 << 10, 7);
    let a = WordCountJob::new(EngineChoice::BlazeTcm)
        .net(NetModel::ideal())
        .run(&corpus)
        .unwrap();
    let b = WordCountJob::new(EngineChoice::Spark)
        .net(NetModel::ideal())
        .run(&corpus)
        .unwrap();
    assert_eq!(top_k(&a.counts, 20), top_k(&b.counts, 20));
}

#[test]
fn empty_and_degenerate_corpora() {
    for text in ["", "\n\n\n", "   \n  ", "word\n"] {
        let corpus = Corpus::from_text(text);
        let expect = serial_reference(&corpus, Tokenizer::Spaces);
        for engine in [EngineChoice::BlazeTcm, EngineChoice::Spark] {
            let r = WordCountJob::new(engine)
                .nodes(2)
                .threads_per_node(2)
                .net(NetModel::ideal())
                .run(&corpus)
                .unwrap();
            assert_eq!(r.counts, expect, "{} on {text:?}", engine.label());
        }
    }
}

#[test]
fn unicode_words_survive_all_paths() {
    // Exercises the UTF-16 JvmWord path and the serde path with non-ASCII.
    let corpus = Corpus::from_text("héllo wörld héllo\n你好 世界 你好 héllo\n");
    let expect = serial_reference(&corpus, Tokenizer::Spaces);
    for engine in [EngineChoice::BlazeTcm, EngineChoice::Spark] {
        let r = WordCountJob::new(engine)
            .nodes(2)
            .threads_per_node(2)
            .net(NetModel::ideal())
            .run(&corpus)
            .unwrap();
        assert_eq!(r.counts, expect, "{}", engine.label());
        assert_eq!(r.counts.get("héllo"), Some(&3));
        assert_eq!(r.counts.get("你好"), Some(&2));
    }
}
