//! Observability suite: the structured tracer must cost ~nothing while
//! disabled, never perturb results while enabled, export valid Chrome
//! trace-event JSON with per-worker tracks, and account for a chained
//! job's wall clock (stage walls + driver bridge ≈ job wall).
//!
//! Trace sessions are process-global (last-start wins), so every test
//! that installs one serializes through [`SESSION_LOCK`] — the library's
//! internal test lock is `pub(crate)` and invisible here.

use std::sync::{Arc, Barrier, Mutex, MutexGuard};

use blaze::cluster::NetModel;
use blaze::corpus::{Corpus, CorpusSpec, Tokenizer};
use blaze::engines::Engine;
use blaze::mapreduce::{run_chained, run_chained_serial, JobInputs, JobSpec};
use blaze::runtime::executor::Executor;
use blaze::trace::{self, chrome, profile, SpanCat, TraceSession};
use blaze::wordcount::serial_reference;
use blaze::workloads::{synthesize_logs, Sessionize, WordCount};

static SESSION_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    SESSION_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn spec(engine: Engine) -> JobSpec {
    JobSpec::new(engine).nodes(2).threads_per_node(2).net(NetModel::ideal())
}

fn small_corpus() -> Corpus {
    Corpus::generate(&CorpusSpec::with_bytes(64 << 10))
}

// ------------------------------------------------------------- overhead ----

/// The disabled probe path is one relaxed atomic load — no clock read, no
/// allocation, no lock. The designed overhead on an untraced run is well
/// under the ~2% budget; this guard only catches gross regressions (an
/// accidental lock or allocation on the disabled path), so the bound is
/// deliberately loose for shared CI machines.
#[test]
fn disabled_probes_are_near_free_and_record_nothing() {
    let _g = lock();
    const PROBES: u32 = 200_000;
    let t0 = std::time::Instant::now();
    for i in 0..PROBES {
        let _s = trace::span_arg(SpanCat::Task, "bench-probe", u64::from(i));
        trace::counter("queue depth", u64::from(i));
    }
    let elapsed = t0.elapsed();
    assert!(
        elapsed.as_nanos() < u128::from(PROBES) * 2_000,
        "disabled probes averaged over 2us each: {elapsed:?} for {PROBES} probe pairs"
    );
    // Nothing recorded while disabled leaks into the next session.
    let session = TraceSession::start();
    let t = session.finish();
    assert_eq!(t.span_count(), 0, "{t:?}");
}

// ----------------------------------------------------- schema round-trip ----

#[test]
fn traced_run_round_trips_through_chrome_json() {
    let _g = lock();
    let session = TraceSession::start();

    // Every pool worker runs at least one task (the barrier holds each
    // one until all four have started), so each worker thread records a
    // Task span in its own buffer.
    let width = 4;
    let exec = Executor::for_threads(Some(width));
    let barrier = Barrier::new(width);
    exec.run_tasks(width, |_, _| {
        barrier.wait();
    })
    .unwrap();

    // One real job on top, for Stage/Map/Exchange spans.
    let corpus = small_corpus();
    let w = Arc::new(WordCount::new(Tokenizer::Spaces));
    spec(Engine::BlazeTcm).threads(width).run_str(&w, &corpus).unwrap();

    let trace = session.finish();
    assert_eq!(trace.dropped(), 0, "nothing hit buffer capacity");
    let exec_threads: Vec<_> = trace
        .threads
        .iter()
        .filter(|t| t.name.starts_with("blaze-exec-"))
        .collect();
    assert!(exec_threads.len() >= width, "expected >= {width} worker tracks: {trace:?}");
    for t in &exec_threads {
        assert!(
            t.spans.iter().any(|s| s.cat == SpanCat::Task),
            "worker {} recorded no Task span",
            t.name
        );
    }
    let cats: std::collections::HashSet<SpanCat> =
        trace.threads.iter().flat_map(|t| t.spans.iter().map(|s| s.cat)).collect();
    for cat in [SpanCat::Stage, SpanCat::Map, SpanCat::Exchange, SpanCat::Task] {
        assert!(cats.contains(&cat), "missing {cat:?} spans: {cats:?}");
    }

    // Export -> parse -> validate: counts agree, every span thread is
    // named, the queue-depth counter track survives.
    let json = chrome::render(&trace);
    let parsed = chrome::parse(&json).unwrap();
    let summary = chrome::validate(&json).unwrap();
    assert_eq!(summary.events, parsed.len());
    assert_eq!(summary.span_events, trace.span_count());
    assert!(summary.span_threads >= width, "{summary:?}");
    assert!(
        summary.thread_names.values().any(|n| n == "blaze-exec-0"),
        "{summary:?}"
    );
    assert!(
        summary.counter_tracks.iter().any(|n| n == "queue depth"),
        "{summary:?}"
    );
}

#[test]
fn profile_analysis_attributes_phases_to_stages() {
    let _g = lock();
    let session = TraceSession::start();
    let corpus = small_corpus();
    let w = Arc::new(WordCount::new(Tokenizer::Spaces));
    spec(Engine::BlazeTcm).threads(4).run_str(&w, &corpus).unwrap();
    let trace = session.finish();

    let report = profile::analyze(&trace);
    assert!(!report.rows.is_empty());
    assert!(report.tasks > 0, "executor tasks should appear in the profile");
    let map = report
        .rows
        .iter()
        .find(|r| r.phase == "map" && r.stage.is_some())
        .expect("a stage-attributed map phase row");
    assert!(map.wall_secs > 0.0 && map.busy_secs >= map.wall_secs * 0.99);
    assert!(!report.critical_path.is_empty());
    assert!(report.critical_secs > 0.0);
    assert!(report.span_wall_secs >= report.rows.iter().map(|r| r.wall_secs).fold(0.0, f64::max));
}

// ---------------------------------------------------------------- parity ----

/// Tracing must never influence results: the traced run's counts are
/// bit-identical to the untraced run's and to the serial oracle, on every
/// engine, at pool widths 1 and 8.
#[test]
fn traced_runs_are_bit_identical_to_untraced_and_oracle() {
    let _g = lock();
    let corpus = small_corpus();
    let oracle = serial_reference(&corpus, Tokenizer::Spaces);
    let w = Arc::new(WordCount::new(Tokenizer::Spaces));
    for engine in [Engine::Blaze, Engine::BlazeTcm, Engine::Spark, Engine::SparkStripped] {
        for threads in [1usize, 8] {
            let untraced = spec(engine).threads(threads).run_str(&w, &corpus).unwrap();
            let session = TraceSession::start();
            let traced = spec(engine).threads(threads).run_str(&w, &corpus).unwrap();
            let trace = session.finish();
            assert!(trace.span_count() > 0, "{} t={threads}: session saw no spans", engine.label());
            assert_eq!(
                traced.output,
                untraced.output,
                "{} t={threads}: tracing changed the output",
                engine.label()
            );
            assert_eq!(traced.output, oracle, "{} t={threads}", engine.label());
        }
    }
}

// ----------------------------------------------------- wall attribution ----

/// The stage-wall fix: driver-side bridge work (finalize/render between
/// stages + re-ingest) is measured on its own, so engine stage walls plus
/// the bridge account for the job wall instead of silently losing the
/// in-between time. Loose tolerances — these are wall-clock measurements
/// on a shared machine.
#[test]
fn chained_stage_walls_plus_bridge_account_for_job_wall() {
    let _g = lock();
    let gap = 120u64;
    let inputs = JobInputs::new()
        .relation_lines("logs", Arc::new(synthesize_logs(12, 4000, gap, 41)));
    let sz = Sessionize::new(gap);
    let expect = run_chained_serial(&sz, &inputs);
    let r = run_chained(&spec(Engine::BlazeTcm).threads(4), &sz, &inputs).unwrap();
    assert_eq!(r.lines, expect);

    assert!(r.bridge_secs >= 0.0);
    assert!(r.detail.get("bridge").is_some(), "chain detail carries the bridge metric: {}", r.detail);
    let stage_walls: f64 = r.stages.iter().map(|s| s.wall_secs).sum();
    let covered = stage_walls + r.bridge_secs;
    // Attributed time can't (meaningfully) exceed the job wall...
    assert!(
        covered <= r.wall_secs * 1.10 + 0.01,
        "stages {stage_walls:.4}s + bridge {:.4}s > wall {:.4}s",
        r.bridge_secs,
        r.wall_secs
    );
    // ...and what the job wall holds beyond the attributed parts (plan
    // compilation, input partitioning) stays a modest slice.
    let unattributed = (r.wall_secs - covered).max(0.0);
    assert!(
        unattributed <= r.wall_secs * 0.5 + 0.05,
        "unattributed driver time {unattributed:.4}s of wall {:.4}s (stages {stage_walls:.4}s, bridge {:.4}s)",
        r.wall_secs,
        r.bridge_secs
    );
}
