//! Trace-lab smoke (PR 7): the CI-sized version of
//! `benches/cache_policies.rs`. A tiny pagerank run records a real cache
//! trace through `JobSpec::trace`; the replay harness then drives it
//! through every eviction policy. Checks: the recorder captures events,
//! every policy earns hits on the re-read pattern, the binary log
//! round-trips, replay is bit-deterministic, and — separately — every
//! policy leaves every engine bit-identical to the serial oracle under a
//! KB-scale budget with spill attached.

use std::sync::Arc;

use blaze::cache::{CacheBudget, PolicySpec};
use blaze::cluster::NetModel;
use blaze::corpus::{Corpus, CorpusSpec};
use blaze::engines::Engine;
use blaze::mapreduce::{
    run_iterative, run_iterative_serial, IterativeSpec, JobInputs, JobSpec,
};
use blaze::storage::trace::{replay, TraceEvent};
use blaze::storage::TraceRecorder;
use blaze::workloads::PageRank;

const ROUNDS: usize = 3;

fn tiny_corpus() -> Corpus {
    Corpus::generate(&CorpusSpec { target_bytes: 8 << 10, vocab_size: 200, ..Default::default() })
}

/// Record the cache trace of a small iterative pagerank run.
fn record_tiny_pagerank() -> (Vec<TraceEvent>, u64) {
    let edges = JobInputs::new().relation("edges", &tiny_corpus());
    let rec = Arc::new(TraceRecorder::new());
    let spec = JobSpec::new(Engine::BlazeTcm)
        .nodes(2)
        .threads_per_node(2)
        .net(NetModel::ideal())
        .trace(Arc::clone(&rec));
    let it = IterativeSpec::new(ROUNDS).tolerance(0.0).cache_budget(CacheBudget::Unbounded);
    run_iterative(&spec, &it, &PageRank::new(), &edges).expect("tiny pagerank");
    (rec.events(), rec.put_bytes())
}

#[test]
fn recorded_pagerank_trace_replays_through_every_policy() {
    let (events, put_bytes) = record_tiny_pagerank();
    assert!(!events.is_empty(), "the iterative driver must touch the cache");
    assert!(put_bytes > 0, "puts must carry byte estimates");

    for policy in PolicySpec::all() {
        // Unbounded: rounds 2.. re-read the cached edge partitions, so
        // every policy must see hits (nothing can be evicted).
        let stats = replay(&events, CacheBudget::Unbounded, policy);
        assert!(stats.hits > 0, "{policy}: no hits on an unbounded replay");
        assert_eq!(stats.evictions, 0, "{policy}: unbounded replay evicted");

        // Tight budget: replaying the same trace twice must give
        // bit-identical stats — the determinism the lab's comparisons
        // (and this repo's parity story) rest on.
        let budget = CacheBudget::Bytes((put_bytes / 2).max(1));
        let first = replay(&events, budget, policy);
        let second = replay(&events, budget, policy);
        assert_eq!(first, second, "{policy}: replay is nondeterministic");
        assert_eq!(
            first.hits + first.misses,
            stats.hits + stats.misses,
            "{policy}: lookup volume depends on the budget"
        );
    }
}

#[test]
fn trace_log_round_trips_through_the_binary_format() {
    let (events, _) = record_tiny_pagerank();
    let rec = TraceRecorder::new();
    for e in &events {
        rec.record(e.op, e.key, e.bytes);
    }
    let decoded = TraceRecorder::events_from_bytes(&rec.to_bytes()).expect("decode");
    assert_eq!(decoded, events, "binary trace log must round-trip");
}

/// The policy knob is invisible in outputs: pagerank on both engines,
/// under every policy, with a KB-scale cache budget and spill attached,
/// stays bit-identical to the serial oracle.
#[test]
fn every_policy_keeps_engines_bit_identical() {
    let corpus = tiny_corpus();
    let edges = JobInputs::new().relation("edges", &corpus);
    let it = IterativeSpec::new(ROUNDS).tolerance(0.0).cache_budget(CacheBudget::Bytes(2048));
    let want = run_iterative_serial(&it, &PageRank::new(), &edges);
    for engine in [Engine::BlazeTcm, Engine::Spark] {
        for policy in PolicySpec::all() {
            let spec = JobSpec::new(engine)
                .nodes(2)
                .threads_per_node(2)
                .net(NetModel::ideal())
                .spill_threshold(1024)
                .eviction_policy(policy);
            let r = run_iterative(&spec, &it, &PageRank::new(), &edges)
                .unwrap_or_else(|e| panic!("{} under {policy}: {e}", engine.label()));
            assert_eq!(
                r.state,
                want.state,
                "{} diverged from the serial oracle under {policy}",
                engine.label()
            );
        }
    }
}
