//! The storage hierarchy's acceptance gate: every workload, on every
//! engine, must produce **bit-identical** output with the bounded-memory
//! exchange forced on (a tiny spill threshold) — and the job report must
//! show the spill actually happened (`storage.spilled_bytes > 0` under
//! the tiny budget, exactly 0 under the default unbounded one).
//!
//! The disk tier under the partition cache rides the same knob: the
//! iterative rows run with a cache budget of a few KB so parsed splits
//! demote to disk and promote back, and the fixed-point state must still
//! match the serial oracle bit-for-bit.

use std::sync::Arc;

use blaze::cache::CacheBudget;
use blaze::cluster::{FailurePlan, NetModel};
use blaze::corpus::{Corpus, CorpusSpec, Tokenizer};
use blaze::engines::Engine;
use blaze::mapreduce::{
    run_chained, run_chained_serial, run_iterative, run_iterative_serial, run_serial,
    run_serial_inputs, IterativeSpec, JobInputs, JobSpec,
};
use blaze::workloads::{
    synthesize_logs, synthesize_points, Components, DistinctCount, Grep, InvertedIndex, Join,
    KMeans, LengthHistogram, PageRank, Sessionize, TopKWords, WordCount,
};

const ENGINES: [Engine; 4] =
    [Engine::Blaze, Engine::BlazeTcm, Engine::Spark, Engine::SparkStripped];

/// A budget of a few KB: far below every test corpus's working set, so
/// every shuffling workload is forced onto the spill path.
const TINY: u64 = 2 << 10;

fn corpus(bytes: u64, seed: u64) -> Corpus {
    Corpus::generate(&CorpusSpec { target_bytes: bytes, seed, ..Default::default() })
}

fn spec(engine: Engine) -> JobSpec {
    JobSpec::new(engine).nodes(2).threads_per_node(2).net(NetModel::ideal())
}

fn spilled(engine: Engine) -> JobSpec {
    spec(engine).spill_threshold(TINY)
}

/// A failure plan exercising the engine's recovery path under spill.
fn failure_plan(engine: Engine) -> FailurePlan {
    match engine {
        Engine::Blaze | Engine::BlazeTcm => FailurePlan::none().fail_node(0, 0).fail_node(1, 1),
        Engine::Spark | Engine::SparkStripped => {
            FailurePlan::none().fail_task(0, 1).fail_task(1, 0)
        }
    }
}

#[test]
fn wordcount_spills_and_matches_serial() {
    let corpus = corpus(96 << 10, 51);
    let w = Arc::new(WordCount::new(Tokenizer::Spaces));
    let expect = run_serial(w.as_ref(), &corpus);
    for engine in ENGINES {
        let r = spilled(engine).run_str(&w, &corpus).unwrap();
        assert_eq!(r.output, expect, "{}", engine.label());
        assert!(
            r.storage.spilled_bytes > 0,
            "{}: tiny budget must spill, got {:?}",
            engine.label(),
            r.storage
        );
        assert!(r.storage.spill_runs > 0, "{}", engine.label());
        // The default (unbounded) exchange never spills.
        let r = spec(engine).run_str(&w, &corpus).unwrap();
        assert_eq!(r.output, expect, "{}", engine.label());
        assert_eq!(r.storage.spilled_bytes, 0, "{}: {:?}", engine.label(), r.storage);
    }
}

#[test]
fn inverted_index_spills_and_matches_serial() {
    // Vec<u32> postings: values that grow under combine exercise the
    // merger's re-estimation and the run cursor's variable-length records.
    let corpus = corpus(64 << 10, 52);
    let w = Arc::new(InvertedIndex::new(Tokenizer::Spaces));
    let expect = run_serial(w.as_ref(), &corpus);
    for engine in ENGINES {
        let r = spilled(engine).run_str(&w, &corpus).unwrap();
        assert_eq!(r.output, expect, "{}", engine.label());
        assert!(r.storage.spilled_bytes > 0, "{}", engine.label());
    }
}

#[test]
fn top_k_and_length_hist_spill_parity() {
    let corpus = corpus(64 << 10, 53);
    let topk = Arc::new(TopKWords::new(Tokenizer::Spaces, 12));
    let hist = Arc::new(LengthHistogram::new(Tokenizer::Spaces));
    let expect_topk = run_serial(topk.as_ref(), &corpus);
    let expect_hist = run_serial(hist.as_ref(), &corpus);
    for engine in ENGINES {
        let r = spilled(engine).run_str(&topk, &corpus).unwrap();
        assert_eq!(r.output, expect_topk, "top-k {}", engine.label());
        assert!(r.storage.spilled_bytes > 0, "top-k {}", engine.label());
        // length-hist: a handful of tiny integer keys — the whole shard
        // fits in a few KB, so parity must hold whether or not anything
        // actually spilled.
        let r = spilled(engine).run(&hist, &corpus).unwrap();
        assert_eq!(r.output, expect_hist, "length-hist {}", engine.label());
    }
}

#[test]
fn join_spills_and_matches_serial() {
    let left = corpus(48 << 10, 54);
    let right = corpus(48 << 10, 55);
    let w = Arc::new(Join::new());
    let inputs = JobInputs::new().relation("left", &left).relation("right", &right);
    let expect = run_serial_inputs(w.as_ref(), &inputs);
    assert!(!expect.is_empty(), "relations must overlap in keys");
    for engine in ENGINES {
        let r = spilled(engine).run_inputs(&w, &inputs).unwrap();
        assert_eq!(r.output, expect, "{}", engine.label());
        assert!(r.storage.spilled_bytes > 0, "{}", engine.label());
    }
}

#[test]
fn distinct_spills_and_matches_serial() {
    let corpus = corpus(64 << 10, 56);
    let w = Arc::new(DistinctCount::new(Tokenizer::Spaces));
    let expect = run_serial(w.as_ref(), &corpus);
    for engine in ENGINES {
        let r = spilled(engine).run(&w, &corpus).unwrap();
        assert_eq!(r.output, expect, "{}", engine.label());
    }
}

#[test]
fn grep_zero_shuffle_never_spills_but_forced_shuffle_does() {
    let corpus = corpus(64 << 10, 57);
    let w = Arc::new(Grep::new("the".to_string()));
    let expect = run_serial(w.as_ref(), &corpus);
    for engine in ENGINES {
        // Elided exchange: the spill threshold has nothing to bound.
        let r = spilled(engine).run(&w, &corpus).unwrap();
        assert_eq!(r.output, expect, "{}", engine.label());
        assert_eq!(r.storage.spilled_bytes, 0, "{}: elided exchange", engine.label());
        // Forced exchange under the tiny budget: matched lines ride the
        // wire and the merge spills.
        let r = spilled(engine).force_shuffle(true).run(&w, &corpus).unwrap();
        assert_eq!(r.output, expect, "{} forced", engine.label());
        assert!(r.storage.spilled_bytes > 0, "{} forced", engine.label());
    }
}

#[test]
fn spill_parity_under_injected_failures() {
    let corpus = corpus(48 << 10, 58);
    let w = Arc::new(WordCount::new(Tokenizer::Spaces));
    let expect = run_serial(w.as_ref(), &corpus);
    for engine in [Engine::Blaze, Engine::BlazeTcm, Engine::Spark] {
        let r = spilled(engine)
            .failures(failure_plan(engine))
            .run_str(&w, &corpus)
            .unwrap();
        assert_eq!(r.output, expect, "{}", engine.label());
        assert!(r.storage.spilled_bytes > 0, "{}", engine.label());
    }
}

#[test]
fn sessionize_chain_spills_and_matches_serial() {
    let gap = 1800u64;
    let inputs = JobInputs::new()
        .relation_lines("logs", Arc::new(synthesize_logs(40, 4000, gap, 59)));
    let w = Sessionize::new(gap);
    let expect = run_chained_serial(&w, &inputs);
    for engine in ENGINES {
        let r = run_chained(&spilled(engine), &w, &inputs).unwrap();
        assert_eq!(r.lines, expect, "{}", engine.label());
        assert!(r.storage.spilled_bytes > 0, "{}: {:?}", engine.label(), r.storage);
        let r = run_chained(&spec(engine), &w, &inputs).unwrap();
        assert_eq!(r.lines, expect, "{}", engine.label());
        assert_eq!(r.storage.spilled_bytes, 0, "{}", engine.label());
    }
}

/// Iterative rows: exchange spill + a cache squeezed to a few KB, so
/// parsed splits demote to the disk tier (and promote back) every round.
fn tiny_cache_spec(engine: Engine) -> (JobSpec, IterativeSpec) {
    let spec = spilled(engine);
    let it = IterativeSpec::new(3).tolerance(0.0).cache_budget(CacheBudget::Bytes(TINY));
    (spec, it)
}

#[test]
fn pagerank_spills_and_matches_fixed_point_oracle() {
    let corpus = Corpus::generate(&CorpusSpec {
        target_bytes: 24 << 10,
        vocab_size: 500,
        seed: 61,
        ..Default::default()
    });
    let inputs = JobInputs::new().relation("edges", &corpus);
    let w = PageRank::new();
    let it = IterativeSpec::new(3).tolerance(0.0).cache_budget(CacheBudget::Bytes(TINY));
    let oracle = run_iterative_serial(&it, &w, &inputs);
    assert!(!oracle.state.is_empty());
    for engine in ENGINES {
        let (spec, it) = tiny_cache_spec(engine);
        let r = run_iterative(&spec, &it, &w, &inputs).unwrap();
        assert_eq!(r.state, oracle.state, "{}", engine.label());
        assert_eq!(r.iterations, oracle.iterations, "{}", engine.label());
        assert!(r.storage.spilled_bytes > 0, "{}: exchange spill", engine.label());
        assert!(
            r.storage.demotions > 0,
            "{}: parsed splits must demote under the tiny cache: {:?}",
            engine.label(),
            r.storage
        );
    }
}

#[test]
fn components_spill_parity() {
    let corpus = Corpus::generate(&CorpusSpec {
        target_bytes: 16 << 10,
        vocab_size: 300,
        seed: 62,
        ..Default::default()
    });
    let inputs = JobInputs::new().relation("edges", &corpus);
    let w = Components::new();
    let it = IterativeSpec::new(3).tolerance(0.0).cache_budget(CacheBudget::Bytes(TINY));
    let oracle = run_iterative_serial(&it, &w, &inputs);
    for engine in ENGINES {
        let (spec, it) = tiny_cache_spec(engine);
        let r = run_iterative(&spec, &it, &w, &inputs).unwrap();
        assert_eq!(r.state, oracle.state, "{}", engine.label());
        assert!(r.storage.spilled_bytes > 0, "{}", engine.label());
    }
}

#[test]
fn kmeans_spill_parity() {
    let inputs =
        JobInputs::new().relation_lines("points", Arc::new(synthesize_points(400, 3, 5, 63)));
    let w = KMeans::new(5);
    let it = IterativeSpec::new(4).tolerance(0.0).cache_budget(CacheBudget::Bytes(TINY));
    let oracle = run_iterative_serial(&it, &w, &inputs);
    for engine in ENGINES {
        let (spec, it) = tiny_cache_spec(engine);
        let r = run_iterative(&spec, &it, &w, &inputs).unwrap();
        assert_eq!(r.state, oracle.state, "{}", engine.label());
        assert_eq!(r.iterations, oracle.iterations, "{}", engine.label());
        assert!(r.storage.demotions > 0, "{}: {:?}", engine.label(), r.storage);
    }
}

/// The work-stealing executor's acceptance gate: the real pool width must
/// be invisible in the output. Every workload shape — single-pass,
/// zero-shuffle, multi-input, two-stage chained, and iterative — runs on
/// every engine at widths 1/2/4/8 under the 2 KB spill budget and must
/// stay bit-identical to the serial oracle. Steal order only reorders
/// combine applications (associative + commutative) and finalize
/// canonicalizes, so any divergence here is an executor bug.
#[test]
fn thread_sweep_spill_parity_all_workloads() {
    let text = corpus(48 << 10, 64);
    let left = corpus(24 << 10, 65);
    let right = corpus(24 << 10, 66);
    let wc = Arc::new(WordCount::new(Tokenizer::Spaces));
    let idx = Arc::new(InvertedIndex::new(Tokenizer::Spaces));
    let topk = Arc::new(TopKWords::new(Tokenizer::Spaces, 12));
    let hist = Arc::new(LengthHistogram::new(Tokenizer::Spaces));
    let distinct = Arc::new(DistinctCount::new(Tokenizer::Spaces));
    let grep = Arc::new(Grep::new("the".to_string()));
    let join = Arc::new(Join::new());
    let join_inputs = JobInputs::new().relation("left", &left).relation("right", &right);
    let expect_wc = run_serial(wc.as_ref(), &text);
    let expect_idx = run_serial(idx.as_ref(), &text);
    let expect_topk = run_serial(topk.as_ref(), &text);
    let expect_hist = run_serial(hist.as_ref(), &text);
    let expect_distinct = run_serial(distinct.as_ref(), &text);
    let expect_grep = run_serial(grep.as_ref(), &text);
    let expect_join = run_serial_inputs(join.as_ref(), &join_inputs);

    let gap = 1800u64;
    let logs =
        JobInputs::new().relation_lines("logs", Arc::new(synthesize_logs(30, 2000, gap, 67)));
    let sz = Sessionize::new(gap);
    let expect_sz = run_chained_serial(&sz, &logs);

    let edges = Corpus::generate(&CorpusSpec {
        target_bytes: 12 << 10,
        vocab_size: 300,
        seed: 68,
        ..Default::default()
    });
    let edge_inputs = JobInputs::new().relation("edges", &edges);
    let pr = PageRank::new();
    let it = IterativeSpec::new(3).tolerance(0.0).cache_budget(CacheBudget::Bytes(TINY));
    let expect_pr = run_iterative_serial(&it, &pr, &edge_inputs);

    for threads in [1usize, 2, 4, 8] {
        for engine in ENGINES {
            let at = |s: JobSpec| s.threads(threads);
            let ctx = format!("{} @{threads}T", engine.label());
            let r = at(spilled(engine)).run_str(&wc, &text).unwrap();
            assert_eq!(r.output, expect_wc, "wordcount {ctx}");
            assert!(r.storage.spilled_bytes > 0, "wordcount {ctx} must spill");
            let r = at(spilled(engine)).run_str(&idx, &text).unwrap();
            assert_eq!(r.output, expect_idx, "index {ctx}");
            let r = at(spilled(engine)).run_str(&topk, &text).unwrap();
            assert_eq!(r.output, expect_topk, "top-k {ctx}");
            let r = at(spilled(engine)).run(&hist, &text).unwrap();
            assert_eq!(r.output, expect_hist, "length-hist {ctx}");
            let r = at(spilled(engine)).run(&distinct, &text).unwrap();
            assert_eq!(r.output, expect_distinct, "distinct {ctx}");
            let r = at(spilled(engine)).run(&grep, &text).unwrap();
            assert_eq!(r.output, expect_grep, "grep {ctx}");
            let r = at(spilled(engine)).run_inputs(&join, &join_inputs).unwrap();
            assert_eq!(r.output, expect_join, "join {ctx}");
            let r = run_chained(&at(spilled(engine)), &sz, &logs).unwrap();
            assert_eq!(r.lines, expect_sz, "sessionize {ctx}");
            let r = run_iterative(&at(spilled(engine)), &it, &pr, &edge_inputs).unwrap();
            assert_eq!(r.state, expect_pr.state, "pagerank {ctx}");
            assert_eq!(r.iterations, expect_pr.iterations, "pagerank {ctx}");
        }
    }
}

/// Same sweep with injected failures riding on top of the tiny spill
/// budget: reruns/retries re-dispatch onto the pool, and recovery at any
/// width must still converge on the serial oracle's bytes.
#[test]
fn thread_sweep_failure_parity() {
    let text = corpus(32 << 10, 69);
    let wc = Arc::new(WordCount::new(Tokenizer::Spaces));
    let expect = run_serial(wc.as_ref(), &text);
    let gap = 1800u64;
    let logs =
        JobInputs::new().relation_lines("logs", Arc::new(synthesize_logs(20, 1500, gap, 70)));
    let sz = Sessionize::new(gap);
    let expect_sz = run_chained_serial(&sz, &logs);
    for threads in [1usize, 2, 4, 8] {
        for engine in [Engine::Blaze, Engine::BlazeTcm, Engine::Spark] {
            let ctx = format!("{} @{threads}T", engine.label());
            let r = spilled(engine)
                .threads(threads)
                .failures(failure_plan(engine))
                .run_str(&wc, &text)
                .unwrap();
            assert_eq!(r.output, expect, "wordcount {ctx}");
            assert!(r.storage.spilled_bytes > 0, "wordcount {ctx} must spill");
            let chained = spilled(engine).threads(threads).failures(failure_plan(engine));
            let r = run_chained(&chained, &sz, &logs).unwrap();
            assert_eq!(r.lines, expect_sz, "sessionize {ctx}");
        }
    }
}

#[test]
fn plan_records_the_spill_threshold() {
    let w = WordCount::new(Tokenizer::Spaces);
    let inputs = JobInputs::new().relation_lines("input", Arc::new(Vec::new()));
    let graph = spilled(Engine::BlazeTcm).plan(&w, &inputs);
    assert_eq!(graph.stage(0).spill_threshold, Some(TINY));
    assert!(graph.render().contains("external merge beyond"), "{}", graph.render());
    let graph = spec(Engine::BlazeTcm).plan(&w, &inputs);
    assert_eq!(graph.stage(0).spill_threshold, None);
    assert!(!graph.render().contains("external merge"), "{}", graph.render());
}
