//! Cross-engine parity for the iterative job driver: PageRank and k-means
//! must reproduce the serial fixed-point oracle **bit-identically** on
//! every engine — per iteration and end-to-end, with and without injected
//! failures — and the partition cache must change only speed, never
//! results.

use std::sync::Arc;

use blaze::cache::{CacheBudget, PartitionCache};
use blaze::cluster::{FailurePlan, NetModel};
use blaze::corpus::{Corpus, CorpusSpec};
use blaze::engines::Engine;
use blaze::mapreduce::{
    run_iterative, run_iterative_serial, run_serial_inputs, IterativeSpec, IterativeWorkload,
    JobInputs, JobSpec,
};
use blaze::workloads::{synthesize_points, Components, KMeans, PageRank};

const ENGINES: [Engine; 4] =
    [Engine::Blaze, Engine::BlazeTcm, Engine::Spark, Engine::SparkStripped];

/// Engines with a recovery path to exercise (stripped Spark has FT off).
const FAILURE_ENGINES: [Engine; 3] = [Engine::Blaze, Engine::BlazeTcm, Engine::Spark];

fn spec(engine: Engine) -> JobSpec {
    JobSpec::new(engine).nodes(2).threads_per_node(2).net(NetModel::ideal())
}

/// A failure plan exercising the engine's recovery path (one-shot
/// injections, consumed by the first round they hit).
fn failure_plan(engine: Engine) -> FailurePlan {
    match engine {
        Engine::Blaze | Engine::BlazeTcm => FailurePlan::none().fail_node(0, 0).fail_node(1, 1),
        Engine::Spark | Engine::SparkStripped => {
            FailurePlan::none().fail_task(0, 1).fail_task(1, 0)
        }
    }
}

/// Corpus lines as an edge relation (`src dst...` per line).
fn edge_inputs(bytes: u64, seed: u64) -> JobInputs {
    let corpus = Corpus::generate(&CorpusSpec {
        target_bytes: bytes,
        vocab_size: 500, // dense-ish graph: nodes recur across lines
        seed,
        ..Default::default()
    });
    JobInputs::new().relation("edges", &corpus)
}

fn point_inputs(n: usize, seed: u64) -> JobInputs {
    JobInputs::new().relation_lines("points", Arc::new(synthesize_points(n, 3, 5, seed)))
}

#[test]
fn pagerank_bit_identical_to_serial_oracle() {
    let inputs = edge_inputs(24 << 10, 31);
    let w = PageRank::new();
    // tolerance 0: a fixed round count, so iterations must match too.
    let it = IterativeSpec::new(4).tolerance(0.0);
    let oracle = run_iterative_serial(&it, &w, &inputs);
    assert_eq!(oracle.iterations, 4);
    assert!(!oracle.state.is_empty());
    for engine in ENGINES {
        let r = run_iterative(&spec(engine), &it, &w, &inputs).unwrap();
        assert_eq!(r.state, oracle.state, "{}", engine.label());
        assert_eq!(r.iterations, oracle.iterations, "{}", engine.label());
        assert_eq!(r.converged, oracle.converged, "{}", engine.label());
    }
}

#[test]
fn pagerank_parity_under_injected_failures() {
    let inputs = edge_inputs(16 << 10, 33);
    let w = PageRank::new();
    let it = IterativeSpec::new(3).tolerance(0.0);
    let oracle = run_iterative_serial(&it, &w, &inputs);
    for engine in FAILURE_ENGINES {
        // Fresh plan per engine: injections are one-shot and consumed by
        // the first round's tasks; recovery must not perturb the state.
        let r = run_iterative(
            &spec(engine).failures(failure_plan(engine)),
            &it,
            &w,
            &inputs,
        )
        .unwrap();
        assert_eq!(r.state, oracle.state, "{}", engine.label());
        assert_eq!(r.iterations, oracle.iterations, "{}", engine.label());
    }
}

#[test]
fn kmeans_bit_identical_to_serial_oracle() {
    let inputs = point_inputs(300, 41);
    let w = KMeans::new(5);
    let it = IterativeSpec::new(12).tolerance(0.0);
    let oracle = run_iterative_serial(&it, &w, &inputs);
    for engine in ENGINES {
        let r = run_iterative(&spec(engine), &it, &w, &inputs).unwrap();
        assert_eq!(r.state, oracle.state, "{}", engine.label());
        assert_eq!(r.iterations, oracle.iterations, "{}", engine.label());
        assert_eq!(r.converged, oracle.converged, "{}", engine.label());
    }
}

#[test]
fn kmeans_parity_under_injected_failures() {
    let inputs = point_inputs(200, 43);
    let w = KMeans::new(4);
    let it = IterativeSpec::new(6).tolerance(0.0);
    let oracle = run_iterative_serial(&it, &w, &inputs);
    for engine in FAILURE_ENGINES {
        let r = run_iterative(
            &spec(engine).failures(failure_plan(engine)),
            &it,
            &w,
            &inputs,
        )
        .unwrap();
        assert_eq!(r.state, oracle.state, "{}", engine.label());
    }
}

#[test]
fn components_bit_identical_to_serial_oracle() {
    // Corpus lines as undirected adjacency fragments; default tolerance
    // (delta counts changed labels, so convergence is exact).
    let inputs = edge_inputs(24 << 10, 81);
    let w = Components::new();
    let it = IterativeSpec::new(8);
    let oracle = run_iterative_serial(&it, &w, &inputs);
    assert!(!oracle.state.is_empty());
    for engine in ENGINES {
        let r = run_iterative(&spec(engine), &it, &w, &inputs).unwrap();
        assert_eq!(r.state, oracle.state, "{}", engine.label());
        assert_eq!(r.iterations, oracle.iterations, "{}", engine.label());
        assert_eq!(r.converged, oracle.converged, "{}", engine.label());
    }
}

#[test]
fn components_parity_under_injected_failures() {
    let inputs = edge_inputs(16 << 10, 83);
    let w = Components::new();
    let it = IterativeSpec::new(4).tolerance(0.0);
    let oracle = run_iterative_serial(&it, &w, &inputs);
    for engine in FAILURE_ENGINES {
        let r = run_iterative(
            &spec(engine).failures(failure_plan(engine)),
            &it,
            &w,
            &inputs,
        )
        .unwrap();
        assert_eq!(r.state, oracle.state, "{}", engine.label());
    }
}

#[test]
fn components_label_two_islands_distinctly() {
    let inputs = JobInputs::new().relation(
        "edges",
        &Corpus::from_text("a b\nb c\nx y\n"),
    );
    let w = Components::new();
    let it = IterativeSpec::new(10);
    for engine in ENGINES {
        let r = run_iterative(&spec(engine), &it, &w, &inputs).unwrap();
        assert!(r.converged, "{}", engine.label());
        let labels: std::collections::HashMap<String, u64> =
            Components::labels_from_state(&r.state).into_iter().collect();
        assert_eq!(labels["a"], labels["b"], "{}", engine.label());
        assert_eq!(labels["b"], labels["c"], "{}", engine.label());
        assert_eq!(labels["x"], labels["y"], "{}", engine.label());
        assert_ne!(labels["a"], labels["x"], "{}", engine.label());
        let sizes = Components::component_sizes(&r.state);
        assert_eq!(
            sizes.iter().map(|&(_, n)| n).collect::<Vec<_>>(),
            vec![3, 2],
            "{}",
            engine.label()
        );
    }
}

/// Every round's step job must individually match `run_serial_inputs` —
/// the per-iteration half of the acceptance bar.
#[test]
fn pagerank_rounds_match_serial_per_iteration() {
    let inputs = edge_inputs(12 << 10, 51);
    let w = PageRank::new();
    let state = w.init_state(&inputs);
    for engine in [Engine::BlazeTcm, Engine::Spark] {
        let sp = spec(engine).shared_cache(Arc::new(PartitionCache::new(CacheBudget::Unbounded)));
        let mut st = state.clone();
        for round in 0..3u64 {
            let step = w.step(&st);
            let ri = inputs.clone().relation_lines("state", Arc::new(st.clone()));
            let expect = run_serial_inputs(step.as_ref(), &ri);
            let got = sp
                .clone()
                .relation_gens(vec![0, round])
                .run_inputs_cached(&step, &ri)
                .unwrap();
            assert_eq!(got.output, expect, "{} round {round}", engine.label());
            let (next, _delta) = w.advance(expect, &st);
            st = next;
        }
    }
    // The manual loop must agree with the driver, too.
    let driven = run_iterative_serial(&IterativeSpec::new(3).tolerance(0.0), &w, &inputs);
    let mut st = state;
    for _ in 0..3 {
        let step = w.step(&st);
        let ri = inputs.clone().relation_lines("state", Arc::new(st.clone()));
        let (next, _) = w.advance(run_serial_inputs(step.as_ref(), &ri), &st);
        st = next;
    }
    assert_eq!(st, driven.state);
}

/// The cache ablation: unbounded vs zero budget changes hit rates and
/// work, never results.
#[test]
fn cache_budget_changes_hits_not_results() {
    let inputs = edge_inputs(16 << 10, 61);
    let w = PageRank::new();
    let it = IterativeSpec::new(4).tolerance(0.0);
    for engine in [Engine::BlazeTcm, Engine::Spark] {
        let warm =
            run_iterative(&spec(engine), &it.cache_budget(CacheBudget::Unbounded), &w, &inputs)
                .unwrap();
        let cold =
            run_iterative(&spec(engine), &it.cache_budget(CacheBudget::Bytes(0)), &w, &inputs)
                .unwrap();
        assert_eq!(warm.state, cold.state, "{}", engine.label());
        // Warm: the static edge relation parses once, then hits every
        // later round on every split.
        assert!(warm.cache.hits > 0, "{}: {:?}", engine.label(), warm.cache);
        assert!(warm.cache.hit_rate() > 0.0, "{}", engine.label());
        // Cold: a zero budget bypasses the cache entirely — nothing is
        // admitted, nothing is even looked up.
        assert_eq!(cold.cache.hits, 0, "{}: {:?}", engine.label(), cold.cache);
        assert_eq!(cold.cache.insertions, 0, "{}: {:?}", engine.label(), cold.cache);
        assert_eq!(cold.cache.bytes_cached, 0, "{}", engine.label());
        // Round 1+ of the warm run serves the edge splits from memory.
        assert!(
            warm.iters[1].cache.hits > 0,
            "{}: round-1 stats {:?}",
            engine.label(),
            warm.iters[1].cache
        );
    }
}

/// Bumping a relation's generation invalidates its cached splits (they
/// stop matching and re-parse); unchanged generations keep hitting.
#[test]
fn generation_bump_forces_reparse() {
    let inputs = edge_inputs(8 << 10, 71);
    let w = PageRank::new();
    let state = w.init_state(&inputs);
    let step = w.step(&state);
    let ri = inputs.clone().relation_lines("state", Arc::new(state.clone()));
    let cache = Arc::new(PartitionCache::new(CacheBudget::Unbounded));
    let sp = spec(Engine::BlazeTcm).shared_cache(Arc::clone(&cache));

    let first = sp.clone().relation_gens(vec![0, 0]).run_inputs_cached(&step, &ri).unwrap();
    assert_eq!(first.cache.hits, 0);
    assert!(first.cache.insertions > 0);

    let second = sp.clone().relation_gens(vec![0, 0]).run_inputs_cached(&step, &ri).unwrap();
    assert!(second.cache.hits > 0, "{:?}", second.cache);
    assert_eq!(second.cache.misses, 0, "{:?}", second.cache);
    assert_eq!(second.output, first.output);

    let bumped = sp.relation_gens(vec![1, 1]).run_inputs_cached(&step, &ri).unwrap();
    assert!(bumped.cache.misses > 0, "{:?}", bumped.cache);
    assert_eq!(bumped.output, first.output);
}

#[test]
fn iterative_report_metrics_are_sane() {
    let inputs = point_inputs(150, 81);
    let w = KMeans::new(3);
    let it = IterativeSpec::new(8).tolerance(0.0);
    let r = run_iterative(&spec(Engine::BlazeTcm), &it, &w, &inputs).unwrap();
    assert_eq!(r.workload, "kmeans");
    assert_eq!(r.iters.len(), r.iterations);
    assert!(r.iterations > 0 && r.iterations <= 8);
    assert!(r.wall_secs > 0.0);
    for row in &r.iters {
        assert!(row.records > 0, "every round maps every point");
        assert!(row.shuffle_bytes > 0, "assignment needs the exchange");
        assert!(row.wall_secs >= 0.0);
    }
    if r.converged {
        assert_eq!(r.iters.last().unwrap().delta, 0.0, "exact fixed point");
    }
    // Per-round cache deltas sum to the cumulative counters.
    let summed: u64 = r.iters.iter().map(|i| i.cache.hits).sum();
    assert_eq!(summed, r.cache.hits);
}

/// The driver validates shapes up front.
#[test]
fn iterative_arity_is_validated() {
    let w = PageRank::new();
    let two = JobInputs::new()
        .relation("a", &Corpus::from_text("x y\n"))
        .relation("b", &Corpus::from_text("y x\n"));
    let err = run_iterative(
        &spec(Engine::Blaze),
        &IterativeSpec::new(2),
        &w,
        &two,
    )
    .unwrap_err();
    assert!(err.to_string().contains("static input relation(s)"), "{err}");
}
