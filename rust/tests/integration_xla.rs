//! Cross-layer integration: the AOT Pallas/XLA artifacts must agree with
//! the rust engines on real corpora. Soft-skips when `make artifacts` has
//! not run (the Makefile's `test` target always builds them first).

use blaze::corpus::{Corpus, CorpusSpec, Tokenizer, Vocab};
use blaze::runtime::HistogramRuntime;
use blaze::wordcount::serial_reference;

fn runtime() -> Option<HistogramRuntime> {
    if !HistogramRuntime::available() {
        eprintln!("skipping xla integration: artifacts/ not built");
        return None;
    }
    Some(HistogramRuntime::from_env().expect("PJRT runtime"))
}

#[test]
fn runtime_histogram_matches_serial_reference() {
    let Some(hr) = runtime() else { return };
    let corpus = Corpus::generate(&CorpusSpec::with_bytes(512 << 10));
    let vocab = Vocab::from_lines(&corpus.lines);
    assert!(vocab.len() <= hr.spec.vocab, "test corpus vocab must fit the artifact");
    let ids = vocab.encode_lines(&corpus.lines);
    let counts = hr.count_tokens(&ids).expect("xla count");

    let reference = serial_reference(&corpus, Tokenizer::Spaces);
    assert_eq!(
        counts.iter().sum::<u64>(),
        corpus.words,
        "total tokens must match corpus words"
    );
    for (word, &expect) in &reference {
        let id = vocab.id_of(word);
        assert!(id > 0, "word {word} must be in vocab");
        assert_eq!(counts[id as usize], expect, "count for {word}");
    }
}

#[test]
fn runtime_and_engine_topk_agree() {
    let Some(hr) = runtime() else { return };
    let corpus = Corpus::generate(&CorpusSpec::with_bytes(256 << 10));
    let vocab = Vocab::from_lines(&corpus.lines);
    let ids = vocab.encode_lines(&corpus.lines);
    let (_, xla_top) = hr.count_tokens_topk(&ids).expect("topk");

    let reference = serial_reference(&corpus, Tokenizer::Spaces);
    let engine_top = blaze::wordcount::top_k(&reference, 5);
    // Compare the top-5 by mapping ids back to words. Counts must match
    // exactly; order can differ on ties, so compare as count-sorted sets.
    let xla_top5: Vec<(String, u64)> = xla_top
        .iter()
        .take(5)
        .map(|&(id, c)| (vocab.word_of(id).to_string(), c))
        .collect();
    let mut a = xla_top5.clone();
    let mut b = engine_top.clone();
    a.sort();
    b.sort();
    assert_eq!(a, b, "xla top5 {xla_top5:?} vs engine {engine_top:?}");
}

#[test]
fn oov_words_fold_into_unk() {
    let Some(hr) = runtime() else { return };
    // A vocab built from only part of the corpus: the rest becomes UNK(0).
    let corpus = Corpus::from_text("alpha beta gamma\nalpha delta epsilon\n");
    let vocab = Vocab::build(["alpha".to_string(), "beta".to_string()]);
    let ids = vocab.encode_lines(&corpus.lines);
    let counts = hr.count_tokens(&ids).expect("count");
    assert_eq!(counts[vocab.id_of("alpha") as usize], 2);
    assert_eq!(counts[vocab.id_of("beta") as usize], 1);
    assert_eq!(counts[0], 3, "gamma+delta+epsilon fold into UNK");
}

#[test]
fn hashed_and_dense_totals_agree() {
    let Some(hr) = runtime() else { return };
    let corpus = Corpus::generate(&CorpusSpec::with_bytes(128 << 10));
    let vocab = Vocab::from_lines(&corpus.lines);
    let ids = vocab.encode_lines(&corpus.lines);
    let dense = hr.count_tokens(&ids).expect("dense");
    let hashed = hr.count_hashed(&ids).expect("hashed");
    assert_eq!(
        dense.iter().sum::<u64>(),
        hashed.iter().sum::<u64>(),
        "both paths must count every token exactly once"
    );
}
