//! Property-based suite over the coordinator invariants (DESIGN.md §6),
//! using the in-tree `util::proptest` harness (seeded, shrinking-lite).

use std::collections::BTreeMap;

use blaze::cluster::{spawn_cluster, NetModel};
use blaze::concurrent::ConcurrentHashMap;
use blaze::corpus::Corpus;
use blaze::dist::{reducer, CombineMode, DistHashMap, DistRange};
use blaze::hash::HashKind;
use blaze::util::pool::{parallel_for, Schedule};
use blaze::util::proptest::{check, check_with, fail, Config, Gen};
use blaze::util::ser::{Decode, Encode};
use blaze::wordcount::{serial_reference, EngineChoice, WordCountJob};

/// ConcurrentHashMap under N threads ≡ serial BTreeMap fold.
#[test]
fn prop_concurrent_map_no_lost_updates() {
    check("concurrent-map-vs-serial", |g| {
        let nthreads = g.usize_in(1, 8);
        let nsegs = g.usize_in(1, 32);
        let keys: Vec<String> = {
            let distinct = g.usize_in(1, 40);
            (0..g.usize_in(1, 400)).map(|i| format!("k{}", i % distinct)).collect()
        };
        let m: ConcurrentHashMap<String, u64> =
            ConcurrentHashMap::new(nsegs, nthreads, HashKind::Fx);
        parallel_for(nthreads, keys.len(), Schedule::Dynamic { chunk: 3 }, |ctx, i| {
            m.upsert(ctx.worker, keys[i].clone(), 1, |a, b| *a += b);
        });
        m.sync(nthreads, |a, b| *a += b);
        let mut serial: BTreeMap<String, u64> = BTreeMap::new();
        for k in &keys {
            *serial.entry(k.clone()).or_insert(0) += 1;
        }
        let mut got: BTreeMap<String, u64> = m.to_vec().into_iter().collect();
        if m.pending_cache_entries() != 0 {
            return fail("cache entries left after sync");
        }
        if got != serial {
            got.retain(|k, v| serial.get(k) != Some(v));
            return fail(format!("diverged on {} keys: {got:?}", got.len()));
        }
        Ok(())
    });
}

/// DistHashMap: every key lands on exactly `owner(hash)`, totals preserved.
#[test]
fn prop_dist_map_routing_and_totals() {
    check_with(Config { cases: 24, ..Default::default() }, "dist-map-routing", |g| {
        let nnodes = g.usize_in(1, 4);
        let nthreads = g.usize_in(1, 3);
        let combine = if g.bool() { CombineMode::Eager } else { CombineMode::None };
        let words: Vec<String> = {
            let distinct = g.usize_in(1, 30);
            (0..g.usize_in(1, 300)).map(|_| {
                let i = g.usize_in(0, distinct - 1);
                format!("w{i}")
            }).collect()
        };
        let words_ref = &words;
        let dict_keys = g.bool();
        let results = spawn_cluster(nnodes, NetModel::ideal(), move |comm| {
            let map: DistHashMap<String, u64> =
                DistHashMap::new(comm.rank, nnodes, nthreads, HashKind::Fx, combine);
            // Every node inserts the full stream (totals = nnodes × stream).
            parallel_for(nthreads, words_ref.len(), Schedule::Static, |ctx, i| {
                map.upsert(ctx.worker, words_ref[i].clone(), 1, reducer::sum);
            });
            map.shuffle(comm, reducer::sum, dict_keys);
            let owned = map.to_vec_local();
            // Routing invariant: we own only keys whose owner is us.
            let misrouted = owned.iter().filter(|(k, _)| map.owner_of(k) != comm.rank).count();
            (owned, misrouted)
        });
        let mut total = 0u64;
        let mut keys_seen = std::collections::HashSet::new();
        for (owned, misrouted) in results {
            if misrouted > 0 {
                return fail(format!("{misrouted} misrouted keys"));
            }
            for (k, v) in owned {
                if !keys_seen.insert(k.clone()) {
                    return fail(format!("key {k} owned by two nodes"));
                }
                total += v;
            }
        }
        let expect = (words.len() * nnodes) as u64;
        if total != expect {
            return fail(format!("total {total} != expected {expect}"));
        }
        Ok(())
    });
}

/// DistRange node blocks partition the index space exactly once, for all
/// shapes (start, end, step, nnodes).
#[test]
fn prop_dist_range_partition() {
    check("dist-range-partition", |g| {
        let start = g.i64_in(-1000, 1000);
        let len = g.i64_in(0, 2000);
        let step = *g.choose(&[1i64, 2, 3, 7, -1, -3]);
        let (a, b) = if step > 0 { (start, start + len) } else { (start + len, start) };
        let range = DistRange::with_step(a, b, step);
        let nnodes = g.usize_in(1, 6);
        let mut covered = 0usize;
        let mut prev_hi = 0usize;
        for rank in 0..nnodes {
            let (lo, hi) = range.node_block(rank, nnodes);
            if lo != prev_hi {
                return fail(format!("gap at rank {rank}: lo {lo} != prev {prev_hi}"));
            }
            covered += hi - lo;
            prev_hi = hi;
        }
        if covered != range.len() {
            return fail(format!("covered {covered} != len {}", range.len()));
        }
        // And the values are within the mathematical range.
        for i in 0..range.len() {
            let v = range.at(i);
            let in_range = if step > 0 { v >= a && v < b } else { v <= a && v > b };
            if !in_range {
                return fail(format!("value {v} (index {i}) outside range"));
            }
        }
        Ok(())
    });
}

/// Binary serialization round-trips arbitrary nested values.
#[test]
fn prop_ser_roundtrip() {
    check("ser-roundtrip", |g| {
        let v: Vec<(String, Vec<i64>)> = g.vec_of(|g| {
            let key = g.word(12);
            let vals = g.vec_of(|g| g.i64_in(i64::MIN / 2, i64::MAX / 2));
            (key, vals)
        });
        let bytes = v.to_bytes();
        match Vec::<(String, Vec<i64>)>::from_bytes(&bytes) {
            Ok(back) if back == v => Ok(()),
            Ok(_) => fail("roundtrip changed value"),
            Err(e) => fail(format!("decode error: {e}")),
        }
    });
}

/// The LZ4-style block codec round-trips arbitrary payloads byte-exactly:
/// empty, incompressible (pseudo-random bytes), and highly repetitive
/// ones alike.
#[test]
fn prop_compress_roundtrip() {
    use blaze::storage::compress::{compress, decompress};

    check("compress-roundtrip", |g| {
        let kind = g.usize_in(0, 2);
        let len = g.usize_in(0, 4096);
        let src: Vec<u8> = match kind {
            0 => Vec::new(),
            1 => (0..len).map(|_| g.below(256) as u8).collect(),
            _ => {
                // Repetitive: a single short word tiled out, the shape
                // that must compress (and stress overlapping copies).
                let word = g.word(6);
                let mut s = Vec::new();
                while s.len() < len {
                    s.extend_from_slice(word.as_bytes());
                    s.push(b' ');
                }
                s.truncate(len);
                s
            }
        };
        let mut packed = Vec::new();
        let n = compress(&src, &mut packed);
        if n != packed.len() {
            return fail(format!("compress reported {n} but wrote {}", packed.len()));
        }
        match decompress(&packed, src.len()) {
            Ok(back) if back == src => Ok(()),
            Ok(_) => fail(format!("roundtrip changed bytes (kind {kind}, len {len})")),
            Err(e) => fail(format!("decode error on kind-{kind} len-{len} input: {e}")),
        }
    });
}

/// The dictionary pair codec round-trips random keyed streams with the
/// dictionary on or off; with it on, every key is either a first sight
/// or a back-ref and the encoded key bytes never exceed the plain form.
#[test]
fn prop_dict_codec_roundtrip() {
    use blaze::util::ser::{decode_pairs, encode_pairs};

    check("dict-codec-roundtrip", |g| {
        let distinct = g.usize_in(1, 20);
        let pairs: Vec<(String, u64)> = (0..g.usize_in(0, 300))
            .map(|_| (format!("key{}", g.usize_in(0, distinct - 1)), g.below(1 << 20)))
            .collect();
        for dict in [false, true] {
            let (bytes, stats) = encode_pairs(&pairs, dict);
            let back: Vec<(String, u64)> = match decode_pairs(&bytes) {
                Ok(back) => back,
                Err(e) => return fail(format!("decode error (dict={dict}): {e}")),
            };
            if back != pairs {
                return fail(format!("roundtrip changed pairs (dict={dict})"));
            }
            if dict {
                if stats.unique as usize > distinct {
                    return fail(format!("{} unique ids for <= {distinct} keys", stats.unique));
                }
                if stats.unique + stats.refs != pairs.len() as u64 {
                    return fail("every key must be a first sight or a back-ref");
                }
                if stats.key_enc_bytes > stats.key_raw_bytes {
                    return fail("dictionary expanded the key bytes");
                }
            } else if stats.refs != 0 || stats.unique != pairs.len() as u64 {
                return fail(format!("disabled dict still deduplicated: {stats:?}"));
            }
        }
        Ok(())
    });
}

/// Random little corpora: every engine ≡ serial reference.
#[test]
fn prop_random_corpora_all_engines() {
    check_with(Config { cases: 12, size: 64, ..Default::default() }, "random-corpora", |g| {
        let nlines = g.usize_in(0, 80);
        let text: String = (0..nlines)
            .map(|_| g.line(12))
            .collect::<Vec<_>>()
            .join("\n");
        let corpus = Corpus::from_text(&text);
        let expect = serial_reference(&corpus, blaze::corpus::Tokenizer::Spaces);
        for engine in [EngineChoice::BlazeTcm, EngineChoice::Spark] {
            let r = WordCountJob::new(engine)
                .nodes(2)
                .threads_per_node(2)
                .net(NetModel::ideal())
                .run(&corpus)
                .map_err(|e| format!("{e}"))?;
            if r.counts != expect {
                return fail(format!("{} diverged on corpus {text:?}", engine.label()));
            }
        }
        Ok(())
    });
}

/// Reducers used through the whole stack are associative+commutative on
/// random streams (fold order must not matter).
#[test]
fn prop_reducer_order_independent() {
    check("reducer-order-independence", |g| {
        let mut values: Vec<u64> = g.vec_of(|g| g.below(1 << 30));
        let mut acc1 = 0u64;
        for &v in &values {
            reducer::sum(&mut acc1, v);
        }
        // Shuffle and refold.
        let seed = g.u64();
        let mut rng = blaze::util::rng::Xoshiro256::new(seed);
        rng.shuffle(&mut values);
        let mut acc2 = 0u64;
        for &v in &values {
            reducer::sum(&mut acc2, v);
        }
        if acc1 != acc2 {
            return fail("sum depends on order");
        }
        Ok(())
    });
}

/// Tokenizers: token count equals iteration count; no empties; spaces
/// tokenizer concatenation round-trips.
#[test]
fn prop_tokenizer_consistency() {
    check("tokenizer-consistency", |g| {
        let line = {
            // Random line with multi-space runs.
            let mut s = String::new();
            for _ in 0..g.usize_in(0, 20) {
                for _ in 0..g.usize_in(1, 3) {
                    s.push(' ');
                }
                s.push_str(&g.word(8));
            }
            s
        };
        let toks: Vec<&str> = blaze::corpus::split_spaces(&line).collect();
        if toks.iter().any(|t| t.is_empty()) {
            return fail("empty token");
        }
        if toks.len() != blaze::corpus::Tokenizer::Spaces.count_words(&line) {
            return fail("count mismatch");
        }
        let rejoined = toks.join(" ");
        let canonical: Vec<&str> = blaze::corpus::split_spaces(&rejoined).collect();
        if canonical != toks {
            return fail("rejoin changed tokens");
        }
        Ok(())
    });
}

/// Join parity when one relation is empty: the inner join must be empty on
/// every engine, matching the serial oracle — regardless of which side is
/// empty, cluster shape, or the non-empty side's content.
#[test]
fn prop_join_parity_with_one_empty_relation() {
    use blaze::mapreduce::{run_serial_inputs, JobInputs, JobSpec};
    use blaze::workloads::Join;
    use std::sync::Arc;

    check_with(Config { cases: 12, ..Default::default() }, "join-empty-relation", |g| {
        let lines: Vec<String> = g.vec_of(|g| g.line(6));
        let full = Corpus::from_text(&lines.join("\n"));
        let empty = Corpus::from_text("");
        let (left, right) =
            if g.bool() { (&full, &empty) } else { (&empty, &full) };
        let inputs = JobInputs::new().relation("left", left).relation("right", right);
        let w = Arc::new(Join::new());
        let expect = run_serial_inputs(w.as_ref(), &inputs);
        if !expect.is_empty() {
            return fail(format!("serial inner join against empty side: {expect:?}"));
        }
        let nnodes = g.usize_in(1, 3);
        for engine in [EngineChoice::Blaze, EngineChoice::BlazeTcm, EngineChoice::Spark] {
            let r = JobSpec::new(engine)
                .nodes(nnodes)
                .threads_per_node(g.usize_in(1, 2))
                .net(NetModel::ideal())
                .run_inputs(&w, &inputs)
                .map_err(|e| e.to_string())?;
            if r.output != expect {
                return fail(format!("{} diverged: {:?}", engine.label(), r.output));
            }
        }
        Ok(())
    });
}

/// Every registered workload through the compiled-plan path must match
/// its serial oracle under a random single failure injected at a random
/// stage boundary (map side or shuffle/reduce side), on a random engine
/// and cluster shape — including the multi-input join, the two-stage
/// chained pipeline, and an iterative min-label run whose injection can
/// land in any round.
#[test]
fn prop_run_plan_parity_under_random_failures() {
    use blaze::cluster::FailurePlan;
    use blaze::engines::Engine;
    use blaze::mapreduce::{
        run_chained, run_chained_serial, run_iterative, run_iterative_serial, run_serial,
        run_serial_inputs, IterativeSpec, JobInputs, JobSpec,
    };
    use blaze::workloads::{
        Components, DistinctCount, Grep, InvertedIndex, Join, LengthHistogram, Sessionize,
        TopKWords, WordCount,
    };
    use std::sync::Arc;

    check_with(Config { cases: 6, size: 48, ..Default::default() }, "run-plan-parity", |g| {
        let text: String =
            (0..g.usize_in(1, 40)).map(|_| g.line(8)).collect::<Vec<_>>().join("\n");
        let corpus = Corpus::from_text(&text);
        let engine = *g.choose(&[Engine::Blaze, Engine::BlazeTcm, Engine::Spark]);
        let nnodes = g.usize_in(1, 3);
        // One failure at a random stage boundary: phase 0 = map side,
        // phase 1 = the shuffle/reduce side of the boundary. Plans are
        // one-shot (consumed by the first run they hit), so build a fresh
        // one per job.
        let fail_phase = g.usize_in(0, 1);
        let fail_idx = g.usize_in(0, nnodes - 1);
        // Real work-stealing pool width — steal order must never leak
        // into output, so any width has to match the serial oracle.
        let threads = g.usize_in(1, 8);
        // The eviction policy is a pure performance knob: output parity
        // must hold under every one of them.
        let policy = *g.choose(&PolicySpec::all());
        let failures = || match engine {
            Engine::Blaze | Engine::BlazeTcm => {
                FailurePlan::none().fail_node(fail_idx, fail_phase)
            }
            Engine::Spark | Engine::SparkStripped => {
                FailurePlan::none().fail_task(fail_phase, fail_idx)
            }
        };
        let spec = || {
            JobSpec::new(engine)
                .nodes(nnodes)
                .threads_per_node(2)
                .threads(threads)
                .net(NetModel::ideal())
                .failures(failures())
                .eviction_policy(policy)
        };
        let tok = blaze::corpus::Tokenizer::Spaces;
        let ctx = format!(
            "{} (nnodes={nnodes}, threads={threads}, fail {fail_idx}@{fail_phase}, {policy})",
            engine.label()
        );
        fn parity<T: PartialEq>(label: &str, ctx: &str, got: &T, want: &T) -> Result<(), String> {
            if got == want {
                Ok(())
            } else {
                fail(format!("{label} diverged on {ctx}"))
            }
        }

        let wc = Arc::new(WordCount::new(tok));
        let r = spec().run_str(&wc, &corpus).map_err(|e| e.to_string())?;
        parity("wordcount", &ctx, &r.output, &run_serial(wc.as_ref(), &corpus))?;

        let idx = Arc::new(InvertedIndex::new(tok));
        let r = spec().run_str(&idx, &corpus).map_err(|e| e.to_string())?;
        parity("index", &ctx, &r.output, &run_serial(idx.as_ref(), &corpus))?;

        let topk = Arc::new(TopKWords::new(tok, 5));
        let r = spec().run_str(&topk, &corpus).map_err(|e| e.to_string())?;
        parity("top-k", &ctx, &r.output, &run_serial(topk.as_ref(), &corpus))?;

        let hist = Arc::new(LengthHistogram::new(tok));
        let r = spec().run(&hist, &corpus).map_err(|e| e.to_string())?;
        parity("length-hist", &ctx, &r.output, &run_serial(hist.as_ref(), &corpus))?;

        let distinct = Arc::new(DistinctCount::new(tok));
        let r = spec().run(&distinct, &corpus).map_err(|e| e.to_string())?;
        parity("distinct", &ctx, &r.output, &run_serial(distinct.as_ref(), &corpus))?;

        let grep = Arc::new(Grep::new("a"));
        let r = spec().run(&grep, &corpus).map_err(|e| e.to_string())?;
        parity("grep", &ctx, &r.output, &run_serial(grep.as_ref(), &corpus))?;

        let right_text: String =
            (0..g.usize_in(0, 30)).map(|_| g.line(6)).collect::<Vec<_>>().join("\n");
        let join_inputs = JobInputs::new()
            .relation("left", &corpus)
            .relation("right", &Corpus::from_text(&right_text));
        let join = Arc::new(Join::new());
        let r = spec().run_inputs(&join, &join_inputs).map_err(|e| e.to_string())?;
        parity("join", &ctx, &r.output, &run_serial_inputs(join.as_ref(), &join_inputs))?;

        // Chained: two shuffle boundaries, so the injection can land on
        // either stage.
        let logs: Vec<String> = (0..g.usize_in(0, 60))
            .map(|_| format!("u{} {}", g.usize_in(0, 4), g.below(400)))
            .collect();
        let log_inputs = JobInputs::new().relation_lines("logs", Arc::new(logs));
        let sz = Sessionize::new(40);
        let want = run_chained_serial(&sz, &log_inputs);
        let r = run_chained(&spec(), &sz, &log_inputs).map_err(|e| e.to_string())?;
        parity("sessionize", &ctx, &r.lines, &want)?;

        // Iterative: the injection lands in whichever round first runs
        // the failing task/node. A KB-scale (or zero) cache budget keeps
        // the policy busy evicting and rejecting mid-run.
        let cc = Components::new();
        let edge_inputs = JobInputs::new().relation("edges", &corpus);
        let budget =
            *g.choose(&[CacheBudget::Unbounded, CacheBudget::Bytes(0), CacheBudget::Bytes(2048)]);
        let it = IterativeSpec::new(3).tolerance(0.0).cache_budget(budget);
        let want = run_iterative_serial(&it, &cc, &edge_inputs);
        let r = run_iterative(&spec(), &it, &cc, &edge_inputs).map_err(|e| e.to_string())?;
        parity("components", &ctx, &r.state, &want.state)?;

        Ok(())
    });
}

/// Deterministic "computation" for a cache key — what a parse of the
/// underlying split would produce.
fn cache_value_of(k: &blaze::cache::CacheKey) -> Vec<u64> {
    vec![k.namespace, k.generation, k.partition, k.namespace ^ (k.partition << 8)]
}

/// The partition cache under random put/get streams matches a reference
/// LRU model exactly: resident set (eviction order), byte accounting, the
/// never-exceeds-budget invariant, and every hit returns precisely the
/// deterministic value of its key.
#[test]
fn prop_partition_cache_matches_lru_model() {
    use blaze::cache::{CacheBudget, CacheKey, PartitionCache};
    use std::sync::Arc;

    check_with(Config { cases: 48, ..Default::default() }, "cache-lru-model", |g| {
        let budget = g.below(500);
        let cache = PartitionCache::new(CacheBudget::Bytes(budget));
        // Reference model: (key, bytes) in recency order, front = LRU.
        let mut model: Vec<(CacheKey, u64)> = Vec::new();
        for _step in 0..g.usize_in(1, 120) {
            let key = CacheKey {
                namespace: g.below(2),
                generation: g.below(2),
                partition: g.below(6),
                splits: 1,
            };
            if g.chance(0.5) {
                let bytes = g.below(300);
                let admitted = cache.put(key, Arc::new(cache_value_of(&key)), bytes);
                if budget == 0 || bytes > budget {
                    if admitted {
                        return fail("entry larger than the whole budget was admitted");
                    }
                } else {
                    if !admitted {
                        return fail("fitting entry was rejected");
                    }
                    model.retain(|(k, _)| *k != key);
                    let mut total: u64 = model.iter().map(|(_, b)| *b).sum();
                    while total + bytes > budget {
                        let (_lru, b) = model.remove(0);
                        total -= b;
                    }
                    model.push((key, bytes));
                }
            } else {
                let hit = cache.get_typed::<Vec<u64>>(&key);
                let in_model = model.iter().position(|(k, _)| *k == key);
                match (hit, in_model) {
                    (Some(v), Some(pos)) => {
                        if *v != cache_value_of(&key) {
                            return fail("hit returned a value for the wrong key");
                        }
                        let e = model.remove(pos);
                        model.push(e); // becomes MRU
                    }
                    (None, None) => {}
                    (Some(_), None) => return fail("cache hit a key the LRU model evicted"),
                    (None, Some(_)) => return fail("cache missed a key the LRU model kept"),
                }
            }
            // Invariants hold after every single operation.
            let cached = cache.bytes_cached();
            if cached > budget {
                return fail(format!("budget exceeded: {cached} > {budget}"));
            }
            let model_bytes: u64 = model.iter().map(|(_, b)| *b).sum();
            if cached != model_bytes {
                return fail(format!("byte accounting diverged: {cached} != {model_bytes}"));
            }
            if cache.len() != model.len() {
                return fail(format!(
                    "resident count diverged: {} != {}",
                    cache.len(),
                    model.len()
                ));
            }
        }
        for (k, _) in &model {
            if !cache.contains(k) {
                return fail(format!("model key {k:?} not resident (LRU order diverged)"));
            }
        }
        Ok(())
    });
}

/// Eviction is invisible to a caller with a deterministic compute
/// function: a get-after-evict misses, recomputes, and lands on a value
/// identical to what was originally cached — under arbitrary interleaved
/// access patterns and tight budgets.
#[test]
fn prop_cache_get_after_evict_recomputes_identical_value() {
    use blaze::cache::{CacheBudget, CacheKey, PartitionCache};
    use std::sync::Arc;

    check("cache-evict-recompute", |g| {
        // Budget fits only a handful of entries: evictions are constant.
        let cache = PartitionCache::new(CacheBudget::Bytes(g.below(200) + 50));
        for _ in 0..g.usize_in(10, 150) {
            let key = CacheKey {
                namespace: 0,
                generation: g.below(3),
                partition: g.below(8),
                splits: 1,
            };
            let value = match cache.get_typed::<Vec<u64>>(&key) {
                Some(hit) => hit,
                None => {
                    let v = Arc::new(cache_value_of(&key));
                    cache.put(key, Arc::clone(&v), 40);
                    v
                }
            };
            if *value != cache_value_of(&key) {
                return fail(format!("key {key:?} resolved to a different value"));
            }
        }
        let s = cache.stats();
        if s.hits + s.misses == 0 {
            return fail("no lookups recorded");
        }
        Ok(())
    });
}

/// The bounded-memory external merger ≡ an in-memory hash fold, for
/// random key/value streams, random budgets (including 0 and effectively
/// unbounded), and randomly injected mid-spill write failures. Failed
/// spills must never lose records.
#[test]
fn prop_external_merger_matches_in_memory_fold() {
    use blaze::cache::CacheKey;
    use blaze::storage::{
        fresh_spill_namespace, BlockMeta, BlockStore, DiskTier, ExternalMerger,
    };
    use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
    use std::sync::Arc;

    /// Injects write failures on a deterministic schedule; delegates
    /// everything else to the real disk tier.
    struct Flaky {
        inner: Arc<DiskTier>,
        writes: AtomicU64,
        /// Fail every `period`-th write (0 = never fail).
        period: u64,
    }
    impl BlockStore for Flaky {
        fn write(&self, key: CacheKey, payload: &[u8]) -> std::io::Result<u64> {
            let n = self.writes.fetch_add(1, Relaxed);
            if self.period > 0 && n % self.period == self.period - 1 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::Other,
                    "injected mid-spill failure",
                ));
            }
            self.inner.write(key, payload)
        }
        fn read(&self, key: &CacheKey) -> std::io::Result<Option<Vec<u8>>> {
            self.inner.read(key)
        }
        fn read_range(
            &self,
            key: &CacheKey,
            offset: u64,
            max_len: usize,
        ) -> std::io::Result<Option<Vec<u8>>> {
            self.inner.read_range(key, offset, max_len)
        }
        fn meta(&self, key: &CacheKey) -> Option<BlockMeta> {
            self.inner.meta(key)
        }
        fn delete(&self, key: &CacheKey) -> bool {
            self.inner.delete(key)
        }
        fn delete_generations_below(&self, namespace: u64, keep: u64) -> usize {
            self.inner.delete_generations_below(namespace, keep)
        }
        fn len(&self) -> usize {
            self.inner.len()
        }
        fn bytes_stored(&self) -> u64 {
            self.inner.bytes_stored()
        }
    }

    check_with(Config { cases: 48, ..Default::default() }, "external-merger-fold", |g| {
        let threshold = *g.choose(&[0u64, 1, 32, 256, 4096, u64::MAX]);
        let period = *g.choose(&[0u64, 1, 2, 5]);
        let distinct = g.usize_in(1, 30);
        let pairs: Vec<(String, u64)> = (0..g.usize_in(0, 400))
            .map(|_| (format!("k{}", g.usize_in(0, distinct - 1)), g.below(1000)))
            .collect();

        let disk = Arc::new(DiskTier::new(None));
        let counters = Arc::clone(disk.counters());
        let flaky =
            Arc::new(Flaky { inner: disk, writes: AtomicU64::new(0), period });
        let mut merger: ExternalMerger<String, u64> = ExternalMerger::new(
            threshold,
            flaky as Arc<dyn BlockStore>,
            Arc::clone(&counters),
            fresh_spill_namespace(),
        );
        let mut expect: BTreeMap<String, u64> = BTreeMap::new();
        for (k, v) in &pairs {
            *expect.entry(k.clone()).or_insert(0) += v;
            merger.insert(k.clone(), *v, |a, b| *a += b);
        }
        let got: BTreeMap<String, u64> = merger.finish(|a, b| *a += b).into_iter().collect();
        if got != expect {
            return fail(format!(
                "merge diverged (threshold={threshold}, fail period={period}): \
                 {} vs {} keys",
                got.len(),
                expect.len()
            ));
        }
        let stats = counters.snapshot();
        if period == 1 && stats.spilled_bytes > 0 {
            return fail("every write fails, so nothing can have spilled");
        }
        if threshold == u64::MAX && stats.spilled_bytes > 0 {
            return fail("unbounded budget must never spill");
        }
        Ok(())
    });
}

/// Spilled execution ≡ serial oracle on real engines: a random corpus, a
/// random engine, and a random spill threshold (down to 0) must leave
/// workload output bit-identical — spilling may only change speed.
#[test]
fn prop_spill_run_parity() {
    use blaze::engines::Engine;
    use blaze::mapreduce::{run_serial, run_serial_inputs, JobInputs, JobSpec};
    use blaze::workloads::{InvertedIndex, Join, WordCount};
    use std::sync::Arc;

    check_with(Config { cases: 8, size: 32, ..Default::default() }, "spill-parity", |g| {
        let text: String =
            (0..g.usize_in(1, 30)).map(|_| g.line(8)).collect::<Vec<_>>().join("\n");
        let corpus = Corpus::from_text(&text);
        let engine = *g.choose(&[Engine::Blaze, Engine::BlazeTcm, Engine::Spark]);
        let threshold = *g.choose(&[0u64, 64, 1024, 64 << 10]);
        let threads = g.usize_in(1, 8);
        let policy = *g.choose(&PolicySpec::all());
        // The data-path knobs are pure representation choices: parity
        // must hold for every combination of compression and key
        // dictionaries against the same serial oracle.
        let compress = g.bool();
        let dict_keys = g.bool();
        let spec = || {
            JobSpec::new(engine)
                .nodes(2)
                .threads_per_node(2)
                .threads(threads)
                .net(NetModel::ideal())
                .spill_threshold(threshold)
                .eviction_policy(policy)
                .compress(compress)
                .dict_keys(dict_keys)
        };
        let ctx = format!(
            "{} threshold={threshold} threads={threads} {policy} \
             compress={compress} dict={dict_keys}",
            engine.label()
        );

        let tok = blaze::corpus::Tokenizer::Spaces;
        let wc = Arc::new(WordCount::new(tok));
        let r = spec().run_str(&wc, &corpus).map_err(|e| e.to_string())?;
        if r.output != run_serial(wc.as_ref(), &corpus) {
            return fail(format!("wordcount diverged on {ctx}"));
        }

        let idx = Arc::new(InvertedIndex::new(tok));
        let r = spec().run_str(&idx, &corpus).map_err(|e| e.to_string())?;
        if r.output != run_serial(idx.as_ref(), &corpus) {
            return fail(format!("index diverged on {ctx}"));
        }

        let right: String =
            (0..g.usize_in(0, 20)).map(|_| g.line(6)).collect::<Vec<_>>().join("\n");
        let join_inputs = JobInputs::new()
            .relation("left", &corpus)
            .relation("right", &Corpus::from_text(&right));
        let join = Arc::new(Join::new());
        let r = spec().run_inputs(&join, &join_inputs).map_err(|e| e.to_string())?;
        if r.output != run_serial_inputs(join.as_ref(), &join_inputs) {
            return fail(format!("join diverged on {ctx}"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Eviction-policy reference models (PR 7)
//
// Each production policy is re-implemented here as a deliberately naive
// O(n) model (plain `Vec` scans instead of tick-keyed `BTreeMap`s), and
// `RefTier` mirrors `MemoryTier::put`'s exact protocol: pre-reject,
// overwrite-forget, victim selection, the admission filter (skipped for
// overwrites), eviction, insert. Driving both through identical random
// op streams and comparing every decision catches any divergence between
// the documented policy semantics and the optimized implementations.

use blaze::cache::{CacheBudget, CacheKey, PolicySpec};
use blaze::storage::policy::{BasePolicy, FrequencySketch, TinyLfuPolicy, GDSF_SCALE};

/// The model-side mirror of [`blaze::storage::EvictionPolicy`].
trait RefPolicy {
    fn on_hit(&mut self, key: &CacheKey);
    fn on_miss(&mut self, _key: &CacheKey) {}
    fn victims(&self, need: u64) -> Vec<CacheKey>;
    fn admits(&mut self, _key: &CacheKey, _bytes: u64, _victims: &[CacheKey]) -> bool {
        true
    }
    fn insert(&mut self, key: CacheKey, bytes: u64);
    fn evict(&mut self, key: &CacheKey) {
        self.forget(key);
    }
    fn forget(&mut self, key: &CacheKey);
}

/// LRU as a recency list: front = least recently used.
#[derive(Default)]
struct RefLru {
    entries: Vec<(CacheKey, u64)>,
}

impl RefPolicy for RefLru {
    fn on_hit(&mut self, key: &CacheKey) {
        if let Some(pos) = self.entries.iter().position(|(k, _)| k == key) {
            let e = self.entries.remove(pos);
            self.entries.push(e);
        }
    }
    fn victims(&self, need: u64) -> Vec<CacheKey> {
        let mut freed = 0;
        let mut out = Vec::new();
        for (k, b) in &self.entries {
            if freed >= need {
                break;
            }
            freed += b;
            out.push(*k);
        }
        out
    }
    fn insert(&mut self, key: CacheKey, bytes: u64) {
        self.entries.push((key, bytes));
    }
    fn forget(&mut self, key: &CacheKey) {
        self.entries.retain(|(k, _)| k != key);
    }
}

/// SLRU as two recency lists; protected cap = 4/5 of the budget, exactly
/// like the production policy.
struct RefSlru {
    cap: u64,
    probation: Vec<(CacheKey, u64)>,
    protected: Vec<(CacheKey, u64)>,
}

impl RefSlru {
    fn new(limit: u64) -> Self {
        Self { cap: (limit / 5).saturating_mul(4), probation: Vec::new(), protected: Vec::new() }
    }
    fn protected_bytes(&self) -> u64 {
        self.protected.iter().map(|(_, b)| *b).sum()
    }
    fn shrink(&mut self) {
        while self.protected_bytes() > self.cap {
            let e = self.protected.remove(0);
            self.probation.push(e); // demoted as probation-MRU
        }
    }
}

impl RefPolicy for RefSlru {
    fn on_hit(&mut self, key: &CacheKey) {
        if let Some(pos) = self.protected.iter().position(|(k, _)| k == key) {
            let e = self.protected.remove(pos);
            self.protected.push(e);
        } else if let Some(pos) = self.probation.iter().position(|(k, _)| k == key) {
            let e = self.probation.remove(pos);
            self.protected.push(e);
            self.shrink();
        }
    }
    fn victims(&self, need: u64) -> Vec<CacheKey> {
        let mut freed = 0;
        let mut out = Vec::new();
        for (k, b) in self.probation.iter().chain(self.protected.iter()) {
            if freed >= need {
                break;
            }
            freed += b;
            out.push(*k);
        }
        out
    }
    fn insert(&mut self, key: CacheKey, bytes: u64) {
        self.probation.push((key, bytes));
    }
    fn forget(&mut self, key: &CacheKey) {
        self.probation.retain(|(k, _)| k != key);
        self.protected.retain(|(k, _)| k != key);
    }
}

/// GDSF as an unordered list re-sorted on every victim scan.
#[derive(Default)]
struct RefGdsf {
    clock: u64,
    entries: Vec<(CacheKey, u64, u64, u64)>, // (key, bytes, freq, priority)
}

impl RefPolicy for RefGdsf {
    fn on_hit(&mut self, key: &CacheKey) {
        let clock = self.clock;
        if let Some(e) = self.entries.iter_mut().find(|(k, ..)| k == key) {
            e.2 += 1;
            e.3 = clock.saturating_add(e.2.saturating_mul(GDSF_SCALE) / e.1.max(1));
        }
    }
    fn victims(&self, need: u64) -> Vec<CacheKey> {
        let mut order: Vec<(u64, CacheKey, u64)> =
            self.entries.iter().map(|(k, b, _, p)| (*p, *k, *b)).collect();
        order.sort(); // (priority, key): the production tie-break
        let mut freed = 0;
        let mut out = Vec::new();
        for (_, k, b) in &order {
            if freed >= need {
                break;
            }
            freed += b;
            out.push(*k);
        }
        out
    }
    fn insert(&mut self, key: CacheKey, bytes: u64) {
        let priority = self.clock.saturating_add(GDSF_SCALE / bytes.max(1));
        self.entries.push((key, bytes, 1, priority));
    }
    fn evict(&mut self, key: &CacheKey) {
        if let Some((.., p)) = self.entries.iter().find(|(k, ..)| k == key) {
            self.clock = self.clock.max(*p);
        }
        self.forget(key);
    }
    fn forget(&mut self, key: &CacheKey) {
        self.entries.retain(|(k, ..)| k != key);
    }
}

/// TinyLFU admission over any base model, sharing the production
/// [`FrequencySketch`] (seeded identically, fed the identical access
/// sequence — so both sketches stay bit-for-bit in sync).
struct RefTinyLfu {
    base: Box<dyn RefPolicy>,
    sketch: FrequencySketch,
}

impl RefPolicy for RefTinyLfu {
    fn on_hit(&mut self, key: &CacheKey) {
        self.sketch.increment(key);
        self.base.on_hit(key);
    }
    fn on_miss(&mut self, key: &CacheKey) {
        self.sketch.increment(key);
        self.base.on_miss(key);
    }
    fn victims(&self, need: u64) -> Vec<CacheKey> {
        self.base.victims(need)
    }
    fn admits(&mut self, key: &CacheKey, bytes: u64, victims: &[CacheKey]) -> bool {
        self.sketch.increment(key);
        if victims.is_empty() {
            return self.base.admits(key, bytes, victims);
        }
        let candidate = self.sketch.estimate(key);
        let strongest = victims.iter().map(|v| self.sketch.estimate(v)).max().unwrap_or(0);
        candidate > strongest && self.base.admits(key, bytes, victims)
    }
    fn insert(&mut self, key: CacheKey, bytes: u64) {
        self.base.insert(key, bytes);
    }
    fn evict(&mut self, key: &CacheKey) {
        self.base.evict(key);
    }
    fn forget(&mut self, key: &CacheKey) {
        self.base.forget(key);
    }
}

fn build_ref(spec: PolicySpec, limit: u64) -> Box<dyn RefPolicy> {
    let base: Box<dyn RefPolicy> = match spec.base {
        BasePolicy::Lru => Box::new(RefLru::default()),
        BasePolicy::Slru => Box::new(RefSlru::new(limit)),
        BasePolicy::Gdsf => Box::new(RefGdsf::default()),
    };
    if spec.tinylfu {
        Box::new(RefTinyLfu { base, sketch: FrequencySketch::new(TinyLfuPolicy::SKETCH_WIDTH) })
    } else {
        base
    }
}

/// Pure mirror of `MemoryTier` for a `Bytes(limit)` budget: same put
/// protocol, same counters.
struct RefTier {
    limit: u64,
    slots: Vec<(CacheKey, u64)>,
    policy: Box<dyn RefPolicy>,
    hits: u64,
    misses: u64,
    insertions: u64,
    evictions: u64,
    rejected: u64,
}

impl RefTier {
    fn new(spec: PolicySpec, limit: u64) -> Self {
        Self {
            limit,
            slots: Vec::new(),
            policy: build_ref(spec, limit),
            hits: 0,
            misses: 0,
            insertions: 0,
            evictions: 0,
            rejected: 0,
        }
    }
    fn bytes(&self) -> u64 {
        self.slots.iter().map(|(_, b)| *b).sum()
    }
    fn contains(&self, key: &CacheKey) -> bool {
        self.slots.iter().any(|(k, _)| k == key)
    }
    fn get(&mut self, key: &CacheKey) -> bool {
        if self.contains(key) {
            self.hits += 1;
            self.policy.on_hit(key);
            true
        } else {
            self.misses += 1;
            self.policy.on_miss(key);
            false
        }
    }
    fn put(&mut self, key: CacheKey, bytes: u64) -> bool {
        if self.limit == 0 || bytes > self.limit {
            self.rejected += 1;
            return false;
        }
        let overwrite = self.contains(&key);
        if overwrite {
            self.slots.retain(|(k, _)| *k != key);
            self.policy.forget(&key);
        }
        let need = (self.bytes() + bytes).saturating_sub(self.limit);
        let victims = self.policy.victims(need);
        if !overwrite && !self.policy.admits(&key, bytes, &victims) {
            self.rejected += 1;
            return false;
        }
        for v in &victims {
            self.slots.retain(|(k, _)| k != v);
            self.policy.evict(v);
            self.evictions += 1;
        }
        self.policy.insert(key, bytes);
        self.slots.push((key, bytes));
        self.insertions += 1;
        true
    }
}

/// Every eviction policy ≡ its pure reference model on random op streams:
/// identical admit/reject decisions, identical hit/miss outcomes,
/// identical eviction counts, byte accounting, and final resident set.
#[test]
fn prop_policy_matches_reference_model() {
    use blaze::storage::MemoryTier;
    use std::sync::Arc;

    check_with(Config { cases: 32, ..Default::default() }, "policy-vs-reference", |g| {
        let limit = g.below(400);
        for spec in PolicySpec::all() {
            let tier = MemoryTier::with_policy(CacheBudget::Bytes(limit), spec);
            let mut model = RefTier::new(spec, limit);
            for step in 0..g.usize_in(1, 150) {
                let key = CacheKey {
                    namespace: g.below(2),
                    generation: g.below(2),
                    partition: g.below(8),
                    splits: 1,
                };
                let ctx = format!("{spec} (limit {limit}, step {step}, key {key:?})");
                if g.chance(0.5) {
                    let bytes = g.below(200);
                    let (admitted, _) = tier.put(key, Arc::new(()), bytes, None);
                    if admitted != model.put(key, bytes) {
                        return fail(format!("admit decision diverged on {ctx}"));
                    }
                } else if tier.get(&key).is_some() != model.get(&key) {
                    return fail(format!("hit/miss diverged on {ctx}"));
                }
                let s = tier.stats();
                let counters = (s.hits, s.misses, s.insertions, s.evictions, s.rejected);
                let want =
                    (model.hits, model.misses, model.insertions, model.evictions, model.rejected);
                if counters != want {
                    return fail(format!("counters {counters:?} != {want:?} on {ctx}"));
                }
                if s.bytes_cached != model.bytes() || s.entries as usize != model.slots.len() {
                    return fail(format!("residency accounting diverged on {ctx}"));
                }
            }
            for (k, _) in &model.slots {
                if !tier.contains(k) {
                    return fail(format!("{spec}: model key {k:?} not resident in the tier"));
                }
            }
        }
        Ok(())
    });
}

/// Cross-policy invariants no policy may break, under a richer op mix
/// (put/get/remove/invalidate): cached bytes never exceed the budget, the
/// counters add up exactly (`hits + misses = gets`,
/// `insertions + rejected = puts`, and the resident count is the exact
/// balance of insertions minus every way an entry can leave), and no
/// phantom keys — `contains` only ever answers `true` for keys that some
/// put actually admitted.
#[test]
fn prop_policy_cross_invariants() {
    use blaze::storage::MemoryTier;
    use std::collections::BTreeSet;
    use std::sync::Arc;

    check_with(Config { cases: 24, ..Default::default() }, "policy-invariants", |g| {
        let limit = g.below(300);
        for spec in PolicySpec::all() {
            let tier = MemoryTier::with_policy(CacheBudget::Bytes(limit), spec);
            let mut ever_admitted: BTreeSet<CacheKey> = BTreeSet::new();
            let (mut puts, mut gets) = (0u64, 0u64);
            let (mut overwrites, mut removed, mut invalidated) = (0u64, 0u64, 0u64);
            for _ in 0..g.usize_in(1, 200) {
                let key = CacheKey {
                    namespace: g.below(3),
                    generation: g.below(3),
                    partition: g.below(6),
                    splits: 1,
                };
                match g.usize_in(0, 9) {
                    0..=3 => {
                        let resident = tier.contains(&key);
                        let (admitted, _) = tier.put(key, Arc::new(()), g.below(200), None);
                        puts += 1;
                        if admitted {
                            ever_admitted.insert(key);
                            if resident {
                                overwrites += 1;
                            }
                        }
                    }
                    4..=7 => {
                        tier.get(&key);
                        gets += 1;
                    }
                    8 => {
                        if tier.remove(&key) {
                            removed += 1;
                        }
                    }
                    _ => {
                        invalidated +=
                            tier.invalidate_generations_below(g.below(3), g.below(3)) as u64;
                    }
                }
                let s = tier.stats();
                let ctx = format!("{spec} (limit {limit})");
                if s.bytes_cached > limit {
                    return fail(format!("budget exceeded on {ctx}: {}", s.bytes_cached));
                }
                if s.hits + s.misses != gets {
                    return fail(format!("lookup counters leak on {ctx}"));
                }
                if s.insertions + s.rejected != puts {
                    return fail(format!("insert counters leak on {ctx}"));
                }
                let gone = overwrites + s.evictions + removed + invalidated;
                if s.entries != s.insertions - gone {
                    return fail(format!(
                        "resident balance broken on {ctx}: {} entries, {} inserted, {gone} gone",
                        s.entries, s.insertions
                    ));
                }
            }
            // No phantom keys anywhere in the op stream's key domain.
            for namespace in 0..3 {
                for generation in 0..3 {
                    for partition in 0..6 {
                        let key = CacheKey { namespace, generation, partition, splits: 1 };
                        if tier.contains(&key) && !ever_admitted.contains(&key) {
                            return fail(format!("{spec}: phantom key {key:?}"));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

/// TieredStore demote/promote under every policy vs a memory+disk
/// reference model: with a disk tier attached, an encoded block — once
/// admitted — must never be lost (any reachable via `contains`, and
/// `get_encoded` returns exactly the last value put) until invalidation
/// drops it, while cached bytes stay within the KB-scale memory budget
/// through arbitrary demotions, promotions, and admission rejections.
#[test]
fn prop_policy_tiered_store_never_loses_blocks() {
    use blaze::cache::PartitionCache;
    use blaze::storage::DiskTier;
    use std::sync::Arc;

    check_with(Config { cases: 12, ..Default::default() }, "policy-tiered-model", |g| {
        let limit = g.below(400) + 1;
        for spec in PolicySpec::all() {
            let store = PartitionCache::with_spill_policy(
                CacheBudget::Bytes(limit),
                Arc::new(DiskTier::new(None)),
                spec,
            );
            // key -> last value successfully put (encoded namespaces only).
            let mut model: BTreeMap<CacheKey, Vec<u64>> = BTreeMap::new();
            let mut version = 0u64;
            for step in 0..g.usize_in(1, 120) {
                let key = CacheKey {
                    namespace: g.below(2),
                    generation: g.below(3),
                    partition: g.below(5),
                    splits: 1,
                };
                let ctx = format!("{spec} (limit {limit}, step {step}, key {key:?})");
                match g.usize_in(0, 9) {
                    0..=3 => {
                        // Oversized entries (bytes > limit) go straight to
                        // disk; the rest contend for memory.
                        version += 1;
                        let mut value = cache_value_of(&key);
                        value.push(version);
                        let bytes = g.below(limit * 2) + 1;
                        if !store.put_encoded(key, Arc::new(value.clone()), bytes) {
                            return fail(format!("encoded put refused on {ctx}"));
                        }
                        model.insert(key, value);
                    }
                    4..=6 => {
                        let hit = store.get_encoded::<Vec<u64>>(&key);
                        match (hit, model.get(&key)) {
                            (Some(got), Some(want)) if *got == *want => {}
                            (Some(_), Some(_)) => {
                                return fail(format!("stale value served on {ctx}"))
                            }
                            (Some(_), None) => {
                                return fail(format!("hit on an unput key on {ctx}"))
                            }
                            (None, Some(_)) => return fail(format!("block lost on {ctx}")),
                            (None, None) => {}
                        }
                    }
                    7..=8 => {
                        // Un-demotable entries in a disjoint namespace:
                        // eviction may drop them (not modeled), but they
                        // must never disturb the encoded blocks.
                        store.put(key_in_ns9(&key), Arc::new(()), g.below(limit) + 1);
                    }
                    _ => {
                        let (namespace, keep) = (g.below(2), g.below(3));
                        store.invalidate_generations_below(namespace, keep);
                        model.retain(|k, _| k.namespace != namespace || k.generation >= keep);
                    }
                }
                if store.bytes_cached() > limit {
                    return fail(format!("memory budget exceeded on {ctx}"));
                }
                for k in model.keys() {
                    if !store.contains(k) {
                        return fail(format!("block {k:?} vanished on {ctx}"));
                    }
                }
            }
            for (k, want) in &model {
                match store.get_encoded::<Vec<u64>>(k) {
                    Some(got) if *got == *want => {}
                    Some(_) => return fail(format!("{spec}: final value of {k:?} is stale")),
                    None => return fail(format!("{spec}: block {k:?} lost at the end")),
                }
            }
        }
        Ok(())
    });
}

/// Map an op-stream key into the plain-put namespace (disjoint from the
/// encoded namespaces so lossy plain evictions never alias a modeled
/// block).
fn key_in_ns9(key: &CacheKey) -> CacheKey {
    CacheKey { namespace: 9, ..*key }
}

/// Panic injection into the work-stealing executor: for random task-set
/// sizes, pool widths, and panic sites, `run_tasks` must run *every*
/// task to completion, report exactly `TaskSetError { panics, first_task }`,
/// and leave the pool fully usable for the next task set.
#[test]
fn prop_executor_panic_injection() {
    use blaze::runtime::{Executor, TaskSetError};
    use std::collections::BTreeSet;
    use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

    check_with(Config { cases: 16, ..Default::default() }, "executor-panic-injection", |g| {
        let width = g.usize_in(1, 8);
        let pool = Executor::new(width);
        for _round in 0..g.usize_in(1, 3) {
            let n = g.usize_in(1, 60);
            let panic_at: BTreeSet<usize> =
                (0..g.usize_in(0, 4)).map(|_| g.usize_in(0, n - 1)).collect();
            let ran = AtomicU64::new(0);
            let result = pool.run_tasks(n, |_ctx, i| {
                ran.fetch_add(1, Relaxed);
                if panic_at.contains(&i) {
                    panic!("injected panic in task {i}");
                }
            });
            let ctx = format!("width {width}, n {n}, panics at {panic_at:?}");
            match (result, panic_at.first()) {
                (Ok(()), None) => {}
                (Ok(()), Some(_)) => return fail(format!("panics swallowed ({ctx})")),
                (Err(_), None) => return fail(format!("error without a panic ({ctx})")),
                (Err(e), Some(&first)) => {
                    let want = TaskSetError { panics: panic_at.len(), first_task: first };
                    if e != want {
                        return fail(format!("got {e:?}, want {want:?} ({ctx})"));
                    }
                }
            }
            if ran.load(Relaxed) != n as u64 {
                return fail(format!(
                    "only {}/{n} tasks ran ({ctx})",
                    ran.load(Relaxed)
                ));
            }
            // The pool must survive the panics: a clean set still works.
            if pool.run_tasks(n, |_ctx, _i| {}).is_err() {
                return fail(format!("pool poisoned after panics ({ctx})"));
            }
        }
        Ok(())
    });
}

/// Random arrival schedules through the multi-tenant job service vs the
/// reference accounting model: no job is lost or duplicated (every
/// admitted handle reaches exactly one stable terminal state), the
/// admission ledger balances (`submitted == completed + failed +
/// cancelled + rejected`), and no tenant's resident bytes in the shared
/// store exceed its quota.
#[test]
fn prop_service_random_arrivals_balance() {
    use blaze::cache::CacheBudget;
    use blaze::service::{
        AdmissionError, JobRequest, JobService, JobStatus, SchedPolicy, ServiceConf,
        WorkloadKind, TENANT_NS_SPAN,
    };

    const QUOTA: u64 = 2 << 10;
    const KINDS: [WorkloadKind; 4] = [
        WorkloadKind::Grep,
        WorkloadKind::WordCount,
        WorkloadKind::Join,
        WorkloadKind::PageRank,
    ];

    check_with(Config { cases: 6, ..Default::default() }, "service-random-arrivals", |g| {
        let policy = if g.bool() { SchedPolicy::Fair } else { SchedPolicy::Fifo };
        let conf = ServiceConf::new()
            .threads(g.usize_in(1, 4))
            .slots(g.usize_in(1, 3))
            .queue_cap(g.usize_in(2, 6))
            .policy(policy)
            .store_budget(CacheBudget::Bytes(QUOTA))
            .spill_threshold(QUOTA)
            .tenant_quota(QUOTA);
        let svc = JobService::new(conf);
        let ntenants = g.usize_in(1, 3);
        let jobs = g.usize_in(3, 10);

        // Reference model: count what we observed at the submit surface.
        let mut submitted = 0u64;
        let mut rejected = 0u64;
        let mut handles = Vec::new();
        let mut cancel_asked = std::collections::HashSet::new();
        for i in 0..jobs {
            let tenant = format!("t{}", g.usize_in(0, ntenants - 1));
            let kind = *g.choose(&KINDS);
            let req = JobRequest::new(tenant, kind)
                .bytes(g.usize_in(2 << 10, 12 << 10) as u64)
                .rounds(2)
                .seed(i as u64 + 1);
            submitted += 1;
            match svc.submit(req) {
                Ok(h) => {
                    if g.chance(0.2) && h.cancel() {
                        cancel_asked.insert(h.id());
                    }
                    handles.push(h);
                }
                Err(AdmissionError::Saturated { in_flight, cap }) => {
                    if in_flight < cap {
                        return fail(format!("saturated below cap: {in_flight} < {cap}"));
                    }
                    rejected += 1;
                }
                Err(e) => return fail(format!("unexpected refusal: {e}")),
            }
        }

        // Every admitted job reaches exactly one *stable* terminal state.
        let (mut done, mut cancelled) = (0u64, 0u64);
        for h in &handles {
            let first = h.wait();
            match &first {
                JobStatus::Done(s) => {
                    if s.lines.is_empty() {
                        return fail(format!("job {} completed with no output", h.id()));
                    }
                    done += 1;
                }
                JobStatus::Cancelled => {
                    if !cancel_asked.contains(&h.id()) {
                        return fail(format!("job {} cancelled unasked", h.id()));
                    }
                    cancelled += 1;
                }
                JobStatus::Failed(e) => return fail(format!("job {} failed: {e}", h.id())),
                other => return fail(format!("wait returned non-terminal {}", other.label())),
            }
            if h.poll().label() != first.label() {
                return fail(format!("job {} changed terminal state", h.id()));
            }
        }

        let store = std::sync::Arc::clone(svc.store());
        let report = svc.shutdown();
        if !report.balances() {
            return fail(format!("ledger out of balance:\n{}", report.render()));
        }
        let want = (submitted, rejected, done, cancelled, 0);
        let got = (
            report.submitted,
            report.rejected,
            report.completed,
            report.cancelled,
            report.failed,
        );
        if got != want {
            return fail(format!("ledger {got:?} != observed {want:?}:\n{}", report.render()));
        }
        let per_tenant: u64 = report.tenants.iter().map(|t| t.metrics.count("jobs.submitted")).sum();
        if per_tenant != submitted {
            return fail(format!("tenant rows sum to {per_tenant}, submitted {submitted}"));
        }
        for (i, t) in report.tenants.iter().enumerate() {
            let base = (i as u64 + 1) * TENANT_NS_SPAN;
            let resident = store.bytes_in_namespace_range(base, base + TENANT_NS_SPAN);
            if resident > QUOTA {
                return fail(format!("tenant {} resident {resident} B > quota {QUOTA} B", t.name));
            }
        }
        Ok(())
    });
}
