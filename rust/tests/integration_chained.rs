//! Cross-engine parity for the planner layer and the chained (multi-stage)
//! pipeline: compiled stage graphs must record the plan-time decisions
//! (exchange elision, cache points, bridge wiring), and `sessionize` —
//! two genuine shuffle boundaries — must reproduce the serial chained
//! oracle bit-identically on every engine, with and without injected
//! failures.

use std::sync::Arc;

use blaze::cache::{CacheBudget, PartitionCache};
use blaze::cluster::{FailurePlan, NetModel};
use blaze::corpus::{Corpus, CorpusSpec, Tokenizer};
use blaze::engines::Engine;
use blaze::mapreduce::{
    run_chained, run_chained_serial, Exchange, InputSource, JobInputs, JobSpec,
};
use blaze::workloads::{synthesize_logs, Grep, PageRank, Sessionize, WordCount};

const ENGINES: [Engine; 4] =
    [Engine::Blaze, Engine::BlazeTcm, Engine::Spark, Engine::SparkStripped];

/// Engines with a recovery path to exercise (stripped Spark has FT off).
const FAILURE_ENGINES: [Engine; 3] = [Engine::Blaze, Engine::BlazeTcm, Engine::Spark];

fn spec(engine: Engine) -> JobSpec {
    JobSpec::new(engine).nodes(2).threads_per_node(2).net(NetModel::ideal())
}

fn failure_plan(engine: Engine) -> FailurePlan {
    match engine {
        Engine::Blaze | Engine::BlazeTcm => FailurePlan::none().fail_node(0, 0).fail_node(1, 1),
        Engine::Spark | Engine::SparkStripped => {
            FailurePlan::none().fail_task(0, 1).fail_task(1, 0)
        }
    }
}

fn log_inputs(users: usize, events: usize, gap: u64, seed: u64) -> JobInputs {
    JobInputs::new().relation_lines("logs", Arc::new(synthesize_logs(users, events, gap, seed)))
}

// ------------------------------------------------------------- the plan ----

#[test]
fn single_pass_jobs_compile_to_one_stage() {
    let corpus = Corpus::from_text("a b\n");
    let inputs = JobInputs::single(&corpus);
    let w = WordCount::new(Tokenizer::Spaces);
    let graph = spec(Engine::BlazeTcm).plan(&w, &inputs);
    assert_eq!(graph.num_stages(), 1);
    assert_eq!(graph.num_exchanges(), 1);
    assert!(graph.boundaries().is_empty());
    assert_eq!(graph.stage(0).exchange, Exchange::Shuffle);
    assert_eq!(graph.stage(0).inputs.len(), 1);
    assert_eq!(graph.stage(0).inputs[0].source, InputSource::External(0));
    assert!(graph.stage(0).cache_point(0).is_none(), "no cache attached, no cache point");
    assert!(graph.render().contains("wordcount"));
}

#[test]
fn zero_shuffle_elision_is_decided_at_plan_time() {
    let corpus = Corpus::from_text("a b\n");
    let inputs = JobInputs::single(&corpus);
    let grep = Grep::new("a");
    let graph = spec(Engine::Spark).plan(&grep, &inputs);
    assert_eq!(graph.stage(0).exchange, Exchange::Elided);
    assert_eq!(graph.num_exchanges(), 0);
    // --force-shuffle overrides the opt-out, visibly in the plan.
    let graph = spec(Engine::Spark).force_shuffle(true).plan(&grep, &inputs);
    assert_eq!(graph.stage(0).exchange, Exchange::Forced);
    assert_eq!(graph.num_exchanges(), 1);
}

#[test]
fn cache_points_follow_the_attached_budget() {
    let corpus = Corpus::from_text("a b\nb c\n");
    let inputs = JobInputs::new()
        .relation("edges", &corpus)
        .relation_lines("state", Arc::new(vec!["a 1 1".to_string()]));
    let w = PageRank::new();
    let step = blaze::mapreduce::IterativeWorkload::step(&w, &["a 1 1".to_string()]);

    // Live cache: every relation gets a point carrying its generation.
    let live = spec(Engine::BlazeTcm)
        .shared_cache(Arc::new(PartitionCache::new(CacheBudget::Unbounded)))
        .relation_gens(vec![0, 7]);
    let graph = live.plan_cached(step.as_ref(), &inputs);
    let p0 = graph.stage(0).cache_point(0).expect("edges cache point");
    let p1 = graph.stage(0).cache_point(1).expect("state cache point");
    assert_eq!((p0.namespace, p0.generation), (0, 0));
    assert_eq!((p1.namespace, p1.generation), (1, 7));

    // Budget 0 (the recompute ablation): the planner elides every point.
    let disabled = spec(Engine::BlazeTcm)
        .shared_cache(Arc::new(PartitionCache::new(CacheBudget::Bytes(0))));
    let graph = disabled.plan_cached(step.as_ref(), &inputs);
    assert!(graph.stage(0).cache_point(0).is_none());
    assert!(graph.stage(0).cache_point(1).is_none());
}

#[test]
fn chained_plan_wires_bridge_relations() {
    let inputs = log_inputs(4, 50, 100, 1);
    let sz = Sessionize::new(100);
    let graph = spec(Engine::BlazeTcm).plan_chained(&sz, &inputs);
    assert_eq!(graph.num_stages(), 2);
    assert_eq!(graph.num_exchanges(), 2);
    assert_eq!(graph.boundaries().len(), 1);
    assert_eq!(graph.stage(0).inputs[0].source, InputSource::External(0));
    assert_eq!(graph.stage(1).inputs.len(), 1);
    assert_eq!(graph.stage(1).inputs[0].source, InputSource::StageOutput(0));
    assert_eq!(graph.stage(1).inputs[0].name, "stage0.out");
    let rendered = graph.render();
    assert!(rendered.contains("sessions"), "{rendered}");
    assert!(rendered.contains("session-stats"), "{rendered}");
}

// --------------------------------------------------------------- parity ----

#[test]
fn sessionize_parity_across_engines() {
    let inputs = log_inputs(12, 1500, 120, 41);
    let sz = Sessionize::new(120);
    let expect = run_chained_serial(&sz, &inputs);
    assert!(!expect.is_empty());
    for engine in ENGINES {
        let r = run_chained(&spec(engine), &sz, &inputs).unwrap();
        assert_eq!(r.lines, expect, "{}", engine.label());
        // Two stages, both shuffling, both attributable.
        assert_eq!(r.stages.len(), 2, "{}", engine.label());
        if engine != Engine::SparkStripped {
            // Stripped Spark ships typed (unserialized) blocks, so its
            // byte counter legitimately reads 0.
            assert!(r.stages.iter().all(|s| s.shuffle_bytes > 0), "{}", engine.label());
        }
        assert!(r.stages.iter().all(|s| s.records_in > 0), "{}", engine.label());
        // Stage 1 reads exactly the bridge lines stage 0 produced.
        let sessions: u64 = Sessionize::stats_from_lines(&expect)
            .iter()
            .map(|(_, n, _)| n)
            .sum();
        assert_eq!(r.stages[1].records_in, sessions, "{}", engine.label());
        assert_eq!(r.shuffle_bytes, r.stages.iter().map(|s| s.shuffle_bytes).sum::<u64>());
    }
}

#[test]
fn sessionize_parity_under_injected_failures() {
    let inputs = log_inputs(8, 600, 90, 43);
    let sz = Sessionize::new(90);
    let expect = run_chained_serial(&sz, &inputs);
    for engine in FAILURE_ENGINES {
        let r = run_chained(&spec(engine).failures(failure_plan(engine)), &sz, &inputs).unwrap();
        assert_eq!(r.lines, expect, "{}", engine.label());
    }
}

#[test]
fn sessionize_empty_input_is_empty_everywhere() {
    let inputs = JobInputs::new().relation_lines("logs", Arc::new(Vec::new()));
    let sz = Sessionize::new(10);
    assert!(run_chained_serial(&sz, &inputs).is_empty());
    for engine in ENGINES {
        let r = run_chained(&spec(engine), &sz, &inputs).unwrap();
        assert!(r.lines.is_empty(), "{}", engine.label());
    }
}

#[test]
fn chained_arity_is_validated() {
    let sz = Sessionize::new(10);
    let two = JobInputs::new()
        .relation_lines("a", Arc::new(Vec::new()))
        .relation_lines("b", Arc::new(Vec::new()));
    let err = run_chained(&spec(Engine::BlazeTcm), &sz, &two).unwrap_err();
    assert!(err.to_string().contains("expects 1 input relation(s)"), "{err}");
}

// ------------------------------------------------------ per-stage stats ----

#[test]
fn single_pass_reports_carry_one_stage_row() {
    let corpus = Corpus::generate(&CorpusSpec::with_bytes(32 << 10));
    let w = Arc::new(WordCount::new(Tokenizer::Spaces));
    for engine in [Engine::BlazeTcm, Engine::Spark] {
        let r = spec(engine).run_str(&w, &corpus).unwrap();
        assert_eq!(r.stages.len(), 1, "{}", engine.label());
        let s = &r.stages[0];
        assert_eq!(s.label, "wordcount");
        assert_eq!(s.records_in, corpus.lines.len() as u64, "{}", engine.label());
        assert!(s.records_out > 0, "{}", engine.label());
        assert_eq!(s.shuffle_bytes, r.shuffle_bytes, "{}", engine.label());
    }
}
