//! The public word-count API: one job description dispatched to either
//! engine, one result type, and the serial reference used for verification.
//!
//! Since the generic job layer landed, this module is a thin facade:
//! [`WordCountJob`] builds a [`crate::mapreduce::JobSpec`], runs
//! [`crate::workloads::WordCount`] through it, and repackages the
//! [`crate::mapreduce::JobReport`] as a [`WordCountResult`] — the public
//! API and results are unchanged.
//!
//! ```no_run
//! use blaze::engines::Engine;
//! use blaze::wordcount::WordCountJob;
//! use blaze::corpus::{Corpus, CorpusSpec};
//!
//! let corpus = Corpus::generate(&CorpusSpec::with_bytes(16 << 20));
//! let result = WordCountJob::new(Engine::Blaze)
//!     .nodes(2)
//!     .threads_per_node(4)
//!     .run(&corpus)
//!     .unwrap();
//! println!("{}", result.summary());
//! assert!(result.verify(&corpus));
//! ```
//!
//! (`EngineChoice` remains as a deprecated-in-spirit alias of
//! [`crate::engines::Engine`] for older call sites.)

use std::collections::HashMap;
use std::sync::Arc;

use crate::cluster::{FailurePlan, NetModel};
use crate::concurrent::CachePolicy;
use crate::corpus::{Corpus, Tokenizer};
use crate::dist::CombineMode;
use crate::engines::spark::SparkConf;
use crate::hash::HashKind;
use crate::mapreduce::JobSpec;
use crate::util::stats::fmt_rate;
use crate::workloads::WordCount;

/// Engine selection — the unified [`crate::engines::Engine`] under its
/// legacy word-count name.
pub use crate::engines::Engine as EngineChoice;

/// Everything needed to run one word count.
#[derive(Clone, Debug)]
pub struct WordCountJob {
    pub engine: EngineChoice,
    pub nnodes: usize,
    /// Simulated per-node thread count (cost model); see
    /// [`JobSpec::threads_per_node`].
    pub threads_per_node: usize,
    /// Real work-stealing executor width (see [`JobSpec::threads`]);
    /// `None` = auto.
    pub threads: Option<usize>,
    pub net: NetModel,
    pub tokenizer: Tokenizer,
    /// Blaze: map-side combining mode (A3 ablation).
    pub combine: CombineMode,
    /// Blaze: hash function.
    pub hash: HashKind,
    /// Blaze: thread-cache policy (default: optimized cache-first; the
    /// paper's prose policy is spill-on-contention).
    pub cache_policy: CachePolicy,
    /// Spark: override individual cost knobs after the engine presets.
    pub spark_overrides: Option<SparkConf>,
    /// Failure injection plan (consumed by whichever engine runs).
    pub failures: std::sync::Arc<FailurePlan>,
    /// Bounded-memory exchange budget (see
    /// [`JobSpec::spill_threshold`]).
    pub spill_threshold: Option<u64>,
    /// Directory spill files live under (`None` = system temp dir).
    pub spill_dir: Option<std::path::PathBuf>,
    /// Block-compress disk-tier writes (see [`JobSpec::compress`]).
    pub compress: bool,
    /// Dictionary-encode repeated keys on the wire (see
    /// [`JobSpec::dict_keys`]).
    pub dict_keys: bool,
}

impl WordCountJob {
    pub fn new(engine: EngineChoice) -> Self {
        Self {
            engine,
            nnodes: 1,
            threads_per_node: 4,
            threads: None,
            net: NetModel::aws_like(),
            tokenizer: Tokenizer::Spaces,
            combine: CombineMode::Eager,
            hash: HashKind::Fx,
            cache_policy: CachePolicy::default(),
            spark_overrides: None,
            failures: std::sync::Arc::new(FailurePlan::none()),
            spill_threshold: None,
            spill_dir: None,
            compress: true,
            dict_keys: true,
        }
    }

    pub fn nodes(mut self, n: usize) -> Self {
        self.nnodes = n;
        self
    }

    pub fn threads_per_node(mut self, t: usize) -> Self {
        self.threads_per_node = t;
        self
    }

    /// Pin the real work-stealing executor to `t` OS threads.
    pub fn threads(mut self, t: usize) -> Self {
        self.threads = Some(t);
        self
    }

    pub fn net(mut self, net: NetModel) -> Self {
        self.net = net;
        self
    }

    pub fn tokenizer(mut self, t: Tokenizer) -> Self {
        self.tokenizer = t;
        self
    }

    pub fn combine(mut self, c: CombineMode) -> Self {
        self.combine = c;
        self
    }

    pub fn cache_policy(mut self, p: CachePolicy) -> Self {
        self.cache_policy = p;
        self
    }

    pub fn spark_conf(mut self, conf: SparkConf) -> Self {
        self.spark_overrides = Some(conf);
        self
    }

    pub fn failures(mut self, plan: FailurePlan) -> Self {
        self.failures = std::sync::Arc::new(plan);
        self
    }

    /// Bound the exchange's in-flight memory (see
    /// [`JobSpec::spill_threshold`]).
    pub fn spill_threshold(mut self, bytes: u64) -> Self {
        self.spill_threshold = Some(bytes);
        self
    }

    /// Where spill files live (`None` = system temp dir).
    pub fn spill_dir(mut self, dir: std::path::PathBuf) -> Self {
        self.spill_dir = Some(dir);
        self
    }

    /// Toggle disk-tier block compression (see [`JobSpec::compress`]).
    pub fn compress(mut self, on: bool) -> Self {
        self.compress = on;
        self
    }

    /// Toggle wire key dictionaries (see [`JobSpec::dict_keys`]).
    pub fn dict_keys(mut self, on: bool) -> Self {
        self.dict_keys = on;
        self
    }

    /// The equivalent generic job description.
    pub fn to_spec(&self) -> JobSpec {
        JobSpec {
            engine: self.engine,
            nnodes: self.nnodes,
            threads_per_node: self.threads_per_node,
            threads: self.threads,
            net: self.net,
            combine: self.combine,
            hash: self.hash,
            cache_policy: self.cache_policy,
            spark_overrides: self.spark_overrides.clone(),
            failures: Arc::clone(&self.failures),
            max_job_reruns: 3,
            force_shuffle: false,
            cache: None,
            relation_gens: Vec::new(),
            spill_threshold: self.spill_threshold,
            spill_dir: self.spill_dir.clone(),
            eviction_policy: None,
            compress: self.compress,
            dict_keys: self.dict_keys,
            trace: None,
        }
    }

    /// Execute on the chosen engine via the generic job layer.
    pub fn run(&self, corpus: &Corpus) -> Result<WordCountResult, WordCountError> {
        let workload = Arc::new(WordCount::new(self.tokenizer));
        let report = self
            .to_spec()
            .run_str(&workload, corpus)
            .map_err(|e| WordCountError(e.0))?;
        let words: u64 = report.output.values().sum();
        Ok(WordCountResult {
            engine: self.engine,
            counts: report.output,
            wall_secs: report.wall_secs,
            words,
            shuffle_bytes: report.shuffle_bytes,
            storage: report.storage,
            detail: report.detail,
            exec: report.exec,
        })
    }
}

/// Uniform result across engines.
#[derive(Debug)]
pub struct WordCountResult {
    pub engine: EngineChoice,
    pub counts: HashMap<String, u64>,
    pub wall_secs: f64,
    pub words: u64,
    pub shuffle_bytes: u64,
    /// Storage-hierarchy activity (exchange spill, persisted blocks).
    pub storage: crate::storage::StorageStats,
    /// Engine-specific metric breakdown (renders as the familiar `k=v`
    /// line via `Display`).
    pub detail: crate::trace::MetricSet,
    /// Work-stealing executor activity during the run (see
    /// [`crate::mapreduce::JobReport::exec`]).
    pub exec: crate::runtime::executor::ExecMetrics,
}

#[derive(Debug, Clone)]
pub struct WordCountError(pub String);

impl std::fmt::Display for WordCountError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "word count failed: {}", self.0)
    }
}

impl std::error::Error for WordCountError {}

impl WordCountResult {
    /// The paper's headline metric.
    pub fn words_per_sec(&self) -> f64 {
        self.words as f64 / self.wall_secs.max(1e-12)
    }

    /// Verify against the serial reference.
    pub fn verify(&self, corpus: &Corpus) -> bool {
        self.counts == serial_reference(corpus, Tokenizer::Spaces)
            || self.counts == serial_reference(corpus, Tokenizer::Normalized)
    }

    /// Human summary line.
    pub fn summary(&self) -> String {
        format!(
            "{:<16} {:>12} words in {:>8.3}s = {:>14}   shuffle={}",
            self.engine.label(),
            self.words,
            self.wall_secs,
            fmt_rate(self.words_per_sec(), "words"),
            crate::util::stats::fmt_bytes(self.shuffle_bytes),
        )
    }

    /// Most frequent `k` words (count desc, then word asc).
    pub fn top_k(&self, k: usize) -> Vec<(String, u64)> {
        top_k(&self.counts, k)
    }
}

/// Single-threaded reference count — the correctness oracle everywhere.
pub fn serial_reference(corpus: &Corpus, tokenizer: Tokenizer) -> HashMap<String, u64> {
    let mut m = HashMap::new();
    for line in &corpus.lines {
        tokenizer.for_each_token(line, |w| {
            *m.entry(w.to_string()).or_insert(0u64) += 1;
        });
    }
    m
}

/// Top-k by count (desc), ties broken alphabetically.
pub fn top_k(counts: &HashMap<String, u64>, k: usize) -> Vec<(String, u64)> {
    let mut v: Vec<(String, u64)> = counts.iter().map(|(k, &c)| (k.clone(), c)).collect();
    v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    v.truncate(k);
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::CorpusSpec;

    fn small_corpus() -> Corpus {
        Corpus::generate(&CorpusSpec::with_bytes(64 << 10))
    }

    #[test]
    fn all_engines_agree_with_reference() {
        let corpus = small_corpus();
        let expect = serial_reference(&corpus, Tokenizer::Spaces);
        for engine in [
            EngineChoice::Blaze,
            EngineChoice::BlazeTcm,
            EngineChoice::Spark,
            EngineChoice::SparkStripped,
        ] {
            let result = WordCountJob::new(engine)
                .nodes(2)
                .threads_per_node(2)
                .net(NetModel::ideal())
                .run(&corpus)
                .unwrap();
            assert_eq!(result.counts, expect, "{}", engine.label());
            assert!(result.verify(&corpus));
            assert!(result.words_per_sec() > 0.0);
        }
    }

    #[test]
    fn top_k_ordering() {
        let mut counts = HashMap::new();
        counts.insert("b".to_string(), 5u64);
        counts.insert("a".to_string(), 5);
        counts.insert("c".to_string(), 9);
        counts.insert("d".to_string(), 1);
        let top = top_k(&counts, 3);
        assert_eq!(
            top,
            vec![("c".to_string(), 9), ("a".to_string(), 5), ("b".to_string(), 5)]
        );
    }

    #[test]
    fn engine_choice_parse() {
        assert_eq!(EngineChoice::parse("blaze"), Some(EngineChoice::Blaze));
        assert_eq!(EngineChoice::parse("tcm"), Some(EngineChoice::BlazeTcm));
        assert_eq!(EngineChoice::parse("spark"), Some(EngineChoice::Spark));
        assert_eq!(
            EngineChoice::parse("spark-stripped"),
            Some(EngineChoice::SparkStripped)
        );
        assert_eq!(EngineChoice::parse("hadoop"), None);
    }

    #[test]
    fn engine_choice_is_the_unified_enum() {
        // Satellite of the job-layer refactor: one enum, two names.
        let e: crate::engines::Engine = EngineChoice::BlazeTcm;
        assert_eq!(e.label(), "Blaze TCM");
    }

    #[test]
    fn summary_contains_rate() {
        let corpus = small_corpus();
        let r = WordCountJob::new(EngineChoice::BlazeTcm)
            .net(NetModel::ideal())
            .run(&corpus)
            .unwrap();
        assert!(r.summary().contains("words/s"));
    }
}
