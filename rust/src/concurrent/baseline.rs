//! Baseline concurrent maps the paper's design is compared against in
//! `bench concurrent_map` (experiment M1):
//!
//! * [`GlobalLockMap`] — one mutex around one chained `std::HashMap`
//!   (the naive shared-map approach).
//! * [`ShardedLockMap`] — N mutexes over N chained `std::HashMap`s
//!   (the common "good enough" sharded design; still blocks on contention,
//!   still allocates per chain node).
//!
//! Both implement exact counting (they block instead of spilling), so they
//! double as oracles in the property tests.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::Mutex;

use crate::hash::{bucket_of, HashKind};

use super::map::MapKey;

/// One global mutex around a chained hash map.
pub struct GlobalLockMap<K, V> {
    inner: Mutex<HashMap<K, V>>,
}

impl<K: Eq + Hash, V> GlobalLockMap<K, V> {
    pub fn new() -> Self {
        Self { inner: Mutex::new(HashMap::new()) }
    }

    pub fn upsert(&self, key: K, value: V, reduce: impl FnOnce(&mut V, V)) {
        let mut m = self.inner.lock().unwrap();
        match m.entry(key) {
            std::collections::hash_map::Entry::Occupied(mut e) => reduce(e.get_mut(), value),
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert(value);
            }
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn get(&self, key: &K) -> Option<V>
    where
        V: Clone,
    {
        self.inner.lock().unwrap().get(key).cloned()
    }

    pub fn into_inner(self) -> HashMap<K, V> {
        self.inner.into_inner().unwrap()
    }
}

impl<K: Eq + Hash, V> Default for GlobalLockMap<K, V> {
    fn default() -> Self {
        Self::new()
    }
}

/// N shards, each a mutex-protected chained map; writers block on their
/// shard's lock (no cache spill).
pub struct ShardedLockMap<K, V> {
    shards: Vec<Mutex<HashMap<K, V>>>,
    hash_kind: HashKind,
}

impl<K: MapKey + Hash, V> ShardedLockMap<K, V> {
    pub fn new(nshards: usize, hash_kind: HashKind) -> Self {
        assert!(nshards > 0);
        Self {
            shards: (0..nshards).map(|_| Mutex::new(HashMap::new())).collect(),
            hash_kind,
        }
    }

    #[inline]
    fn shard_of(&self, key: &K) -> usize {
        bucket_of(key.hash_with(self.hash_kind), self.shards.len())
    }

    pub fn upsert(&self, key: K, value: V, reduce: impl FnOnce(&mut V, V)) {
        let s = self.shard_of(&key);
        let mut m = self.shards[s].lock().unwrap();
        match m.entry(key) {
            std::collections::hash_map::Entry::Occupied(mut e) => reduce(e.get_mut(), value),
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert(value);
            }
        }
    }

    pub fn get(&self, key: &K) -> Option<V>
    where
        V: Clone,
    {
        self.shards[self.shard_of(key)].lock().unwrap().get(key).cloned()
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn to_vec(&self) -> Vec<(K, V)>
    where
        K: Clone,
        V: Clone,
    {
        let mut out = Vec::new();
        for s in &self.shards {
            let m = s.lock().unwrap();
            out.extend(m.iter().map(|(k, v)| (k.clone(), v.clone())));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pool::{parallel_for, Schedule};

    #[test]
    fn global_lock_counts() {
        let m: GlobalLockMap<String, u64> = GlobalLockMap::new();
        parallel_for(4, 1000, Schedule::Dynamic { chunk: 8 }, |_ctx, i| {
            m.upsert(format!("k{}", i % 10), 1, |a, b| *a += b);
        });
        assert_eq!(m.len(), 10);
        assert_eq!(m.get(&"k0".to_string()), Some(100));
    }

    #[test]
    fn sharded_lock_counts() {
        let m: ShardedLockMap<String, u64> = ShardedLockMap::new(16, HashKind::Fx);
        parallel_for(4, 1000, Schedule::Dynamic { chunk: 8 }, |_ctx, i| {
            m.upsert(format!("k{}", i % 10), 1, |a, b| *a += b);
        });
        assert_eq!(m.len(), 10);
        let total: u64 = m.to_vec().iter().map(|(_, v)| v).sum();
        assert_eq!(total, 1000);
    }

    #[test]
    fn sharded_agrees_with_global() {
        let a: ShardedLockMap<String, u64> = ShardedLockMap::new(8, HashKind::Fx);
        let b: GlobalLockMap<String, u64> = GlobalLockMap::new();
        for i in 0..500 {
            let k = format!("w{}", i % 23);
            a.upsert(k.clone(), 2, |x, y| *x += y);
            b.upsert(k, 2, |x, y| *x += y);
        }
        let mut va = a.to_vec();
        va.sort();
        let mut vb: Vec<(String, u64)> = b.into_inner().into_iter().collect();
        vb.sort();
        assert_eq!(va, vb);
    }
}
