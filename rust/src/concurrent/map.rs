//! The paper's `ConcurrentHashMap`: a data portion of lockable **segments**
//! plus a **thread cache** portion, such that *no writer ever blocks*.
//!
//! > "When a thread wants to update a segment, it has to lock the segment
//! > first. In the case that a segment is already locked by another thread,
//! > the data will be flushed to a thread-local linear probing hash map in
//! > the thread cache portion, so that no thread will ever get blocked. The
//! > cache will be synchronized to the main data portion either periodically
//! > or after the map phase ends."
//!
//! Consistency model: **eventual** for associative, commutative updates.
//! Reads ([`ConcurrentHashMap::get`], iteration) are only guaranteed
//! complete after [`ConcurrentHashMap::sync`].

use std::sync::Mutex;

use super::probe::{Entry, ProbeTable};
use crate::hash::{bucket_of, HashKind};
use crate::util::pool::{self, Schedule};

/// Keys usable in the concurrent/distributed maps.
pub trait MapKey: Clone + Eq + Send + Sync {
    fn hash_with(&self, kind: HashKind) -> u64;
}

impl MapKey for String {
    #[inline]
    fn hash_with(&self, kind: HashKind) -> u64 {
        kind.hash(self.as_bytes())
    }
}

impl MapKey for u64 {
    #[inline]
    fn hash_with(&self, _kind: HashKind) -> u64 {
        crate::hash::mix_u64(*self)
    }
}

impl MapKey for i64 {
    #[inline]
    fn hash_with(&self, _kind: HashKind) -> u64 {
        crate::hash::mix_u64(*self as u64)
    }
}

impl MapKey for u32 {
    #[inline]
    fn hash_with(&self, _kind: HashKind) -> u64 {
        crate::hash::mix_u64(*self as u64)
    }
}

/// Values storable in the maps.
pub trait MapValue: Clone + Send + Sync {}
impl<T: Clone + Send + Sync> MapValue for T {}

/// Padded mutex to keep per-thread caches on distinct cache lines.
#[repr(align(64))]
struct Padded<T>(Mutex<T>);

/// When writers move data from their thread to the shared segments.
///
/// The paper describes both: "the data will be flushed to a thread-local
/// linear probing hash map in the thread cache portion" (on contention) and
/// "the cache will be synchronized to the main data portion either
/// **periodically** or after the map phase ends."
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CachePolicy {
    /// Try the segment lock; on contention, spill to the thread cache
    /// (the paper's prose default). Every upsert touches a shared line.
    SpillOnContention,
    /// Combine in the thread cache first and flush to the segments when
    /// the cache exceeds a threshold ("periodically"). Hot keys combine
    /// with zero shared-memory traffic — this is what makes the map scale
    /// on Zipf-skewed streams (see EXPERIMENTS.md §Perf).
    CacheFirst { flush_at: usize },
}

impl Default for CachePolicy {
    fn default() -> Self {
        // 64k entries x ~48B ≈ 3 MB per thread cache: fits in L2/L3 and
        // comfortably holds a natural-language vocabulary between flushes.
        CachePolicy::CacheFirst { flush_at: 64 * 1024 }
    }
}

/// Statistics the benches report: how often writers found their segment
/// contended and spilled to the cache.
#[derive(Debug, Default, Clone, Copy)]
pub struct MapStats {
    pub direct_upserts: u64,
    pub cached_upserts: u64,
}

pub struct ConcurrentHashMap<K: MapKey, V: MapValue> {
    segments: Vec<Padded<ProbeTable<K, V>>>,
    caches: Vec<Padded<ProbeTable<K, V>>>,
    hash_kind: HashKind,
    policy: CachePolicy,
    stats: Vec<Padded<MapStats>>,
}

/// Default segment count: enough that `nthreads` concurrent writers
/// rarely collide on a segment (8× writers rounded up to a power of two,
/// floor 32 — the full rationale lives in the [module
/// docs](crate::concurrent#segment-count-heuristic)). Pass the **real**
/// writer count — the executor pool width
/// ([`crate::runtime::Executor::width`]) — not the simulated
/// `threads_per_node` cost knob.
/// Total: saturates instead of overflowing on absurd widths (`usize::MAX`
/// would otherwise panic in debug and wrap to 0 segments in release), and
/// caps at the largest representable power of two.
pub fn default_segments(nthreads: usize) -> usize {
    const MAX_POW2: usize = 1 << (usize::BITS - 1);
    nthreads
        .saturating_mul(8)
        .checked_next_power_of_two()
        .unwrap_or(MAX_POW2)
        .max(32)
}

impl<K: MapKey, V: MapValue> ConcurrentHashMap<K, V> {
    /// `nsegments` lockable segments; `nthreads` thread caches. Threads are
    /// identified by the `tid` argument of the write methods (the pool's
    /// `WorkerCtx::worker` index).
    pub fn new(nsegments: usize, nthreads: usize, hash_kind: HashKind) -> Self {
        Self::with_policy(nsegments, nthreads, hash_kind, CachePolicy::default())
    }

    pub fn with_policy(
        nsegments: usize,
        nthreads: usize,
        hash_kind: HashKind,
        policy: CachePolicy,
    ) -> Self {
        assert!(nsegments > 0 && nthreads > 0);
        Self {
            segments: (0..nsegments).map(|_| Padded(Mutex::new(ProbeTable::new()))).collect(),
            caches: (0..nthreads).map(|_| Padded(Mutex::new(ProbeTable::new()))).collect(),
            hash_kind,
            policy,
            stats: (0..nthreads).map(|_| Padded(Mutex::new(MapStats::default()))).collect(),
        }
    }

    pub fn policy(&self) -> CachePolicy {
        self.policy
    }

    pub fn with_threads(nthreads: usize) -> Self {
        Self::new(default_segments(nthreads), nthreads, HashKind::default())
    }

    pub fn hash_kind(&self) -> HashKind {
        self.hash_kind
    }

    pub fn num_segments(&self) -> usize {
        self.segments.len()
    }

    pub fn num_threads(&self) -> usize {
        self.caches.len()
    }

    #[inline]
    fn segment_of(&self, hash: u64) -> usize {
        bucket_of(hash, self.segments.len())
    }

    /// The paper's non-blocking associative update: try the owning segment;
    /// if contended, spill to the caller's thread cache.
    ///
    /// `reduce` must be associative and commutative (e.g. `+=`) for the
    /// eventual-consistency contract to hold.
    #[inline]
    pub fn upsert(&self, tid: usize, key: K, value: V, reduce: impl Fn(&mut V, V)) {
        let hash = key.hash_with(self.hash_kind);
        self.upsert_hashed(tid, hash, key, value, reduce)
    }

    /// `upsert` with a precomputed hash (hot path for callers that already
    /// hashed the key for routing).
    #[inline]
    pub fn upsert_hashed(
        &self,
        tid: usize,
        hash: u64,
        key: K,
        value: V,
        reduce: impl Fn(&mut V, V),
    ) {
        match self.policy {
            CachePolicy::SpillOnContention => {
                let seg = self.segment_of(hash);
                if let Ok(mut table) = self.segments[seg].0.try_lock() {
                    table.upsert(hash, key, value, reduce);
                    if cfg!(debug_assertions) {
                        self.stats[tid].0.lock().unwrap().direct_upserts += 1;
                    }
                } else {
                    // Segment contended: never block — spill to the cache.
                    let mut cache = self.caches[tid].0.lock().unwrap();
                    cache.upsert(hash, key, value, reduce);
                    if cfg!(debug_assertions) {
                        drop(cache);
                        self.stats[tid].0.lock().unwrap().cached_upserts += 1;
                    }
                }
            }
            CachePolicy::CacheFirst { flush_at } => {
                let mut cache = self.caches[tid].0.lock().unwrap();
                cache.upsert(hash, key, value, &reduce);
                if cache.len() >= flush_at {
                    let drained = cache.drain();
                    drop(cache);
                    self.flush_entries(drained, &reduce);
                }
            }
        }
    }

    /// Merge a drained cache into the segments (periodic flush). Blocking
    /// locks are fine here: this runs once per `flush_at` upserts.
    fn flush_entries(&self, entries: Vec<Entry<K, V>>, reduce: &impl Fn(&mut V, V)) {
        let nsegs = self.segments.len();
        let mut by_seg: Vec<Vec<Entry<K, V>>> = (0..nsegs).map(|_| Vec::new()).collect();
        for e in entries {
            by_seg[bucket_of(e.hash, nsegs)].push(e);
        }
        for (s, bucket) in by_seg.into_iter().enumerate() {
            if bucket.is_empty() {
                continue;
            }
            let mut seg = self.segments[s].0.lock().unwrap();
            for e in bucket {
                seg.upsert(e.hash, e.key, e.value, reduce);
            }
        }
    }

    /// Visit-or-insert with a borrowed key: only allocates the owned key on
    /// first insertion. See [`crate::concurrent::ProbeTable::upsert_with`].
    #[inline]
    pub fn upsert_borrowed(
        &self,
        tid: usize,
        hash: u64,
        key_matches: impl Fn(&K) -> bool + Copy,
        make_key: impl FnOnce() -> K,
        value: V,
        reduce: impl Fn(&mut V, V),
    ) {
        match self.policy {
            CachePolicy::SpillOnContention => {
                let seg = self.segment_of(hash);
                if let Ok(mut table) = self.segments[seg].0.try_lock() {
                    table.upsert_with(hash, key_matches, make_key, value, reduce);
                } else {
                    let mut cache = self.caches[tid].0.lock().unwrap();
                    cache.upsert_with(hash, key_matches, make_key, value, reduce);
                }
            }
            CachePolicy::CacheFirst { flush_at } => {
                let mut cache = self.caches[tid].0.lock().unwrap();
                cache.upsert_with(hash, key_matches, make_key, value, &reduce);
                if cache.len() >= flush_at {
                    let drained = cache.drain();
                    drop(cache);
                    self.flush_entries(drained, &reduce);
                }
            }
        }
    }

    /// Synchronize all thread caches into the segments (the paper's
    /// "periodically or after the map phase ends" step), in parallel:
    /// phase A drains each cache and buckets its entries by segment;
    /// phase B merges each segment's bucket list under its own lock.
    pub fn sync(&self, nthreads: usize, reduce: impl Fn(&mut V, V) + Sync) {
        let nsegs = self.segments.len();
        // Phase A: drain caches, bucket by segment.
        let buckets: Vec<Mutex<Vec<Vec<Entry<K, V>>>>> = (0..self.caches.len())
            .map(|_| Mutex::new(Vec::new()))
            .collect();
        pool::parallel_for(nthreads, self.caches.len(), Schedule::Dynamic { chunk: 1 }, |_ctx, c| {
            let entries = self.caches[c].0.lock().unwrap().drain();
            let mut by_seg: Vec<Vec<Entry<K, V>>> = (0..nsegs).map(|_| Vec::new()).collect();
            for e in entries {
                by_seg[bucket_of(e.hash, nsegs)].push(e);
            }
            *buckets[c].lock().unwrap() = by_seg;
        });
        let buckets: Vec<Vec<Vec<Entry<K, V>>>> =
            buckets.into_iter().map(|m| m.into_inner().unwrap()).collect();
        // Phase B: per segment, merge every cache's bucket.
        let reduce = &reduce;
        pool::parallel_for(nthreads, nsegs, Schedule::Dynamic { chunk: 4 }, |_ctx, s| {
            let mut seg = self.segments[s].0.lock().unwrap();
            for cache_buckets in &buckets {
                if let Some(bucket) = cache_buckets.get(s) {
                    for e in bucket {
                        seg.upsert(e.hash, e.key.clone(), e.value.clone(), reduce);
                    }
                }
            }
        });
    }

    /// Point lookup. Only complete after [`sync`](Self::sync).
    pub fn get(&self, key: &K) -> Option<V> {
        let hash = key.hash_with(self.hash_kind);
        let seg = self.segment_of(hash);
        self.segments[seg].0.lock().unwrap().get(hash, key).cloned()
    }

    /// Total entries across segments (excludes unsynced cache entries).
    pub fn len(&self) -> usize {
        self.segments.iter().map(|s| s.0.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Entries still sitting in thread caches (0 after a sync).
    pub fn pending_cache_entries(&self) -> usize {
        self.caches.iter().map(|c| c.0.lock().unwrap().len()).sum()
    }

    /// Visit every synced entry. Locks one segment at a time.
    pub fn for_each(&self, mut f: impl FnMut(&K, &V)) {
        for s in &self.segments {
            let t = s.0.lock().unwrap();
            for e in t.iter() {
                f(&e.key, &e.value);
            }
        }
    }

    /// Collect all synced entries.
    pub fn to_vec(&self) -> Vec<(K, V)> {
        let mut out = Vec::with_capacity(self.len());
        self.for_each(|k, v| out.push((k.clone(), v.clone())));
        out
    }

    /// Drain all synced entries, leaving the map empty.
    pub fn drain_entries(&self) -> Vec<Entry<K, V>> {
        let mut out = Vec::new();
        for s in &self.segments {
            out.extend(s.0.lock().unwrap().drain());
        }
        out
    }

    /// Drain **everything** — thread caches and segments — one lock at a
    /// time, without the pool-parallel [`sync`](Self::sync) pass. Safe to
    /// call while writers keep upserting: they block only on the single
    /// table being drained and land in the freshly emptied one, so the
    /// same key may come back once from this drain and again from a later
    /// one (callers merge through their associative + commutative
    /// `reduce`). This is the map-phase spill path, which runs *inside* a
    /// mapper task and therefore cannot nest another pool dispatch.
    pub fn drain_all(&self) -> Vec<Entry<K, V>> {
        let mut out = Vec::new();
        for c in &self.caches {
            out.extend(c.0.lock().unwrap().drain());
        }
        for s in &self.segments {
            out.extend(s.0.lock().unwrap().drain());
        }
        out
    }

    /// Aggregate contention statistics (only tracked in debug builds).
    pub fn stats(&self) -> MapStats {
        let mut agg = MapStats::default();
        for s in &self.stats {
            let s = s.0.lock().unwrap();
            agg.direct_upserts += s.direct_upserts;
            agg.cached_upserts += s.cached_upserts;
        }
        agg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pool::{parallel_for, Schedule};
    use std::collections::HashMap;

    #[test]
    fn default_segments_is_a_padded_power_of_two() {
        for nthreads in 0..=256 {
            let n = default_segments(nthreads);
            assert!(n.is_power_of_two(), "{nthreads} -> {n}");
            assert!(n >= 32, "{nthreads} -> {n} breaks the floor");
            // ≥ 8 segments per writer, so collisions stay rare.
            assert!(n >= nthreads * 8, "{nthreads} -> {n}");
        }
    }

    #[test]
    fn default_segments_monotone_and_exact_on_powers_of_two() {
        // Already-power-of-two products round to themselves, not up.
        assert_eq!(default_segments(4), 32);
        assert_eq!(default_segments(8), 64);
        assert_eq!(default_segments(16), 128);
        // Off-power widths round up.
        assert_eq!(default_segments(5), 64);
        assert_eq!(default_segments(9), 128);
        let mut prev = 0;
        for nthreads in 0..=64 {
            let n = default_segments(nthreads);
            assert!(n >= prev, "must be monotone in the writer count");
            prev = n;
        }
    }

    #[test]
    fn default_segments_survives_degenerate_widths() {
        // 0 and 1 take the floor rather than panicking or returning 0.
        assert_eq!(default_segments(0), 32);
        assert_eq!(default_segments(1), 32);
        // Huge widths saturate at the top power of two instead of
        // overflowing (the old `nthreads * 8` arithmetic panicked in
        // debug and wrapped in release).
        let top = 1usize << (usize::BITS - 1);
        assert_eq!(default_segments(usize::MAX), top);
        assert_eq!(default_segments(usize::MAX / 8), top);
        assert_eq!(default_segments(top), top);
    }

    #[test]
    fn single_thread_upsert_get() {
        let m: ConcurrentHashMap<String, u64> = ConcurrentHashMap::with_threads(1);
        m.upsert(0, "the".into(), 1, |a, b| *a += b);
        m.upsert(0, "the".into(), 1, |a, b| *a += b);
        m.upsert(0, "cat".into(), 1, |a, b| *a += b);
        m.sync(1, |a, b| *a += b);
        assert_eq!(m.get(&"the".to_string()), Some(2));
        assert_eq!(m.get(&"cat".to_string()), Some(1));
        assert_eq!(m.get(&"dog".to_string()), None);
        assert_eq!(m.len(), 2);
    }

    #[test]
    fn parallel_counts_match_serial() {
        // The core no-lost-updates invariant: N threads hammering a Zipfy
        // key set must produce exactly the serial counts after sync.
        let nthreads = 8;
        let keys: Vec<String> = (0..200).map(|i| format!("w{}", i % 37)).collect();
        let m: ConcurrentHashMap<String, u64> = ConcurrentHashMap::with_threads(nthreads);
        parallel_for(nthreads, 10_000, Schedule::Dynamic { chunk: 16 }, |ctx, i| {
            let k = &keys[i % keys.len()];
            m.upsert(ctx.worker, k.clone(), 1, |a, b| *a += b);
        });
        m.sync(nthreads, |a, b| *a += b);
        assert_eq!(m.pending_cache_entries(), 0);

        let mut serial: HashMap<String, u64> = HashMap::new();
        for i in 0..10_000 {
            *serial.entry(keys[i % keys.len()].clone()).or_insert(0) += 1;
        }
        assert_eq!(m.len(), serial.len());
        for (k, v) in &serial {
            assert_eq!(m.get(k), Some(*v), "key {k}");
        }
    }

    #[test]
    fn contention_spills_to_cache_and_syncs() {
        // One segment forces every concurrent writer after the first to
        // take the cache path; sync must still produce exact totals.
        let nthreads = 4;
        let m: ConcurrentHashMap<String, u64> = ConcurrentHashMap::new(1, nthreads, HashKind::Fx);
        parallel_for(nthreads, 8_000, Schedule::Dynamic { chunk: 8 }, |ctx, i| {
            m.upsert(ctx.worker, format!("k{}", i % 11), 1, |a, b| *a += b);
        });
        m.sync(nthreads, |a, b| *a += b);
        let total: u64 = m.to_vec().iter().map(|(_, v)| v).sum();
        assert_eq!(total, 8_000);
        assert_eq!(m.len(), 11);
    }

    #[test]
    fn sync_is_idempotent() {
        let m: ConcurrentHashMap<String, u64> = ConcurrentHashMap::with_threads(2);
        m.upsert(0, "a".into(), 5, |x, y| *x += y);
        m.sync(2, |a, b| *a += b);
        let before = m.to_vec();
        m.sync(2, |a, b| *a += b);
        let mut after = m.to_vec();
        let mut before = before;
        before.sort();
        after.sort();
        assert_eq!(before, after);
    }

    #[test]
    fn drain_leaves_empty() {
        let m: ConcurrentHashMap<String, u64> = ConcurrentHashMap::with_threads(2);
        for i in 0..50 {
            m.upsert(0, format!("x{i}"), 1, |a, b| *a += b);
        }
        m.sync(2, |a, b| *a += b);
        let drained = m.drain_entries();
        assert_eq!(drained.len(), 50);
        assert!(m.is_empty());
    }

    #[test]
    fn integer_keys_work() {
        let m: ConcurrentHashMap<u64, i64> = ConcurrentHashMap::with_threads(4);
        parallel_for(4, 4096, Schedule::Static, |ctx, i| {
            m.upsert(ctx.worker, (i % 64) as u64, 1i64, |a, b| *a += b);
        });
        m.sync(4, |a, b| *a += b);
        assert_eq!(m.len(), 64);
        assert_eq!(m.get(&0u64), Some(64));
    }

    #[test]
    fn policies_agree_exactly() {
        // Same stream through both cache policies => identical counts.
        let nthreads = 4;
        let keys: Vec<String> = (0..5_000).map(|i| format!("w{}", i % 61)).collect();
        let mut results = Vec::new();
        for policy in [
            CachePolicy::SpillOnContention,
            CachePolicy::CacheFirst { flush_at: 64 * 1024 },
        ] {
            let m: ConcurrentHashMap<String, u64> =
                ConcurrentHashMap::with_policy(8, nthreads, HashKind::Fx, policy);
            parallel_for(nthreads, keys.len(), Schedule::Dynamic { chunk: 7 }, |ctx, i| {
                m.upsert(ctx.worker, keys[i].clone(), 1, |a, b| *a += b);
            });
            m.sync(nthreads, |a, b| *a += b);
            let mut v = m.to_vec();
            v.sort();
            results.push(v);
        }
        assert_eq!(results[0], results[1]);
    }

    #[test]
    fn cache_first_flushes_at_threshold() {
        // Tiny flush threshold: distinct keys exceed it, forcing periodic
        // flushes into the segments mid-stream.
        let m: ConcurrentHashMap<String, u64> = ConcurrentHashMap::with_policy(
            4,
            1,
            HashKind::Fx,
            CachePolicy::CacheFirst { flush_at: 8 },
        );
        for i in 0..100 {
            m.upsert(0, format!("k{i}"), 1, |a, b| *a += b);
        }
        // Flushes already moved most entries into segments before any sync.
        assert!(m.len() >= 100 - 8, "segments hold flushed entries: {}", m.len());
        m.sync(1, |a, b| *a += b);
        assert_eq!(m.len(), 100);
        assert_eq!(m.get(&"k0".to_string()), Some(1));
    }

    #[test]
    fn cache_first_combines_hot_keys_locally() {
        // One hot key hammered: with CacheFirst the segment sees at most a
        // few flushes, and counts stay exact.
        let m: ConcurrentHashMap<String, u64> = ConcurrentHashMap::with_policy(
            4,
            4,
            HashKind::Fx,
            CachePolicy::CacheFirst { flush_at: 1024 },
        );
        parallel_for(4, 40_000, Schedule::Static, |ctx, _| {
            m.upsert(ctx.worker, "the".to_string(), 1, |a, b| *a += b);
        });
        m.sync(4, |a, b| *a += b);
        assert_eq!(m.get(&"the".to_string()), Some(40_000));
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn min_max_reducers() {
        let m: ConcurrentHashMap<String, u64> = ConcurrentHashMap::with_threads(2);
        let max = |a: &mut u64, b: u64| {
            if b > *a {
                *a = b;
            }
        };
        m.upsert(0, "m".into(), 3, max);
        m.upsert(1, "m".into(), 9, max);
        m.upsert(0, "m".into(), 5, max);
        m.sync(2, max);
        assert_eq!(m.get(&"m".to_string()), Some(9));
    }
}
