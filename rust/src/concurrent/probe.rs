//! Linear-probing open-addressing hash table — the building block of both
//! the segments and the thread caches of [`super::ConcurrentHashMap`].
//!
//! The paper's rationale (§MPI/OpenMP MapReduce Design): linear probing
//! "incurs less memory allocation and bulk memory access than chained hash
//! tables, which is the default in many STL implementations". This table
//! stores entries inline in one flat slot array, grows by doubling, and
//! never allocates per insert.
//!
//! Hashes are computed by the caller and carried with each entry, so a
//! rehash/grow never touches key bytes, and merging two tables compares
//! hashes before keys.

/// A single stored entry: precomputed hash + key + value.
#[derive(Clone, Debug)]
pub struct Entry<K, V> {
    pub hash: u64,
    pub key: K,
    pub value: V,
}

/// Open-addressing table with linear probing and power-of-two capacity.
#[derive(Clone, Debug)]
pub struct ProbeTable<K, V> {
    slots: Vec<Option<Entry<K, V>>>,
    len: usize,
    /// capacity mask (`slots.len() - 1`)
    mask: usize,
}

/// Grow when `len * 8 >= capacity * 7` would be too tight for probing;
/// we use a 70% load factor.
const LOAD_NUM: usize = 7;
const LOAD_DEN: usize = 10;

const MIN_CAP: usize = 16;

impl<K: Eq, V> ProbeTable<K, V> {
    pub fn new() -> Self {
        Self::with_capacity(MIN_CAP)
    }

    /// Capacity is rounded up to a power of two and sized so `n` entries
    /// fit under the load factor.
    pub fn with_capacity(n: usize) -> Self {
        let want = (n * LOAD_DEN / LOAD_NUM + 1).max(MIN_CAP).next_power_of_two();
        Self {
            slots: (0..want).map(|_| None).collect(),
            len: 0,
            mask: want - 1,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Bytes of slot storage (for memory accounting in benches).
    pub fn slot_bytes(&self) -> usize {
        self.slots.len() * std::mem::size_of::<Option<Entry<K, V>>>()
    }

    #[inline]
    fn start_index(&self, hash: u64) -> usize {
        // High bits are the best-mixed for multiplicative hashes; fold them
        // onto the mask.
        (hash >> 32) as usize & self.mask ^ (hash as usize & self.mask)
    }

    /// Insert `(hash, key, value)`, combining with `reduce(existing, new)`
    /// when the key is already present. Returns `true` if a new slot was
    /// filled (i.e. the key was new).
    #[inline]
    pub fn upsert(
        &mut self,
        hash: u64,
        key: K,
        value: V,
        reduce: impl FnOnce(&mut V, V),
    ) -> bool {
        if (self.len + 1) * LOAD_DEN > self.slots.len() * LOAD_NUM {
            self.grow();
        }
        let mut i = self.start_index(hash);
        loop {
            match &mut self.slots[i] {
                slot @ None => {
                    *slot = Some(Entry { hash, key, value });
                    self.len += 1;
                    return true;
                }
                Some(e) if e.hash == hash && e.key == key => {
                    reduce(&mut e.value, value);
                    return false;
                }
                _ => i = (i + 1) & self.mask,
            }
        }
    }

    /// `upsert` without materializing the key unless it is new: the caller
    /// supplies a match predicate and a key constructor. This is the
    /// zero-allocation hot path for string keys (`&str` lookup, `String`
    /// built only on first insertion) — the "Blaze TCM" variant's core trick.
    #[inline]
    pub fn upsert_with(
        &mut self,
        hash: u64,
        key_matches: impl Fn(&K) -> bool,
        make_key: impl FnOnce() -> K,
        value: V,
        reduce: impl FnOnce(&mut V, V),
    ) -> bool {
        if (self.len + 1) * LOAD_DEN > self.slots.len() * LOAD_NUM {
            self.grow();
        }
        let mut i = self.start_index(hash);
        loop {
            match &mut self.slots[i] {
                slot @ None => {
                    *slot = Some(Entry { hash, key: make_key(), value });
                    self.len += 1;
                    return true;
                }
                Some(e) if e.hash == hash && key_matches(&e.key) => {
                    reduce(&mut e.value, value);
                    return false;
                }
                _ => i = (i + 1) & self.mask,
            }
        }
    }

    /// Look up by precomputed hash + key.
    #[inline]
    pub fn get(&self, hash: u64, key: &K) -> Option<&V> {
        let mut i = self.start_index(hash);
        loop {
            match &self.slots[i] {
                None => return None,
                Some(e) if e.hash == hash && e.key == *key => return Some(&e.value),
                _ => i = (i + 1) & self.mask,
            }
        }
    }

    fn grow(&mut self) {
        let new_cap = self.slots.len() * 2;
        let old: Vec<Option<Entry<K, V>>> =
            std::mem::replace(&mut self.slots, (0..new_cap).map(|_| None).collect());
        self.mask = new_cap - 1;
        self.len = 0;
        for e in old.into_iter().flatten() {
            // Re-probe; keys are unique so plain insert (closure unreachable).
            self.upsert(e.hash, e.key, e.value, |_, _| unreachable!("dup during grow"));
        }
    }

    /// Iterate over stored entries (arbitrary order).
    pub fn iter(&self) -> impl Iterator<Item = &Entry<K, V>> {
        self.slots.iter().flatten()
    }

    /// Remove and return all entries, leaving an empty (shrunk) table.
    pub fn drain(&mut self) -> Vec<Entry<K, V>> {
        let out: Vec<Entry<K, V>> = std::mem::replace(
            &mut self.slots,
            (0..MIN_CAP).map(|_| None).collect(),
        )
        .into_iter()
        .flatten()
        .collect();
        self.mask = MIN_CAP - 1;
        self.len = 0;
        out
    }

    /// Merge another table's entries into this one.
    pub fn merge_from(&mut self, other: ProbeTable<K, V>, reduce: impl Fn(&mut V, V)) {
        for e in other.slots.into_iter().flatten() {
            self.upsert(e.hash, e.key, e.value, &reduce);
        }
    }
}

impl<K: Eq, V> Default for ProbeTable<K, V> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::fxhash;

    fn h(s: &str) -> u64 {
        fxhash(s.as_bytes())
    }

    #[test]
    fn insert_get_update() {
        let mut t: ProbeTable<String, u64> = ProbeTable::new();
        assert!(t.upsert(h("a"), "a".into(), 1, |x, y| *x += y));
        assert!(!t.upsert(h("a"), "a".into(), 2, |x, y| *x += y));
        assert!(t.upsert(h("b"), "b".into(), 5, |x, y| *x += y));
        assert_eq!(t.get(h("a"), &"a".to_string()), Some(&3));
        assert_eq!(t.get(h("b"), &"b".to_string()), Some(&5));
        assert_eq!(t.get(h("c"), &"c".to_string()), None);
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn growth_preserves_entries() {
        let mut t: ProbeTable<String, u64> = ProbeTable::with_capacity(4);
        for i in 0..10_000 {
            let k = format!("key{i}");
            t.upsert(h(&k), k, i, |_, _| panic!("no dups"));
        }
        assert_eq!(t.len(), 10_000);
        assert!(t.capacity() >= 10_000);
        for i in (0..10_000).step_by(97) {
            let k = format!("key{i}");
            assert_eq!(t.get(h(&k), &k), Some(&i));
        }
    }

    #[test]
    fn colliding_hashes_resolved_by_key() {
        // Force identical hashes: probing must still distinguish keys.
        let mut t: ProbeTable<String, u64> = ProbeTable::new();
        t.upsert(42, "x".into(), 1, |a, b| *a += b);
        t.upsert(42, "y".into(), 2, |a, b| *a += b);
        t.upsert(42, "x".into(), 10, |a, b| *a += b);
        assert_eq!(t.get(42, &"x".to_string()), Some(&11));
        assert_eq!(t.get(42, &"y".to_string()), Some(&2));
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn wraparound_probing() {
        // Hashes that all start probing at the last slot exercise the wrap.
        let mut t: ProbeTable<u64, u64> = ProbeTable::with_capacity(8);
        let cap = t.capacity() as u64;
        // start_index = (high & mask) ^ (low & mask); high = mask, low = 0
        // pins the initial probe to the LAST slot, forcing wraparound.
        let hash = (cap - 1) << 32;
        for k in 0..6u64 {
            t.upsert(hash, k, k * 100, |_, _| {});
        }
        for k in 0..6u64 {
            assert_eq!(t.get(hash, &k), Some(&(k * 100)));
        }
    }

    #[test]
    fn drain_empties_and_returns_all() {
        let mut t: ProbeTable<String, u64> = ProbeTable::new();
        for i in 0..100 {
            let k = format!("k{i}");
            t.upsert(h(&k), k, 1, |a, b| *a += b);
        }
        let drained = t.drain();
        assert_eq!(drained.len(), 100);
        assert_eq!(t.len(), 0);
        assert!(t.get(h("k0"), &"k0".to_string()).is_none());
    }

    #[test]
    fn merge_from_reduces() {
        let mut a: ProbeTable<String, u64> = ProbeTable::new();
        let mut b: ProbeTable<String, u64> = ProbeTable::new();
        a.upsert(h("w"), "w".into(), 3, |x, y| *x += y);
        b.upsert(h("w"), "w".into(), 4, |x, y| *x += y);
        b.upsert(h("z"), "z".into(), 9, |x, y| *x += y);
        a.merge_from(b, |x, y| *x += y);
        assert_eq!(a.get(h("w"), &"w".to_string()), Some(&7));
        assert_eq!(a.get(h("z"), &"z".to_string()), Some(&9));
    }

    #[test]
    fn integer_keys() {
        let mut t: ProbeTable<u64, i64> = ProbeTable::new();
        for i in 0..1000u64 {
            t.upsert(crate::hash::mix_u64(i), i, 1, |a, b| *a += b);
            t.upsert(crate::hash::mix_u64(i), i, 1, |a, b| *a += b);
        }
        assert_eq!(t.len(), 1000);
        assert_eq!(t.get(crate::hash::mix_u64(7), &7), Some(&2));
    }
}
