//! Single-node concurrent hash maps.
//!
//! [`ConcurrentHashMap`] is the paper's design (segments + thread caches,
//! never-blocking writers); [`baseline`] holds the lock-based designs it is
//! benchmarked against; [`probe::ProbeTable`] is the shared linear-probing
//! building block.

pub mod baseline;
pub mod map;
pub mod probe;

pub use baseline::{GlobalLockMap, ShardedLockMap};
pub use map::{default_segments, CachePolicy, ConcurrentHashMap, MapKey, MapStats, MapValue};
pub use probe::{Entry, ProbeTable};
