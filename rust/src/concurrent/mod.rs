//! Single-node concurrent hash maps.
//!
//! [`ConcurrentHashMap`] is the paper's design (segments + thread caches,
//! never-blocking writers); [`baseline`] holds the lock-based designs it is
//! benchmarked against; [`probe::ProbeTable`] is the shared linear-probing
//! building block.
//!
//! # Segment-count heuristic
//!
//! [`default_segments`] sizes the lock-striped segment array from the
//! **real** writer count — since the work-stealing executor landed, that
//! is the pool width ([`crate::runtime::Executor::width`]), which is what
//! the engines pass down as `nthreads`, *not* the simulated
//! `threads_per_node` cost knob. The formula is `8 × writers`, rounded up
//! to a power of two, floor 32:
//!
//! * **8×** — a writer holds a segment lock only to flush a full thread
//!   cache, but flushes from concurrent writers land on uniformly random
//!   segments; 8× oversubscription keeps the collision probability per
//!   flush under ~12% even with every writer flushing at once.
//! * **power of two** — segment selection is `hash & (nsegments - 1)`;
//!   a mask is measurably cheaper than `%` on the flush path.
//! * **floor 32** — a 1–2 thread map still gets enough segments that the
//!   shuffle's per-segment drain parallelizes downstream, and the fixed
//!   cost is trivial (a `Mutex` + `ProbeTable` header per segment).

pub mod baseline;
pub mod map;
pub mod probe;

pub use baseline::{GlobalLockMap, ShardedLockMap};
pub use map::{default_segments, CachePolicy, ConcurrentHashMap, MapKey, MapStats, MapValue};
pub use probe::{Entry, ProbeTable};
