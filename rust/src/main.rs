//! `blaze` — CLI launcher for the MapReduce reproduction.
//!
//! Subcommands:
//!
//! * `run`       — one job (`--workload
//!   wordcount|index|top-k|length-hist|join|distinct|grep|sessionize|pagerank|kmeans|components`)
//!   on a chosen engine/cluster shape; the iterative set takes
//!   `--iterations`, `--tolerance`, and `--cache-budget` (the in-memory
//!   ablation knob), the chained `sessionize` takes `--session-gap`.
//! * `plan`      — compile a workload's stage graph (stages, shuffle
//!   edges, cache points, elided exchanges) and print it without
//!   executing — the planner's ablation/debugging view.
//! * `compare`   — the paper's experiment: all engines on one corpus,
//!   printed as the words/sec bar chart.
//! * `profile`   — run one job under the structured tracer and print the
//!   per-stage phase breakdown, worker utilization, and critical path
//!   (same options as `run`).
//! * `trace-check` — validate a Chrome trace-event JSON file written by
//!   `--trace-out` and summarize its tracks.
//! * `serve`     — the multi-tenant job service: replay an arrival trace
//!   (`--script <arrivals.json>`, or a synthetic `--tenants/--jobs/--mix`
//!   schedule) through the stage-granular fair scheduler over one shared
//!   store, with per-tenant quotas and typed admission control.
//! * `generate`  — synthesize a corpus to a file.
//! * `fault`     — fault-tolerance demo (inject failures on both engines).
//! * `xla`       — run the XLA/PJRT-accelerated combiner on a corpus.
//!
//! `run` and `profile` take `--trace-out <file>` to dump the span
//! timeline as Chrome trace-event JSON (open in Perfetto or
//! `chrome://tracing`).
//!
//! `blaze <subcommand> --help` lists options.

use std::sync::Arc;

use blaze::cache::{CacheBudget, PartitionCache, PolicySpec};
use blaze::cluster::{FailurePlan, NetModel};
use blaze::corpus::{Corpus, CorpusSpec, Tokenizer};
use blaze::dist::CombineMode;
use blaze::engines::Engine;
use blaze::mapreduce::{
    run_chained, run_chained_serial, run_iterative, run_iterative_serial, run_serial,
    run_serial_inputs, ChainReport, IterativeReport, IterativeSpec, IterativeWorkload,
    JobInputs, JobSpec, StageGraph,
};
use blaze::metrics::ascii_bar_chart;
use blaze::util::cli::{Args, CliError, Command};
use blaze::wordcount::{serial_reference, WordCountJob};
use blaze::workloads::{
    synthesize_logs, synthesize_points, Components, DistinctCount, Grep, InvertedIndex, Join,
    KMeans, LengthHistogram, PageRank, Sessionize, TopKWords, WordCount,
};

/// The one `--workload` token list (`run`/`plan` help text and their
/// unknown-workload errors all reference it, so it cannot drift).
const WORKLOADS: &str =
    "wordcount|index|top-k|length-hist|join|distinct|grep|sessionize|pagerank|kmeans|components";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match argv.first().map(String::as_str) {
        Some("run") => dispatch(cmd_run(), &argv[1..], do_run),
        Some("plan") => dispatch(cmd_plan(), &argv[1..], do_plan),
        Some("compare") => dispatch(cmd_compare(), &argv[1..], do_compare),
        Some("profile") => dispatch(cmd_profile(), &argv[1..], do_profile),
        Some("trace-check") => dispatch(cmd_trace_check(), &argv[1..], do_trace_check),
        Some("serve") => dispatch(cmd_serve(), &argv[1..], do_serve),
        Some("generate") => dispatch(cmd_generate(), &argv[1..], do_generate),
        Some("fault") => dispatch(cmd_fault(), &argv[1..], do_fault),
        Some("xla") => dispatch(cmd_xla(), &argv[1..], do_xla),
        Some("--help") | Some("-h") | None => {
            print_usage();
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand: {other}\n");
            print_usage();
            2
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    println!(
        "blaze — Spark vs MPI/OpenMP word-count MapReduce (Li 2018), reproduced\n\n\
         Usage: blaze <run|plan|compare|profile|trace-check|serve|generate|fault|xla> [options]\n\
         Try `blaze run --help`."
    );
}

fn dispatch(cmd: Command, argv: &[String], f: fn(&Args) -> Result<(), String>) -> i32 {
    match cmd.parse(argv) {
        Ok(args) => match f(&args) {
            Ok(()) => 0,
            Err(e) => {
                eprintln!("error: {e}");
                1
            }
        },
        Err(CliError::HelpRequested(h)) => {
            println!("{h}");
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    }
}

fn corpus_opts(cmd: Command) -> Command {
    cmd.opt("bytes", Some("16MB"), "corpus size to generate")
        .opt("input", None, "read corpus from file instead of generating")
        .opt("vocab", Some("30000"), "generator vocabulary size")
        .opt("seed", Some("12648430"), "generator seed")
}

fn load_corpus(args: &Args) -> Result<Corpus, String> {
    load_relation(args, "input", 0)
}

/// The join's right relation: `--input-right <file>`, or generated like the
/// left one with `seed+1` so the relations overlap in keys but not lines.
fn load_right_corpus(args: &Args) -> Result<Corpus, String> {
    load_relation(args, "input-right", 1)
}

fn load_relation(args: &Args, input_opt: &str, seed_offset: u64) -> Result<Corpus, String> {
    if let Some(path) = args.get(input_opt) {
        let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
        return Ok(Corpus::from_text(&text));
    }
    let spec = CorpusSpec {
        target_bytes: args.get_bytes("bytes").map_err(|e| e.to_string())?,
        vocab_size: args.get_usize("vocab").map_err(|e| e.to_string())?,
        seed: args.get_u64("seed").map_err(|e| e.to_string())?.wrapping_add(seed_offset),
        ..Default::default()
    };
    Ok(Corpus::generate(&spec))
}

fn cluster_opts(cmd: Command) -> Command {
    cmd.opt("nodes", Some("1"), "simulated node count")
        .opt(
            "threads",
            Some("auto"),
            "real executor threads (work-stealing pool width): auto|<n>",
        )
        .opt(
            "threads-per-node",
            Some("4"),
            "simulated worker threads per node (cost model, not OS threads)",
        )
        .opt("net", Some("aws"), "network model: aws|ideal|slow")
        .opt("tokenizer", Some("paper"), "tokenizer: paper|normalized")
}

/// `--threads auto|<n>` → `None` (auto-size from the machine) or a pinned
/// pool width.
fn parse_threads(args: &Args) -> Result<Option<usize>, String> {
    let raw = args.get_str("threads");
    if raw.eq_ignore_ascii_case("auto") {
        return Ok(None);
    }
    match raw.parse::<usize>() {
        Ok(n) if n > 0 => Ok(Some(n)),
        _ => Err(format!("bad --threads {raw} (auto or a positive integer)")),
    }
}

/// The storage-hierarchy knobs (shared by `run` and `plan`).
fn spill_opts(cmd: Command) -> Command {
    cmd.opt(
        "spill-threshold",
        Some("none"),
        "bounded-memory exchange: spill sorted runs to disk beyond this many \
         in-flight bytes per reduce shard (none = unbounded memory); also \
         disk-backs the partition cache",
    )
    .opt("spill-dir", None, "directory for spill files (default: system temp)")
    .opt(
        "cache-policy",
        Some("lru"),
        "partition-cache eviction policy: lru|slru|gdsf|tinylfu[-lru|-slru|-gdsf]",
    )
    .opt(
        "compress",
        Some("on"),
        "block-compress spill runs and persisted shuffle blocks on the disk \
         tier: on|off",
    )
    .opt(
        "dict-keys",
        Some("on"),
        "dictionary-encode repeated keys in shuffle payloads and spill runs: \
         on|off",
    )
}

/// `on|off` (also `true|false`, `1|0`) → bool.
fn parse_on_off(name: &str, raw: &str) -> Result<bool, String> {
    match raw.to_ascii_lowercase().as_str() {
        "on" | "true" | "1" => Ok(true),
        "off" | "false" | "0" => Ok(false),
        _ => Err(format!("bad --{name} {raw} (on|off)")),
    }
}

/// `--cache-policy` → a [`PolicySpec`] (error text lists the menu).
fn parse_cache_policy(raw: &str) -> Result<PolicySpec, String> {
    PolicySpec::parse(raw).ok_or_else(|| {
        format!("bad --cache-policy {raw} (lru|slru|gdsf|tinylfu[-lru|-slru|-gdsf])")
    })
}

/// `none|off|unbounded|inf` → no budget; anything else parses as bytes.
fn parse_spill_threshold(raw: &str) -> Result<Option<u64>, String> {
    match raw.to_ascii_lowercase().as_str() {
        "none" | "off" | "unbounded" | "inf" => Ok(None),
        other => blaze::util::cli::parse_bytes(other)
            .map(Some)
            .ok_or_else(|| format!("bad --spill-threshold {raw}")),
    }
}

/// Apply the spill knobs onto a built spec.
fn apply_spill(mut spec: JobSpec, args: &Args) -> Result<JobSpec, String> {
    if let Some(bytes) = parse_spill_threshold(&args.get_str("spill-threshold"))? {
        spec = spec.spill_threshold(bytes);
    }
    if let Some(dir) = args.get("spill-dir") {
        spec = spec.spill_dir(std::path::PathBuf::from(dir));
    }
    spec = spec.eviction_policy(parse_cache_policy(&args.get_str("cache-policy"))?);
    spec = spec.compress(parse_on_off("compress", &args.get_str("compress"))?);
    spec = spec.dict_keys(parse_on_off("dict-keys", &args.get_str("dict-keys"))?);
    Ok(spec)
}

fn job_from_args(engine: Engine, args: &Args) -> Result<WordCountJob, String> {
    let mut job = WordCountJob::new(engine)
        .nodes(args.get_usize("nodes").map_err(|e| e.to_string())?)
        .threads_per_node(args.get_usize("threads-per-node").map_err(|e| e.to_string())?)
        .net(NetModel::parse(&args.get_str("net")).ok_or("bad --net")?)
        .tokenizer(Tokenizer::parse(&args.get_str("tokenizer")).ok_or("bad --tokenizer")?);
    if let Some(t) = parse_threads(args)? {
        job = job.threads(t);
    }
    // Spill knobs, when this subcommand defines them (`compare`/`fault`
    // don't): the wordcount facade honors the same budget as the
    // generic-workload path.
    if let Some(raw) = args.get("spill-threshold") {
        if let Some(bytes) = parse_spill_threshold(raw)? {
            job = job.spill_threshold(bytes);
        }
    }
    if let Some(dir) = args.get("spill-dir") {
        job = job.spill_dir(std::path::PathBuf::from(dir));
    }
    if let Some(raw) = args.get("compress") {
        job = job.compress(parse_on_off("compress", raw)?);
    }
    if let Some(raw) = args.get("dict-keys") {
        job = job.dict_keys(parse_on_off("dict-keys", raw)?);
    }
    Ok(job)
}

// ------------------------------------------------------------------ run ----

fn cmd_run() -> Command {
    run_opts(Command::new("run", "run one MapReduce job"))
}

/// The full `run` option set — shared with `profile`, which accepts the
/// same workloads and knobs.
fn run_opts(cmd: Command) -> Command {
    let cmd = cmd
        .opt("engine", Some("blaze-tcm"), "blaze|blaze-tcm|spark|spark-stripped")
        .opt("workload", Some("wordcount"), WORKLOADS)
        .opt("combine", Some("eager"), "map-side combine: eager|none (blaze)")
        .opt("top", Some("10"), "print the top-K entries")
        .opt("pattern", Some("the"), "grep: substring to match")
        .opt(
            "input-right",
            None,
            "join: right relation from file (default: generated, seed+1)",
        )
        .opt("session-gap", Some("1800"), "sessionize: max intra-session gap (ts units)")
        .opt("users", Some("50"), "sessionize: synthesized user count")
        .opt("events", Some("20000"), "sessionize: synthesized event count")
        .opt("iterations", Some("10"), "iterative workloads: max rounds")
        .opt(
            "tolerance",
            Some("1e-6"),
            "iterative workloads: stop once the round delta is <= this",
        )
        .opt(
            "cache-budget",
            Some("unbounded"),
            "partition cache budget: unbounded|none|<size> (none = recompute every round)",
        )
        .opt("points", Some("20000"), "kmeans: synthesized point count")
        .opt("dims", Some("4"), "kmeans: point dimensionality")
        .opt("clusters", Some("8"), "kmeans: cluster count")
        .opt(
            "trace-out",
            None,
            "write a Chrome trace-event JSON timeline (open in Perfetto or chrome://tracing)",
        )
        .flag("force-shuffle", "run the exchange even for zero-shuffle workloads")
        .flag("verify", "check against the serial reference");
    corpus_opts(cluster_opts(spill_opts(cmd)))
}

fn do_run(args: &Args) -> Result<(), String> {
    let Some(path) = args.get("trace-out").map(str::to_string) else {
        return run_workload(args);
    };
    // Tracing never alters results (probes only read clocks and append to
    // side buffers), so the traced run's output is bit-identical.
    let session = blaze::trace::TraceSession::start();
    let result = run_workload(args);
    let trace = session.finish();
    result?;
    write_trace(&path, &trace)
}

/// Write a drained trace as Chrome trace-event JSON and print a summary.
fn write_trace(path: &str, trace: &blaze::trace::Trace) -> Result<(), String> {
    blaze::trace::chrome::write_file(std::path::Path::new(path), trace)
        .map_err(|e| format!("writing {path}: {e}"))?;
    let dropped = trace.dropped();
    println!(
        "\ntrace: {} span(s) across {} thread(s) -> {path}{}",
        trace.span_count(),
        trace.threads.len(),
        if dropped > 0 {
            format!(" ({dropped} event(s) dropped at buffer capacity)")
        } else {
            String::new()
        }
    );
    Ok(())
}

/// Dispatch `--workload` to its runner (shared by `run` and `profile`).
fn run_workload(args: &Args) -> Result<(), String> {
    match args.get_str("workload").as_str() {
        "wordcount" | "wc" => do_run_wordcount(args),
        "pagerank" | "page-rank" => do_run_pagerank(args),
        "kmeans" | "k-means" => do_run_kmeans(args),
        "components" | "connected-components" => do_run_components(args),
        "sessionize" | "sessions" => do_run_sessionize(args),
        other => do_run_workload(other, args),
    }
}

/// Build the generic job spec from the shared cluster/engine options.
fn spec_from_args(args: &Args) -> Result<JobSpec, String> {
    let engine = Engine::parse(&args.get_str("engine")).ok_or("bad --engine")?;
    let combine = CombineMode::parse(&args.get_str("combine"))
        .ok_or_else(|| format!("bad --combine {}", args.get_str("combine")))?;
    let mut spec = JobSpec::new(engine)
        .nodes(args.get_usize("nodes").map_err(|e| e.to_string())?)
        .threads_per_node(args.get_usize("threads-per-node").map_err(|e| e.to_string())?)
        .net(NetModel::parse(&args.get_str("net")).ok_or("bad --net")?)
        .combine(combine)
        .force_shuffle(args.has_flag("force-shuffle"));
    if let Some(t) = parse_threads(args)? {
        spec = spec.threads(t);
    }
    apply_spill(spec, args)
}

/// One `storage:` line when anything touched a tier below memory.
fn print_storage(storage: &blaze::storage::StorageStats) {
    if !storage.is_zero() {
        println!("storage: {storage}");
    }
}

/// The non-wordcount workloads, through the generic job layer.
fn do_run_workload(name: &str, args: &Args) -> Result<(), String> {
    let spec = spec_from_args(args)?;
    let corpus = load_corpus(args)?;
    let tokenizer = Tokenizer::parse(&args.get_str("tokenizer")).ok_or("bad --tokenizer")?;
    let k = args.get_usize("top").map_err(|e| e.to_string())?;
    println!(
        "corpus: {} lines, {} ({} words)",
        corpus.num_lines(),
        blaze::util::stats::fmt_bytes(corpus.bytes),
        corpus.words
    );
    match name {
        "index" | "inverted-index" => {
            let w = Arc::new(InvertedIndex::new(tokenizer));
            let r = spec.run_str(&w, &corpus).map_err(|e| e.to_string())?;
            println!("{}", r.summary());
            println!("detail: {}", r.detail);
            print_storage(&r.storage);
            let mut terms: Vec<(&String, &Vec<u32>)> = r.output.iter().collect();
            terms.sort_by(|a, b| b.1.len().cmp(&a.1.len()).then(a.0.cmp(b.0)));
            println!("\n{} terms; {k} with the most postings:", r.output.len());
            for (term, postings) in terms.into_iter().take(k) {
                println!(
                    "  {:>8} lines  {term}  (first: {:?})",
                    postings.len(),
                    &postings[..postings.len().min(5)]
                );
            }
            verify(args, &r.output, || run_serial(w.as_ref(), &corpus))
        }
        "top-k" | "topk" => {
            let w = Arc::new(TopKWords::new(tokenizer, k));
            let r = spec.run_str(&w, &corpus).map_err(|e| e.to_string())?;
            println!("{}", r.summary());
            println!("detail: {}", r.detail);
            print_storage(&r.storage);
            println!("\ntop {k} words:");
            for (word, count) in &r.output {
                println!("  {count:>10}  {word}");
            }
            verify(args, &r.output, || run_serial(w.as_ref(), &corpus))
        }
        "length-hist" | "lengths" | "histogram" => {
            let w = Arc::new(LengthHistogram::new(tokenizer));
            // Integer keys: no borrowed-string path to exploit.
            let r = spec.run(&w, &corpus).map_err(|e| e.to_string())?;
            println!("{}", r.summary());
            println!("detail: {}", r.detail);
            print_storage(&r.storage);
            let total: u64 = r.output.iter().map(|(_, n)| n).sum();
            println!("\ntoken length histogram:");
            for (len, n) in &r.output {
                let bar = "▪".repeat((n * 40 / total.max(1)) as usize);
                println!("  {len:>3} chars: {n:>10} {bar}");
            }
            verify(args, &r.output, || run_serial(w.as_ref(), &corpus))
        }
        "join" => {
            let right = load_right_corpus(args)?;
            println!(
                "right relation: {} lines, {}",
                right.num_lines(),
                blaze::util::stats::fmt_bytes(right.bytes)
            );
            let w = Arc::new(Join::new());
            let inputs =
                JobInputs::new().relation("left", &corpus).relation("right", &right);
            let r = spec.run_inputs(&w, &inputs).map_err(|e| e.to_string())?;
            println!("{}", r.summary());
            println!("detail: {}", r.detail);
            print_storage(&r.storage);
            let pairs: u64 = r.output.values().map(|s| s.pairs()).sum();
            let mut keys: Vec<(&String, u64)> =
                r.output.iter().map(|(k, s)| (k, s.pairs())).collect();
            keys.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(b.0)));
            println!(
                "\n{} keys matched on both sides ({pairs} joined pairs); {k} widest:",
                r.output.len()
            );
            for (key, n) in keys.into_iter().take(k) {
                println!("  {n:>10} pairs  {key}");
            }
            verify(args, &r.output, || run_serial_inputs(w.as_ref(), &inputs))
        }
        "distinct" | "distinct-count" => {
            let w = Arc::new(DistinctCount::new(tokenizer));
            let r = spec.run(&w, &corpus).map_err(|e| e.to_string())?;
            println!("{}", r.summary());
            println!("detail: {}", r.detail);
            print_storage(&r.storage);
            println!(
                "\n≈ {} distinct tokens ({}-register sketch; corpus holds {} total)",
                r.output,
                blaze::workloads::REGISTERS,
                corpus.words
            );
            verify(args, &r.output, || run_serial(w.as_ref(), &corpus))
        }
        "grep" => {
            let pattern = args.get_str("pattern");
            let w = Arc::new(Grep::new(pattern.clone()));
            let r = spec.run(&w, &corpus).map_err(|e| e.to_string())?;
            println!("{}", r.summary());
            println!("detail: {}", r.detail);
            print_storage(&r.storage);
            println!(
                "\n{} lines match {pattern:?} (shuffle bytes: {} — zero-shuffle fast \
                 path unless --force-shuffle); first {k}:",
                r.output.len(),
                r.shuffle_bytes
            );
            for (doc, line) in r.output.iter().take(k) {
                println!("  {doc:>8}: {line}");
            }
            verify(args, &r.output, || run_serial(w.as_ref(), &corpus))
        }
        other => Err(format!("unknown --workload {other} ({WORKLOADS})")),
    }
}

/// Per-stage rows of a chained run — the multi-stage attribution view
/// (one renderer for CLI and benches: `benchkit::stage_table`).
fn print_chain(r: &ChainReport) {
    println!("{}", r.summary());
    println!("{}", blaze::benchkit::stage_table("stages", &r.stages).to_markdown());
    print_storage(&r.storage);
}

/// Sessionization: the two-stage chained pipeline (`--session-gap` splits
/// sessions; input synthesized from `--users`/`--events` unless `--input`
/// supplies a `user ts` log).
fn do_run_sessionize(args: &Args) -> Result<(), String> {
    let spec = spec_from_args(args)?;
    let gap = args.get_u64("session-gap").map_err(|e| e.to_string())?;
    let lines: Vec<String> = if let Some(path) = args.get("input") {
        std::fs::read_to_string(path)
            .map_err(|e| format!("reading {path}: {e}"))?
            .lines()
            .map(str::to_string)
            .collect()
    } else {
        let users = args.get_usize("users").map_err(|e| e.to_string())?;
        let events = args.get_usize("events").map_err(|e| e.to_string())?;
        if users == 0 {
            return Err("--users must be at least 1".into());
        }
        synthesize_logs(users, events, gap, args.get_u64("seed").map_err(|e| e.to_string())?)
    };
    println!("log: {} event line(s), session gap {gap}", lines.len());
    let w = Sessionize::new(gap);
    let inputs = JobInputs::new().relation_lines("logs", Arc::new(lines));
    let r = run_chained(&spec, &w, &inputs).map_err(|e| e.to_string())?;
    print_chain(&r);
    let k = args.get_usize("top").map_err(|e| e.to_string())?;
    let stats = Sessionize::stats_from_lines(&r.lines);
    let sessions: u64 = stats.iter().map(|(_, n, _)| n).sum();
    println!("\n{sessions} session(s) across {} length bucket(s); first {k}:", stats.len());
    println!("  events   sessions   total duration");
    for (events, n, dur) in stats.into_iter().take(k) {
        println!("  {events:>6} {n:>10} {dur:>16}");
    }
    if args.has_flag("verify") {
        if r.lines == run_chained_serial(&w, &inputs) {
            println!("\nverify: OK (bit-identical to the serial chained oracle)");
        } else {
            return Err("verification FAILED (lines diverge from serial oracle)".into());
        }
    }
    Ok(())
}

/// Label-propagation connected components over the corpus-as-graph (each
/// line `u v1 v2 ...` lists undirected edges), on the iterative driver.
fn do_run_components(args: &Args) -> Result<(), String> {
    let spec = spec_from_args(args)?;
    let corpus = load_corpus(args)?;
    println!(
        "graph: {} adjacency line(s), {}",
        corpus.num_lines(),
        blaze::util::stats::fmt_bytes(corpus.bytes)
    );
    let it = iterative_spec_from_args(args)?;
    let w = Components::new();
    let inputs = JobInputs::new().relation("edges", &corpus);
    let r = run_iterative(&spec, &it, &w, &inputs).map_err(|e| e.to_string())?;
    print_iterations(&r);
    let k = args.get_usize("top").map_err(|e| e.to_string())?;
    let sizes = Components::component_sizes(&r.state);
    println!("\n{} component(s); {k} largest:", sizes.len());
    for (label, n) in sizes.into_iter().take(k) {
        println!("  {n:>10} node(s)  label {label}");
    }
    verify_iterative(args, &it, &w, &inputs, &r)
}

/// Shared `--iterations`/`--tolerance`/`--cache-budget` parsing.
fn iterative_spec_from_args(args: &Args) -> Result<IterativeSpec, String> {
    let budget = args.get_str("cache-budget");
    Ok(IterativeSpec::new(args.get_usize("iterations").map_err(|e| e.to_string())?)
        .tolerance(args.get_f64("tolerance").map_err(|e| e.to_string())?)
        .cache_budget(
            CacheBudget::parse(&budget).ok_or_else(|| format!("bad --cache-budget {budget}"))?,
        ))
}

fn print_iterations(r: &IterativeReport) {
    println!("{}", r.summary());
    println!("  round      delta    wall(s)      emissions    shuffle      cache");
    for it in &r.iters {
        println!(
            "  {:>5} {:>10.3e} {:>10.3} {:>14} {:>10} {}",
            it.round,
            it.delta,
            it.wall_secs,
            it.records,
            blaze::util::stats::fmt_bytes(it.shuffle_bytes),
            it.cache,
        );
    }
    print_storage(&r.storage);
}

/// Verify an iterative run against the fixed-point serial oracle.
fn verify_iterative<I: IterativeWorkload>(
    args: &Args,
    it: &IterativeSpec,
    w: &I,
    inputs: &JobInputs,
    r: &IterativeReport,
) -> Result<(), String> {
    if args.has_flag("verify") {
        let oracle = run_iterative_serial(it, w, inputs);
        if r.state == oracle.state && r.iterations == oracle.iterations {
            println!("\nverify: OK (bit-identical to the serial fixed-point oracle)");
        } else {
            return Err("verification FAILED (state diverges from serial oracle)".into());
        }
    }
    Ok(())
}

/// PageRank over the corpus-as-graph: each line is `src dst...`.
fn do_run_pagerank(args: &Args) -> Result<(), String> {
    let spec = spec_from_args(args)?;
    let corpus = load_corpus(args)?;
    println!(
        "graph: {} adjacency line(s), {}",
        corpus.num_lines(),
        blaze::util::stats::fmt_bytes(corpus.bytes)
    );
    let it = iterative_spec_from_args(args)?;
    let w = PageRank::new();
    let inputs = JobInputs::new().relation("edges", &corpus);
    let r = run_iterative(&spec, &it, &w, &inputs).map_err(|e| e.to_string())?;
    print_iterations(&r);
    let k = args.get_usize("top").map_err(|e| e.to_string())?;
    let mut ranks = PageRank::ranks_from_state(&r.state);
    ranks.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then_with(|| a.0.cmp(&b.0)));
    println!("\n{} node(s); top {k} by rank:", ranks.len());
    for (node, rank) in ranks.into_iter().take(k) {
        println!("  {rank:>12.3e}  {node}");
    }
    verify_iterative(args, &it, &w, &inputs, &r)
}

/// k-means over synthesized fixed-point points (seeded by `--seed`).
fn do_run_kmeans(args: &Args) -> Result<(), String> {
    let spec = spec_from_args(args)?;
    let it = iterative_spec_from_args(args)?;
    let n = args.get_usize("points").map_err(|e| e.to_string())?;
    let dims = args.get_usize("dims").map_err(|e| e.to_string())?;
    let clusters = args.get_usize("clusters").map_err(|e| e.to_string())?;
    if clusters == 0 || clusters > n {
        return Err(format!("--clusters must be in 1..={n} (got {clusters})"));
    }
    if dims == 0 {
        return Err("--dims must be at least 1".into());
    }
    let seed = args.get_u64("seed").map_err(|e| e.to_string())?;
    let points = synthesize_points(n, dims, clusters, seed);
    println!("points: {n} x {dims}d around {clusters} blob(s), seed {seed}");
    let w = KMeans::new(clusters);
    let inputs = JobInputs::new().relation_lines("points", Arc::new(points));
    let r = run_iterative(&spec, &it, &w, &inputs).map_err(|e| e.to_string())?;
    print_iterations(&r);
    println!("\nfinal centroids:");
    for (cid, coords) in KMeans::centroids_from_state(&r.state) {
        println!("  {cid:>4}: {coords:?}");
    }
    verify_iterative(args, &it, &w, &inputs, &r)
}

/// `expect` is a closure so the serial reference pass only runs when the
/// user actually asked for verification.
fn verify<T: PartialEq>(args: &Args, got: &T, expect: impl FnOnce() -> T) -> Result<(), String> {
    if args.has_flag("verify") {
        if *got == expect() {
            println!("\nverify: OK (matches serial reference)");
        } else {
            return Err("verification FAILED".into());
        }
    }
    Ok(())
}

fn do_run_wordcount(args: &Args) -> Result<(), String> {
    let engine = Engine::parse(&args.get_str("engine")).ok_or("bad --engine")?;
    let corpus = load_corpus(args)?;
    let combine = match args.get_str("combine").as_str() {
        "eager" => CombineMode::Eager,
        "none" => CombineMode::None,
        other => return Err(format!("bad --combine {other}")),
    };
    let job = job_from_args(engine, args)?.combine(combine);
    println!(
        "corpus: {} lines, {} ({} words)",
        corpus.num_lines(),
        blaze::util::stats::fmt_bytes(corpus.bytes),
        corpus.words
    );
    let result = job.run(&corpus).map_err(|e| e.to_string())?;
    println!("{}", result.summary());
    println!("detail: {}", result.detail);
    print_storage(&result.storage);
    let k = args.get_usize("top").map_err(|e| e.to_string())?;
    if k > 0 {
        println!("\ntop {k} words:");
        for (w, c) in result.top_k(k) {
            println!("  {c:>10}  {w}");
        }
    }
    if args.has_flag("verify") {
        if result.counts == serial_reference(&corpus, job.tokenizer) {
            println!("\nverify: OK (matches serial reference)");
        } else {
            return Err("verification FAILED".into());
        }
    }
    Ok(())
}

// ----------------------------------------------------------------- plan ----

fn cmd_plan() -> Command {
    let cmd = Command::new(
        "plan",
        "compile a workload's stage graph and print it without executing",
    )
    .opt("engine", Some("blaze-tcm"), "blaze|blaze-tcm|spark|spark-stripped")
    .opt("workload", Some("wordcount"), WORKLOADS)
    .opt("combine", Some("eager"), "map-side combine: eager|none (blaze)")
    .opt("top", Some("10"), "top-k: K")
    .opt("pattern", Some("the"), "grep: substring to match")
    .opt("session-gap", Some("1800"), "sessionize: max intra-session gap (ts units)")
    .opt("clusters", Some("8"), "kmeans: cluster count")
    .opt(
        "cache-budget",
        Some("unbounded"),
        "iterative workloads: cache budget (none = every cache point elided)",
    )
    .opt(
        "tenant",
        None,
        "render cache-point keys in this service tenant index's namespace range",
    )
    .flag("force-shuffle", "run the exchange even for zero-shuffle workloads");
    cluster_opts(spill_opts(cmd))
}

/// Placeholder inputs carrying only relation names — all the planner
/// reads.
fn placeholder(names: &[&str]) -> JobInputs {
    let mut inputs = JobInputs::new();
    for name in names {
        inputs = inputs.relation_lines(name, Arc::new(Vec::new()));
    }
    inputs
}

/// The per-round step plan of an iterative workload, with the cache
/// points a real run would get under `--cache-budget`.
fn iterative_step_plan<I: IterativeWorkload>(
    spec: &JobSpec,
    args: &Args,
    w: &I,
    rels: &[&str],
) -> Result<StageGraph, String> {
    let budget = args.get_str("cache-budget");
    let budget =
        CacheBudget::parse(&budget).ok_or_else(|| format!("bad --cache-budget {budget}"))?;
    let policy = spec.eviction_policy.unwrap_or_default();
    let spec = spec
        .clone()
        .shared_cache(Arc::new(PartitionCache::with_policy(budget, policy)))
        .relation_gens(vec![0; rels.len()]);
    let step = w.step(&[]);
    println!("(per-round step plan; the state relation's generation bumps every round)\n");
    Ok(spec.plan_cached(step.as_ref(), &placeholder(rels)))
}

fn do_plan(args: &Args) -> Result<(), String> {
    let mut spec = spec_from_args(args)?;
    if let Some(t) = parse_tenant(args)? {
        let base = blaze::service::tenant_namespace_base(t);
        println!(
            "tenant {t}: cache-key namespaces [{base}, {}) in the shared service store\n",
            base + blaze::service::TENANT_NS_SPAN
        );
        spec = spec.namespace_base(base);
    }
    let tokenizer = Tokenizer::parse(&args.get_str("tokenizer")).ok_or("bad --tokenizer")?;
    let k = args.get_usize("top").map_err(|e| e.to_string())?;
    let name = args.get_str("workload");
    let graph = match name.as_str() {
        "wordcount" | "wc" => spec.plan(&WordCount::new(tokenizer), &placeholder(&["input"])),
        "index" | "inverted-index" => {
            spec.plan(&InvertedIndex::new(tokenizer), &placeholder(&["input"]))
        }
        "top-k" | "topk" => spec.plan(&TopKWords::new(tokenizer, k), &placeholder(&["input"])),
        "length-hist" | "lengths" | "histogram" => {
            spec.plan(&LengthHistogram::new(tokenizer), &placeholder(&["input"]))
        }
        "join" => spec.plan(&Join::new(), &placeholder(&["left", "right"])),
        "distinct" | "distinct-count" => {
            spec.plan(&DistinctCount::new(tokenizer), &placeholder(&["input"]))
        }
        "grep" => spec.plan(&Grep::new(args.get_str("pattern")), &placeholder(&["input"])),
        "sessionize" | "sessions" => {
            let gap = args.get_u64("session-gap").map_err(|e| e.to_string())?;
            spec.plan_chained(&Sessionize::new(gap), &placeholder(&["logs"]))
        }
        "pagerank" | "page-rank" => {
            iterative_step_plan(&spec, args, &PageRank::new(), &["edges", "state"])?
        }
        "kmeans" | "k-means" => {
            let clusters = args.get_usize("clusters").map_err(|e| e.to_string())?.max(1);
            iterative_step_plan(&spec, args, &KMeans::new(clusters), &["points", "state"])?
        }
        "components" | "connected-components" => {
            iterative_step_plan(&spec, args, &Components::new(), &["edges", "state"])?
        }
        other => return Err(format!("unknown --workload {other} ({WORKLOADS})")),
    };
    println!("{}", graph.render());
    Ok(())
}

// -------------------------------------------------------------- compare ----

fn cmd_compare() -> Command {
    let cmd = Command::new(
        "compare",
        "the paper's experiment: every engine on the same corpus (words/sec chart)",
    );
    corpus_opts(cluster_opts(cmd))
}

fn do_compare(args: &Args) -> Result<(), String> {
    let corpus = load_corpus(args)?;
    println!(
        "corpus: {} ({} words); cluster: {} node(s) x {} simulated thread(s), \
         net={}; executor threads: {}\n",
        blaze::util::stats::fmt_bytes(corpus.bytes),
        corpus.words,
        args.get_str("nodes"),
        args.get_str("threads-per-node"),
        args.get_str("net"),
        args.get_str("threads"),
    );
    let mut bars = Vec::new();
    for engine in [
        Engine::Spark,
        Engine::Blaze,
        Engine::BlazeTcm,
    ] {
        let job = job_from_args(engine, args)?;
        let result = job.run(&corpus).map_err(|e| e.to_string())?;
        println!("{}", result.summary());
        bars.push((engine.label().to_string(), result.words_per_sec()));
    }
    println!(
        "\n{}",
        ascii_bar_chart("Word count throughput (paper Fig. 1 shape)", &bars, "words")
    );
    let spark = bars[0].1;
    let best = bars[1..].iter().map(|(_, v)| *v).fold(0.0, f64::max);
    println!("speedup (best Blaze / Spark): {:.1}x", best / spark);
    Ok(())
}

// -------------------------------------------------------------- profile ----

fn cmd_profile() -> Command {
    run_opts(Command::new(
        "profile",
        "run one job under the tracer; print per-stage phase breakdown, \
         worker utilization, and the critical path",
    ))
    .opt(
        "script",
        None,
        "profile a service replay of this arrival trace instead of a single job",
    )
    .opt(
        "tenant",
        None,
        "keep only this tenant index's queue-wait/admission/preemption spans \
         in the breakdown and trace export",
    )
}

fn do_profile(args: &Args) -> Result<(), String> {
    let exec = blaze::runtime::executor::Executor::for_threads(parse_threads(args)?);
    let before = exec.metrics();
    let session = blaze::trace::TraceSession::start();
    let sw = blaze::util::stats::Stopwatch::start();
    let result = match args.get("script") {
        Some(path) => profile_service_replay(args, path),
        None => run_workload(args),
    };
    let wall_secs = sw.elapsed_secs();
    let mut trace = session.finish();
    result?;
    if let Some(t) = parse_tenant(args)? {
        filter_service_spans(&mut trace, t as u64);
    }
    print_profile(&trace, &exec.metrics().delta_since(&before), wall_secs);
    if let Some(path) = args.get("trace-out") {
        write_trace(path, &trace)?;
    }
    Ok(())
}

/// `--tenant <idx>` on `plan`/`profile` (absent = no tenant view).
fn parse_tenant(args: &Args) -> Result<Option<usize>, String> {
    match args.get("tenant") {
        None => Ok(None),
        Some(raw) => raw
            .parse::<usize>()
            .map(Some)
            .map_err(|_| format!("bad --tenant {raw} (a tenant index)")),
    }
}

/// Keep only `tenant`'s service-scheduling spans (queue-wait, admission,
/// preemption — their arg is the tenant index); every other span category
/// passes through untouched.
fn filter_service_spans(trace: &mut blaze::trace::Trace, tenant: u64) {
    use blaze::trace::SpanCat;
    for thread in &mut trace.threads {
        thread.spans.retain(|s| {
            !matches!(s.cat, SpanCat::QueueWait | SpanCat::Admission | SpanCat::Preemption)
                || s.arg == tenant
        });
    }
}

/// `blaze profile --script`: drive the job service from an arrival trace
/// under the tracer, so queue-wait/admission/preemption show up in the
/// phase breakdown alongside engine phases.
fn profile_service_replay(args: &Args, path: &str) -> Result<(), String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    let events = blaze::service::parse_script(&text)?;
    let mut conf = blaze::service::ServiceConf::new()
        .engine(Engine::parse(&args.get_str("engine")).ok_or("bad --engine")?);
    if let Some(t) = parse_threads(args)? {
        conf = conf.threads(t);
    }
    if let Some(bytes) = parse_spill_threshold(&args.get_str("spill-threshold"))? {
        conf = conf.spill_threshold(bytes);
    }
    if let Some(dir) = args.get("spill-dir") {
        conf = conf.spill_dir(std::path::PathBuf::from(dir));
    }
    replay_schedule(blaze::service::JobService::new(conf), &events)
}

/// The `blaze profile` tables: phase breakdown, executor utilization,
/// critical path.
fn print_profile(
    trace: &blaze::trace::Trace,
    exec: &blaze::runtime::executor::ExecMetrics,
    wall_secs: f64,
) {
    let report = blaze::trace::profile::analyze(trace);
    println!(
        "\nphase breakdown ({} span(s), {} executor task(s); busy/wall = effective parallelism):",
        trace.span_count(),
        report.tasks
    );
    println!("  {:>5}  {:<12} {:>10} {:>10} {:>8}", "stage", "phase", "wall(s)", "busy(s)", "count");
    for row in &report.rows {
        println!(
            "  {:>5}  {:<12} {:>10.4} {:>10.4} {:>8}",
            row.stage.map_or("-".to_string(), |s| s.to_string()),
            row.phase,
            row.wall_secs,
            row.busy_secs,
            row.count
        );
    }
    println!(
        "\nexecutor: {} worker(s), {:.1}% utilized over {:.3}s wall; \
         {} task(s), {} steal(s), steal imbalance {:.2}",
        exec.width,
        exec.utilization(wall_secs) * 100.0,
        wall_secs,
        exec.total_tasks(),
        exec.total_steals(),
        exec.steal_imbalance(),
    );
    if !report.critical_path.is_empty() {
        println!(
            "\ncritical path — {:.3}s of {:.3}s span wall:",
            report.critical_secs, report.span_wall_secs
        );
        for step in &report.critical_path {
            println!(
                "  stage {:>3}  {:<12} {:>10.4}s",
                step.stage.map_or("-".to_string(), |s| s.to_string()),
                step.phase,
                step.secs
            );
        }
    }
}

// ---------------------------------------------------------- trace-check ----

fn cmd_trace_check() -> Command {
    Command::new(
        "trace-check",
        "validate a Chrome trace-event JSON file written by --trace-out: \
         blaze trace-check <trace.json>",
    )
}

fn do_trace_check(args: &Args) -> Result<(), String> {
    let [path] = args.positional() else {
        return Err("usage: blaze trace-check <trace.json>".into());
    };
    let json = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    let summary = blaze::trace::chrome::validate(&json).map_err(|e| format!("{path}: {e}"))?;
    println!(
        "{path}: OK — {} event(s): {} span(s) across {} thread track(s), \
         {} counter sample(s) on {} track(s)",
        summary.events,
        summary.span_events,
        summary.span_threads,
        summary.counter_events,
        summary.counter_tracks.len(),
    );
    for (tid, name) in &summary.thread_names {
        println!("  tid {tid:>3}: {name}");
    }
    if !summary.counter_tracks.is_empty() {
        println!("  counter track(s): {}", summary.counter_tracks.join(", "));
    }
    Ok(())
}

// ---------------------------------------------------------------- serve ----

fn cmd_serve() -> Command {
    Command::new(
        "serve",
        "multi-tenant job service: replay an arrival trace (or a synthetic \
         schedule) through the fair scheduler over one shared store",
    )
    .opt(
        "script",
        None,
        "arrival trace JSON, one event per line: \
         {\"at_ms\":..,\"tenant\":..,\"workload\":..,\"bytes\":..,\"weight\":..} \
         (default: a synthetic schedule from the options below)",
    )
    .opt("tenants", Some("3"), "synthetic schedule: tenant count")
    .opt("jobs", Some("12"), "synthetic schedule: total arrivals")
    .opt(
        "mix",
        Some("grep,wordcount,pagerank"),
        "synthetic schedule: workload cycle (grep|wordcount|join|pagerank)",
    )
    .opt("gap-ms", Some("20"), "synthetic schedule: inter-arrival gap")
    .opt("bytes", Some("64KB"), "synthetic schedule: per-job corpus size")
    .opt("engine", Some("blaze-tcm"), "blaze|blaze-tcm|spark|spark-stripped")
    .opt("threads", Some("auto"), "executor threads per job: auto|<n>")
    .opt("slots", Some("2"), "concurrent stage slots the scheduler hands out")
    .opt("queue-cap", Some("32"), "max jobs in flight before admission rejects")
    .opt("policy", Some("fair"), "stage scheduling across tenants: fair|fifo")
    .opt("store-budget", Some("unbounded"), "shared store memory budget")
    .opt(
        "tenant-quota",
        Some("none"),
        "per-tenant resident-byte quota in the shared store; over-quota \
         inserts demote to disk at birth (none = unlimited)",
    )
    .opt(
        "spill-threshold",
        Some("none"),
        "bounded-memory exchange budget per job (none = unbounded)",
    )
    .opt("spill-dir", None, "spill/demotion directory (default: system temp)")
    .opt("trace-out", None, "write the service timeline as Chrome trace-event JSON")
    .flag("verify", "check every job against its serial oracle in-job")
}

fn do_serve(args: &Args) -> Result<(), String> {
    use blaze::service::{self, JobService, SchedPolicy, ServiceConf};

    let events = match args.get("script") {
        Some(path) => {
            let text =
                std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
            service::parse_script(&text)?
        }
        None => service::synthetic(
            args.get_usize("tenants").map_err(|e| e.to_string())?.max(1),
            args.get_usize("jobs").map_err(|e| e.to_string())?,
            &service::parse_mix(&args.get_str("mix"))?,
            args.get_u64("gap-ms").map_err(|e| e.to_string())?,
            args.get_bytes("bytes").map_err(|e| e.to_string())?,
            args.has_flag("verify"),
        ),
    };
    if events.is_empty() {
        return Err("empty arrival schedule".into());
    }
    let policy = SchedPolicy::parse(&args.get_str("policy"))
        .ok_or_else(|| format!("bad --policy {} (fair|fifo)", args.get_str("policy")))?;
    let budget_raw = args.get_str("store-budget");
    let mut conf = ServiceConf::new()
        .engine(Engine::parse(&args.get_str("engine")).ok_or("bad --engine")?)
        .slots(args.get_usize("slots").map_err(|e| e.to_string())?)
        .queue_cap(args.get_usize("queue-cap").map_err(|e| e.to_string())?)
        .policy(policy)
        .store_budget(
            CacheBudget::parse(&budget_raw)
                .ok_or_else(|| format!("bad --store-budget {budget_raw}"))?,
        );
    if let Some(t) = parse_threads(args)? {
        conf = conf.threads(t);
    }
    let quota_raw = args.get_str("tenant-quota");
    match quota_raw.to_ascii_lowercase().as_str() {
        "none" | "off" | "unlimited" => {}
        other => {
            let quota = blaze::util::cli::parse_bytes(other)
                .ok_or_else(|| format!("bad --tenant-quota {quota_raw}"))?;
            conf = conf.tenant_quota(quota);
        }
    }
    if let Some(bytes) = parse_spill_threshold(&args.get_str("spill-threshold"))? {
        conf = conf.spill_threshold(bytes);
    }
    if let Some(dir) = args.get("spill-dir") {
        conf = conf.spill_dir(std::path::PathBuf::from(dir));
    }

    let tenants: std::collections::BTreeSet<&str> =
        events.iter().map(|e| e.tenant.as_str()).collect();
    println!(
        "serving {} arrival(s) from {} tenant(s); policy={}, {} slot(s), queue cap {}",
        events.len(),
        tenants.len(),
        policy.name(),
        args.get_str("slots"),
        args.get_str("queue-cap"),
    );

    let session = args.get("trace-out").map(|_| blaze::trace::TraceSession::start());
    let result = replay_schedule(JobService::new(conf), &events);
    if let Some(session) = session {
        let trace = session.finish();
        if let Some(path) = args.get("trace-out") {
            write_trace(path, &trace)?;
        }
    }
    result
}

/// Replay `events` (already sorted by `at_ms`) against a running
/// service: open-loop submission on the script's clock, then drain,
/// shut down, and print the service report. Errors if any job failed.
fn replay_schedule(
    svc: blaze::service::JobService,
    events: &[blaze::service::ScriptEvent],
) -> Result<(), String> {
    use blaze::service::JobStatus;

    let start = std::time::Instant::now();
    let mut handles = Vec::new();
    for ev in events {
        let due = std::time::Duration::from_millis(ev.at_ms);
        if let Some(sleep) = due.checked_sub(start.elapsed()) {
            std::thread::sleep(sleep);
        }
        match svc.submit(ev.request()) {
            Ok(h) => handles.push(h),
            Err(e) => println!(
                "  t+{:>5}ms  {:<12} {:<9} rejected: {e}",
                ev.at_ms,
                ev.tenant,
                ev.workload.name()
            ),
        }
    }
    let mut failed = 0usize;
    for h in &handles {
        match h.wait() {
            JobStatus::Done(s) => println!(
                "  job {:>3}  {:<12} {:<9} done in {:>8.3}s (exec {:.3}s, {} record(s){})",
                h.id(),
                h.tenant(),
                h.kind().name(),
                s.latency_secs,
                s.exec_secs,
                s.records,
                if s.verified { ", verified" } else { "" },
            ),
            JobStatus::Failed(e) => {
                failed += 1;
                println!(
                    "  job {:>3}  {:<12} {:<9} FAILED: {e}",
                    h.id(),
                    h.tenant(),
                    h.kind().name()
                );
            }
            JobStatus::Cancelled => println!(
                "  job {:>3}  {:<12} {:<9} cancelled",
                h.id(),
                h.tenant(),
                h.kind().name()
            ),
            JobStatus::Queued | JobStatus::Running => unreachable!("wait() returns terminal"),
        }
    }
    let report = svc.shutdown();
    println!("\n{}", report.render());
    if failed > 0 {
        return Err(format!("{failed} job(s) failed"));
    }
    Ok(())
}

// ------------------------------------------------------------- generate ----

fn cmd_generate() -> Command {
    let cmd = Command::new("generate", "synthesize a corpus and write it to a file")
        .opt("out", Some("corpus.txt"), "output path");
    corpus_opts(cmd)
}

fn do_generate(args: &Args) -> Result<(), String> {
    let corpus = load_corpus(args)?;
    let path = args.get_str("out");
    std::fs::write(&path, corpus.to_text()).map_err(|e| e.to_string())?;
    println!(
        "wrote {} ({} lines, {} words) to {path}",
        blaze::util::stats::fmt_bytes(corpus.bytes),
        corpus.num_lines(),
        corpus.words
    );
    Ok(())
}

// ---------------------------------------------------------------- fault ----

fn cmd_fault() -> Command {
    let cmd = Command::new(
        "fault",
        "fault-injection demo: task failure on spark (lineage retry) vs node failure on blaze (job rerun)",
    );
    corpus_opts(cluster_opts(cmd))
}

fn do_fault(args: &Args) -> Result<(), String> {
    let corpus = load_corpus(args)?;
    println!("--- Spark: one map task fails; lineage retries just that task ---");
    let job = job_from_args(Engine::Spark, args)?
        .failures(FailurePlan::none().fail_task(0, 0));
    let r = job.run(&corpus).map_err(|e| e.to_string())?;
    println!("{}\ndetail: {}\n", r.summary(), r.detail);

    println!("--- Spark: executor 1's shuffle output lost; lineage recomputes lost partitions ---");
    let job = job_from_args(Engine::Spark, args)?
        .failures(FailurePlan::none().lose_executor(1));
    let r = job.run(&corpus).map_err(|e| e.to_string())?;
    println!("{}\ndetail: {}\n", r.summary(), r.detail);

    println!("--- Blaze: one node fails mid-map; no FT, whole job reruns ---");
    let job = job_from_args(Engine::BlazeTcm, args)?
        .failures(FailurePlan::none().fail_node(0, 0));
    let r = job.run(&corpus).map_err(|e| e.to_string())?;
    println!("{}\ndetail: {}", r.summary(), r.detail);
    println!(
        "\nThe paper's argument: Blaze pays the failure cost only when a failure\n\
         happens (rerun), Spark pays FT overhead on every run (persisted shuffle\n\
         blocks + lineage bookkeeping). See `cargo bench --bench ablation_fault_tolerance`."
    );
    Ok(())
}

// ------------------------------------------------------------------ xla ----

fn cmd_xla() -> Command {
    let cmd = Command::new(
        "xla",
        "count with the XLA/PJRT-accelerated combiner (AOT Pallas histogram kernel)",
    )
    .opt("top", Some("10"), "print the top-K words");
    corpus_opts(cmd)
}

fn do_xla(args: &Args) -> Result<(), String> {
    use blaze::corpus::Vocab;
    use blaze::runtime::HistogramRuntime;
    if !HistogramRuntime::available() {
        return Err("artifacts/ not built — run `make artifacts` first".into());
    }
    let corpus = load_corpus(args)?;
    let hr = HistogramRuntime::from_env().map_err(|e| format!("{e:#}"))?;
    let vocab = Vocab::from_lines(&corpus.lines);
    println!(
        "corpus: {} words, {} distinct (vocab capacity {})",
        corpus.words,
        vocab.len() - 1,
        hr.spec.vocab
    );
    let sw = blaze::util::stats::Stopwatch::start();
    let ids = vocab.encode_lines(&corpus.lines);
    let encode_secs = sw.elapsed_secs();
    let sw = blaze::util::stats::Stopwatch::start();
    let counts = hr.count_tokens(&ids).map_err(|e| format!("{e:#}"))?;
    let count_secs = sw.elapsed_secs();
    let total: u64 = counts.iter().sum();
    println!(
        "encode: {encode_secs:.3}s; xla count: {count_secs:.3}s = {}",
        blaze::util::stats::fmt_rate(total as f64 / count_secs, "tokens")
    );
    let k = args.get_usize("top").map_err(|e| e.to_string())?;
    let mut ranked: Vec<(usize, u64)> = counts.iter().copied().enumerate().collect();
    ranked.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    println!("\ntop {k} (id 0 = OOV beyond vocab capacity):");
    for (id, c) in ranked.into_iter().take(k) {
        let word = if id < vocab.len() { vocab.word_of(id as i32) } else { "?" };
        println!("  {c:>10}  {word}");
    }
    Ok(())
}
