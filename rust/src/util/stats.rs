//! Timing and summary statistics used by the metrics layer, the bench
//! harness, and the engines' phase breakdowns.

use std::time::{Duration, Instant};

/// A simple wall-clock stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Online summary of a stream of f64 samples (Welford's algorithm) plus the
/// raw samples for exact percentiles — our sample counts are small (bench
/// repetitions, phase timings), so keeping them is fine.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    mean: f64,
    m2: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
        let n = self.samples.len() as f64;
        let delta = x - self.mean;
        self.mean += delta / n;
        self.m2 += delta * (x - self.mean);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            f64::NAN
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.samples.len() < 2 {
            0.0
        } else {
            self.m2 / (self.samples.len() as f64 - 1.0)
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Percentile with linear interpolation between closest ranks
    /// (the "exclusive" convention numpy's default matches).
    pub fn percentile(&self, p: f64) -> f64 {
        assert!((0.0..=100.0).contains(&p));
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pos = (p / 100.0) * (sorted.len() as f64 - 1.0);
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi.min(sorted.len() - 1)] * frac
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    /// Median absolute deviation — the bench harness reports median±MAD,
    /// which is robust to the occasional slow outlier rep.
    pub fn mad(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let med = self.median();
        let mut devs: Vec<f64> = self.samples.iter().map(|x| (x - med).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        devs[(devs.len() - 1) / 2]
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

/// Pretty-print a byte count ("2.0 GB").
pub fn fmt_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Pretty-print a rate ("12.3 Mwords/s").
pub fn fmt_rate(per_sec: f64, unit: &str) -> String {
    if per_sec >= 1e9 {
        format!("{:.2} G{unit}/s", per_sec / 1e9)
    } else if per_sec >= 1e6 {
        format!("{:.2} M{unit}/s", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:.2} k{unit}/s", per_sec / 1e3)
    } else {
        format!("{per_sec:.2} {unit}/s")
    }
}

/// Pretty-print a duration with sensible units.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_mean_stddev() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // Sample stddev of this classic set is ~2.138.
        assert!((s.stddev() - 2.13809).abs() < 1e-4);
        assert_eq!(s.count(), 8);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn summary_percentiles() {
        let mut s = Summary::new();
        for x in 1..=100 {
            s.add(x as f64);
        }
        assert_eq!(s.median(), 50.5); // interpolated midpoint of 1..=100
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert!((s.percentile(90.0) - 90.1).abs() < 1e-9);
    }

    #[test]
    fn summary_mad_robust_to_outlier() {
        let mut s = Summary::new();
        for x in [10.0, 10.0, 10.0, 10.0, 1000.0] {
            s.add(x);
        }
        assert_eq!(s.median(), 10.0);
        assert_eq!(s.mad(), 0.0);
    }

    #[test]
    fn empty_summary_is_nan() {
        let s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.percentile(50.0).is_nan());
    }

    #[test]
    fn formatters() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2 * 1024 * 1024), "2.00 MB");
        assert_eq!(fmt_rate(12_300_000.0, "words"), "12.30 Mwords/s");
        assert_eq!(fmt_rate(450.0, "req"), "450.00 req/s");
        assert_eq!(fmt_duration(Duration::from_millis(12)), "12.000 ms");
    }

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        let a = sw.elapsed();
        let b = sw.elapsed();
        assert!(b >= a);
    }
}
