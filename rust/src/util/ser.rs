//! Binary serialization framework (the offline crate set has no `serde`).
//!
//! Two jobs:
//!
//! 1. **Real wire format** for the simulated cluster: shuffle payloads and
//!    control messages are encoded with [`Encode`]/[`Decode`] before they
//!    cross a simulated node boundary, so "bytes on the network" is a real,
//!    measured quantity (the paper's local-reduce claim is about exactly
//!    this number).
//! 2. **Cost carrier** for the Spark-sim: Spark serializes records at every
//!    shuffle boundary (and that cost is one of the paper's three explanations
//!    for the gap). The Spark engine routes all inter-stage data through this
//!    module; the `ablation_serialization` bench toggles it.
//!
//! Format: little-endian fixed-width integers, varint-free (simple and fast);
//! strings and vectors are length-prefixed with u32.

use std::collections::HashMap;

/// Serialize into a byte buffer.
pub trait Encode {
    fn encode(&self, out: &mut Vec<u8>);

    fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode(&mut out);
        out
    }
}

/// Deserialize from a byte slice via a cursor.
pub trait Decode: Sized {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError>;

    fn from_bytes(bytes: &[u8]) -> Result<Self, DecodeError> {
        let mut r = Reader::new(bytes);
        let v = Self::decode(&mut r)?;
        if !r.is_empty() {
            return Err(DecodeError::TrailingBytes(r.remaining()));
        }
        Ok(v)
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DecodeError {
    /// Needed more bytes than remained in the buffer.
    Truncated { need: usize, have: usize },
    /// A length prefix exceeded a sanity bound.
    LengthOverflow(u64),
    /// String payload was not valid UTF-8.
    Utf8,
    /// Unknown enum discriminant.
    BadTag(u8),
    /// Bytes left over after a full decode.
    TrailingBytes(usize),
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::Truncated { need, have } => {
                write!(f, "truncated input: need {need} bytes, have {have}")
            }
            DecodeError::LengthOverflow(n) => write!(f, "length prefix too large: {n}"),
            DecodeError::Utf8 => write!(f, "invalid utf-8 in string payload"),
            DecodeError::BadTag(t) => write!(f, "unknown discriminant {t}"),
            DecodeError::TrailingBytes(n) => write!(f, "{n} trailing bytes after decode"),
        }
    }
}

impl std::error::Error for DecodeError {}

/// Decode cursor over a byte slice.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8], DecodeError> {
        if self.remaining() < n {
            return Err(DecodeError::Truncated { need: n, have: self.remaining() });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
}

macro_rules! impl_int {
    ($($t:ty),*) => {$(
        impl Encode for $t {
            #[inline]
            fn encode(&self, out: &mut Vec<u8>) {
                out.extend_from_slice(&self.to_le_bytes());
            }
        }
        impl Decode for $t {
            #[inline]
            fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
                let n = std::mem::size_of::<$t>();
                let b = r.take(n)?;
                Ok(<$t>::from_le_bytes(b.try_into().unwrap()))
            }
        }
    )*};
}

impl_int!(u8, u16, u32, u64, i8, i16, i32, i64, f32, f64);

impl Encode for usize {
    #[inline]
    fn encode(&self, out: &mut Vec<u8>) {
        (*self as u64).encode(out);
    }
}

impl Decode for usize {
    #[inline]
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        Ok(u64::decode(r)? as usize)
    }
}

impl Encode for bool {
    fn encode(&self, out: &mut Vec<u8>) {
        out.push(u8::from(*self));
    }
}

impl Decode for bool {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        match u8::decode(r)? {
            0 => Ok(false),
            1 => Ok(true),
            t => Err(DecodeError::BadTag(t)),
        }
    }
}

/// Sanity cap on decoded lengths (1 GiB): corrupt prefixes fail fast instead
/// of OOM-ing the process.
const MAX_LEN: u64 = 1 << 30;

fn encode_len(len: usize, out: &mut Vec<u8>) {
    (len as u32).encode(out)
}

fn decode_len(r: &mut Reader<'_>) -> Result<usize, DecodeError> {
    let n = u32::decode(r)? as u64;
    if n > MAX_LEN {
        return Err(DecodeError::LengthOverflow(n));
    }
    Ok(n as usize)
}

impl Encode for str {
    fn encode(&self, out: &mut Vec<u8>) {
        encode_len(self.len(), out);
        out.extend_from_slice(self.as_bytes());
    }
}

impl Encode for String {
    fn encode(&self, out: &mut Vec<u8>) {
        self.as_str().encode(out);
    }
}

impl Decode for String {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        let n = decode_len(r)?;
        let b = r.take(n)?;
        std::str::from_utf8(b).map(str::to_owned).map_err(|_| DecodeError::Utf8)
    }
}

impl<T: Encode> Encode for Vec<T> {
    fn encode(&self, out: &mut Vec<u8>) {
        encode_len(self.len(), out);
        for item in self {
            item.encode(out);
        }
    }
}

impl<T: Decode> Decode for Vec<T> {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        let n = decode_len(r)?;
        // Reserve conservatively: a corrupt length can still claim up to
        // MAX_LEN items; cap the pre-allocation by remaining bytes.
        let cap = n.min(r.remaining().max(1));
        let mut v = Vec::with_capacity(cap);
        for _ in 0..n {
            v.push(T::decode(r)?);
        }
        Ok(v)
    }
}

impl<A: Encode, B: Encode> Encode for (A, B) {
    fn encode(&self, out: &mut Vec<u8>) {
        self.0.encode(out);
        self.1.encode(out);
    }
}

impl<A: Decode, B: Decode> Decode for (A, B) {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        Ok((A::decode(r)?, B::decode(r)?))
    }
}

impl<A: Encode, B: Encode, C: Encode> Encode for (A, B, C) {
    fn encode(&self, out: &mut Vec<u8>) {
        self.0.encode(out);
        self.1.encode(out);
        self.2.encode(out);
    }
}

impl<A: Decode, B: Decode, C: Decode> Decode for (A, B, C) {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        Ok((A::decode(r)?, B::decode(r)?, C::decode(r)?))
    }
}

impl<T: Encode> Encode for Option<T> {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            None => out.push(0),
            Some(v) => {
                out.push(1);
                v.encode(out);
            }
        }
    }
}

impl<T: Decode> Decode for Option<T> {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        match u8::decode(r)? {
            0 => Ok(None),
            1 => Ok(Some(T::decode(r)?)),
            t => Err(DecodeError::BadTag(t)),
        }
    }
}

impl<K: Encode, V: Encode> Encode for HashMap<K, V> {
    fn encode(&self, out: &mut Vec<u8>) {
        encode_len(self.len(), out);
        for (k, v) in self {
            k.encode(out);
            v.encode(out);
        }
    }
}

impl<K: Decode + std::hash::Hash + Eq, V: Decode> Decode for HashMap<K, V> {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        let n = decode_len(r)?;
        let mut m = HashMap::with_capacity(n.min(r.remaining().max(1)));
        for _ in 0..n {
            let k = K::decode(r)?;
            let v = V::decode(r)?;
            m.insert(k, v);
        }
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip<T: Encode + Decode + PartialEq + std::fmt::Debug>(v: T) {
        let bytes = v.to_bytes();
        let back = T::from_bytes(&bytes).expect("decode");
        assert_eq!(v, back);
    }

    #[test]
    fn ints_roundtrip() {
        roundtrip(0u8);
        roundtrip(255u8);
        roundtrip(u16::MAX);
        roundtrip(123456789u32);
        roundtrip(u64::MAX);
        roundtrip(-1i64);
        roundtrip(i32::MIN);
        roundtrip(3.14159f64);
        roundtrip(true);
        roundtrip(false);
    }

    #[test]
    fn strings_roundtrip() {
        roundtrip(String::new());
        roundtrip("hello".to_string());
        roundtrip("héllo — 你好 🎉".to_string());
    }

    #[test]
    fn containers_roundtrip() {
        roundtrip(vec![1u32, 2, 3]);
        roundtrip(Vec::<u64>::new());
        roundtrip(("key".to_string(), 42u64));
        roundtrip((1u8, "x".to_string(), -9i64));
        roundtrip(Some(7u32));
        roundtrip(Option::<String>::None);
        roundtrip(vec![("a".to_string(), 1u64), ("b".to_string(), 2u64)]);
    }

    #[test]
    fn hashmap_roundtrip() {
        let mut m = HashMap::new();
        m.insert("alpha".to_string(), 10u64);
        m.insert("beta".to_string(), 20u64);
        roundtrip(m);
    }

    #[test]
    fn truncated_fails() {
        let bytes = 12345u64.to_bytes();
        assert!(matches!(
            u64::from_bytes(&bytes[..4]),
            Err(DecodeError::Truncated { .. })
        ));
    }

    #[test]
    fn trailing_bytes_fail() {
        let mut bytes = 1u32.to_bytes();
        bytes.push(0xFF);
        assert!(matches!(u32::from_bytes(&bytes), Err(DecodeError::TrailingBytes(1))));
    }

    #[test]
    fn corrupt_length_fails_fast() {
        // A string claiming 2^31 bytes with a 2-byte payload.
        let mut bytes = Vec::new();
        (0x8000_0000u32).encode(&mut bytes);
        bytes.extend_from_slice(b"ab");
        assert!(matches!(
            String::from_bytes(&bytes),
            Err(DecodeError::LengthOverflow(_))
        ));
    }

    #[test]
    fn bad_utf8_fails() {
        let mut bytes = Vec::new();
        encode_len(2, &mut bytes);
        bytes.extend_from_slice(&[0xFF, 0xFE]);
        assert_eq!(String::from_bytes(&bytes), Err(DecodeError::Utf8));
    }

    #[test]
    fn bad_option_tag_fails() {
        assert!(matches!(
            Option::<u8>::from_bytes(&[7]),
            Err(DecodeError::BadTag(7))
        ));
    }
}
