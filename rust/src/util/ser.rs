//! Binary serialization framework (the offline crate set has no `serde`).
//!
//! Two jobs:
//!
//! 1. **Real wire format** for the simulated cluster: shuffle payloads and
//!    control messages are encoded with [`Encode`]/[`Decode`] before they
//!    cross a simulated node boundary, so "bytes on the network" is a real,
//!    measured quantity (the paper's local-reduce claim is about exactly
//!    this number).
//! 2. **Cost carrier** for the Spark-sim: Spark serializes records at every
//!    shuffle boundary (and that cost is one of the paper's three explanations
//!    for the gap). The Spark engine routes all inter-stage data through this
//!    module; the `ablation_serialization` bench toggles it.
//!
//! Format: little-endian fixed-width integers, varint-free (simple and fast);
//! strings and vectors are length-prefixed with u32.

use std::collections::HashMap;

use crate::util::arena::{ArenaMark, StrArena, StrRef};

/// Serialize into a byte buffer.
pub trait Encode {
    fn encode(&self, out: &mut Vec<u8>);

    fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode(&mut out);
        out
    }
}

/// Deserialize from a byte slice via a cursor.
pub trait Decode: Sized {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError>;

    fn from_bytes(bytes: &[u8]) -> Result<Self, DecodeError> {
        let mut r = Reader::new(bytes);
        let v = Self::decode(&mut r)?;
        if !r.is_empty() {
            return Err(DecodeError::TrailingBytes(r.remaining()));
        }
        Ok(v)
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DecodeError {
    /// Needed more bytes than remained in the buffer.
    Truncated { need: usize, have: usize },
    /// A length prefix exceeded a sanity bound.
    LengthOverflow(u64),
    /// String payload was not valid UTF-8.
    Utf8,
    /// Unknown enum discriminant.
    BadTag(u8),
    /// Bytes left over after a full decode.
    TrailingBytes(usize),
    /// A dictionary back-reference named an id the stream never defined.
    BadDictId(u64),
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::Truncated { need, have } => {
                write!(f, "truncated input: need {need} bytes, have {have}")
            }
            DecodeError::LengthOverflow(n) => write!(f, "length prefix too large: {n}"),
            DecodeError::Utf8 => write!(f, "invalid utf-8 in string payload"),
            DecodeError::BadTag(t) => write!(f, "unknown discriminant {t}"),
            DecodeError::TrailingBytes(n) => write!(f, "{n} trailing bytes after decode"),
            DecodeError::BadDictId(id) => write!(f, "undefined dictionary id {id}"),
        }
    }
}

impl std::error::Error for DecodeError {}

/// Decode cursor over a byte slice.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8], DecodeError> {
        if self.remaining() < n {
            return Err(DecodeError::Truncated { need: n, have: self.remaining() });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
}

macro_rules! impl_int {
    ($($t:ty),*) => {$(
        impl Encode for $t {
            #[inline]
            fn encode(&self, out: &mut Vec<u8>) {
                out.extend_from_slice(&self.to_le_bytes());
            }
        }
        impl Decode for $t {
            #[inline]
            fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
                let n = std::mem::size_of::<$t>();
                let b = r.take(n)?;
                Ok(<$t>::from_le_bytes(b.try_into().unwrap()))
            }
        }
    )*};
}

impl_int!(u8, u16, u32, u64, i8, i16, i32, i64, f32, f64);

impl Encode for usize {
    #[inline]
    fn encode(&self, out: &mut Vec<u8>) {
        (*self as u64).encode(out);
    }
}

impl Decode for usize {
    #[inline]
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        Ok(u64::decode(r)? as usize)
    }
}

impl Encode for bool {
    fn encode(&self, out: &mut Vec<u8>) {
        out.push(u8::from(*self));
    }
}

impl Decode for bool {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        match u8::decode(r)? {
            0 => Ok(false),
            1 => Ok(true),
            t => Err(DecodeError::BadTag(t)),
        }
    }
}

/// Sanity cap on decoded lengths (1 GiB): corrupt prefixes fail fast instead
/// of OOM-ing the process.
const MAX_LEN: u64 = 1 << 30;

fn encode_len(len: usize, out: &mut Vec<u8>) {
    (len as u32).encode(out)
}

fn decode_len(r: &mut Reader<'_>) -> Result<usize, DecodeError> {
    let n = u32::decode(r)? as u64;
    if n > MAX_LEN {
        return Err(DecodeError::LengthOverflow(n));
    }
    Ok(n as usize)
}

impl Encode for str {
    fn encode(&self, out: &mut Vec<u8>) {
        encode_len(self.len(), out);
        out.extend_from_slice(self.as_bytes());
    }
}

impl Encode for String {
    fn encode(&self, out: &mut Vec<u8>) {
        self.as_str().encode(out);
    }
}

impl Decode for String {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        let n = decode_len(r)?;
        let b = r.take(n)?;
        std::str::from_utf8(b).map(str::to_owned).map_err(|_| DecodeError::Utf8)
    }
}

impl<T: Encode> Encode for Vec<T> {
    fn encode(&self, out: &mut Vec<u8>) {
        encode_len(self.len(), out);
        for item in self {
            item.encode(out);
        }
    }
}

impl<T: Decode> Decode for Vec<T> {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        let n = decode_len(r)?;
        // Reserve conservatively: a corrupt length can still claim up to
        // MAX_LEN items; cap the pre-allocation by remaining bytes.
        let cap = n.min(r.remaining().max(1));
        let mut v = Vec::with_capacity(cap);
        for _ in 0..n {
            v.push(T::decode(r)?);
        }
        Ok(v)
    }
}

impl<A: Encode, B: Encode> Encode for (A, B) {
    fn encode(&self, out: &mut Vec<u8>) {
        self.0.encode(out);
        self.1.encode(out);
    }
}

impl<A: Decode, B: Decode> Decode for (A, B) {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        Ok((A::decode(r)?, B::decode(r)?))
    }
}

impl<A: Encode, B: Encode, C: Encode> Encode for (A, B, C) {
    fn encode(&self, out: &mut Vec<u8>) {
        self.0.encode(out);
        self.1.encode(out);
        self.2.encode(out);
    }
}

impl<A: Decode, B: Decode, C: Decode> Decode for (A, B, C) {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        Ok((A::decode(r)?, B::decode(r)?, C::decode(r)?))
    }
}

impl<T: Encode> Encode for Option<T> {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            None => out.push(0),
            Some(v) => {
                out.push(1);
                v.encode(out);
            }
        }
    }
}

impl<T: Decode> Decode for Option<T> {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        match u8::decode(r)? {
            0 => Ok(None),
            1 => Ok(Some(T::decode(r)?)),
            t => Err(DecodeError::BadTag(t)),
        }
    }
}

impl<K: Encode, V: Encode> Encode for HashMap<K, V> {
    fn encode(&self, out: &mut Vec<u8>) {
        encode_len(self.len(), out);
        for (k, v) in self {
            k.encode(out);
            v.encode(out);
        }
    }
}

impl<K: Decode + std::hash::Hash + Eq, V: Decode> Decode for HashMap<K, V> {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        let n = decode_len(r)?;
        let mut m = HashMap::with_capacity(n.min(r.remaining().max(1)));
        for _ in 0..n {
            let k = K::decode(r)?;
            let v = V::decode(r)?;
            m.insert(k, v);
        }
        Ok(m)
    }
}

// ---------------------------------------------------------------------------
// Varints + the per-run key dictionary (PR 9's wire-format layer).
// ---------------------------------------------------------------------------

/// LEB128 unsigned varint — the dictionary wire format's integer shape
/// (ids and counts are small and skewed, exactly what varints are for).
pub fn encode_varint(mut v: u64, out: &mut Vec<u8>) {
    loop {
        let byte = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// Decode a LEB128 varint. Rejects encodings longer than 10 bytes or
/// overflowing 64 bits.
pub fn decode_varint(r: &mut Reader<'_>) -> Result<u64, DecodeError> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let b = u8::decode(r)?;
        if shift == 63 && b > 1 {
            return Err(DecodeError::LengthOverflow(u64::MAX));
        }
        v |= u64::from(b & 0x7F) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
        if shift > 63 {
            return Err(DecodeError::LengthOverflow(u64::MAX));
        }
    }
}

/// What a [`DictWriter`] saved: unique entries vs back-references, and
/// key bytes as-written vs what plain (undictionaried) encoding would
/// have cost. `key_enc_bytes / key_raw_bytes` is the key-stream ratio
/// reported in `StageStats`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DictStats {
    /// Distinct strings written inline (dictionary insertions).
    pub unique: u64,
    /// Keys emitted as back-references to an earlier entry.
    pub refs: u64,
    /// Key bytes a plain encoding would have written (4-byte length
    /// prefix + payload per occurrence) — the *logical* key volume.
    pub key_raw_bytes: u64,
    /// Key bytes actually written (tags + inline entries + references).
    pub key_enc_bytes: u64,
}

impl DictStats {
    pub fn is_zero(&self) -> bool {
        *self == DictStats::default()
    }

    /// Field-wise sum — aggregate per-run dictionaries into a stage view.
    pub fn merged(&self, other: &DictStats) -> DictStats {
        DictStats {
            unique: self.unique + other.unique,
            refs: self.refs + other.refs,
            key_raw_bytes: self.key_raw_bytes + other.key_raw_bytes,
            key_enc_bytes: self.key_enc_bytes + other.key_enc_bytes,
        }
    }
}

/// Write side of the per-run string dictionary.
///
/// Wire format, self-describing (the reader needs no knob): each key is
/// a varint *tag*. Tag `0` introduces a new entry — `[varint len][bytes]`
/// — which implicitly receives the next 1-based id. Tag `n > 0` is a
/// back-reference to entry `n`. A disabled writer (`--dict-keys off`)
/// simply always emits tag-0 inline entries and registers nothing, so
/// the same reader decodes both streams.
pub struct DictWriter {
    ids: HashMap<String, u64>,
    enabled: bool,
    stats: DictStats,
}

impl DictWriter {
    pub fn new(enabled: bool) -> Self {
        Self { ids: HashMap::new(), enabled, stats: DictStats::default() }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn stats(&self) -> DictStats {
        self.stats
    }

    /// Encode one string key occurrence.
    pub fn encode_str(&mut self, s: &str, out: &mut Vec<u8>) {
        let before = out.len();
        match self.ids.get(s) {
            Some(&id) => {
                encode_varint(id, out);
                self.stats.refs += 1;
            }
            None => {
                if self.enabled {
                    let id = self.ids.len() as u64 + 1;
                    self.ids.insert(s.to_owned(), id);
                }
                encode_varint(0, out);
                encode_varint(s.len() as u64, out);
                out.extend_from_slice(s.as_bytes());
                self.stats.unique += 1;
            }
        }
        self.stats.key_raw_bytes += 4 + s.len() as u64;
        self.stats.key_enc_bytes += (out.len() - before) as u64;
    }
}

/// Read side of the dictionary: interns every inline entry into a
/// [`StrArena`] and resolves back-references to the same [`StrRef`] — so
/// a run's repeated keys decode to *one* arena string and the hot path
/// hands out 8-byte handles instead of fresh `String`s (the zero-copy
/// decode layer).
#[derive(Default)]
pub struct DictReader {
    arena: StrArena,
    ids: Vec<StrRef>,
}

impl DictReader {
    pub fn new() -> Self {
        Self::default()
    }

    /// Decode one key occurrence (inline entry or back-reference).
    pub fn decode_str(&mut self, r: &mut Reader<'_>) -> Result<StrRef, DecodeError> {
        let tag = decode_varint(r)?;
        if tag == 0 {
            let len = decode_varint(r)?;
            if len > MAX_LEN {
                return Err(DecodeError::LengthOverflow(len));
            }
            let bytes = r.take(len as usize)?;
            let s = std::str::from_utf8(bytes).map_err(|_| DecodeError::Utf8)?;
            let sref = self.arena.intern(s);
            self.ids.push(sref);
            Ok(sref)
        } else {
            let idx = (tag - 1) as usize;
            self.ids.get(idx).copied().ok_or(DecodeError::BadDictId(tag))
        }
    }

    /// Intern a string that did *not* come off the wire (e.g. the
    /// merger's in-memory remainder joining disk runs in one loser
    /// tree). Does not register a wire id.
    pub fn intern(&mut self, s: &str) -> StrRef {
        self.arena.intern(s)
    }

    /// Resolve a handle produced by this reader.
    pub fn get(&self, r: StrRef) -> &str {
        self.arena.get(r)
    }

    /// Bytes held by the arena (decoded key payloads).
    pub fn bytes_used(&self) -> usize {
        self.arena.bytes_used()
    }

    /// Checkpoint before decoding a record from a possibly-short buffer;
    /// [`DictReader::rollback`] after a `Truncated` error un-registers
    /// anything the failed attempt interned, so the retry (with more
    /// bytes) doesn't define duplicate ids.
    pub fn checkpoint(&self) -> DictCheckpoint {
        DictCheckpoint { ids: self.ids.len(), arena: self.arena.mark() }
    }

    pub fn rollback(&mut self, cp: DictCheckpoint) {
        self.ids.truncate(cp.ids);
        self.arena.truncate(cp.arena);
    }
}

/// Rollback point for [`DictReader::checkpoint`].
#[derive(Clone, Copy, Debug)]
pub struct DictCheckpoint {
    ids: usize,
    arena: ArenaMark,
}

/// Keys that can travel the dictionary-encoded, zero-copy data path.
///
/// The contract that keeps every engine bit-identical to the oracle:
///
/// * `dict_encode` → `dict_decode` round-trips through a fresh
///   writer/reader pair processing the same occurrence sequence.
/// * [`DataKey::ref_hash`] **must** equal
///   [`MapKey::hash_with`](crate::concurrent::MapKey::hash_with) on the
///   materialized key — shard routing and segment choice are computed on
///   both forms.
/// * `ref_cmp` must order refs exactly as `Ord` orders materialized keys
///   (the loser-tree merge compares refs across runs).
///
/// String keys get the real dictionary + arena treatment; integer keys
/// are their own ref (already cheap); composite/odd keys can fall back
/// to `Ref = Self`.
pub trait DataKey: Sized + Eq + std::hash::Hash {
    /// Borrowed/handle form a decoded key takes before (if ever) being
    /// materialized. `Copy` keeps merge heads and map probes allocation-free.
    type Ref: Copy;

    /// Encode one occurrence of `self` through the run dictionary.
    fn dict_encode(&self, dict: &mut DictWriter, out: &mut Vec<u8>);

    /// Decode one occurrence into a handle tied to `dict`.
    fn dict_decode(r: &mut Reader<'_>, dict: &mut DictReader) -> Result<Self::Ref, DecodeError>;

    /// Convert an owned key into a handle in `dict` (for merging owned
    /// in-memory data with decoded runs under one comparator).
    fn ref_from_owned(this: Self, dict: &mut DictReader) -> Self::Ref;

    /// Order two handles, possibly from different runs' dictionaries.
    fn ref_cmp(a: &Self::Ref, da: &DictReader, b: &Self::Ref, db: &DictReader)
        -> std::cmp::Ordering;

    /// Clone a handle back into an owned key.
    fn ref_materialize(r: &Self::Ref, dict: &DictReader) -> Self;

    /// Does this handle denote the same key as `owned`?
    fn ref_eq_owned(r: &Self::Ref, dict: &DictReader, owned: &Self) -> bool;

    /// Hash of the denoted key — must equal `MapKey::hash_with` on the
    /// materialized key (routing happens on both forms).
    fn ref_hash(r: &Self::Ref, dict: &DictReader, kind: crate::hash::HashKind) -> u64;

    /// Borrowed-key map probe: look up `r` in an owned-key map without
    /// materializing (the zero-copy combine hot path).
    fn map_get_mut<'m, V>(
        map: &'m mut HashMap<Self, V>,
        r: &Self::Ref,
        dict: &DictReader,
    ) -> Option<&'m mut V>;
}

impl DataKey for String {
    type Ref = StrRef;

    fn dict_encode(&self, dict: &mut DictWriter, out: &mut Vec<u8>) {
        dict.encode_str(self, out);
    }

    fn dict_decode(r: &mut Reader<'_>, dict: &mut DictReader) -> Result<Self::Ref, DecodeError> {
        dict.decode_str(r)
    }

    fn ref_from_owned(this: Self, dict: &mut DictReader) -> Self::Ref {
        dict.intern(&this)
    }

    fn ref_cmp(
        a: &Self::Ref,
        da: &DictReader,
        b: &Self::Ref,
        db: &DictReader,
    ) -> std::cmp::Ordering {
        da.get(*a).cmp(db.get(*b))
    }

    fn ref_materialize(r: &Self::Ref, dict: &DictReader) -> Self {
        dict.get(*r).to_owned()
    }

    fn ref_eq_owned(r: &Self::Ref, dict: &DictReader, owned: &Self) -> bool {
        dict.get(*r) == owned
    }

    fn ref_hash(r: &Self::Ref, dict: &DictReader, kind: crate::hash::HashKind) -> u64 {
        kind.hash(dict.get(*r).as_bytes())
    }

    fn map_get_mut<'m, V>(
        map: &'m mut HashMap<Self, V>,
        r: &Self::Ref,
        dict: &DictReader,
    ) -> Option<&'m mut V> {
        map.get_mut(dict.get(*r))
    }
}

macro_rules! impl_datakey_int {
    ($($t:ty),*) => {$(
        impl DataKey for $t {
            type Ref = $t;

            fn dict_encode(&self, _dict: &mut DictWriter, out: &mut Vec<u8>) {
                self.encode(out);
            }

            fn dict_decode(
                r: &mut Reader<'_>,
                _dict: &mut DictReader,
            ) -> Result<Self::Ref, DecodeError> {
                <$t>::decode(r)
            }

            fn ref_from_owned(this: Self, _dict: &mut DictReader) -> Self::Ref {
                this
            }

            fn ref_cmp(
                a: &Self::Ref,
                _da: &DictReader,
                b: &Self::Ref,
                _db: &DictReader,
            ) -> std::cmp::Ordering {
                a.cmp(b)
            }

            fn ref_materialize(r: &Self::Ref, _dict: &DictReader) -> Self {
                *r
            }

            fn ref_eq_owned(r: &Self::Ref, _dict: &DictReader, owned: &Self) -> bool {
                r == owned
            }

            fn ref_hash(r: &Self::Ref, _dict: &DictReader, kind: crate::hash::HashKind) -> u64 {
                crate::concurrent::MapKey::hash_with(r, kind)
            }

            fn map_get_mut<'m, V>(
                map: &'m mut HashMap<Self, V>,
                r: &Self::Ref,
                _dict: &DictReader,
            ) -> Option<&'m mut V> {
                map.get_mut(r)
            }
        }
    )*};
}

impl_datakey_int!(u32, u64, i64);

/// Encode a `(K, V)` batch for the wire: varint pair count, then
/// `key (dictionary) · value (plain)` per pair. Returns the bytes and
/// the dictionary's savings stats. The batch is its own dictionary
/// scope — decode with a fresh [`DictReader`] (or [`decode_pairs`]).
pub fn encode_pairs<K: DataKey, V: Encode>(
    pairs: &[(K, V)],
    dict_keys: bool,
) -> (Vec<u8>, DictStats) {
    let mut dict = DictWriter::new(dict_keys);
    let mut out = Vec::new();
    encode_varint(pairs.len() as u64, &mut out);
    for (k, v) in pairs {
        k.dict_encode(&mut dict, &mut out);
        v.encode(&mut out);
    }
    (out, dict.stats())
}

/// Decode an [`encode_pairs`] payload into owned pairs. The streaming
/// consumers (shuffle read, external merge) decode incrementally against
/// a live [`DictReader`] instead; this is the whole-buffer convenience.
pub fn decode_pairs<K: DataKey, V: Decode>(bytes: &[u8]) -> Result<Vec<(K, V)>, DecodeError> {
    let mut r = Reader::new(bytes);
    let mut dict = DictReader::new();
    let n = decode_varint(&mut r)? as usize;
    let mut out = Vec::with_capacity(n.min(r.remaining().max(1)));
    for _ in 0..n {
        let kr = K::dict_decode(&mut r, &mut dict)?;
        let v = V::decode(&mut r)?;
        out.push((K::ref_materialize(&kr, &dict), v));
    }
    if !r.is_empty() {
        return Err(DecodeError::TrailingBytes(r.remaining()));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip<T: Encode + Decode + PartialEq + std::fmt::Debug>(v: T) {
        let bytes = v.to_bytes();
        let back = T::from_bytes(&bytes).expect("decode");
        assert_eq!(v, back);
    }

    #[test]
    fn ints_roundtrip() {
        roundtrip(0u8);
        roundtrip(255u8);
        roundtrip(u16::MAX);
        roundtrip(123456789u32);
        roundtrip(u64::MAX);
        roundtrip(-1i64);
        roundtrip(i32::MIN);
        roundtrip(3.14159f64);
        roundtrip(true);
        roundtrip(false);
    }

    #[test]
    fn strings_roundtrip() {
        roundtrip(String::new());
        roundtrip("hello".to_string());
        roundtrip("héllo — 你好 🎉".to_string());
    }

    #[test]
    fn containers_roundtrip() {
        roundtrip(vec![1u32, 2, 3]);
        roundtrip(Vec::<u64>::new());
        roundtrip(("key".to_string(), 42u64));
        roundtrip((1u8, "x".to_string(), -9i64));
        roundtrip(Some(7u32));
        roundtrip(Option::<String>::None);
        roundtrip(vec![("a".to_string(), 1u64), ("b".to_string(), 2u64)]);
    }

    #[test]
    fn hashmap_roundtrip() {
        let mut m = HashMap::new();
        m.insert("alpha".to_string(), 10u64);
        m.insert("beta".to_string(), 20u64);
        roundtrip(m);
    }

    #[test]
    fn truncated_fails() {
        let bytes = 12345u64.to_bytes();
        assert!(matches!(
            u64::from_bytes(&bytes[..4]),
            Err(DecodeError::Truncated { .. })
        ));
    }

    #[test]
    fn trailing_bytes_fail() {
        let mut bytes = 1u32.to_bytes();
        bytes.push(0xFF);
        assert!(matches!(u32::from_bytes(&bytes), Err(DecodeError::TrailingBytes(1))));
    }

    #[test]
    fn corrupt_length_fails_fast() {
        // A string claiming 2^31 bytes with a 2-byte payload.
        let mut bytes = Vec::new();
        (0x8000_0000u32).encode(&mut bytes);
        bytes.extend_from_slice(b"ab");
        assert!(matches!(
            String::from_bytes(&bytes),
            Err(DecodeError::LengthOverflow(_))
        ));
    }

    #[test]
    fn bad_utf8_fails() {
        let mut bytes = Vec::new();
        encode_len(2, &mut bytes);
        bytes.extend_from_slice(&[0xFF, 0xFE]);
        assert_eq!(String::from_bytes(&bytes), Err(DecodeError::Utf8));
    }

    #[test]
    fn bad_option_tag_fails() {
        assert!(matches!(
            Option::<u8>::from_bytes(&[7]),
            Err(DecodeError::BadTag(7))
        ));
    }

    #[test]
    fn varint_roundtrips() {
        for v in [0u64, 1, 127, 128, 300, 16383, 16384, u32::MAX as u64, u64::MAX] {
            let mut out = Vec::new();
            encode_varint(v, &mut out);
            let mut r = Reader::new(&out);
            assert_eq!(decode_varint(&mut r).unwrap(), v);
            assert!(r.is_empty());
        }
        // Single-byte values stay single-byte.
        let mut out = Vec::new();
        encode_varint(42, &mut out);
        assert_eq!(out, [42]);
    }

    #[test]
    fn varint_rejects_overlong_and_truncated() {
        // 11 continuation bytes: > 64 bits of payload.
        let overlong = [0xFFu8; 11];
        assert!(matches!(
            decode_varint(&mut Reader::new(&overlong)),
            Err(DecodeError::LengthOverflow(_))
        ));
        // Continuation bit set, then nothing.
        assert!(matches!(
            decode_varint(&mut Reader::new(&[0x80])),
            Err(DecodeError::Truncated { .. })
        ));
    }

    #[test]
    fn dict_roundtrip_shrinks_repeated_keys() {
        let keys = ["the", "quick", "the", "the", "fox", "quick", "the"];
        let mut dict = DictWriter::new(true);
        let mut out = Vec::new();
        for k in keys {
            dict.encode_str(k, &mut out);
        }
        let stats = dict.stats();
        assert_eq!(stats.unique, 3);
        assert_eq!(stats.refs, 4);
        assert!(stats.key_enc_bytes < stats.key_raw_bytes, "{stats:?}");

        let mut reader = DictReader::new();
        let mut r = Reader::new(&out);
        let refs: Vec<StrRef> =
            keys.iter().map(|_| reader.decode_str(&mut r).unwrap()).collect();
        assert!(r.is_empty());
        for (k, sref) in keys.iter().zip(&refs) {
            assert_eq!(reader.get(*sref), *k);
        }
        // Repeats resolve to the same arena handle (zero-copy).
        assert_eq!(refs[0], refs[2]);
        assert_eq!(refs[0], refs[3]);
        assert_eq!(reader.bytes_used(), "thequickfox".len());
    }

    #[test]
    fn disabled_writer_streams_decode_identically() {
        let keys = ["a", "b", "a"];
        let mut dict = DictWriter::new(false);
        let mut out = Vec::new();
        for k in keys {
            dict.encode_str(k, &mut out);
        }
        assert_eq!(dict.stats().refs, 0);
        assert_eq!(dict.stats().unique, 3);
        let mut reader = DictReader::new();
        let mut r = Reader::new(&out);
        for k in keys {
            let sref = reader.decode_str(&mut r).unwrap();
            assert_eq!(reader.get(sref), k);
        }
    }

    #[test]
    fn dict_checkpoint_rollback_prevents_double_registration() {
        let mut dict = DictWriter::new(true);
        let mut out = Vec::new();
        dict.encode_str("alpha", &mut out);
        dict.encode_str("beta", &mut out);
        dict.encode_str("alpha", &mut out); // back-ref to id 1

        let mut reader = DictReader::new();
        let mut r = Reader::new(&out[..1]); // truncated mid-entry
        let cp = reader.checkpoint();
        assert!(reader.decode_str(&mut r).is_err());
        reader.rollback(cp);

        // Retry with the full buffer: ids must line up.
        let mut r = Reader::new(&out);
        let a = reader.decode_str(&mut r).unwrap();
        let b = reader.decode_str(&mut r).unwrap();
        let a2 = reader.decode_str(&mut r).unwrap();
        assert_eq!(reader.get(a), "alpha");
        assert_eq!(reader.get(b), "beta");
        assert_eq!(a, a2);
    }

    #[test]
    fn bad_dict_id_fails() {
        // Back-reference to id 9 in an empty dictionary.
        let mut out = Vec::new();
        encode_varint(9, &mut out);
        let mut reader = DictReader::new();
        assert_eq!(
            reader.decode_str(&mut Reader::new(&out)),
            Err(DecodeError::BadDictId(9))
        );
    }

    #[test]
    fn encode_pairs_roundtrips_string_and_int_keys() {
        let pairs: Vec<(String, u64)> = vec![
            ("word".into(), 1),
            ("count".into(), 2),
            ("word".into(), 3),
        ];
        for dict_on in [true, false] {
            let (bytes, stats) = encode_pairs(&pairs, dict_on);
            let back: Vec<(String, u64)> = decode_pairs(&bytes).unwrap();
            assert_eq!(back, pairs);
            assert_eq!(stats.refs > 0, dict_on);
        }

        let ints: Vec<(u64, i64)> = vec![(7, -1), (8, 2)];
        let (bytes, stats) = encode_pairs(&ints, true);
        assert_eq!(decode_pairs::<u64, i64>(&bytes).unwrap(), ints);
        // Integer keys bypass the dictionary entirely.
        assert!(stats.is_zero());
    }

    #[test]
    fn ref_hash_matches_mapkey_hash() {
        use crate::concurrent::MapKey;
        use crate::hash::HashKind;
        let mut dict = DictReader::new();
        for kind in [HashKind::Fx, HashKind::Fnv1a] {
            let s = "consistency".to_string();
            let sref = String::ref_from_owned(s.clone(), &mut dict);
            assert_eq!(String::ref_hash(&sref, &dict, kind), s.hash_with(kind));
            let n = 0xDEAD_BEEFu64;
            let nref = u64::ref_from_owned(n, &mut dict);
            assert_eq!(u64::ref_hash(&nref, &dict, kind), n.hash_with(kind));
        }
    }

    #[test]
    fn datakey_map_probe_and_cmp() {
        let mut dict = DictReader::new();
        let mut m: HashMap<String, u64> = HashMap::new();
        m.insert("hit".into(), 10);
        let hit = String::ref_from_owned("hit".into(), &mut dict);
        let miss = String::ref_from_owned("miss".into(), &mut dict);
        *String::map_get_mut(&mut m, &hit, &dict).unwrap() += 5;
        assert_eq!(m["hit"], 15);
        assert!(String::map_get_mut(&mut m, &miss, &dict).is_none());
        assert!(String::ref_eq_owned(&hit, &dict, &"hit".to_string()));
        assert!(!String::ref_eq_owned(&hit, &dict, &"miss".to_string()));
        assert_eq!(
            String::ref_cmp(&hit, &dict, &miss, &dict),
            "hit".cmp("miss")
        );
        assert_eq!(String::ref_materialize(&hit, &dict), "hit");
    }
}
