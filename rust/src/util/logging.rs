//! Leveled logger with per-component prefixes.
//!
//! Controlled by the `BLAZE_LOG` env var (`error|warn|info|debug|trace`,
//! default `info`). Cheap when disabled: level check is one atomic load.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn from_str(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // u8::MAX = uninitialized

fn init_from_env() -> u8 {
    let lvl = std::env::var("BLAZE_LOG")
        .ok()
        .and_then(|s| Level::from_str(&s))
        .unwrap_or(Level::Info) as u8;
    MAX_LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Current max level, initializing from the environment on first use.
#[inline]
pub fn max_level() -> Level {
    let raw = MAX_LEVEL.load(Ordering::Relaxed);
    let raw = if raw == u8::MAX { init_from_env() } else { raw };
    // Safety: raw is always a valid Level discriminant after init.
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the level programmatically (tests, benches).
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

#[inline]
pub fn enabled(level: Level) -> bool {
    level <= max_level()
}

/// Emit a log record. Use through the `log_*!` macros.
pub fn emit(level: Level, component: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let stderr = std::io::stderr();
    let mut w = stderr.lock();
    let _ = writeln!(w, "[{} {component}] {msg}", level.tag());
}

#[macro_export]
macro_rules! log_error {
    ($component:expr, $($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Error, $component, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($component:expr, $($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Warn, $component, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($component:expr, $($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Info, $component, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($component:expr, $($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Debug, $component, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_trace {
    ($component:expr, $($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Trace, $component, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::from_str("error"), Some(Level::Error));
        assert_eq!(Level::from_str("WARN"), Some(Level::Warn));
        assert_eq!(Level::from_str("Info"), Some(Level::Info));
        assert_eq!(Level::from_str("bogus"), None);
    }

    #[test]
    fn level_ordering_gates() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Trace));
        set_level(Level::Info);
    }

    #[test]
    fn emit_does_not_panic() {
        set_level(Level::Trace);
        emit(Level::Info, "test", format_args!("hello {}", 42));
        set_level(Level::Info);
    }
}
