//! Minimal property-based testing harness (the offline crate set has no
//! `proptest`/`quickcheck`).
//!
//! Design: a [`Gen`] wraps a seeded [`Xoshiro256`] and produces random values
//! through combinator functions; [`check`] runs a property over N generated
//! cases and, on failure, retries with a bounded greedy **shrink** loop
//! (halving sizes / simplifying elements) before reporting the seed and the
//! minimal counterexample found. Failures always print the case seed so the
//! exact case can be replayed with [`check_seeded`].

use super::rng::Xoshiro256;

/// Random value source handed to generators and properties.
pub struct Gen {
    rng: Xoshiro256,
    /// Size hint: generators should produce structures ~this large.
    pub size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Self {
        Self { rng: Xoshiro256::new(seed), size }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn below(&mut self, bound: u64) -> u64 {
        self.rng.next_below(bound)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.next_below((hi - lo + 1) as u64) as usize
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        self.rng.range_i64(lo, hi)
    }

    pub fn f64_unit(&mut self) -> f64 {
        self.rng.next_f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.index(xs.len())]
    }

    /// Vector of random length in `[0, size]` built by `f`.
    pub fn vec_of<T>(&mut self, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = self.usize_in(0, self.size);
        (0..n).map(|_| f(self)).collect()
    }

    /// Lowercase ASCII word of length in `[1, max_len]` — the shape of a
    /// word-count key.
    pub fn word(&mut self, max_len: usize) -> String {
        let n = self.usize_in(1, max_len.max(1));
        (0..n)
            .map(|_| (b'a' + self.below(26) as u8) as char)
            .collect()
    }

    /// A "text line": words joined by single spaces, occasionally empty.
    pub fn line(&mut self, max_words: usize) -> String {
        let n = self.usize_in(0, max_words);
        (0..n).map(|_| self.word(8)).collect::<Vec<_>>().join(" ")
    }
}

/// Outcome of a property over one case.
pub type PropResult = Result<(), String>;

/// Convenience: build a failing result.
pub fn fail(msg: impl Into<String>) -> PropResult {
    Err(msg.into())
}

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub size: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // Fixed default seed: deterministic CI. Override via BLAZE_PROP_SEED.
        let seed = std::env::var("BLAZE_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xB1A2_E000);
        Self { cases: 64, size: 32, seed }
    }
}

/// Run `prop` over `config.cases` generated cases. The property receives a
/// fresh seeded `Gen` per case. Panics with seed + message on failure, after
/// trying smaller sizes for a more readable counterexample.
pub fn check_with(config: Config, name: &str, prop: impl Fn(&mut Gen) -> PropResult) {
    for case in 0..config.cases {
        let case_seed = config.seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen::new(case_seed, config.size);
        if let Err(msg) = prop(&mut g) {
            // Shrink: re-run the same seed at smaller sizes; report the
            // smallest size that still fails.
            let mut min_fail: Option<(usize, String)> = Some((config.size, msg));
            let mut sz = config.size;
            while sz > 1 {
                sz /= 2;
                let mut g = Gen::new(case_seed, sz);
                if let Err(m) = prop(&mut g) {
                    min_fail = Some((sz, m));
                } else {
                    break;
                }
            }
            let (size, msg) = min_fail.unwrap();
            panic!(
                "property '{name}' failed (case {case}, seed {case_seed:#x}, size {size}): {msg}\n\
                 reproduce with check_seeded({case_seed:#x}, {size}, ...)"
            );
        }
    }
}

/// Run with default config.
pub fn check(name: &str, prop: impl Fn(&mut Gen) -> PropResult) {
    check_with(Config::default(), name, prop);
}

/// Replay a single case (from a failure report).
pub fn check_seeded(seed: u64, size: usize, prop: impl Fn(&mut Gen) -> PropResult) {
    let mut g = Gen::new(seed, size);
    if let Err(msg) = prop(&mut g) {
        panic!("seeded property case failed (seed {seed:#x}, size {size}): {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("reverse-twice-is-identity", |g| {
            let v = g.vec_of(|g| g.u64());
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            if v == w {
                Ok(())
            } else {
                fail("reverse twice changed the vector")
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports_seed() {
        check("always-fails", |_g| fail("nope"));
    }

    #[test]
    fn generators_respect_bounds() {
        check("bounds", |g| {
            let a = g.usize_in(3, 9);
            if !(3..=9).contains(&a) {
                return fail(format!("usize_in out of range: {a}"));
            }
            let b = g.i64_in(-5, 5);
            if !(-5..=5).contains(&b) {
                return fail(format!("i64_in out of range: {b}"));
            }
            let w = g.word(6);
            if w.is_empty() || w.len() > 6 || !w.bytes().all(|c| c.is_ascii_lowercase()) {
                return fail(format!("bad word: {w:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn determinism_same_seed_same_values() {
        let mut g1 = Gen::new(99, 16);
        let mut g2 = Gen::new(99, 16);
        for _ in 0..100 {
            assert_eq!(g1.u64(), g2.u64());
        }
    }

    #[test]
    fn lines_tokenize_like_words() {
        check("line-shape", |g| {
            let line = g.line(10);
            for w in line.split(' ').filter(|w| !w.is_empty()) {
                if !w.bytes().all(|c| c.is_ascii_lowercase()) {
                    return fail(format!("bad token {w:?}"));
                }
            }
            Ok(())
        });
    }
}
