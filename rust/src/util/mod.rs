//! Hand-built substrate utilities.
//!
//! The build environment is fully offline, so everything a crates.io
//! dependency would normally provide is implemented here: PRNG (`rng`),
//! thread pool (`pool`), binary serialization (`ser`), CLI parsing (`cli`),
//! arena allocation (`arena`), statistics (`stats`), logging (`logging`),
//! and a property-testing harness (`proptest`).

pub mod arena;
pub mod cli;
pub mod logging;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod ser;
pub mod stats;
