//! An OpenMP-style thread pool.
//!
//! The paper's map phase is "OpenMP threads pulling indices from a range";
//! this module provides that shape natively: a pool of long-lived workers and
//! a `parallel_for` with OpenMP's three classic schedule kinds:
//!
//! * [`Schedule::Static`] — range pre-split into `nthreads` contiguous
//!   chunks (lowest overhead, best locality, worst load balance).
//! * [`Schedule::Dynamic`] — workers claim fixed-size chunks from a shared
//!   atomic cursor (best balance, one CAS per chunk).
//! * [`Schedule::Guided`] — chunk size decays with the remaining range
//!   (balance of the two).
//!
//! Closures run with a `WorkerCtx` carrying the worker id, so callers can
//! keep per-thread state (the ConcurrentHashMap thread caches key off it).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// OpenMP-style loop schedule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    Static,
    Dynamic { chunk: usize },
    Guided { min_chunk: usize },
}

impl Default for Schedule {
    fn default() -> Self {
        // Dynamic with a modest chunk is the safest default for skewed
        // work-per-item (exactly the word-count case: line lengths vary).
        Schedule::Dynamic { chunk: 64 }
    }
}

/// Context handed to each parallel-for body invocation.
#[derive(Clone, Copy, Debug)]
pub struct WorkerCtx {
    /// Worker index in `[0, nthreads)`.
    pub worker: usize,
    /// Total number of workers executing the loop.
    pub nthreads: usize,
}

/// Number of worker threads to use when the caller does not specify:
/// the machine's available parallelism.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Run `body(ctx, i)` for every `i` in `[0, n)` across `nthreads` scoped
/// threads using the given schedule. Panics in the body are propagated to
/// the caller after all workers stop.
///
/// This uses `std::thread::scope`, so `body` may borrow from the caller's
/// stack — the same ergonomics as an OpenMP `parallel for`.
pub fn parallel_for<F>(nthreads: usize, n: usize, schedule: Schedule, body: F)
where
    F: Fn(WorkerCtx, usize) + Sync,
{
    parallel_for_range(nthreads, 0, n, schedule, body)
}

/// `parallel_for` over an explicit `[start, end)` range.
pub fn parallel_for_range<F>(nthreads: usize, start: usize, end: usize, schedule: Schedule, body: F)
where
    F: Fn(WorkerCtx, usize) + Sync,
{
    assert!(nthreads > 0, "parallel_for: need at least one thread");
    let n = end.saturating_sub(start);
    if n == 0 {
        return;
    }
    if nthreads == 1 {
        let ctx = WorkerCtx { worker: 0, nthreads: 1 };
        for i in start..end {
            body(ctx, i);
        }
        return;
    }

    let panicked: Arc<AtomicUsize> = Arc::new(AtomicUsize::new(0));
    let cursor = AtomicUsize::new(start);
    let body = &body;

    std::thread::scope(|scope| {
        for worker in 0..nthreads {
            let panicked = Arc::clone(&panicked);
            let cursor = &cursor;
            scope.spawn(move || {
                let ctx = WorkerCtx { worker, nthreads };
                let run = AssertUnwindSafe(|| match schedule {
                    Schedule::Static => {
                        // Contiguous block assignment, remainder spread over
                        // the first `n % nthreads` workers.
                        let base = n / nthreads;
                        let rem = n % nthreads;
                        let lo = start + worker * base + worker.min(rem);
                        let hi = lo + base + usize::from(worker < rem);
                        for i in lo..hi {
                            body(ctx, i);
                        }
                    }
                    Schedule::Dynamic { chunk } => {
                        let chunk = chunk.max(1);
                        loop {
                            let lo = cursor.fetch_add(chunk, Ordering::Relaxed);
                            if lo >= end {
                                break;
                            }
                            let hi = (lo + chunk).min(end);
                            for i in lo..hi {
                                body(ctx, i);
                            }
                        }
                    }
                    Schedule::Guided { min_chunk } => {
                        let min_chunk = min_chunk.max(1);
                        loop {
                            // Claim ~remaining/(2*nthreads), floored.
                            let lo = cursor.load(Ordering::Relaxed);
                            if lo >= end {
                                break;
                            }
                            let remaining = end - lo;
                            let want = (remaining / (2 * nthreads)).max(min_chunk);
                            let lo = cursor.fetch_add(want, Ordering::Relaxed);
                            if lo >= end {
                                break;
                            }
                            let hi = (lo + want).min(end);
                            for i in lo..hi {
                                body(ctx, i);
                            }
                        }
                    }
                });
                if catch_unwind(run).is_err() {
                    panicked.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
    });

    let n_panics = panicked.load(Ordering::Relaxed);
    if n_panics > 0 {
        panic!("parallel_for: {n_panics} worker(s) panicked");
    }
}

/// Fork–join: run `nthreads` copies of `body(ctx)` (an OpenMP `parallel`
/// region without the loop). Used by the engines for per-thread pipelines.
pub fn parallel_region<F>(nthreads: usize, body: F)
where
    F: Fn(WorkerCtx) + Sync,
{
    assert!(nthreads > 0);
    if nthreads == 1 {
        body(WorkerCtx { worker: 0, nthreads: 1 });
        return;
    }
    let panicked = AtomicUsize::new(0);
    let body = &body;
    std::thread::scope(|scope| {
        for worker in 0..nthreads {
            let panicked = &panicked;
            scope.spawn(move || {
                let ctx = WorkerCtx { worker, nthreads };
                if catch_unwind(AssertUnwindSafe(|| body(ctx))).is_err() {
                    panicked.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
    });
    if panicked.load(Ordering::Relaxed) > 0 {
        panic!("parallel_region: worker(s) panicked");
    }
}

/// Parallel map: apply `f` to every element of `items`, preserving order.
pub fn parallel_map<T, U, F>(nthreads: usize, items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send + Default + Clone,
    F: Fn(WorkerCtx, &T) -> U + Sync,
{
    let mut out = vec![U::default(); items.len()];
    {
        let slots: Vec<std::sync::Mutex<&mut U>> =
            out.iter_mut().map(std::sync::Mutex::new).collect();
        parallel_for(nthreads, items.len(), Schedule::default(), |ctx, i| {
            let v = f(ctx, &items[i]);
            **slots[i].lock().unwrap() = v;
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    fn coverage_test(schedule: Schedule, nthreads: usize, n: usize) {
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for(nthreads, n, schedule, |_ctx, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "index {i} hit count");
        }
    }

    #[test]
    fn static_covers_each_index_once() {
        for &(t, n) in &[(1, 10), (3, 10), (4, 4), (8, 3), (4, 1000), (7, 1001)] {
            coverage_test(Schedule::Static, t, n);
        }
    }

    #[test]
    fn dynamic_covers_each_index_once() {
        for &(t, n) in &[(1, 10), (3, 100), (8, 1000), (4, 1)] {
            coverage_test(Schedule::Dynamic { chunk: 7 }, t, n);
        }
    }

    #[test]
    fn guided_covers_each_index_once() {
        for &(t, n) in &[(2, 50), (4, 1000), (8, 12345)] {
            coverage_test(Schedule::Guided { min_chunk: 4 }, t, n);
        }
    }

    #[test]
    fn empty_range_is_noop() {
        parallel_for(4, 0, Schedule::Static, |_, _| panic!("must not run"));
    }

    #[test]
    fn range_offsets_respected() {
        let sum = AtomicU64::new(0);
        parallel_for_range(3, 10, 20, Schedule::Dynamic { chunk: 2 }, |_, i| {
            assert!((10..20).contains(&i));
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), (10..20).sum::<usize>() as u64);
    }

    #[test]
    fn worker_ids_are_in_range() {
        parallel_for(4, 100, Schedule::Dynamic { chunk: 1 }, |ctx, _| {
            assert!(ctx.worker < ctx.nthreads);
            assert_eq!(ctx.nthreads, 4);
        });
    }

    #[test]
    fn parallel_region_runs_every_worker() {
        let hits: Vec<AtomicU64> = (0..6).map(|_| AtomicU64::new(0)).collect();
        parallel_region(6, |ctx| {
            hits[ctx.worker].fetch_add(1, Ordering::Relaxed);
        });
        for h in &hits {
            assert_eq!(h.load(Ordering::Relaxed), 1);
        }
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<u64> = (0..500).collect();
        let out = parallel_map(4, &items, |_ctx, &x| x * 2);
        assert_eq!(out, (0..500).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "worker(s) panicked")]
    fn body_panic_propagates() {
        parallel_for(4, 100, Schedule::Dynamic { chunk: 1 }, |_, i| {
            if i == 57 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn borrows_caller_stack() {
        let data = vec![1u64; 256];
        let sum = AtomicU64::new(0);
        parallel_for(4, data.len(), Schedule::Static, |_, i| {
            sum.fetch_add(data[i], Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 256);
    }
}
