//! Minimal command-line argument parser (the offline crate set has no
//! `clap`). Supports subcommands, `--flag`, `--key value`, `--key=value`,
//! and generated `--help` text.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Declarative description of one option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// A parsed argument set.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    UnknownOption(String),
    MissingValue(String),
    BadValue { key: String, value: String, expect: &'static str },
    HelpRequested(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::UnknownOption(o) => write!(f, "unknown option: {o}"),
            CliError::MissingValue(o) => write!(f, "option {o} requires a value"),
            CliError::BadValue { key, value, expect } => {
                write!(f, "bad value for --{key}: {value:?} (expected {expect})")
            }
            CliError::HelpRequested(h) => write!(f, "{h}"),
        }
    }
}

impl std::error::Error for CliError {}

/// A command with a fixed option table.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, opts: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: Option<&'static str>, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.name, self.about);
        let _ = writeln!(s, "\nOptions:");
        for o in &self.opts {
            let head = if o.is_flag {
                format!("  --{}", o.name)
            } else {
                format!("  --{} <value>", o.name)
            };
            let default = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            let _ = writeln!(s, "{head:<28} {}{}", o.help, default);
        }
        s
    }

    /// Parse `argv` (not including the program/subcommand name).
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        // Seed defaults.
        for o in &self.opts {
            if let Some(d) = o.default {
                args.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(CliError::HelpRequested(self.help_text()));
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (rest, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| CliError::UnknownOption(a.clone()))?;
                if spec.is_flag {
                    args.flags.push(key.to_string());
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError::MissingValue(a.clone()))?
                        }
                    };
                    args.values.insert(key.to_string(), val);
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(String::as_str)
    }

    pub fn get_str(&self, key: &str) -> String {
        self.values.get(key).cloned().unwrap_or_default()
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn get_usize(&self, key: &str) -> Result<usize, CliError> {
        self.parse_with(key, "integer", |s| s.parse::<usize>().ok())
    }

    pub fn get_u64(&self, key: &str) -> Result<u64, CliError> {
        self.parse_with(key, "integer", |s| s.parse::<u64>().ok())
    }

    pub fn get_f64(&self, key: &str) -> Result<f64, CliError> {
        self.parse_with(key, "float", |s| s.parse::<f64>().ok())
    }

    /// Parse sizes like `64MB`, `2GB`, `4096`, `512kb`.
    pub fn get_bytes(&self, key: &str) -> Result<u64, CliError> {
        self.parse_with(key, "size (e.g. 64MB)", |s| parse_bytes(s))
    }

    fn parse_with<T>(
        &self,
        key: &str,
        expect: &'static str,
        f: impl Fn(&str) -> Option<T>,
    ) -> Result<T, CliError> {
        let raw = self.values.get(key).ok_or_else(|| CliError::MissingValue(format!("--{key}")))?;
        f(raw).ok_or_else(|| CliError::BadValue {
            key: key.to_string(),
            value: raw.clone(),
            expect,
        })
    }
}

/// Parse a human-friendly byte size: plain integers, or suffixed with
/// kb/mb/gb (case-insensitive, optional trailing 'b').
pub fn parse_bytes(s: &str) -> Option<u64> {
    let s = s.trim().to_ascii_lowercase();
    let (num, mult) = if let Some(p) = s.strip_suffix("gb").or_else(|| s.strip_suffix("g")) {
        (p, 1u64 << 30)
    } else if let Some(p) = s.strip_suffix("mb").or_else(|| s.strip_suffix("m")) {
        (p, 1u64 << 20)
    } else if let Some(p) = s.strip_suffix("kb").or_else(|| s.strip_suffix("k")) {
        (p, 1u64 << 10)
    } else {
        (s.as_str(), 1u64)
    };
    let num = num.trim();
    if let Ok(int) = num.parse::<u64>() {
        return Some(int * mult);
    }
    num.parse::<f64>().ok().map(|f| (f * mult as f64) as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("run", "run a word count")
            .opt("bytes", Some("64MB"), "corpus size")
            .opt("nodes", Some("1"), "simulated node count")
            .opt("engine", Some("blaze"), "engine name")
            .flag("verify", "verify against serial reference")
    }

    fn argv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&argv(&[])).unwrap();
        assert_eq!(a.get("bytes"), Some("64MB"));
        assert_eq!(a.get_usize("nodes").unwrap(), 1);
        assert!(!a.has_flag("verify"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = cmd().parse(&argv(&["--nodes", "4", "--engine=spark", "--verify"])).unwrap();
        assert_eq!(a.get_usize("nodes").unwrap(), 4);
        assert_eq!(a.get("engine"), Some("spark"));
        assert!(a.has_flag("verify"));
    }

    #[test]
    fn byte_sizes() {
        assert_eq!(parse_bytes("4096"), Some(4096));
        assert_eq!(parse_bytes("64MB"), Some(64 << 20));
        assert_eq!(parse_bytes("2gb"), Some(2 << 30));
        assert_eq!(parse_bytes("512kb"), Some(512 << 10));
        assert_eq!(parse_bytes("1.5mb"), Some((1.5 * (1 << 20) as f64) as u64));
        assert_eq!(parse_bytes("xyz"), None);
        let a = cmd().parse(&argv(&["--bytes", "2MB"])).unwrap();
        assert_eq!(a.get_bytes("bytes").unwrap(), 2 << 20);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(matches!(
            cmd().parse(&argv(&["--bogus"])),
            Err(CliError::UnknownOption(_))
        ));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(matches!(
            cmd().parse(&argv(&["--nodes"])),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn bad_value_rejected() {
        let a = cmd().parse(&argv(&["--nodes", "many"])).unwrap();
        assert!(matches!(a.get_usize("nodes"), Err(CliError::BadValue { .. })));
    }

    #[test]
    fn help_requested() {
        match cmd().parse(&argv(&["--help"])) {
            Err(CliError::HelpRequested(h)) => {
                assert!(h.contains("--bytes"));
                assert!(h.contains("default: 64MB"));
            }
            other => panic!("expected help, got {other:?}"),
        }
    }

    #[test]
    fn positional_args_collected() {
        let a = cmd().parse(&argv(&["input.txt", "--nodes", "2", "extra"])).unwrap();
        assert_eq!(a.positional(), &["input.txt".to_string(), "extra".to_string()]);
    }
}
