//! Bump arena for string/byte storage — the repo's TCMalloc analog.
//!
//! The paper's fastest configuration links TCMalloc ("Blaze TCM"), whose win
//! on word count is almost entirely cheaper small allocations in the insert
//! hot path (one `malloc` per new key). [`StrArena`] isolates exactly that
//! effect: keys are copied once into large slabs and handed out as stable
//! `u64` references, so the hash map stores fixed-size handles and the
//! allocator is a pointer bump.
//!
//! `bench allocator` (experiment M2) compares per-insert `String` allocation
//! against arena interning, reproducing the Blaze vs Blaze-TCM bar.

/// Default slab size: 256 KiB — large enough that slab allocation is
/// negligible, small enough not to waste memory at low key counts.
const SLAB_BYTES: usize = 256 * 1024;

/// A reference to a string stored in a [`StrArena`]: packed (slab, offset,
/// len). Copy, 8 bytes — this is what hash-map entries store.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct StrRef(u64);

impl StrRef {
    fn new(slab: usize, offset: usize, len: usize) -> Self {
        debug_assert!(slab < (1 << 20));
        debug_assert!(offset < (1 << 24));
        debug_assert!(len < (1 << 20));
        StrRef(((slab as u64) << 44) | ((offset as u64) << 20) | len as u64)
    }

    fn slab(self) -> usize {
        (self.0 >> 44) as usize
    }

    fn offset(self) -> usize {
        ((self.0 >> 20) & 0xFF_FFFF) as usize
    }

    fn len(self) -> usize {
        (self.0 & 0xF_FFFF) as usize
    }
}

/// Append-only string arena. Not thread-safe by itself — each worker thread
/// owns one (matching the thread-cache design) or access is externally
/// synchronized.
#[derive(Debug, Default)]
pub struct StrArena {
    slabs: Vec<Vec<u8>>,
    bytes_used: usize,
}

impl StrArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Copy `s` into the arena and return a stable handle. Strings larger
    /// than the default slab get a dedicated exactly-sized slab; the hard
    /// cap is the [`StrRef`] packing (1 MiB per string).
    pub fn intern(&mut self, s: &str) -> StrRef {
        let bytes = s.as_bytes();
        assert!(bytes.len() < (1 << 20), "string larger than StrRef length field");
        let need_new = match self.slabs.last() {
            None => true,
            Some(slab) => slab.len() + bytes.len() > slab.capacity(),
        };
        if need_new {
            self.slabs.push(Vec::with_capacity(SLAB_BYTES.max(bytes.len())));
        }
        let slab_idx = self.slabs.len() - 1;
        let slab = &mut self.slabs[slab_idx];
        let offset = slab.len();
        slab.extend_from_slice(bytes);
        self.bytes_used += bytes.len();
        StrRef::new(slab_idx, offset, bytes.len())
    }

    /// Resolve a handle back to its string slice.
    pub fn get(&self, r: StrRef) -> &str {
        let slab = &self.slabs[r.slab()];
        // Safety of UTF-8: intern only accepts &str and slabs are append-only.
        std::str::from_utf8(&slab[r.offset()..r.offset() + r.len()]).expect("arena utf8")
    }

    /// Total payload bytes stored.
    pub fn bytes_used(&self) -> usize {
        self.bytes_used
    }

    /// Total bytes reserved (slab capacity).
    pub fn bytes_reserved(&self) -> usize {
        self.slabs.iter().map(|s| s.capacity()).sum()
    }

    pub fn slab_count(&self) -> usize {
        self.slabs.len()
    }

    /// Checkpoint for [`StrArena::truncate`]: everything interned after
    /// the mark can be rolled back. Used by the dictionary decoder to
    /// retry a partially-decoded record after a short read without
    /// double-registering its strings.
    pub fn mark(&self) -> ArenaMark {
        ArenaMark {
            slabs: self.slabs.len(),
            last_len: self.slabs.last().map_or(0, Vec::len),
            bytes_used: self.bytes_used,
        }
    }

    /// Roll back to `mark`, invalidating every [`StrRef`] handed out
    /// since. Handles issued before the mark stay valid (slabs are only
    /// ever truncated back to their state at the mark).
    pub fn truncate(&mut self, mark: ArenaMark) {
        debug_assert!(mark.slabs <= self.slabs.len(), "mark from a different arena epoch");
        self.slabs.truncate(mark.slabs);
        if let Some(last) = self.slabs.last_mut() {
            last.truncate(mark.last_len);
        }
        self.bytes_used = mark.bytes_used;
    }
}

/// A rollback point in a [`StrArena`] — see [`StrArena::mark`].
#[derive(Clone, Copy, Debug)]
pub struct ArenaMark {
    slabs: usize,
    last_len: usize,
    bytes_used: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intern_and_get_roundtrip() {
        let mut a = StrArena::new();
        let r1 = a.intern("hello");
        let r2 = a.intern("world");
        let r3 = a.intern("");
        assert_eq!(a.get(r1), "hello");
        assert_eq!(a.get(r2), "world");
        assert_eq!(a.get(r3), "");
        assert_eq!(a.bytes_used(), 10);
    }

    #[test]
    fn handles_survive_slab_growth() {
        let mut a = StrArena::new();
        let mut refs = Vec::new();
        // Enough data to force several slabs.
        for i in 0..100_000 {
            refs.push((a.intern(&format!("word{i}")), format!("word{i}")));
        }
        assert!(a.slab_count() > 1, "expected multiple slabs");
        for (r, expect) in &refs {
            assert_eq!(a.get(*r), expect);
        }
    }

    #[test]
    fn unicode_strings() {
        let mut a = StrArena::new();
        let r = a.intern("héllo wörld — 你好");
        assert_eq!(a.get(r), "héllo wörld — 你好");
    }

    #[test]
    fn strref_is_copy_and_small() {
        assert_eq!(std::mem::size_of::<StrRef>(), 8);
        let mut a = StrArena::new();
        let r = a.intern("x");
        let r2 = r; // Copy
        assert_eq!(a.get(r), a.get(r2));
    }

    #[test]
    fn mark_and_truncate_roll_back_interns() {
        let mut a = StrArena::new();
        let keep = a.intern("stable");
        let m = a.mark();
        let _gone1 = a.intern("ephemeral-1");
        // Force a slab boundary inside the rollback window.
        let _gone2 = a.intern(&"x".repeat(SLAB_BYTES - 8));
        assert!(a.slab_count() > 1);
        a.truncate(m);
        assert_eq!(a.get(keep), "stable");
        assert_eq!(a.bytes_used(), "stable".len());
        assert_eq!(a.slab_count(), 1);
        // Re-interning after rollback reuses the space.
        let again = a.intern("ephemeral-1");
        assert_eq!(a.get(again), "ephemeral-1");
    }

    #[test]
    fn truncate_on_empty_mark_clears_everything() {
        let mut a = StrArena::new();
        let m = a.mark();
        a.intern("abc");
        a.truncate(m);
        assert_eq!(a.bytes_used(), 0);
        assert_eq!(a.slab_count(), 0);
    }

    #[test]
    fn large_string_near_slab_boundary() {
        let mut a = StrArena::new();
        let big = "a".repeat(SLAB_BYTES - 1);
        let r = a.intern(&big);
        assert_eq!(a.get(r).len(), SLAB_BYTES - 1);
        let r2 = a.intern("tail");
        assert_eq!(a.get(r2), "tail");
        assert_eq!(a.slab_count(), 2);
    }
}
