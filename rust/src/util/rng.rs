//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has no `rand`, so this module provides the two
//! generators the rest of the workspace needs:
//!
//! * [`SplitMix64`] — tiny, streamable, used for seeding and hashing-adjacent
//!   work (Steele et al., "Fast splittable pseudorandom number generators").
//! * [`Xoshiro256`] — xoshiro256** 1.0 (Blackman & Vigna), the workhorse
//!   generator for corpus synthesis and property tests.
//!
//! Both are fully deterministic from their seed, which the property-test
//! harness relies on for failure reproduction.

/// SplitMix64: 64 bits of state, one multiply-xor-shift chain per output.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0. Seeded through SplitMix64 per the authors' guidance so
/// that low-entropy seeds (0, 1, 2, ...) still give well-mixed streams.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift rejection.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below: bound must be positive");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize index in `[0, len)`.
    #[inline]
    pub fn index(&mut self, len: usize) -> usize {
        self.next_below(len as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in the inclusive integer range `[lo, hi]`.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi as i128 - lo as i128 + 1) as u128;
        if span > u64::MAX as u128 {
            return self.next_u64() as i64; // full-width request
        }
        lo.wrapping_add(self.next_below(span as u64) as i64)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Split off an independently-seeded child generator (for per-thread /
    /// per-node streams that must not correlate).
    pub fn split(&mut self) -> Xoshiro256 {
        Xoshiro256::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 from the public-domain C impl.
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism: same seed, same stream.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_streams() {
        let mut a = Xoshiro256::new(42);
        let mut b = Xoshiro256::new(42);
        let mut c = Xoshiro256::new(43);
        let va: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..16).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn next_below_is_in_range_and_covers() {
        let mut r = Xoshiro256::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn next_f64_in_unit_interval() {
        let mut r = Xoshiro256::new(9);
        for _ in 0..1000 {
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn range_i64_inclusive_bounds() {
        let mut r = Xoshiro256::new(11);
        let mut lo_hit = false;
        let mut hi_hit = false;
        for _ in 0..2000 {
            let v = r.range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_hit |= v == -3;
            hi_hit |= v == 3;
        }
        assert!(lo_hit && hi_hit);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_are_independent() {
        let mut parent = Xoshiro256::new(1);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        let v1: Vec<u64> = (0..8).map(|_| c1.next_u64()).collect();
        let v2: Vec<u64> = (0..8).map(|_| c2.next_u64()).collect();
        assert_ne!(v1, v2);
    }

    #[test]
    fn chance_extremes() {
        let mut r = Xoshiro256::new(3);
        for _ in 0..100 {
            assert!(!r.chance(0.0));
            assert!(r.chance(1.0));
        }
    }
}
