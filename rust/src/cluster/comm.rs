//! MPI-flavoured communicator for the simulated cluster.
//!
//! Each simulated node is an OS thread; `Comm` gives them ranked,
//! per-pair-ordered, tagged message passing plus the collectives the
//! MapReduce engines need (`barrier`, `all_to_all`, `gather`, `broadcast`).
//! Message payloads are raw bytes — callers serialize with [`crate::util::ser`],
//! which is exactly what makes "bytes on the wire" measurable.
//!
//! Transport: an `nnodes × nnodes` matrix of unbounded mpsc channels
//! (`tx[src][dst]`), so sends never block and per-pair FIFO order holds.
//! Receive applies the [`NetModel`] cost of the message and accounts it.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Barrier, Mutex};

use super::netmodel::NetModel;

/// Message tags keep protocol phases honest: a mismatched tag at the head
/// of a pair's queue is a bug, not a reordering.
pub type Tag = u32;

pub const TAG_SHUFFLE: Tag = 1;
pub const TAG_GATHER: Tag = 2;
pub const TAG_BCAST: Tag = 3;
pub const TAG_CONTROL: Tag = 4;

struct Message {
    tag: Tag,
    payload: Vec<u8>,
}

/// Per-node communication statistics (shared, atomically updated).
#[derive(Debug, Default)]
pub struct CommStats {
    pub msgs_sent: AtomicU64,
    pub bytes_sent: AtomicU64,
    /// Nanoseconds of simulated network time charged to this node's recvs.
    pub net_time_ns: AtomicU64,
}

impl CommStats {
    pub fn bytes(&self) -> u64 {
        self.bytes_sent.load(Ordering::Relaxed)
    }

    pub fn msgs(&self) -> u64 {
        self.msgs_sent.load(Ordering::Relaxed)
    }

    pub fn net_time_secs(&self) -> f64 {
        self.net_time_ns.load(Ordering::Relaxed) as f64 / 1e9
    }
}

/// Cluster-wide shared state handed to every node's `Comm`.
pub struct Fabric {
    nnodes: usize,
    netmodel: NetModel,
    /// tx[src][dst]
    senders: Vec<Vec<Sender<Message>>>,
    /// rx[dst][src], each behind a mutex so only the owning node thread
    /// uses it (Receiver is !Sync; the mutex makes Fabric shareable).
    receivers: Vec<Vec<Mutex<Receiver<Message>>>>,
    barrier: Barrier,
    stats: Vec<CommStats>,
}

impl Fabric {
    pub fn new(nnodes: usize, netmodel: NetModel) -> Arc<Self> {
        assert!(nnodes > 0);
        // senders[src][dst] pairs with receivers[dst][src].
        let mut sender_slots: Vec<Vec<Option<Sender<Message>>>> =
            (0..nnodes).map(|_| (0..nnodes).map(|_| None).collect()).collect();
        let mut receivers: Vec<Vec<Mutex<Receiver<Message>>>> =
            (0..nnodes).map(|_| Vec::new()).collect();
        for dst in 0..nnodes {
            for src in 0..nnodes {
                let (tx, rx) = channel();
                sender_slots[src][dst] = Some(tx);
                receivers[dst].push(Mutex::new(rx));
            }
        }
        let senders = sender_slots
            .into_iter()
            .map(|row| row.into_iter().map(Option::unwrap).collect())
            .collect();
        Arc::new(Self {
            nnodes,
            netmodel,
            senders,
            receivers,
            barrier: Barrier::new(nnodes),
            stats: (0..nnodes).map(|_| CommStats::default()).collect(),
        })
    }

    pub fn nnodes(&self) -> usize {
        self.nnodes
    }

    pub fn stats(&self, rank: usize) -> &CommStats {
        &self.stats[rank]
    }

    /// Total bytes sent across all nodes.
    pub fn total_bytes_sent(&self) -> u64 {
        self.stats.iter().map(|s| s.bytes()).sum()
    }

    /// Total simulated network seconds across all nodes.
    pub fn total_net_time_secs(&self) -> f64 {
        self.stats.iter().map(|s| s.net_time_secs()).sum()
    }
}

/// A node's handle on the fabric.
#[derive(Clone)]
pub struct Comm {
    pub rank: usize,
    fabric: Arc<Fabric>,
}

impl Comm {
    pub fn new(rank: usize, fabric: Arc<Fabric>) -> Self {
        assert!(rank < fabric.nnodes());
        Self { rank, fabric }
    }

    pub fn nnodes(&self) -> usize {
        self.fabric.nnodes
    }

    /// Send `payload` to `dst` (never blocks; unbounded queue).
    pub fn send(&self, dst: usize, tag: Tag, payload: Vec<u8>) {
        let stats = &self.fabric.stats[self.rank];
        stats.msgs_sent.fetch_add(1, Ordering::Relaxed);
        stats.bytes_sent.fetch_add(payload.len() as u64, Ordering::Relaxed);
        self.fabric.senders[self.rank][dst]
            .send(Message { tag, payload })
            .expect("peer receiver dropped");
    }

    /// Blocking receive of the next message from `src`; the tag must match
    /// (per-pair FIFO makes a mismatch a protocol bug). Applies the network
    /// model's cost as wall-clock sleep, charged to this (receiving) node.
    pub fn recv(&self, src: usize, tag: Tag) -> Vec<u8> {
        let msg = {
            let rx = self.fabric.receivers[self.rank][src].lock().unwrap();
            rx.recv().expect("peer sender dropped")
        };
        assert_eq!(
            msg.tag, tag,
            "protocol error: rank {} expected tag {tag} from {src}, got {}",
            self.rank, msg.tag
        );
        if src != self.rank {
            let cost = self.fabric.netmodel.cost(msg.payload.len());
            if !cost.is_zero() {
                std::thread::sleep(cost);
            }
            self.fabric.stats[self.rank]
                .net_time_ns
                .fetch_add(cost.as_nanos() as u64, Ordering::Relaxed);
        }
        msg.payload
    }

    /// Rendezvous of all nodes.
    pub fn barrier(&self) {
        self.fabric.barrier.wait();
    }

    /// All-to-all exchange: `outgoing[d]` goes to rank `d`; returns
    /// `incoming[s]` = the buffer rank `s` sent here. Self-delivery is a
    /// free move (no network charge).
    pub fn all_to_all(&self, mut outgoing: Vec<Vec<u8>>) -> Vec<Vec<u8>> {
        let n = self.nnodes();
        assert_eq!(outgoing.len(), n, "all_to_all needs one buffer per rank");
        // Keep our own slice out of the network path.
        let mine = std::mem::take(&mut outgoing[self.rank]);
        for dst in 0..n {
            if dst != self.rank {
                self.send(dst, TAG_SHUFFLE, std::mem::take(&mut outgoing[dst]));
            }
        }
        let mut incoming: Vec<Vec<u8>> = (0..n).map(|_| Vec::new()).collect();
        incoming[self.rank] = mine;
        for src in 0..n {
            if src != self.rank {
                incoming[src] = self.recv(src, TAG_SHUFFLE);
            }
        }
        incoming
    }

    /// Gather every rank's buffer at `root`; returns `Some(buffers)` at the
    /// root (indexed by rank), `None` elsewhere.
    pub fn gather(&self, root: usize, payload: Vec<u8>) -> Option<Vec<Vec<u8>>> {
        if self.rank == root {
            let mut all: Vec<Vec<u8>> = (0..self.nnodes()).map(|_| Vec::new()).collect();
            all[root] = payload;
            for src in 0..self.nnodes() {
                if src != root {
                    all[src] = self.recv(src, TAG_GATHER);
                }
            }
            Some(all)
        } else {
            self.send(root, TAG_GATHER, payload);
            None
        }
    }

    /// Broadcast `payload` from `root` to every rank; returns the payload
    /// everywhere.
    pub fn broadcast(&self, root: usize, payload: Option<Vec<u8>>) -> Vec<u8> {
        if self.rank == root {
            let payload = payload.expect("root must provide a payload");
            for dst in 0..self.nnodes() {
                if dst != root {
                    self.send(dst, TAG_BCAST, payload.clone());
                }
            }
            payload
        } else {
            self.recv(root, TAG_BCAST)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::spawn_cluster;

    #[test]
    fn point_to_point_roundtrip() {
        let results = spawn_cluster(2, NetModel::ideal(), |comm| {
            if comm.rank == 0 {
                comm.send(1, TAG_CONTROL, b"ping".to_vec());
                comm.recv(1, TAG_CONTROL)
            } else {
                let m = comm.recv(0, TAG_CONTROL);
                assert_eq!(m, b"ping");
                comm.send(0, TAG_CONTROL, b"pong".to_vec());
                m
            }
        });
        assert_eq!(results[0], b"pong");
        assert_eq!(results[1], b"ping");
    }

    #[test]
    fn all_to_all_routes_correctly() {
        let n = 4;
        let results = spawn_cluster(n, NetModel::ideal(), move |comm| {
            let outgoing: Vec<Vec<u8>> =
                (0..n).map(|dst| vec![comm.rank as u8, dst as u8]).collect();
            comm.all_to_all(outgoing)
        });
        for (me, incoming) in results.iter().enumerate() {
            for (src, buf) in incoming.iter().enumerate() {
                assert_eq!(buf, &vec![src as u8, me as u8], "src {src} -> dst {me}");
            }
        }
    }

    #[test]
    fn gather_collects_at_root() {
        let results = spawn_cluster(3, NetModel::ideal(), |comm| {
            comm.gather(0, vec![comm.rank as u8; comm.rank + 1])
        });
        let at_root = results[0].as_ref().expect("root gets all");
        assert_eq!(at_root.len(), 3);
        for (rank, buf) in at_root.iter().enumerate() {
            assert_eq!(buf, &vec![rank as u8; rank + 1]);
        }
        assert!(results[1].is_none());
        assert!(results[2].is_none());
    }

    #[test]
    fn broadcast_reaches_all() {
        let results = spawn_cluster(4, NetModel::ideal(), |comm| {
            let payload = (comm.rank == 1).then(|| b"hello".to_vec());
            comm.broadcast(1, payload)
        });
        for r in results {
            assert_eq!(r, b"hello");
        }
    }

    #[test]
    fn stats_count_bytes() {
        let fabric_probe = spawn_cluster_with_fabric(2, NetModel::ideal(), |comm| {
            if comm.rank == 0 {
                comm.send(1, TAG_CONTROL, vec![0u8; 1000]);
            } else {
                comm.recv(0, TAG_CONTROL);
            }
            comm.barrier();
        });
        assert_eq!(fabric_probe.stats(0).bytes(), 1000);
        assert_eq!(fabric_probe.stats(0).msgs(), 1);
        assert_eq!(fabric_probe.stats(1).bytes(), 0);
    }

    #[test]
    fn network_model_charges_time() {
        let fabric = spawn_cluster_with_fabric(2, NetModel::slow(), |comm| {
            if comm.rank == 0 {
                comm.send(1, TAG_CONTROL, vec![0u8; 125_000]); // ~10ms at 12.5MB/s
            } else {
                comm.recv(0, TAG_CONTROL);
            }
            comm.barrier();
        });
        let t = fabric.stats(1).net_time_secs();
        assert!(t > 0.005, "expected ≥5ms of simulated net time, got {t}");
    }

    /// Test helper: run a cluster and return the fabric for stats probing.
    fn spawn_cluster_with_fabric<F>(nnodes: usize, net: NetModel, f: F) -> Arc<Fabric>
    where
        F: Fn(&Comm) + Sync,
    {
        let fabric = Fabric::new(nnodes, net);
        let fabric2 = Arc::clone(&fabric);
        std::thread::scope(|scope| {
            for rank in 0..nnodes {
                let comm = Comm::new(rank, Arc::clone(&fabric2));
                let f = &f;
                scope.spawn(move || f(&comm));
            }
        });
        fabric
    }
}
