//! Simulated multi-node cluster.
//!
//! The paper's testbed is an MPI cluster of AWS instances; here each node
//! is an OS thread (which in turn runs an OpenMP-style pool for its "cores")
//! and the network is a message-passing fabric with an explicit cost model.
//! See DESIGN.md §2 for why this substitution preserves the paper's claims.

pub mod comm;
pub mod failure;
pub mod netmodel;

pub use comm::{Comm, CommStats, Fabric, Tag, TAG_BCAST, TAG_CONTROL, TAG_GATHER, TAG_SHUFFLE};
pub use failure::{FailurePlan, NodeSite, TaskSite};
pub use netmodel::NetModel;

use std::sync::Arc;

/// Launch an `nnodes`-node cluster, run `f` on every node thread, and
/// return the per-rank results. The closure may freely use its own
/// [`crate::util::pool`] parallelism for intra-node threads.
pub fn spawn_cluster<T, F>(nnodes: usize, net: NetModel, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(&Comm) -> T + Sync,
{
    let fabric = Fabric::new(nnodes, net);
    spawn_on_fabric(&fabric, &f)
}

/// Like [`spawn_cluster`] but on a caller-owned fabric, so the caller can
/// inspect [`Fabric`] statistics (bytes shuffled, simulated network time)
/// after the run.
pub fn spawn_on_fabric<T, F>(fabric: &Arc<Fabric>, f: &F) -> Vec<T>
where
    T: Send,
    F: Fn(&Comm) -> T + Sync,
{
    let nnodes = fabric.nnodes();
    let mut slots: Vec<Option<T>> = (0..nnodes).map(|_| None).collect();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for rank in 0..nnodes {
            let comm = Comm::new(rank, Arc::clone(fabric));
            handles.push(scope.spawn(move || f(&comm)));
        }
        for (rank, h) in handles.into_iter().enumerate() {
            match h.join() {
                Ok(v) => slots[rank] = Some(v),
                Err(p) => std::panic::resume_unwind(p),
            }
        }
    });
    slots.into_iter().map(Option::unwrap).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spawn_cluster_returns_per_rank_results() {
        let results = spawn_cluster(4, NetModel::ideal(), |comm| comm.rank * 10);
        assert_eq!(results, vec![0, 10, 20, 30]);
    }

    #[test]
    fn single_node_cluster() {
        let results = spawn_cluster(1, NetModel::ideal(), |comm| {
            assert_eq!(comm.nnodes(), 1);
            // Self all-to-all short-circuits.
            let incoming = comm.all_to_all(vec![b"self".to_vec()]);
            incoming[0].clone()
        });
        assert_eq!(results[0], b"self");
    }

    #[test]
    fn nodes_can_use_intra_node_pools() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let results = spawn_cluster(2, NetModel::ideal(), |_comm| {
            let sum = AtomicU64::new(0);
            crate::util::pool::parallel_for(
                3,
                100,
                crate::util::pool::Schedule::Static,
                |_ctx, i| {
                    sum.fetch_add(i as u64, Ordering::Relaxed);
                },
            );
            sum.load(Ordering::Relaxed)
        });
        assert_eq!(results, vec![4950, 4950]);
    }
}
