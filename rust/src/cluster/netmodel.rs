//! Network cost model for the simulated cluster.
//!
//! The paper ran on AWS r5.xlarge instances ("up to 10 Gigabit" NICs). Our
//! nodes are threads in one process, so inter-node transfers are modeled:
//! each received message costs `latency + bytes / bandwidth` of wall-clock
//! time, charged at the receiver (NIC serialization). This makes "bytes
//! shuffled" — the quantity the paper's local-reduce argument is about — a
//! real cost in every words/sec number we report.

use std::time::Duration;

#[derive(Clone, Copy, Debug)]
pub struct NetModel {
    /// One-way per-message latency.
    pub latency: Duration,
    /// Link bandwidth in bytes/second.
    pub bandwidth: f64,
    /// If false, transfers are free (pure in-memory move) — used by unit
    /// tests and by the "ideal network" ablation.
    pub enabled: bool,
}

impl NetModel {
    /// AWS-like defaults: ~50 µs latency, 10 Gbit/s ≈ 1.25 GB/s.
    pub fn aws_like() -> Self {
        Self {
            latency: Duration::from_micros(50),
            bandwidth: 1.25e9,
            enabled: true,
        }
    }

    /// Free, instantaneous network.
    pub fn ideal() -> Self {
        Self {
            latency: Duration::ZERO,
            bandwidth: f64::INFINITY,
            enabled: false,
        }
    }

    /// A slow network (100 Mbit/s, 200 µs) — exaggerates shuffle cost to
    /// make the local-reduce ablation legible on small corpora.
    pub fn slow() -> Self {
        Self {
            latency: Duration::from_micros(200),
            bandwidth: 12.5e6,
            enabled: true,
        }
    }

    /// Wall-clock cost of one `bytes`-sized message.
    pub fn cost(&self, bytes: usize) -> Duration {
        if !self.enabled {
            return Duration::ZERO;
        }
        let transfer = bytes as f64 / self.bandwidth;
        self.latency + Duration::from_secs_f64(transfer)
    }

    pub fn parse(s: &str) -> Option<NetModel> {
        match s {
            "aws" | "aws-like" => Some(Self::aws_like()),
            "ideal" | "none" => Some(Self::ideal()),
            "slow" => Some(Self::slow()),
            _ => None,
        }
    }
}

impl Default for NetModel {
    fn default() -> Self {
        Self::aws_like()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_is_free() {
        let m = NetModel::ideal();
        assert_eq!(m.cost(0), Duration::ZERO);
        assert_eq!(m.cost(1 << 30), Duration::ZERO);
    }

    #[test]
    fn cost_scales_with_bytes() {
        let m = NetModel::aws_like();
        let small = m.cost(1024);
        let big = m.cost(128 << 20);
        assert!(big > small);
        // 128 MB at 1.25 GB/s ≈ 100 ms (+latency).
        let secs = big.as_secs_f64();
        assert!((0.09..0.2).contains(&secs), "got {secs}");
    }

    #[test]
    fn latency_floor() {
        let m = NetModel::aws_like();
        assert!(m.cost(1) >= Duration::from_micros(50));
    }

    #[test]
    fn parse_names() {
        assert!(NetModel::parse("aws").unwrap().enabled);
        assert!(!NetModel::parse("ideal").unwrap().enabled);
        assert!(NetModel::parse("slow").unwrap().enabled);
        assert!(NetModel::parse("wat").is_none());
    }
}
