//! Failure injection for the fault-tolerance experiments (A2).
//!
//! The paper's argument: Blaze skips fault tolerance entirely (rerun the
//! whole job on failure), Spark pays for it continuously (persisted shuffle
//! output + lineage bookkeeping) but recovers by recomputing only lost
//! partitions. Both engines consult a [`FailurePlan`]:
//!
//! * the Spark engine asks [`should_fail_task`] before each task attempt —
//!   a planned failure makes that attempt abort, and the scheduler retries
//!   from lineage;
//! * the Blaze engine asks [`should_fail_node`] once per phase — a planned
//!   failure aborts the whole job, and the driver reruns it from scratch.
//!
//! Failures are one-shot: the plan records consumed injections so retries
//! succeed (matching "as long as it succeeds before the fourth try").

use std::collections::HashSet;
use std::sync::Mutex;

/// Identifies a task attempt in the Spark engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TaskSite {
    pub stage: usize,
    pub partition: usize,
}

/// Identifies a phase on a node in the Blaze engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct NodeSite {
    pub rank: usize,
    /// 0 = map phase, 1 = shuffle phase.
    pub phase: usize,
}

#[derive(Debug, Default)]
pub struct FailurePlan {
    /// Task attempts that should fail (first attempt only).
    fail_tasks: Mutex<HashSet<TaskSite>>,
    /// Node phases that should fail (first run only).
    fail_nodes: Mutex<HashSet<NodeSite>>,
    /// Executors whose shuffle output is lost after the map stage
    /// (Spark-sim: triggers lineage recomputation of lost partitions).
    lose_executors: Mutex<Vec<usize>>,
}

impl FailurePlan {
    pub fn none() -> Self {
        Self::default()
    }

    pub fn fail_task(self, stage: usize, partition: usize) -> Self {
        self.fail_tasks.lock().unwrap().insert(TaskSite { stage, partition });
        self
    }

    pub fn fail_node(self, rank: usize, phase: usize) -> Self {
        self.fail_nodes.lock().unwrap().insert(NodeSite { rank, phase });
        self
    }

    /// Consume a planned task failure, if any. Returns true exactly once
    /// per planned site.
    pub fn should_fail_task(&self, stage: usize, partition: usize) -> bool {
        self.fail_tasks.lock().unwrap().remove(&TaskSite { stage, partition })
    }

    /// Consume a planned node failure, if any.
    pub fn should_fail_node(&self, rank: usize, phase: usize) -> bool {
        self.fail_nodes.lock().unwrap().remove(&NodeSite { rank, phase })
    }

    /// Plan the loss of an executor's shuffle output (Spark-sim only).
    pub fn lose_executor(self, rank: usize) -> Self {
        self.lose_executors.lock().unwrap().push(rank);
        self
    }

    /// Consume one planned executor loss, if any.
    pub fn take_lost_executor(&self) -> Option<usize> {
        self.lose_executors.lock().unwrap().pop()
    }

    pub fn is_empty(&self) -> bool {
        self.fail_tasks.lock().unwrap().is_empty()
            && self.fail_nodes.lock().unwrap().is_empty()
            && self.lose_executors.lock().unwrap().is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_failure_fires_once() {
        let plan = FailurePlan::none().fail_task(1, 3);
        assert!(!plan.should_fail_task(0, 3));
        assert!(!plan.should_fail_task(1, 2));
        assert!(plan.should_fail_task(1, 3));
        assert!(!plan.should_fail_task(1, 3), "consumed: retry must succeed");
        assert!(plan.is_empty());
    }

    #[test]
    fn node_failure_fires_once() {
        let plan = FailurePlan::none().fail_node(2, 0);
        assert!(plan.should_fail_node(2, 0));
        assert!(!plan.should_fail_node(2, 0));
    }

    #[test]
    fn empty_plan_never_fails() {
        let plan = FailurePlan::none();
        assert!(!plan.should_fail_task(0, 0));
        assert!(!plan.should_fail_node(0, 0));
        assert!(plan.is_empty());
    }

    #[test]
    fn executor_loss_consumed_once() {
        let plan = FailurePlan::none().lose_executor(2);
        assert!(!plan.is_empty());
        assert_eq!(plan.take_lost_executor(), Some(2));
        assert_eq!(plan.take_lost_executor(), None);
    }

    #[test]
    fn multiple_injections() {
        let plan = FailurePlan::none().fail_task(0, 1).fail_task(0, 2).fail_node(1, 1);
        assert!(plan.should_fail_task(0, 1));
        assert!(plan.should_fail_task(0, 2));
        assert!(plan.should_fail_node(1, 1));
        assert!(plan.is_empty());
    }
}
