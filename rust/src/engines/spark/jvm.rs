//! JVM cost carriers for the Spark-sim engine.
//!
//! The paper's first explanation for the gap is "MPI/OpenMP uses C++ and
//! runs natively while Spark/Scala runs through a virtual machine". Rather
//! than a fudge factor, this module reproduces the two dominant JVM
//! *mechanisms* at word-count scale, both ablatable via [`super::SparkConf`]:
//!
//! * **UTF-16 strings** ([`JvmWord`]): Spark 2.4 on EMR 5.20 runs Java 8,
//!   where `java.lang.String` is a UTF-16 `char[]`. Every string the
//!   pipeline touches is decoded UTF-8 → UTF-16 on creation (HDFS read,
//!   `split`, shuffle read) and encoded back on the wire (`writeUTF`),
//!   doubling memory traffic and adding conversion work — exactly what the
//!   JVM pays. `JvmWord` stores `Vec<u16>` and performs those conversions
//!   at the same points the JVM would.
//!
//! * **Garbage collection** ([`GcSim`]): the JVM's allocation rate drives
//!   minor GC pauses. `GcSim` counts bytes allocated through the cost
//!   carriers; every `young_gen_bytes` of allocation triggers a
//!   stop-the-executor pause of `minor_pause` (ParNew-style: a few ms per
//!   young-gen fill — we default to 3 ms / 64 MiB, the conservative end of
//!   observed Java 8 behaviour).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use std::collections::HashMap;

use crate::concurrent::MapKey;
use crate::hash::HashKind;
use crate::util::arena::StrRef;
use crate::util::ser::{DataKey, Decode, DecodeError, DictReader, DictWriter, Encode, Reader};

/// A Java-8-style string: UTF-16 code units in memory, UTF-8 on the wire.
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JvmWord(pub Vec<u16>);

impl JvmWord {
    /// Decode UTF-8 → UTF-16 (what `new String(bytes, UTF_8)` does).
    #[inline]
    pub fn from_str(s: &str) -> Self {
        JvmWord(s.encode_utf16().collect())
    }

    /// Encode UTF-16 → UTF-8 (what `String.getBytes(UTF_8)` does).
    pub fn to_string_lossy(&self) -> String {
        String::from_utf16_lossy(&self.0)
    }

    /// In-memory footprint (the 2-byte chars + object header estimate).
    #[inline]
    pub fn heap_bytes(&self) -> usize {
        self.0.len() * 2 + 40 // char[] + String header + array header
    }
}

impl MapKey for JvmWord {
    #[inline]
    fn hash_with(&self, kind: HashKind) -> u64 {
        // Hash the UTF-16 bytes (the JVM hashes chars too).
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(self.0.as_ptr().cast(), self.0.len() * 2)
        };
        kind.hash(bytes)
    }
}

impl Encode for JvmWord {
    fn encode(&self, out: &mut Vec<u8>) {
        // writeUTF: convert UTF-16 back to UTF-8 for the wire.
        let s = self.to_string_lossy();
        s.encode(out);
    }
}

impl Decode for JvmWord {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        // readUTF: parse UTF-8, materialize UTF-16.
        let s = String::decode(r)?;
        Ok(JvmWord::from_str(&s))
    }
}

/// `JvmWord` rides the string dictionary as its UTF-8 wire form (exactly
/// what `writeUTF` puts on the wire), deferring the UTF-16
/// materialization to the points the JVM would pay it (`readUTF` on a
/// dictionary miss). Refs are arena handles to the UTF-8 payload, so
/// comparisons/hashes re-derive the UTF-16 view without allocating the
/// `Vec<u16>` — except `ref_hash`, which must match
/// [`MapKey::hash_with`]'s byte order and builds the code-unit buffer.
impl DataKey for JvmWord {
    type Ref = StrRef;

    fn dict_encode(&self, dict: &mut DictWriter, out: &mut Vec<u8>) {
        dict.encode_str(&self.to_string_lossy(), out);
    }

    fn dict_decode(r: &mut Reader<'_>, dict: &mut DictReader) -> Result<Self::Ref, DecodeError> {
        dict.decode_str(r)
    }

    fn ref_from_owned(this: Self, dict: &mut DictReader) -> Self::Ref {
        dict.intern(&this.to_string_lossy())
    }

    fn ref_cmp(
        a: &Self::Ref,
        da: &DictReader,
        b: &Self::Ref,
        db: &DictReader,
    ) -> std::cmp::Ordering {
        // Must match `Ord for JvmWord` = lexicographic over UTF-16 code
        // units, which differs from `str` byte order above the BMP.
        da.get(*a).encode_utf16().cmp(db.get(*b).encode_utf16())
    }

    fn ref_materialize(r: &Self::Ref, dict: &DictReader) -> Self {
        JvmWord::from_str(dict.get(*r))
    }

    fn ref_eq_owned(r: &Self::Ref, dict: &DictReader, owned: &Self) -> bool {
        owned.0.iter().copied().eq(dict.get(*r).encode_utf16())
    }

    fn ref_hash(r: &Self::Ref, dict: &DictReader, kind: HashKind) -> u64 {
        let units: Vec<u16> = dict.get(*r).encode_utf16().collect();
        let bytes: &[u8] =
            unsafe { std::slice::from_raw_parts(units.as_ptr().cast(), units.len() * 2) };
        kind.hash(bytes)
    }

    fn map_get_mut<'m, V>(
        map: &'m mut HashMap<Self, V>,
        r: &Self::Ref,
        dict: &DictReader,
    ) -> Option<&'m mut V> {
        // No `Borrow<str>` bridge from `JvmWord`: probe with a fresh
        // UTF-16 key, the allocation `readUTF` would pay anyway.
        map.get_mut(&JvmWord::from_str(dict.get(*r)))
    }
}

/// Heap-footprint estimate for GC accounting — what each record "costs"
/// the JVM allocator when materialized as objects. The trait itself now
/// lives in the storage subsystem (the cache, the spill paths, and this
/// engine all share one estimator); re-exported here so
/// `engines::spark::HeapSize` keeps resolving.
pub use crate::storage::HeapSize;

impl HeapSize for JvmWord {
    #[inline]
    fn heap_bytes(&self) -> usize {
        JvmWord::heap_bytes(self)
    }
}

/// Minor-GC simulator: allocation-rate-driven pauses.
#[derive(Debug)]
pub struct GcSim {
    enabled: bool,
    young_gen_bytes: u64,
    minor_pause: Duration,
    allocated: AtomicU64,
    pauses: AtomicU64,
    pause_ns: AtomicU64,
}

impl GcSim {
    pub fn new(enabled: bool) -> Self {
        Self {
            enabled,
            young_gen_bytes: 64 << 20,
            minor_pause: Duration::from_millis(3),
            allocated: AtomicU64::new(0),
            pauses: AtomicU64::new(0),
            pause_ns: AtomicU64::new(0),
        }
    }

    /// Record `bytes` of allocation; sleeps through a "minor collection"
    /// whenever the young generation fills.
    #[inline]
    pub fn allocated(&self, bytes: usize) {
        if !self.enabled {
            return;
        }
        let before = self.allocated.fetch_add(bytes as u64, Ordering::Relaxed);
        let after = before + bytes as u64;
        if before / self.young_gen_bytes != after / self.young_gen_bytes {
            // Crossed a young-gen boundary: pause this executor thread.
            std::thread::sleep(self.minor_pause);
            self.pauses.fetch_add(1, Ordering::Relaxed);
            self.pause_ns
                .fetch_add(self.minor_pause.as_nanos() as u64, Ordering::Relaxed);
        }
    }

    pub fn total_allocated(&self) -> u64 {
        self.allocated.load(Ordering::Relaxed)
    }

    pub fn pause_count(&self) -> u64 {
        self.pauses.load(Ordering::Relaxed)
    }

    pub fn pause_secs(&self) -> f64 {
        self.pause_ns.load(Ordering::Relaxed) as f64 / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jvm_word_roundtrip() {
        for s in ["hello", "héllo", "你好", ""] {
            let w = JvmWord::from_str(s);
            assert_eq!(w.to_string_lossy(), s);
            let bytes = w.to_bytes();
            let back = JvmWord::from_bytes(&bytes).unwrap();
            assert_eq!(back, w);
        }
    }

    #[test]
    fn jvm_word_heap_accounting() {
        let w = JvmWord::from_str("word");
        assert_eq!(w.heap_bytes(), 4 * 2 + 40);
    }

    #[test]
    fn jvm_word_hashes_distinctly() {
        let a = JvmWord::from_str("alpha").hash_with(HashKind::Fx);
        let b = JvmWord::from_str("alphb").hash_with(HashKind::Fx);
        assert_ne!(a, b);
        assert_eq!(a, JvmWord::from_str("alpha").hash_with(HashKind::Fx));
    }

    #[test]
    fn jvm_word_dict_pairs_roundtrip() {
        use crate::util::ser::{decode_pairs, encode_pairs};
        let words: Vec<(JvmWord, u64)> = ["apfel", "birne", "apfel", "你好", "apfel"]
            .iter()
            .map(|s| (JvmWord::from_str(s), 1u64))
            .collect();
        let (bytes, stats) = encode_pairs(&words, true);
        assert_eq!(stats.unique, 3);
        assert_eq!(stats.refs, 2);
        assert!(stats.key_enc_bytes < stats.key_raw_bytes);
        let back: Vec<(JvmWord, u64)> = decode_pairs(&bytes).unwrap();
        assert_eq!(back, words);
        // Disabled writer: every occurrence inline, same reader decodes.
        let (bytes, stats) = encode_pairs(&words, false);
        assert_eq!((stats.unique, stats.refs), (5, 0));
        let back: Vec<(JvmWord, u64)> = decode_pairs(&bytes).unwrap();
        assert_eq!(back, words);
    }

    #[test]
    fn jvm_word_refs_follow_utf16_order_and_hash() {
        // U+1F600 encodes as a surrogate pair starting 0xD83D, which
        // sorts *below* U+E000 in UTF-16 code units — the opposite of
        // UTF-8 byte order. ref_cmp must follow the owned Ord.
        let hi = JvmWord::from_str("😀");
        let pua = JvmWord::from_str("\u{e000}");
        assert!(hi < pua, "UTF-16 code-unit order");
        let mut dict = DictReader::new();
        let r_hi = JvmWord::ref_from_owned(hi.clone(), &mut dict);
        let r_pua = JvmWord::ref_from_owned(pua.clone(), &mut dict);
        assert_eq!(
            JvmWord::ref_cmp(&r_hi, &dict, &r_pua, &dict),
            std::cmp::Ordering::Less
        );
        assert!(JvmWord::ref_eq_owned(&r_hi, &dict, &hi));
        assert!(!JvmWord::ref_eq_owned(&r_hi, &dict, &pua));
        for kind in [HashKind::Fx, HashKind::Fnv1a, HashKind::Wy] {
            assert_eq!(JvmWord::ref_hash(&r_hi, &dict, kind), hi.hash_with(kind));
        }
        assert_eq!(JvmWord::ref_materialize(&r_hi, &dict), hi);
        let mut map = HashMap::new();
        map.insert(hi.clone(), 7u64);
        *JvmWord::map_get_mut(&mut map, &r_hi, &dict).unwrap() += 1;
        assert_eq!(map[&hi], 8);
        assert!(JvmWord::map_get_mut(&mut map, &r_pua, &dict).is_none());
    }

    #[test]
    fn gc_pauses_on_young_gen_fill() {
        let gc = GcSim {
            enabled: true,
            young_gen_bytes: 1024,
            minor_pause: Duration::from_micros(10),
            allocated: AtomicU64::new(0),
            pauses: AtomicU64::new(0),
            pause_ns: AtomicU64::new(0),
        };
        for _ in 0..10 {
            gc.allocated(256);
        }
        // 2560 bytes / 1024 young gen = 2 boundary crossings.
        assert_eq!(gc.pause_count(), 2);
        assert!(gc.pause_secs() > 0.0);
        assert_eq!(gc.total_allocated(), 2560);
    }

    #[test]
    fn gc_disabled_is_free() {
        let gc = GcSim::new(false);
        gc.allocated(1 << 30);
        assert_eq!(gc.pause_count(), 0);
        assert_eq!(gc.total_allocated(), 0);
    }
}
