//! Execution metrics for the Spark-sim engine — the phase/overhead
//! breakdown the ablation benches report.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

#[derive(Debug, Default)]
pub struct SparkMetrics {
    pub tasks_launched: AtomicU64,
    pub task_failures: AtomicU64,
    pub job_restarts: AtomicU64,
    pub shuffle_bytes_written: AtomicU64,
    pub shuffle_bytes_read: AtomicU64,
    pub records_shuffled: AtomicU64,
    /// Map partitions recomputed from lineage after a block loss.
    pub lineage_recomputes: AtomicU64,
    /// Nanosecond accumulators.
    ser_ns: AtomicU64,
    deser_ns: AtomicU64,
    dispatch_ns: AtomicU64,
    net_ns: AtomicU64,
    disk_ns: AtomicU64,
    vm_ns: AtomicU64,
    gc_ns: AtomicU64,
}

impl SparkMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_ser(&self, d: Duration) {
        self.ser_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn add_deser(&self, d: Duration) {
        self.deser_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn add_dispatch(&self, d: Duration) {
        self.dispatch_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn add_net(&self, d: Duration) {
        self.net_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn add_disk(&self, d: Duration) {
        self.disk_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn add_vm(&self, d: Duration) {
        self.vm_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn add_gc(&self, d: Duration) {
        self.gc_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn vm_secs(&self) -> f64 {
        self.vm_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn gc_secs(&self) -> f64 {
        self.gc_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn ser_secs(&self) -> f64 {
        self.ser_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn deser_secs(&self) -> f64 {
        self.deser_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn dispatch_secs(&self) -> f64 {
        self.dispatch_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn net_secs(&self) -> f64 {
        self.net_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn disk_secs(&self) -> f64 {
        self.disk_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "tasks={} failures={} restarts={} recomputes={} shuffle_out={} shuffle_in={} records={} \
             ser={:.3}s deser={:.3}s dispatch={:.3}s net={:.3}s disk={:.3}s vm={:.3}s gc={:.3}s",
            self.tasks_launched.load(Ordering::Relaxed),
            self.task_failures.load(Ordering::Relaxed),
            self.job_restarts.load(Ordering::Relaxed),
            self.lineage_recomputes.load(Ordering::Relaxed),
            crate::util::stats::fmt_bytes(self.shuffle_bytes_written.load(Ordering::Relaxed)),
            crate::util::stats::fmt_bytes(self.shuffle_bytes_read.load(Ordering::Relaxed)),
            self.records_shuffled.load(Ordering::Relaxed),
            self.ser_secs(),
            self.deser_secs(),
            self.dispatch_secs(),
            self.net_secs(),
            self.disk_secs(),
            self.vm_secs(),
            self.gc_secs(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulators_add_up() {
        let m = SparkMetrics::new();
        m.tasks_launched.fetch_add(3, Ordering::Relaxed);
        m.add_ser(Duration::from_millis(10));
        m.add_ser(Duration::from_millis(5));
        assert!((m.ser_secs() - 0.015).abs() < 1e-9);
        assert!(m.summary().contains("tasks=3"));
    }
}
