//! Execution metrics for the Spark-sim engine — the phase/overhead
//! breakdown the ablation benches report.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::trace::MetricSet;

#[derive(Debug, Default)]
pub struct SparkMetrics {
    pub tasks_launched: AtomicU64,
    pub task_failures: AtomicU64,
    pub job_restarts: AtomicU64,
    pub shuffle_bytes_written: AtomicU64,
    pub shuffle_bytes_read: AtomicU64,
    pub records_shuffled: AtomicU64,
    /// Map partitions recomputed from lineage after a block loss.
    pub lineage_recomputes: AtomicU64,
    /// Nanosecond accumulators.
    ser_ns: AtomicU64,
    deser_ns: AtomicU64,
    dispatch_ns: AtomicU64,
    net_ns: AtomicU64,
    disk_ns: AtomicU64,
    vm_ns: AtomicU64,
    gc_ns: AtomicU64,
}

impl SparkMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_ser(&self, d: Duration) {
        self.ser_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn add_deser(&self, d: Duration) {
        self.deser_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn add_dispatch(&self, d: Duration) {
        self.dispatch_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn add_net(&self, d: Duration) {
        self.net_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn add_disk(&self, d: Duration) {
        self.disk_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn add_vm(&self, d: Duration) {
        self.vm_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn add_gc(&self, d: Duration) {
        self.gc_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn vm_secs(&self) -> f64 {
        self.vm_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn gc_secs(&self) -> f64 {
        self.gc_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn ser_secs(&self) -> f64 {
        self.ser_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn deser_secs(&self) -> f64 {
        self.deser_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn dispatch_secs(&self) -> f64 {
        self.dispatch_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn net_secs(&self) -> f64 {
        self.net_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn disk_secs(&self) -> f64 {
        self.disk_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// The breakdown as a typed [`MetricSet`] — same keys, same order,
    /// same rendering as the old hand-formatted summary line.
    pub fn metric_set(&self) -> MetricSet {
        MetricSet::new()
            .with_count("tasks", self.tasks_launched.load(Ordering::Relaxed))
            .with_count("failures", self.task_failures.load(Ordering::Relaxed))
            .with_count("restarts", self.job_restarts.load(Ordering::Relaxed))
            .with_count("recomputes", self.lineage_recomputes.load(Ordering::Relaxed))
            .with_bytes("shuffle_out", self.shuffle_bytes_written.load(Ordering::Relaxed))
            .with_bytes("shuffle_in", self.shuffle_bytes_read.load(Ordering::Relaxed))
            .with_count("records", self.records_shuffled.load(Ordering::Relaxed))
            .with_secs("ser", self.ser_secs())
            .with_secs("deser", self.deser_secs())
            .with_secs("dispatch", self.dispatch_secs())
            .with_secs("net", self.net_secs())
            .with_secs("disk", self.disk_secs())
            .with_secs("vm", self.vm_secs())
            .with_secs("gc", self.gc_secs())
    }

    /// One-line human summary (the rendered [`Self::metric_set`]).
    pub fn summary(&self) -> String {
        self.metric_set().to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulators_add_up() {
        let m = SparkMetrics::new();
        m.tasks_launched.fetch_add(3, Ordering::Relaxed);
        m.add_ser(Duration::from_millis(10));
        m.add_ser(Duration::from_millis(5));
        assert!((m.ser_secs() - 0.015).abs() < 1e-9);
        assert!(m.summary().contains("tasks=3"));
    }

    #[test]
    fn metric_set_renders_the_legacy_summary_format() {
        let m = SparkMetrics::new();
        m.tasks_launched.fetch_add(3, Ordering::Relaxed);
        m.shuffle_bytes_written.fetch_add(2048, Ordering::Relaxed);
        m.add_ser(Duration::from_millis(10));
        assert_eq!(
            m.summary(),
            format!(
                "tasks=3 failures=0 restarts=0 recomputes=0 shuffle_out={} shuffle_in={} \
                 records=0 ser=0.010s deser=0.000s dispatch=0.000s net=0.000s disk=0.000s \
                 vm=0.000s gc=0.000s",
                crate::util::stats::fmt_bytes(2048),
                crate::util::stats::fmt_bytes(0),
            )
        );
        assert_eq!(m.metric_set().count("tasks"), 3);
        assert!((m.metric_set().value("ser") - 0.010).abs() < 1e-9);
    }
}
