//! `SparkContext` — driver, task scheduler, and executor simulation.
//!
//! Execution model mirrored from Spark:
//!
//! * the **driver** (`run_job`) resolves the lineage into stages and runs
//!   them in dependency order;
//! * each **stage** is a set of tasks, one per partition; task `p`
//!   *belongs* to node `p % nnodes` (shuffle-block ownership,
//!   executor-loss scope), while the tasks themselves execute as
//!   stealable units on the process-wide work-stealing pool
//!   ([`crate::runtime::Executor`], the real `--threads` knob —
//!   `threads_per_node` stays a cost-model parameter);
//! * every task attempt pays `task_launch_overhead` (driver dispatch +
//!   task deserialization, milliseconds in real Spark);
//! * task failures (from the [`FailurePlan`]) are retried up to
//!   `max_task_retries` when fault tolerance is on; otherwise they abort
//!   the job, and the driver restarts it from scratch up to
//!   `max_job_restarts` times — the paper's "simply run the task multiple
//!   times" regime.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::cache::PartitionCache;
use crate::cluster::FailurePlan;
use crate::runtime::executor::Executor;
use crate::storage::{DiskTier, StorageCounters, StorageStats};

use super::conf::SparkConf;
use super::block::ShuffleBlockStore;
use super::jvm::GcSim;
use super::metrics::SparkMetrics;
use super::rdd::{ComputeFn, JobError, Rdd};

pub struct CtxInner {
    pub conf: SparkConf,
    pub store: ShuffleBlockStore,
    pub metrics: SparkMetrics,
    pub gc: GcSim,
    pub failures: std::sync::Arc<FailurePlan>,
    /// Storage pool for `Rdd::persist`/`cache` (sized by
    /// `conf.cache_budget` unless a shared instance was injected; gets a
    /// disk tier — `MEMORY_AND_DISK` — when `conf.spill_threshold` is
    /// set).
    pub cache: Arc<PartitionCache>,
    /// The context's disk tier: persisted shuffle blocks and
    /// shuffle-spill runs write through it, so the job's disk traffic
    /// lands in one counters cell.
    pub disk: Arc<DiskTier>,
    /// Spill-side counters of the context-*owned* cache (`None` when the
    /// cache was injected — its owner accounts that activity).
    cache_storage: Option<Arc<StorageCounters>>,
}

/// Namespace allocator for ad-hoc `persist()` calls. Process-wide, not
/// per-context: contexts can share one [`PartitionCache`] (see
/// [`SparkContext::with_shared_cache`]), and two contexts restarting a
/// private counter would collide on the same namespaces and serve each
/// other's persisted partitions. Starts above the relation-index
/// namespaces the generic job layer reserves.
static NEXT_PERSIST_NS: AtomicU64 = AtomicU64::new(1 << 32);

/// Handed to every task: which node it runs on + shared context.
pub struct TaskCtx<'a> {
    pub inner: &'a CtxInner,
    /// Simulated node executing this task.
    pub node: usize,
}

#[derive(Clone)]
pub struct SparkContext {
    inner: Arc<CtxInner>,
}

impl SparkContext {
    pub fn new(conf: SparkConf) -> Self {
        Self::with_failures(conf, FailurePlan::none())
    }

    pub fn with_failures(conf: SparkConf, failures: FailurePlan) -> Self {
        Self::with_failures_arc(conf, Arc::new(failures))
    }

    /// Like [`with_failures`](Self::with_failures) with a shared plan
    /// (used by the unified `wordcount` front-end).
    pub fn with_failures_arc(conf: SparkConf, failures: Arc<FailurePlan>) -> Self {
        // With the spill knob set, the context-owned cache gets its own
        // disk tier: persist becomes MEMORY_AND_DISK instead of the
        // lossy MEMORY_ONLY evict+recompute.
        let (cache, cache_storage) = if conf.spill_threshold.is_some() {
            let cache_disk =
                Arc::new(DiskTier::new(conf.spill_dir.clone()).compression(conf.compress));
            let cell = Arc::clone(cache_disk.counters());
            let cache = PartitionCache::with_spill_policy(
                conf.cache_budget,
                cache_disk,
                conf.eviction_policy,
            );
            (Arc::new(cache), Some(cell))
        } else {
            (Arc::new(PartitionCache::with_policy(conf.cache_budget, conf.eviction_policy)), None)
        };
        Self::build(conf, failures, cache, cache_storage)
    }

    /// Build a context over an externally owned [`PartitionCache`]
    /// (ignoring `conf.cache_budget`). The iterative driver hands every
    /// round's context the same cache so persisted partitions outlive a
    /// single job. The injected cache's storage activity is accounted by
    /// its owner, not by [`SparkContext::storage_stats`].
    pub fn with_shared_cache(
        conf: SparkConf,
        failures: Arc<FailurePlan>,
        cache: Arc<PartitionCache>,
    ) -> Self {
        Self::build(conf, failures, cache, None)
    }

    fn build(
        conf: SparkConf,
        failures: Arc<FailurePlan>,
        cache: Arc<PartitionCache>,
        cache_storage: Option<Arc<StorageCounters>>,
    ) -> Self {
        assert!(conf.nnodes > 0 && conf.threads_per_node > 0);
        let disk = Arc::new(DiskTier::new(conf.spill_dir.clone()).compression(conf.compress));
        let store = ShuffleBlockStore::new(conf.fault_tolerance.then(|| Arc::clone(&disk)));
        let gc = GcSim::new(conf.gc_model);
        Self {
            inner: Arc::new(CtxInner {
                conf,
                store,
                metrics: SparkMetrics::new(),
                gc,
                failures,
                cache,
                disk,
                cache_storage,
            }),
        }
    }

    /// This context's storage-hierarchy activity: shuffle spill +
    /// persisted shuffle blocks, plus the context-owned cache's
    /// demotions/promotions when it has one. Contexts are per-job, so
    /// the snapshot is the job's total.
    pub fn storage_stats(&self) -> StorageStats {
        let mut stats = self.inner.disk.counters().snapshot();
        if let Some(cell) = &self.inner.cache_storage {
            stats = stats.merged(&cell.snapshot());
        }
        stats
    }

    pub fn inner(&self) -> &CtxInner {
        &self.inner
    }

    /// The storage pool behind `Rdd::persist`/`cache`.
    pub fn partition_cache(&self) -> &Arc<PartitionCache> {
        &self.inner.cache
    }

    /// Fresh namespace for an ad-hoc `persist()` (disjoint from the
    /// relation-index namespaces the generic job layer reserves, and from
    /// every other context's — the allocator is process-wide because the
    /// cache can be shared).
    pub(crate) fn fresh_persist_namespace(&self) -> u64 {
        NEXT_PERSIST_NS.fetch_add(1, Ordering::Relaxed)
    }

    pub fn conf(&self) -> &SparkConf {
        &self.inner.conf
    }

    pub fn metrics(&self) -> &SparkMetrics {
        &self.inner.metrics
    }

    /// Default partition count: 2 tasks per worker thread cluster-wide
    /// (Spark's guidance of 2–4× parallelism).
    pub fn default_partitions(&self) -> usize {
        self.inner.conf.nnodes * self.inner.conf.threads_per_node * 2
    }

    /// Source RDD from an in-memory vector, chunked into `partitions`.
    pub fn parallelize<T>(&self, data: Vec<T>, partitions: usize) -> Rdd<T>
    where
        T: Clone + Send + Sync + 'static,
    {
        assert!(partitions > 0);
        let data = Arc::new(data);
        let compute: ComputeFn<T> = Arc::new(move |_tc, p| {
            let (lo, hi) = partition_bounds(data.len(), partitions, p);
            data[lo..hi].to_vec()
        });
        Rdd {
            ctx: self.clone(),
            num_partitions: partitions,
            stage: 0,
            compute,
            upstream: Vec::new(),
        }
    }

    /// Source RDD over corpus lines (Spark's `textFile` analog: each task
    /// materializes its split as owned strings, as a JVM executor would
    /// when reading HDFS blocks).
    pub fn text_lines(&self, lines: Arc<Vec<String>>, partitions: usize) -> Rdd<String> {
        assert!(partitions > 0);
        let compute: ComputeFn<String> = Arc::new(move |_tc, p| {
            let (lo, hi) = partition_bounds(lines.len(), partitions, p);
            lines[lo..hi].to_vec()
        });
        Rdd {
            ctx: self.clone(),
            num_partitions: partitions,
            stage: 0,
            compute,
            upstream: Vec::new(),
        }
    }

    /// Like [`text_lines`](Self::text_lines), but each item carries its
    /// global line index. Generic workloads need record identity (e.g. the
    /// inverted index keys postings by line id).
    pub fn text_lines_indexed(
        &self,
        lines: Arc<Vec<String>>,
        partitions: usize,
    ) -> Rdd<(u64, String)> {
        assert!(partitions > 0);
        let compute: ComputeFn<(u64, String)> = Arc::new(move |_tc, p| {
            let (lo, hi) = partition_bounds(lines.len(), partitions, p);
            (lo..hi).map(|i| (i as u64, lines[i].clone())).collect()
        });
        Rdd {
            ctx: self.clone(),
            num_partitions: partitions,
            stage: 0,
            compute,
            upstream: Vec::new(),
        }
    }

    /// Run one stage's tasks across the simulated cluster. `body` must be
    /// retry-safe. Returns when all tasks have succeeded.
    pub(crate) fn run_stage(
        &self,
        stage: usize,
        num_partitions: usize,
        body: impl Fn(&TaskCtx, usize) + Sync,
    ) -> Result<(), JobError> {
        let inner = &*self.inner;
        let conf = &inner.conf;
        let error: Mutex<Option<JobError>> = Mutex::new(None);

        // One stealable task per partition on the shared work-stealing
        // pool. Task `p` still *belongs* to simulated node `p % nnodes`
        // (shuffle-block ownership, executor-loss scope) no matter which
        // pool worker steals it.
        let exec = Executor::for_threads(conf.threads);
        let ran = exec.run_tasks(num_partitions, |_ctx, p| {
            if error.lock().unwrap().is_some() {
                return; // job already failed; drain quickly
            }
            let tc = TaskCtx { inner, node: p % conf.nnodes };
            if let Err(e) = run_task_with_retries(&tc, stage, p, &body) {
                error.lock().unwrap().get_or_insert(e);
            }
        });
        if let Err(e) = ran {
            // A panicking task body fails its task — the pool survives —
            // and surfaces like any failed task, feeding the driver's
            // whole-job restart loop.
            error
                .lock()
                .unwrap()
                .get_or_insert(JobError::TaskFailed { stage, partition: e.first_task });
        }

        match error.into_inner().unwrap() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Driver entry: run `rdd`'s full lineage and materialize it. Handles
    /// the no-FT whole-job restart loop.
    pub(crate) fn run_job<T: Send + Sync + 'static>(
        &self,
        rdd: &Rdd<T>,
    ) -> Result<Vec<T>, JobError> {
        let conf = &self.inner.conf;
        let mut restarts = 0usize;
        loop {
            match self.try_job_once(rdd) {
                Ok(v) => return Ok(v),
                Err(e) => {
                    let retryable = !conf.fault_tolerance
                        && matches!(e, JobError::TaskFailed { .. })
                        && restarts < conf.max_job_restarts;
                    if !retryable {
                        return Err(e);
                    }
                    restarts += 1;
                    self.inner
                        .metrics
                        .job_restarts
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    // Blaze-style recovery: throw everything away, rerun.
                    self.inner.store.clear();
                    for dep in &rdd.upstream {
                        dep.reset();
                    }
                }
            }
        }
    }

    fn try_job_once<T: Send + Sync + 'static>(&self, rdd: &Rdd<T>) -> Result<Vec<T>, JobError> {
        // 1. Materialize all shuffle dependencies (map stages), in order.
        for dep in &rdd.upstream {
            dep.ensure(self)?;
        }
        // Injected executor loss: the node's shuffle output vanishes after
        // the map stage; reduce tasks will recover via lineage.
        while let Some(rank) = self.inner.failures.take_lost_executor() {
            let lost = self.inner.store.remove_owned_by(rank);
            crate::log_warn!(
                "spark",
                "executor {rank} lost: {lost} shuffle block(s) gone, recovering from lineage"
            );
        }
        // 2. Result stage: compute each output partition, keep order.
        let slots: Vec<Mutex<Vec<T>>> =
            (0..rdd.num_partitions).map(|_| Mutex::new(Vec::new())).collect();
        let compute = &rdd.compute;
        self.run_stage(rdd.stage, rdd.num_partitions, |tc, p| {
            let out = compute(tc, p);
            *slots[p].lock().unwrap() = out;
        })?;
        let mut all = Vec::new();
        for s in slots {
            all.extend(s.into_inner().unwrap());
        }
        Ok(all)
    }
}

/// Element bounds `[lo, hi)` of partition `p` when `n` items split into
/// `partitions` contiguous chunks, remainder spread over the first
/// `n % partitions` partitions. Shared by every source RDD so indexed and
/// unindexed sources partition identically.
fn partition_bounds(n: usize, partitions: usize, p: usize) -> (usize, usize) {
    let base = n / partitions;
    let rem = n % partitions;
    let lo = p * base + p.min(rem);
    let hi = lo + base + usize::from(p < rem);
    (lo, hi)
}

/// One task with Spark's attempt semantics.
fn run_task_with_retries(
    tc: &TaskCtx,
    stage: usize,
    partition: usize,
    body: impl Fn(&TaskCtx, usize),
) -> Result<(), JobError> {
    let inner = tc.inner;
    let conf = &inner.conf;
    let max_attempts = if conf.fault_tolerance { conf.max_task_retries.max(1) } else { 1 };
    for _attempt in 0..max_attempts {
        inner
            .metrics
            .tasks_launched
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        // Dispatch latency (driver → executor).
        if !conf.task_launch_overhead.is_zero() {
            std::thread::sleep(conf.task_launch_overhead);
            inner.metrics.add_dispatch(conf.task_launch_overhead);
        }
        // Injected failure?
        if inner.failures.should_fail_task(stage, partition) {
            inner
                .metrics
                .task_failures
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            if conf.fault_tolerance {
                continue; // retry from lineage
            }
            return Err(JobError::TaskFailed { stage, partition });
        }
        body(tc, partition);
        return Ok(());
    }
    Err(JobError::RetriesExhausted { stage, partition })
}
