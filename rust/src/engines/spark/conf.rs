//! Spark-sim configuration: the cost knobs that stand in for the JVM/Spark
//! mechanisms the paper blames for the performance gap.
//!
//! Each knob maps to one of the paper's three explanations and is toggled
//! by an ablation bench:
//!
//! | knob | Spark mechanism modeled | paper's cause | ablation |
//! |---|---|---|---|
//! | `serialize_shuffle` | records serialized at every stage boundary | "runs through a virtual machine" (serde + UTF-8 re-validation) | A1 |
//! | `boxed_records` | per-record heap objects (JVM object model) | same | A1 |
//! | `fault_tolerance` | shuffle blocks persisted to disk + task retry from lineage | "fault tolerance incurs additional overhead" | A2 |
//! | `map_side_combine` | per-partition combiner at shuffle write | contrast with Blaze's *continuous* combine | A3 |
//! | `task_launch_overhead` | driver → executor task dispatch latency | (implementation overhead) | — |

use std::path::PathBuf;
use std::time::Duration;

use crate::cache::CacheBudget;
use crate::cluster::NetModel;
use crate::storage::PolicySpec;

#[derive(Clone, Debug)]
pub struct SparkConf {
    /// Simulated cluster size.
    pub nnodes: usize,
    /// **Simulated** worker threads per node (r5.xlarge = 4 vCPU) — a
    /// cost-model parameter that shapes `default_partitions` and the
    /// modeled reports, *not* how many OS threads run. Real parallelism
    /// is [`SparkConf::threads`].
    pub threads_per_node: usize,
    /// **Real** executor width: stage partitions dispatch as stealable
    /// tasks onto the process-wide work-stealing pool
    /// ([`crate::runtime::Executor`]) of this many workers. `None` = auto
    /// (`BLAZE_THREADS`, else the machine's available parallelism).
    pub threads: Option<usize>,
    /// Network cost model for cross-node shuffle fetches.
    pub net: NetModel,
    /// Persist shuffle blocks to local "disk" (a temp dir) and retry failed
    /// tasks from lineage. Off = Blaze-style no-FT (job restarts on failure).
    pub fault_tolerance: bool,
    /// Serialize records at stage boundaries (JVM executors must; a native
    /// engine moving in-memory structs need not).
    pub serialize_shuffle: bool,
    /// Allocate each record as a separate heap object in the hot paths
    /// (JVM object-model pressure proxy).
    pub boxed_records: bool,
    /// Model Java-8 UTF-16 strings: every pipeline string is decoded to
    /// UTF-16 on creation and encoded back at the wire (see `jvm::JvmWord`).
    pub jvm_strings: bool,
    /// Model allocation-rate-driven minor GC pauses (see `jvm::GcSim`).
    pub gc_model: bool,
    /// JVM-vs-native instruction-throughput ratio applied to task *compute*
    /// time (not to modeled sleeps). The memory-side JVM costs (UTF-16,
    /// allocation, GC) are executed mechanically; this factor stands in for
    /// the part that cannot be executed natively — bytecode dispatch, JIT
    /// quality on megamorphic iterator chains, safepoint polling. 2.5 is
    /// the conservative middle of published JVM-vs-C++ ratios for
    /// string/allocation-heavy workloads. Set to 1.0 to ablate (A1).
    pub vm_execution_factor: f64,
    /// Map-side combining at shuffle write (Spark's `reduceByKey` does this).
    pub map_side_combine: bool,
    /// Per-task dispatch latency (driver scheduling + task deserialization;
    /// Spark's is on the order of milliseconds).
    pub task_launch_overhead: Duration,
    /// Task retries before the job is declared failed (Spark default: 4
    /// attempts).
    pub max_task_retries: usize,
    /// Whole-job restarts allowed when `fault_tolerance` is off.
    pub max_job_restarts: usize,
    /// Size of the `Rdd::persist`/`cache` storage pool — the
    /// `spark.memory.fraction` stand-in (see [`crate::cache`] for the
    /// exact mapping). Ignored when the context is built over an injected
    /// shared cache.
    pub cache_budget: CacheBudget,
    /// Bounded-memory exchange (`spark.shuffle.spill` +
    /// `ExternalAppendOnlyMap`): reduce-side merges beyond this many
    /// in-flight bytes sort-and-spill runs to the context's disk tier
    /// and merge externally. This is the default for direct
    /// `Rdd::reduce_by_key` use; the engine's plan path executes the
    /// per-stage threshold the planner recorded
    /// ([`crate::mapreduce::StagePlan::spill_threshold`]) instead. Also
    /// arms the persist cache's disk tier (`MEMORY_AND_DISK` instead of
    /// `MEMORY_ONLY`) when the context builds its own cache. `None` =
    /// the unbounded in-memory exchange.
    pub spill_threshold: Option<u64>,
    /// Directory for spill files and persisted shuffle blocks (`None` =
    /// the system temp dir).
    pub spill_dir: Option<PathBuf>,
    /// Eviction policy of the persist cache the context builds over
    /// `cache_budget` (the `--cache-policy` knob). Ignored when the
    /// context is built over an injected shared cache, which keeps the
    /// policy it was constructed with.
    pub eviction_policy: PolicySpec,
    /// Block-compress everything the context's disk tiers store — spill
    /// runs, persisted shuffle blocks, demoted persist splits (Spark's
    /// `spark.shuffle.compress` / `spark.io.compression.codec`; the
    /// `--compress` knob). Ignored for an injected shared cache, whose
    /// disk tier keeps the codec it was built with.
    pub compress: bool,
    /// Dictionary-encode repeated keys in shuffle payloads and spill
    /// runs (the `--dict-keys` knob).
    pub dict_keys: bool,
}

impl Default for SparkConf {
    fn default() -> Self {
        Self {
            nnodes: 1,
            threads_per_node: 4,
            threads: None,
            net: NetModel::aws_like(),
            fault_tolerance: true,
            serialize_shuffle: true,
            boxed_records: true,
            jvm_strings: true,
            gc_model: true,
            vm_execution_factor: 2.5,
            map_side_combine: true,
            task_launch_overhead: Duration::from_millis(2),
            max_task_retries: 4,
            max_job_restarts: 3,
            cache_budget: CacheBudget::Unbounded,
            spill_threshold: None,
            spill_dir: None,
            eviction_policy: PolicySpec::default(),
            compress: true,
            dict_keys: true,
        }
    }
}

impl SparkConf {
    /// Faithful EMR-like defaults at a given cluster shape.
    pub fn emr_like(nnodes: usize, threads_per_node: usize) -> Self {
        Self { nnodes, threads_per_node, ..Default::default() }
    }

    /// All overhead knobs off — the "what if Spark were native, non-FT,
    /// zero-dispatch" hypothetical used as the ablation floor.
    pub fn stripped(nnodes: usize, threads_per_node: usize) -> Self {
        Self {
            nnodes,
            threads_per_node,
            threads: None,
            net: NetModel::aws_like(),
            fault_tolerance: false,
            serialize_shuffle: false,
            boxed_records: false,
            jvm_strings: false,
            gc_model: false,
            vm_execution_factor: 1.0,
            map_side_combine: true,
            task_launch_overhead: Duration::ZERO,
            max_task_retries: 1,
            max_job_restarts: 3,
            cache_budget: CacheBudget::Unbounded,
            spill_threshold: None,
            spill_dir: None,
            eviction_policy: PolicySpec::default(),
            compress: true,
            dict_keys: true,
        }
    }

    /// Fast config for unit tests:
    /// no sleeps, no disk, ideal network.
    pub fn for_tests(nnodes: usize, threads_per_node: usize) -> Self {
        Self {
            nnodes,
            threads_per_node,
            threads: None,
            net: NetModel::ideal(),
            fault_tolerance: true,
            serialize_shuffle: true,
            boxed_records: false,
            jvm_strings: false,
            gc_model: false,
            vm_execution_factor: 1.0,
            map_side_combine: true,
            task_launch_overhead: Duration::ZERO,
            max_task_retries: 4,
            max_job_restarts: 3,
            cache_budget: CacheBudget::Unbounded,
            spill_threshold: None,
            spill_dir: None,
            eviction_policy: PolicySpec::default(),
            compress: true,
            dict_keys: true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_model_real_spark() {
        let c = SparkConf::default();
        assert!(c.fault_tolerance);
        assert!(c.serialize_shuffle);
        assert!(c.jvm_strings);
        assert!(c.gc_model);
        assert!(c.map_side_combine);
        assert!(c.task_launch_overhead > Duration::ZERO);
        assert!(c.vm_execution_factor > 1.0);
    }

    #[test]
    fn stripped_removes_overheads() {
        let c = SparkConf::stripped(2, 4);
        assert!(!c.fault_tolerance);
        assert!(!c.serialize_shuffle);
        assert!(!c.boxed_records);
        assert!(!c.jvm_strings);
        assert!(!c.gc_model);
        assert_eq!(c.task_launch_overhead, Duration::ZERO);
        assert_eq!(c.nnodes, 2);
    }
}
