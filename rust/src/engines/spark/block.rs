//! Shuffle block storage for the Spark-sim engine.
//!
//! Map tasks write one block per (map partition, reduce partition); reduce
//! tasks fetch all blocks of their reduce partition. Blocks are either raw
//! serialized bytes (when `serialize_shuffle`) or type-erased in-memory
//! record vectors (the native-engine ablation).
//!
//! With `fault_tolerance` on, serialized blocks are additionally persisted
//! through the context's [`DiskTier`] — real disk I/O, the same durability
//! cost Spark pays so that reduce-task retries and lost executors can
//! re-fetch map output without recomputing the map stage. Persisting
//! through the shared tier (rather than ad-hoc `File::create` calls, the
//! pre-storage-subsystem design) means the bytes are checksummed, land in
//! the job's [`StorageStats`](crate::storage::StorageStats) row, and share
//! the namespace map in [`crate::storage`]
//! (`NS_SHUFFLE_BLOCKS + shuffle_id`).

use std::any::Any;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::cache::CacheKey;
use crate::storage::{BlockStore, DiskTier, NS_SHUFFLE_BLOCKS};

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct BlockId {
    pub shuffle: usize,
    pub map_part: usize,
    pub reduce_part: usize,
}

pub enum BlockData {
    /// Serialized records (`Vec<(K, V)>` encoded with `util::ser`).
    Bytes(Vec<u8>),
    /// Type-erased `Vec<(K, V)>` moved without serialization, carrying a
    /// heap-size estimate so memory metrics (and cache budgets) account
    /// for native-engine blocks instead of silently reading them as 0.
    Typed { data: Box<dyn Any + Send + Sync>, est_bytes: usize },
}

impl BlockData {
    /// In-memory footprint: exact for serialized blocks, the caller's
    /// `HeapSize` estimate for typed (unserialized) blocks.
    pub fn byte_len(&self) -> usize {
        match self {
            BlockData::Bytes(b) => b.len(),
            BlockData::Typed { est_bytes, .. } => *est_bytes,
        }
    }
}

pub struct Block {
    /// Which simulated node produced (and stores) this block.
    pub owner_node: usize,
    pub data: BlockData,
    /// Records in the block (metrics).
    pub records: u64,
}

/// In-memory shuffle blocks + optional write-through persistence via the
/// context's disk tier.
pub struct ShuffleBlockStore {
    blocks: Mutex<HashMap<BlockId, Block>>,
    /// Disk tier serialized blocks are persisted through (fault
    /// tolerance on); `None` = memory-only blocks.
    persist: Option<Arc<DiskTier>>,
    next_shuffle_id: AtomicU64,
}

impl ShuffleBlockStore {
    pub fn new(persist: Option<Arc<DiskTier>>) -> Self {
        Self {
            blocks: Mutex::new(HashMap::new()),
            persist,
            next_shuffle_id: AtomicU64::new(0),
        }
    }

    /// The disk-tier key of one shuffle block (see the namespace map in
    /// [`crate::storage`]).
    fn block_key(id: &BlockId) -> CacheKey {
        CacheKey {
            namespace: NS_SHUFFLE_BLOCKS + id.shuffle as u64,
            generation: 0,
            partition: ((id.map_part as u64) << 32) | id.reduce_part as u64,
            splits: 0,
        }
    }

    pub fn fresh_shuffle_id(&self) -> usize {
        self.next_shuffle_id.fetch_add(1, Ordering::Relaxed) as usize
    }

    pub fn persists(&self) -> bool {
        self.persist.is_some()
    }

    /// Store a block; persists serialized blocks through the disk tier
    /// when enabled. Returns the bytes written to disk (0 if not
    /// persisted).
    pub fn put(&self, id: BlockId, block: Block) -> u64 {
        let mut disk_bytes = 0u64;
        if let (Some(disk), BlockData::Bytes(bytes)) = (&self.persist, &block.data) {
            disk_bytes =
                disk.write(Self::block_key(&id), bytes).expect("persist shuffle block");
        }
        self.blocks.lock().unwrap().insert(id, block);
        disk_bytes
    }

    /// Fetch a block's data for reading. Serialized blocks are cloned (the
    /// reader deserializes its own copy, as a remote fetch would); typed
    /// blocks are taken (single consumer).
    pub fn fetch(&self, id: BlockId) -> Option<(usize, FetchedData, u64)> {
        let mut map = self.blocks.lock().unwrap();
        match map.get(&id) {
            Some(Block { owner_node, data: BlockData::Bytes(b), records }) => {
                Some((*owner_node, FetchedData::Bytes(b.clone()), *records))
            }
            Some(Block { data: BlockData::Typed { .. }, .. }) => {
                // Take ownership of the typed payload.
                let Block { owner_node, data, records } = map.remove(&id).unwrap();
                match data {
                    BlockData::Typed { data, est_bytes } => {
                        Some((owner_node, FetchedData::Typed { data, est_bytes }, records))
                    }
                    BlockData::Bytes(_) => unreachable!(),
                }
            }
            None => None,
        }
    }

    /// Drop every block owned by `node` (simulated executor loss). Returns
    /// how many blocks disappeared. Persisted copies are removed too — the
    /// machine is gone, disk and all.
    pub fn remove_owned_by(&self, node: usize) -> usize {
        let mut map = self.blocks.lock().unwrap();
        let victims: Vec<BlockId> = map
            .iter()
            .filter(|(_, b)| b.owner_node == node)
            .map(|(id, _)| *id)
            .collect();
        for id in &victims {
            map.remove(id);
            if let Some(disk) = &self.persist {
                disk.delete(&Self::block_key(id));
            }
        }
        victims.len()
    }

    /// Drop all blocks (job restart / cleanup). Only this store's keys
    /// are retired from the (possibly shared) disk tier.
    pub fn clear(&self) {
        let mut map = self.blocks.lock().unwrap();
        if let Some(disk) = &self.persist {
            for id in map.keys() {
                disk.delete(&Self::block_key(id));
            }
        }
        map.clear();
    }

    pub fn len(&self) -> usize {
        self.blocks.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

pub enum FetchedData {
    Bytes(Vec<u8>),
    Typed { data: Box<dyn Any + Send + Sync>, est_bytes: usize },
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bid(m: usize, r: usize) -> BlockId {
        BlockId { shuffle: 0, map_part: m, reduce_part: r }
    }

    #[test]
    fn put_fetch_bytes() {
        let store = ShuffleBlockStore::new(None);
        store.put(bid(0, 1), Block { owner_node: 0, data: BlockData::Bytes(vec![1, 2, 3]), records: 3 });
        let (owner, data, records) = store.fetch(bid(0, 1)).unwrap();
        assert_eq!(owner, 0);
        assert_eq!(records, 3);
        match data {
            FetchedData::Bytes(b) => assert_eq!(b, vec![1, 2, 3]),
            _ => panic!("expected bytes"),
        }
        // Bytes blocks can be fetched repeatedly (persisted semantics).
        assert!(store.fetch(bid(0, 1)).is_some());
    }

    #[test]
    fn put_fetch_typed_is_single_consumer() {
        let store = ShuffleBlockStore::new(None);
        let payload: Vec<(String, u64)> = vec![("a".into(), 1)];
        store.put(
            bid(1, 0),
            Block {
                owner_node: 2,
                data: BlockData::Typed { data: Box::new(payload), est_bytes: 41 },
                records: 1,
            },
        );
        let (_, data, _) = store.fetch(bid(1, 0)).unwrap();
        match data {
            FetchedData::Typed { data, est_bytes } => {
                let v = data.downcast::<Vec<(String, u64)>>().unwrap();
                assert_eq!(*v, vec![("a".to_string(), 1u64)]);
                assert_eq!(est_bytes, 41);
            }
            _ => panic!("expected typed"),
        }
        assert!(store.fetch(bid(1, 0)).is_none(), "typed blocks are moved out");
    }

    #[test]
    fn typed_blocks_report_estimated_bytes() {
        let data = BlockData::Typed { data: Box::new(vec![1u64, 2]), est_bytes: 32 };
        assert_eq!(data.byte_len(), 32);
        assert_eq!(BlockData::Bytes(vec![0u8; 7]).byte_len(), 7);
    }

    #[test]
    fn missing_block_is_none() {
        let store = ShuffleBlockStore::new(None);
        assert!(store.fetch(bid(9, 9)).is_none());
    }

    #[test]
    fn persistence_writes_through_the_disk_tier() {
        let disk = Arc::new(DiskTier::new(None));
        let store = ShuffleBlockStore::new(Some(Arc::clone(&disk)));
        let written = store.put(
            bid(0, 0),
            Block { owner_node: 0, data: BlockData::Bytes(vec![0u8; 100]), records: 10 },
        );
        assert_eq!(written, 100, "put reports logical bytes");
        // The tier compresses by default, so the all-zeros block lands
        // smaller than its logical size; counters track stored bytes.
        let stored = disk.bytes_stored();
        assert!(stored > 0 && stored < 100, "block persisted compressed: {stored}");
        assert_eq!(disk.counters().snapshot().disk_bytes_written, stored);
        store.clear();
        assert!(store.is_empty());
        assert_eq!(disk.bytes_stored(), 0, "clear retires the persisted copies");
    }

    #[test]
    fn executor_loss_removes_persisted_copies() {
        let disk = Arc::new(DiskTier::new(None));
        let store = ShuffleBlockStore::new(Some(Arc::clone(&disk)));
        store.put(bid(0, 0), Block { owner_node: 0, data: BlockData::Bytes(vec![1; 10]), records: 1 });
        store.put(bid(1, 1), Block { owner_node: 1, data: BlockData::Bytes(vec![2; 20]), records: 1 });
        assert_eq!(store.remove_owned_by(1), 1);
        assert_eq!(store.len(), 1);
        assert_eq!(disk.bytes_stored(), 10, "only the lost node's copies vanish");
    }

    #[test]
    fn shuffle_ids_are_fresh() {
        let store = ShuffleBlockStore::new(None);
        let a = store.fresh_shuffle_id();
        let b = store.fresh_shuffle_id();
        assert_ne!(a, b);
    }
}
