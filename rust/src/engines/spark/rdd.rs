//! RDD abstraction with Spark's execution semantics:
//!
//! * **lazy narrow transformations** (`map`, `flat_map`, `filter`) compose
//!   into a single per-partition compute function — Spark's pipelining —
//!   so a stage's task runs the whole narrow chain with no materialization
//!   between operators;
//! * **wide transformations** (`reduce_by_key`) cut the lineage into
//!   stages: the parent side becomes a *map stage* that writes shuffle
//!   blocks (one per reduce partition), and the result RDD's compute
//!   *fetches* those blocks — across the simulated network when the block
//!   lives on another node;
//! * **lineage** is the graph of [`StageRunner`]s hanging off each RDD.
//!   With fault tolerance on, a failed task is retried from lineage; with
//!   it off, any failure aborts the job (the driver restarts from scratch,
//!   Blaze-style);
//! * **persistence** (`persist`/`cache`) stores materialized partitions in
//!   the context's memory-budgeted [`crate::cache::PartitionCache`];
//!   evicted partitions silently recompute from lineage on next access —
//!   Spark's `MEMORY_ONLY` storage level.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::cache::CacheKey;
use crate::concurrent::MapKey;
use crate::hash::{bucket_of, HashKind};
use crate::storage::{fresh_spill_namespace, BlockStore, ExternalMerger};
use crate::util::ser::{decode_varint, encode_pairs, DataKey, Decode, DictReader, Encode, Reader};

use super::block::{Block, BlockData, BlockId, FetchedData};
use super::context::{SparkContext, TaskCtx};
use super::jvm::HeapSize;

/// Errors surfaced to the driver.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JobError {
    /// A task failed and fault tolerance is disabled.
    TaskFailed { stage: usize, partition: usize },
    /// A task exhausted its retry budget (FT on).
    RetriesExhausted { stage: usize, partition: usize },
    /// The whole job failed more times than `max_job_restarts`.
    JobAborted { restarts: usize },
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JobError::TaskFailed { stage, partition } => {
                write!(f, "task failed (stage {stage}, partition {partition}), no fault tolerance")
            }
            JobError::RetriesExhausted { stage, partition } => {
                write!(f, "task retries exhausted (stage {stage}, partition {partition})")
            }
            JobError::JobAborted { restarts } => {
                write!(f, "job aborted after {restarts} restart(s)")
            }
        }
    }
}

impl std::error::Error for JobError {}

/// Charge the JVM instruction-throughput tax on a measured compute span:
/// sleep `(factor - 1) x elapsed`, so wall-clock reflects a JVM executing
/// the same work (see `SparkConf::vm_execution_factor`).
pub(crate) fn vm_tax(tc: &TaskCtx, compute_elapsed: std::time::Duration) {
    let factor = tc.inner.conf.vm_execution_factor;
    if factor > 1.0 {
        let extra = compute_elapsed.mul_f64(factor - 1.0);
        if !extra.is_zero() {
            std::thread::sleep(extra);
            tc.inner.metrics.add_vm(extra);
        }
    }
}

/// Per-partition compute: the fused narrow-op chain of a stage.
pub type ComputeFn<T> = Arc<dyn Fn(&TaskCtx, usize) -> Vec<T> + Send + Sync>;

/// A runnable map stage (the parent side of a shuffle), with memoized
/// completion so diamond lineage runs each stage once per job.
pub trait StageRunner: Send + Sync {
    /// Ensure this stage's shuffle output exists (running upstream first).
    fn ensure(&self, ctx: &SparkContext) -> Result<(), JobError>;
    /// Forget completion (job restart).
    fn reset(&self);
}

/// Keys that can cross a shuffle boundary (`Ord` so the bounded-memory
/// exchange can sort spill runs; [`DataKey`] so blocks dictionary-encode
/// repeated keys and the read side decodes them zero-copy).
pub trait ShuffleKey:
    MapKey + DataKey + Encode + Decode + HeapSize + std::hash::Hash + Ord + Send + Sync + 'static
{
}
impl<
        T: MapKey + DataKey + Encode + Decode + HeapSize + std::hash::Hash + Ord + Send + Sync + 'static,
    > ShuffleKey for T
{
}

/// Values that can cross a shuffle boundary.
pub trait ShuffleVal: Clone + Encode + Decode + HeapSize + Send + Sync + 'static {}
impl<T: Clone + Encode + Decode + HeapSize + Send + Sync + 'static> ShuffleVal for T {}

pub struct Rdd<T: Send + 'static> {
    pub(crate) ctx: SparkContext,
    pub(crate) num_partitions: usize,
    /// Stage index of the tasks that compute this RDD's partitions
    /// (== number of shuffle boundaries below it). Used by failure plans.
    pub(crate) stage: usize,
    pub(crate) compute: ComputeFn<T>,
    pub(crate) upstream: Vec<Arc<dyn StageRunner>>,
}

impl<T: Send + 'static> Clone for Rdd<T> {
    fn clone(&self) -> Self {
        Self {
            ctx: self.ctx.clone(),
            num_partitions: self.num_partitions,
            stage: self.stage,
            compute: Arc::clone(&self.compute),
            upstream: self.upstream.clone(),
        }
    }
}

impl<T: Send + Sync + 'static> Rdd<T> {
    pub fn num_partitions(&self) -> usize {
        self.num_partitions
    }

    /// Stage index of this RDD's own tasks.
    pub fn stage(&self) -> usize {
        self.stage
    }

    /// Narrow: element-wise transform, fused into the current stage.
    pub fn map<U, F>(&self, f: F) -> Rdd<U>
    where
        U: Send + Sync + 'static,
        F: Fn(T) -> U + Send + Sync + 'static,
    {
        let parent = Arc::clone(&self.compute);
        Rdd {
            ctx: self.ctx.clone(),
            num_partitions: self.num_partitions,
            stage: self.stage,
            compute: Arc::new(move |tc, p| parent(tc, p).into_iter().map(&f).collect()),
            upstream: self.upstream.clone(),
        }
    }

    /// Narrow: one-to-many transform, fused into the current stage.
    pub fn flat_map<U, I, F>(&self, f: F) -> Rdd<U>
    where
        U: Send + Sync + 'static,
        I: IntoIterator<Item = U>,
        F: Fn(T) -> I + Send + Sync + 'static,
    {
        let parent = Arc::clone(&self.compute);
        Rdd {
            ctx: self.ctx.clone(),
            num_partitions: self.num_partitions,
            stage: self.stage,
            compute: Arc::new(move |tc, p| {
                parent(tc, p).into_iter().flat_map(&f).collect()
            }),
            upstream: self.upstream.clone(),
        }
    }

    /// Narrow: whole-partition transform, fused into the current stage
    /// (Spark's `mapPartitions`). The generic job layer uses this for
    /// per-shard partial reduces (e.g. top-K candidate selection).
    pub fn map_partitions<U, F>(&self, f: F) -> Rdd<U>
    where
        U: Send + Sync + 'static,
        F: Fn(Vec<T>) -> Vec<U> + Send + Sync + 'static,
    {
        let parent = Arc::clone(&self.compute);
        Rdd {
            ctx: self.ctx.clone(),
            num_partitions: self.num_partitions,
            stage: self.stage,
            compute: Arc::new(move |tc, p| f(parent(tc, p))),
            upstream: self.upstream.clone(),
        }
    }

    /// Concatenate this RDD's partitions with `other`'s (Spark's `union`):
    /// the result has `self.num_partitions() + other.num_partitions()`
    /// partitions and stays narrow — no data moves. A `reduce_by_key`
    /// downstream shuffles *both* sides into the same reduce partitions
    /// (co-partitioned by key hash), which is exactly Spark's
    /// union-then-shuffle join plan; the job layer uses this to co-group
    /// multi-input workloads.
    pub fn union(&self, other: &Rdd<T>) -> Rdd<T> {
        assert!(
            std::ptr::eq(
                self.ctx.inner() as *const _,
                other.ctx.inner() as *const _
            ),
            "union across different SparkContexts"
        );
        let n_left = self.num_partitions;
        let left = Arc::clone(&self.compute);
        let right = Arc::clone(&other.compute);
        let mut upstream = self.upstream.clone();
        upstream.extend(other.upstream.iter().cloned());
        Rdd {
            ctx: self.ctx.clone(),
            num_partitions: n_left + other.num_partitions,
            stage: self.stage.max(other.stage),
            compute: Arc::new(move |tc, p| {
                if p < n_left {
                    left(tc, p)
                } else {
                    right(tc, p - n_left)
                }
            }),
            upstream,
        }
    }

    /// Narrow: keep elements satisfying `f`.
    pub fn filter<F>(&self, f: F) -> Rdd<T>
    where
        T: Clone,
        F: Fn(&T) -> bool + Send + Sync + 'static,
    {
        let parent = Arc::clone(&self.compute);
        Rdd {
            ctx: self.ctx.clone(),
            num_partitions: self.num_partitions,
            stage: self.stage,
            compute: Arc::new(move |tc, p| {
                parent(tc, p).into_iter().filter(|x| f(x)).collect()
            }),
            upstream: self.upstream.clone(),
        }
    }

    /// Action: materialize every partition and concatenate in order.
    pub fn collect(&self) -> Result<Vec<T>, JobError> {
        self.ctx.run_job(self)
    }

    /// Action: total element count.
    pub fn count(&self) -> Result<u64, JobError> {
        Ok(self.collect()?.len() as u64)
    }
}

impl<T: Clone + HeapSize + Encode + Decode + Send + Sync + 'static> Rdd<T> {
    /// Spark's `persist()`: materialized partitions go into the context's
    /// [`PartitionCache`](crate::cache::PartitionCache) (size-aware, LRU,
    /// memory-budgeted — see that module for the `spark.memory.fraction`
    /// mapping). A later compute of the same partition is served from
    /// memory; when the entry is in **no tier** (evicted with no disk
    /// tier attached, or rejected by the budget), the partition is
    /// recomputed from its narrow lineage chain — Spark's `MEMORY_ONLY`
    /// storage-level contract. With a disk tier attached to the cache
    /// (`spill_threshold` set), evicted partitions demote to disk and
    /// promote back on access instead — `MEMORY_AND_DISK`. Entry sizes
    /// are `HeapSize` estimates, mirroring Spark's `SizeEstimator`.
    pub fn persist(&self) -> Rdd<T> {
        self.persist_keyed(self.ctx.fresh_persist_namespace(), 0)
    }

    /// Alias for [`persist`](Self::persist) (Spark's `cache()`).
    pub fn cache(&self) -> Rdd<T> {
        self.persist()
    }

    /// [`persist`](Self::persist) under an explicit cache identity. The
    /// generic job layer keys each input relation's parsed RDD by
    /// `(relation index, content generation)` so the cache survives across
    /// the per-round contexts of an iterative run.
    pub(crate) fn persist_keyed(&self, namespace: u64, generation: u64) -> Rdd<T> {
        let parent = Arc::clone(&self.compute);
        // Part of the cache key: entries cut for a different partition
        // count must never be served to this RDD.
        let splits = self.num_partitions as u64;
        let compute: ComputeFn<T> = Arc::new(move |tc, p| {
            // Budget 0: persist is a no-op, not a clone-then-reject detour
            // — the recompute ablation must time lineage recomputation.
            if tc.inner.cache.is_disabled() {
                return parent(tc, p);
            }
            let key = CacheKey { namespace, generation, partition: p as u64, splits };
            // Encoded lookup: falls through to the disk tier (promoting
            // demoted partitions) when the cache has one.
            if let Some(hit) = tc.inner.cache.get_encoded::<Vec<T>>(&key) {
                return (*hit).clone();
            }
            // Miss in every tier: recompute from lineage, then offer the
            // fresh partition back to the store — but only clone it when
            // some tier could actually admit it.
            let out = parent(tc, p);
            let bytes = out.heap_bytes() as u64;
            if tc.inner.cache.fits(bytes) {
                tc.inner.cache.put_encoded(key, Arc::new(out.clone()), bytes);
            }
            out
        });
        Rdd {
            ctx: self.ctx.clone(),
            num_partitions: self.num_partitions,
            stage: self.stage,
            compute,
            upstream: self.upstream.clone(),
        }
    }
}

impl<K: ShuffleKey, V: ShuffleVal> Rdd<(K, V)> {
    /// Wide: group by key and fold values with `reduce`. Cuts the lineage:
    /// the receiver becomes a map stage (shuffle write), the returned RDD
    /// reads shuffled blocks (shuffle fetch + merge). The reduce-side
    /// merge is memory-bounded by the context conf's `spill_threshold`
    /// (the direct-RDD-API default; the job layer's plan path passes the
    /// stage's planned threshold via
    /// [`reduce_by_key_spilled`](Self::reduce_by_key_spilled) instead).
    pub fn reduce_by_key(
        &self,
        reduce: fn(&mut V, V),
        num_out_partitions: usize,
    ) -> Rdd<(K, V)> {
        self.reduce_by_key_spilled(
            reduce,
            num_out_partitions,
            self.ctx.conf().spill_threshold,
        )
    }

    /// [`reduce_by_key`](Self::reduce_by_key) with an explicit
    /// bounded-memory budget for the reduce-side merge — how the engine's
    /// plan path honors
    /// [`crate::mapreduce::StagePlan::spill_threshold`]: the spill
    /// decision made at plan time, not the conf, governs plan execution.
    pub(crate) fn reduce_by_key_spilled(
        &self,
        reduce: fn(&mut V, V),
        num_out_partitions: usize,
        spill_threshold: Option<u64>,
    ) -> Rdd<(K, V)> {
        assert!(num_out_partitions > 0);
        let shuffle_id = self.ctx.inner().store.fresh_shuffle_id();
        let dep = Arc::new(ShuffleDep {
            shuffle_id,
            stage: self.stage,
            map_partitions: self.num_partitions,
            reduce_partitions: num_out_partitions,
            parent_compute: Arc::clone(&self.compute),
            parent_upstream: self.upstream.clone(),
            reduce,
            spill_threshold,
            done: AtomicBool::new(false),
        });

        let fetch_dep = Arc::clone(&dep);
        let compute: ComputeFn<(K, V)> = Arc::new(move |tc, r| fetch_dep.read_partition(tc, r));

        Rdd {
            ctx: self.ctx.clone(),
            num_partitions: num_out_partitions,
            stage: self.stage + 1,
            compute,
            upstream: vec![dep],
        }
    }

    /// Action: reduce and collect into a `HashMap`.
    pub fn reduce_by_key_collect(
        &self,
        reduce: fn(&mut V, V),
        num_out_partitions: usize,
    ) -> Result<HashMap<K, V>, JobError>
    where
        K: Eq,
    {
        Ok(self
            .reduce_by_key(reduce, num_out_partitions)
            .collect()?
            .into_iter()
            .collect())
    }
}

/// The shuffle dependency: runs the map stage (write side) on `ensure`,
/// serves the fetch side through `read_partition`.
pub(crate) struct ShuffleDep<K: ShuffleKey, V: ShuffleVal> {
    pub shuffle_id: usize,
    /// Stage index of the map tasks.
    pub stage: usize,
    pub map_partitions: usize,
    pub reduce_partitions: usize,
    pub parent_compute: ComputeFn<(K, V)>,
    pub parent_upstream: Vec<Arc<dyn StageRunner>>,
    pub reduce: fn(&mut V, V),
    /// Bounded-memory budget of the reduce-side merge (from the compiled
    /// stage on the plan path, from the conf for direct RDD use).
    pub spill_threshold: Option<u64>,
    pub done: AtomicBool,
}

/// Reduce-side accumulator: the in-memory map, or the bounded-memory
/// external merger when the conf sets a spill threshold.
enum ReduceAcc<K: ShuffleKey, V: ShuffleVal> {
    Mem(HashMap<K, V>),
    External(ExternalMerger<K, V>),
}

impl<K: ShuffleKey, V: ShuffleVal> ReduceAcc<K, V> {
    fn insert(&mut self, k: K, v: V, reduce: fn(&mut V, V)) {
        match self {
            ReduceAcc::Mem(map) => match map.entry(k) {
                std::collections::hash_map::Entry::Occupied(mut e) => reduce(e.get_mut(), v),
                std::collections::hash_map::Entry::Vacant(e) => {
                    e.insert(v);
                }
            },
            ReduceAcc::External(merger) => merger.insert(k, v, reduce),
        }
    }

    /// Zero-copy insert: combine through a decoded key handle,
    /// materializing the key only when it is new to the accumulator.
    fn insert_ref(&mut self, kr: K::Ref, dict: &DictReader, v: V, reduce: fn(&mut V, V)) {
        match self {
            ReduceAcc::Mem(map) => match K::map_get_mut(map, &kr, dict) {
                Some(slot) => reduce(slot, v),
                None => {
                    map.insert(K::ref_materialize(&kr, dict), v);
                }
            },
            ReduceAcc::External(merger) => merger.insert_ref(kr, dict, v, reduce),
        }
    }

    fn finish(self, reduce: fn(&mut V, V)) -> Vec<(K, V)> {
        match self {
            ReduceAcc::Mem(map) => map.into_iter().collect(),
            ReduceAcc::External(merger) => merger.finish(reduce),
        }
    }
}

impl<K: ShuffleKey, V: ShuffleVal> ShuffleDep<K, V> {
    /// Reduce-side read: fetch every map partition's block for reduce
    /// partition `r`, charging network cost for remote blocks, then merge
    /// — through the bounded-memory external merger when this shuffle's
    /// `spill_threshold` is set (Spark's `spark.shuffle.spill`).
    fn read_partition(&self, tc: &TaskCtx, r: usize) -> Vec<(K, V)> {
        let inner = tc.inner;
        let conf = &inner.conf;
        let mut acc: ReduceAcc<K, V> = match self.spill_threshold {
            Some(threshold) => ReduceAcc::External(
                ExternalMerger::new(
                    threshold,
                    Arc::clone(&inner.disk) as Arc<dyn BlockStore>,
                    Arc::clone(inner.disk.counters()),
                    fresh_spill_namespace(),
                )
                .with_dict_keys(conf.dict_keys),
            ),
            None => ReduceAcc::Mem(HashMap::new()),
        };
        let read_t0 = Instant::now();
        let mut slept = std::time::Duration::ZERO;
        for m in 0..self.map_partitions {
            let id = BlockId { shuffle: self.shuffle_id, map_part: m, reduce_part: r };
            let fetched = match inner.store.fetch(id) {
                Some(f) => Some(f),
                None => {
                    // Block lost (executor failure): recompute the missing
                    // map partition from lineage — Spark's recovery story.
                    // The narrow parent chain is deterministic, so this
                    // regenerates exactly the lost blocks.
                    inner
                        .metrics
                        .lineage_recomputes
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    self.write_partition(tc, m);
                    inner.store.fetch(id)
                }
            };
            let Some((owner, data, records)) = fetched else {
                panic!("missing shuffle block {id:?} even after lineage recompute");
            };
            inner.metrics.shuffle_bytes_read.fetch_add(
                match &data {
                    FetchedData::Bytes(b) => b.len() as u64,
                    FetchedData::Typed { .. } => 0,
                },
                Ordering::Relaxed,
            );
            inner.metrics.records_shuffled.fetch_add(records, Ordering::Relaxed);
            // Remote fetch crosses the simulated network.
            if owner != tc.node {
                let bytes = match &data {
                    FetchedData::Bytes(b) => b.len(),
                    // Typed (no-serde) transfers still move the records'
                    // in-memory footprint across the wire.
                    FetchedData::Typed { est_bytes, .. } => *est_bytes,
                };
                let cost = conf.net.cost(bytes);
                if !cost.is_zero() {
                    std::thread::sleep(cost);
                    slept += cost;
                }
                inner.metrics.add_net(cost);
            }
            match data {
                FetchedData::Bytes(b) => {
                    // Streaming decode against the block's dictionary:
                    // repeated keys resolve to one arena entry, and the
                    // combine probes the accumulator through the handle —
                    // keys materialize only when first seen.
                    let t0 = Instant::now();
                    let mut rd = Reader::new(&b);
                    let mut dict = DictReader::new();
                    let count = decode_varint(&mut rd).expect("shuffle block decode");
                    let mut alloc = 0usize;
                    for _ in 0..count {
                        let kr = K::dict_decode(&mut rd, &mut dict)
                            .expect("shuffle block decode");
                        let v = V::decode(&mut rd).expect("shuffle block decode");
                        alloc += v.heap_bytes();
                        if conf.boxed_records {
                            // JVM object-model proxy: each incoming record
                            // becomes its own heap allocation before merging.
                            let k = K::ref_materialize(&kr, &dict);
                            alloc += k.heap_bytes();
                            let boxed = Box::new((k, v));
                            let (k, v) = *boxed;
                            acc.insert(k, v, self.reduce);
                        } else {
                            acc.insert_ref(kr, &dict, v, self.reduce);
                        }
                    }
                    assert!(rd.is_empty(), "shuffle block decode: trailing bytes");
                    inner.metrics.add_deser(t0.elapsed());
                    // readUTF materializes fresh values; unique key
                    // payloads live once, in the decode arena.
                    inner.gc.allocated(alloc + dict.bytes_used());
                }
                FetchedData::Typed { data, .. } => {
                    let pairs = *data
                        .downcast::<Vec<(K, V)>>()
                        .expect("typed shuffle block of unexpected type");
                    if conf.boxed_records {
                        for boxed in pairs.into_iter().map(Box::new) {
                            let (k, v) = *boxed;
                            acc.insert(k, v, self.reduce);
                        }
                    } else {
                        for (k, v) in pairs {
                            acc.insert(k, v, self.reduce);
                        }
                    }
                }
            }
        }
        let out = acc.finish(self.reduce);
        // Deser + merge are JVM-executed; exclude the modeled network
        // time. Spill I/O wall is deliberately *included*: Spark's spill
        // path runs through JVM serializer streams
        // (`DiskBlockObjectWriter`), and the disk counters are shared
        // across concurrent tasks, so a per-task subtraction would
        // nondeterministically deduct other tasks' disk time.
        vm_tax(tc, read_t0.elapsed().saturating_sub(slept));
        out
    }

    /// Map-side write for one map partition: compute the parent chain,
    /// bucket by reduce partition (with optional map-side combine),
    /// optionally serialize, store (optionally persisting to disk).
    fn write_partition(&self, tc: &TaskCtx, m: usize) {
        let inner = tc.inner;
        let conf = &inner.conf;
        let compute_t0 = Instant::now();
        let pairs = (self.parent_compute)(tc, m);
        // GC accounting: these records were just materialized as objects.
        inner
            .gc
            .allocated(pairs.iter().map(HeapSize::heap_bytes).sum());
        let pairs = if conf.boxed_records {
            // Per-record heap objects on the write side too.
            pairs.into_iter().map(Box::new).map(|b| *b).collect()
        } else {
            pairs
        };

        let r_parts = self.reduce_partitions;
        // Bucket (and combine) by reduce partition.
        let mut buckets: Vec<Vec<(K, V)>> = (0..r_parts).map(|_| Vec::new()).collect();
        if conf.map_side_combine {
            if let Some(threshold) = self.spill_threshold {
                // Bounded map-side combine (ROADMAP 2b): the combiners
                // share the stage's spill budget, so a skew-heavy map
                // partition sort-and-spills instead of growing without
                // limit. Each merger's sorted output still encodes as one
                // block, so the read side is unchanged.
                let per_part = (threshold / r_parts as u64).max(1);
                let mut combined: Vec<ExternalMerger<K, V>> = (0..r_parts)
                    .map(|_| {
                        ExternalMerger::new(
                            per_part,
                            Arc::clone(&inner.disk) as Arc<dyn BlockStore>,
                            Arc::clone(inner.disk.counters()),
                            fresh_spill_namespace(),
                        )
                        .with_dict_keys(conf.dict_keys)
                    })
                    .collect();
                for (k, v) in pairs {
                    let r = bucket_of(k.hash_with(HashKind::Fx), r_parts);
                    combined[r].insert(k, v, self.reduce);
                }
                for (r, merger) in combined.into_iter().enumerate() {
                    buckets[r] = merger.finish(self.reduce);
                }
            } else {
                let mut combined: Vec<HashMap<K, V>> =
                    (0..r_parts).map(|_| HashMap::new()).collect();
                for (k, v) in pairs {
                    let r = bucket_of(k.hash_with(HashKind::Fx), r_parts);
                    match combined[r].entry(k) {
                        std::collections::hash_map::Entry::Occupied(mut e) => {
                            (self.reduce)(e.get_mut(), v)
                        }
                        std::collections::hash_map::Entry::Vacant(e) => {
                            e.insert(v);
                        }
                    }
                }
                for (r, map) in combined.into_iter().enumerate() {
                    buckets[r] = map.into_iter().collect();
                }
            }
        } else {
            for (k, v) in pairs {
                let r = bucket_of(k.hash_with(HashKind::Fx), r_parts);
                buckets[r].push((k, v));
            }
        }

        // The work above (narrow chain + combine) is JVM-executed code.
        vm_tax(tc, compute_t0.elapsed());

        // Write one block per reduce partition.
        for (r, bucket) in buckets.into_iter().enumerate() {
            let records = bucket.len() as u64;
            let data = if conf.serialize_shuffle {
                let t0 = Instant::now();
                // Dictionary-encode repeated keys (tag-0-only stream when
                // the knob is off — same self-describing format either
                // way, so the read side never consults the conf).
                let (bytes, dict) = encode_pairs(&bucket, conf.dict_keys);
                inner.disk.counters().record_dict(&dict);
                inner.gc.allocated(bytes.len());
                inner.metrics.add_ser(t0.elapsed());
                inner
                    .metrics
                    .shuffle_bytes_written
                    .fetch_add(bytes.len() as u64, Ordering::Relaxed);
                BlockData::Bytes(bytes)
            } else {
                // Unserialized blocks still occupy memory; estimate it so
                // block metrics don't undercount the native-engine path.
                let est_bytes = bucket.iter().map(HeapSize::heap_bytes).sum::<usize>();
                BlockData::Typed { data: Box::new(bucket), est_bytes }
            };
            let id = BlockId { shuffle: self.shuffle_id, map_part: m, reduce_part: r };
            let t0 = Instant::now();
            let disk = inner.store.put(id, Block { owner_node: tc.node, data, records });
            if disk > 0 {
                inner.metrics.add_disk(t0.elapsed());
            }
        }
    }
}

impl<K: ShuffleKey, V: ShuffleVal> StageRunner for ShuffleDep<K, V> {
    fn ensure(&self, ctx: &SparkContext) -> Result<(), JobError> {
        if self.done.load(Ordering::Acquire) {
            return Ok(());
        }
        for dep in &self.parent_upstream {
            dep.ensure(ctx)?;
        }
        ctx.run_stage(self.stage, self.map_partitions, |tc, m| {
            self.write_partition(tc, m);
        })?;
        self.done.store(true, Ordering::Release);
        Ok(())
    }

    fn reset(&self) {
        self.done.store(false, Ordering::Release);
        for dep in &self.parent_upstream {
            dep.reset();
        }
    }
}
