//! Spark-style baseline engine.
//!
//! A faithful-mechanism simulation of the Spark 2.4 pipeline the paper
//! benchmarks against (see `conf.rs` for which JVM/Spark costs are modeled
//! and how the ablations toggle them), generalized over [`Workload`]s:
//!
//! ```scala
//! textFile.flatMap(line => workload.map(line))   // narrow, fused
//!         .reduceByKey(workload.combine)         // stage cut + shuffle
//!         .mapPartitions(workload.finalizeLocal) // narrow, fused
//! ```
//!
//! Word count is [`crate::workloads::WordCount`] through [`run_workload`]
//! (or [`run_workload_jvm`] when `jvm_strings` models UTF-16 strings).
//! Multi-input jobs (joins) run through [`run_workload_multi`]: one
//! indexed-textFile chain per relation, `union`ed so a single
//! `reduceByKey` co-partitions every side.
//!
//! Since the planner layer ([`crate::mapreduce::plan`]) landed, this
//! engine is a **stage executor**: [`run_plan`] is its single
//! plan-execution path (union the per-relation chains, cut the stage at
//! the exchange — or skip the cut when the compiled [`StagePlan`] elided
//! it — then per-partition finalize and collect). The
//! `run_workload{,_multi,_cached,_jvm}` entry points survive only as thin
//! wrappers that build their per-relation mapped chains and hand them to
//! [`run_plan`]; cache points (which relations persist their parsed RDD,
//! under which namespace/generation) are read off the plan, not decided
//! here.

pub mod block;
pub mod conf;
pub mod context;
pub mod jvm;
pub mod metrics;
pub mod rdd;

pub use conf::SparkConf;
pub use jvm::{GcSim, HeapSize, JvmWord};
pub use context::{SparkContext, TaskCtx};
pub use metrics::SparkMetrics;
pub use rdd::{JobError, Rdd};

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::corpus::{Corpus, Tokenizer};
use crate::mapreduce::{CacheableWorkload, StagePlan, StrWorkload, Workload};
use rdd::{ShuffleKey, ShuffleVal};

/// The canonical word count on the Spark-sim engine. Returns the counts
/// (merged across partitions) or the job error.
pub fn word_count(
    ctx: &SparkContext,
    corpus: &Corpus,
    tokenizer: Tokenizer,
) -> Result<HashMap<String, u64>, JobError> {
    word_count_lines(ctx, Arc::new(corpus.lines.clone()), tokenizer)
}

/// `word_count` over shared lines (avoids cloning the corpus per run in
/// benches — the engine still clones per task, as `textFile` would).
pub fn word_count_lines(
    ctx: &SparkContext,
    lines: Arc<Vec<String>>,
    tokenizer: Tokenizer,
) -> Result<HashMap<String, u64>, JobError> {
    let w = Arc::new(crate::workloads::WordCount::new(tokenizer));
    let (entries, _emitted) = if ctx.conf().jvm_strings {
        let stage = StagePlan::single(w.name(), w.needs_shuffle(), 1);
        run_workload_jvm(ctx, &stage, lines, &w)?
    } else {
        run_workload(ctx, lines, &w)?
    };
    Ok(entries.into_iter().collect())
}

/// The engine's **single plan-execution path**, shared by every wrapper:
/// `union` the per-relation mapped chains, cut the stage at the exchange
/// when the compiled plan says so (`reduceByKey`: shuffle write + fetch
/// with all modeled costs), then per-partition finalize and collect.
///
/// A stage whose exchange was [elided](crate::mapreduce::Exchange::Elided)
/// at plan time skips the stage cut entirely: no serialization, no blocks
/// written — the finalize runs per *map* partition (exact, because such
/// keys are globally unique) and `SparkMetrics::shuffle_bytes_written`
/// stays 0.
pub fn run_plan<K, V, F>(
    ctx: &SparkContext,
    stage: &StagePlan,
    sources: Vec<Rdd<(K, V)>>,
    reduce: fn(&mut V, V),
    finalize_shard: F,
) -> Result<Vec<(K, V)>, JobError>
where
    K: ShuffleKey,
    V: ShuffleVal,
    F: Fn(Vec<(K, V)>) -> Vec<(K, V)> + Send + Sync + 'static,
{
    let _stage_span = crate::trace::span_arg(
        crate::trace::SpanCat::Stage,
        "spark",
        stage.id as u64,
    );
    let partitions = ctx.default_partitions();
    let mut pairs: Option<Rdd<(K, V)>> = None;
    for source in sources {
        pairs = Some(match pairs {
            Some(p) => p.union(&source),
            None => source,
        });
    }
    let pairs = pairs.expect("a stage needs at least one input source");
    if stage.runs_exchange() {
        // The stage cut honors the *planned* spill budget — the conf's
        // threshold is only the default for direct RDD-API use.
        pairs
            .reduce_by_key_spilled(reduce, partitions, stage.spill_threshold)
            .map_partitions(finalize_shard)
            .collect()
    } else {
        pairs.map_partitions(finalize_shard).collect()
    }
}

/// Run a generic [`Workload`] over one input relation: indexed textFile →
/// fused flatMap of the workload's map → the plan path's exchange +
/// per-partition `finalize_local` + collect. Returns the finalized
/// entries (key sets disjoint across partitions) and the number of
/// map-phase emissions observed. Thin wrapper: compiles the workload's
/// one-stage plan and hands it to [`run_workload_multi`].
pub fn run_workload<W: Workload>(
    ctx: &SparkContext,
    lines: Arc<Vec<String>>,
    w: &Arc<W>,
) -> Result<(Vec<(W::Key, W::Value)>, u64), JobError> {
    let stage = StagePlan::single(w.name(), w.needs_shuffle(), 1);
    run_workload_multi(ctx, &stage, std::slice::from_ref(&lines), w)
}

/// Run a generic [`Workload`] over N tagged input relations — Spark's
/// union-then-shuffle plan. Each relation becomes its own indexed
/// `textFile` → flatMap chain (tagged with its relation index, so
/// [`Workload::map_rel`] knows which side a record came from); the chains
/// are handed to [`run_plan`], which `union`s them so one `reduceByKey`
/// co-partitions every side's emissions — or skips the stage cut when the
/// plan elided the exchange. Thin wrapper over [`run_plan`].
pub fn run_workload_multi<W: Workload>(
    ctx: &SparkContext,
    stage: &StagePlan,
    relations: &[Arc<Vec<String>>],
    w: &Arc<W>,
) -> Result<(Vec<(W::Key, W::Value)>, u64), JobError> {
    assert!(!relations.is_empty(), "a job needs at least one input relation");
    let partitions = ctx.default_partitions();
    let emitted = Arc::new(AtomicU64::new(0));
    let mut sources = Vec::with_capacity(relations.len());
    for (rel, lines) in relations.iter().enumerate() {
        let text = ctx.text_lines_indexed(Arc::clone(lines), partitions);
        let counter = Arc::clone(&emitted);
        let wm = Arc::clone(w);
        // flatMap(record => workload.map_rel(rel, record)) — materializes
        // owned keys, exactly like the Scala example's String objects.
        sources.push(text.flat_map(move |(doc, line): (u64, String)| {
            let mut out = Vec::new();
            wm.map_rel(rel, doc, &line, &mut |k, v| out.push((k, v)));
            counter.fetch_add(out.len() as u64, Ordering::Relaxed);
            out
        }));
    }
    let wf = Arc::clone(w);
    let entries =
        run_plan(ctx, stage, sources, W::combine, move |shard| wf.finalize_local(shard))?;
    Ok((entries, emitted.load(Ordering::Relaxed)))
}

/// Run a [`CacheableWorkload`] with per-relation persisted parse RDDs —
/// Spark's canonical iterative-job plan:
///
/// ```scala
/// val parsed = textFile.map(parse).persist()          // hits after round 1
/// parsed.flatMap(p => step.map(p, broadcastState))    // re-run every round
///       .reduceByKey(step.combine)
/// ```
///
/// Each relation with a planned
/// [`CachePoint`](crate::mapreduce::CachePoint) persists its parsed RDD
/// under that point's namespace and content generation in the context's
/// [`PartitionCache`](crate::cache::PartitionCache); contexts built over a
/// shared cache (see [`SparkContext::with_shared_cache`]) therefore serve
/// later rounds of an iterative job from memory, and evicted partitions
/// transparently recompute from lineage. Relations whose plan carries no
/// cache point (no cache attached, or the recompute ablation) skip the
/// persist entirely. Otherwise identical to [`run_workload_multi`].
/// Thin wrapper over [`run_plan`].
pub fn run_workload_cached<W: CacheableWorkload>(
    ctx: &SparkContext,
    stage: &StagePlan,
    relations: &[Arc<Vec<String>>],
    w: &Arc<W>,
) -> Result<(Vec<(W::Key, W::Value)>, u64), JobError> {
    assert!(!relations.is_empty(), "a job needs at least one input relation");
    let partitions = ctx.default_partitions();
    let emitted = Arc::new(AtomicU64::new(0));
    let mut sources = Vec::with_capacity(relations.len());
    for (rel, lines) in relations.iter().enumerate() {
        let text = ctx.text_lines_indexed(Arc::clone(lines), partitions);
        let wp = Arc::clone(w);
        // map(parse).persist(): the cacheable half of the round, under
        // the identity the planner assigned (if it assigned one).
        let parsed = text.flat_map(move |(doc, line): (u64, String)| wp.parse_rel(rel, doc, &line));
        let parsed = match stage.cache_point(rel) {
            Some(cp) => parsed.persist_keyed(cp.namespace, cp.generation),
            None => parsed,
        };
        let wm = Arc::clone(w);
        let counter = Arc::clone(&emitted);
        sources.push(parsed.flat_map(move |p: W::Parsed| {
            let mut out = Vec::new();
            wm.map_parsed(rel, &p, &mut |k, v| out.push((k, v)));
            counter.fetch_add(out.len() as u64, Ordering::Relaxed);
            out
        }));
    }
    let wf = Arc::clone(w);
    let entries =
        run_plan(ctx, stage, sources, W::combine, move |shard| wf.finalize_local(shard))?;
    Ok((entries, emitted.load(Ordering::Relaxed)))
}

/// The Java-8-faithful pipeline for string-keyed workloads: every pipeline
/// string is a UTF-16 [`JvmWord`], so the engine pays the JVM's
/// decode/encode and memory-traffic costs at the same points a Spark
/// executor does (textFile read, split, writeUTF / readUTF at the
/// shuffle). Keys convert back to platform strings at the driver, where
/// `finalize_local` then runs once over the collected set (exact for
/// filtering partial reduces — see the trait contract). An exchange the
/// plan elided skips the `reduceByKey` stage cut like every other path.
/// Thin wrapper over [`run_plan`] (the per-partition finalize is the
/// identity here — the real finalize runs at the driver, after the
/// UTF-16 → platform-string conversion).
pub fn run_workload_jvm<W: StrWorkload>(
    ctx: &SparkContext,
    stage: &StagePlan,
    lines: Arc<Vec<String>>,
    w: &Arc<W>,
) -> Result<(Vec<(String, W::Value)>, u64), JobError> {
    let partitions = ctx.default_partitions();
    let text = ctx.text_lines_indexed(lines, partitions);
    let emitted = Arc::new(AtomicU64::new(0));
    let counter = Arc::clone(&emitted);
    let wm = Arc::clone(w);
    let pairs = text.flat_map(move |(doc, line): (u64, String)| {
        // new String(bytes, UTF_8): the JVM materializes the line as UTF-16
        // before any tokenization runs.
        let line16 = JvmWord::from_str(&line).to_string_lossy();
        let mut out = Vec::new();
        // Each emitted token is a fresh UTF-16 String.
        wm.map_str(doc, &line16, &mut |t, v| out.push((JvmWord::from_str(t), v)));
        counter.fetch_add(out.len() as u64, Ordering::Relaxed);
        out
    });
    let collected = run_plan(ctx, stage, vec![pairs], W::combine, |shard| shard)?;
    // Driver-side collect converts to platform strings once (outside the
    // engines' timed loops this is negligible; kept for API uniformity).
    let entries: Vec<(String, W::Value)> =
        collected.into_iter().map(|(k, v)| (k.to_string_lossy(), v)).collect();
    Ok((w.finalize_local(entries), emitted.load(Ordering::Relaxed)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::FailurePlan;
    use crate::corpus::CorpusSpec;

    fn tiny_corpus() -> Corpus {
        Corpus::from_text("the cat sat\nthe cat\nthe end\n")
    }

    fn serial_counts(c: &Corpus) -> HashMap<String, u64> {
        let mut m = HashMap::new();
        for line in &c.lines {
            for w in crate::corpus::split_spaces(line) {
                *m.entry(w.to_string()).or_insert(0u64) += 1;
            }
        }
        m
    }

    #[test]
    fn word_count_tiny() {
        let ctx = SparkContext::new(SparkConf::for_tests(1, 2));
        let counts = word_count(&ctx, &tiny_corpus(), Tokenizer::Spaces).unwrap();
        assert_eq!(counts.get("the"), Some(&3));
        assert_eq!(counts.get("cat"), Some(&2));
        assert_eq!(counts.get("sat"), Some(&1));
        assert_eq!(counts.get("end"), Some(&1));
        assert_eq!(counts.len(), 4);
    }

    #[test]
    fn word_count_matches_serial_on_generated_corpus() {
        let corpus = Corpus::generate(&CorpusSpec::with_bytes(128 << 10));
        for nnodes in [1usize, 3] {
            let ctx = SparkContext::new(SparkConf::for_tests(nnodes, 2));
            let counts = word_count(&ctx, &corpus, Tokenizer::Spaces).unwrap();
            assert_eq!(counts, serial_counts(&corpus), "nnodes={nnodes}");
        }
    }

    #[test]
    fn no_serde_path_matches() {
        let corpus = Corpus::generate(&CorpusSpec::with_bytes(64 << 10));
        let mut conf = SparkConf::for_tests(2, 2);
        conf.serialize_shuffle = false;
        conf.fault_tolerance = false; // typed blocks can't persist
        let ctx = SparkContext::new(conf);
        let counts = word_count(&ctx, &corpus, Tokenizer::Spaces).unwrap();
        assert_eq!(counts, serial_counts(&corpus));
        assert_eq!(ctx.metrics().shuffle_bytes_written.load(std::sync::atomic::Ordering::Relaxed), 0);
    }

    #[test]
    fn no_combine_ships_more_records() {
        // Small vocab + tiling => heavy repetition; the per-partition
        // combiner then collapses the shuffled record count.
        let corpus = Corpus::generate(&CorpusSpec {
            target_bytes: 256 << 10,
            base_block_bytes: Some(64 << 10),
            vocab_size: 1000,
            ..Default::default()
        });
        let run = |combine: bool| {
            let mut conf = SparkConf::for_tests(2, 2);
            conf.map_side_combine = combine;
            let ctx = SparkContext::new(conf);
            let counts = word_count(&ctx, &corpus, Tokenizer::Spaces).unwrap();
            let shipped = ctx
                .metrics()
                .records_shuffled
                .load(std::sync::atomic::Ordering::Relaxed);
            (counts, shipped)
        };
        let (with, shipped_with) = run(true);
        let (without, shipped_without) = run(false);
        assert_eq!(with, without);
        assert!(
            shipped_without > shipped_with * 3,
            "uncombined shuffle must ship many more records: {shipped_without} vs {shipped_with}"
        );
    }

    #[test]
    fn boxed_records_path_matches() {
        let corpus = tiny_corpus();
        let mut conf = SparkConf::for_tests(1, 2);
        conf.boxed_records = true;
        let ctx = SparkContext::new(conf);
        let counts = word_count(&ctx, &corpus, Tokenizer::Spaces).unwrap();
        assert_eq!(counts.get("the"), Some(&3));
    }

    #[test]
    fn task_failure_with_ft_recovers_via_retry() {
        let corpus = Corpus::generate(&CorpusSpec::with_bytes(32 << 10));
        let conf = SparkConf::for_tests(2, 2);
        // Fail one map task (stage 0) and one reduce task (stage 1).
        let failures = FailurePlan::none().fail_task(0, 1).fail_task(1, 3);
        let ctx = SparkContext::with_failures(conf, failures);
        let counts = word_count(&ctx, &corpus, Tokenizer::Spaces).unwrap();
        assert_eq!(counts, serial_counts(&corpus));
        let m = ctx.metrics();
        assert_eq!(m.task_failures.load(std::sync::atomic::Ordering::Relaxed), 2);
        assert_eq!(m.job_restarts.load(std::sync::atomic::Ordering::Relaxed), 0);
    }

    #[test]
    fn task_failure_without_ft_restarts_job() {
        let corpus = Corpus::generate(&CorpusSpec::with_bytes(32 << 10));
        let mut conf = SparkConf::for_tests(2, 2);
        conf.fault_tolerance = false;
        let failures = FailurePlan::none().fail_task(0, 0);
        let ctx = SparkContext::with_failures(conf, failures);
        let counts = word_count(&ctx, &corpus, Tokenizer::Spaces).unwrap();
        assert_eq!(counts, serial_counts(&corpus));
        let m = ctx.metrics();
        assert_eq!(m.job_restarts.load(std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn narrow_ops_compose() {
        let ctx = SparkContext::new(SparkConf::for_tests(1, 2));
        let rdd = ctx.parallelize((0i64..100).collect(), 4);
        let out = rdd
            .map(|x| x * 2)
            .filter(|x| x % 3 == 0)
            .flat_map(|x| vec![x, x])
            .collect()
            .unwrap();
        let expect: Vec<i64> = (0..100)
            .map(|x| x * 2)
            .filter(|x| x % 3 == 0)
            .flat_map(|x| vec![x, x])
            .collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn count_action() {
        let ctx = SparkContext::new(SparkConf::for_tests(1, 2));
        let rdd = ctx.parallelize(vec![1u64; 1000], 8);
        assert_eq!(rdd.count().unwrap(), 1000);
    }

    #[test]
    fn lost_executor_recovers_via_lineage() {
        let corpus = Corpus::generate(&CorpusSpec::with_bytes(64 << 10));
        let conf = SparkConf::for_tests(2, 2);
        // Node 1's shuffle output vanishes after the map stage.
        let failures = FailurePlan::none().lose_executor(1);
        let ctx = SparkContext::with_failures(conf, failures);
        let counts = word_count(&ctx, &corpus, Tokenizer::Spaces).unwrap();
        assert_eq!(counts, serial_counts(&corpus));
        use std::sync::atomic::Ordering::Relaxed;
        let m = ctx.metrics();
        assert!(
            m.lineage_recomputes.load(Relaxed) > 0,
            "lost blocks must be recomputed from lineage"
        );
        assert_eq!(m.job_restarts.load(Relaxed), 0, "no full restart needed");
    }

    #[test]
    fn losing_every_executor_still_recovers() {
        let corpus = Corpus::generate(&CorpusSpec::with_bytes(32 << 10));
        let conf = SparkConf::for_tests(2, 2);
        let failures = FailurePlan::none().lose_executor(0).lose_executor(1);
        let ctx = SparkContext::with_failures(conf, failures);
        let counts = word_count(&ctx, &corpus, Tokenizer::Spaces).unwrap();
        assert_eq!(counts, serial_counts(&corpus));
    }

    #[test]
    fn jvm_pipeline_matches_serial() {
        let corpus = Corpus::generate(&CorpusSpec::with_bytes(64 << 10));
        let mut conf = SparkConf::for_tests(2, 2);
        conf.jvm_strings = true;
        conf.gc_model = true;
        let ctx = SparkContext::new(conf);
        let counts = word_count(&ctx, &corpus, Tokenizer::Spaces).unwrap();
        assert_eq!(counts, serial_counts(&corpus));
        // GC accounting saw the allocation stream.
        assert!(ctx.inner().gc.total_allocated() > corpus.bytes);
    }

    #[test]
    fn generic_runner_runs_non_string_keys() {
        use crate::workloads::LengthHistogram;
        let corpus = Corpus::from_text("aa bbb aa\ncccc a\n");
        let ctx = SparkContext::new(SparkConf::for_tests(2, 2));
        let w = Arc::new(LengthHistogram::new(Tokenizer::Spaces));
        let (entries, emitted) =
            run_workload(&ctx, Arc::new(corpus.lines.clone()), &w).unwrap();
        let mut hist = entries;
        hist.sort_unstable();
        assert_eq!(hist, vec![(1, 1), (2, 2), (3, 1), (4, 1)]);
        // Dense per-record pre-combine: fewer emissions than tokens.
        assert!(emitted <= 5);
    }

    #[test]
    fn persist_serves_later_collects_from_cache() {
        let ctx = SparkContext::new(SparkConf::for_tests(1, 2));
        let rdd = ctx.parallelize((0u64..100).collect(), 4).map(|x| x * 2).persist();
        let a = rdd.collect().unwrap();
        let b = rdd.collect().unwrap();
        assert_eq!(a, b);
        let s = ctx.partition_cache().stats();
        assert_eq!(s.misses, 4, "first collect misses every partition: {s:?}");
        assert!(s.hits >= 4, "second collect is served from memory: {s:?}");
    }

    #[test]
    fn persist_with_zero_budget_recomputes_from_lineage() {
        use crate::cache::CacheBudget;
        let mut conf = SparkConf::for_tests(1, 2);
        conf.cache_budget = CacheBudget::Bytes(0);
        let ctx = SparkContext::new(conf);
        let rdd = ctx.parallelize((0i64..50).collect(), 4).map(|x| x + 1).cache();
        assert_eq!(rdd.collect().unwrap(), rdd.collect().unwrap());
        let s = ctx.partition_cache().stats();
        // Budget 0 bypasses the cache outright: no hits, nothing admitted,
        // every collect recomputes from lineage.
        assert_eq!(s.hits, 0, "{s:?}");
        assert_eq!(s.insertions, 0, "{s:?}");
        assert_eq!(s.bytes_cached, 0, "{s:?}");
    }

    #[test]
    fn metrics_track_shuffle_bytes() {
        let corpus = Corpus::generate(&CorpusSpec::with_bytes(32 << 10));
        let ctx = SparkContext::new(SparkConf::for_tests(2, 2));
        word_count(&ctx, &corpus, Tokenizer::Spaces).unwrap();
        let m = ctx.metrics();
        use std::sync::atomic::Ordering::Relaxed;
        assert!(m.shuffle_bytes_written.load(Relaxed) > 0);
        assert!(m.shuffle_bytes_read.load(Relaxed) >= m.shuffle_bytes_written.load(Relaxed));
        assert!(m.tasks_launched.load(Relaxed) > 0);
    }
}
