//! The Blaze engine — the paper's MPI/OpenMP design (native, no fault
//! tolerance, continuous map-side combine in a distributed hash map) —
//! generalized to arbitrary [`Workload`]s.
//!
//! The pipeline is exactly the paper's: a [`DistRange`] over record indices
//! (one range per input relation for multi-input jobs — see
//! [`run_workload_multi`]) is split into per-node blocks and mapped across
//! nodes × threads; every emission combines continuously into a
//! [`DistHashMap`]; one all-to-all shuffle then re-shards by key owner.
//! No fault tolerance: an injected node failure aborts the attempt and
//! the driver reruns the whole job (the paper's §Conclusion regime,
//! bounded by `max_job_reruns`).
//!
//! Since the planner layer ([`crate::mapreduce::plan`]) landed, this
//! engine is a **stage executor**: [`run_plan`] is its single
//! plan-execution path (the rerun loop around map → exchange → per-node
//! finalize), and it *reads* the per-stage decisions — run the exchange
//! or elide it, cache a relation's parsed split under which key — from
//! the compiled [`StagePlan`] instead of re-deriving them. The
//! `run_workload{,_multi,_str,_str_lines,_cached}` entry points survive
//! only as thin wrappers that supply their map closure to [`run_plan`].
//!
//! Word count is just [`crate::workloads::WordCount`] through this
//! machinery; the two [`KeyPath`]s reproduce the paper's two bars:
//!
//! * [`KeyPath::AllocPerToken`] ("Blaze"): every emission materializes an
//!   owned key — what the C++ `std::getline(ss, word)` loop does. This is
//!   [`run_workload`], the path any workload can take.
//! * [`KeyPath::ZeroAlloc`] ("Blaze TCM" analog): string keys stay borrowed
//!   `&str`s; the owned key is built only on first insertion. This is
//!   [`run_workload_str`], available to [`StrWorkload`]s, and stands in
//!   for TCMalloc's cheap small allocations (see DESIGN.md §2).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::cache::{CacheKey, PartitionCache};
use crate::cluster::{spawn_on_fabric, Comm, Fabric, FailurePlan, NetModel};
use crate::concurrent::{CachePolicy, MapKey, MapValue};
use crate::corpus::{Corpus, Tokenizer};
use crate::dist::{reducer, CombineMode, DistHashMap, DistRange};
use crate::hash::HashKind;
use crate::mapreduce::{CacheableWorkload, StagePlan, StrWorkload, Workload};
use crate::runtime::executor::{ExecCtx, Executor, TaskSetError};
use crate::storage::{DiskTier, HeapSize, PolicySpec, StorageStats};
use crate::trace::{self, SpanCat};
use crate::util::ser::{DataKey, Decode, DictStats, Encode};
use crate::util::stats::Stopwatch;

/// Key-insert strategy (the paper's Blaze vs Blaze-TCM bars).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KeyPath {
    AllocPerToken,
    ZeroAlloc,
}

impl KeyPath {
    pub fn parse(s: &str) -> Option<KeyPath> {
        match s {
            "alloc" | "blaze" => Some(KeyPath::AllocPerToken),
            "zero" | "tcm" | "arena" => Some(KeyPath::ZeroAlloc),
            _ => None,
        }
    }
}

#[derive(Clone, Debug)]
pub struct BlazeConf {
    pub nnodes: usize,
    /// **Simulated** per-node thread count — a cost-model parameter (it
    /// shapes partitioning arithmetic and reports), *not* how many OS
    /// threads run. Real parallelism is [`BlazeConf::threads`].
    pub threads_per_node: usize,
    /// **Real** executor width: map tasks and reduce shards of every
    /// simulated node dispatch onto the process-wide work-stealing pool
    /// ([`crate::runtime::Executor`]) of this many workers. `None` = auto
    /// (`BLAZE_THREADS`, else the machine's available parallelism).
    pub threads: Option<usize>,
    pub net: NetModel,
    pub combine: CombineMode,
    pub hash: HashKind,
    pub tokenizer: Tokenizer,
    pub key_path: KeyPath,
    /// Thread-cache policy of the distributed map. Default: the optimized
    /// `CacheFirst` (see EXPERIMENTS.md §Perf); the paper's prose policy is
    /// `SpillOnContention`.
    pub cache_policy: CachePolicy,
    /// Whole-job reruns allowed on an injected node failure (no FT).
    pub max_job_reruns: usize,
    /// Directory the bounded-memory exchange spills under (`None` = the
    /// system temp dir). Whether a stage spills at all — and beyond how
    /// many in-flight bytes — was decided at plan time
    /// ([`StagePlan::spill_threshold`]); this conf only places the files.
    pub spill_dir: Option<PathBuf>,
    /// Eviction policy of the iterative-driver relation cache. Blaze does
    /// not build its own cache (the driver injects a shared
    /// [`crate::cache::PartitionCache`]); the field is carried here for
    /// conf parity with [`super::spark::SparkConf`] so `--cache-policy`
    /// threads identically through both engines.
    pub eviction_policy: PolicySpec,
    /// Framed block compression on the exchange's spill tier (the
    /// `--compress` knob; on by default, `off` is the ablation arm).
    pub compress: bool,
    /// Dictionary-encode repeated string keys on exchange payloads and
    /// spill runs (the `--dict-keys` knob; on by default).
    pub dict_keys: bool,
}

impl Default for BlazeConf {
    fn default() -> Self {
        Self {
            nnodes: 1,
            threads_per_node: 4,
            threads: None,
            net: NetModel::aws_like(),
            combine: CombineMode::Eager,
            hash: HashKind::Fx,
            tokenizer: Tokenizer::Spaces,
            key_path: KeyPath::ZeroAlloc,
            cache_policy: CachePolicy::default(),
            max_job_reruns: 3,
            spill_dir: None,
            eviction_policy: PolicySpec::default(),
            compress: true,
            dict_keys: true,
        }
    }
}

impl BlazeConf {
    pub fn new(nnodes: usize, threads_per_node: usize) -> Self {
        Self { nnodes, threads_per_node, ..Default::default() }
    }

    /// Fast test config: ideal network.
    pub fn for_tests(nnodes: usize, threads_per_node: usize) -> Self {
        Self { nnodes, threads_per_node, net: NetModel::ideal(), ..Default::default() }
    }
}

/// Outcome of one Blaze word-count run.
#[derive(Debug)]
pub struct BlazeReport {
    /// Global counts (gathered from all nodes, outside the timed section).
    pub counts: HashMap<String, u64>,
    /// Wall-clock of the slowest node's map+shuffle (the job time).
    pub wall_secs: f64,
    /// Max per-node map-phase seconds.
    pub map_secs: f64,
    /// Max per-node shuffle seconds.
    pub shuffle_secs: f64,
    /// Bytes serialized onto the simulated wire.
    pub shuffle_bytes: u64,
    /// Total words counted.
    pub words: u64,
    /// Whole-job reruns consumed by injected failures.
    pub reruns: usize,
}

impl BlazeReport {
    pub fn words_per_sec(&self) -> f64 {
        self.words as f64 / self.wall_secs.max(1e-12)
    }
}

/// Outcome of one generic workload run: per-node finalized shards
/// (disjoint key sets), concatenated, plus the phase timings.
#[derive(Debug)]
pub struct WorkloadReport<K, V> {
    pub entries: Vec<(K, V)>,
    pub wall_secs: f64,
    pub map_secs: f64,
    pub shuffle_secs: f64,
    pub shuffle_bytes: u64,
    /// Map-phase emissions.
    pub records: u64,
    pub reruns: usize,
    /// Bounded-memory exchange activity (spilled runs + disk traffic);
    /// all zeros when the stage planned no spill.
    pub storage: StorageStats,
}

/// Error when injected failures exceed the rerun budget.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobFailed {
    pub attempts: usize,
}

impl std::fmt::Display for JobFailed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "blaze job failed after {} attempt(s)", self.attempts)
    }
}

impl std::error::Error for JobFailed {}

/// Run a generic [`Workload`] over a single corpus (owned-key emissions,
/// the [`KeyPath::AllocPerToken`] path). Thin wrapper: compiles the
/// workload's one-stage plan and hands it to [`run_workload_multi`].
pub fn run_workload<W: Workload>(
    conf: &BlazeConf,
    corpus: &Corpus,
    failures: &FailurePlan,
    w: &W,
) -> Result<WorkloadReport<W::Key, W::Value>, JobFailed> {
    let stage = StagePlan::single(w.name(), w.needs_shuffle(), 1);
    run_workload_multi(conf, &stage, &[Arc::new(corpus.lines.clone())], failures, w)
}

/// Run a generic [`Workload`] over N tagged input relations. Each relation
/// gets its own [`DistRange`] split across the nodes; emissions from every
/// relation combine into the same [`DistHashMap`], so the one all-to-all
/// exchange co-locates join keys from all sides. Whether the exchange
/// runs at all was decided when `stage` was compiled
/// ([`Exchange::Elided`](crate::mapreduce::Exchange) puts zero bytes on
/// the fabric). Thin wrapper over [`run_plan`].
pub fn run_workload_multi<W: Workload>(
    conf: &BlazeConf,
    stage: &StagePlan,
    relations: &[Arc<Vec<String>>],
    failures: &FailurePlan,
    w: &W,
) -> Result<WorkloadReport<W::Key, W::Value>, JobFailed> {
    assert!(!relations.is_empty(), "a job needs at least one input relation");
    run_plan(
        conf,
        stage,
        failures,
        W::combine,
        |comm: &Comm, map: &DistHashMap<W::Key, W::Value>| {
            let mut records = 0u64;
            for (rel, lines) in relations.iter().enumerate() {
                records += map_node_block(conf, lines, comm.rank, |ctx, i, line| {
                    let mut n = 0u64;
                    w.map_rel(rel, i as u64, line, &mut |k, v| {
                        n += 1;
                        map.upsert_spillable(ctx.worker, k, v, W::combine);
                    });
                    n
                })?;
            }
            Ok(records)
        },
        |shard| w.finalize_local(shard),
    )
}

/// Run a [`CacheableWorkload`] with a partition-result cache: each node's
/// **parsed** block of a relation is stored in `cache` under the
/// [`CachePoint`](crate::mapreduce::CachePoint) the planner assigned it —
/// `(namespace, generation, node rank, node count)` — so a later run over
/// the same relation contents (same generation — the iterative driver's
/// static relations) skips tokenization entirely and goes straight to
/// `map_parsed` + combine. A changed relation bumps its generation and
/// re-parses; writers drop stale generations via
/// `PartitionCache::invalidate_generations_below` (bounded budgets would
/// also LRU them out). A relation **without** a planned cache point
/// (no cache attached, or the `CacheBudget::Bytes(0)` recompute ablation)
/// goes straight to the parser — no lookup, no size estimate, no rejected
/// put. Thin wrapper over [`run_plan`].
///
/// The cached path always materializes owned parsed records, so the
/// [`KeyPath`] distinction (borrowed-key inserts) does not apply here.
pub fn run_workload_cached<W: CacheableWorkload>(
    conf: &BlazeConf,
    stage: &StagePlan,
    relations: &[Arc<Vec<String>>],
    cache: &Arc<PartitionCache>,
    failures: &FailurePlan,
    w: &W,
) -> Result<WorkloadReport<W::Key, W::Value>, JobFailed> {
    assert!(!relations.is_empty(), "a job needs at least one input relation");
    run_plan(
        conf,
        stage,
        failures,
        W::combine,
        |comm: &Comm, map: &DistHashMap<W::Key, W::Value>| {
            let mut records = 0u64;
            for (rel, lines) in relations.iter().enumerate() {
                let reparse = || -> Result<Arc<Vec<W::Parsed>>, TaskSetError> {
                    Ok(Arc::new(parse_node_block(conf, lines, comm.rank, |i, line| {
                        w.parse_rel(rel, i as u64, line)
                    })?))
                };
                let parsed: Arc<Vec<W::Parsed>> = match stage.cache_point(rel) {
                    // The planner assigned no cache point (no cache, or
                    // the recompute ablation): parse, touch nothing.
                    None => reparse()?,
                    Some(cp) => {
                        let key = CacheKey {
                            namespace: cp.namespace,
                            generation: cp.generation,
                            partition: comm.rank as u64,
                            // Key on the decomposition too: a cache shared
                            // across cluster shapes must never serve
                            // another shape's block.
                            splits: conf.nnodes as u64,
                        };
                        // Encoded entry point: with a disk tier attached
                        // to the cache, evicted blocks demote to disk and
                        // this lookup promotes them back instead of
                        // reparsing.
                        match cache.get_encoded::<Vec<W::Parsed>>(&key) {
                            Some(hit) => hit,
                            None => {
                                let block = reparse()?;
                                let bytes = block.heap_bytes() as u64;
                                cache.put_encoded(key, Arc::clone(&block), bytes);
                                block
                            }
                        }
                    }
                };
                let emitted = AtomicU64::new(0);
                let exec = Executor::for_threads(conf.threads);
                run_chunked(&exec, 0, parsed.len(), |ctx, i| {
                    let mut n = 0u64;
                    w.map_parsed(rel, &parsed[i], &mut |k, v| {
                        n += 1;
                        map.upsert_spillable(ctx.worker, k, v, W::combine);
                    });
                    emitted.fetch_add(n, Ordering::Relaxed);
                })?;
                records += emitted.load(Ordering::Relaxed);
            }
            Ok(records)
        },
        |shard| w.finalize_local(shard),
    )
}

/// Records per stealable map/parse task: the classic dynamic-schedule
/// chunk — small enough to balance skewed line lengths, large enough to
/// amortize the queue round-trip.
const MAP_CHUNK: usize = 64;

/// Dispatch `[lo, hi)` onto the executor as `⌈n / MAP_CHUNK⌉` stealable
/// tasks of contiguous indices. `body` runs with the executing pool
/// worker's [`ExecCtx`] (its `worker` id keys the map's thread caches).
fn run_chunked<G>(exec: &Executor, lo: usize, hi: usize, body: G) -> Result<(), TaskSetError>
where
    G: Fn(ExecCtx, usize) + Sync,
{
    let n = hi.saturating_sub(lo);
    if n == 0 {
        return Ok(());
    }
    exec.run_tasks(n.div_ceil(MAP_CHUNK), |ctx, t| {
        let a = lo + t * MAP_CHUNK;
        let b = (a + MAP_CHUNK).min(hi);
        for i in a..b {
            body(ctx, i);
        }
    })
}

/// Parse this node's contiguous block of `lines` on the shared executor,
/// preserving record order (records that parse to `None` are dropped):
/// each chunk task fills its own slot, and the slots concatenate in chunk
/// order regardless of which worker parsed what.
fn parse_node_block<P: Send>(
    conf: &BlazeConf,
    lines: &Arc<Vec<String>>,
    rank: usize,
    parse: impl Fn(usize, &str) -> Option<P> + Sync,
) -> Result<Vec<P>, TaskSetError> {
    let range = DistRange::new(0, lines.len() as i64);
    let (lo, hi) = range.node_block(rank, conf.nnodes);
    let n = hi.saturating_sub(lo);
    if n == 0 {
        return Ok(Vec::new());
    }
    let exec = Executor::for_threads(conf.threads);
    let ntasks = n.div_ceil(MAP_CHUNK);
    let slots: Vec<Mutex<Vec<P>>> = (0..ntasks).map(|_| Mutex::new(Vec::new())).collect();
    exec.run_tasks(ntasks, |_ctx, t| {
        let a = lo + t * MAP_CHUNK;
        let b = (a + MAP_CHUNK).min(hi);
        *slots[t].lock().unwrap() = (a..b).filter_map(|i| parse(i, &lines[i])).collect();
    })?;
    let mut out = Vec::with_capacity(n);
    for s in slots {
        out.extend(s.into_inner().unwrap());
    }
    Ok(out)
}

/// Run a string-keyed [`StrWorkload`] through the zero-alloc borrowed-key
/// insert path (the [`KeyPath::ZeroAlloc`] / "TCM" path). Thin wrapper:
/// compiles the workload's one-stage plan.
pub fn run_workload_str<W: StrWorkload>(
    conf: &BlazeConf,
    corpus: &Corpus,
    failures: &FailurePlan,
    w: &W,
) -> Result<WorkloadReport<String, W::Value>, JobFailed> {
    let stage = StagePlan::single(w.name(), w.needs_shuffle(), 1);
    run_workload_str_lines(conf, &stage, Arc::new(corpus.lines.clone()), failures, w)
}

/// [`run_workload_str`] over already-shared lines (what the job layer
/// hands down). String paths are single-input: a multi-relation job runs
/// through [`run_workload_multi`]. Thin wrapper over [`run_plan`].
pub fn run_workload_str_lines<W: StrWorkload>(
    conf: &BlazeConf,
    stage: &StagePlan,
    lines: Arc<Vec<String>>,
    failures: &FailurePlan,
    w: &W,
) -> Result<WorkloadReport<String, W::Value>, JobFailed> {
    run_plan(
        conf,
        stage,
        failures,
        W::combine,
        |comm: &Comm, map: &DistHashMap<String, W::Value>| {
            map_node_block(conf, &lines, comm.rank, |ctx, i, line| {
                let mut n = 0u64;
                w.map_str(i as u64, line, &mut |t, v| {
                    n += 1;
                    map.upsert_str_spillable(ctx.worker, t, v, W::combine);
                });
                n
            })
        },
        |shard| w.finalize_local(shard),
    )
}

/// Run word count on the Blaze engine.
pub fn word_count(conf: &BlazeConf, corpus: &Corpus) -> Result<BlazeReport, JobFailed> {
    word_count_with_failures(conf, corpus, &FailurePlan::none())
}

/// Word count with failure injection — a thin facade over the generic
/// runners; `conf.key_path` picks the insert path (the paper's two bars).
pub fn word_count_with_failures(
    conf: &BlazeConf,
    corpus: &Corpus,
    failures: &FailurePlan,
) -> Result<BlazeReport, JobFailed> {
    let w = crate::workloads::WordCount::new(conf.tokenizer);
    let r = match conf.key_path {
        KeyPath::ZeroAlloc => run_workload_str(conf, corpus, failures, &w)?,
        KeyPath::AllocPerToken => run_workload(conf, corpus, failures, &w)?,
    };
    Ok(BlazeReport {
        counts: r.entries.into_iter().collect(),
        wall_secs: r.wall_secs,
        map_secs: r.map_secs,
        shuffle_secs: r.shuffle_secs,
        shuffle_bytes: r.shuffle_bytes,
        words: r.records,
        reruns: r.reruns,
    })
}

/// Map this node's block of the record range: `per_record(ctx, i, line)`
/// for every owned index, as chunked stealable tasks on the shared
/// work-stealing executor. Returns the total emission count reported by
/// `per_record`, or the task-set error if any map task panicked.
fn map_node_block<F>(
    conf: &BlazeConf,
    lines: &Arc<Vec<String>>,
    rank: usize,
    per_record: F,
) -> Result<u64, TaskSetError>
where
    F: Fn(ExecCtx, usize, &str) -> u64 + Sync,
{
    let range = DistRange::new(0, lines.len() as i64);
    let (lo, hi) = range.node_block(rank, conf.nnodes);
    let exec = Executor::for_threads(conf.threads);
    let records = AtomicU64::new(0);
    run_chunked(&exec, lo, hi, |ctx, i| {
        let n = per_record(ctx, i, &lines[i]);
        records.fetch_add(n, Ordering::Relaxed);
    })?;
    Ok(records.load(Ordering::Relaxed))
}

/// Per-node result of one attempt.
struct NodeOutcome<K, V> {
    entries: Vec<(K, V)>,
    map_secs: f64,
    shuffle_secs: f64,
    wall_secs: f64,
    records: u64,
    failed: bool,
    /// Dictionary savings of this node's outgoing exchange payloads.
    wire_dict: DictStats,
}

/// Per-job context of the bounded-memory exchange: one disk tier shared
/// by every node's merger (runs are namespaced per merger, so they never
/// collide), whose counters become the job's `storage` row.
struct SpillCtx {
    threshold: u64,
    disk: Arc<DiskTier>,
}

/// The engine's **single plan-execution path**, shared by every workload
/// and every wrapper: the whole-job rerun loop around single attempts of
/// map → exchange → per-node finalize. Whether the exchange runs was
/// decided when `stage` was compiled
/// ([`Exchange::Elided`](crate::mapreduce::Exchange) settles thread
/// caches locally and puts zero bytes on the fabric).
pub fn run_plan<K, V, R, M, F>(
    conf: &BlazeConf,
    stage: &StagePlan,
    failures: &FailurePlan,
    reduce: R,
    map_node: M,
    finalize_shard: F,
) -> Result<WorkloadReport<K, V>, JobFailed>
where
    K: MapKey + DataKey + Encode + Decode + Ord + HeapSize,
    V: MapValue + Encode + Decode + HeapSize,
    R: Fn(&mut V, V) + Sync + Copy,
    M: Fn(&Comm, &DistHashMap<K, V>) -> Result<u64, TaskSetError> + Sync,
    F: Fn(Vec<(K, V)>) -> Vec<(K, V)> + Sync,
{
    let _stage_span = trace::span_arg(SpanCat::Stage, "blaze", stage.id as u64);
    let skip_shuffle = !stage.runs_exchange();
    // The bounded-memory exchange, as planned: one disk tier for the
    // whole job (dropped — files and all — when the report is built).
    let spill = stage.spill_threshold.filter(|_| !skip_shuffle).map(|threshold| SpillCtx {
        threshold,
        disk: Arc::new(DiskTier::new(conf.spill_dir.clone()).compression(conf.compress)),
    });
    let mut reruns = 0usize;
    let job_sw = Stopwatch::start(); // total across attempts: failures cost time
    loop {
        match try_attempt(
            conf,
            failures,
            skip_shuffle,
            spill.as_ref(),
            reduce,
            &map_node,
            &finalize_shard,
        ) {
            Ok(mut report) => {
                report.reruns = reruns;
                report.wall_secs = job_sw.elapsed_secs();
                // The attempt left only the exchange-wire dictionary
                // stats in `storage`; fold the spill tier's counters
                // (disk traffic, compression, spill-run dictionaries)
                // on top.
                report.storage = spill
                    .as_ref()
                    .map_or_else(StorageStats::default, |s| s.disk.counters().snapshot())
                    .merged(&report.storage);
                return Ok(report);
            }
            Err(()) if reruns < conf.max_job_reruns => reruns += 1,
            Err(()) => return Err(JobFailed { attempts: reruns + 1 }),
        }
    }
}

/// One attempt. An injected node failure fails the whole attempt — Blaze
/// has no fault tolerance — but the failed node still participates in the
/// shuffle protocol with empty payloads so peers don't deadlock.
fn try_attempt<K, V, R, M, F>(
    conf: &BlazeConf,
    failures: &FailurePlan,
    skip_shuffle: bool,
    spill: Option<&SpillCtx>,
    reduce: R,
    map_node: &M,
    finalize_shard: &F,
) -> Result<WorkloadReport<K, V>, ()>
where
    K: MapKey + DataKey + Encode + Decode + Ord + HeapSize,
    V: MapValue + Encode + Decode + HeapSize,
    R: Fn(&mut V, V) + Sync + Copy,
    M: Fn(&Comm, &DistHashMap<K, V>) -> Result<u64, TaskSetError> + Sync,
    F: Fn(Vec<(K, V)>) -> Vec<(K, V)> + Sync,
{
    let fabric = Fabric::new(conf.nnodes, conf.net);
    // The real-execution pool: every node's map tasks dispatch here. The
    // per-node map is sized by the pool's width, so thread-cache ids the
    // workers carry ([`ExecCtx::worker`]) always index in range.
    let exec = Executor::for_threads(conf.threads);
    let run_node = |comm: &Comm| -> NodeOutcome<K, V> {
        let mut map: DistHashMap<K, V> = DistHashMap::with_policy(
            comm.rank,
            conf.nnodes,
            exec.width(),
            conf.hash,
            conf.combine,
            conf.cache_policy,
        );
        // The spill budget bounds the map phase too (ROADMAP 2b): past
        // the threshold, pending combine state parks on the job's spill
        // tier and rejoins at the exchange.
        if let Some(sp) = spill {
            map = map.with_map_bound(sp.threshold, Arc::clone(&sp.disk), conf.dict_keys);
        }
        comm.barrier();
        let job_sw = Stopwatch::start();

        // ---- Map phase (the paper's DistRange::map) ----
        let map_span = trace::span_arg(SpanCat::Map, "map", comm.rank as u64);
        let mut sw = Stopwatch::start();
        let mut failed = failures.should_fail_node(comm.rank, 0);
        let records = if failed {
            0
        } else {
            match map_node(comm, &map) {
                Ok(n) => n,
                // A panicking map task fails this node's attempt (the
                // pool itself survives); the rerun loop treats it
                // exactly like an injected node failure.
                Err(e) => {
                    crate::log_warn!(
                        "blaze",
                        "node {}: map phase failed: {e}; rerunning job",
                        comm.rank
                    );
                    failed = true;
                    0
                }
            }
        };
        let map_secs = sw.restart().as_secs_f64();
        drop(map_span);

        // ---- Shuffle phase ----
        let exchange_span = trace::span_arg(SpanCat::Exchange, "exchange", comm.rank as u64);
        failed |= failures.should_fail_node(comm.rank, 1);
        let (entries, wire_dict) = if skip_shuffle {
            // Zero-shuffle fast path: every key was declared globally
            // unique, so nothing needs co-location — settle thread caches
            // locally and put zero bytes on the fabric.
            map.settle_local(reduce);
            (map.to_vec_local(), DictStats::default())
        } else if let Some(sp) = spill {
            // Bounded-memory exchange: the reduce-side merge runs through
            // an external merger that spills sorted runs beyond the
            // planned budget.
            map.shuffle_external(comm, reduce, sp.threshold, &sp.disk, conf.dict_keys)
        } else {
            let stats = map.shuffle(comm, reduce, conf.dict_keys);
            (map.to_vec_local(), stats)
        };
        let shuffle_secs = sw.elapsed_secs();
        drop(exchange_span);
        let entries = {
            let _fin = trace::span_arg(SpanCat::Finalize, "finalize", comm.rank as u64);
            finalize_shard(entries)
        };
        let wall_secs = job_sw.elapsed_secs();

        NodeOutcome {
            entries,
            map_secs,
            shuffle_secs,
            wall_secs,
            records,
            failed,
            wire_dict,
        }
    };

    let outcomes = spawn_on_fabric(&fabric, &run_node);
    if outcomes.iter().any(|o| o.failed) {
        return Err(());
    }
    let mut entries = Vec::new();
    let mut records = 0u64;
    let (mut map_secs, mut shuffle_secs, mut wall_secs) = (0.0f64, 0.0f64, 0.0f64);
    let mut wire_dict = DictStats::default();
    for o in outcomes {
        records += o.records;
        map_secs = map_secs.max(o.map_secs);
        shuffle_secs = shuffle_secs.max(o.shuffle_secs);
        wall_secs = wall_secs.max(o.wall_secs);
        wire_dict = wire_dict.merged(&o.wire_dict);
        // Keys are owner-sharded (or producer-sharded with globally
        // unique keys on the zero-shuffle path): no overlaps between nodes.
        entries.extend(o.entries);
    }
    // Carry the exchange-wire dictionary stats in the storage row;
    // `run_plan` merges the spill tier's counters on top.
    let mut storage = StorageStats::default();
    storage.add_dict(&wire_dict);
    Ok(WorkloadReport {
        entries,
        wall_secs,
        map_secs,
        shuffle_secs,
        shuffle_bytes: fabric.total_bytes_sent(),
        records,
        reruns: 0,
        storage,
    })
}

/// The paper's verbatim high-level interface, for the quickstart example:
/// a `DistRange` mapreduce with an explicit mapper closure.
pub fn word_count_paper_api(
    comm: &Comm,
    nthreads: usize,
    lines: &[String],
    target: &DistHashMap<String, u64>,
) {
    let range = DistRange::new(0, lines.len() as i64);
    range.mapreduce(comm, nthreads, target, reducer::sum, |i, emit| {
        for word in crate::corpus::split_spaces(&lines[i as usize]) {
            emit(word.to_string(), 1);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::CorpusSpec;

    fn serial_counts(c: &Corpus) -> HashMap<String, u64> {
        let mut m = HashMap::new();
        for line in &c.lines {
            for w in crate::corpus::split_spaces(line) {
                *m.entry(w.to_string()).or_insert(0u64) += 1;
            }
        }
        m
    }

    #[test]
    fn word_count_matches_serial() {
        let corpus = Corpus::generate(&CorpusSpec::with_bytes(128 << 10));
        let expect = serial_counts(&corpus);
        for nnodes in [1usize, 2, 4] {
            let conf = BlazeConf::for_tests(nnodes, 2);
            let report = word_count(&conf, &corpus).unwrap();
            assert_eq!(report.counts, expect, "nnodes={nnodes}");
            assert_eq!(report.words, expect.values().sum::<u64>());
        }
    }

    #[test]
    fn both_key_paths_agree() {
        let corpus = Corpus::generate(&CorpusSpec::with_bytes(64 << 10));
        let mut conf = BlazeConf::for_tests(2, 2);
        conf.key_path = KeyPath::AllocPerToken;
        let a = word_count(&conf, &corpus).unwrap();
        conf.key_path = KeyPath::ZeroAlloc;
        let b = word_count(&conf, &corpus).unwrap();
        assert_eq!(a.counts, b.counts);
    }

    #[test]
    fn combine_none_agrees_but_ships_more() {
        // Small vocab + tiling => heavy key repetition, so eager combining
        // collapses the shuffle volume.
        let corpus = Corpus::generate(&CorpusSpec {
            target_bytes: 256 << 10,
            base_block_bytes: Some(64 << 10),
            vocab_size: 1000,
            ..Default::default()
        });
        let mut conf = BlazeConf::for_tests(2, 2);
        let eager = word_count(&conf, &corpus).unwrap();
        conf.combine = CombineMode::None;
        let none = word_count(&conf, &corpus).unwrap();
        assert_eq!(eager.counts, none.counts);
        assert!(
            none.shuffle_bytes > eager.shuffle_bytes * 5,
            "uncombined {} vs combined {}",
            none.shuffle_bytes,
            eager.shuffle_bytes
        );
    }

    #[test]
    fn node_failure_triggers_rerun() {
        let corpus = Corpus::generate(&CorpusSpec::with_bytes(32 << 10));
        let conf = BlazeConf::for_tests(2, 2);
        let failures = FailurePlan::none().fail_node(1, 0);
        let report = word_count_with_failures(&conf, &corpus, &failures).unwrap();
        assert_eq!(report.reruns, 1);
        assert_eq!(report.counts, serial_counts(&corpus));
    }

    #[test]
    fn too_many_failures_aborts() {
        let corpus = Corpus::from_text("a b\n");
        let mut conf = BlazeConf::for_tests(1, 1);
        conf.max_job_reruns = 0; // no rerun budget: first failure aborts
        let failures = FailurePlan::none().fail_node(0, 0);
        let err = word_count_with_failures(&conf, &corpus, &failures).unwrap_err();
        assert_eq!(err.attempts, 1);
    }

    #[test]
    fn paper_api_counts() {
        use crate::cluster::spawn_cluster;
        let lines: Vec<String> =
            vec!["the cat".into(), "the hat".into(), "the cat".into()];
        let results = spawn_cluster(2, NetModel::ideal(), |comm| {
            let target: DistHashMap<String, u64> =
                DistHashMap::new(comm.rank, 2, 2, HashKind::Fx, CombineMode::Eager);
            word_count_paper_api(comm, 2, &lines, &target);
            target.to_vec_local()
        });
        let merged: HashMap<String, u64> = results.into_iter().flatten().collect();
        assert_eq!(merged.get("the"), Some(&3));
        assert_eq!(merged.get("cat"), Some(&2));
        assert_eq!(merged.get("hat"), Some(&1));
    }

    #[test]
    fn normalized_tokenizer_variant() {
        let corpus = Corpus::from_text("The cat! THE CAT?\n");
        let mut conf = BlazeConf::for_tests(1, 1);
        conf.tokenizer = Tokenizer::Normalized;
        let report = word_count(&conf, &corpus).unwrap();
        assert_eq!(report.counts.get("the"), Some(&2));
        assert_eq!(report.counts.get("cat"), Some(&2));
    }

    #[test]
    fn generic_runner_runs_non_string_keys() {
        use crate::workloads::LengthHistogram;
        let corpus = Corpus::from_text("aa bbb aa\ncccc a\n");
        let conf = BlazeConf::for_tests(2, 2);
        let w = LengthHistogram::new(Tokenizer::Spaces);
        let r = run_workload(&conf, &corpus, &FailurePlan::none(), &w).unwrap();
        let mut hist: Vec<(u32, u64)> = r.entries;
        hist.sort_unstable();
        assert_eq!(hist, vec![(1, 1), (2, 2), (3, 1), (4, 1)]);
    }
}
