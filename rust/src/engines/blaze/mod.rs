//! The Blaze engine — the paper's MPI/OpenMP MapReduce, natively in Rust.
//!
//! Word count is exactly the paper's pipeline: a [`DistRange`] over line
//! indices is mapped across nodes × threads; the mapper tokenizes its line
//! and emits `(word, 1)` into a [`DistHashMap`], which combines
//! continuously (map-side local reduce); one all-to-all shuffle then makes
//! the map globally consistent. No fault tolerance: a node failure aborts
//! the job and the driver reruns it from scratch (the paper's §Conclusion
//! regime, bounded by `max_job_reruns`).
//!
//! Two insert paths reproduce the paper's two bars:
//! * [`KeyPath::AllocPerToken`] ("Blaze"): every token materializes an
//!   owned `String` before the map insert — what the C++
//!   `std::getline(ss, word)` loop does.
//! * [`KeyPath::ZeroAlloc`] ("Blaze TCM" analog): tokens are borrowed
//!   `&str`s; the owned key is built only on first insertion. This stands
//!   in for TCMalloc's cheap small allocations (see DESIGN.md §2).

use std::collections::HashMap;
use std::sync::Arc;

use crate::cluster::{spawn_on_fabric, Comm, Fabric, FailurePlan, NetModel};
use crate::corpus::{Corpus, Tokenizer};
use crate::concurrent::CachePolicy;
use crate::dist::{reducer, CombineMode, DistHashMap, DistRange};
use crate::hash::HashKind;
use crate::util::pool::{self, Schedule};
use crate::util::stats::Stopwatch;

/// Key-insert strategy (the paper's Blaze vs Blaze-TCM bars).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KeyPath {
    AllocPerToken,
    ZeroAlloc,
}

impl KeyPath {
    pub fn parse(s: &str) -> Option<KeyPath> {
        match s {
            "alloc" | "blaze" => Some(KeyPath::AllocPerToken),
            "zero" | "tcm" | "arena" => Some(KeyPath::ZeroAlloc),
            _ => None,
        }
    }
}

#[derive(Clone, Debug)]
pub struct BlazeConf {
    pub nnodes: usize,
    pub threads_per_node: usize,
    pub net: NetModel,
    pub combine: CombineMode,
    pub hash: HashKind,
    pub tokenizer: Tokenizer,
    pub key_path: KeyPath,
    /// Thread-cache policy of the distributed map. Default: the optimized
    /// `CacheFirst` (see EXPERIMENTS.md §Perf); the paper's prose policy is
    /// `SpillOnContention`.
    pub cache_policy: CachePolicy,
    /// Whole-job reruns allowed on an injected node failure (no FT).
    pub max_job_reruns: usize,
}

impl Default for BlazeConf {
    fn default() -> Self {
        Self {
            nnodes: 1,
            threads_per_node: 4,
            net: NetModel::aws_like(),
            combine: CombineMode::Eager,
            hash: HashKind::Fx,
            tokenizer: Tokenizer::Spaces,
            key_path: KeyPath::ZeroAlloc,
            cache_policy: CachePolicy::default(),
            max_job_reruns: 3,
        }
    }
}

impl BlazeConf {
    pub fn new(nnodes: usize, threads_per_node: usize) -> Self {
        Self { nnodes, threads_per_node, ..Default::default() }
    }

    /// Fast test config: ideal network.
    pub fn for_tests(nnodes: usize, threads_per_node: usize) -> Self {
        Self { nnodes, threads_per_node, net: NetModel::ideal(), ..Default::default() }
    }
}

/// Outcome of one Blaze word-count run.
#[derive(Debug)]
pub struct BlazeReport {
    /// Global counts (gathered from all nodes, outside the timed section).
    pub counts: HashMap<String, u64>,
    /// Wall-clock of the slowest node's map+shuffle (the job time).
    pub wall_secs: f64,
    /// Max per-node map-phase seconds.
    pub map_secs: f64,
    /// Max per-node shuffle seconds.
    pub shuffle_secs: f64,
    /// Bytes serialized onto the simulated wire.
    pub shuffle_bytes: u64,
    /// Total words counted.
    pub words: u64,
    /// Whole-job reruns consumed by injected failures.
    pub reruns: usize,
}

impl BlazeReport {
    pub fn words_per_sec(&self) -> f64 {
        self.words as f64 / self.wall_secs.max(1e-12)
    }
}

/// Error when injected failures exceed the rerun budget.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobFailed {
    pub attempts: usize,
}

impl std::fmt::Display for JobFailed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "blaze job failed after {} attempt(s)", self.attempts)
    }
}

impl std::error::Error for JobFailed {}

/// Run word count on the Blaze engine.
pub fn word_count(conf: &BlazeConf, corpus: &Corpus) -> Result<BlazeReport, JobFailed> {
    word_count_with_failures(conf, corpus, &FailurePlan::none())
}

/// Word count with failure injection: an injected node failure aborts the
/// whole job (Blaze has no fault tolerance) and the driver reruns it.
pub fn word_count_with_failures(
    conf: &BlazeConf,
    corpus: &Corpus,
    failures: &FailurePlan,
) -> Result<BlazeReport, JobFailed> {
    let lines = Arc::new(corpus.lines.clone());
    let mut reruns = 0usize;
    let job_sw = Stopwatch::start(); // total across attempts: failures cost time
    loop {
        match try_word_count(conf, &lines, failures) {
            Ok(mut report) => {
                report.reruns = reruns;
                report.wall_secs = job_sw.elapsed_secs();
                return Ok(report);
            }
            Err(()) if reruns < conf.max_job_reruns => reruns += 1,
            Err(()) => return Err(JobFailed { attempts: reruns + 1 }),
        }
    }
}

/// Per-node result of one attempt.
struct NodeOutcome {
    counts: Vec<(String, u64)>,
    map_secs: f64,
    shuffle_secs: f64,
    wall_secs: f64,
    words: u64,
    failed: bool,
}

fn try_word_count(
    conf: &BlazeConf,
    lines: &Arc<Vec<String>>,
    failures: &FailurePlan,
) -> Result<BlazeReport, ()> {
    let fabric = Fabric::new(conf.nnodes, conf.net);
    let range = DistRange::new(0, lines.len() as i64);
    let run_node = |comm: &Comm| -> NodeOutcome {
        let map: DistHashMap<String, u64> = DistHashMap::with_policy(
            comm.rank,
            conf.nnodes,
            conf.threads_per_node,
            conf.hash,
            conf.combine,
            conf.cache_policy,
        );
        comm.barrier();
        let job_sw = Stopwatch::start();

        // ---- Map phase (the paper's DistRange::map) ----
        let mut sw = Stopwatch::start();
        let mut failed = failures.should_fail_node(comm.rank, 0);
        let words = if failed {
            0
        } else {
            count_node_block(conf, lines, &range, comm.rank, &map)
        };
        let map_secs = sw.restart().as_secs_f64();

        // A failed node still participates in the shuffle protocol with
        // empty payloads so peers don't deadlock; the driver discards the
        // attempt.
        failed |= failures.should_fail_node(comm.rank, 1);
        map.shuffle(comm, reducer::sum);
        let shuffle_secs = sw.elapsed_secs();
        let wall_secs = job_sw.elapsed_secs();

        NodeOutcome {
            counts: map.to_vec_local(),
            map_secs,
            shuffle_secs,
            wall_secs,
            words,
            failed,
        }
    };

    let outcomes = spawn_on_fabric(&fabric, &run_node);
    if outcomes.iter().any(|o| o.failed) {
        return Err(());
    }
    let mut counts = HashMap::new();
    let mut words = 0u64;
    for o in &outcomes {
        words += o.words;
        for (k, v) in &o.counts {
            // Keys are owner-sharded: no overlaps between nodes.
            counts.insert(k.clone(), *v);
        }
    }
    Ok(BlazeReport {
        counts,
        wall_secs: outcomes.iter().map(|o| o.wall_secs).fold(0.0, f64::max),
        map_secs: outcomes.iter().map(|o| o.map_secs).fold(0.0, f64::max),
        shuffle_secs: outcomes.iter().map(|o| o.shuffle_secs).fold(0.0, f64::max),
        shuffle_bytes: fabric.total_bytes_sent(),
        words,
        reruns: 0,
    })
}

/// The map phase on one node: tokenize this node's block of lines into the
/// distributed map. Returns the number of words processed.
fn count_node_block(
    conf: &BlazeConf,
    lines: &Arc<Vec<String>>,
    range: &DistRange,
    rank: usize,
    map: &DistHashMap<String, u64>,
) -> u64 {
    let (lo, hi) = range.node_block(rank, conf.nnodes);
    let words = std::sync::atomic::AtomicU64::new(0);
    let tokenizer = conf.tokenizer;
    let key_path = conf.key_path;
    pool::parallel_for_range(
        conf.threads_per_node,
        lo,
        hi,
        Schedule::Dynamic { chunk: 64 },
        |ctx, i| {
            let line = &lines[i];
            let mut n = 0u64;
            match key_path {
                KeyPath::ZeroAlloc => {
                    tokenizer.for_each_token(line, |w| {
                        n += 1;
                        map.upsert_str(ctx.worker, w, 1, reducer::sum);
                    });
                }
                KeyPath::AllocPerToken => {
                    tokenizer.for_each_token(line, |w| {
                        n += 1;
                        map.upsert(ctx.worker, w.to_string(), 1, reducer::sum);
                    });
                }
            }
            words.fetch_add(n, std::sync::atomic::Ordering::Relaxed);
        },
    );
    words.load(std::sync::atomic::Ordering::Relaxed)
}

/// The paper's verbatim high-level interface, for the quickstart example:
/// a `DistRange` mapreduce with an explicit mapper closure.
pub fn word_count_paper_api(
    comm: &Comm,
    nthreads: usize,
    lines: &[String],
    target: &DistHashMap<String, u64>,
) {
    let range = DistRange::new(0, lines.len() as i64);
    range.mapreduce(comm, nthreads, target, reducer::sum, |i, emit| {
        for word in crate::corpus::split_spaces(&lines[i as usize]) {
            emit(word.to_string(), 1);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::CorpusSpec;

    fn serial_counts(c: &Corpus) -> HashMap<String, u64> {
        let mut m = HashMap::new();
        for line in &c.lines {
            for w in crate::corpus::split_spaces(line) {
                *m.entry(w.to_string()).or_insert(0u64) += 1;
            }
        }
        m
    }

    #[test]
    fn word_count_matches_serial() {
        let corpus = Corpus::generate(&CorpusSpec::with_bytes(128 << 10));
        let expect = serial_counts(&corpus);
        for nnodes in [1usize, 2, 4] {
            let conf = BlazeConf::for_tests(nnodes, 2);
            let report = word_count(&conf, &corpus).unwrap();
            assert_eq!(report.counts, expect, "nnodes={nnodes}");
            assert_eq!(report.words, expect.values().sum::<u64>());
        }
    }

    #[test]
    fn both_key_paths_agree() {
        let corpus = Corpus::generate(&CorpusSpec::with_bytes(64 << 10));
        let mut conf = BlazeConf::for_tests(2, 2);
        conf.key_path = KeyPath::AllocPerToken;
        let a = word_count(&conf, &corpus).unwrap();
        conf.key_path = KeyPath::ZeroAlloc;
        let b = word_count(&conf, &corpus).unwrap();
        assert_eq!(a.counts, b.counts);
    }

    #[test]
    fn combine_none_agrees_but_ships_more() {
        // Small vocab + tiling => heavy key repetition, so eager combining
        // collapses the shuffle volume.
        let corpus = Corpus::generate(&CorpusSpec {
            target_bytes: 256 << 10,
            base_block_bytes: Some(64 << 10),
            vocab_size: 1000,
            ..Default::default()
        });
        let mut conf = BlazeConf::for_tests(2, 2);
        let eager = word_count(&conf, &corpus).unwrap();
        conf.combine = CombineMode::None;
        let none = word_count(&conf, &corpus).unwrap();
        assert_eq!(eager.counts, none.counts);
        assert!(
            none.shuffle_bytes > eager.shuffle_bytes * 5,
            "uncombined {} vs combined {}",
            none.shuffle_bytes,
            eager.shuffle_bytes
        );
    }

    #[test]
    fn node_failure_triggers_rerun() {
        let corpus = Corpus::generate(&CorpusSpec::with_bytes(32 << 10));
        let conf = BlazeConf::for_tests(2, 2);
        let failures = FailurePlan::none().fail_node(1, 0);
        let report = word_count_with_failures(&conf, &corpus, &failures).unwrap();
        assert_eq!(report.reruns, 1);
        assert_eq!(report.counts, serial_counts(&corpus));
    }

    #[test]
    fn too_many_failures_aborts() {
        let corpus = Corpus::from_text("a b\n");
        let mut conf = BlazeConf::for_tests(1, 1);
        conf.max_job_reruns = 0; // no rerun budget: first failure aborts
        let failures = FailurePlan::none().fail_node(0, 0);
        let err = word_count_with_failures(&conf, &corpus, &failures).unwrap_err();
        assert_eq!(err.attempts, 1);
    }

    #[test]
    fn paper_api_counts() {
        use crate::cluster::spawn_cluster;
        let lines: Vec<String> =
            vec!["the cat".into(), "the hat".into(), "the cat".into()];
        let results = spawn_cluster(2, NetModel::ideal(), |comm| {
            let target: DistHashMap<String, u64> =
                DistHashMap::new(comm.rank, 2, 2, HashKind::Fx, CombineMode::Eager);
            word_count_paper_api(comm, 2, &lines, &target);
            target.to_vec_local()
        });
        let merged: HashMap<String, u64> = results.into_iter().flatten().collect();
        assert_eq!(merged.get("the"), Some(&3));
        assert_eq!(merged.get("cat"), Some(&2));
        assert_eq!(merged.get("hat"), Some(&1));
    }

    #[test]
    fn normalized_tokenizer_variant() {
        let corpus = Corpus::from_text("The cat! THE CAT?\n");
        let mut conf = BlazeConf::for_tests(1, 1);
        conf.tokenizer = Tokenizer::Normalized;
        let report = word_count(&conf, &corpus).unwrap();
        assert_eq!(report.counts.get("the"), Some(&2));
        assert_eq!(report.counts.get("cat"), Some(&2));
    }
}
