//! The two MapReduce engines under comparison.
//!
//! * [`blaze`] — the paper's MPI/OpenMP design (native, no fault tolerance,
//!   continuous map-side combine in a distributed hash map).
//! * [`spark`] — the Spark 2.4 baseline, simulated mechanism-by-mechanism
//!   (RDD lineage, stages at shuffle boundaries, serialized + persisted
//!   shuffle blocks, per-task dispatch overhead).

pub mod blaze;
pub mod spark;

/// Which engine a CLI/bench invocation targets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    Blaze,
    BlazeTcm,
    Spark,
}

impl Engine {
    pub fn parse(s: &str) -> Option<Engine> {
        match s {
            "blaze" => Some(Engine::Blaze),
            "blaze-tcm" | "tcm" => Some(Engine::BlazeTcm),
            "spark" => Some(Engine::Spark),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Engine::Blaze => "Blaze",
            Engine::BlazeTcm => "Blaze TCM",
            Engine::Spark => "Spark",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_parse() {
        assert_eq!(Engine::parse("blaze"), Some(Engine::Blaze));
        assert_eq!(Engine::parse("tcm"), Some(Engine::BlazeTcm));
        assert_eq!(Engine::parse("spark"), Some(Engine::Spark));
        assert_eq!(Engine::parse("flink"), None);
    }
}
