//! The two MapReduce engines under comparison.
//!
//! * [`blaze`] — the paper's MPI/OpenMP design (native, no fault tolerance,
//!   continuous map-side combine in a distributed hash map).
//! * [`spark`] — the Spark 2.4 baseline, simulated mechanism-by-mechanism
//!   (RDD lineage, stages at shuffle boundaries, serialized + persisted
//!   shuffle blocks, per-task dispatch overhead).
//!
//! Both execute arbitrary [`crate::mapreduce::Workload`]s — single- or
//! multi-input ([`crate::mapreduce::JobInputs`]), with or without a
//! shuffle exchange ([`crate::mapreduce::Workload::needs_shuffle`]); the
//! shared driver surface is [`crate::mapreduce::JobSpec`].

pub mod blaze;
pub mod spark;

/// Which engine a job targets — the paper's figure bars plus the stripped
/// Spark ablation floor. This is the single engine enum for the whole
/// stack; `wordcount::EngineChoice` re-exports it under its legacy name.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Engine {
    /// Paper's engine, per-token key allocation (the "Blaze" bar).
    Blaze,
    /// Paper's engine, zero-alloc insert path (the "Blaze TCM" bar).
    BlazeTcm,
    /// Spark-style baseline with faithful overheads.
    Spark,
    /// Spark with all modeled overheads stripped (ablation floor).
    SparkStripped,
}

impl Engine {
    pub fn parse(s: &str) -> Option<Engine> {
        match s {
            "blaze" => Some(Engine::Blaze),
            "blaze-tcm" | "tcm" => Some(Engine::BlazeTcm),
            "spark" => Some(Engine::Spark),
            "spark-stripped" => Some(Engine::SparkStripped),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Engine::Blaze => "Blaze",
            Engine::BlazeTcm => "Blaze TCM",
            Engine::Spark => "Spark",
            Engine::SparkStripped => "Spark (stripped)",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_parse() {
        assert_eq!(Engine::parse("blaze"), Some(Engine::Blaze));
        assert_eq!(Engine::parse("tcm"), Some(Engine::BlazeTcm));
        assert_eq!(Engine::parse("spark"), Some(Engine::Spark));
        assert_eq!(Engine::parse("spark-stripped"), Some(Engine::SparkStripped));
        assert_eq!(Engine::parse("flink"), None);
    }

    #[test]
    fn labels_are_distinct() {
        let all = [Engine::Blaze, Engine::BlazeTcm, Engine::Spark, Engine::SparkStripped];
        let mut labels: Vec<&str> = all.iter().map(|e| e.label()).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), all.len());
    }
}
