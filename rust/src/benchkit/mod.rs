//! Bench harness (the offline crate set has no `criterion`).
//!
//! Each bench binary (`rust/benches/*.rs`, `harness = false`) builds a
//! [`BenchRunner`], registers measurements, and prints markdown tables +
//! ASCII charts. Methodology: `warmup` untimed runs, then `reps` timed
//! runs; the reported statistic is median ± MAD (robust to stray outliers
//! on a shared machine). CSVs land in `target/bench-results/`.
//!
//! # Mapping numbers to the paper's setup
//!
//! The paper measured word count over a ~2 GB corpus (Bible + Shakespeare
//! repeated ~200×) on AWS r5.xlarge instances with "up to 10 Gigabit"
//! NICs. This repo reproduces that shape, scaled so a default run takes
//! seconds, with each paper-relevant quantity modeled rather than
//! hand-waved:
//!
//! * **Corpus** — [`crate::corpus::Corpus::generate`] tiles a
//!   Zipf-sampled base block exactly like the paper repeats its source
//!   text; `BLAZE_BENCH_BYTES` rescales it. Defaults: 32 MB, 30k vocab.
//! * **Network** — [`crate::cluster::NetModel::aws_like`] models the
//!   r5.xlarge class (~50 µs latency, 10 Gbit/s ≈ 1.25 GB/s); every
//!   inter-node transfer is really serialized and pays
//!   `latency + bytes/bandwidth` of wall-clock, so shuffle bytes are a
//!   *measured* cost in every reported rate.
//! * **Engines** — `Engine::Blaze` / `Engine::BlazeTcm` are the paper's
//!   two MPI/OpenMP bars (per-token alloc vs zero-alloc inserts);
//!   `Engine::Spark` carries the modeled Spark 2.4 overheads
//!   (serialization, task dispatch, UTF-16 strings, GC, persisted
//!   shuffle blocks); `Engine::SparkStripped` is the ablation floor with
//!   all of them off.
//!
//! A full-scale reproduction of the paper's headline figure:
//!
//! ```bash
//! BLAZE_BENCH_BYTES=2GB BLAZE_BENCH_REPS=5 cargo bench --bench figure1_wordcount
//! ```
//!
//! and the cross-workload grid (joins, sketches, grep included):
//!
//! ```bash
//! BLAZE_BENCH_BYTES=2GB cargo bench --bench workloads
//! ```
//!
//! # Environment knobs
//!
//! So `cargo bench` scales to the machine/time budget:
//! * `BLAZE_BENCH_BYTES`   — corpus size for the word-count benches
//!   (default 32 MB; the paper used 2 GB — set `BLAZE_BENCH_BYTES=2GB`
//!   for a full-scale run).
//! * `BLAZE_BENCH_REPS`    — timed repetitions (default 3).
//! * `BLAZE_BENCH_WARMUP`  — warmup runs (default 1).

use crate::util::stats::Summary;

/// One measured configuration.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    /// Seconds per rep.
    pub secs: Summary,
    /// Work units per rep (e.g. words), for rate reporting.
    pub work_units: f64,
    pub unit: &'static str,
}

impl Measurement {
    pub fn median_secs(&self) -> f64 {
        self.secs.median()
    }

    pub fn rate(&self) -> f64 {
        self.work_units / self.median_secs().max(1e-12)
    }
}

pub struct BenchRunner {
    pub title: String,
    pub reps: usize,
    pub warmup: usize,
    pub results: Vec<Measurement>,
}

impl BenchRunner {
    pub fn new(title: impl Into<String>) -> Self {
        Self {
            title: title.into(),
            reps: env_usize("BLAZE_BENCH_REPS", 3),
            warmup: env_usize("BLAZE_BENCH_WARMUP", 1),
            results: Vec::new(),
        }
    }

    /// Time `f` (which returns the work-unit count of one run).
    pub fn bench(&mut self, name: impl Into<String>, unit: &'static str, mut f: impl FnMut() -> f64) {
        let name = name.into();
        for _ in 0..self.warmup {
            let _ = f();
        }
        let mut secs = Summary::new();
        let mut work = 0.0;
        for _ in 0..self.reps.max(1) {
            let t0 = std::time::Instant::now();
            work = f();
            secs.add(t0.elapsed().as_secs_f64());
        }
        let m = Measurement { name: name.clone(), secs, work_units: work, unit };
        eprintln!(
            "  {name:<40} {:>10.4}s ± {:.4}s   {}",
            m.median_secs(),
            m.secs.mad(),
            crate::util::stats::fmt_rate(m.rate(), unit),
        );
        self.results.push(m);
    }

    /// Markdown table of all measurements.
    pub fn table(&self) -> crate::metrics::Table {
        let mut t = crate::metrics::Table::new(
            self.title.clone(),
            &["config", "median (s)", "mad (s)", "rate"],
        );
        for m in &self.results {
            t.row(&[
                m.name.clone(),
                format!("{:.4}", m.median_secs()),
                format!("{:.4}", m.secs.mad()),
                crate::util::stats::fmt_rate(m.rate(), m.unit),
            ]);
        }
        t
    }

    /// Bar chart of rates (the paper's figure format).
    pub fn chart(&self) -> String {
        let bars: Vec<(String, f64)> =
            self.results.iter().map(|m| (m.name.clone(), m.rate())).collect();
        let unit = self.results.first().map(|m| m.unit).unwrap_or("ops");
        crate::metrics::ascii_bar_chart(&self.title, &bars, unit)
    }

    /// Print table + chart and write the CSV under `target/bench-results/`.
    pub fn finish(&self) {
        println!("\n{}", self.table().to_markdown());
        println!("{}", self.chart());
        let slug: String = self
            .title
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
            .collect();
        let path = std::path::Path::new("target/bench-results").join(format!("{slug}.csv"));
        if let Err(e) = self.table().write_csv(&path) {
            eprintln!("warning: could not write {}: {e}", path.display());
        } else {
            println!("(csv written to {})", path.display());
        }
    }
}

/// Markdown table of per-stage rows ([`crate::mapreduce::StageStats`]) —
/// how a multi-stage run's wall clock and shuffle volume attribute to its
/// stages. Bench binaries print one per chained/multi-stage measurement
/// so bench rows stay comparable stage by stage.
pub fn stage_table(
    title: impl Into<String>,
    stages: &[crate::mapreduce::StageStats],
) -> crate::metrics::Table {
    let mut t = crate::metrics::Table::new(
        title.into(),
        &["stage", "records in", "records out", "shuffle", "dict keys", "wall (s)"],
    );
    for s in stages {
        let dict = if s.dict.is_zero() {
            "-".to_string()
        } else {
            format!(
                "{}->{} ({} uniq, {} refs)",
                crate::util::stats::fmt_bytes(s.dict.key_raw_bytes),
                crate::util::stats::fmt_bytes(s.dict.key_enc_bytes),
                s.dict.unique,
                s.dict.refs,
            )
        };
        t.row(&[
            format!("{} '{}'", s.stage, s.label),
            s.records_in.to_string(),
            s.records_out.to_string(),
            crate::util::stats::fmt_bytes(s.shuffle_bytes),
            dict,
            format!("{:.4}", s.wall_secs),
        ]);
    }
    t
}

/// One row of a machine-readable bench report: what the perf-trajectory
/// tooling consumes (wall + shuffle + spill volume per
/// workload×engine×threads).
#[derive(Clone, Debug)]
pub struct MachineRow {
    pub workload: String,
    pub engine: String,
    /// Real executor width the row ran at (`0` = unrecorded/auto — rows
    /// from benches that don't sweep the thread axis).
    pub threads: usize,
    pub wall_secs: f64,
    pub shuffle_bytes: u64,
    pub spilled_bytes: u64,
    /// Post-compression bytes the row's run actually put on disk
    /// (`spilled_bytes` stays logical — the pair is the compression
    /// ratio of the data-path ablations in `benches/spill.rs`). `0` =
    /// unrecorded (rows from benches that don't sweep the codec axis).
    pub stored_bytes: u64,
    /// Partition-cache hit rate (`hits / (hits + misses)`, in `[0, 1]`)
    /// of the row's run; `0.0` = unrecorded (rows from benches that don't
    /// touch the cache). The trace-lab rows (`benches/cache_policies.rs`)
    /// carry the replayed per-policy rate here.
    pub hit_rate: f64,
    /// Executor busy fraction (`busy_ns / (width * wall)`, in `[0, 1]`)
    /// over the row's run — worker utilization from the instrumented
    /// work-stealing pool. `0.0` = unrecorded (rows from benches that
    /// don't snapshot [`crate::runtime::executor::ExecMetrics`]).
    pub busy_frac: f64,
}

/// Machine-readable companion to the human tables: collected by the
/// bench binaries and written as JSON (e.g. `BENCH_5.json`) next to the
/// CSVs under `target/bench-results/`. Hand-rolled writer — the offline
/// crate set has no `serde`.
#[derive(Default)]
pub struct MachineReport {
    rows: Vec<MachineRow>,
}

impl MachineReport {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn row(
        &mut self,
        workload: impl Into<String>,
        engine: impl Into<String>,
        wall_secs: f64,
        shuffle_bytes: u64,
        spilled_bytes: u64,
    ) {
        self.row_threaded(workload, engine, 0, wall_secs, shuffle_bytes, spilled_bytes);
    }

    /// [`row`](Self::row) with the real executor width recorded — the
    /// thread axis of the scaling sweeps.
    pub fn row_threaded(
        &mut self,
        workload: impl Into<String>,
        engine: impl Into<String>,
        threads: usize,
        wall_secs: f64,
        shuffle_bytes: u64,
        spilled_bytes: u64,
    ) {
        self.rows.push(MachineRow {
            workload: workload.into(),
            engine: engine.into(),
            threads,
            wall_secs,
            shuffle_bytes,
            spilled_bytes,
            stored_bytes: 0,
            hit_rate: 0.0,
            busy_frac: 0.0,
        });
    }

    /// Data-path ablation row (`benches/spill.rs`): the codec/dictionary
    /// config rides in the `engine` column; `spilled_bytes` is the
    /// logical spill volume and `stored_bytes` what the disk tier
    /// actually wrote after compression.
    pub fn row_datapath(
        &mut self,
        workload: impl Into<String>,
        config: impl Into<String>,
        wall_secs: f64,
        shuffle_bytes: u64,
        spilled_bytes: u64,
        stored_bytes: u64,
    ) {
        self.rows.push(MachineRow {
            workload: workload.into(),
            engine: config.into(),
            threads: 0,
            wall_secs,
            shuffle_bytes,
            spilled_bytes,
            stored_bytes,
            hit_rate: 0.0,
            busy_frac: 0.0,
        });
    }

    /// [`row_threaded`](Self::row_threaded) with the executor busy
    /// fraction recorded — the utilization column of the scaling sweeps.
    #[allow(clippy::too_many_arguments)]
    pub fn row_exec(
        &mut self,
        workload: impl Into<String>,
        engine: impl Into<String>,
        threads: usize,
        wall_secs: f64,
        shuffle_bytes: u64,
        spilled_bytes: u64,
        busy_frac: f64,
    ) {
        self.rows.push(MachineRow {
            workload: workload.into(),
            engine: engine.into(),
            threads,
            wall_secs,
            shuffle_bytes,
            spilled_bytes,
            stored_bytes: 0,
            hit_rate: 0.0,
            busy_frac,
        });
    }

    /// Trace-lab row: one (workload × policy) replay, keyed like every
    /// other row (the policy name rides in the `engine` column) plus the
    /// replayed cache hit rate.
    pub fn row_cache(
        &mut self,
        workload: impl Into<String>,
        policy: impl Into<String>,
        wall_secs: f64,
        hit_rate: f64,
    ) {
        self.rows.push(MachineRow {
            workload: workload.into(),
            engine: policy.into(),
            threads: 0,
            wall_secs,
            shuffle_bytes: 0,
            spilled_bytes: 0,
            stored_bytes: 0,
            hit_rate,
            busy_frac: 0.0,
        });
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn to_json(&self) -> String {
        fn esc(s: &str) -> String {
            s.chars()
                .flat_map(|c| match c {
                    '"' => vec!['\\', '"'],
                    '\\' => vec!['\\', '\\'],
                    '\n' => vec!['\\', 'n'],
                    c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
                    c => vec![c],
                })
                .collect()
        }
        let mut out = String::from("{\n  \"rows\": [\n");
        for (i, r) in self.rows.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"workload\": \"{}\", \"engine\": \"{}\", \"threads\": {}, \
                 \"wall_secs\": {:.6}, \"shuffle_bytes\": {}, \"spilled_bytes\": {}, \
                 \"stored_bytes\": {}, \"hit_rate\": {:.6}, \"busy_frac\": {:.6}}}{}\n",
                esc(&r.workload),
                esc(&r.engine),
                r.threads,
                r.wall_secs,
                r.shuffle_bytes,
                r.spilled_bytes,
                r.stored_bytes,
                r.hit_rate,
                r.busy_frac,
                if i + 1 < self.rows.len() { "," } else { "" },
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write the JSON under `target/bench-results/<name>` and announce
    /// the path (mirrors [`BenchRunner::finish`]'s CSV behavior).
    pub fn write(&self, name: &str) {
        let path = std::path::Path::new("target/bench-results").join(name);
        if let Some(parent) = path.parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        match std::fs::write(&path, self.to_json()) {
            Ok(()) => println!("(json written to {})", path.display()),
            Err(e) => eprintln!("warning: could not write {}: {e}", path.display()),
        }
    }

    /// Like [`write`](Self::write), but rows already in the file whose
    /// `(workload, engine, threads)` key this report does **not** re-emit
    /// are kept — so several bench binaries (`workloads`,
    /// `figure1_wordcount`) can each contribute their slice of one
    /// `BENCH_N.json` without clobbering the other's rows.
    pub fn write_merged(&self, name: &str) {
        let path = std::path::Path::new("target/bench-results").join(name);
        let mut merged = MachineReport::new();
        if let Ok(existing) = std::fs::read_to_string(&path) {
            merged.rows.extend(parse_rows(&existing).into_iter().filter(|old| {
                !self.rows.iter().any(|r| {
                    r.workload == old.workload
                        && r.engine == old.engine
                        && r.threads == old.threads
                })
            }));
        }
        merged.rows.extend(self.rows.iter().cloned());
        merged.write(name);
    }
}

/// Parse rows back out of [`MachineReport::to_json`] output (one row
/// object per line — the only format [`MachineReport::write`] produces).
/// Tolerant: lines that don't carry the row fields are skipped.
pub fn parse_rows(json: &str) -> Vec<MachineRow> {
    fn str_field(line: &str, key: &str) -> Option<String> {
        let tag = format!("\"{key}\": \"");
        let rest = &line[line.find(&tag)? + tag.len()..];
        let mut out = String::new();
        let mut chars = rest.chars();
        while let Some(c) = chars.next() {
            match c {
                '"' => return Some(out),
                '\\' => match chars.next()? {
                    'n' => out.push('\n'),
                    'u' => {
                        let hex: String = chars.by_ref().take(4).collect();
                        out.push(char::from_u32(u32::from_str_radix(&hex, 16).ok()?)?);
                    }
                    c => out.push(c),
                },
                c => out.push(c),
            }
        }
        None
    }
    fn num_field<T: std::str::FromStr>(line: &str, key: &str) -> Option<T> {
        let tag = format!("\"{key}\": ");
        let rest = &line[line.find(&tag)? + tag.len()..];
        let end = rest
            .find(|c: char| !(c.is_ascii_digit() || c == '.' || c == '-'))
            .unwrap_or(rest.len());
        rest[..end].parse().ok()
    }
    json.lines()
        .filter_map(|line| {
            Some(MachineRow {
                workload: str_field(line, "workload")?,
                engine: str_field(line, "engine")?,
                // Absent in pre-threads files: read as the same
                // "unrecorded" marker `row` writes.
                threads: num_field(line, "threads").unwrap_or(0),
                wall_secs: num_field(line, "wall_secs")?,
                shuffle_bytes: num_field(line, "shuffle_bytes")?,
                spilled_bytes: num_field(line, "spilled_bytes")?,
                // Absent in pre-compression files: read as "unrecorded".
                stored_bytes: num_field(line, "stored_bytes").unwrap_or(0),
                // Absent in pre-trace-lab files: read as "unrecorded".
                hit_rate: num_field(line, "hit_rate").unwrap_or(0.0),
                // Absent in pre-observability files: read as "unrecorded".
                busy_frac: num_field(line, "busy_frac").unwrap_or(0.0),
            })
        })
        .collect()
}

/// Corpus size for word-count benches.
pub fn bench_corpus_bytes() -> u64 {
    std::env::var("BLAZE_BENCH_BYTES")
        .ok()
        .and_then(|s| crate::util::cli::parse_bytes(&s))
        .unwrap_or(32 << 20)
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_stats() {
        let mut r = BenchRunner::new("test bench");
        r.reps = 3;
        r.warmup = 0;
        r.bench("noop", "ops", || {
            std::hint::black_box(42);
            100.0
        });
        assert_eq!(r.results.len(), 1);
        assert_eq!(r.results[0].secs.count(), 3);
        assert!(r.results[0].rate() > 0.0);
        let md = r.table().to_markdown();
        assert!(md.contains("noop"));
    }

    #[test]
    fn corpus_bytes_default() {
        // Only check it parses to something sane (env may be set).
        assert!(bench_corpus_bytes() >= 1 << 10);
    }

    #[test]
    fn machine_report_emits_json_rows() {
        let mut r = MachineReport::new();
        assert!(r.is_empty());
        r.row("wordcount", "spark", 0.25, 1024, 0);
        r.row_threaded("join", "blaze-tcm", 4, 1.5, 4096, 2048);
        let json = r.to_json();
        assert!(json.contains("\"workload\": \"wordcount\""), "{json}");
        assert!(json.contains("\"threads\": 0"), "{json}");
        assert!(json.contains("\"threads\": 4"), "{json}");
        assert!(json.contains("\"spilled_bytes\": 2048"), "{json}");
        // Exactly one separating comma between the two rows.
        assert_eq!(json.matches("},\n").count(), 1, "{json}");
        assert!(json.trim_end().ends_with('}'), "{json}");
    }

    #[test]
    fn machine_report_escapes_strings() {
        let mut r = MachineReport::new();
        r.row("we\"ird\\name", "e\nngine", 0.0, 0, 0);
        let json = r.to_json();
        assert!(json.contains("we\\\"ird\\\\name"), "{json}");
        assert!(json.contains("e\\nngine"), "{json}");
    }

    #[test]
    fn machine_report_round_trips_through_parse() {
        let mut r = MachineReport::new();
        r.row_threaded("wordcount", "spark", 2, 0.25, 1024, 0);
        r.row("we\"ird\\name", "e\nngine", 1.5, 4096, 2048);
        let rows = parse_rows(&r.to_json());
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].workload, "wordcount");
        assert_eq!(rows[0].threads, 2);
        assert_eq!(rows[0].shuffle_bytes, 1024);
        assert_eq!(rows[1].workload, "we\"ird\\name");
        assert_eq!(rows[1].engine, "e\nngine");
        assert_eq!(rows[1].threads, 0);
        assert_eq!(rows[1].spilled_bytes, 2048);
    }

    #[test]
    fn exec_rows_round_trip_busy_fraction() {
        let mut r = MachineReport::new();
        r.row_exec("wordcount", "blaze-tcm", 8, 0.5, 1024, 0, 0.875);
        r.row("wordcount", "spark", 0.25, 1024, 0);
        let rows = parse_rows(&r.to_json());
        assert_eq!(rows.len(), 2);
        assert!((rows[0].busy_frac - 0.875).abs() < 1e-9);
        assert_eq!(rows[1].busy_frac, 0.0, "plain rows read as unrecorded");
        // Pre-busy-frac files parse too, defaulting the new column.
        let legacy = "    {\"workload\": \"w\", \"engine\": \"e\", \"threads\": 2, \
                      \"wall_secs\": 1.0, \"shuffle_bytes\": 3, \"spilled_bytes\": 4, \
                      \"hit_rate\": 0.5}\n";
        let rows = parse_rows(legacy);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].busy_frac, 0.0);
    }

    #[test]
    fn cache_rows_round_trip_hit_rate() {
        let mut r = MachineReport::new();
        r.row_cache("pagerank-trace", "slru", 0.01, 0.8125);
        r.row("wordcount", "spark", 0.25, 1024, 0);
        let rows = parse_rows(&r.to_json());
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].engine, "slru");
        assert!((rows[0].hit_rate - 0.8125).abs() < 1e-9);
        assert_eq!(rows[1].hit_rate, 0.0, "plain rows read as unrecorded");
        // Pre-hit-rate files parse too, defaulting the new column.
        let legacy = "    {\"workload\": \"w\", \"engine\": \"e\", \"threads\": 2, \
                      \"wall_secs\": 1.0, \"shuffle_bytes\": 3, \"spilled_bytes\": 4}\n";
        let rows = parse_rows(legacy);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].hit_rate, 0.0);
    }

    #[test]
    fn datapath_rows_round_trip_stored_bytes() {
        let mut r = MachineReport::new();
        r.row_datapath("wordcount-spill", "lz4+dict", 0.5, 1024, 8192, 2048);
        r.row("wordcount", "spark", 0.25, 1024, 0);
        let rows = parse_rows(&r.to_json());
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].engine, "lz4+dict");
        assert_eq!(rows[0].spilled_bytes, 8192);
        assert_eq!(rows[0].stored_bytes, 2048);
        assert_eq!(rows[1].stored_bytes, 0, "plain rows read as unrecorded");
        // Pre-compression files parse too, defaulting the new column.
        let legacy = "    {\"workload\": \"w\", \"engine\": \"e\", \"threads\": 2, \
                      \"wall_secs\": 1.0, \"shuffle_bytes\": 3, \"spilled_bytes\": 4, \
                      \"hit_rate\": 0.5, \"busy_frac\": 0.25}\n";
        let rows = parse_rows(legacy);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].stored_bytes, 0);
    }

    #[test]
    fn parse_rows_skips_non_row_lines() {
        let rows = parse_rows("{\n  \"rows\": [\n  ]\n}\nnot json\n");
        assert!(rows.is_empty());
    }
}
