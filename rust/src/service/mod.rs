//! Multi-tenant job service: many tenants, one executor, one store.
//!
//! [`JobService`] accepts a stream of [`JobRequest`]s tagged with a
//! tenant name and fair-share weight, compiles each through the existing
//! planner ([`JobSpec`] → `StageGraph`), and runs them *concurrently*
//! over the process-wide [`runtime`](crate::runtime) executor and one
//! shared [`TieredStore`](crate::storage::TieredStore). Three mechanisms
//! keep tenants from hurting each other:
//!
//! * **Stage-granular fair scheduling** ([`sched`]): every stage boundary
//!   re-contends a bounded pool of stage slots under weighted fair
//!   queueing across tenants ([`SchedPolicy::Fair`]) — a 40-round
//!   pagerank yields to a freshly-arrived grep at its next round
//!   boundary instead of draining first. [`SchedPolicy::Fifo`] keeps the
//!   single-queue baseline for comparison.
//! * **Tenant-namespaced storage**: tenant `i` owns cache-key namespaces
//!   `[(i+1)·2³², (i+2)·2³²)` and each job offsets generations by
//!   `seq · 2²⁰`, so jobs share one store without key collisions, and
//!   [`TieredStore::set_namespace_quota`] caps each tenant's resident
//!   bytes (over-quota inserts demote to disk at birth rather than
//!   evicting a neighbour).
//! * **Admission control**: `submit` rejects with a typed
//!   [`AdmissionError`] once `queue_cap` jobs are in flight or shutdown
//!   has begun — saturation is a refusal, not an OOM.
//!
//! Every decision is observable: admissions, queue waits, and
//! preemptions are trace spans ([`SpanCat::Admission`] /
//! [`SpanCat::QueueWait`] / [`SpanCat::Preemption`], arg = tenant
//! index), and [`JobService::report`] returns per-tenant
//! [`MetricSet`] rows. `blaze serve --script <arrivals.json>` replays an
//! arrival trace through all of it.

pub mod catalog;
pub mod script;
mod sched;

pub use catalog::{JobOutcome, JobRequest, WorkloadKind};
pub use sched::{SchedPolicy, TenantSchedStats};
pub use script::{parse_mix, parse_script, synthetic, ScriptEvent};

use std::fmt;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::cache::{CacheBudget, PartitionCache};
use crate::engines::Engine;
use crate::mapreduce::{JobSpec, MapReduceError, StageGate};
use crate::trace::metrics::MetricSet;
use crate::trace::{span_arg, SpanCat};

use sched::SchedCore;

/// Width of each tenant's cache-key namespace range. Tenant indices stay
/// well below the spill-namespace base (`2⁴²`), so service keys never
/// collide with engine spill namespaces.
pub const TENANT_NS_SPAN: u64 = 1 << 32;

/// First namespace of tenant `idx`'s range. Tenant 0 starts at `2³²`,
/// leaving the low namespaces for un-namespaced standalone jobs.
pub fn tenant_namespace_base(idx: usize) -> u64 {
    (idx as u64 + 1) * TENANT_NS_SPAN
}

/// Generation offset of the service's `seq`-th job: iterative drivers
/// bump per-round generations in the 2²⁰ space below this, so no two
/// jobs ever reuse a `(namespace, generation)` pair.
fn job_generation_base(seq: u64) -> u64 {
    seq << 20
}

// --------------------------------------------------------------- conf ----

/// Service-wide configuration: the "how" every admitted job inherits.
#[derive(Clone, Debug)]
pub struct ServiceConf {
    pub engine: Engine,
    /// Executor threads per job (`None` = the spec default).
    pub threads: Option<usize>,
    /// Concurrent stage slots the scheduler hands out.
    pub slots: usize,
    /// Max jobs in flight (queued + running); beyond it `submit` rejects.
    pub queue_cap: usize,
    pub policy: SchedPolicy,
    /// Memory budget of the shared store.
    pub store_budget: CacheBudget,
    /// Per-tenant cap on resident store bytes (see
    /// [`TieredStore::set_namespace_quota`](crate::storage::TieredStore::set_namespace_quota)).
    pub tenant_quota: Option<u64>,
    /// Bound each job's exchange memory (spills beyond it).
    pub spill_threshold: Option<u64>,
    /// Spill/demotion directory; also gives the shared store a disk tier
    /// so over-quota inserts demote instead of being refused.
    pub spill_dir: Option<PathBuf>,
}

impl ServiceConf {
    pub fn new() -> Self {
        Self {
            engine: Engine::BlazeTcm,
            threads: None,
            slots: 2,
            queue_cap: 32,
            policy: SchedPolicy::Fair,
            store_budget: CacheBudget::Unbounded,
            tenant_quota: None,
            spill_threshold: None,
            spill_dir: None,
        }
    }

    pub fn engine(mut self, engine: Engine) -> Self {
        self.engine = engine;
        self
    }

    pub fn threads(mut self, t: usize) -> Self {
        self.threads = Some(t);
        self
    }

    pub fn slots(mut self, slots: usize) -> Self {
        self.slots = slots.max(1);
        self
    }

    pub fn queue_cap(mut self, cap: usize) -> Self {
        self.queue_cap = cap.max(1);
        self
    }

    pub fn policy(mut self, policy: SchedPolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn store_budget(mut self, budget: CacheBudget) -> Self {
        self.store_budget = budget;
        self
    }

    pub fn tenant_quota(mut self, bytes: u64) -> Self {
        self.tenant_quota = Some(bytes);
        self
    }

    pub fn spill_threshold(mut self, bytes: u64) -> Self {
        self.spill_threshold = Some(bytes);
        self
    }

    pub fn spill_dir(mut self, dir: PathBuf) -> Self {
        self.spill_dir = Some(dir);
        self
    }
}

impl Default for ServiceConf {
    fn default() -> Self {
        Self::new()
    }
}

// ---------------------------------------------------------- admission ----

/// Why the service refused a [`JobRequest`] at the door.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AdmissionError {
    /// In-flight jobs (queued + running) already at the cap.
    Saturated { in_flight: usize, cap: usize },
    /// `shutdown` has begun; no new work is admitted.
    ShuttingDown,
}

impl fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AdmissionError::Saturated { in_flight, cap } => {
                write!(f, "service saturated: {in_flight} job(s) in flight (cap {cap})")
            }
            AdmissionError::ShuttingDown => write!(f, "service is shutting down"),
        }
    }
}

impl std::error::Error for AdmissionError {}

// --------------------------------------------------------- job states ----

/// What a submitted job resolved to (the terminal variants carry why).
#[derive(Clone, Debug)]
pub enum JobStatus {
    /// Admitted, waiting for its first stage slot.
    Queued,
    Running,
    Done(JobSummary),
    Failed(String),
    Cancelled,
}

impl JobStatus {
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobStatus::Done(_) | JobStatus::Failed(_) | JobStatus::Cancelled)
    }

    pub fn label(&self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Running => "running",
            JobStatus::Done(_) => "done",
            JobStatus::Failed(_) => "failed",
            JobStatus::Cancelled => "cancelled",
        }
    }
}

/// A completed job's result.
#[derive(Clone, Debug)]
pub struct JobSummary {
    /// Submit → completion, queue wait included.
    pub latency_secs: f64,
    /// Wall inside the engine (stage execution only).
    pub exec_secs: f64,
    pub records: u64,
    /// Canonical sorted-line rendering of the output (see
    /// [`JobOutcome::lines`]).
    pub lines: Vec<String>,
    /// The in-job oracle check ran and passed.
    pub verified: bool,
}

#[derive(Debug)]
struct JobState {
    /// Submission sequence number — doubles as the FIFO rank.
    id: u64,
    tenant: usize,
    tenant_name: String,
    kind: WorkloadKind,
    submitted_at: Instant,
    cancelled: AtomicBool,
    status: Mutex<JobStatus>,
    done: Condvar,
}

impl JobState {
    fn set_status(&self, s: JobStatus) {
        *self.status.lock().unwrap() = s;
        self.done.notify_all();
    }
}

/// Caller-side handle for a submitted job.
#[derive(Clone, Debug)]
pub struct JobHandle {
    state: Arc<JobState>,
    shared: Arc<Shared>,
}

impl JobHandle {
    pub fn id(&self) -> u64 {
        self.state.id
    }

    pub fn tenant(&self) -> &str {
        &self.state.tenant_name
    }

    pub fn kind(&self) -> WorkloadKind {
        self.state.kind
    }

    pub fn poll(&self) -> JobStatus {
        self.state.status.lock().unwrap().clone()
    }

    /// Block until the job reaches a terminal status.
    pub fn wait(&self) -> JobStatus {
        let mut st = self.state.status.lock().unwrap();
        while !st.is_terminal() {
            st = self.state.done.wait(st).unwrap();
        }
        st.clone()
    }

    /// Request cancellation; the job stops at its next stage boundary.
    /// Returns false if it had already reached a terminal status.
    pub fn cancel(&self) -> bool {
        if self.poll().is_terminal() {
            return false;
        }
        self.state.cancelled.store(true, Relaxed);
        self.shared.core.kick();
        true
    }
}

// ------------------------------------------------------------ service ----

#[derive(Debug, Default)]
struct TenantCounters {
    submitted: u64,
    completed: u64,
    failed: u64,
    cancelled: u64,
    rejected: u64,
}

#[derive(Debug)]
struct TenantEntry {
    name: String,
    counters: TenantCounters,
}

#[derive(Debug)]
struct Shared {
    conf: ServiceConf,
    core: SchedCore,
    store: Arc<PartitionCache>,
    tenants: Mutex<Vec<TenantEntry>>,
    in_flight: AtomicU64,
    next_seq: AtomicU64,
    shutting_down: AtomicBool,
    submitted: AtomicU64,
    rejected: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    cancelled: AtomicU64,
}

impl Shared {
    fn tenant_counter(&self, idx: usize, f: impl FnOnce(&mut TenantCounters)) {
        f(&mut self.tenants.lock().unwrap()[idx].counters)
    }
}

/// The job's stage-boundary hook: acquire a slot from the scheduler on
/// entry, charge the measured wall to the tenant's vtime on exit.
#[derive(Debug)]
struct ServiceGate {
    shared: Arc<Shared>,
    state: Arc<JobState>,
}

impl StageGate for ServiceGate {
    fn begin_stage(&self, _stage: u64) -> Result<(), MapReduceError> {
        self.shared
            .core
            .acquire(self.state.tenant, self.state.id, &self.state.cancelled)
            .map_err(|()| MapReduceError(format!("job {} cancelled while queued", self.state.id)))
    }

    fn end_stage(&self, _stage: u64, wall_secs: f64) {
        self.shared.core.release(self.state.tenant, wall_secs);
    }
}

/// The multi-tenant job service. See the [module docs](self).
#[derive(Debug)]
pub struct JobService {
    shared: Arc<Shared>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    started: Instant,
}

impl JobService {
    pub fn new(conf: ServiceConf) -> Self {
        // Quota demotion and low-budget operation both need somewhere to
        // demote to, so any of the pressure knobs implies a disk tier
        // (`None` spill dir = the system temp directory).
        let want_disk =
            conf.spill_dir.is_some() || conf.spill_threshold.is_some() || conf.tenant_quota.is_some();
        let store = if want_disk {
            Arc::new(PartitionCache::with_spill(
                conf.store_budget,
                Arc::new(crate::storage::DiskTier::new(conf.spill_dir.clone())),
            ))
        } else {
            Arc::new(PartitionCache::new(conf.store_budget))
        };
        let core = SchedCore::new(conf.slots, conf.policy);
        Self {
            shared: Arc::new(Shared {
                conf,
                core,
                store,
                tenants: Mutex::new(Vec::new()),
                in_flight: AtomicU64::new(0),
                next_seq: AtomicU64::new(0),
                shutting_down: AtomicBool::new(false),
                submitted: AtomicU64::new(0),
                rejected: AtomicU64::new(0),
                completed: AtomicU64::new(0),
                failed: AtomicU64::new(0),
                cancelled: AtomicU64::new(0),
            }),
            workers: Mutex::new(Vec::new()),
            started: Instant::now(),
        }
    }

    /// The shared store (tests inspect per-tenant residency through it).
    pub fn store(&self) -> &Arc<PartitionCache> {
        &self.shared.store
    }

    /// Jobs admitted but not yet terminal.
    pub fn in_flight(&self) -> usize {
        self.shared.in_flight.load(Relaxed) as usize
    }

    /// Index of `name` in the tenant table, registering it (scheduler
    /// row + store quota) on first sight. The weight is fixed at first
    /// registration.
    fn tenant_index(&self, name: &str, weight: u64) -> usize {
        let mut tenants = self.shared.tenants.lock().unwrap();
        if let Some(idx) = tenants.iter().position(|t| t.name == name) {
            return idx;
        }
        let idx = self.shared.core.register_tenant(weight);
        debug_assert_eq!(idx, tenants.len());
        if let Some(quota) = self.shared.conf.tenant_quota {
            let base = tenant_namespace_base(idx);
            self.shared.store.set_namespace_quota(base, base + TENANT_NS_SPAN, quota);
        }
        tenants.push(TenantEntry { name: name.to_string(), counters: TenantCounters::default() });
        idx
    }

    /// Admit `req` or refuse it with a typed reason. Admitted jobs run
    /// on their own worker thread, contending for stage slots through
    /// the scheduler; the returned handle polls, waits, and cancels.
    pub fn submit(&self, req: JobRequest) -> Result<JobHandle, AdmissionError> {
        let tenant = self.tenant_index(&req.tenant, req.weight);
        let _adm = span_arg(SpanCat::Admission, "admission", tenant as u64);
        self.shared.submitted.fetch_add(1, Relaxed);
        self.shared.tenant_counter(tenant, |c| c.submitted += 1);
        if self.shared.shutting_down.load(Relaxed) {
            self.shared.rejected.fetch_add(1, Relaxed);
            self.shared.tenant_counter(tenant, |c| c.rejected += 1);
            return Err(AdmissionError::ShuttingDown);
        }
        let in_flight = self.shared.in_flight.load(Relaxed) as usize;
        if in_flight >= self.shared.conf.queue_cap {
            self.shared.rejected.fetch_add(1, Relaxed);
            self.shared.tenant_counter(tenant, |c| c.rejected += 1);
            return Err(AdmissionError::Saturated { in_flight, cap: self.shared.conf.queue_cap });
        }
        self.shared.in_flight.fetch_add(1, Relaxed);
        let seq = self.shared.next_seq.fetch_add(1, Relaxed);
        let state = Arc::new(JobState {
            id: seq,
            tenant,
            tenant_name: req.tenant.clone(),
            kind: req.kind,
            submitted_at: Instant::now(),
            cancelled: AtomicBool::new(false),
            status: Mutex::new(JobStatus::Queued),
            done: Condvar::new(),
        });
        let handle = JobHandle { state: Arc::clone(&state), shared: Arc::clone(&self.shared) };
        let shared = Arc::clone(&self.shared);
        let worker = std::thread::Builder::new()
            .name(format!("blaze-svc-{seq}"))
            .spawn(move || run_job(shared, state, req))
            .expect("spawn service job thread");
        self.workers.lock().unwrap().push(worker);
        Ok(handle)
    }

    /// Stop admitting, drain every in-flight job, and report.
    pub fn shutdown(self) -> ServiceReport {
        self.shared.shutting_down.store(true, Relaxed);
        let workers = std::mem::take(&mut *self.workers.lock().unwrap());
        for w in workers {
            let _ = w.join();
        }
        self.report()
    }

    /// Snapshot the admission ledger and per-tenant metrics.
    pub fn report(&self) -> ServiceReport {
        let sh = &self.shared;
        let sched = sh.core.tenant_stats();
        let tenants = sh.tenants.lock().unwrap();
        let rows = tenants
            .iter()
            .enumerate()
            .map(|(i, t)| {
                let mut m = MetricSet::new();
                m.set_count("jobs.submitted", t.counters.submitted);
                m.set_count("jobs.completed", t.counters.completed);
                m.set_count("jobs.failed", t.counters.failed);
                m.set_count("jobs.cancelled", t.counters.cancelled);
                m.set_count("jobs.rejected", t.counters.rejected);
                if let Some(s) = sched.get(i) {
                    m.set_count("sched.weight", s.weight);
                    m.set_secs("sched.queue_wait", s.queue_wait_secs);
                    m.set_secs("sched.stage_wall", s.stage_secs);
                    m.set_count("sched.stages", s.stages);
                    m.set_count("sched.bypassed", s.bypassed);
                }
                let base = tenant_namespace_base(i);
                m.set_bytes("store.resident", sh.store.bytes_in_namespace_range(base, base + TENANT_NS_SPAN));
                if let Some(q) = sh.store.namespace_quota_bytes(base) {
                    m.set_bytes("store.quota", q);
                }
                TenantReport { name: t.name.clone(), metrics: m }
            })
            .collect();
        ServiceReport {
            wall_secs: self.started.elapsed().as_secs_f64(),
            submitted: sh.submitted.load(Relaxed),
            rejected: sh.rejected.load(Relaxed),
            completed: sh.completed.load(Relaxed),
            failed: sh.failed.load(Relaxed),
            cancelled: sh.cancelled.load(Relaxed),
            in_flight: sh.in_flight.load(Relaxed),
            preemptions: sh.core.preemptions(),
            tenants: rows,
        }
    }
}

/// Body of a job's worker thread: provision the spec with the tenant's
/// key bases, the shared store, and the scheduling gate, then run the
/// catalog workload and settle the ledger.
fn run_job(shared: Arc<Shared>, state: Arc<JobState>, req: JobRequest) {
    state.set_status(JobStatus::Running);
    let gate: Arc<dyn StageGate> =
        Arc::new(ServiceGate { shared: Arc::clone(&shared), state: Arc::clone(&state) });
    let mut spec = JobSpec::new(shared.conf.engine)
        .shared_cache(Arc::clone(&shared.store))
        .stage_gate(gate)
        .namespace_base(tenant_namespace_base(state.tenant))
        .generation_base(job_generation_base(state.id));
    if let Some(t) = shared.conf.threads {
        spec = spec.threads(t);
    }
    if let Some(b) = shared.conf.spill_threshold {
        spec = spec.spill_threshold(b);
    }
    if let Some(d) = &shared.conf.spill_dir {
        spec = spec.spill_dir(d.clone());
    }
    let outcome = catalog::execute(req, spec);
    let latency = state.submitted_at.elapsed().as_secs_f64();
    let status = match outcome {
        Ok(out) => {
            shared.completed.fetch_add(1, Relaxed);
            shared.tenant_counter(state.tenant, |c| c.completed += 1);
            JobStatus::Done(JobSummary {
                latency_secs: latency,
                exec_secs: out.exec_secs,
                records: out.records,
                lines: out.lines,
                verified: out.verified,
            })
        }
        Err(_) if state.cancelled.load(Relaxed) => {
            shared.cancelled.fetch_add(1, Relaxed);
            shared.tenant_counter(state.tenant, |c| c.cancelled += 1);
            JobStatus::Cancelled
        }
        Err(e) => {
            shared.failed.fetch_add(1, Relaxed);
            shared.tenant_counter(state.tenant, |c| c.failed += 1);
            JobStatus::Failed(e.to_string())
        }
    };
    shared.in_flight.fetch_sub(1, Relaxed);
    state.set_status(status);
}

// ------------------------------------------------------------- report ----

/// One tenant's row in the service report.
#[derive(Clone, Debug)]
pub struct TenantReport {
    pub name: String,
    pub metrics: MetricSet,
}

/// The service's admission ledger plus per-tenant metrics.
#[derive(Clone, Debug)]
pub struct ServiceReport {
    pub wall_secs: f64,
    /// Every `submit` call, including refused ones.
    pub submitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub cancelled: u64,
    /// Jobs still running when the snapshot was taken (0 after
    /// [`JobService::shutdown`]).
    pub in_flight: u64,
    pub preemptions: u64,
    pub tenants: Vec<TenantReport>,
}

impl ServiceReport {
    /// The admission ledger balances: every submitted job is accounted
    /// for exactly once. The property suite enforces this invariant over
    /// random arrival schedules.
    pub fn balances(&self) -> bool {
        self.in_flight == 0
            && self.submitted
                == self.completed + self.failed + self.cancelled + self.rejected
    }

    pub fn render(&self) -> String {
        let mut out = format!(
            "service: {} submitted = {} completed + {} failed + {} cancelled + {} rejected \
             ({} in flight) in {:.2}s; {} preemption(s)\n",
            self.submitted,
            self.completed,
            self.failed,
            self.cancelled,
            self.rejected,
            self.in_flight,
            self.wall_secs,
            self.preemptions,
        );
        for t in &self.tenants {
            out.push_str(&format!("  tenant {:<12} {}\n", t.name, t.metrics));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conf() -> ServiceConf {
        ServiceConf::new().threads(2).slots(2)
    }

    /// Two tenants' verified jobs run concurrently to completion and the
    /// ledger balances.
    #[test]
    fn mixed_tenants_complete_and_balance() {
        let svc = JobService::new(conf());
        let mut handles = Vec::new();
        for (tenant, kind) in [
            ("alpha", WorkloadKind::Grep),
            ("beta", WorkloadKind::WordCount),
            ("alpha", WorkloadKind::PageRank),
            ("beta", WorkloadKind::Grep),
        ] {
            let req = JobRequest::new(tenant, kind).bytes(8 << 10).rounds(2).verify(true);
            handles.push(svc.submit(req).expect("admitted"));
        }
        for h in &handles {
            match h.wait() {
                JobStatus::Done(s) => assert!(s.verified),
                other => panic!("job {} ({}) ended {other:?}", h.id(), h.tenant()),
            }
        }
        let report = svc.shutdown();
        assert_eq!(report.completed, 4);
        assert!(report.balances(), "{}", report.render());
        assert_eq!(report.tenants.len(), 2);
        assert_eq!(report.tenants[0].metrics.count("jobs.submitted"), 2);
    }

    /// Saturation is a typed refusal, not a hang or an OOM.
    #[test]
    fn saturated_service_rejects_with_reason() {
        let svc = JobService::new(conf().queue_cap(1));
        let first = svc
            .submit(JobRequest::new("a", WorkloadKind::PageRank).bytes(32 << 10).rounds(3))
            .expect("first admitted");
        let refused = svc.submit(JobRequest::new("b", WorkloadKind::Grep).bytes(4 << 10));
        assert_eq!(
            refused.expect_err("cap reached"),
            AdmissionError::Saturated { in_flight: 1, cap: 1 }
        );
        first.wait();
        let report = svc.shutdown();
        assert_eq!((report.completed, report.rejected), (1, 1));
        assert!(report.balances());
    }

    /// Cancellation lands at a stage boundary and settles as Cancelled.
    #[test]
    fn cancelled_job_settles_as_cancelled() {
        // One slot shared by two multi-stage jobs: the victim cannot
        // finish its dozen stage-boundary gate crossings before the
        // cancel flag lands, so cancellation reaches it mid-flight.
        let svc = JobService::new(conf().slots(1));
        let long = svc
            .submit(JobRequest::new("a", WorkloadKind::PageRank).bytes(64 << 10).rounds(6))
            .expect("admitted");
        let victim = svc
            .submit(JobRequest::new("b", WorkloadKind::PageRank).bytes(64 << 10).rounds(6))
            .expect("admitted");
        assert!(victim.cancel());
        assert!(matches!(victim.wait(), JobStatus::Cancelled));
        assert!(matches!(long.wait(), JobStatus::Done(_)));
        let report = svc.shutdown();
        assert_eq!((report.completed, report.cancelled), (1, 1));
        assert!(report.balances(), "{}", report.render());
    }
}
