//! Stage-granular scheduler core for the job service.
//!
//! Jobs never own the executor: every stage boundary passes through
//! [`SchedCore::acquire`], which parks the calling job thread until the
//! scheduler grants one of `slots` concurrent stage permits. Because the
//! permit is re-contended *per stage*, a long iterative job yields to a
//! newly-arrived short job at its next round boundary instead of holding
//! the service until it finishes. Two policies:
//!
//! * [`SchedPolicy::Fifo`] — waiters are ranked by job sequence number:
//!   the oldest submitted job wins every grant, so an early long job
//!   drains to completion before anything behind it runs. This is the
//!   single-queue baseline `benches/service.rs` measures against.
//! * [`SchedPolicy::Fair`] — weighted fair queueing across tenants. A
//!   tenant accrues virtual time `vtime += stage_wall / weight` for each
//!   stage it completes; the waiter whose tenant has the smallest vtime
//!   runs next. A tenant that went idle re-enters at the busy minimum
//!   (`vtime = max(own, min busy vtime)`) so sleeping never banks credit.
//!
//! Every decision is observable: the park inside `acquire` is wrapped in
//! a [`SpanCat::QueueWait`] span (arg = tenant index), and a fair grant
//! that jumps an older waiter emits a [`SpanCat::Preemption`] span whose
//! arg is the bypassed tenant's index.

use std::sync::atomic::{AtomicBool, Ordering::Relaxed};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use crate::trace::{span_arg, SpanCat};

/// How the service orders waiting stages.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Weighted fair queueing across tenants (the default).
    Fair,
    /// Strict job-submission order — the single-queue baseline.
    Fifo,
}

impl SchedPolicy {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "fair" | "wfq" => Some(Self::Fair),
            "fifo" => Some(Self::Fifo),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Fair => "fair",
            Self::Fifo => "fifo",
        }
    }
}

#[derive(Debug)]
struct TenantSched {
    vtime: f64,
    weight: u64,
    /// Stages currently holding a slot.
    active: usize,
    /// Stages parked in `acquire`.
    waiting: usize,
    queue_wait_secs: f64,
    stage_secs: f64,
    stages: u64,
    /// Times an older waiter of this tenant was jumped by a fair grant.
    bypassed: u64,
}

#[derive(Debug)]
struct Waiter {
    /// Arrival order of this *stage* request (tie-breaker).
    ticket: u64,
    /// Submission order of the owning job (FIFO rank).
    job_seq: u64,
    tenant: usize,
}

#[derive(Debug)]
struct SchedState {
    slots_free: usize,
    next_ticket: u64,
    waiters: Vec<Waiter>,
    tenants: Vec<TenantSched>,
    preemptions: u64,
}

/// Per-tenant scheduling totals for the service report.
#[derive(Clone, Debug)]
pub struct TenantSchedStats {
    pub weight: u64,
    pub queue_wait_secs: f64,
    pub stage_secs: f64,
    pub stages: u64,
    pub bypassed: u64,
}

#[derive(Debug)]
pub(crate) struct SchedCore {
    policy: SchedPolicy,
    state: Mutex<SchedState>,
    cond: Condvar,
}

impl SchedCore {
    pub(crate) fn new(slots: usize, policy: SchedPolicy) -> Self {
        Self {
            policy,
            state: Mutex::new(SchedState {
                slots_free: slots.max(1),
                next_ticket: 0,
                waiters: Vec::new(),
                tenants: Vec::new(),
                preemptions: 0,
            }),
            cond: Condvar::new(),
        }
    }

    /// Register a tenant; returns its dense scheduler index. The tenant
    /// starts at the busy minimum vtime, not zero, so late arrivals get
    /// no retroactive credit for time before they existed.
    pub(crate) fn register_tenant(&self, weight: u64) -> usize {
        let mut st = self.state.lock().unwrap();
        let vtime = busy_min_vtime(&st).unwrap_or(0.0);
        st.tenants.push(TenantSched {
            vtime,
            weight: weight.max(1),
            active: 0,
            waiting: 0,
            queue_wait_secs: 0.0,
            stage_secs: 0.0,
            stages: 0,
            bypassed: 0,
        });
        st.tenants.len() - 1
    }

    /// Block until the scheduler grants a stage slot. Returns `Err(())`
    /// if `cancelled` is raised while parked (the caller must [`kick`]
    /// after raising the flag so parked waiters recheck it).
    ///
    /// [`kick`]: Self::kick
    pub(crate) fn acquire(
        &self,
        tenant: usize,
        job_seq: u64,
        cancelled: &AtomicBool,
    ) -> Result<(), ()> {
        let _wait = span_arg(SpanCat::QueueWait, "queue-wait", tenant as u64);
        let started = Instant::now();
        let mut st = self.state.lock().unwrap();
        // Idle catch-up: a tenant with nothing running or queued re-enters
        // at the busy minimum so time spent idle never banks credit.
        if st.tenants[tenant].active + st.tenants[tenant].waiting == 0 {
            if let Some(min) = busy_min_vtime(&st) {
                let t = &mut st.tenants[tenant];
                if t.vtime < min {
                    t.vtime = min;
                }
            }
        }
        let ticket = st.next_ticket;
        st.next_ticket += 1;
        st.waiters.push(Waiter { ticket, job_seq, tenant });
        st.tenants[tenant].waiting += 1;
        loop {
            if cancelled.load(Relaxed) {
                st.waiters.retain(|w| w.ticket != ticket);
                st.tenants[tenant].waiting -= 1;
                drop(st);
                // Our departure may unblock the pick for someone else.
                self.cond.notify_all();
                return Err(());
            }
            if st.slots_free > 0 && self.pick(&st) == Some(ticket) {
                break;
            }
            st = self.cond.wait(st).unwrap();
        }
        // Granted. A fair grant that jumps the oldest waiting job is a
        // preemption of that job's turn — record whose. (Ranked by job
        // submission order, so FIFO grants never count as preemptions.)
        if let Some(oldest) = st.waiters.iter().min_by_key(|w| (w.job_seq, w.ticket)) {
            if oldest.ticket != ticket {
                let bypassed = oldest.tenant;
                st.preemptions += 1;
                st.tenants[bypassed].bypassed += 1;
                drop(span_arg(SpanCat::Preemption, "preemption", bypassed as u64));
            }
        }
        st.slots_free -= 1;
        st.waiters.retain(|w| w.ticket != ticket);
        let t = &mut st.tenants[tenant];
        t.waiting -= 1;
        t.active += 1;
        t.queue_wait_secs += started.elapsed().as_secs_f64();
        Ok(())
    }

    /// Release a stage slot, charging `wall_secs / weight` to the
    /// tenant's virtual time.
    pub(crate) fn release(&self, tenant: usize, wall_secs: f64) {
        let mut st = self.state.lock().unwrap();
        st.slots_free += 1;
        let t = &mut st.tenants[tenant];
        t.active -= 1;
        t.vtime += wall_secs / t.weight as f64;
        t.stage_secs += wall_secs;
        t.stages += 1;
        drop(st);
        self.cond.notify_all();
    }

    /// Wake every parked waiter so cancellation flags get rechecked.
    pub(crate) fn kick(&self) {
        self.cond.notify_all();
    }

    pub(crate) fn preemptions(&self) -> u64 {
        self.state.lock().unwrap().preemptions
    }

    pub(crate) fn tenant_stats(&self) -> Vec<TenantSchedStats> {
        self.state
            .lock()
            .unwrap()
            .tenants
            .iter()
            .map(|t| TenantSchedStats {
                weight: t.weight,
                queue_wait_secs: t.queue_wait_secs,
                stage_secs: t.stage_secs,
                stages: t.stages,
                bypassed: t.bypassed,
            })
            .collect()
    }

    /// The ticket that should run next, or `None` with no waiters.
    fn pick(&self, st: &SchedState) -> Option<u64> {
        match self.policy {
            SchedPolicy::Fifo => {
                st.waiters.iter().min_by_key(|w| (w.job_seq, w.ticket)).map(|w| w.ticket)
            }
            SchedPolicy::Fair => st
                .waiters
                .iter()
                .min_by(|a, b| {
                    let (va, vb) = (st.tenants[a.tenant].vtime, st.tenants[b.tenant].vtime);
                    va.partial_cmp(&vb)
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.ticket.cmp(&b.ticket))
                })
                .map(|w| w.ticket),
        }
    }

    #[cfg(test)]
    fn waiting_count(&self) -> usize {
        self.state.lock().unwrap().waiters.len()
    }
}

/// Minimum vtime over tenants with work in the system (running or
/// waiting); `None` when the service is idle.
fn busy_min_vtime(st: &SchedState) -> Option<f64> {
    st.tenants
        .iter()
        .filter(|t| t.active + t.waiting > 0)
        .map(|t| t.vtime)
        .min_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::sync::Arc;
    use std::time::Duration;

    fn park_until(core: &SchedCore, waiters: usize) {
        for _ in 0..2000 {
            if core.waiting_count() >= waiters {
                return;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        panic!("waiters never parked");
    }

    /// FIFO ranks by job submission order even when the younger job's
    /// stage request arrived first.
    #[test]
    fn fifo_grants_in_job_order() {
        let core = Arc::new(SchedCore::new(1, SchedPolicy::Fifo));
        let t0 = core.register_tenant(1);
        let flag = Arc::new(AtomicBool::new(false));
        core.acquire(t0, 0, &flag).unwrap();

        let (tx, rx) = mpsc::channel();
        let mut joins = Vec::new();
        // Job 5's stage request is registered before job 2's.
        for job in [5u64, 2] {
            let (core, flag, tx) = (Arc::clone(&core), Arc::clone(&flag), tx.clone());
            joins.push(std::thread::spawn(move || {
                core.acquire(t0, job, &flag).unwrap();
                tx.send(job).unwrap();
                core.release(t0, 0.0);
            }));
            park_until(&core, joins.len());
        }
        core.release(t0, 1.0);
        assert_eq!(rx.recv_timeout(Duration::from_secs(5)).unwrap(), 2);
        assert_eq!(rx.recv_timeout(Duration::from_secs(5)).unwrap(), 5);
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(core.preemptions(), 0, "fifo never jumps the oldest waiter");
    }

    /// Fair picks the tenant with less accrued service even when its
    /// waiter (and job) is younger, and records the bypass.
    #[test]
    fn fair_prefers_lighter_tenant_and_counts_preemption() {
        let core = Arc::new(SchedCore::new(1, SchedPolicy::Fair));
        let heavy = core.register_tenant(1);
        let light = core.register_tenant(1);
        let flag = Arc::new(AtomicBool::new(false));

        // Tenant `heavy` completes a long stage, accruing vtime, then
        // holds the slot again.
        core.acquire(heavy, 0, &flag).unwrap();
        core.release(heavy, 10.0);
        core.acquire(heavy, 0, &flag).unwrap();

        let (tx, rx) = mpsc::channel();
        let mut joins = Vec::new();
        // heavy's next stage parks first (older ticket, older job)...
        for (tenant, job, tag) in [(heavy, 1u64, "heavy"), (light, 7, "light")] {
            let (core, flag, tx) = (Arc::clone(&core), Arc::clone(&flag), tx.clone());
            joins.push(std::thread::spawn(move || {
                core.acquire(tenant, job, &flag).unwrap();
                tx.send(tag).unwrap();
                core.release(tenant, 0.1);
            }));
            park_until(&core, joins.len());
        }
        core.release(heavy, 1.0);
        // ...but light has ~0 vtime and wins the grant.
        assert_eq!(rx.recv_timeout(Duration::from_secs(5)).unwrap(), "light");
        assert_eq!(rx.recv_timeout(Duration::from_secs(5)).unwrap(), "heavy");
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(core.preemptions(), 1);
        let stats = core.tenant_stats();
        assert_eq!(stats[heavy].bypassed, 1);
        assert_eq!(stats[light].bypassed, 0);
    }

    /// A parked waiter whose job is cancelled returns `Err` after a kick.
    #[test]
    fn cancelled_waiter_unparks_with_err() {
        let core = Arc::new(SchedCore::new(1, SchedPolicy::Fair));
        let t0 = core.register_tenant(1);
        let flag = Arc::new(AtomicBool::new(false));
        core.acquire(t0, 0, &flag).unwrap();

        let cancel = Arc::new(AtomicBool::new(false));
        let (core2, cancel2) = (Arc::clone(&core), Arc::clone(&cancel));
        let j = std::thread::spawn(move || core2.acquire(t0, 1, &cancel2));
        park_until(&core, 1);
        cancel.store(true, Relaxed);
        core.kick();
        assert_eq!(j.join().unwrap(), Err(()));
        assert_eq!(core.waiting_count(), 0);
        core.release(t0, 0.0);
    }
}
