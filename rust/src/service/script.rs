//! Arrival-trace scripts for `blaze serve --script`.
//!
//! A script is JSON with **one event object per line** (the same
//! line-oriented discipline as the bench-report files, so the parser
//! stays dependency-free). Surrounding `[` / `]` lines and trailing
//! commas are tolerated:
//!
//! ```json
//! [
//!   {"at_ms": 0,  "tenant": "ads",    "workload": "pagerank", "bytes": 262144},
//!   {"at_ms": 10, "tenant": "search", "workload": "grep", "bytes": 16384, "weight": 2},
//!   {"at_ms": 40, "tenant": "search", "workload": "grep", "verify": true}
//! ]
//! ```
//!
//! `tenant` and `workload` are required; `at_ms` defaults to 0, `bytes`
//! to 64 KiB, `weight` to 1, `seed` to the line number, `verify` to
//! false. Events replay in `at_ms` order regardless of file order.

use super::catalog::{JobRequest, WorkloadKind};

/// One arrival in a replayable schedule.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ScriptEvent {
    /// Submission time, milliseconds from replay start.
    pub at_ms: u64,
    pub tenant: String,
    pub workload: WorkloadKind,
    pub bytes: u64,
    pub weight: u64,
    pub seed: u64,
    pub verify: bool,
}

impl ScriptEvent {
    pub fn request(&self) -> JobRequest {
        JobRequest::new(self.tenant.clone(), self.workload)
            .bytes(self.bytes)
            .seed(self.seed)
            .weight(self.weight)
            .verify(self.verify)
    }
}

fn str_field(line: &str, key: &str) -> Option<String> {
    let tag = format!("\"{key}\"");
    let rest = &line[line.find(&tag)? + tag.len()..];
    let rest = rest.trim_start().strip_prefix(':')?.trim_start();
    let rest = rest.strip_prefix('"')?;
    Some(rest[..rest.find('"')?].to_string())
}

fn num_field(line: &str, key: &str) -> Option<u64> {
    let tag = format!("\"{key}\"");
    let rest = &line[line.find(&tag)? + tag.len()..];
    let rest = rest.trim_start().strip_prefix(':')?.trim_start();
    let end = rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len());
    rest[..end].parse().ok()
}

fn bool_field(line: &str, key: &str) -> Option<bool> {
    let tag = format!("\"{key}\"");
    let rest = &line[line.find(&tag)? + tag.len()..];
    let rest = rest.trim_start().strip_prefix(':')?.trim_start();
    if rest.starts_with("true") {
        Some(true)
    } else if rest.starts_with("false") {
        Some(false)
    } else {
        None
    }
}

/// Parse a script file's text into a schedule, sorted by `at_ms`.
pub fn parse_script(text: &str) -> Result<Vec<ScriptEvent>, String> {
    let mut events = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim().trim_end_matches(',');
        if line.is_empty() || line == "[" || line == "]" {
            continue;
        }
        let err = |what: &str| format!("script line {}: {what} in {line:?}", i + 1);
        let tenant = str_field(line, "tenant").ok_or_else(|| err("missing \"tenant\""))?;
        let name = str_field(line, "workload").ok_or_else(|| err("missing \"workload\""))?;
        let workload =
            WorkloadKind::parse(&name).ok_or_else(|| err("unknown \"workload\""))?;
        events.push(ScriptEvent {
            at_ms: num_field(line, "at_ms").unwrap_or(0),
            tenant,
            workload,
            bytes: num_field(line, "bytes").unwrap_or(64 << 10),
            weight: num_field(line, "weight").unwrap_or(1).max(1),
            seed: num_field(line, "seed").unwrap_or(i as u64 + 1),
            verify: bool_field(line, "verify").unwrap_or(false),
        });
    }
    events.sort_by_key(|e| e.at_ms);
    Ok(events)
}

/// Parse a comma-separated workload mix (`"grep,pagerank"`).
pub fn parse_mix(s: &str) -> Result<Vec<WorkloadKind>, String> {
    let mut mix = Vec::new();
    for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        mix.push(WorkloadKind::parse(part).ok_or_else(|| format!("unknown workload '{part}'"))?);
    }
    if mix.is_empty() {
        return Err("empty workload mix".into());
    }
    Ok(mix)
}

/// Synthesize an open-loop schedule: `jobs` arrivals `gap_ms` apart,
/// tenants round-robin, workloads cycling through `mix`.
pub fn synthetic(
    tenants: usize,
    jobs: usize,
    mix: &[WorkloadKind],
    gap_ms: u64,
    bytes: u64,
    verify: bool,
) -> Vec<ScriptEvent> {
    assert!(!mix.is_empty(), "synthetic schedule needs a non-empty mix");
    (0..jobs)
        .map(|i| ScriptEvent {
            at_ms: i as u64 * gap_ms,
            tenant: format!("tenant-{}", i % tenants.max(1)),
            workload: mix[i % mix.len()],
            bytes,
            weight: 1,
            seed: i as u64 + 1,
            verify,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_array_with_defaults_and_sorts() {
        let text = r#"[
            {"at_ms": 20, "tenant": "b", "workload": "grep"},
            {"tenant":"a","workload":"pagerank","bytes":1024,"weight":3,"seed":9,"verify":true},
        ]"#;
        let events = parse_script(text).unwrap();
        assert_eq!(events.len(), 2);
        // Sorted by at_ms: the defaulted (0) event first.
        assert_eq!(events[0].tenant, "a");
        assert_eq!(events[0].workload, WorkloadKind::PageRank);
        assert_eq!((events[0].bytes, events[0].weight, events[0].seed), (1024, 3, 9));
        assert!(events[0].verify);
        assert_eq!(events[1].at_ms, 20);
        assert_eq!(events[1].bytes, 64 << 10);
        assert!(!events[1].verify);
    }

    #[test]
    fn rejects_unknown_workload_and_missing_tenant() {
        assert!(parse_script(r#"{"tenant": "a", "workload": "mystery"}"#).is_err());
        assert!(parse_script(r#"{"workload": "grep"}"#).is_err());
    }

    #[test]
    fn synthetic_cycles_tenants_and_mix() {
        let mix = parse_mix("grep, pagerank").unwrap();
        let events = synthetic(2, 4, &mix, 10, 4096, false);
        assert_eq!(events.len(), 4);
        assert_eq!(events[3].at_ms, 30);
        assert_eq!(events[2].tenant, "tenant-0");
        assert_eq!(events[3].workload, WorkloadKind::PageRank);
        assert_eq!(events[1].workload, WorkloadKind::PageRank);
    }
}
