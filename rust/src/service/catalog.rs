//! Workload catalog: the named job types a tenant can submit.
//!
//! A [`JobRequest`] describes *what* to run (workload kind, synthesized
//! input size, seed) without touching *how* (engine, threads, store,
//! scheduling) — the service owns the how and hands the catalog a fully
//! provisioned [`JobSpec`]. Every kind synthesizes its own input from
//! `(bytes, seed)` so a request is reproducible from its fields alone,
//! and every kind can self-verify against the repo's serial oracles
//! (`verify: true` turns an output divergence into a job failure).
//!
//! The kinds span the service's scheduling envelope: [`Grep`] is the
//! short zero-shuffle probe, [`WordCount`] the paper's one-exchange
//! workload, [`Join`] the two-relation shuffle-heavy case, and
//! [`PageRank`] the long multi-round iterative job whose rounds the fair
//! scheduler interleaves with everything else.

use std::sync::Arc;

use crate::cluster::FailurePlan;
use crate::corpus::{Corpus, CorpusSpec, Tokenizer};
use crate::mapreduce::{
    run_iterative, run_iterative_serial, run_serial, run_serial_inputs, IterativeSpec, JobInputs,
    JobSpec, MapReduceError,
};
use crate::workloads::{Grep, Join, JoinSides, PageRank, WordCount};

/// Workloads the service can build from a byte budget and a seed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadKind {
    /// Zero-shuffle scan — the short job the fairness bench protects.
    Grep,
    /// The paper's workload: one map + one exchange.
    WordCount,
    /// Two-relation equi-join (relations seeded `seed` / `seed + 1`).
    Join,
    /// Multi-round iterative job over the corpus-as-graph.
    PageRank,
}

impl WorkloadKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "grep" => Some(Self::Grep),
            "wordcount" | "wc" => Some(Self::WordCount),
            "join" => Some(Self::Join),
            "pagerank" | "pr" => Some(Self::PageRank),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Grep => "grep",
            Self::WordCount => "wordcount",
            Self::Join => "join",
            Self::PageRank => "pagerank",
        }
    }

    /// Single-stage jobs the latency benches bucket as "short".
    pub fn is_short(self) -> bool {
        matches!(self, Self::Grep)
    }
}

/// One tenant's job: what to run and over how much synthesized input.
#[derive(Debug)]
pub struct JobRequest {
    pub tenant: String,
    pub kind: WorkloadKind,
    /// Target size of the synthesized input corpus.
    pub bytes: u64,
    pub seed: u64,
    /// Fair-share weight of the tenant (fixed at first submission).
    pub weight: u64,
    /// Round cap for iterative kinds (ignored by the others).
    pub rounds: usize,
    /// Check the output against the serial oracle inside the job; a
    /// divergence fails the job.
    pub verify: bool,
    /// Injected failures, delivered to the engine's retry machinery —
    /// used by the isolation tests to crash one tenant's job on purpose.
    pub failures: Option<FailurePlan>,
    /// Override the spec's job-level rerun budget (e.g. `Some(0)` turns
    /// any injected failure into a hard job failure).
    pub max_job_reruns: Option<usize>,
}

impl JobRequest {
    pub fn new(tenant: impl Into<String>, kind: WorkloadKind) -> Self {
        Self {
            tenant: tenant.into(),
            kind,
            bytes: 64 << 10,
            seed: 7,
            weight: 1,
            rounds: 4,
            verify: false,
            failures: None,
            max_job_reruns: None,
        }
    }

    pub fn bytes(mut self, bytes: u64) -> Self {
        self.bytes = bytes;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn weight(mut self, weight: u64) -> Self {
        self.weight = weight.max(1);
        self
    }

    pub fn rounds(mut self, rounds: usize) -> Self {
        self.rounds = rounds;
        self
    }

    pub fn verify(mut self, on: bool) -> Self {
        self.verify = on;
        self
    }

    pub fn failures(mut self, plan: FailurePlan) -> Self {
        self.failures = Some(plan);
        self
    }

    pub fn max_job_reruns(mut self, n: usize) -> Self {
        self.max_job_reruns = Some(n);
        self
    }
}

/// Canonical result of a job: a sorted line rendering of the output,
/// comparable across engines, runs, and thread counts.
#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub lines: Vec<String>,
    pub records: u64,
    /// Wall inside the engine (excludes queue wait).
    pub exec_secs: f64,
    /// True when the in-job oracle check ran (and passed — a mismatch
    /// fails the job instead).
    pub verified: bool,
}

fn corpus(bytes: u64, seed: u64) -> Corpus {
    let mut spec = CorpusSpec::with_bytes(bytes.max(1 << 10));
    spec.seed = seed;
    Corpus::generate(&spec)
}

fn mismatch(kind: WorkloadKind) -> MapReduceError {
    MapReduceError(format!(
        "verification failed: {} output diverges from the serial oracle",
        kind.name()
    ))
}

fn grep_lines(out: &[(u64, String)]) -> Vec<String> {
    let mut v: Vec<String> = out.iter().map(|(doc, line)| format!("{doc}\t{line}")).collect();
    v.sort_unstable();
    v
}

fn count_lines(out: &std::collections::HashMap<String, u64>) -> Vec<String> {
    let mut v: Vec<String> = out.iter().map(|(k, n)| format!("{k}\t{n}")).collect();
    v.sort_unstable();
    v
}

fn join_lines(out: &std::collections::HashMap<String, JoinSides>) -> Vec<String> {
    let mut v: Vec<String> = out
        .iter()
        .map(|(k, s)| format!("{k}\t{}|{}", s.left.join(","), s.right.join(",")))
        .collect();
    v.sort_unstable();
    v
}

/// Run `req` on a service-provisioned spec (gate, shared store, and
/// tenant key bases already attached).
pub(crate) fn execute(req: JobRequest, mut spec: JobSpec) -> Result<JobOutcome, MapReduceError> {
    if let Some(n) = req.max_job_reruns {
        spec.max_job_reruns = n;
    }
    if let Some(plan) = req.failures {
        spec = spec.failures(plan);
    }
    match req.kind {
        WorkloadKind::Grep => {
            let c = corpus(req.bytes, req.seed);
            let w = Arc::new(Grep::new("the"));
            let r = spec.run(&w, &c)?;
            let lines = grep_lines(&r.output);
            let verified = req.verify;
            if verified && lines != grep_lines(&run_serial(w.as_ref(), &c)) {
                return Err(mismatch(req.kind));
            }
            Ok(JobOutcome { lines, records: r.records, exec_secs: r.wall_secs, verified })
        }
        WorkloadKind::WordCount => {
            let c = corpus(req.bytes, req.seed);
            let w = Arc::new(WordCount::new(Tokenizer::Spaces));
            let r = spec.run(&w, &c)?;
            let lines = count_lines(&r.output);
            let verified = req.verify;
            if verified && lines != count_lines(&run_serial(w.as_ref(), &c)) {
                return Err(mismatch(req.kind));
            }
            Ok(JobOutcome { lines, records: r.records, exec_secs: r.wall_secs, verified })
        }
        WorkloadKind::Join => {
            let left = corpus(req.bytes, req.seed);
            let right = corpus(req.bytes, req.seed.wrapping_add(1));
            let w = Arc::new(Join::new());
            let inputs = JobInputs::new().relation("left", &left).relation("right", &right);
            let r = spec.run_inputs(&w, &inputs)?;
            let lines = join_lines(&r.output);
            let verified = req.verify;
            if verified && lines != join_lines(&run_serial_inputs(w.as_ref(), &inputs)) {
                return Err(mismatch(req.kind));
            }
            Ok(JobOutcome { lines, records: r.records, exec_secs: r.wall_secs, verified })
        }
        WorkloadKind::PageRank => {
            let c = corpus(req.bytes, req.seed);
            let w = PageRank::new();
            let inputs = JobInputs::new().relation("edges", &c);
            let it = IterativeSpec::new(req.rounds.max(1));
            let r = run_iterative(&spec, &it, &w, &inputs)?;
            let verified = req.verify;
            if verified {
                let oracle = run_iterative_serial(&it, &w, &inputs);
                if r.state != oracle.state || r.iterations != oracle.iterations {
                    return Err(mismatch(req.kind));
                }
            }
            let mut lines = r.state.clone();
            lines.sort_unstable();
            let records = r.iters.iter().map(|round| round.records).sum();
            Ok(JobOutcome { lines, records, exec_secs: r.wall_secs, verified })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engines::Engine;

    #[test]
    fn kind_parse_round_trips() {
        for kind in
            [WorkloadKind::Grep, WorkloadKind::WordCount, WorkloadKind::Join, WorkloadKind::PageRank]
        {
            assert_eq!(WorkloadKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(WorkloadKind::parse("kmeanz"), None);
    }

    /// Every kind runs standalone on a bare spec and passes its own
    /// oracle check.
    #[test]
    fn every_kind_self_verifies() {
        for kind in
            [WorkloadKind::Grep, WorkloadKind::WordCount, WorkloadKind::Join, WorkloadKind::PageRank]
        {
            let req = JobRequest::new("t", kind).bytes(8 << 10).rounds(2).verify(true);
            let spec = JobSpec::new(Engine::BlazeTcm).threads(2);
            let out = execute(req, spec).expect("job runs");
            assert!(out.verified);
            assert!(!out.lines.is_empty());
        }
    }
}
