//! The planner layer: compile a job into an explicit [`StageGraph`]
//! *before* any engine runs it.
//!
//! Both real systems execute stage graphs cut at shuffle boundaries —
//! Spark's DAG scheduler turns an RDD lineage into stages, and the staged
//! communication design of DataMPI does the moral equivalent on the MPI
//! side. Until this module existed, our engines re-derived the same
//! per-job decisions (run the exchange or elide it? cache a relation's
//! parsed split, under which key?) independently inside every entry
//! point. Now those decisions are made exactly once, at **plan time**:
//!
//! * [`JobSpec::plan`] / [`JobSpec::plan_cached`] compile a single
//!   [`Workload`] into a one-stage graph — the exchange is
//!   [`Exchange::Elided`] when the workload declares its keys globally
//!   unique ([`Workload::needs_shuffle`] == false), [`Exchange::Forced`]
//!   when [`JobSpec::force_shuffle`] overrides that, and each input
//!   relation gets a [`CachePoint`] when (and only when) a live partition
//!   cache is attached;
//! * [`JobSpec::plan_chained`] compiles a [`ChainedWorkload`] — a
//!   multi-stage pipeline in which stage N's reduced output, rendered to
//!   canonical lines, becomes stage N+1's tagged input relation — into an
//!   N-stage graph whose [`ShuffleBoundary`] edges separate the stages;
//! * the engines execute stages through their **single** plan-execution
//!   path ([`JobEngine::run_plan`](super::JobEngine::run_plan) →
//!   `engines::blaze::run_plan` / `engines::spark::run_plan`); the legacy
//!   `run_workload{,_str,_cached}` names survive only as thin wrappers
//!   that compile or receive a plan;
//! * [`run_chained`] drives a multi-stage pipeline stage by stage over
//!   one compiled graph; [`run_chained_serial`] is its single-threaded
//!   oracle (every stage through
//!   [`run_serial_inputs`](super::run_serial_inputs)), which engines must
//!   match bit-identically;
//! * `blaze plan --workload <name>` prints [`StageGraph::render`] without
//!   executing — the ablation/debugging view of what was decided.
//!
//! The iterative driver ([`super::run_iterative`]) is a plan-per-round
//! loop over the same machinery: each round's step job compiles a fresh
//! one-stage graph (the fed-back state relation's generation bumps, so
//! its cache point changes) and executes it through the engines' plan
//! path.

use std::sync::Arc;

use crate::cache::CacheStats;
use crate::engines::Engine;
use crate::runtime::executor::ExecMetrics;
use crate::storage::StorageStats;
use crate::trace::{self, MetricSet, SpanCat};
use crate::util::ser::DictStats;
use crate::util::stats::Stopwatch;

use super::{
    engine_for, run_serial_inputs, CacheableWorkload, JobInputs, JobSpec, MapReduceError,
    Workload,
};

/// How a stage boundary's exchange was planned. The decision is made
/// here, at plan time — engines only read it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Exchange {
    /// All-to-all exchange: keys must co-locate before the reduce.
    Shuffle,
    /// Elided at plan time: the workload declared every key globally
    /// unique, so per-producer shards are already disjoint and nothing
    /// moves (zero bytes on the wire).
    Elided,
    /// The workload opted out but [`JobSpec::force_shuffle`] overrode it —
    /// the ablation that measures what the elision saves.
    Forced,
}

impl Exchange {
    /// Does the engine run the exchange for this stage?
    pub fn runs_exchange(self) -> bool {
        !matches!(self, Exchange::Elided)
    }

    fn describe(self) -> &'static str {
        match self {
            Exchange::Shuffle => "all-to-all shuffle",
            Exchange::Elided => "elided (keys globally unique)",
            Exchange::Forced => "forced (--force-shuffle ablation)",
        }
    }
}

/// The edge between two stages of a [`StageGraph`]: stage `from`'s
/// reduced output crosses a shuffle boundary (its rendered lines become
/// stage `to`'s tagged input relation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShuffleBoundary {
    pub from: usize,
    pub to: usize,
}

/// Where a stage's input relation comes from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InputSource {
    /// The job's external input relation at this index.
    External(usize),
    /// The rendered reduced output of an earlier stage.
    StageOutput(usize),
}

/// Plan-time decision to cache one input relation's parsed split in the
/// attached [`PartitionCache`](crate::cache::PartitionCache), and under
/// which identity. Absent when no cache is attached or its budget is 0 —
/// so the recompute ablation never even consults the store.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CachePoint {
    /// Cache namespace (the relation index for job-layer plans).
    pub namespace: u64,
    /// Content generation of the relation (bumped when its lines change,
    /// e.g. the iterative driver's fed-back state relation every round).
    pub generation: u64,
}

/// One planned input relation of a stage.
#[derive(Clone, Debug)]
pub struct StageInput {
    pub name: String,
    pub source: InputSource,
    pub cache: Option<CachePoint>,
}

impl StageInput {
    fn describe(&self) -> String {
        let src = match self.source {
            InputSource::External(i) => format!("external #{i}"),
            InputSource::StageOutput(s) => format!("output of stage {s}"),
        };
        match &self.cache {
            Some(cp) => format!(
                "{} ({src}, cached ns={} gen={})",
                self.name, cp.namespace, cp.generation
            ),
            None => format!("{} ({src})", self.name),
        }
    }
}

/// One stage of the compiled graph: a map → (exchange) → reduce pass with
/// every per-stage decision already made.
#[derive(Clone, Debug)]
pub struct StagePlan {
    pub id: usize,
    /// The stage workload's name (report label).
    pub label: String,
    pub exchange: Exchange,
    pub inputs: Vec<StageInput>,
    /// Bounded-memory exchange, decided at plan time from
    /// [`JobSpec::spill_threshold`]: reduce shards beyond this many
    /// in-flight bytes sort-and-spill runs to the disk tier and merge
    /// externally. `None` = unbounded in-memory exchange.
    pub spill_threshold: Option<u64>,
    /// Disk-tier block compression, from [`JobSpec::compress`] — whether
    /// payloads this stage spills/persists are LZ4-block-compressed.
    pub compress: bool,
    /// Dictionary key encoding on the stage's spill runs and exchange
    /// payloads, from [`JobSpec::dict_keys`].
    pub dict_keys: bool,
}

impl StagePlan {
    /// A free-standing one-stage plan for the engines' direct entry
    /// points and tests: `nrels` external inputs, the exchange decided
    /// from the workload's declaration, no force-shuffle override, no
    /// cache points, no spill.
    pub fn single(label: &str, needs_shuffle: bool, nrels: usize) -> StagePlan {
        StagePlan {
            id: 0,
            label: label.to_string(),
            exchange: plan_exchange(needs_shuffle, false),
            inputs: (0..nrels)
                .map(|i| StageInput {
                    name: format!("input{i}"),
                    source: InputSource::External(i),
                    cache: None,
                })
                .collect(),
            spill_threshold: None,
            compress: true,
            dict_keys: true,
        }
    }

    /// Does this stage run its exchange?
    pub fn runs_exchange(&self) -> bool {
        self.exchange.runs_exchange()
    }

    /// The planned cache point of input relation `rel`, if any.
    pub fn cache_point(&self, rel: usize) -> Option<&CachePoint> {
        self.inputs.get(rel).and_then(|i| i.cache.as_ref())
    }
}

/// The compiled execution plan of one job: stages separated by
/// [`ShuffleBoundary`] edges. Single-pass jobs compile to one stage;
/// [`ChainedWorkload`]s to one stage per pipeline step.
#[derive(Clone, Debug)]
pub struct StageGraph {
    /// The job's (driver-level) workload name.
    pub job: String,
    pub engine: Engine,
    pub stages: Vec<StagePlan>,
}

impl StageGraph {
    pub fn stage(&self, id: usize) -> &StagePlan {
        &self.stages[id]
    }

    pub fn num_stages(&self) -> usize {
        self.stages.len()
    }

    /// How many stages actually run their exchange.
    pub fn num_exchanges(&self) -> usize {
        self.stages.iter().filter(|s| s.runs_exchange()).count()
    }

    /// The inter-stage edges (each is a shuffle boundary crossed by a
    /// rendered bridge relation).
    pub fn boundaries(&self) -> Vec<ShuffleBoundary> {
        (1..self.stages.len())
            .map(|to| ShuffleBoundary { from: to - 1, to })
            .collect()
    }

    /// Human-readable plan — what `blaze plan --workload <name>` prints.
    pub fn render(&self) -> String {
        let mut out = format!(
            "plan '{}' on {} — {} stage(s), {} exchange(s)\n",
            self.job,
            self.engine.label(),
            self.num_stages(),
            self.num_exchanges(),
        );
        for s in &self.stages {
            out.push_str(&format!("  stage {} '{}'\n", s.id, s.label));
            for i in &s.inputs {
                out.push_str(&format!("    input:    {}\n", i.describe()));
            }
            out.push_str(&format!("    exchange: {}\n", s.exchange.describe()));
            if let Some(bytes) = s.spill_threshold {
                out.push_str(&format!(
                    "    spill:    external merge beyond {} in-flight\n",
                    crate::util::stats::fmt_bytes(bytes)
                ));
            }
            out.push_str(&format!(
                "    datapath: compress={} dict-keys={}\n",
                if s.compress { "lz4" } else { "off" },
                if s.dict_keys { "on" } else { "off" },
            ));
        }
        out
    }
}

/// Decide a stage's exchange from the workload's declaration and the
/// force-shuffle override — the one place this logic lives now.
fn plan_exchange(needs_shuffle: bool, force: bool) -> Exchange {
    if needs_shuffle {
        Exchange::Shuffle
    } else if force {
        Exchange::Forced
    } else {
        Exchange::Elided
    }
}

fn external_inputs(inputs: &JobInputs) -> Vec<StageInput> {
    inputs
        .relations
        .iter()
        .enumerate()
        .map(|(i, r)| StageInput {
            name: r.name.clone(),
            source: InputSource::External(i),
            cache: None,
        })
        .collect()
}

impl JobSpec {
    /// Compile `w` over `inputs` into its one-stage [`StageGraph`] (no
    /// cache points — see [`plan_cached`](Self::plan_cached)).
    pub fn plan<W: Workload>(&self, w: &W, inputs: &JobInputs) -> StageGraph {
        StageGraph {
            job: w.name().to_string(),
            engine: self.engine,
            stages: vec![StagePlan {
                id: 0,
                label: w.name().to_string(),
                exchange: plan_exchange(w.needs_shuffle(), self.force_shuffle),
                inputs: external_inputs(inputs),
                spill_threshold: self.spill_threshold,
                compress: self.compress,
                dict_keys: self.dict_keys,
            }],
        }
    }

    /// Compile a [`CacheableWorkload`]'s one-stage graph, deciding each
    /// relation's [`CachePoint`] at plan time: points are planned only
    /// when a partition cache is attached *and* its budget admits
    /// anything at all — with `CacheBudget::Bytes(0)` the plan carries no
    /// points and the engines never touch the store (the recompute
    /// ablation times recomputation, nothing else).
    pub fn plan_cached<W: CacheableWorkload>(&self, w: &W, inputs: &JobInputs) -> StageGraph {
        let cache_on = self.cache.as_ref().is_some_and(|c| !c.is_disabled());
        let mut graph = self.plan(w, inputs);
        if cache_on {
            for (rel, input) in graph.stages[0].inputs.iter_mut().enumerate() {
                // The spec's bases offset the whole key scheme: the job
                // service keys namespaces by tenant and generations by
                // job, so one shared store never cross-serves entries.
                // Both are 0 outside the service.
                input.cache = Some(CachePoint {
                    namespace: self.namespace_base + rel as u64,
                    generation: self.generation_base
                        + self.relation_gens.get(rel).copied().unwrap_or(0),
                });
            }
        }
        graph
    }

    /// Compile a [`ChainedWorkload`] into its multi-stage [`StageGraph`]:
    /// stage 0 maps the chain's external relations; every later stage
    /// maps exactly one relation — the previous stage's reduced output,
    /// rendered to lines and tagged `stage<N>.out`.
    pub fn plan_chained<C: ChainedWorkload + ?Sized>(
        &self,
        c: &C,
        inputs: &JobInputs,
    ) -> StageGraph {
        let stages = c
            .stages()
            .iter()
            .enumerate()
            .map(|(i, st)| {
                let shape = st.shape();
                let ins = if i == 0 {
                    external_inputs(inputs)
                } else {
                    vec![StageInput {
                        name: format!("stage{}.out", i - 1),
                        source: InputSource::StageOutput(i - 1),
                        cache: None,
                    }]
                };
                StagePlan {
                    id: i,
                    label: shape.name.to_string(),
                    exchange: plan_exchange(shape.needs_shuffle, self.force_shuffle),
                    inputs: ins,
                    spill_threshold: self.spill_threshold,
                    compress: self.compress,
                    dict_keys: self.dict_keys,
                }
            })
            .collect();
        StageGraph { job: c.name().to_string(), engine: self.engine, stages }
    }
}

/// Per-stage metrics of one run — a [`JobReport`](super::JobReport) holds
/// one row per executed stage, so multi-stage runs stay attributable.
#[derive(Clone, Debug)]
pub struct StageStats {
    pub stage: usize,
    /// The stage workload's name.
    pub label: String,
    /// Input records (relation lines) the stage mapped over.
    pub records_in: u64,
    /// Reduced rows the stage produced (after per-shard finalize).
    pub records_out: u64,
    pub shuffle_bytes: u64,
    /// Dictionary key-encoding activity attributed to this stage (spill
    /// runs + exchange wire). All zeros with `--dict-keys off`, for
    /// integer-keyed workloads, and on paths that never serialize.
    pub dict: DictStats,
    pub wall_secs: f64,
}

/// Statically known shape of one chain stage — what the planner needs
/// before anything executes.
#[derive(Clone, Copy, Debug)]
pub struct StageShape {
    pub name: &'static str,
    pub needs_shuffle: bool,
    pub num_relations: usize,
}

/// Result of one executed chain stage.
#[derive(Debug)]
pub struct StageOutcome {
    /// The stage's reduced output, rendered to canonical lines (the next
    /// stage's bridge relation, or the chain's final output).
    pub lines: Vec<String>,
    /// Reduced rows before rendering.
    pub rows: u64,
    /// Map-phase emissions.
    pub records: u64,
    pub shuffle_bytes: u64,
    /// The stage's storage-hierarchy activity (exchange spill etc).
    pub storage: StorageStats,
    /// Engine-side wall of the stage (map + exchange + per-shard
    /// finalize). Driver-side finalize/render time is *not* in here — it
    /// reports separately as [`Self::render_secs`], so chained stage
    /// walls plus bridge time sum to the job wall instead of silently
    /// losing (or double-counting) the rendering between stages.
    pub wall_secs: f64,
    /// Driver-side finalize + bridge-line rendering after the engine
    /// returned.
    pub render_secs: f64,
    pub detail: MetricSet,
}

/// A type-erased stage of a chained pipeline. Implementations run one
/// typed [`Workload`] through an engine's plan path and render its
/// reduced output to bridge lines; [`TypedStage`] is the adapter that
/// does this for any workload + renderer pair.
pub trait ChainStage: Send + Sync {
    fn shape(&self) -> StageShape;

    /// Execute stage `stage_id` of `graph` on `spec`'s engine.
    fn execute(
        &self,
        spec: &JobSpec,
        graph: &StageGraph,
        stage_id: usize,
        inputs: &JobInputs,
    ) -> Result<StageOutcome, MapReduceError>;

    /// Execute serially (the oracle path) and return the bridge lines.
    fn execute_serial(&self, inputs: &JobInputs) -> Vec<String>;
}

/// Adapter wrapping a typed [`Workload`] plus a canonical line renderer
/// into a [`ChainStage`]. The renderer must be deterministic (sort by
/// key) — its lines are both the next stage's input relation and the
/// bit-identity surface the parity tests compare across engines.
pub struct TypedStage<W: Workload> {
    w: Arc<W>,
    render: Box<dyn Fn(W::Output) -> Vec<String> + Send + Sync>,
}

impl<W: Workload> TypedStage<W> {
    pub fn boxed(
        w: Arc<W>,
        render: impl Fn(W::Output) -> Vec<String> + Send + Sync + 'static,
    ) -> Box<dyn ChainStage> {
        Box::new(TypedStage { w, render: Box::new(render) })
    }
}

impl<W: Workload> ChainStage for TypedStage<W> {
    fn shape(&self) -> StageShape {
        StageShape {
            name: self.w.name(),
            needs_shuffle: self.w.needs_shuffle(),
            num_relations: self.w.num_relations(),
        }
    }

    fn execute(
        &self,
        spec: &JobSpec,
        graph: &StageGraph,
        stage_id: usize,
        inputs: &JobInputs,
    ) -> Result<StageOutcome, MapReduceError> {
        if inputs.len() != self.w.num_relations() {
            return Err(MapReduceError(format!(
                "stage '{}' expects {} input relation(s), got {}",
                self.w.name(),
                self.w.num_relations(),
                inputs.len()
            )));
        }
        let run = engine_for::<W>(spec.engine).run_plan(spec, graph, stage_id, &self.w, inputs)?;
        let rows = run.entries.len() as u64;
        // Driver-side finalize + render is real wall time between stages
        // — time it and span it so it attributes to the bridge, not to
        // any stage's engine wall.
        let _bridge = trace::span_arg(SpanCat::Bridge, "render", stage_id as u64);
        let sw = Stopwatch::start();
        let out = self.w.finalize(run.entries);
        let lines = (self.render)(out);
        Ok(StageOutcome {
            lines,
            rows,
            records: run.records,
            shuffle_bytes: run.shuffle_bytes,
            storage: run.storage,
            wall_secs: run.wall_secs,
            render_secs: sw.elapsed_secs(),
            detail: run.detail,
        })
    }

    fn execute_serial(&self, inputs: &JobInputs) -> Vec<String> {
        (self.render)(run_serial_inputs(self.w.as_ref(), inputs))
    }
}

/// A multi-stage pipeline: stage N's reduced output, rendered to
/// canonical lines, is stage N+1's tagged input relation. Compile it with
/// [`JobSpec::plan_chained`], run it with [`run_chained`], oracle it with
/// [`run_chained_serial`]. See the authoring guide in
/// [`crate::workloads`] (`Sessionize` is the worked example).
pub trait ChainedWorkload: Send + Sync {
    /// Stable name (CLI token, report label).
    fn name(&self) -> &'static str;

    /// External input relations stage 0 consumes.
    fn num_relations(&self) -> usize {
        1
    }

    /// The pipeline's stages, in order. Stage 0's workload must declare
    /// [`num_relations`](Self::num_relations) inputs; every later stage's
    /// workload must declare exactly one (the bridge relation).
    fn stages(&self) -> Vec<Box<dyn ChainStage>>;
}

/// Outcome of one chained run: the final stage's rendered lines plus
/// per-stage metrics.
#[derive(Debug)]
pub struct ChainReport {
    pub engine: Engine,
    pub workload: &'static str,
    /// The last stage's reduced output, rendered to canonical lines.
    pub lines: Vec<String>,
    pub wall_secs: f64,
    /// Total map-phase emissions across stages.
    pub records: u64,
    /// Total shuffle bytes across stages.
    pub shuffle_bytes: u64,
    /// One row per executed stage.
    pub stages: Vec<StageStats>,
    /// Per-stage engine details folded under `stage{i}.` prefixes, plus
    /// the chain-level `bridge` seconds.
    pub detail: MetricSet,
    /// Driver-side time between stages: finalize + bridge-line rendering
    /// + next-stage input construction. Stage engine walls plus this sum
    /// to [`Self::wall_secs`] (within scheduling noise) — it used to
    /// vanish into the job wall unattributed.
    pub bridge_secs: f64,
    /// Worker-pool activity across all stages (see
    /// [`JobReport::exec`](super::JobReport::exec)).
    pub exec: ExecMetrics,
    /// Cache activity across stages (all zeros unless a cache was
    /// attached).
    pub cache: CacheStats,
    /// Storage-hierarchy activity summed across stages (exchange spill,
    /// demotions, disk traffic).
    pub storage: StorageStats,
}

impl ChainReport {
    pub fn summary(&self) -> String {
        use crate::util::stats::{fmt_bytes, fmt_rate};
        format!(
            "{:<12} {:<16} {:>12} emissions in {:>8.3}s = {:>14}   {} stage(s), shuffle={}",
            self.workload,
            self.engine.label(),
            self.records,
            self.wall_secs,
            fmt_rate(self.records as f64 / self.wall_secs.max(1e-12), "recs"),
            self.stages.len(),
            fmt_bytes(self.shuffle_bytes),
        )
    }
}

fn check_chain_shapes<C: ChainedWorkload + ?Sized>(
    c: &C,
    stages: &[Box<dyn ChainStage>],
    inputs: &JobInputs,
) -> Result<(), MapReduceError> {
    if stages.is_empty() {
        return Err(MapReduceError(format!("chained workload '{}' has no stages", c.name())));
    }
    if inputs.len() != c.num_relations() {
        return Err(MapReduceError(format!(
            "chained workload '{}' expects {} input relation(s), got {}",
            c.name(),
            c.num_relations(),
            inputs.len()
        )));
    }
    for (i, st) in stages.iter().enumerate() {
        let shape = st.shape();
        let want = if i == 0 { c.num_relations() } else { 1 };
        if shape.num_relations != want {
            return Err(MapReduceError(format!(
                "chained workload '{}': stage {i} '{}' expects {} relation(s), \
                 but the chain supplies {want}",
                c.name(),
                shape.name,
                shape.num_relations
            )));
        }
    }
    Ok(())
}

/// The bridge relation between stage `from` and the next stage.
fn bridge_inputs(from: usize, lines: &[String]) -> JobInputs {
    JobInputs::new().relation_lines(&format!("stage{from}.out"), Arc::new(lines.to_vec()))
}

/// Execute a [`ChainedWorkload`] on `spec`'s engine: compile the graph
/// once, then run stage by stage, rendering each stage's reduced output
/// into the next stage's tagged input relation.
pub fn run_chained<C: ChainedWorkload + ?Sized>(
    spec: &JobSpec,
    c: &C,
    inputs: &JobInputs,
) -> Result<ChainReport, MapReduceError> {
    let stages = c.stages();
    check_chain_shapes(c, &stages, inputs)?;
    let graph = spec.plan_chained(c, inputs);
    let before = spec.cache.as_ref().map(|cache| cache.stats());
    let (exec, exec_before) = spec.exec_snapshot();

    let sw = Stopwatch::start();
    let mut current = inputs.clone();
    let mut lines: Vec<String> = Vec::new();
    let mut stats = Vec::new();
    let mut detail = MetricSet::new();
    let (mut records, mut shuffle_bytes) = (0u64, 0u64);
    let mut bridge_secs = 0.0;
    let mut storage = StorageStats::default();
    for (i, st) in stages.iter().enumerate() {
        let records_in: u64 = current.relations.iter().map(|r| r.lines.len() as u64).sum();
        // Each chain stage re-acquires the spec's scheduling gate (when
        // one is attached), so concurrent jobs interleave at stage
        // granularity instead of holding a slot for the whole pipeline.
        let outcome = spec.gated(i as u64, || st.execute(spec, &graph, i, &current))?;
        records += outcome.records;
        shuffle_bytes += outcome.shuffle_bytes;
        bridge_secs += outcome.render_secs;
        storage = storage.merged(&outcome.storage);
        stats.push(StageStats {
            stage: i,
            label: st.shape().name.to_string(),
            records_in,
            records_out: outcome.rows,
            shuffle_bytes: outcome.shuffle_bytes,
            dict: outcome.storage.dict_stats(),
            wall_secs: outcome.wall_secs,
        });
        detail.merge_prefixed(&format!("stage{i}"), &outcome.detail);
        lines = outcome.lines;
        if i + 1 < stages.len() {
            let _span = trace::span_arg(SpanCat::Bridge, "inputs", i as u64);
            let bsw = Stopwatch::start();
            current = bridge_inputs(i, &lines);
            bridge_secs += bsw.elapsed_secs();
        }
    }
    detail.set_secs("bridge", bridge_secs);
    let cache = match (before, &spec.cache) {
        (Some(before), Some(cache)) => cache.stats().delta_since(&before),
        _ => CacheStats::default(),
    };
    Ok(ChainReport {
        engine: spec.engine,
        workload: c.name(),
        lines,
        wall_secs: sw.elapsed_secs(),
        records,
        shuffle_bytes,
        stages: stats,
        detail,
        bridge_secs,
        exec: exec.metrics().delta_since(&exec_before),
        cache,
        storage,
    })
}

/// The single-threaded oracle for [`run_chained`]: every stage through
/// [`run_serial_inputs`], the same rendered bridge between stages.
/// Engines must reproduce its final lines bit-identically.
pub fn run_chained_serial<C: ChainedWorkload + ?Sized>(c: &C, inputs: &JobInputs) -> Vec<String> {
    let stages = c.stages();
    assert_eq!(
        inputs.len(),
        c.num_relations(),
        "chained workload '{}' expects {} input relation(s)",
        c.name(),
        c.num_relations()
    );
    let mut current = inputs.clone();
    let mut lines = Vec::new();
    for (i, st) in stages.iter().enumerate() {
        lines = st.execute_serial(&current);
        if i + 1 < stages.len() {
            current = bridge_inputs(i, &lines);
        }
    }
    lines
}
