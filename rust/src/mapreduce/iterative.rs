//! The iterative job driver — multi-round MapReduce with feedback.
//!
//! Spark's pitch is that iterative algorithms (PageRank, k-means, logistic
//! regression) are where in-memory reuse pays: the same input is re-read
//! every round, so caching it across rounds removes the dominant cost.
//! This module supplies the driver loop that makes those workloads
//! expressible on *both* engines:
//!
//! * an [`IterativeWorkload`] owns the algorithm: it derives the initial
//!   **state** (a line-rendered relation) from the static inputs, builds a
//!   per-round step job (a [`CacheableWorkload`]) with the current state
//!   broadcast into it, and folds each round's reduced output into the
//!   next state plus a scalar convergence **delta**;
//! * [`run_iterative`] executes the loop on an engine as a
//!   **plan-per-round** driver: every round runs the step job over
//!   `static relations + [state]` (the state appended as the last tagged
//!   relation), compiling a fresh one-stage
//!   [`StageGraph`](super::StageGraph) whose cache points carry the
//!   round's generations (the state relation's generation bumps each
//!   round) and executing it through the engines' single plan path; one
//!   [`PartitionCache`] is shared across rounds so parsed splits of the
//!   unchanged relations are served from memory;
//! * [`run_iterative_serial`] is the same loop over
//!   [`run_serial_inputs`](crate::mapreduce::run_serial_inputs) — the
//!   fixed-point serial oracle every engine must match **bit-identically**
//!   (workloads keep their arithmetic in integer fixed-point precisely so
//!   combine order cannot perturb results).
//!
//! Determinism contract for workload authors: `advance` must render the
//! next state in a canonical order (sort by key) and use only
//! order-insensitive arithmetic — see the authoring guide in
//! [`crate::workloads`].

use std::sync::Arc;

use crate::cache::{CacheBudget, CacheStats, PartitionCache};
use crate::engines::Engine;
use crate::storage::{DiskTier, StorageStats};
use crate::trace::{self, SpanCat};
use crate::util::stats::Stopwatch;

use super::{
    run_serial_inputs, CacheableWorkload, JobInputs, JobSpec, MapReduceError, Workload,
};

/// How long to iterate and how much memory the rounds may cache.
#[derive(Clone, Copy, Debug)]
pub struct IterativeSpec {
    /// Hard cap on rounds (the driver stops here even if not converged).
    pub max_iters: usize,
    /// Stop once a round's delta is `<=` this.
    pub tolerance: f64,
    /// Budget of the partition cache shared across rounds;
    /// `CacheBudget::Bytes(0)` is the recompute-every-round ablation.
    pub cache_budget: CacheBudget,
}

impl Default for IterativeSpec {
    fn default() -> Self {
        Self { max_iters: 10, tolerance: 1e-6, cache_budget: CacheBudget::Unbounded }
    }
}

impl IterativeSpec {
    pub fn new(max_iters: usize) -> Self {
        Self { max_iters, ..Default::default() }
    }

    pub fn tolerance(mut self, t: f64) -> Self {
        self.tolerance = t;
        self
    }

    pub fn cache_budget(mut self, b: CacheBudget) -> Self {
        self.cache_budget = b;
        self
    }
}

/// A multi-round algorithm over static input relations plus a fed-back
/// state relation. See the module docs for the execution model and
/// [`crate::workloads`] for the authoring guide (PageRank and k-means are
/// the worked examples).
pub trait IterativeWorkload: Send + Sync {
    /// The per-round step job. Its [`Workload::num_relations`] must equal
    /// [`num_static_relations`](Self::num_static_relations) + 1 (the state
    /// relation is appended last).
    type Step: CacheableWorkload;

    /// Stable name (CLI token, report label).
    fn name(&self) -> &'static str;

    /// How many static input relations the job reads (the fed-back state
    /// relation is appended after them).
    fn num_static_relations(&self) -> usize {
        1
    }

    /// Derive the initial state lines from the static inputs. Must be
    /// canonically ordered (sorted by key) — every later state inherits
    /// its order through [`advance`](Self::advance).
    fn init_state(&self, inputs: &JobInputs) -> Vec<String>;

    /// Build the round's step workload with `state` broadcast into it
    /// (Spark's broadcast-variable role: mappers need random access to the
    /// previous round's state).
    fn step(&self, state: &[String]) -> Arc<Self::Step>;

    /// Fold one round's reduced output into the next state and the
    /// round's convergence delta. Must be deterministic: sort keys, use
    /// order-insensitive (fixed-point) arithmetic.
    fn advance(
        &self,
        output: <Self::Step as Workload>::Output,
        state: &[String],
    ) -> (Vec<String>, f64);
}

/// Per-round metrics of one iterative run.
#[derive(Clone, Debug)]
pub struct IterationStats {
    /// 0-based round index.
    pub round: usize,
    /// Convergence delta reported by `advance` for this round.
    pub delta: f64,
    pub wall_secs: f64,
    pub shuffle_bytes: u64,
    /// Map-phase emissions of the round's step job.
    pub records: u64,
    /// What this round did to the shared partition cache.
    pub cache: CacheStats,
    /// The round's storage-hierarchy activity (exchange spill + cache
    /// demotions/promotions).
    pub storage: StorageStats,
}

/// Outcome of [`run_iterative`].
#[derive(Clone, Debug)]
pub struct IterativeReport {
    pub engine: Engine,
    pub workload: &'static str,
    /// Final state lines (canonical order).
    pub state: Vec<String>,
    /// Rounds actually executed.
    pub iterations: usize,
    /// Did the delta reach the tolerance before `max_iters`?
    pub converged: bool,
    pub wall_secs: f64,
    pub iters: Vec<IterationStats>,
    /// Cumulative cache stats across all rounds.
    pub cache: CacheStats,
    /// Cumulative storage-hierarchy activity across all rounds.
    pub storage: StorageStats,
}

impl IterativeReport {
    pub fn summary(&self) -> String {
        format!(
            "{:<12} {:<16} {} round(s){} in {:>8.3}s   cache: {}",
            self.workload,
            self.engine.label(),
            self.iterations,
            if self.converged { " (converged)" } else { "" },
            self.wall_secs,
            self.cache,
        )
    }
}

/// Outcome of [`run_iterative_serial`] — the fixed-point oracle.
#[derive(Clone, Debug, PartialEq)]
pub struct SerialIterativeOutcome {
    pub state: Vec<String>,
    pub iterations: usize,
    pub converged: bool,
    /// Per-round deltas (same length as `iterations`).
    pub deltas: Vec<f64>,
}

/// Validate the static-input arity. Runs **before** `init_state`, which
/// is entitled to index its relations.
fn check_arity<I: IterativeWorkload>(w: &I, inputs: &JobInputs) -> Result<(), MapReduceError> {
    if inputs.len() != w.num_static_relations() {
        return Err(MapReduceError(format!(
            "iterative workload '{}' expects {} static input relation(s), got {}",
            w.name(),
            w.num_static_relations(),
            inputs.len()
        )));
    }
    Ok(())
}

fn check_step_shape<I: IterativeWorkload>(w: &I, step: &I::Step) -> Result<(), MapReduceError> {
    if step.num_relations() != w.num_static_relations() + 1 {
        return Err(MapReduceError(format!(
            "iterative workload '{}': step job expects {} relation(s), \
             but static inputs + state make {}",
            w.name(),
            step.num_relations(),
            w.num_static_relations() + 1
        )));
    }
    Ok(())
}

/// Append the fed-back state as the last tagged relation of the round.
fn round_inputs(inputs: &JobInputs, state: &[String]) -> JobInputs {
    inputs.clone().relation_lines("state", Arc::new(state.to_vec()))
}

/// Execute `w` on `spec`'s engine: loop the step job, feeding each round's
/// reduced output back in as the `state` relation, until the delta reaches
/// `it.tolerance` or `it.max_iters` rounds ran. Each round compiles its
/// own one-stage plan (via
/// [`JobSpec::run_inputs_cached`](super::JobSpec::run_inputs_cached) →
/// [`JobSpec::plan_cached`](super::JobSpec::plan_cached)) and executes it
/// through the same engine stage executors as every single-pass job. One
/// [`PartitionCache`] of `it.cache_budget` bytes is shared across every
/// round (and handed to both engines), so parsed splits of the static
/// relations — whose cache generation never changes — are reused; the
/// state relation's generation is bumped every round and its stale
/// generations are invalidated as the driver advances, so even an
/// unbounded cache holds at most one parsed copy of the state.
pub fn run_iterative<I: IterativeWorkload>(
    spec: &JobSpec,
    it: &IterativeSpec,
    w: &I,
    inputs: &JobInputs,
) -> Result<IterativeReport, MapReduceError> {
    check_arity(w, inputs)?;
    let mut state = w.init_state(inputs);
    check_step_shape(w, w.step(&state).as_ref())?;

    // With the spill knob set, the shared cache gets a disk tier: evicted
    // parsed splits demote instead of forcing a reparse (disk-backed
    // persist rather than the PR 3 evict+recompute). A cache already
    // attached to the spec (the job service's store, shared across
    // tenants) is used as-is — its budget and policy govern, not
    // `it.cache_budget`.
    let policy = spec.eviction_policy.unwrap_or_default();
    let cache = match &spec.cache {
        Some(shared) => Arc::clone(shared),
        None => Arc::new(match spec.spill_threshold {
            Some(_) => PartitionCache::with_spill_policy(
                it.cache_budget,
                Arc::new(DiskTier::new(spec.spill_dir.clone())),
                policy,
            ),
            None => PartitionCache::with_policy(it.cache_budget, policy),
        }),
    };
    if let Some(rec) = &spec.trace {
        cache.attach_recorder(Arc::clone(rec));
    }
    let mut spec = spec.clone().shared_cache(Arc::clone(&cache));
    let nrels = inputs.len() + 1;
    // Delta the cache stats around the run: with a pre-attached shared
    // store the lifetime totals belong to everyone, not this job.
    let cache_before = cache.stats();

    let sw = Stopwatch::start();
    let mut iters = Vec::new();
    let mut converged = false;
    let mut storage = StorageStats::default();
    for round in 0..it.max_iters {
        let _round_span = trace::span_arg(SpanCat::Round, "round", round as u64);
        // Static relations stay at generation 0; the state relation's
        // content changes every round.
        let mut gens = vec![0u64; nrels];
        gens[nrels - 1] = round as u64;
        spec = spec.relation_gens(gens);

        let step = w.step(&state);
        let report = spec.run_inputs_cached(&step, &round_inputs(inputs, &state))?;
        // Older state generations can never be read again; free them now
        // rather than leaving an unbounded cache to accumulate one dead
        // parsed state per round (bounded budgets would also LRU them
        // out). The keys carry the spec's namespace/generation bases
        // (see `plan_cached`), so mirror them here.
        cache.invalidate_generations_below(
            spec.namespace_base + (nrels - 1) as u64,
            spec.generation_base + round as u64,
        );
        // `advance` is driver-side wall between rounds — span it so it
        // shows up as its own phase rather than hiding in the round gap.
        let (next, delta) = {
            let _adv = trace::span_arg(SpanCat::Driver, "advance", round as u64);
            w.advance(report.output, &state)
        };
        storage = storage.merged(&report.storage);
        iters.push(IterationStats {
            round,
            delta,
            wall_secs: report.wall_secs,
            shuffle_bytes: report.shuffle_bytes,
            records: report.records,
            cache: report.cache,
            storage: report.storage,
        });
        state = next;
        if delta <= it.tolerance {
            converged = true;
            break;
        }
    }
    Ok(IterativeReport {
        engine: spec.engine,
        workload: w.name(),
        state,
        iterations: iters.len(),
        converged,
        wall_secs: sw.elapsed_secs(),
        iters,
        cache: cache.stats().delta_since(&cache_before),
        storage,
    })
}

/// The fixed-point serial oracle: the exact driver loop of
/// [`run_iterative`], with every round's step job executed by
/// [`run_serial_inputs`]. Engines must reproduce its final state
/// bit-identically (workload arithmetic is integer fixed-point, so there
/// is no float-ordering escape hatch).
pub fn run_iterative_serial<I: IterativeWorkload>(
    it: &IterativeSpec,
    w: &I,
    inputs: &JobInputs,
) -> SerialIterativeOutcome {
    // Oracle convention (matches `run_serial_inputs`): shape errors assert.
    assert_eq!(
        inputs.len(),
        w.num_static_relations(),
        "iterative workload '{}' expects {} static input relation(s)",
        w.name(),
        w.num_static_relations()
    );
    let mut state = w.init_state(inputs);
    let mut deltas = Vec::new();
    let mut converged = false;
    for _round in 0..it.max_iters {
        let step = w.step(&state);
        let output = run_serial_inputs(step.as_ref(), &round_inputs(inputs, &state));
        let (next, delta) = w.advance(output, &state);
        deltas.push(delta);
        state = next;
        if delta <= it.tolerance {
            converged = true;
            break;
        }
    }
    SerialIterativeOutcome { state, iterations: deltas.len(), converged, deltas }
}
