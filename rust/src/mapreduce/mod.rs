//! The workload-generic MapReduce job layer.
//!
//! The paper demonstrates its claim on exactly one workload; this module
//! generalizes both engines to run *any* associative map/combine/shuffle/
//! reduce job. The pieces:
//!
//! * [`Workload`] — what a job computes: a per-record map ([`Workload::map`]
//!   for single-input jobs, [`Workload::map_rel`] when the job reads
//!   several tagged relations) that emits `(K, V)` pairs, an
//!   associative+commutative `combine`, an optional per-shard partial
//!   reduce (`finalize_local`, e.g. top-K heap selection), and a
//!   driver-side `finalize` into the output type.
//! * [`StrWorkload`] — string-keyed workloads that can also emit borrowed
//!   `&str` keys, unlocking the zero-alloc "TCM" insert path on Blaze and
//!   the UTF-16 `JvmWord` modeling on the Spark sim.
//! * [`JobInputs`] / [`Relation`] — the job's N tagged input relations.
//!   Single-input jobs wrap their corpus with [`JobInputs::single`]; a
//!   join supplies one relation per side and `map_rel` is told which side
//!   each record came from.
//! * [`JobSpec`] / [`JobReport`] — one engine-agnostic job description
//!   (cluster shape, network, combine mode, failure plan) and one uniform
//!   result (output + wall time + shuffle bytes + per-stage rows + engine
//!   detail).
//! * [`plan`] — the **planner layer**: every job is compiled into an
//!   explicit [`StageGraph`] (stages separated by [`ShuffleBoundary`]
//!   edges, exchange elision and cache points decided at plan time)
//!   before any engine touches it. Multi-stage pipelines are
//!   [`ChainedWorkload`]s driven by [`run_chained`] /
//!   [`run_chained_serial`].
//! * [`JobEngine`] — the shared engine abstraction both backends
//!   implement: one [`JobEngine::run_plan`] method executing one stage of
//!   a compiled graph. [`engine_for`]/[`engine_for_str`] hand back the
//!   right trait object for an [`Engine`] choice.
//! * [`run_serial`] / [`run_serial_inputs`] — the single-threaded reference
//!   executors, the correctness oracle for every engine × workload
//!   combination.
//! * [`CacheableWorkload`] — workloads whose record mapping factors into a
//!   cacheable parse plus a per-round map; together with
//!   [`JobSpec::run_inputs_cached`] and the
//!   [`crate::cache::PartitionCache`], the engines skip tokenization of
//!   unchanged relations on later rounds of an iterative job.
//! * [`iterative`] — the multi-round driver: [`IterativeSpec`] /
//!   [`run_iterative`] loop a [`IterativeWorkload`]'s step job, feeding
//!   each round's reduced output back in as a tagged relation until
//!   convergence or an iteration cap ([`run_iterative_serial`] is the
//!   fixed-point serial oracle).
//!
//! Concrete workloads live in [`crate::workloads`] (that module's docs are
//! the workload-authoring guide); `wordcount::WordCountJob` is a thin
//! facade over this layer.
//!
//! # The zero-shuffle fast path
//!
//! A workload whose keys never repeat (grep: one emission per matching
//! line, keyed by line id) has nothing to co-locate: `combine` can never
//! fire, so the shards each producer holds are already disjoint. Such a
//! workload overrides [`Workload::needs_shuffle`] to `false`; the planner
//! records the elision in the compiled stage ([`Exchange::Elided`]) and
//! both engines skip the exchange entirely — no serialization, no bytes
//! on the simulated wire, `JobReport::shuffle_bytes == 0`. Set
//! [`JobSpec::force_shuffle()`] to run the exchange anyway
//! ([`Exchange::Forced`] in the plan) and measure what the skip saves.
//!
//! # The `finalize_local` contract
//!
//! Engines apply `finalize_local` independently to each owned shard (a
//! node's key shard on Blaze, a reduce partition on Spark, the whole entry
//! set serially). It must therefore be a *filtering partial reduce*: for
//! any partition of the reduced entries into disjoint shards,
//! `finalize(concat(map(finalize_local, shards)))` must equal
//! `finalize(all entries)`. Identity (the default) and bounded top-K
//! selection both satisfy this; anything that mixes information across
//! keys it then discards does not.

pub mod iterative;
pub mod plan;

pub use iterative::{
    run_iterative, run_iterative_serial, IterationStats, IterativeReport, IterativeSpec,
    IterativeWorkload, SerialIterativeOutcome,
};
pub use plan::{
    run_chained, run_chained_serial, CachePoint, ChainReport, ChainStage, ChainedWorkload,
    Exchange, InputSource, ShuffleBoundary, StageGraph, StageInput, StageOutcome, StagePlan,
    StageShape, StageStats, TypedStage,
};

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

use crate::cache::{CacheStats, PartitionCache};
use crate::cluster::{FailurePlan, NetModel};
use crate::concurrent::{CachePolicy, MapKey, MapValue};
use crate::corpus::{Corpus, Tokenizer};
use crate::dist::CombineMode;
use crate::engines::blaze::{BlazeConf, KeyPath};
use crate::engines::spark::{SparkConf, SparkContext};
use crate::engines::Engine;
use crate::hash::HashKind;
use crate::runtime::executor::{ExecMetrics, Executor};
use crate::storage::{HeapSize, PolicySpec, StorageStats, TraceRecorder};
use crate::trace::MetricSet;
use crate::util::ser::{DataKey, Decode, Encode};
use crate::util::stats::{fmt_bytes, fmt_rate, Stopwatch};

/// Keys a generic job can shuffle: routable (`MapKey`), wire-encodable
/// (`Encode`/`Decode` plus the dictionary/arena path via [`DataKey`]),
/// JVM-cost-modelable, hashable for Spark partitioning, and totally
/// ordered so finalizers can be deterministic.
pub trait JobKey:
    MapKey + DataKey + Encode + Decode + HeapSize + std::hash::Hash + Ord + std::fmt::Debug + 'static
{
}
impl<T> JobKey for T where
    T: MapKey
        + DataKey
        + Encode
        + Decode
        + HeapSize
        + std::hash::Hash
        + Ord
        + std::fmt::Debug
        + 'static
{
}

/// Values a generic job can shuffle.
pub trait JobValue: MapValue + Encode + Decode + HeapSize + std::fmt::Debug + 'static {}
impl<T> JobValue for T where T: MapValue + Encode + Decode + HeapSize + std::fmt::Debug + 'static {}

/// A MapReduce workload: how records become `(K, V)` emissions, how values
/// combine, and how reduced entries become the final output.
///
/// Single-input workloads implement [`map`](Self::map); multi-input
/// workloads override [`map_rel`](Self::map_rel) (whose default delegates
/// to `map`) and stub `map` out with a panic — engines only ever call
/// `map_rel`, and the job layer validates relation arity before running.
pub trait Workload: Send + Sync + 'static {
    type Key: JobKey;
    type Value: JobValue;
    type Output;

    /// Stable name (CLI `--workload` token, bench/report label).
    fn name(&self) -> &'static str;

    /// Number of input relations this workload consumes. The job layer
    /// rejects a [`JobInputs`] whose relation count disagrees.
    fn num_relations(&self) -> usize {
        1
    }

    /// Does correctness depend on co-locating every value of a key before
    /// `finalize_local`? Default `true`. Return `false` **only if** every
    /// key is emitted at most once across the whole job (e.g. grep keyed
    /// by line id): `combine` then never fires, per-producer shards are
    /// already disjoint, and the engines skip the shuffle exchange
    /// entirely (`JobReport::shuffle_bytes` reads 0 unless
    /// [`JobSpec::force_shuffle()`] is set).
    fn needs_shuffle(&self) -> bool {
        true
    }

    /// Map one record of a single-input job. `doc` is the record's global
    /// index (line number) — identity for workloads like inverted
    /// indexing. Multi-input workloads stub this with a panic and
    /// override [`map_rel`](Self::map_rel) instead.
    fn map(&self, doc: u64, record: &str, emit: &mut dyn FnMut(Self::Key, Self::Value));

    /// Map one record of relation `rel` (its index into the job's
    /// [`JobInputs`]; always 0 for single-input jobs). `doc` is the
    /// record's index *within its relation*. Default delegates to
    /// [`map`](Self::map), ignoring the tag — multi-input workloads (e.g.
    /// a join, which must know which side a record came from) override
    /// this instead of `map`.
    fn map_rel(
        &self,
        rel: usize,
        doc: u64,
        record: &str,
        emit: &mut dyn FnMut(Self::Key, Self::Value),
    ) {
        debug_assert_eq!(rel, 0, "single-input workload handed relation {rel}");
        self.map(doc, record, emit);
    }

    /// Fold `v` into `acc`. Must be associative and commutative; engines
    /// fold in thread, cache, and shuffle arrival order.
    fn combine(acc: &mut Self::Value, v: Self::Value);

    /// Optional per-shard partial reduce, applied by each engine to every
    /// owned shard independently (see the module docs for the contract).
    fn finalize_local(
        &self,
        shard: Vec<(Self::Key, Self::Value)>,
    ) -> Vec<(Self::Key, Self::Value)> {
        shard
    }

    /// Driver-side finalize over the concatenated shards.
    fn finalize(&self, entries: Vec<(Self::Key, Self::Value)>) -> Self::Output;
}

/// String-keyed workloads that can emit keys as borrowed `&str` slices of
/// the input record. Blaze uses this for the zero-alloc insert path (the
/// paper's "TCM" bar); the Spark sim uses it to route tokens through
/// UTF-16 [`crate::engines::spark::JvmWord`]s when `jvm_strings` is on.
pub trait StrWorkload: Workload<Key = String> {
    /// Must emit exactly what [`Workload::map`] emits, with keys borrowed.
    fn map_str(&self, doc: u64, record: &str, emit: &mut dyn FnMut(&str, Self::Value));
}

/// Workloads whose record mapping factors into **parse** (pure per-record
/// tokenization, independent of any per-round state) and **map** (emission
/// from the parsed form). Iterative jobs re-read their inputs every round;
/// engines cache the parsed form in the
/// [`PartitionCache`](crate::cache::PartitionCache) keyed by
/// `(relation, generation, split)` so later rounds skip tokenization —
/// the mechanism behind Spark's `textFile(...).map(parse).cache()` idiom.
///
/// Contract: for every record,
/// `parse_rel(rel, doc, rec).map(|p| map_parsed(rel, &p, emit))` must emit
/// exactly what [`Workload::map_rel`] emits (a `None` parse means the
/// record emits nothing). `parse_rel` must be a pure function of its
/// arguments; all per-round (broadcast) state belongs in `map_parsed`, on
/// the workload value itself — cached parses outlive the round that
/// produced them.
pub trait CacheableWorkload: Workload {
    /// Parsed form of one record — what the partition cache stores.
    /// `Encode`/`Decode` so cached splits can **demote to the disk tier**
    /// under memory pressure and promote back on access (see
    /// [`crate::storage::TieredStore`]).
    type Parsed: Clone + Send + Sync + HeapSize + Encode + Decode + 'static;

    /// Tokenize one record of relation `rel`; `None` for records that emit
    /// nothing (blank/malformed lines).
    fn parse_rel(&self, rel: usize, doc: u64, record: &str) -> Option<Self::Parsed>;

    /// Emit from the parsed form (may consult per-round broadcast state
    /// held on `self`).
    fn map_parsed(
        &self,
        rel: usize,
        parsed: &Self::Parsed,
        emit: &mut dyn FnMut(Self::Key, Self::Value),
    );
}

/// One tagged input relation: a name (surfaced in diagnostics, e.g. the
/// relation-arity error) plus its records. Lines are shared, not copied —
/// engines clone per task exactly as they would for a single-input corpus.
#[derive(Clone, Debug)]
pub struct Relation {
    pub name: String,
    pub lines: Arc<Vec<String>>,
}

/// The N tagged input relations of one job.
///
/// Single-input jobs wrap their corpus with [`JobInputs::single`] (which
/// is what [`JobSpec::run`] does for you); multi-input workloads receive
/// one relation per [`Workload::num_relations`] slot, in order, and
/// [`Workload::map_rel`] is told which relation each record came from.
#[derive(Clone, Debug, Default)]
pub struct JobInputs {
    pub relations: Vec<Relation>,
}

impl JobInputs {
    pub fn new() -> Self {
        Self::default()
    }

    /// The classic single-relation input.
    pub fn single(corpus: &Corpus) -> Self {
        Self::new().relation("input", corpus)
    }

    /// Append a relation built from a corpus (lines are copied once, into
    /// the shared `Arc`).
    pub fn relation(self, name: &str, corpus: &Corpus) -> Self {
        self.relation_lines(name, Arc::new(corpus.lines.clone()))
    }

    /// Append a relation over already-shared lines.
    pub fn relation_lines(mut self, name: &str, lines: Arc<Vec<String>>) -> Self {
        self.relations.push(Relation { name: name.to_string(), lines });
        self
    }

    pub fn len(&self) -> usize {
        self.relations.len()
    }

    pub fn is_empty(&self) -> bool {
        self.relations.is_empty()
    }

    /// Just the line vectors, in relation order (what the engines map over).
    pub fn line_sets(&self) -> Vec<Arc<Vec<String>>> {
        self.relations.iter().map(|r| Arc::clone(&r.lines)).collect()
    }
}

/// Error surfaced by the generic layer (wraps either engine's failure).
#[derive(Debug, Clone)]
pub struct MapReduceError(pub String);

impl std::fmt::Display for MapReduceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "mapreduce job failed: {}", self.0)
    }
}

impl std::error::Error for MapReduceError {}

/// Per-stage scheduling hook threaded through a [`JobSpec`] by the job
/// service ([`crate::service`]): before an engine executes a stage, the
/// job layer calls [`begin_stage`](Self::begin_stage) — which blocks
/// until the scheduler grants the job a stage slot — and releases the
/// slot with the stage's wall time afterwards. Stage granularity is the
/// point: a long iterative job re-acquires between rounds, so short jobs
/// from other tenants interleave instead of starving.
pub trait StageGate: Send + Sync + std::fmt::Debug {
    /// Block until the job may run its next stage. `Err` means the job
    /// was cancelled while waiting — the stage is never executed and the
    /// error propagates as the job's failure.
    fn begin_stage(&self, stage: u64) -> Result<(), MapReduceError>;

    /// Release the slot acquired by [`begin_stage`](Self::begin_stage),
    /// charging `wall_secs` of stage time to the job's tenant (the fair
    /// scheduler's virtual-time accounting). Called exactly once per
    /// successful `begin_stage`, whether the stage succeeded or failed.
    fn end_stage(&self, stage: u64, wall_secs: f64);
}

/// Everything needed to run one job on one engine, minus the workload.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub engine: Engine,
    pub nnodes: usize,
    /// **Simulated** per-node thread count — shapes partitioning
    /// arithmetic and the engines' cost models, not how many OS threads
    /// run. Real parallelism is [`JobSpec::threads`].
    pub threads_per_node: usize,
    /// **Real** executor width: both engines dispatch their map tasks and
    /// stage partitions onto the process-wide work-stealing pool
    /// ([`crate::runtime::Executor`]) of this many workers. `None` = auto
    /// (`BLAZE_THREADS`, else the machine's available parallelism).
    pub threads: Option<usize>,
    pub net: NetModel,
    /// Blaze: map-side combining mode (A3 ablation).
    pub combine: CombineMode,
    /// Blaze: hash function.
    pub hash: HashKind,
    /// Blaze: thread-cache policy of the distributed map.
    pub cache_policy: CachePolicy,
    /// Spark: override individual cost knobs after the engine presets.
    pub spark_overrides: Option<SparkConf>,
    /// Failure injection plan (consumed by whichever engine runs).
    pub failures: Arc<FailurePlan>,
    /// Blaze: whole-job reruns allowed on an injected node failure.
    pub max_job_reruns: usize,
    /// Run the shuffle exchange even for workloads that opt out via
    /// [`Workload::needs_shuffle`] — the ablation that measures what the
    /// zero-shuffle fast path saves.
    pub force_shuffle: bool,
    /// Shared partition cache for [`run_inputs_cached`](Self::run_inputs_cached):
    /// the iterative driver hands the same instance to every round so
    /// parsed splits survive across jobs. `None` = the cached entry point
    /// degrades to [`run_inputs`](Self::run_inputs).
    pub cache: Option<Arc<PartitionCache>>,
    /// Per-relation content generation for cache keys (missing entries
    /// read as 0). Bump a relation's generation when its lines change —
    /// stale-generation entries stop matching; drop them with
    /// `PartitionCache::invalidate_generations_below` (bounded budgets
    /// would also age them out via LRU).
    pub relation_gens: Vec<u64>,
    /// Bounded-memory exchange: when set, a reduce shard whose in-flight
    /// bytes exceed this budget sort-and-spills runs to the disk tier
    /// and finalize merges them externally (see
    /// [`crate::storage::ExternalMerger`]). Recorded per stage in the
    /// compiled plan ([`StagePlan::spill_threshold`]); `None` = the
    /// unbounded in-memory exchange the paper assumes.
    pub spill_threshold: Option<u64>,
    /// Directory spill files live under (`None` = the system temp dir).
    pub spill_dir: Option<PathBuf>,
    /// Block-compress disk-tier payloads (spill runs, demoted cache
    /// splits, persisted shuffle blocks) with the built-in LZ4-style
    /// codec (the `--compress` knob). On by default; `false` is the
    /// ablation that stores every block raw.
    pub compress: bool,
    /// Dictionary-encode repeated keys in spill runs and exchange
    /// payloads (the `--dict-keys` knob). On by default; `false` writes
    /// every key inline — the ablation axis of `benches/spill.rs`.
    pub dict_keys: bool,
    /// Eviction policy of every partition cache built from this spec
    /// (the `--cache-policy` knob; see [`crate::storage::policy`]).
    /// `None` = whatever the engine conf carries (LRU by default).
    pub eviction_policy: Option<PolicySpec>,
    /// Trace-lab hook: when set, the iterative driver attaches this
    /// recorder to the round-shared partition cache it builds, so every
    /// real get/put the run issues lands in the recorder's access log
    /// (see [`crate::storage::trace`]). `None` = no recording overhead.
    pub trace: Option<Arc<TraceRecorder>>,
    /// Per-stage scheduling gate (see [`StageGate`]): every engine stage
    /// this spec runs first acquires a slot through it. `None` = run
    /// immediately (every non-service path).
    pub gate: Option<Arc<dyn StageGate>>,
    /// Offset added to every relation index when forming cache-key
    /// namespaces ([`plan_cached`](Self::plan_cached)). The job service
    /// gives each tenant a disjoint namespace range so one shared
    /// [`PartitionCache`] can never cross-serve tenants; 0 (the default)
    /// reproduces the single-tenant key scheme exactly.
    pub namespace_base: u64,
    /// Offset added to every relation generation in cache keys — the
    /// service keys it by job sequence number so two jobs over
    /// same-shaped inputs still resolve to distinct entries. 0 outside
    /// the service.
    pub generation_base: u64,
}

impl JobSpec {
    pub fn new(engine: Engine) -> Self {
        Self {
            engine,
            nnodes: 1,
            threads_per_node: 4,
            threads: None,
            net: NetModel::aws_like(),
            combine: CombineMode::Eager,
            hash: HashKind::Fx,
            cache_policy: CachePolicy::default(),
            spark_overrides: None,
            failures: Arc::new(FailurePlan::none()),
            max_job_reruns: 3,
            force_shuffle: false,
            cache: None,
            relation_gens: Vec::new(),
            spill_threshold: None,
            spill_dir: None,
            compress: true,
            dict_keys: true,
            eviction_policy: None,
            trace: None,
            gate: None,
            namespace_base: 0,
            generation_base: 0,
        }
    }

    pub fn nodes(mut self, n: usize) -> Self {
        self.nnodes = n;
        self
    }

    pub fn threads_per_node(mut self, t: usize) -> Self {
        self.threads_per_node = t;
        self
    }

    /// Pin the real work-stealing executor to `t` OS threads (see
    /// [`Self::threads`]; default auto-sizes from the machine).
    pub fn threads(mut self, t: usize) -> Self {
        self.threads = Some(t);
        self
    }

    pub fn net(mut self, net: NetModel) -> Self {
        self.net = net;
        self
    }

    pub fn combine(mut self, c: CombineMode) -> Self {
        self.combine = c;
        self
    }

    pub fn cache_policy(mut self, p: CachePolicy) -> Self {
        self.cache_policy = p;
        self
    }

    pub fn spark_conf(mut self, conf: SparkConf) -> Self {
        self.spark_overrides = Some(conf);
        self
    }

    pub fn failures(mut self, plan: FailurePlan) -> Self {
        self.failures = Arc::new(plan);
        self
    }

    pub fn force_shuffle(mut self, force: bool) -> Self {
        self.force_shuffle = force;
        self
    }

    /// Bound the exchange's in-flight memory: shards beyond `bytes` spill
    /// sorted runs to disk and merge externally (see
    /// [`Self::spill_threshold`]). Also arms the partition cache's disk
    /// tier on the paths that build one from this spec.
    pub fn spill_threshold(mut self, bytes: u64) -> Self {
        self.spill_threshold = Some(bytes);
        self
    }

    /// Where spill files live (`None` = system temp dir).
    pub fn spill_dir(mut self, dir: PathBuf) -> Self {
        self.spill_dir = Some(dir);
        self
    }

    /// Toggle disk-tier block compression (see [`Self::compress`]).
    pub fn compress(mut self, on: bool) -> Self {
        self.compress = on;
        self
    }

    /// Toggle dictionary key encoding on the spill/exchange data path
    /// (see [`Self::dict_keys`]).
    pub fn dict_keys(mut self, on: bool) -> Self {
        self.dict_keys = on;
        self
    }

    /// Pick the partition cache's eviction policy (`--cache-policy`):
    /// LRU, SLRU, GDSF, or any of them under a TinyLFU admission filter.
    /// Applies to every cache built from this spec (the iterative
    /// driver's, the Spark sim's persist store); caches injected via
    /// [`Self::shared_cache`] keep the policy they were built with.
    pub fn eviction_policy(mut self, policy: PolicySpec) -> Self {
        self.eviction_policy = Some(policy);
        self
    }

    /// Record the iterative driver's cache accesses into `rec` (the
    /// trace lab's capture hook; see [`crate::storage::trace`]).
    pub fn trace(mut self, rec: Arc<TraceRecorder>) -> Self {
        self.trace = Some(rec);
        self
    }

    /// Attach a shared partition cache (see [`Self::run_inputs_cached`]).
    ///
    /// Contract: one cache serves **one workload's** relations. Cached
    /// entries are keyed by relation index + generation + split shape,
    /// not by workload, so running a *different* [`CacheableWorkload`]
    /// against the same cache without bumping `relation_gens` would at
    /// best miss on the parsed type and reparse, and — if both workloads
    /// share a `Parsed` type — silently serve the other workload's parse
    /// output. The iterative driver follows the contract by creating a
    /// fresh cache per run.
    pub fn shared_cache(mut self, cache: Arc<PartitionCache>) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Tag each relation's content generation for cache keys.
    pub fn relation_gens(mut self, gens: Vec<u64>) -> Self {
        self.relation_gens = gens;
        self
    }

    /// Attach a per-stage scheduling gate (see [`StageGate`]).
    pub fn stage_gate(mut self, gate: Arc<dyn StageGate>) -> Self {
        self.gate = Some(gate);
        self
    }

    /// Offset cache-key namespaces by `base` (see [`Self::namespace_base`]).
    pub fn namespace_base(mut self, base: u64) -> Self {
        self.namespace_base = base;
        self
    }

    /// Offset cache-key generations by `base` (see [`Self::generation_base`]).
    pub fn generation_base(mut self, base: u64) -> Self {
        self.generation_base = base;
        self
    }

    /// Run `f` (one stage's engine call) under the spec's stage gate: a
    /// no-op passthrough without one, otherwise acquire a slot, run, and
    /// release with the stage's measured wall.
    pub(crate) fn gated<T>(
        &self,
        stage: u64,
        f: impl FnOnce() -> Result<T, MapReduceError>,
    ) -> Result<T, MapReduceError> {
        let Some(gate) = &self.gate else { return f() };
        gate.begin_stage(stage)?;
        let sw = Stopwatch::start();
        let out = f();
        gate.end_stage(stage, sw.elapsed_secs());
        out
    }

    /// Run `w` on this spec's engine (owned-key emission path everywhere)
    /// over a single input relation.
    pub fn run<W: Workload>(
        &self,
        w: &Arc<W>,
        corpus: &Corpus,
    ) -> Result<JobReport<W::Output>, MapReduceError> {
        self.run_inputs(w, &JobInputs::single(corpus))
    }

    /// Run `w` over N tagged input relations — the general entry point;
    /// multi-input workloads (joins) have no single-corpus shorthand.
    /// Compiles the job's one-stage [`StageGraph`] and executes it
    /// through the engine's single plan path.
    pub fn run_inputs<W: Workload>(
        &self,
        w: &Arc<W>,
        inputs: &JobInputs,
    ) -> Result<JobReport<W::Output>, MapReduceError> {
        self.check_arity(w.as_ref(), inputs)?;
        let graph = self.plan(w.as_ref(), inputs);
        let (exec, before) = self.exec_snapshot();
        let run =
            self.gated(0, || engine_for::<W>(self.engine).run_plan(self, &graph, 0, w, inputs))?;
        Ok(self.finish(w, run, inputs, exec.metrics().delta_since(&before)))
    }

    /// Run a [`CacheableWorkload`] through the engines' partition-cached
    /// paths when [`Self::cache`] is attached (parsed input splits are
    /// stored under `(relation, generation, split)` and reused across
    /// jobs — the iterative driver's hot path); without a cache this is
    /// exactly [`run_inputs`](Self::run_inputs). The returned
    /// [`JobReport::cache`] holds what *this* run did to the shared cache.
    pub fn run_inputs_cached<W: CacheableWorkload>(
        &self,
        w: &Arc<W>,
        inputs: &JobInputs,
    ) -> Result<JobReport<W::Output>, MapReduceError> {
        let Some(cache) = &self.cache else {
            return self.run_inputs(w, inputs);
        };
        self.check_arity(w.as_ref(), inputs)?;
        // Compile the round's plan: cache points (namespace + generation
        // per relation) are decided here, not inside the engines.
        let graph = self.plan_cached(w.as_ref(), inputs);
        let stage = graph.stage(0);
        let before = cache.stats();
        let before_storage = cache.storage_stats();
        let rels = inputs.line_sets();
        let (exec, exec_before) = self.exec_snapshot();
        let run = self.gated(0, || match self.engine {
            Engine::Blaze | Engine::BlazeTcm => {
                let conf = self.blaze_conf(KeyPath::AllocPerToken);
                let r = crate::engines::blaze::run_workload_cached(
                    &conf,
                    stage,
                    &rels,
                    cache,
                    &self.failures,
                    w.as_ref(),
                )
                .map_err(|e| MapReduceError(e.to_string()))?;
                Ok(blaze_job_run(r))
            }
            Engine::Spark | Engine::SparkStripped => {
                let ctx = self.spark_context();
                let sw = Stopwatch::start();
                let (entries, records) =
                    crate::engines::spark::run_workload_cached(&ctx, stage, &rels, w)
                        .map_err(|e| MapReduceError(e.to_string()))?;
                Ok(spark_job_run(&ctx, entries, records, sw.elapsed_secs()))
            }
        })?;
        let mut report =
            self.finish(w, run, inputs, exec.metrics().delta_since(&exec_before));
        report.cache = cache.stats().delta_since(&before);
        // Exchange spill (engine-side) + cache demotions/promotions
        // (shared-store side) in one storage row.
        report.storage =
            report.storage.merged(&cache.storage_stats().delta_since(&before_storage));
        Ok(report)
    }

    /// Run a string-keyed workload with the engines' specialized string
    /// paths: zero-alloc inserts on Blaze TCM, UTF-16 `JvmWord` modeling
    /// on the faithful Spark sim. String paths are single-input only —
    /// multi-input jobs go through [`run_inputs`](Self::run_inputs).
    pub fn run_str<W: StrWorkload>(
        &self,
        w: &Arc<W>,
        corpus: &Corpus,
    ) -> Result<JobReport<W::Output>, MapReduceError> {
        let inputs = JobInputs::single(corpus);
        self.check_arity(w.as_ref(), &inputs)?;
        let graph = self.plan(w.as_ref(), &inputs);
        let (exec, before) = self.exec_snapshot();
        let run = self.gated(0, || {
            engine_for_str::<W>(self.engine).run_plan(self, &graph, 0, w, &inputs)
        })?;
        Ok(self.finish(w, run, &inputs, exec.metrics().delta_since(&before)))
    }

    fn check_arity<W: Workload>(&self, w: &W, inputs: &JobInputs) -> Result<(), MapReduceError> {
        if inputs.len() != w.num_relations() {
            let names: Vec<&str> =
                inputs.relations.iter().map(|r| r.name.as_str()).collect();
            return Err(MapReduceError(format!(
                "workload '{}' expects {} input relation(s), got {} ({names:?})",
                w.name(),
                w.num_relations(),
                inputs.len()
            )));
        }
        Ok(())
    }

    /// Snapshot the process-wide worker pool this spec's jobs run on, so
    /// callers can delta its counters around the engine call. The pool is
    /// shared: concurrent jobs on the same width see each other's work,
    /// so [`JobReport::exec`] describes "the pool during this job" —
    /// exact when one job runs at a time (the CLI and bench paths).
    fn exec_snapshot(&self) -> (Arc<Executor>, ExecMetrics) {
        let exec = Executor::for_threads(self.threads);
        let before = exec.metrics();
        (exec, before)
    }

    fn finish<W: Workload>(
        &self,
        w: &Arc<W>,
        run: JobRun<W::Key, W::Value>,
        inputs: &JobInputs,
        exec: ExecMetrics,
    ) -> JobReport<W::Output> {
        let records_in: u64 = inputs.relations.iter().map(|r| r.lines.len() as u64).sum();
        let stages = vec![StageStats {
            stage: 0,
            label: w.name().to_string(),
            records_in,
            records_out: run.entries.len() as u64,
            shuffle_bytes: run.shuffle_bytes,
            dict: run.storage.dict_stats(),
            wall_secs: run.wall_secs,
        }];
        JobReport {
            engine: self.engine,
            workload: w.name(),
            output: w.finalize(run.entries),
            wall_secs: run.wall_secs,
            records: run.records,
            shuffle_bytes: run.shuffle_bytes,
            detail: run.detail,
            cache: CacheStats::default(),
            storage: run.storage,
            exec,
            stages,
        }
    }

    pub(crate) fn blaze_conf(&self, key_path: KeyPath) -> BlazeConf {
        BlazeConf {
            nnodes: self.nnodes,
            threads_per_node: self.threads_per_node,
            threads: self.threads,
            net: self.net,
            combine: self.combine,
            hash: self.hash,
            // Unused by the generic runners: tokenization happens inside
            // `Workload::map` (the facade's word-count path builds its
            // workload from its own conf).
            tokenizer: Tokenizer::Spaces,
            key_path,
            cache_policy: self.cache_policy,
            max_job_reruns: self.max_job_reruns,
            spill_dir: self.spill_dir.clone(),
            compress: self.compress,
            dict_keys: self.dict_keys,
            eviction_policy: self.eviction_policy.unwrap_or_default(),
        }
    }

    pub(crate) fn spark_context(&self) -> SparkContext {
        let mut conf = self.spark_overrides.clone().unwrap_or_else(|| {
            let mut c = if self.engine == Engine::SparkStripped {
                SparkConf::stripped(self.nnodes, self.threads_per_node)
            } else {
                SparkConf::emr_like(self.nnodes, self.threads_per_node)
            };
            c.net = self.net;
            c
        });
        // The spill and real-thread knobs are job-level: they override
        // whatever the conf (preset or explicit) carried, but only when
        // actually set.
        if self.threads.is_some() {
            conf.threads = self.threads;
        }
        if self.spill_threshold.is_some() {
            conf.spill_threshold = self.spill_threshold;
        }
        if self.spill_dir.is_some() {
            conf.spill_dir = self.spill_dir.clone();
        }
        if let Some(policy) = self.eviction_policy {
            conf.eviction_policy = policy;
        }
        // Data-path knobs are plain bools (default on), so they always
        // flow from the job spec — the CLI/bench ablations set them here.
        conf.compress = self.compress;
        conf.dict_keys = self.dict_keys;
        match &self.cache {
            // Share the job-spec cache so persisted partitions survive
            // across the per-round contexts of an iterative run.
            Some(cache) => {
                SparkContext::with_shared_cache(conf, Arc::clone(&self.failures), Arc::clone(cache))
            }
            None => SparkContext::with_failures_arc(conf, Arc::clone(&self.failures)),
        }
    }
}

/// Raw engine outcome before the driver-side finalize: the concatenated
/// per-shard (already `finalize_local`-ed) entries plus run metrics.
#[derive(Debug)]
pub struct JobRun<K, V> {
    pub entries: Vec<(K, V)>,
    pub wall_secs: f64,
    /// Map-phase emissions observed (may exceed the steady-state count
    /// when failure injection forces reruns/retries).
    pub records: u64,
    pub shuffle_bytes: u64,
    /// Engine-side storage activity (exchange spill, persisted shuffle
    /// blocks).
    pub storage: StorageStats,
    pub detail: MetricSet,
}

/// Uniform result of one job on one engine.
#[derive(Debug)]
pub struct JobReport<O> {
    pub engine: Engine,
    pub workload: &'static str,
    pub output: O,
    pub wall_secs: f64,
    /// Map-phase emissions.
    pub records: u64,
    pub shuffle_bytes: u64,
    /// Engine-specific metric breakdown, typed (renders exactly like the
    /// old `k=v`-joined string via `Display`).
    pub detail: MetricSet,
    /// What this run did to the shared partition cache (all zeros unless
    /// the job went through [`JobSpec::run_inputs_cached`] with a cache
    /// attached).
    pub cache: CacheStats,
    /// Storage-hierarchy activity: exchange spill (sorted runs written +
    /// merged back), cache demotions/promotions, and raw disk traffic
    /// (persisted shuffle blocks land here too). All zeros when nothing
    /// touched a tier below memory.
    pub storage: StorageStats,
    /// Worker-pool activity during the job: per-worker busy/idle nanos,
    /// task counts, steals, and the task-latency histogram, deltaed
    /// around the engine call. The pool is process-wide per width, so
    /// concurrent jobs on the same width fold together here.
    pub exec: ExecMetrics,
    /// Per-stage rows (records in/out, shuffle bytes, wall per stage).
    /// Single-pass jobs have exactly one; multi-stage pipelines report
    /// through [`ChainReport::stages`] instead.
    pub stages: Vec<StageStats>,
}

impl<O> JobReport<O> {
    pub fn records_per_sec(&self) -> f64 {
        self.records as f64 / self.wall_secs.max(1e-12)
    }

    pub fn summary(&self) -> String {
        format!(
            "{:<12} {:<16} {:>12} emissions in {:>8.3}s = {:>14}   shuffle={}",
            self.workload,
            self.engine.label(),
            self.records,
            self.wall_secs,
            fmt_rate(self.records_per_sec(), "recs"),
            fmt_bytes(self.shuffle_bytes),
        )
    }
}

/// The shared engine abstraction: anything that can execute one stage of
/// a compiled [`StageGraph`] against a [`JobSpec`] over the stage's
/// tagged input relations — the **single** plan-execution path of each
/// backend. Callers hold it as a trait object from
/// [`engine_for`]/[`engine_for_str`].
pub trait JobEngine<W: Workload>: Send + Sync {
    /// Execute stage `stage_id` of `graph`: map the stage's inputs with
    /// `w`, run (or elide) the exchange the plan decided, apply the
    /// per-shard finalize. Single-pass jobs are one-stage graphs.
    fn run_plan(
        &self,
        spec: &JobSpec,
        graph: &StageGraph,
        stage_id: usize,
        w: &Arc<W>,
        inputs: &JobInputs,
    ) -> Result<JobRun<W::Key, W::Value>, MapReduceError>;
}

/// Blaze backend (owned-key emissions).
struct BlazeExec {
    key_path: KeyPath,
}

impl<W: Workload> JobEngine<W> for BlazeExec {
    fn run_plan(
        &self,
        spec: &JobSpec,
        graph: &StageGraph,
        stage_id: usize,
        w: &Arc<W>,
        inputs: &JobInputs,
    ) -> Result<JobRun<W::Key, W::Value>, MapReduceError> {
        let conf = spec.blaze_conf(self.key_path);
        let rels = inputs.line_sets();
        let r = crate::engines::blaze::run_workload_multi(
            &conf,
            graph.stage(stage_id),
            &rels,
            &spec.failures,
            w.as_ref(),
        )
        .map_err(|e| MapReduceError(e.to_string()))?;
        Ok(blaze_job_run(r))
    }
}

/// Blaze backend through the zero-alloc borrowed-key path (single-input).
struct BlazeStrExec;

impl<W: StrWorkload> JobEngine<W> for BlazeStrExec {
    fn run_plan(
        &self,
        spec: &JobSpec,
        graph: &StageGraph,
        stage_id: usize,
        w: &Arc<W>,
        inputs: &JobInputs,
    ) -> Result<JobRun<String, W::Value>, MapReduceError> {
        let conf = spec.blaze_conf(KeyPath::ZeroAlloc);
        let lines = Arc::clone(&inputs.relations[0].lines);
        let r = crate::engines::blaze::run_workload_str_lines(
            &conf,
            graph.stage(stage_id),
            lines,
            &spec.failures,
            w.as_ref(),
        )
        .map_err(|e| MapReduceError(e.to_string()))?;
        Ok(blaze_job_run(r))
    }
}

fn blaze_job_run<K, V>(r: crate::engines::blaze::WorkloadReport<K, V>) -> JobRun<K, V> {
    JobRun {
        entries: r.entries,
        wall_secs: r.wall_secs,
        records: r.records,
        shuffle_bytes: r.shuffle_bytes,
        storage: r.storage,
        detail: MetricSet::new()
            .with_secs("map", r.map_secs)
            .with_secs("shuffle", r.shuffle_secs)
            .with_count("reruns", r.reruns as u64),
    }
}

/// Spark-sim backend (owned-key emissions; the UTF-16 string modeling only
/// applies to string-keyed workloads, via [`SparkStrExec`]).
struct SparkExec;

impl<W: Workload> JobEngine<W> for SparkExec {
    fn run_plan(
        &self,
        spec: &JobSpec,
        graph: &StageGraph,
        stage_id: usize,
        w: &Arc<W>,
        inputs: &JobInputs,
    ) -> Result<JobRun<W::Key, W::Value>, MapReduceError> {
        let ctx = spec.spark_context();
        let rels = inputs.line_sets();
        let sw = Stopwatch::start();
        let (entries, records) =
            crate::engines::spark::run_workload_multi(&ctx, graph.stage(stage_id), &rels, w)
                .map_err(|e| MapReduceError(e.to_string()))?;
        Ok(spark_job_run(&ctx, entries, records, sw.elapsed_secs()))
    }
}

/// Spark-sim backend honoring `jvm_strings` for string-keyed workloads
/// (single-input).
struct SparkStrExec;

impl<W: StrWorkload> JobEngine<W> for SparkStrExec {
    fn run_plan(
        &self,
        spec: &JobSpec,
        graph: &StageGraph,
        stage_id: usize,
        w: &Arc<W>,
        inputs: &JobInputs,
    ) -> Result<JobRun<String, W::Value>, MapReduceError> {
        let ctx = spec.spark_context();
        let stage = graph.stage(stage_id);
        let lines = Arc::clone(&inputs.relations[0].lines);
        let sw = Stopwatch::start();
        let result = if ctx.conf().jvm_strings {
            crate::engines::spark::run_workload_jvm(&ctx, stage, lines, w)
        } else {
            crate::engines::spark::run_workload_multi(&ctx, stage, std::slice::from_ref(&lines), w)
        };
        let (entries, records) = result.map_err(|e| MapReduceError(e.to_string()))?;
        Ok(spark_job_run(&ctx, entries, records, sw.elapsed_secs()))
    }
}

fn spark_job_run<K, V>(
    ctx: &SparkContext,
    entries: Vec<(K, V)>,
    records: u64,
    wall_secs: f64,
) -> JobRun<K, V> {
    use std::sync::atomic::Ordering::Relaxed;
    JobRun {
        entries,
        wall_secs,
        records,
        shuffle_bytes: ctx.metrics().shuffle_bytes_written.load(Relaxed),
        // Shuffle spill + persisted shuffle blocks + (for contexts that
        // own their cache) persist demotions — the context is per-job, so
        // the snapshot is the job's delta.
        storage: ctx.storage_stats(),
        detail: ctx.metrics().metric_set(),
    }
}

/// The engine trait object for an [`Engine`] choice (owned-key path).
/// `BlazeTcm` degrades to the alloc path here: without borrowed keys the
/// two Blaze variants are indistinguishable.
pub fn engine_for<W: Workload>(engine: Engine) -> Box<dyn JobEngine<W>> {
    match engine {
        Engine::Blaze => Box::new(BlazeExec { key_path: KeyPath::AllocPerToken }),
        Engine::BlazeTcm => Box::new(BlazeExec { key_path: KeyPath::ZeroAlloc }),
        Engine::Spark | Engine::SparkStripped => Box::new(SparkExec),
    }
}

/// The engine trait object for string-keyed workloads: `BlazeTcm` gets the
/// zero-alloc insert path, Spark gets the UTF-16 `JvmWord` pipeline when
/// its conf asks for it.
pub fn engine_for_str<W: StrWorkload>(engine: Engine) -> Box<dyn JobEngine<W>> {
    match engine {
        Engine::Blaze => Box::new(BlazeExec { key_path: KeyPath::AllocPerToken }),
        Engine::BlazeTcm => Box::new(BlazeStrExec),
        Engine::Spark | Engine::SparkStripped => Box::new(SparkStrExec),
    }
}

/// Single-threaded reference executor — the correctness oracle for every
/// engine × workload combination (single input relation; multi-input
/// workloads go through [`run_serial_inputs`]).
pub fn run_serial<W: Workload>(w: &W, corpus: &Corpus) -> W::Output {
    assert_eq!(
        w.num_relations(),
        1,
        "workload '{}' is multi-input; oracle it with run_serial_inputs",
        w.name()
    );
    let mut acc: HashMap<W::Key, W::Value> = HashMap::new();
    for (i, line) in corpus.lines.iter().enumerate() {
        serial_map(w, &mut acc, 0, i as u64, line);
    }
    w.finalize(w.finalize_local(acc.into_iter().collect()))
}

/// [`run_serial`] over N tagged relations — the oracle for multi-input
/// workloads (joins).
pub fn run_serial_inputs<W: Workload>(w: &W, inputs: &JobInputs) -> W::Output {
    assert_eq!(
        inputs.len(),
        w.num_relations(),
        "workload '{}' expects {} input relation(s)",
        w.name(),
        w.num_relations()
    );
    let mut acc: HashMap<W::Key, W::Value> = HashMap::new();
    for (rel, r) in inputs.relations.iter().enumerate() {
        for (i, line) in r.lines.iter().enumerate() {
            serial_map(w, &mut acc, rel, i as u64, line);
        }
    }
    w.finalize(w.finalize_local(acc.into_iter().collect()))
}

fn serial_map<W: Workload>(
    w: &W,
    acc: &mut HashMap<W::Key, W::Value>,
    rel: usize,
    doc: u64,
    line: &str,
) {
    w.map_rel(rel, doc, line, &mut |k, v| match acc.entry(k) {
        std::collections::hash_map::Entry::Occupied(mut e) => W::combine(e.get_mut(), v),
        std::collections::hash_map::Entry::Vacant(e) => {
            e.insert(v);
        }
    });
}
