//! The workload-generic MapReduce job layer.
//!
//! The paper demonstrates its claim on exactly one workload; this module
//! generalizes both engines to run *any* associative map/combine/shuffle/
//! reduce job. The pieces:
//!
//! * [`Workload`] — what a job computes: a per-record `map` that emits
//!   `(K, V)` pairs, an associative+commutative `combine`, an optional
//!   per-shard partial reduce (`finalize_local`, e.g. top-K heap
//!   selection), and a driver-side `finalize` into the output type.
//! * [`StrWorkload`] — string-keyed workloads that can also emit borrowed
//!   `&str` keys, unlocking the zero-alloc "TCM" insert path on Blaze and
//!   the UTF-16 `JvmWord` modeling on the Spark sim.
//! * [`JobSpec`] / [`JobReport`] — one engine-agnostic job description
//!   (cluster shape, network, combine mode, failure plan) and one uniform
//!   result (output + wall time + shuffle bytes + engine detail).
//! * [`JobEngine`] — the shared engine abstraction both backends implement;
//!   [`engine_for`]/[`engine_for_str`] hand back the right trait object for
//!   an [`Engine`] choice.
//! * [`run_serial`] — the single-threaded reference executor, the
//!   correctness oracle for every engine × workload combination.
//!
//! Concrete workloads live in [`crate::workloads`]; `wordcount::WordCountJob`
//! is a thin facade over this layer.
//!
//! # The `finalize_local` contract
//!
//! Engines apply `finalize_local` independently to each owned shard (a
//! node's key shard on Blaze, a reduce partition on Spark, the whole entry
//! set serially). It must therefore be a *filtering partial reduce*: for
//! any partition of the reduced entries into disjoint shards,
//! `finalize(concat(map(finalize_local, shards)))` must equal
//! `finalize(all entries)`. Identity (the default) and bounded top-K
//! selection both satisfy this; anything that mixes information across
//! keys it then discards does not.

use std::collections::HashMap;
use std::sync::Arc;

use crate::cluster::{FailurePlan, NetModel};
use crate::concurrent::{CachePolicy, MapKey, MapValue};
use crate::corpus::{Corpus, Tokenizer};
use crate::dist::CombineMode;
use crate::engines::blaze::{BlazeConf, KeyPath};
use crate::engines::spark::{HeapSize, SparkConf, SparkContext};
use crate::engines::Engine;
use crate::hash::HashKind;
use crate::util::ser::{Decode, Encode};
use crate::util::stats::{fmt_bytes, fmt_rate, Stopwatch};

/// Keys a generic job can shuffle: routable (`MapKey`), wire-encodable,
/// JVM-cost-modelable, hashable for Spark partitioning, and totally
/// ordered so finalizers can be deterministic.
pub trait JobKey:
    MapKey + Encode + Decode + HeapSize + std::hash::Hash + Ord + std::fmt::Debug + 'static
{
}
impl<T> JobKey for T where
    T: MapKey + Encode + Decode + HeapSize + std::hash::Hash + Ord + std::fmt::Debug + 'static
{
}

/// Values a generic job can shuffle.
pub trait JobValue: MapValue + Encode + Decode + HeapSize + std::fmt::Debug + 'static {}
impl<T> JobValue for T where T: MapValue + Encode + Decode + HeapSize + std::fmt::Debug + 'static {}

/// A MapReduce workload: how records become `(K, V)` emissions, how values
/// combine, and how reduced entries become the final output.
pub trait Workload: Send + Sync + 'static {
    type Key: JobKey;
    type Value: JobValue;
    type Output;

    /// Stable name (CLI `--workload` token, bench/report label).
    fn name(&self) -> &'static str;

    /// Map one record. `doc` is the record's global index (line number) —
    /// identity for workloads like inverted indexing.
    fn map(&self, doc: u64, record: &str, emit: &mut dyn FnMut(Self::Key, Self::Value));

    /// Fold `v` into `acc`. Must be associative and commutative; engines
    /// fold in thread, cache, and shuffle arrival order.
    fn combine(acc: &mut Self::Value, v: Self::Value);

    /// Optional per-shard partial reduce, applied by each engine to every
    /// owned shard independently (see the module docs for the contract).
    fn finalize_local(
        &self,
        shard: Vec<(Self::Key, Self::Value)>,
    ) -> Vec<(Self::Key, Self::Value)> {
        shard
    }

    /// Driver-side finalize over the concatenated shards.
    fn finalize(&self, entries: Vec<(Self::Key, Self::Value)>) -> Self::Output;
}

/// String-keyed workloads that can emit keys as borrowed `&str` slices of
/// the input record. Blaze uses this for the zero-alloc insert path (the
/// paper's "TCM" bar); the Spark sim uses it to route tokens through
/// UTF-16 [`crate::engines::spark::JvmWord`]s when `jvm_strings` is on.
pub trait StrWorkload: Workload<Key = String> {
    /// Must emit exactly what [`Workload::map`] emits, with keys borrowed.
    fn map_str(&self, doc: u64, record: &str, emit: &mut dyn FnMut(&str, Self::Value));
}

/// Error surfaced by the generic layer (wraps either engine's failure).
#[derive(Debug, Clone)]
pub struct MapReduceError(pub String);

impl std::fmt::Display for MapReduceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "mapreduce job failed: {}", self.0)
    }
}

impl std::error::Error for MapReduceError {}

/// Everything needed to run one job on one engine, minus the workload.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub engine: Engine,
    pub nnodes: usize,
    pub threads_per_node: usize,
    pub net: NetModel,
    /// Blaze: map-side combining mode (A3 ablation).
    pub combine: CombineMode,
    /// Blaze: hash function.
    pub hash: HashKind,
    /// Blaze: thread-cache policy of the distributed map.
    pub cache_policy: CachePolicy,
    /// Spark: override individual cost knobs after the engine presets.
    pub spark_overrides: Option<SparkConf>,
    /// Failure injection plan (consumed by whichever engine runs).
    pub failures: Arc<FailurePlan>,
    /// Blaze: whole-job reruns allowed on an injected node failure.
    pub max_job_reruns: usize,
}

impl JobSpec {
    pub fn new(engine: Engine) -> Self {
        Self {
            engine,
            nnodes: 1,
            threads_per_node: 4,
            net: NetModel::aws_like(),
            combine: CombineMode::Eager,
            hash: HashKind::Fx,
            cache_policy: CachePolicy::default(),
            spark_overrides: None,
            failures: Arc::new(FailurePlan::none()),
            max_job_reruns: 3,
        }
    }

    pub fn nodes(mut self, n: usize) -> Self {
        self.nnodes = n;
        self
    }

    pub fn threads_per_node(mut self, t: usize) -> Self {
        self.threads_per_node = t;
        self
    }

    pub fn net(mut self, net: NetModel) -> Self {
        self.net = net;
        self
    }

    pub fn combine(mut self, c: CombineMode) -> Self {
        self.combine = c;
        self
    }

    pub fn cache_policy(mut self, p: CachePolicy) -> Self {
        self.cache_policy = p;
        self
    }

    pub fn spark_conf(mut self, conf: SparkConf) -> Self {
        self.spark_overrides = Some(conf);
        self
    }

    pub fn failures(mut self, plan: FailurePlan) -> Self {
        self.failures = Arc::new(plan);
        self
    }

    /// Run `w` on this spec's engine (owned-key emission path everywhere).
    pub fn run<W: Workload>(
        &self,
        w: &Arc<W>,
        corpus: &Corpus,
    ) -> Result<JobReport<W::Output>, MapReduceError> {
        let run = engine_for::<W>(self.engine).run(self, w, corpus)?;
        Ok(self.finish(w, run))
    }

    /// Run a string-keyed workload with the engines' specialized string
    /// paths: zero-alloc inserts on Blaze TCM, UTF-16 `JvmWord` modeling
    /// on the faithful Spark sim.
    pub fn run_str<W: StrWorkload>(
        &self,
        w: &Arc<W>,
        corpus: &Corpus,
    ) -> Result<JobReport<W::Output>, MapReduceError> {
        let run = engine_for_str::<W>(self.engine).run(self, w, corpus)?;
        Ok(self.finish(w, run))
    }

    fn finish<W: Workload>(
        &self,
        w: &Arc<W>,
        run: JobRun<W::Key, W::Value>,
    ) -> JobReport<W::Output> {
        JobReport {
            engine: self.engine,
            workload: w.name(),
            output: w.finalize(run.entries),
            wall_secs: run.wall_secs,
            records: run.records,
            shuffle_bytes: run.shuffle_bytes,
            detail: run.detail,
        }
    }

    pub(crate) fn blaze_conf(&self, key_path: KeyPath) -> BlazeConf {
        BlazeConf {
            nnodes: self.nnodes,
            threads_per_node: self.threads_per_node,
            net: self.net,
            combine: self.combine,
            hash: self.hash,
            // Unused by the generic runners: tokenization happens inside
            // `Workload::map` (the facade's word-count path builds its
            // workload from its own conf).
            tokenizer: Tokenizer::Spaces,
            key_path,
            cache_policy: self.cache_policy,
            max_job_reruns: self.max_job_reruns,
        }
    }

    pub(crate) fn spark_context(&self) -> SparkContext {
        let conf = self.spark_overrides.clone().unwrap_or_else(|| {
            let mut c = if self.engine == Engine::SparkStripped {
                SparkConf::stripped(self.nnodes, self.threads_per_node)
            } else {
                SparkConf::emr_like(self.nnodes, self.threads_per_node)
            };
            c.net = self.net;
            c
        });
        SparkContext::with_failures_arc(conf, Arc::clone(&self.failures))
    }
}

/// Raw engine outcome before the driver-side finalize: the concatenated
/// per-shard (already `finalize_local`-ed) entries plus run metrics.
#[derive(Debug)]
pub struct JobRun<K, V> {
    pub entries: Vec<(K, V)>,
    pub wall_secs: f64,
    /// Map-phase emissions observed (may exceed the steady-state count
    /// when failure injection forces reruns/retries).
    pub records: u64,
    pub shuffle_bytes: u64,
    pub detail: String,
}

/// Uniform result of one job on one engine.
#[derive(Debug)]
pub struct JobReport<O> {
    pub engine: Engine,
    pub workload: &'static str,
    pub output: O,
    pub wall_secs: f64,
    /// Map-phase emissions.
    pub records: u64,
    pub shuffle_bytes: u64,
    /// Engine-specific metric breakdown.
    pub detail: String,
}

impl<O> JobReport<O> {
    pub fn records_per_sec(&self) -> f64 {
        self.records as f64 / self.wall_secs.max(1e-12)
    }

    pub fn summary(&self) -> String {
        format!(
            "{:<12} {:<16} {:>12} emissions in {:>8.3}s = {:>14}   shuffle={}",
            self.workload,
            self.engine.label(),
            self.records,
            self.wall_secs,
            fmt_rate(self.records_per_sec(), "recs"),
            fmt_bytes(self.shuffle_bytes),
        )
    }
}

/// The shared engine abstraction: anything that can execute a [`Workload`]
/// against a [`JobSpec`]. Both backends implement it; callers hold it as a
/// trait object from [`engine_for`]/[`engine_for_str`].
pub trait JobEngine<W: Workload>: Send + Sync {
    fn run(
        &self,
        spec: &JobSpec,
        w: &Arc<W>,
        corpus: &Corpus,
    ) -> Result<JobRun<W::Key, W::Value>, MapReduceError>;
}

/// Blaze backend (owned-key emissions).
struct BlazeExec {
    key_path: KeyPath,
}

impl<W: Workload> JobEngine<W> for BlazeExec {
    fn run(
        &self,
        spec: &JobSpec,
        w: &Arc<W>,
        corpus: &Corpus,
    ) -> Result<JobRun<W::Key, W::Value>, MapReduceError> {
        let conf = spec.blaze_conf(self.key_path);
        let r = crate::engines::blaze::run_workload(&conf, corpus, &spec.failures, w.as_ref())
            .map_err(|e| MapReduceError(e.to_string()))?;
        Ok(blaze_job_run(r))
    }
}

/// Blaze backend through the zero-alloc borrowed-key path.
struct BlazeStrExec;

impl<W: StrWorkload> JobEngine<W> for BlazeStrExec {
    fn run(
        &self,
        spec: &JobSpec,
        w: &Arc<W>,
        corpus: &Corpus,
    ) -> Result<JobRun<String, W::Value>, MapReduceError> {
        let conf = spec.blaze_conf(KeyPath::ZeroAlloc);
        let r = crate::engines::blaze::run_workload_str(&conf, corpus, &spec.failures, w.as_ref())
            .map_err(|e| MapReduceError(e.to_string()))?;
        Ok(blaze_job_run(r))
    }
}

fn blaze_job_run<K, V>(r: crate::engines::blaze::WorkloadReport<K, V>) -> JobRun<K, V> {
    JobRun {
        entries: r.entries,
        wall_secs: r.wall_secs,
        records: r.records,
        shuffle_bytes: r.shuffle_bytes,
        detail: format!(
            "map={:.3}s shuffle={:.3}s reruns={}",
            r.map_secs, r.shuffle_secs, r.reruns
        ),
    }
}

/// Spark-sim backend (owned-key emissions; the UTF-16 string modeling only
/// applies to string-keyed workloads, via [`SparkStrExec`]).
struct SparkExec;

impl<W: Workload> JobEngine<W> for SparkExec {
    fn run(
        &self,
        spec: &JobSpec,
        w: &Arc<W>,
        corpus: &Corpus,
    ) -> Result<JobRun<W::Key, W::Value>, MapReduceError> {
        let ctx = spec.spark_context();
        let lines = Arc::new(corpus.lines.clone());
        let sw = Stopwatch::start();
        let (entries, records) = crate::engines::spark::run_workload(&ctx, lines, w)
            .map_err(|e| MapReduceError(e.to_string()))?;
        Ok(spark_job_run(&ctx, entries, records, sw.elapsed_secs()))
    }
}

/// Spark-sim backend honoring `jvm_strings` for string-keyed workloads.
struct SparkStrExec;

impl<W: StrWorkload> JobEngine<W> for SparkStrExec {
    fn run(
        &self,
        spec: &JobSpec,
        w: &Arc<W>,
        corpus: &Corpus,
    ) -> Result<JobRun<String, W::Value>, MapReduceError> {
        let ctx = spec.spark_context();
        let lines = Arc::new(corpus.lines.clone());
        let sw = Stopwatch::start();
        let result = if ctx.conf().jvm_strings {
            crate::engines::spark::run_workload_jvm(&ctx, lines, w)
        } else {
            crate::engines::spark::run_workload(&ctx, lines, w)
        };
        let (entries, records) = result.map_err(|e| MapReduceError(e.to_string()))?;
        Ok(spark_job_run(&ctx, entries, records, sw.elapsed_secs()))
    }
}

fn spark_job_run<K, V>(
    ctx: &SparkContext,
    entries: Vec<(K, V)>,
    records: u64,
    wall_secs: f64,
) -> JobRun<K, V> {
    use std::sync::atomic::Ordering::Relaxed;
    JobRun {
        entries,
        wall_secs,
        records,
        shuffle_bytes: ctx.metrics().shuffle_bytes_written.load(Relaxed),
        detail: ctx.metrics().summary(),
    }
}

/// The engine trait object for an [`Engine`] choice (owned-key path).
/// `BlazeTcm` degrades to the alloc path here: without borrowed keys the
/// two Blaze variants are indistinguishable.
pub fn engine_for<W: Workload>(engine: Engine) -> Box<dyn JobEngine<W>> {
    match engine {
        Engine::Blaze => Box::new(BlazeExec { key_path: KeyPath::AllocPerToken }),
        Engine::BlazeTcm => Box::new(BlazeExec { key_path: KeyPath::ZeroAlloc }),
        Engine::Spark | Engine::SparkStripped => Box::new(SparkExec),
    }
}

/// The engine trait object for string-keyed workloads: `BlazeTcm` gets the
/// zero-alloc insert path, Spark gets the UTF-16 `JvmWord` pipeline when
/// its conf asks for it.
pub fn engine_for_str<W: StrWorkload>(engine: Engine) -> Box<dyn JobEngine<W>> {
    match engine {
        Engine::Blaze => Box::new(BlazeExec { key_path: KeyPath::AllocPerToken }),
        Engine::BlazeTcm => Box::new(BlazeStrExec),
        Engine::Spark | Engine::SparkStripped => Box::new(SparkStrExec),
    }
}

/// Single-threaded reference executor — the correctness oracle for every
/// engine × workload combination.
pub fn run_serial<W: Workload>(w: &W, corpus: &Corpus) -> W::Output {
    let mut acc: HashMap<W::Key, W::Value> = HashMap::new();
    for (i, line) in corpus.lines.iter().enumerate() {
        w.map(i as u64, line, &mut |k, v| match acc.entry(k) {
            std::collections::hash_map::Entry::Occupied(mut e) => W::combine(e.get_mut(), v),
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert(v);
            }
        });
    }
    w.finalize(w.finalize_local(acc.into_iter().collect()))
}
