//! # Blaze — Spark vs MPI/OpenMP Word Count MapReduce, reproduced
//!
//! A production-shaped reproduction of Junhao Li's *"Comparing Spark vs
//! MPI/OpenMP On Word Count MapReduce"* (2018). The paper's MPI/OpenMP
//! MapReduce design — [`concurrent::ConcurrentHashMap`],
//! [`dist::DistHashMap`], [`dist::DistRange`] — is implemented natively in
//! Rust on a simulated multi-node cluster ([`cluster`]), and compared
//! against a Spark-style baseline engine ([`engines::spark`]) on the classic
//! word-count task ([`wordcount`]).
//!
//! ## The generic job layer
//!
//! The paper demonstrates its claim on one workload; this crate generalizes
//! it. [`mapreduce`] defines a [`mapreduce::Workload`] trait (per-record
//! map → `(K, V)` emissions — per tagged input relation for multi-input
//! jobs — associative combine, optional per-shard partial reduce) plus a
//! [`mapreduce::JobSpec`]/[`mapreduce::JobInputs`]/[`mapreduce::JobReport`]
//! triple that both engines execute behind a shared
//! [`mapreduce::JobEngine`] trait object. Every job is first **compiled**
//! by the planner layer ([`mapreduce::plan`]) into an explicit
//! [`mapreduce::StageGraph`] — stages separated by shuffle boundaries,
//! exchange elision and cache points decided at plan time
//! (`blaze plan --workload ...` prints the graph) — and the engines are
//! stage executors with a single plan-execution path each. Multi-stage
//! pipelines ([`mapreduce::ChainedWorkload`], e.g.
//! [`workloads::Sessionize`]) chain stages through rendered bridge
//! relations. [`workloads`] ships the job suite on top — word count,
//! inverted index, top-K words, a token-length histogram, a two-relation
//! inner join, a distinct-count sketch, a zero-shuffle grep, and the
//! multi-stage sessionizer — each runnable from the CLI
//! (`blaze run --workload ...`) on every engine and verified against
//! [`mapreduce::run_serial`]/[`mapreduce::run_serial_inputs`]/
//! [`mapreduce::run_chained_serial`]. The [`workloads`] module docs
//! double as the workload-authoring guide. [`wordcount::WordCountJob`]
//! remains the stable word-count facade, now a thin wrapper over the job
//! layer.
//!
//! ## Iterative jobs and the partition cache
//!
//! [`cache`] is the memory-budgeted, size-aware partition store (LRU
//! eviction, per-entry byte accounting, hit/miss/evict stats) that backs
//! Spark's headline feature — in-memory reuse — on both engines:
//! `Rdd::persist`/`cache()` on the Spark sim (with lineage recomputation
//! on eviction) and a parsed-input-split cache on Blaze.
//! [`mapreduce::run_iterative`] drives multi-round jobs
//! ([`mapreduce::IterativeWorkload`]): each round's reduced output feeds
//! back in as a tagged relation until convergence or an iteration cap.
//! [`workloads::PageRank`], [`workloads::KMeans`] and
//! [`workloads::Components`] (label-propagation connected components)
//! ride on it as plan-per-round loops, all verified against the serial
//! fixed-point oracle [`mapreduce::run_iterative_serial`].
//!
//! ## The storage hierarchy
//!
//! [`storage`] is the tier below all of that: a [`storage::BlockStore`]
//! abstraction with a checksummed [`storage::DiskTier`], the
//! [`storage::TieredStore`] that [`cache`]'s partition store now is
//! (entries demote to disk under memory pressure and promote back on
//! access), and the bounded-memory exchange
//! ([`storage::ExternalMerger`]): with a spill threshold set
//! ([`mapreduce::JobSpec::spill_threshold`], CLI `--spill-threshold`),
//! reduce shards past the budget sort-and-spill runs to disk and merge
//! back with a loser tree — bit-identical to the in-memory fold at any
//! budget. [`storage::StorageStats`] rides in every job report.
//!
//! The compute hot-spot additionally has an XLA/PJRT-accelerated path: a
//! Pallas token-histogram kernel AOT-lowered from JAX at build time and
//! executed from Rust through [`runtime`].
//!
//! ## Observability
//!
//! [`trace`] is the zero-dependency structured tracing + metrics layer:
//! process-global span probes (near-free when no [`trace::TraceSession`]
//! records) capture per-thread timelines of stage/map/exchange/spill/
//! cache events, [`trace::chrome`] exports them as Perfetto-loadable
//! Chrome trace JSON (`--trace-out`), and [`trace::profile`] folds them
//! into the per-stage phase breakdown behind `blaze profile`. The
//! executor counts per-worker busy/idle nanos, steals and task-latency
//! histograms unconditionally
//! ([`runtime::executor::ExecMetrics`] in every
//! [`mapreduce::JobReport`]), and report `detail` fields are typed
//! [`trace::MetricSet`]s rather than strings.
//!
//! ## The service layer
//!
//! [`service`] turns the single-job CLI into a multi-tenant job service:
//! [`service::JobService`] admits a stream of tenant-tagged
//! [`service::JobRequest`]s, schedules their stages under weighted fair
//! queueing over a bounded slot pool (stage-granular, so long iterative
//! jobs interleave with short scans), isolates tenants in the shared
//! [`storage::TieredStore`] via namespace ranges and per-tenant byte
//! quotas, and refuses work with a typed
//! [`service::AdmissionError`] when saturated. `blaze serve` replays
//! arrival traces through it; queue waits, admissions, and preemptions
//! are trace spans.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured results.

pub mod benchkit;
pub mod cache;
pub mod cluster;
pub mod concurrent;
pub mod corpus;
pub mod dist;
pub mod engines;
pub mod hash;
pub mod mapreduce;
pub mod metrics;
pub mod runtime;
pub mod service;
pub mod storage;
pub mod trace;
pub mod util;
pub mod wordcount;
pub mod workloads;
