//! Hash functions shared across the stack.
//!
//! Three string hashes (callers choose per workload) plus the multiplicative
//! integer hash that both the `DistHashMap` key-router (L3) and the Pallas
//! hashed-bucket kernel (L1) use — keeping the two layers' bucket assignment
//! identical so a rust-side shard and a kernel-side histogram agree.

/// The Fibonacci multiplier: 2^64 / φ, the classic multiplicative-hash
/// constant. Shared with `python/compile/kernels/hash_bucket.py`.
pub const GOLDEN_GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;

/// Multiplicative integer hash (Fibonacci hashing). Good avalanche on the
/// high bits; callers take the top bits for bucket indices.
#[inline]
pub fn mix_u64(x: u64) -> u64 {
    // splitmix64 finalizer — also what the L1 kernel mirrors in int32 space.
    let mut z = x.wrapping_mul(GOLDEN_GAMMA);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Map a hash to a bucket in `[0, n)` via the high-bits multiply trick
/// (no modulo in the hot path).
#[inline]
pub fn bucket_of(hash: u64, n: usize) -> usize {
    (((hash as u128) * (n as u128)) >> 64) as usize
}

/// The FNV-1a offset basis — the initial `state` for [`fnv1a_with`].
pub const FNV1A_OFFSET: u64 = 0xCBF2_9CE4_8422_2325;

/// FNV-1a, 64-bit: simple, decent for short ASCII words, byte-at-a-time.
#[inline]
pub fn fnv1a(bytes: &[u8]) -> u64 {
    fnv1a_with(FNV1A_OFFSET, bytes)
}

/// [`fnv1a`] continuing from `state` — the streaming form (folding
/// chunks sequentially equals one pass over their concatenation), which
/// the storage subsystem uses for block checksums.
#[inline]
pub fn fnv1a_with(state: u64, bytes: &[u8]) -> u64 {
    const PRIME: u64 = 0x0000_0100_0000_01B3;
    let mut h = state;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// FxHash-style word-at-a-time hash (rustc's hasher shape): reads 8 bytes
/// per round, rotate–xor–multiply. The default for the word-count hot path.
///
/// One deviation from stock fx: each round ends with `h ^= h >> 32`.
/// Stock fx only spreads entropy *upward* (multiply mod 2^64), so a
/// single-byte difference in a chunk's top byte stays confined to a
/// byte-wide window after rotation and can cancel against the next chunk's
/// low byte — on 50k `wordN` keys that produces ~1k full 64-bit collisions.
/// The downward xorshift costs <1 cycle/round and makes the output behave
/// like a random function again (see `few_collisions_fxhash`).
#[inline]
pub fn fxhash(bytes: &[u8]) -> u64 {
    const SEED: u64 = 0x51_7C_C1_B7_27_22_0A_95;
    let mut h: u64 = 0;
    let mut chunks = bytes.chunks_exact(8);
    for c in &mut chunks {
        let w = u64::from_le_bytes(c.try_into().unwrap());
        h = (h.rotate_left(5) ^ w).wrapping_mul(SEED);
        h ^= h >> 32;
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        let mut tail = [0u8; 8];
        tail[..rem.len()].copy_from_slice(rem);
        let w = u64::from_le_bytes(tail) | ((rem.len() as u64) << 56);
        h = (h.rotate_left(5) ^ w).wrapping_mul(SEED);
        h ^= h >> 32;
    }
    // Finalize: one full mix round for short keys that took a single round.
    mix_u64(h)
}

/// wyhash-flavoured hash: 64→128-bit multiply folding, strongest mixing of
/// the three, slightly more work per byte than fx for short keys.
#[inline]
pub fn wyhash(bytes: &[u8]) -> u64 {
    const K0: u64 = 0xA076_1D64_78BD_642F;
    const K1: u64 = 0xE703_7ED1_A0B4_28DB;
    #[inline]
    fn mum(a: u64, b: u64) -> u64 {
        let r = (a as u128).wrapping_mul(b as u128);
        (r as u64) ^ ((r >> 64) as u64)
    }
    let mut h = K0 ^ (bytes.len() as u64).wrapping_mul(K1);
    let mut chunks = bytes.chunks_exact(16);
    for c in &mut chunks {
        let a = u64::from_le_bytes(c[..8].try_into().unwrap());
        let b = u64::from_le_bytes(c[8..].try_into().unwrap());
        h = mum(a ^ h, b ^ K1);
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        let mut tail = [0u8; 16];
        tail[..rem.len()].copy_from_slice(rem);
        let a = u64::from_le_bytes(tail[..8].try_into().unwrap());
        let b = u64::from_le_bytes(tail[8..].try_into().unwrap());
        h = mum(a ^ h, b ^ K1 ^ rem.len() as u64);
    }
    mum(h, K0)
}

/// Which string hash an engine uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HashKind {
    Fx,
    Fnv1a,
    Wy,
}

impl HashKind {
    #[inline]
    pub fn hash(self, bytes: &[u8]) -> u64 {
        match self {
            HashKind::Fx => fxhash(bytes),
            HashKind::Fnv1a => fnv1a(bytes),
            HashKind::Wy => wyhash(bytes),
        }
    }

    pub fn parse(s: &str) -> Option<HashKind> {
        match s {
            "fx" => Some(HashKind::Fx),
            "fnv" | "fnv1a" => Some(HashKind::Fnv1a),
            "wy" | "wyhash" => Some(HashKind::Wy),
            _ => None,
        }
    }
}

impl Default for HashKind {
    fn default() -> Self {
        HashKind::Fx
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    const HASHES: [fn(&[u8]) -> u64; 3] = [fnv1a, fxhash, wyhash];

    #[test]
    fn deterministic() {
        for h in HASHES {
            assert_eq!(h(b"hello"), h(b"hello"));
            assert_ne!(h(b"hello"), h(b"hellp"));
            assert_ne!(h(b""), h(b"\0"));
        }
    }

    #[test]
    fn length_extension_distinct() {
        // "ab" + "" vs "a" + "b" style collisions on the tail path.
        for h in HASHES {
            assert_ne!(h(b"ab"), h(b"a"));
            assert_ne!(h(b"abcdefgh"), h(b"abcdefg"));
            assert_ne!(h(b"abcdefghi"), h(b"abcdefgh"));
        }
    }

    fn count_collisions(h: fn(&[u8]) -> u64) -> usize {
        // 50k distinct short words should have no more than a handful of
        // 64-bit collisions (expected ~0).
        let mut seen = HashSet::new();
        let mut collisions = 0;
        for i in 0..50_000 {
            let w = format!("word{i}");
            if !seen.insert(h(w.as_bytes())) {
                collisions += 1;
            }
        }
        collisions
    }

    #[test]
    fn few_collisions_fnv1a() {
        assert!(count_collisions(fnv1a) <= 1, "fnv1a: {}", count_collisions(fnv1a));
    }

    #[test]
    fn few_collisions_fxhash() {
        assert!(count_collisions(fxhash) <= 1, "fxhash: {}", count_collisions(fxhash));
    }

    #[test]
    fn few_collisions_wyhash() {
        assert!(count_collisions(wyhash) <= 1, "wyhash: {}", count_collisions(wyhash));
    }

    #[test]
    fn bucket_of_uniform_enough() {
        // Top-bit bucketing over mixed hashes: each of 16 buckets gets
        // within 3x of the mean on 16k keys.
        let n = 16;
        let mut counts = vec![0usize; n];
        for i in 0..16_384u64 {
            counts[bucket_of(mix_u64(i), n)] += 1;
        }
        let mean = 16_384 / n;
        for (b, &c) in counts.iter().enumerate() {
            assert!(c > mean / 3 && c < mean * 3, "bucket {b} count {c} vs mean {mean}");
        }
    }

    #[test]
    fn bucket_of_in_range() {
        for i in 0..1000u64 {
            let h = mix_u64(i);
            for n in [1usize, 2, 3, 7, 16, 1000] {
                assert!(bucket_of(h, n) < n);
            }
        }
    }

    #[test]
    fn mix_u64_bijective_sample() {
        // splitmix64 finalizer is a bijection; sample-check distinctness.
        let mut seen = HashSet::new();
        for i in 0..10_000u64 {
            assert!(seen.insert(mix_u64(i)));
        }
    }

    #[test]
    fn hashkind_parse() {
        assert_eq!(HashKind::parse("fx"), Some(HashKind::Fx));
        assert_eq!(HashKind::parse("fnv1a"), Some(HashKind::Fnv1a));
        assert_eq!(HashKind::parse("wyhash"), Some(HashKind::Wy));
        assert_eq!(HashKind::parse("md5"), None);
    }
}
