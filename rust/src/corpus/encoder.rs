//! Word → token-id encoding for the XLA-accelerated combiner path.
//!
//! The Pallas histogram kernel (L1) counts **integer token ids**, not
//! strings; [`Vocab`] provides the bidirectional mapping. Out-of-vocabulary
//! words map to the reserved [`Vocab::UNK`] id 0, so the id space is
//! `[0, len())` and histogram slot 0 aggregates all OOV mass.

use std::collections::HashMap;

pub struct Vocab {
    word_to_id: HashMap<String, i32>,
    id_to_word: Vec<String>,
}

impl Vocab {
    /// Reserved id for out-of-vocabulary words.
    pub const UNK: i32 = 0;

    /// Build from a word list; ids are assigned in order starting at 1
    /// (0 is UNK). Duplicates are ignored.
    pub fn build(words: impl IntoIterator<Item = String>) -> Self {
        let mut word_to_id = HashMap::new();
        let mut id_to_word = vec!["<unk>".to_string()];
        for w in words {
            if !word_to_id.contains_key(&w) {
                let id = id_to_word.len() as i32;
                word_to_id.insert(w.clone(), id);
                id_to_word.push(w);
            }
        }
        Self { word_to_id, id_to_word }
    }

    /// Build from a corpus' lines (first-seen order).
    pub fn from_lines<'a>(lines: impl IntoIterator<Item = &'a String>) -> Self {
        let mut words = Vec::new();
        let mut seen = HashMap::new();
        for line in lines {
            for w in crate::corpus::tokenizer::split_spaces(line) {
                if seen.insert(w.to_string(), ()).is_none() {
                    words.push(w.to_string());
                }
            }
        }
        Self::build(words)
    }

    /// Number of ids (including UNK).
    pub fn len(&self) -> usize {
        self.id_to_word.len()
    }

    pub fn is_empty(&self) -> bool {
        self.id_to_word.len() <= 1
    }

    pub fn id_of(&self, word: &str) -> i32 {
        self.word_to_id.get(word).copied().unwrap_or(Self::UNK)
    }

    pub fn word_of(&self, id: i32) -> &str {
        &self.id_to_word[id as usize]
    }

    /// Encode a line into token ids, appending to `out`.
    pub fn encode_line_into(&self, line: &str, out: &mut Vec<i32>) {
        for w in crate::corpus::tokenizer::split_spaces(line) {
            out.push(self.id_of(w));
        }
    }

    /// Encode many lines into one flat id buffer.
    pub fn encode_lines(&self, lines: &[String]) -> Vec<i32> {
        let mut out = Vec::new();
        for l in lines {
            self.encode_line_into(l, &mut out);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_assigns_dense_ids() {
        let v = Vocab::build(["the".into(), "cat".into(), "the".into(), "sat".into()]);
        assert_eq!(v.len(), 4); // unk + 3
        assert_eq!(v.id_of("the"), 1);
        assert_eq!(v.id_of("cat"), 2);
        assert_eq!(v.id_of("sat"), 3);
        assert_eq!(v.word_of(2), "cat");
    }

    #[test]
    fn oov_maps_to_unk() {
        let v = Vocab::build(["a".into()]);
        assert_eq!(v.id_of("zebra"), Vocab::UNK);
        assert_eq!(v.word_of(Vocab::UNK), "<unk>");
    }

    #[test]
    fn encode_lines_flat() {
        let v = Vocab::build(["a".into(), "b".into()]);
        let lines = vec!["a b".to_string(), "b zebra a".to_string()];
        let ids = v.encode_lines(&lines);
        assert_eq!(ids, vec![1, 2, 2, 0, 1]);
    }

    #[test]
    fn from_lines_covers_corpus() {
        let lines = vec!["x y".to_string(), "y z".to_string()];
        let v = Vocab::from_lines(&lines);
        assert_eq!(v.len(), 4);
        let ids = v.encode_lines(&lines);
        assert!(ids.iter().all(|&i| i != Vocab::UNK));
    }

    #[test]
    fn roundtrip_id_word() {
        let lines = vec!["alpha beta gamma".to_string()];
        let v = Vocab::from_lines(&lines);
        for w in ["alpha", "beta", "gamma"] {
            assert_eq!(v.word_of(v.id_of(w)), w);
        }
    }
}
