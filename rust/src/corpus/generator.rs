//! Corpus synthesis to a target byte size.
//!
//! Reproduces the paper's input shape ("Bible and Shakespeare's works,
//! repeated about 200 times to make it roughly 2 GB"): a base block of
//! Zipf-sampled lines is generated once and then **tiled** to the target
//! size, so key statistics are stationary and generation cost stays small
//! even for GB-scale corpora. `unique_block` mode skips tiling for
//! experiments that need an untiled stream.

use super::zipf::ZipfVocab;
use crate::util::rng::Xoshiro256;

#[derive(Clone, Debug)]
pub struct CorpusSpec {
    /// Target total size in bytes (approximate; whole lines only).
    pub target_bytes: u64,
    /// Distinct-word budget of the vocabulary.
    pub vocab_size: usize,
    /// Zipf exponent.
    pub exponent: f64,
    /// Words per line are sampled uniformly in this range.
    pub words_per_line: (usize, usize),
    /// Size of the freshly-generated base block that gets tiled. The paper
    /// repeats its source ~200x; we default to 1/200 of the target
    /// (clamped to [64 KiB, 16 MiB]).
    pub base_block_bytes: Option<u64>,
    /// RNG seed.
    pub seed: u64,
}

impl Default for CorpusSpec {
    fn default() -> Self {
        Self {
            target_bytes: 64 << 20,
            vocab_size: 30_000,
            exponent: 1.07,
            words_per_line: (5, 15),
            base_block_bytes: None,
            seed: 0xC0FFEE,
        }
    }
}

impl CorpusSpec {
    pub fn with_bytes(target_bytes: u64) -> Self {
        Self { target_bytes, ..Default::default() }
    }

    fn resolved_base_block(&self) -> u64 {
        self.base_block_bytes.unwrap_or_else(|| {
            (self.target_bytes / 200).clamp(64 << 10, 16 << 20).min(self.target_bytes.max(1))
        })
    }
}

/// An in-memory corpus: lines of space-separated words.
#[derive(Clone, Debug)]
pub struct Corpus {
    pub lines: Vec<String>,
    pub bytes: u64,
    pub words: u64,
}

impl Corpus {
    /// Generate per `spec`.
    pub fn generate(spec: &CorpusSpec) -> Corpus {
        let vocab = ZipfVocab::from_seed(
            &super::seed::combined(),
            spec.vocab_size,
            spec.exponent,
        );
        let mut rng = Xoshiro256::new(spec.seed);
        let base_budget = spec.resolved_base_block();

        // Generate the base block.
        let mut base_lines: Vec<String> = Vec::new();
        let mut base_bytes = 0u64;
        let mut base_words = 0u64;
        let (wmin, wmax) = spec.words_per_line;
        while base_bytes < base_budget {
            let nwords = rng.index(wmax - wmin + 1) + wmin;
            let mut line = String::with_capacity(nwords * 7);
            for w in 0..nwords {
                if w > 0 {
                    line.push(' ');
                }
                line.push_str(vocab.sample(&mut rng));
            }
            base_bytes += line.len() as u64 + 1; // +1 for the newline
            base_words += nwords as u64;
            base_lines.push(line);
        }

        // Tile to target.
        let mut lines = Vec::new();
        let mut bytes = 0u64;
        let mut words = 0u64;
        'outer: loop {
            for l in &base_lines {
                if bytes >= spec.target_bytes {
                    break 'outer;
                }
                bytes += l.len() as u64 + 1;
                words += l.split(' ').count() as u64;
                lines.push(l.clone());
            }
            if base_lines.is_empty() {
                break;
            }
        }
        let _ = base_words;
        Corpus { lines, bytes, words }
    }

    /// Generate with *no tiling* — every line fresh (slower; used by tests
    /// that need all-distinct streams).
    pub fn generate_unique(spec: &CorpusSpec) -> Corpus {
        let mut s = spec.clone();
        s.base_block_bytes = Some(spec.target_bytes);
        Self::generate(&s)
    }

    /// Total line count.
    pub fn num_lines(&self) -> usize {
        self.lines.len()
    }

    /// Concatenate into one newline-joined string (for file export).
    pub fn to_text(&self) -> String {
        let mut s = String::with_capacity(self.bytes as usize);
        for l in &self.lines {
            s.push_str(l);
            s.push('\n');
        }
        s
    }

    /// Load from a newline-separated text blob.
    pub fn from_text(text: &str) -> Corpus {
        let lines: Vec<String> = text.lines().map(str::to_owned).collect();
        let bytes = lines.iter().map(|l| l.len() as u64 + 1).sum();
        let words = lines.iter().map(|l| l.split(' ').filter(|w| !w.is_empty()).count() as u64).sum();
        Corpus { lines, bytes, words }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn generates_close_to_target_size() {
        let spec = CorpusSpec::with_bytes(1 << 20);
        let c = Corpus::generate(&spec);
        let actual: u64 = c.lines.iter().map(|l| l.len() as u64 + 1).sum();
        assert_eq!(actual, c.bytes);
        assert!(c.bytes >= 1 << 20, "undershot: {}", c.bytes);
        assert!(c.bytes < (1 << 20) + 200, "overshot by a lot: {}", c.bytes);
        assert!(c.words > 50_000);
    }

    #[test]
    fn tiled_corpus_repeats_lines() {
        let spec = CorpusSpec {
            target_bytes: 1 << 20,
            base_block_bytes: Some(64 << 10),
            ..Default::default()
        };
        let c = Corpus::generate(&spec);
        // ~16 repeats of the base block: the first line appears many times.
        let first = &c.lines[0];
        let occurrences = c.lines.iter().filter(|l| l == &first).count();
        assert!(occurrences >= 8, "expected tiling, got {occurrences} copies");
    }

    #[test]
    fn unique_corpus_mostly_distinct_lines() {
        let spec = CorpusSpec {
            target_bytes: 256 << 10,
            ..Default::default()
        };
        let c = Corpus::generate_unique(&spec);
        let distinct: std::collections::HashSet<&String> = c.lines.iter().collect();
        assert!(
            distinct.len() * 10 >= c.lines.len() * 9,
            "too many dup lines: {}/{}",
            distinct.len(),
            c.lines.len()
        );
    }

    #[test]
    fn deterministic_generation() {
        let spec = CorpusSpec::with_bytes(128 << 10);
        let a = Corpus::generate(&spec);
        let b = Corpus::generate(&spec);
        assert_eq!(a.lines, b.lines);
        let mut spec2 = spec.clone();
        spec2.seed = 999;
        let c = Corpus::generate(&spec2);
        assert_ne!(a.lines, c.lines);
    }

    #[test]
    fn word_frequencies_are_zipfy() {
        let c = Corpus::generate(&CorpusSpec::with_bytes(512 << 10));
        let mut freq: HashMap<&str, u64> = HashMap::new();
        for line in &c.lines {
            for w in line.split(' ') {
                *freq.entry(w).or_insert(0) += 1;
            }
        }
        let mut counts: Vec<u64> = freq.values().copied().collect();
        counts.sort_unstable_by(|a, b| b.cmp(a));
        // Head dominance: top word ≫ 100th word.
        assert!(counts[0] > counts.get(100).copied().unwrap_or(0) * 10);
        // Realistic distinct-word count for the size.
        assert!(freq.len() > 1_000, "distinct words: {}", freq.len());
    }

    #[test]
    fn text_roundtrip() {
        let c = Corpus::generate(&CorpusSpec::with_bytes(32 << 10));
        let text = c.to_text();
        let back = Corpus::from_text(&text);
        assert_eq!(c.lines, back.lines);
        assert_eq!(c.bytes, back.bytes);
    }

    #[test]
    fn words_per_line_respected() {
        let spec = CorpusSpec {
            target_bytes: 64 << 10,
            words_per_line: (3, 7),
            ..Default::default()
        };
        let c = Corpus::generate(&spec);
        for l in &c.lines {
            let n = l.split(' ').count();
            assert!((3..=7).contains(&n), "line with {n} words");
        }
    }
}
