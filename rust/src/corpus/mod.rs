//! Workload substrate: synthesis and tokenization of the word-count corpus.
//!
//! Reproduces the paper's input (Bible+Shakespeare repeated to a target
//! size) with a Zipf-sampled generator seeded from embedded public-domain
//! excerpts. See DESIGN.md §2 for the substitution argument.

pub mod encoder;
pub mod generator;
pub mod seed;
pub mod tokenizer;
pub mod zipf;

pub use encoder::Vocab;
pub use generator::{Corpus, CorpusSpec};
pub use tokenizer::{split_normalized, split_spaces, Tokenizer};
pub use zipf::ZipfVocab;
