//! Zipfian vocabulary and word sampler.
//!
//! English word frequency famously follows Zipf's law with exponent s ≈ 1:
//! the Bible+Shakespeare mixture the paper uses has ~30k distinct words with
//! "the"/"and"/"of" dominating. [`ZipfVocab`] reproduces that profile: ranks
//! come from the embedded seed text (most-frequent first), padded with
//! synthetic rare words up to the requested vocabulary size, and sampling is
//! inverse-CDF (binary search over the cumulative weights).

use crate::util::rng::Xoshiro256;
use std::collections::HashMap;

pub struct ZipfVocab {
    words: Vec<String>,
    /// Cumulative probability per rank, cum[i] = P(rank <= i).
    cum: Vec<f64>,
    exponent: f64,
}

impl ZipfVocab {
    /// Build from seed text: words ranked by observed frequency, then padded
    /// with `wNNNN` synthetic words to `vocab_size`, weighted 1/rank^s.
    pub fn from_seed(seed_text: &str, vocab_size: usize, exponent: f64) -> Self {
        assert!(vocab_size > 0);
        let mut freq: HashMap<&str, u64> = HashMap::new();
        for w in seed_text.split_whitespace() {
            *freq.entry(w).or_insert(0) += 1;
        }
        let mut ranked: Vec<(&str, u64)> = freq.into_iter().collect();
        // Stable rank order: frequency desc, then alphabetical for ties.
        ranked.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(b.0)));
        let mut words: Vec<String> = ranked
            .into_iter()
            .take(vocab_size)
            .map(|(w, _)| w.to_string())
            .collect();
        let mut pad = 0usize;
        while words.len() < vocab_size {
            words.push(format!("w{pad:05}"));
            pad += 1;
        }
        // Zipf weights over the final rank order.
        let mut cum = Vec::with_capacity(words.len());
        let mut total = 0.0f64;
        for i in 0..words.len() {
            total += 1.0 / ((i + 1) as f64).powf(exponent);
            cum.push(total);
        }
        for c in &mut cum {
            *c /= total;
        }
        Self { words, cum, exponent }
    }

    /// Default profile: seed = KJV+Shakespeare excerpts, 30k vocab, s=1.07
    /// (the classic fit for English).
    pub fn english_like(vocab_size: usize) -> Self {
        Self::from_seed(&super::seed::combined(), vocab_size, 1.07)
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    pub fn exponent(&self) -> f64 {
        self.exponent
    }

    pub fn word(&self, rank: usize) -> &str {
        &self.words[rank]
    }

    pub fn words(&self) -> &[String] {
        &self.words
    }

    /// Sample a rank by inverse CDF.
    #[inline]
    pub fn sample_rank(&self, rng: &mut Xoshiro256) -> usize {
        let u = rng.next_f64();
        // partition_point: first index with cum[i] >= u.
        self.cum.partition_point(|&c| c < u).min(self.words.len() - 1)
    }

    /// Sample a word.
    #[inline]
    pub fn sample<'a>(&'a self, rng: &mut Xoshiro256) -> &'a str {
        self.word(self.sample_rank(rng))
    }

    /// Expected probability of the given rank (for tests/analysis).
    pub fn prob(&self, rank: usize) -> f64 {
        let lo = if rank == 0 { 0.0 } else { self.cum[rank - 1] };
        self.cum[rank] - lo
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_ranks_put_the_first() {
        let v = ZipfVocab::english_like(1000);
        // "the" and "and" dominate the seed excerpts.
        assert!(v.word(0) == "the" || v.word(0) == "and", "rank0 = {}", v.word(0));
        assert_eq!(v.len(), 1000);
    }

    #[test]
    fn padding_fills_vocab() {
        let v = ZipfVocab::from_seed("alpha beta alpha", 10, 1.0);
        assert_eq!(v.len(), 10);
        assert_eq!(v.word(0), "alpha");
        assert!(v.word(5).starts_with('w'), "synthetic pad: {}", v.word(5));
        // All distinct.
        let set: std::collections::HashSet<&str> =
            (0..10).map(|i| v.word(i)).collect();
        assert_eq!(set.len(), 10);
    }

    #[test]
    fn sampling_follows_zipf_shape() {
        let v = ZipfVocab::english_like(5000);
        let mut rng = Xoshiro256::new(1234);
        let mut counts = vec![0u64; v.len()];
        let n = 200_000;
        for _ in 0..n {
            counts[v.sample_rank(&mut rng)] += 1;
        }
        // Rank 0 should be ~ p0 * n; check within 15%.
        let expect0 = v.prob(0) * n as f64;
        assert!(
            (counts[0] as f64 - expect0).abs() < expect0 * 0.15,
            "rank0 count {} vs expected {expect0}",
            counts[0]
        );
        // Monotone-ish decay: top rank beats rank 10 beats rank 100.
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[100]);
        // Tail gets sampled at least occasionally.
        let tail: u64 = counts[1000..].iter().sum();
        assert!(tail > 0, "tail never sampled");
    }

    #[test]
    fn probs_sum_to_one() {
        let v = ZipfVocab::english_like(100);
        let total: f64 = (0..v.len()).map(|r| v.prob(r)).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn deterministic_sampling() {
        let v = ZipfVocab::english_like(1000);
        let mut a = Xoshiro256::new(7);
        let mut b = Xoshiro256::new(7);
        for _ in 0..100 {
            assert_eq!(v.sample_rank(&mut a), v.sample_rank(&mut b));
        }
    }
}
