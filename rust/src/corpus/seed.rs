//! Seed text for corpus synthesis.
//!
//! The paper's input is "the Bible and Shakespeare's works, repeated about
//! 200 times to make it roughly 2 GB". Both sources are public domain; we
//! embed representative excerpts (KJV Genesis/Psalms, Hamlet, Sonnet 18)
//! whose word-frequency profile seeds the Zipf vocabulary, and the
//! generator repeats/extends them to the requested size — the same
//! "stationary repeated corpus" shape the paper used.

/// King James Version excerpts (public domain).
pub const KJV_EXCERPT: &str = "\
in the beginning god created the heaven and the earth
and the earth was without form and void and darkness was upon the face of the deep
and the spirit of god moved upon the face of the waters
and god said let there be light and there was light
and god saw the light that it was good and god divided the light from the darkness
and god called the light day and the darkness he called night
and the evening and the morning were the first day
and god said let there be a firmament in the midst of the waters
and let it divide the waters from the waters
and god made the firmament and divided the waters which were under the firmament
from the waters which were above the firmament and it was so
and god called the firmament heaven and the evening and the morning were the second day
the lord is my shepherd i shall not want
he maketh me to lie down in green pastures he leadeth me beside the still waters
he restoreth my soul he leadeth me in the paths of righteousness for his name sake
yea though i walk through the valley of the shadow of death i will fear no evil
for thou art with me thy rod and thy staff they comfort me
thou preparest a table before me in the presence of mine enemies
thou anointest my head with oil my cup runneth over
surely goodness and mercy shall follow me all the days of my life
and i will dwell in the house of the lord for ever
";

/// Shakespeare excerpts (public domain): Hamlet III.i and Sonnet 18.
pub const SHAKESPEARE_EXCERPT: &str = "\
to be or not to be that is the question
whether tis nobler in the mind to suffer
the slings and arrows of outrageous fortune
or to take arms against a sea of troubles
and by opposing end them to die to sleep
no more and by a sleep to say we end
the heartache and the thousand natural shocks
that flesh is heir to tis a consummation
devoutly to be wished to die to sleep
to sleep perchance to dream ay there is the rub
for in that sleep of death what dreams may come
when we have shuffled off this mortal coil
must give us pause there is the respect
that makes calamity of so long life
shall i compare thee to a summers day
thou art more lovely and more temperate
rough winds do shake the darling buds of may
and summers lease hath all too short a date
sometime too hot the eye of heaven shines
and often is his gold complexion dimmed
and every fair from fair sometime declines
by chance or natures changing course untrimmed
but thy eternal summer shall not fade
nor lose possession of that fair thou owest
nor shall death brag thou wanderest in his shade
when in eternal lines to time thou growest
so long as men can breathe or eyes can see
so long lives this and this gives life to thee
";

/// Both excerpts concatenated — the default seed block.
pub fn combined() -> String {
    format!("{KJV_EXCERPT}{SHAKESPEARE_EXCERPT}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeds_are_nonempty_lowercase_space_separated() {
        for text in [KJV_EXCERPT, SHAKESPEARE_EXCERPT] {
            assert!(!text.is_empty());
            for line in text.lines() {
                assert!(!line.is_empty());
                for w in line.split(' ') {
                    assert!(!w.is_empty(), "double space in seed line: {line:?}");
                    assert!(
                        w.bytes().all(|b| b.is_ascii_lowercase()),
                        "non-lowercase token {w:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn combined_has_both() {
        let c = combined();
        assert!(c.contains("beginning"));
        assert!(c.contains("perchance"));
    }

    #[test]
    fn seed_vocabulary_is_reasonably_rich() {
        use std::collections::HashSet;
        let c = combined();
        let vocab: HashSet<&str> = c.split_whitespace().collect();
        assert!(vocab.len() > 150, "vocab {} too small", vocab.len());
    }
}
