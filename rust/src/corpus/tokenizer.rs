//! Tokenization.
//!
//! The paper's mapper splits lines on single spaces
//! (`std::getline(ss, word, ' ')`). [`split_spaces`] reproduces that
//! (skipping the empty tokens consecutive delimiters would produce);
//! [`split_normalized`] is the "real-world" variant (lowercase +
//! alphanumeric runs) offered by the engines behind a flag.
//!
//! The zero-copy iterator forms are the map-phase hot path: no allocation
//! per token, just subslices of the line.

/// Paper-faithful: split on ASCII space, skip empties.
#[inline]
pub fn split_spaces(line: &str) -> impl Iterator<Item = &str> {
    line.split(' ').filter(|w| !w.is_empty())
}

/// Lowercasing, punctuation-stripping tokenizer: maximal runs of ASCII
/// alphanumerics; uppercase mapped to lowercase. Allocates only for tokens
/// containing uppercase letters.
pub fn split_normalized(line: &str) -> Vec<std::borrow::Cow<'_, str>> {
    use std::borrow::Cow;
    let bytes = line.as_bytes();
    let mut out = Vec::new();
    let mut start = None;
    let mut needs_lower = false;
    for (i, &b) in bytes.iter().enumerate() {
        if b.is_ascii_alphanumeric() {
            if start.is_none() {
                start = Some(i);
                needs_lower = false;
            }
            needs_lower |= b.is_ascii_uppercase();
        } else if let Some(s) = start.take() {
            out.push(make_token(&line[s..i], needs_lower));
        }
    }
    if let Some(s) = start {
        out.push(make_token(&line[s..], needs_lower));
    }
    return out;

    fn make_token(s: &str, needs_lower: bool) -> Cow<'_, str> {
        if needs_lower {
            Cow::Owned(s.to_ascii_lowercase())
        } else {
            Cow::Borrowed(s)
        }
    }
}

/// Tokenizer selection for engine configs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tokenizer {
    /// Paper-faithful single-space split.
    Spaces,
    /// Lowercased alphanumeric runs.
    Normalized,
}

impl Tokenizer {
    pub fn parse(s: &str) -> Option<Tokenizer> {
        match s {
            "spaces" | "paper" => Some(Tokenizer::Spaces),
            "normalized" | "norm" => Some(Tokenizer::Normalized),
            _ => None,
        }
    }

    /// Count words in a line without materializing tokens (for stats).
    pub fn count_words(self, line: &str) -> usize {
        match self {
            Tokenizer::Spaces => split_spaces(line).count(),
            Tokenizer::Normalized => split_normalized(line).len(),
        }
    }

    /// Visit each token of `line`.
    pub fn for_each_token(self, line: &str, mut f: impl FnMut(&str)) {
        match self {
            Tokenizer::Spaces => {
                for t in split_spaces(line) {
                    f(t);
                }
            }
            Tokenizer::Normalized => {
                for t in split_normalized(line) {
                    f(&t);
                }
            }
        }
    }
}

impl Default for Tokenizer {
    fn default() -> Self {
        Tokenizer::Spaces
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_spaces_basic() {
        let toks: Vec<&str> = split_spaces("the quick brown fox").collect();
        assert_eq!(toks, ["the", "quick", "brown", "fox"]);
    }

    #[test]
    fn split_spaces_skips_empties() {
        let toks: Vec<&str> = split_spaces("  a  b ").collect();
        assert_eq!(toks, ["a", "b"]);
        assert_eq!(split_spaces("").count(), 0);
        assert_eq!(split_spaces("   ").count(), 0);
    }

    #[test]
    fn split_spaces_keeps_punctuation() {
        // Paper-faithful: "fox." is a distinct word from "fox".
        let toks: Vec<&str> = split_spaces("fox. Fox fox").collect();
        assert_eq!(toks, ["fox.", "Fox", "fox"]);
    }

    #[test]
    fn normalized_strips_and_lowercases() {
        let toks = split_normalized("The quick-brown FOX! (42)");
        let toks: Vec<&str> = toks.iter().map(|c| c.as_ref()).collect();
        assert_eq!(toks, ["the", "quick", "brown", "fox", "42"]);
    }

    #[test]
    fn normalized_borrows_when_already_lowercase() {
        let toks = split_normalized("already lower");
        assert!(matches!(toks[0], std::borrow::Cow::Borrowed(_)));
        let toks = split_normalized("Upper");
        assert!(matches!(toks[0], std::borrow::Cow::Owned(_)));
    }

    #[test]
    fn count_words_matches_iteration() {
        let line = "one two  three four";
        assert_eq!(Tokenizer::Spaces.count_words(line), 4);
        let mut n = 0;
        Tokenizer::Spaces.for_each_token(line, |_| n += 1);
        assert_eq!(n, 4);
    }

    #[test]
    fn tokenizer_parse() {
        assert_eq!(Tokenizer::parse("paper"), Some(Tokenizer::Spaces));
        assert_eq!(Tokenizer::parse("norm"), Some(Tokenizer::Normalized));
        assert_eq!(Tokenizer::parse("x"), None);
    }
}
