//! The XLA-accelerated combiner: dictionary-encoded token streams are
//! histogrammed by the AOT Pallas kernel instead of the hash map.
//!
//! This is the cross-layer integration point: L3 shards and pads the token
//! stream, the L1/L2 artifact counts a shard, and L3 merges the per-shard
//! count vectors (an associative reduce, the same contract as
//! `dist::reducer`). The hashed variant mirrors the kernel's bucket hash
//! bit-for-bit so rust and the accelerator agree on bucket assignment.

use anyhow::{Context, Result};

use super::client::Runtime;

/// Keep in sync with `python/compile/kernels/hash_bucket.py::HASH_MULT`.
pub const HASH_MULT: u32 = 0x9E37_79B9;

/// The kernel's bucket function: golden-ratio multiply, take the top
/// log2(buckets) bits. `buckets` must be a power of two.
#[inline]
pub fn hash_bucket_of(token: i32, buckets: u32) -> u32 {
    debug_assert!(buckets.is_power_of_two());
    let shift = 32 - buckets.trailing_zeros();
    (token as u32).wrapping_mul(HASH_MULT) >> shift
}

/// Static shapes of the AOT artifacts (from `artifacts/manifest.txt`).
#[derive(Clone, Copy, Debug)]
pub struct ShardSpec {
    pub shard_tokens: usize,
    pub vocab: usize,
    pub hash_buckets: usize,
    pub top_k: usize,
    pub pad_id: i32,
}

/// High-level driver for the histogram artifacts.
pub struct HistogramRuntime {
    rt: Runtime,
    pub spec: ShardSpec,
}

impl HistogramRuntime {
    pub fn new(rt: Runtime) -> Result<Self> {
        let m = rt.manifest().context("histogram runtime needs artifacts")?;
        let spec = ShardSpec {
            shard_tokens: m["shard_tokens"] as usize,
            vocab: m["vocab"] as usize,
            hash_buckets: m["hash_buckets"] as usize,
            top_k: m["top_k"] as usize,
            pad_id: m["pad_id"] as i32,
        };
        Ok(Self { rt, spec })
    }

    pub fn from_env() -> Result<Self> {
        Self::new(Runtime::from_env()?)
    }

    pub fn available() -> bool {
        Runtime::artifacts_available()
    }

    /// Count token ids in `[0, vocab)` with the dense-histogram artifact.
    /// Handles sharding + padding; merges shard counts in rust.
    pub fn count_tokens(&self, tokens: &[i32]) -> Result<Vec<u64>> {
        let exe = self.rt.load("token_hist")?;
        let n = self.spec.shard_tokens;
        let mut totals = vec![0u64; self.spec.vocab];
        let mut shard = vec![self.spec.pad_id; n];
        for chunk in tokens.chunks(n) {
            shard[..chunk.len()].copy_from_slice(chunk);
            shard[chunk.len()..].fill(self.spec.pad_id);
            let out = exe.run(&[xla::Literal::vec1(&shard)])?;
            let counts = out
                .into_iter()
                .next()
                .context("empty result tuple")?
                .to_vec::<i32>()?;
            for (t, c) in totals.iter_mut().zip(counts) {
                *t += c as u64;
            }
        }
        Ok(totals)
    }

    /// Dense counts plus top-k, using the composed L2 graph for the final
    /// shard-merge's top-k (counts still merged in rust across shards).
    pub fn count_tokens_topk(&self, tokens: &[i32]) -> Result<(Vec<u64>, Vec<(i32, u64)>)> {
        let totals = self.count_tokens(tokens)?;
        let mut ranked: Vec<(i32, u64)> = totals
            .iter()
            .enumerate()
            .map(|(id, &c)| (id as i32, c))
            .collect();
        ranked.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        ranked.truncate(self.spec.top_k);
        Ok((totals, ranked))
    }

    /// Run the single-shard top-k artifact (exercises the fused L2 graph).
    pub fn shard_topk(&self, shard_tokens: &[i32]) -> Result<Vec<(i32, u64)>> {
        anyhow::ensure!(
            shard_tokens.len() == self.spec.shard_tokens,
            "shard_topk needs exactly one shard"
        );
        let exe = self.rt.load("token_hist_topk")?;
        let out = exe.run(&[xla::Literal::vec1(shard_tokens)])?;
        anyhow::ensure!(out.len() == 3, "expected (counts, top_counts, top_ids)");
        let mut it = out.into_iter();
        let _counts = it.next().unwrap();
        let top_counts = it.next().unwrap().to_vec::<i32>()?;
        let top_ids = it.next().unwrap().to_vec::<i32>()?;
        Ok(top_ids
            .into_iter()
            .zip(top_counts)
            .map(|(id, c)| (id, c as u64))
            .collect())
    }

    /// Hashed-bucket counts (for unbounded vocab): same sharding protocol.
    pub fn count_hashed(&self, tokens: &[i32]) -> Result<Vec<u64>> {
        let exe = self.rt.load("hash_hist")?;
        let n = self.spec.shard_tokens;
        let mut totals = vec![0u64; self.spec.hash_buckets];
        let mut shard = vec![self.spec.pad_id; n];
        for chunk in tokens.chunks(n) {
            shard[..chunk.len()].copy_from_slice(chunk);
            shard[chunk.len()..].fill(self.spec.pad_id);
            let out = exe.run(&[xla::Literal::vec1(&shard)])?;
            let counts = out
                .into_iter()
                .next()
                .context("empty result tuple")?
                .to_vec::<i32>()?;
            for (t, c) in totals.iter_mut().zip(counts) {
                *t += c as u64;
            }
        }
        Ok(totals)
    }

    /// Serial rust reference for `count_tokens` (test oracle).
    pub fn count_tokens_serial(&self, tokens: &[i32]) -> Vec<u64> {
        let mut totals = vec![0u64; self.spec.vocab];
        for &t in tokens {
            if t >= 0 && (t as usize) < self.spec.vocab {
                totals[t as usize] += 1;
            }
        }
        totals
    }

    /// Serial rust reference for `count_hashed`.
    pub fn count_hashed_serial(&self, tokens: &[i32]) -> Vec<u64> {
        let mut totals = vec![0u64; self.spec.hash_buckets];
        for &t in tokens {
            if t >= 0 {
                totals[hash_bucket_of(t, self.spec.hash_buckets as u32) as usize] += 1;
            }
        }
        totals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_bucket_in_range_and_deterministic() {
        for buckets in [256u32, 4096] {
            for t in [0i32, 1, 12345, i32::MAX, 7_777_777] {
                let b = hash_bucket_of(t, buckets);
                assert!(b < buckets);
                assert_eq!(b, hash_bucket_of(t, buckets));
            }
        }
    }

    #[test]
    fn hash_bucket_pinned_value() {
        // Same pinned vector as python test_matches_known_constant.
        let t = 12345i32;
        let h = (t as u32 as u64 * HASH_MULT as u64) % (1u64 << 32);
        let expect = (h >> (32 - 8)) as u32;
        assert_eq!(hash_bucket_of(t, 256), expect);
    }

    #[test]
    fn hash_buckets_spread() {
        let mut counts = vec![0u32; 256];
        for t in 0..65_536i32 {
            counts[hash_bucket_of(t, 256) as usize] += 1;
        }
        let mean = 65_536 / 256;
        assert!(counts.iter().all(|&c| c > mean / 3 && c < mean * 3));
    }

    fn runtime() -> Option<HistogramRuntime> {
        if !HistogramRuntime::available() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(HistogramRuntime::from_env().unwrap())
    }

    #[test]
    fn count_tokens_matches_serial() {
        let Some(hr) = runtime() else { return };
        let mut rng = crate::util::rng::Xoshiro256::new(99);
        // 1.5 shards worth of ids, some OOV-ish (clamped by vocab), some pad.
        let n = hr.spec.shard_tokens * 3 / 2;
        let tokens: Vec<i32> = (0..n)
            .map(|_| {
                if rng.chance(0.05) {
                    -1
                } else {
                    rng.next_below(hr.spec.vocab as u64) as i32
                }
            })
            .collect();
        let got = hr.count_tokens(&tokens).unwrap();
        assert_eq!(got, hr.count_tokens_serial(&tokens));
    }

    #[test]
    fn count_hashed_matches_serial() {
        let Some(hr) = runtime() else { return };
        let mut rng = crate::util::rng::Xoshiro256::new(7);
        let n = hr.spec.shard_tokens + 1000;
        let tokens: Vec<i32> =
            (0..n).map(|_| rng.next_below(1 << 20) as i32).collect();
        let got = hr.count_hashed(&tokens).unwrap();
        assert_eq!(got, hr.count_hashed_serial(&tokens));
    }

    #[test]
    fn topk_artifact_agrees() {
        let Some(hr) = runtime() else { return };
        let n = hr.spec.shard_tokens;
        // Unequal counts: 42 strictly dominates, then 7.
        let mut tokens = vec![42i32; n * 3 / 4];
        tokens.resize(n, 7);
        let top = hr.shard_topk(&tokens).unwrap();
        assert_eq!(top.len(), hr.spec.top_k);
        assert_eq!(top[0], (42, (n * 3 / 4) as u64));
        assert_eq!(top[1], (7, (n - n * 3 / 4) as u64));
        // Ties break by ascending id (matches wordcount::top_k).
        assert!(top[2].1 == 0);
    }
}
