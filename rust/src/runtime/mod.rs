//! Run-time substrate shared by both engines.
//!
//! Two halves:
//!
//! * [`executor`] — the process-wide **work-stealing thread pool** both
//!   engines dispatch their map tasks and reduce-stage partitions onto
//!   (the real `--threads` knob, as opposed to the simulated
//!   `threads_per_node` cost model);
//! * [`client`]/[`histogram`] — the XLA/PJRT runtime: loads AOT
//!   artifacts produced by `python/compile/aot.py` (`make artifacts`)
//!   and executes them from the rust hot path. Layering contract (see
//!   DESIGN.md §3): Python runs only at build time; these modules make
//!   the rust binary self-contained at run time.

pub mod client;
pub mod executor;
pub mod histogram;

pub use client::{Executable, Runtime};
pub use executor::{default_width, ExecCtx, Executor, StealStats, TaskSetError};
pub use histogram::{hash_bucket_of, HistogramRuntime, ShardSpec, HASH_MULT};
