//! XLA/PJRT runtime: loads AOT artifacts produced by `python/compile/aot.py`
//! (`make artifacts`) and executes them from the rust hot path.
//!
//! Layering contract (see DESIGN.md §3): Python runs only at build time;
//! these modules make the rust binary self-contained at run time.

pub mod client;
pub mod histogram;

pub use client::{Executable, Runtime};
pub use histogram::{hash_bucket_of, HistogramRuntime, ShardSpec, HASH_MULT};
