//! The real-execution work-stealing stage executor.
//!
//! Both engines used to fan each simulated node's work out on ad-hoc
//! scoped threads (`util::pool::parallel_for` per phase): with N node
//! threads each spawning `threads_per_node` workers, the process ran
//! `N × T` OS threads regardless of the machine, so "thread count" was a
//! cost-model fiction with no real x-axis. This module is the fix: one
//! process-wide pool of [`Executor::width`] long-lived workers, shared by
//! every simulated node of every engine. Map tasks and reduce-stage
//! partitions are submitted as *task sets* and the pool's workers pull
//! them with classic work stealing:
//!
//! * a **global injector** queue receives every submitted task set
//!   (submitters are the engines' node/driver threads — they are never
//!   workers, so worker ids stay dense in `[0, width)`);
//! * each worker owns a **local deque**; when it runs dry it takes a
//!   batch (`⌈injector/width⌉`, capped) from the injector, and only then
//!   tries to **steal half** of a sibling's deque — the back half, the
//!   work its owner (popping from the front) would reach last.
//!
//! Determinism: the pool changes *scheduling*, never *results*. Every
//! caller in this crate folds emissions with an associative + commutative
//! `combine` into owner-sharded maps (or writes to per-task slots), so
//! output is bit-identical to the serial oracle at any width — enforced
//! by the parity grids in `tests/` at widths 1, 2, 4 and 8.
//!
//! Panic containment: each task runs under `catch_unwind`; a panicking
//! task marks the set failed ([`TaskSetError`]) but the worker survives
//! and keeps draining the queues, so a poisoned job cannot poison the
//! pool. Engines convert the error into their existing recovery loops
//! (Blaze's whole-job rerun, the Spark sim's task-failure restart).

use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::trace::{self, MetricSet, SpanCat};

/// Context handed to every task body: which pool worker is executing it.
/// Callers key per-thread state (the `ConcurrentHashMap` thread caches)
/// off `worker`, which is unique among concurrently running tasks.
#[derive(Clone, Copy, Debug)]
pub struct ExecCtx {
    /// Worker index in `[0, width)`.
    pub worker: usize,
    /// Pool width (total workers).
    pub width: usize,
}

/// A task set failed: at least one task body panicked. The panic payloads
/// are swallowed (the workers survive); the engines turn this into their
/// own failure currency.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TaskSetError {
    /// How many tasks panicked.
    pub panics: usize,
    /// Lowest task index that panicked.
    pub first_task: usize,
}

impl std::fmt::Display for TaskSetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} task(s) panicked (first: task {})",
            self.panics, self.first_task
        )
    }
}

impl std::error::Error for TaskSetError {}

/// Steal-side counters, for observability and the fairness tests.
#[derive(Debug, Clone, Copy, Default)]
pub struct StealStats {
    /// Batches taken from the global injector.
    pub injector_takes: u64,
    /// Batches stolen from sibling deques.
    pub steals: u64,
}

/// Log₂-bucketed task-latency histogram cells (bucket `i` counts task
/// durations in `[2^i, 2^(i+1))` ns; the last bucket absorbs the tail).
const LATENCY_BUCKETS: usize = 40;

struct LatencyCells {
    buckets: [AtomicU64; LATENCY_BUCKETS],
}

fn latency_bucket(ns: u64) -> usize {
    if ns == 0 {
        0
    } else {
        (63 - ns.leading_zeros() as usize).min(LATENCY_BUCKETS - 1)
    }
}

impl LatencyCells {
    fn new() -> Self {
        Self { buckets: std::array::from_fn(|_| AtomicU64::new(0)) }
    }

    fn record(&self, ns: u64) {
        self.buckets[latency_bucket(ns)].fetch_add(1, Ordering::Relaxed);
    }

    fn snapshot(&self) -> LatencySnapshot {
        LatencySnapshot {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
        }
    }
}

/// A point-in-time copy of the task-latency histogram. Subtract two
/// snapshots ([`delta_since`](Self::delta_since)) to isolate one job.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LatencySnapshot {
    /// `buckets[i]` counts tasks whose run time fell in
    /// `[2^i, 2^(i+1))` ns.
    pub buckets: Vec<u64>,
}

impl LatencySnapshot {
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Upper bound (ns) of the bucket where the cumulative count crosses
    /// quantile `q` in `[0, 1]`. 0 when empty.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= target {
                return 1u64 << (i + 1).min(63);
            }
        }
        1u64 << self.buckets.len().min(63)
    }

    pub fn delta_since(&self, before: &LatencySnapshot) -> LatencySnapshot {
        let n = self.buckets.len().max(before.buckets.len());
        LatencySnapshot {
            buckets: (0..n)
                .map(|i| {
                    let now = self.buckets.get(i).copied().unwrap_or(0);
                    let then = before.buckets.get(i).copied().unwrap_or(0);
                    now.saturating_sub(then)
                })
                .collect(),
        }
    }
}

/// Per-worker activity cells, updated by the worker itself.
struct WorkerCounters {
    busy_ns: AtomicU64,
    idle_ns: AtomicU64,
    tasks: AtomicU64,
    steals: AtomicU64,
    injector_takes: AtomicU64,
}

impl WorkerCounters {
    fn new() -> Self {
        Self {
            busy_ns: AtomicU64::new(0),
            idle_ns: AtomicU64::new(0),
            tasks: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            injector_takes: AtomicU64::new(0),
        }
    }
}

/// One worker's activity totals at snapshot time.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkerStats {
    pub worker: usize,
    /// Nanoseconds spent running task bodies.
    pub busy_ns: u64,
    /// Nanoseconds spent parked on the idle condvar (accumulated at
    /// wake-up, so a window's first wake may attribute earlier parked
    /// time to it — treat as approximate).
    pub idle_ns: u64,
    /// Task bodies executed (nested inline sets included).
    pub tasks: u64,
    /// Batches stolen from sibling deques.
    pub steals: u64,
    /// Batches taken from the global injector.
    pub injector_takes: u64,
}

impl WorkerStats {
    fn delta_since(&self, before: &WorkerStats) -> WorkerStats {
        WorkerStats {
            worker: self.worker,
            busy_ns: self.busy_ns.saturating_sub(before.busy_ns),
            idle_ns: self.idle_ns.saturating_sub(before.idle_ns),
            tasks: self.tasks.saturating_sub(before.tasks),
            steals: self.steals.saturating_sub(before.steals),
            injector_takes: self.injector_takes.saturating_sub(before.injector_takes),
        }
    }
}

/// Structured executor metrics: a point-in-time snapshot of every
/// worker's counters plus the task-latency histogram. The job layer
/// snapshots the pool before and after a run and ships the
/// [`delta_since`](Self::delta_since) in the `JobReport`. The pool is
/// process-wide, so concurrent jobs' activity lands in the same window —
/// deltas describe *the pool during the job*, not the job exclusively.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ExecMetrics {
    /// Pool width the snapshot was taken from.
    pub width: usize,
    pub workers: Vec<WorkerStats>,
    pub latency: LatencySnapshot,
}

impl ExecMetrics {
    /// Per-field saturating difference (same pool, later minus earlier).
    pub fn delta_since(&self, before: &ExecMetrics) -> ExecMetrics {
        let blank = WorkerStats::default();
        ExecMetrics {
            width: self.width,
            workers: self
                .workers
                .iter()
                .enumerate()
                .map(|(i, w)| w.delta_since(before.workers.get(i).unwrap_or(&blank)))
                .collect(),
            latency: self.latency.delta_since(&before.latency),
        }
    }

    /// Per-field sum (for folding per-stage windows into a chain total).
    pub fn merged(&self, other: &ExecMetrics) -> ExecMetrics {
        let width = self.width.max(other.width);
        let blank = WorkerStats::default();
        let mut workers = Vec::with_capacity(self.workers.len().max(other.workers.len()));
        for i in 0..self.workers.len().max(other.workers.len()) {
            let a = self.workers.get(i).unwrap_or(&blank);
            let b = other.workers.get(i).unwrap_or(&blank);
            workers.push(WorkerStats {
                worker: i,
                busy_ns: a.busy_ns + b.busy_ns,
                idle_ns: a.idle_ns + b.idle_ns,
                tasks: a.tasks + b.tasks,
                steals: a.steals + b.steals,
                injector_takes: a.injector_takes + b.injector_takes,
            });
        }
        let n = self.latency.buckets.len().max(other.latency.buckets.len());
        let latency = LatencySnapshot {
            buckets: (0..n)
                .map(|i| {
                    self.latency.buckets.get(i).copied().unwrap_or(0)
                        + other.latency.buckets.get(i).copied().unwrap_or(0)
                })
                .collect(),
        };
        ExecMetrics { width, workers, latency }
    }

    pub fn busy_secs(&self) -> f64 {
        self.workers.iter().map(|w| w.busy_ns).sum::<u64>() as f64 / 1e9
    }

    pub fn total_tasks(&self) -> u64 {
        self.workers.iter().map(|w| w.tasks).sum()
    }

    pub fn total_steals(&self) -> u64 {
        self.workers.iter().map(|w| w.steals).sum()
    }

    pub fn total_injector_takes(&self) -> u64 {
        self.workers.iter().map(|w| w.injector_takes).sum()
    }

    /// Mean worker utilization over a window of `wall_secs`:
    /// `Σ busy / (width × wall)`, clamped to `[0, 1]`.
    pub fn utilization(&self, wall_secs: f64) -> f64 {
        if self.width == 0 || wall_secs <= 0.0 {
            return 0.0;
        }
        (self.busy_secs() / (self.width as f64 * wall_secs)).clamp(0.0, 1.0)
    }

    /// Task-count imbalance: busiest worker's tasks over the per-worker
    /// mean. 1.0 = perfectly balanced; 0.0 when no tasks ran.
    pub fn steal_imbalance(&self) -> f64 {
        let total = self.total_tasks();
        if total == 0 || self.workers.is_empty() {
            return 0.0;
        }
        let max = self.workers.iter().map(|w| w.tasks).max().unwrap_or(0) as f64;
        max / (total as f64 / self.workers.len() as f64)
    }

    /// The metrics a `JobReport` renders: utilization needs the job wall,
    /// so the caller passes it in.
    pub fn to_metric_set(&self, wall_secs: f64) -> MetricSet {
        let mut m = MetricSet::new();
        m.set_ratio("util", self.utilization(wall_secs));
        m.set_count("tasks", self.total_tasks());
        m.set_count("steals", self.total_steals());
        m.set_ratio("imbalance", self.steal_imbalance());
        m.set_secs("busy", self.busy_secs());
        m.set_secs("p50_task", self.latency.quantile_ns(0.5) as f64 / 1e9);
        m.set_secs("p99_task", self.latency.quantile_ns(0.99) as f64 / 1e9);
        m
    }
}

/// A type-erased task: `call(data, index, worker, width)` invokes task
/// `index` of the set whose harness `data` points to.
struct RawTask {
    call: unsafe fn(*const (), usize, usize, usize),
    data: *const (),
    index: usize,
}

// SAFETY: `data` points at a `SetHarness<F>` (`F: Sync`) that the
// submitting thread keeps alive — it blocks until every task of the set
// has finished — so sending the pointer to a worker thread is sound.
unsafe impl Send for RawTask {}

/// Completion state of one submitted task set. Heap-allocated (`Arc`) so
/// a worker can signal completion safely after the submitter's stack
/// frame — which holds the closure — becomes eligible for reuse.
struct SetState {
    remaining: AtomicUsize,
    panics: AtomicUsize,
    first_panic: AtomicUsize,
    done: Mutex<bool>,
    done_cv: Condvar,
}

impl SetState {
    fn new(n: usize) -> Self {
        Self {
            remaining: AtomicUsize::new(n),
            panics: AtomicUsize::new(0),
            first_panic: AtomicUsize::new(usize::MAX),
            done: Mutex::new(false),
            done_cv: Condvar::new(),
        }
    }

    fn wait_done(&self) {
        let mut done = self.done.lock().unwrap();
        while !*done {
            done = self.done_cv.wait(done).unwrap();
        }
    }
}

/// The stack-held harness a task set's `RawTask.data` points to.
struct SetHarness<F> {
    state: Arc<SetState>,
    body: F,
}

/// The trampoline behind `RawTask.call`.
///
/// SAFETY: `data` must point to a live `SetHarness<F>` whose submitter is
/// blocked in `SetState::wait_done`. After the `fetch_sub` below the
/// harness may be freed at any moment, so everything past it goes through
/// the owned `Arc<SetState>` clone only.
unsafe fn call_task<F>(data: *const (), index: usize, worker: usize, width: usize)
where
    F: Fn(ExecCtx, usize) + Sync,
{
    let harness = &*(data as *const SetHarness<F>);
    let state = Arc::clone(&harness.state);
    let ctx = ExecCtx { worker, width };
    if catch_unwind(AssertUnwindSafe(|| (harness.body)(ctx, index))).is_err() {
        state.panics.fetch_add(1, Ordering::Relaxed);
        state.first_panic.fetch_min(index, Ordering::Relaxed);
    }
    if state.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
        let mut done = state.done.lock().unwrap();
        *done = true;
        state.done_cv.notify_all();
    }
}

/// Everything behind the global injector's lock. `unclaimed` counts tasks
/// sitting in *any* queue (injector or a worker deque) not yet picked up
/// for execution — the sleep/exit condition.
struct Shared {
    injector: VecDeque<RawTask>,
    unclaimed: usize,
    shutdown: bool,
}

struct Inner {
    width: usize,
    state: Mutex<Shared>,
    cv: Condvar,
    deques: Vec<Mutex<VecDeque<RawTask>>>,
    counters: Vec<WorkerCounters>,
    latency: LatencyCells,
}

thread_local! {
    /// `(pool token, worker id)` of the executor this thread belongs to,
    /// if it is a pool worker. Lets a nested `run_tasks` from inside a
    /// task run inline (same worker id, no deadlock) instead of blocking
    /// a worker on work only workers can do.
    static WORKER: std::cell::Cell<Option<(usize, usize)>> =
        const { std::cell::Cell::new(None) };
}

/// Per-task-batch cap when refilling from the injector: big enough to
/// amortize the lock, small enough that a straggler's backlog stays
/// stealable.
const MAX_TAKE: usize = 32;

impl Inner {
    /// Identity of this pool, for the nested-submission check. Equal to
    /// `Arc::as_ptr` of every `Arc<Inner>` handle to this pool.
    fn token(&self) -> usize {
        self as *const Inner as usize
    }

    /// Claim one queued task for execution (bookkeeping only).
    fn claim(&self) {
        self.state.lock().unwrap().unclaimed -= 1;
    }

    /// Refill from the global injector: pop a fair share (≤ [`MAX_TAKE`])
    /// of the queue, run the first task, park the rest on our deque.
    fn take_from_injector(&self, me: usize) -> Option<RawTask> {
        let mut rest = Vec::new();
        let (first, unclaimed) = {
            let mut s = self.state.lock().unwrap();
            let len = s.injector.len();
            if len == 0 {
                return None;
            }
            let take = (len / self.width).clamp(1, MAX_TAKE);
            s.unclaimed -= 1; // the one we run now
            let first = s.injector.pop_front().unwrap();
            rest.reserve(take - 1);
            for _ in 1..take {
                match s.injector.pop_front() {
                    Some(t) => rest.push(t),
                    None => break,
                }
            }
            (first, s.unclaimed)
        };
        if !rest.is_empty() {
            let mut d = self.deques[me].lock().unwrap();
            d.extend(rest);
        }
        self.counters[me].injector_takes.fetch_add(1, Ordering::Relaxed);
        trace::counter("queue depth", unclaimed as u64);
        Some(first)
    }

    /// Steal the back half of the first non-empty sibling deque — the
    /// work its owner (popping from the front) would reach last.
    fn steal(&self, me: usize) -> Option<RawTask> {
        for k in 1..self.width {
            let victim = (me + k) % self.width;
            let mut stolen = {
                let mut d = self.deques[victim].lock().unwrap();
                let len = d.len();
                if len == 0 {
                    continue;
                }
                d.split_off(len - len.div_ceil(2))
            };
            let first = stolen.pop_front().unwrap();
            self.claim();
            if !stolen.is_empty() {
                let mut d = self.deques[me].lock().unwrap();
                d.append(&mut stolen);
            }
            self.counters[me].steals.fetch_add(1, Ordering::Relaxed);
            return Some(first);
        }
        None
    }

    fn run(&self, task: RawTask, me: usize) {
        let span = trace::span(SpanCat::Task, "task");
        let start = Instant::now();
        // SAFETY: the task's harness is alive (its submitter is blocked
        // until `remaining` hits 0, and this task is still counted).
        unsafe { (task.call)(task.data, task.index, me, self.width) }
        let dur_ns = start.elapsed().as_nanos() as u64;
        drop(span);
        self.counters[me].busy_ns.fetch_add(dur_ns, Ordering::Relaxed);
        self.counters[me].tasks.fetch_add(1, Ordering::Relaxed);
        self.latency.record(dur_ns);
    }
}

fn worker_loop(inner: Arc<Inner>, me: usize) {
    WORKER.with(|c| c.set(Some((inner.token(), me))));
    loop {
        let own = self_pop(&inner, me);
        if let Some(task) = own {
            inner.claim();
            inner.run(task, me);
            continue;
        }
        if let Some(task) = inner.take_from_injector(me) {
            inner.run(task, me);
            continue;
        }
        if let Some(task) = inner.steal(me) {
            inner.run(task, me);
            continue;
        }
        // Nothing visible. Sleep — or exit once shut down and drained.
        let s = inner.state.lock().unwrap();
        let parked = Instant::now();
        if s.unclaimed == 0 {
            if s.shutdown {
                return;
            }
            // Safe plain wait: every submit increments `unclaimed` and
            // notifies under this same lock, so no wakeup can be lost.
            drop(inner.cv.wait(s).unwrap());
        } else {
            // Work exists but a sibling holds it transiently (mid-push
            // or mid-steal): timed nap, then re-sweep. Correctness never
            // depends on this timing, only liveness.
            drop(inner.cv.wait_timeout(s, Duration::from_millis(1)).unwrap());
        }
        inner.counters[me]
            .idle_ns
            .fetch_add(parked.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }
}

fn self_pop(inner: &Inner, me: usize) -> Option<RawTask> {
    inner.deques[me].lock().unwrap().pop_front()
}

/// The work-stealing pool. See the module docs for the architecture.
/// Create standalone with [`Executor::new`] or get the process-wide
/// cached instance for a width via [`Executor::for_threads`].
pub struct Executor {
    inner: Arc<Inner>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Executor {
    /// Spawn a pool of `width` workers (`width` is clamped to ≥ 1).
    pub fn new(width: usize) -> Arc<Executor> {
        let width = width.max(1);
        let inner = Arc::new(Inner {
            width,
            state: Mutex::new(Shared {
                injector: VecDeque::new(),
                unclaimed: 0,
                shutdown: false,
            }),
            cv: Condvar::new(),
            deques: (0..width).map(|_| Mutex::new(VecDeque::new())).collect(),
            counters: (0..width).map(|_| WorkerCounters::new()).collect(),
            latency: LatencyCells::new(),
        });
        let handles = (0..width)
            .map(|me| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("blaze-exec-{me}"))
                    .spawn(move || worker_loop(inner, me))
                    .expect("spawn executor worker")
            })
            .collect();
        Arc::new(Executor { inner, handles: Mutex::new(handles) })
    }

    /// The process-wide executor for a requested width. `None` = auto
    /// ([`default_width`]: `BLAZE_THREADS`, else the machine's available
    /// parallelism). Executors are cached per width and shared by every
    /// job in the process — workers are spawned once, not per job.
    pub fn for_threads(threads: Option<usize>) -> Arc<Executor> {
        static CACHE: OnceLock<Mutex<HashMap<usize, Arc<Executor>>>> = OnceLock::new();
        let width = threads.unwrap_or_else(default_width).max(1);
        let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
        let mut map = cache.lock().unwrap();
        Arc::clone(map.entry(width).or_insert_with(|| Executor::new(width)))
    }

    /// Number of workers.
    pub fn width(&self) -> usize {
        self.inner.width
    }

    /// Steal-side counters since the pool was created.
    pub fn stats(&self) -> StealStats {
        let m = self.metrics();
        StealStats {
            injector_takes: m.total_injector_takes(),
            steals: m.total_steals(),
        }
    }

    /// Snapshot every worker's activity counters plus the task-latency
    /// histogram. Counters are cumulative since pool creation; take a
    /// snapshot before and after a job and
    /// [`delta_since`](ExecMetrics::delta_since) to isolate its window.
    pub fn metrics(&self) -> ExecMetrics {
        ExecMetrics {
            width: self.inner.width,
            workers: self
                .inner
                .counters
                .iter()
                .enumerate()
                .map(|(worker, c)| WorkerStats {
                    worker,
                    busy_ns: c.busy_ns.load(Ordering::Relaxed),
                    idle_ns: c.idle_ns.load(Ordering::Relaxed),
                    tasks: c.tasks.load(Ordering::Relaxed),
                    steals: c.steals.load(Ordering::Relaxed),
                    injector_takes: c.injector_takes.load(Ordering::Relaxed),
                })
                .collect(),
            latency: self.inner.latency.snapshot(),
        }
    }

    /// Run `body(ctx, i)` for every `i` in `[0, n)` on the pool and wait
    /// for all of them. Tasks may run in any order on any worker; `body`
    /// may borrow from the caller's stack (the call blocks until the set
    /// completes, like a scoped spawn).
    ///
    /// Called from inside a pool task (of *this* executor), the whole set
    /// runs inline under the current worker's id — nested submission can
    /// never deadlock the pool, and `ctx.worker` stays a valid exclusive
    /// index for tid-keyed structures.
    ///
    /// Returns `Err` if any task panicked (see [`TaskSetError`]); the
    /// remaining tasks still run to completion and the pool stays usable.
    pub fn run_tasks<F>(&self, n: usize, body: F) -> Result<(), TaskSetError>
    where
        F: Fn(ExecCtx, usize) + Sync,
    {
        if n == 0 {
            return Ok(());
        }
        if let Some((token, worker)) = WORKER.with(|c| c.get()) {
            if token == self.inner.token() {
                return run_inline(&self.inner, worker, n, &body);
            }
        }
        let state = Arc::new(SetState::new(n));
        let harness = SetHarness { state: Arc::clone(&state), body };
        let data = &harness as *const SetHarness<F> as *const ();
        let call = call_task::<F> as unsafe fn(*const (), usize, usize, usize);
        {
            let mut s = self.inner.state.lock().unwrap();
            s.injector.extend((0..n).map(|index| RawTask { call, data, index }));
            s.unclaimed += n;
            self.inner.cv.notify_all();
            trace::counter("queue depth", s.unclaimed as u64);
        }
        state.wait_done();
        let panics = state.panics.load(Ordering::Acquire);
        if panics == 0 {
            Ok(())
        } else {
            Err(TaskSetError { panics, first_task: state.first_panic.load(Ordering::Acquire) })
        }
    }
}

impl Drop for Executor {
    /// Shut down: workers drain every queued task, then exit, and the
    /// drop joins them. (Cached [`Executor::for_threads`] instances live
    /// for the process and are never dropped.)
    fn drop(&mut self) {
        {
            let mut s = self.inner.state.lock().unwrap();
            s.shutdown = true;
            self.inner.cv.notify_all();
        }
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

fn run_inline<F>(inner: &Inner, worker: usize, n: usize, body: &F) -> Result<(), TaskSetError>
where
    F: Fn(ExecCtx, usize) + Sync,
{
    let ctx = ExecCtx { worker, width: inner.width };
    let mut panics = 0usize;
    let mut first_task = usize::MAX;
    for i in 0..n {
        let span = trace::span(SpanCat::Task, "task");
        let start = Instant::now();
        let failed = catch_unwind(AssertUnwindSafe(|| body(ctx, i))).is_err();
        let dur_ns = start.elapsed().as_nanos() as u64;
        drop(span);
        // Nested sets run inside the outer task's busy window, so only
        // the task count and latency are recorded — not busy nanos.
        inner.counters[worker].tasks.fetch_add(1, Ordering::Relaxed);
        inner.latency.record(dur_ns);
        if failed {
            panics += 1;
            if first_task == usize::MAX {
                first_task = i;
            }
        }
    }
    if panics == 0 {
        Ok(())
    } else {
        Err(TaskSetError { panics, first_task })
    }
}

/// Pool width when the caller does not pin one: the `BLAZE_THREADS`
/// environment variable if set to a positive integer, else the machine's
/// available parallelism.
pub fn default_width() -> usize {
    if let Some(n) = width_from_env(std::env::var("BLAZE_THREADS").ok().as_deref()) {
        return n;
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Parse a `BLAZE_THREADS`-style override. `None`/empty/non-numeric/zero
/// all mean "no override".
fn width_from_env(value: Option<&str>) -> Option<usize> {
    value.and_then(|v| v.trim().parse::<usize>().ok()).filter(|&n| n > 0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_each_index_once_at_every_width() {
        for width in [1usize, 2, 3, 4, 8] {
            let exec = Executor::new(width);
            for n in [1usize, 7, 64, 1000] {
                let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
                exec.run_tasks(n, |_ctx, i| {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                })
                .unwrap();
                for (i, h) in hits.iter().enumerate() {
                    assert_eq!(h.load(Ordering::Relaxed), 1, "width={width} n={n} index={i}");
                }
            }
        }
    }

    #[test]
    fn worker_ids_are_dense_and_in_range() {
        let exec = Executor::new(4);
        exec.run_tasks(500, |ctx, _| {
            assert!(ctx.worker < ctx.width);
            assert_eq!(ctx.width, 4);
        })
        .unwrap();
    }

    #[test]
    fn empty_set_is_noop() {
        let exec = Executor::new(2);
        exec.run_tasks(0, |_, _| panic!("must not run")).unwrap();
    }

    #[test]
    fn nested_submission_runs_inline_without_deadlock() {
        let exec = Executor::new(2);
        let total = AtomicU64::new(0);
        exec.run_tasks(8, |outer, _| {
            // A nested set from inside a task must not block a worker on
            // work only workers can do. It runs inline under our id.
            exec.run_tasks(16, |inner, _| {
                assert_eq!(inner.worker, outer.worker);
                total.fetch_add(1, Ordering::Relaxed);
            })
            .unwrap();
        })
        .unwrap();
        assert_eq!(total.load(Ordering::Relaxed), 8 * 16);
    }

    #[test]
    fn panic_is_contained_and_reported() {
        let exec = Executor::new(4);
        let ran = AtomicU64::new(0);
        let err = exec
            .run_tasks(100, |_ctx, i| {
                ran.fetch_add(1, Ordering::Relaxed);
                if i == 13 || i == 57 {
                    panic!("boom");
                }
            })
            .unwrap_err();
        assert_eq!(err.panics, 2);
        assert!(err.first_task == 13 || err.first_task == 57);
        // Panicking tasks still count as run; the rest all completed.
        assert_eq!(ran.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn pool_survives_panics_and_stays_usable() {
        let exec = Executor::new(2);
        for _ in 0..3 {
            assert!(exec.run_tasks(10, |_, _| panic!("poison attempt")).is_err());
        }
        let sum = AtomicU64::new(0);
        exec.run_tasks(100, |_, i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        })
        .unwrap();
        assert_eq!(sum.load(Ordering::Relaxed), (0..100u64).sum::<u64>());
    }

    #[test]
    fn concurrent_submitters_share_the_pool() {
        let exec = Executor::new(4);
        let total = AtomicU64::new(0);
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let exec = &exec;
                let total = &total;
                scope.spawn(move || {
                    exec.run_tasks(250, |_, _| {
                        total.fetch_add(1, Ordering::Relaxed);
                    })
                    .unwrap();
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn straggler_backlog_is_stolen() {
        // Worker A refills a big batch from the injector, then stalls on
        // the set's one slow task; its parked backlog must migrate to the
        // idle sibling rather than wait behind the straggler.
        let exec = Executor::new(2);
        let by_worker = [AtomicU64::new(0), AtomicU64::new(0)];
        exec.run_tasks(64, |ctx, i| {
            by_worker[ctx.worker].fetch_add(1, Ordering::Relaxed);
            if i == 0 {
                std::thread::sleep(Duration::from_millis(250));
            } else {
                std::thread::sleep(Duration::from_millis(1));
            }
        })
        .unwrap();
        let a = by_worker[0].load(Ordering::Relaxed);
        let b = by_worker[1].load(Ordering::Relaxed);
        assert_eq!(a + b, 64);
        assert!(a > 0 && b > 0, "both workers must participate: {a} vs {b}");
        let stats = exec.stats();
        assert!(stats.injector_takes > 0);
        assert!(
            stats.steals > 0,
            "the straggler's parked backlog must be stolen: {stats:?}"
        );
    }

    #[test]
    fn shutdown_joins_cleanly_with_concurrent_submitter() {
        let exec = Executor::new(2);
        let count = Arc::new(AtomicU64::new(0));
        let handle = {
            let exec = Arc::clone(&exec);
            let count = Arc::clone(&count);
            std::thread::spawn(move || {
                exec.run_tasks(50, |_, _| {
                    std::thread::sleep(Duration::from_millis(1));
                    count.fetch_add(1, Ordering::Relaxed);
                })
                .unwrap();
            })
        };
        drop(exec); // the submitter's clone keeps the pool alive
        handle.join().unwrap();
        // Every queued task ran before the last ref dropped the pool.
        assert_eq!(count.load(Ordering::Relaxed), 50);
    }

    #[test]
    fn for_threads_caches_per_width() {
        let a = Executor::for_threads(Some(3));
        let b = Executor::for_threads(Some(3));
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(a.width(), 3);
        let c = Executor::for_threads(Some(5));
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(c.width(), 5);
    }

    #[test]
    fn width_env_override_parsing() {
        assert_eq!(width_from_env(None), None);
        assert_eq!(width_from_env(Some("")), None);
        assert_eq!(width_from_env(Some("abc")), None);
        assert_eq!(width_from_env(Some("0")), None);
        assert_eq!(width_from_env(Some("6")), Some(6));
        assert_eq!(width_from_env(Some(" 12 ")), Some(12));
    }

    #[test]
    fn latency_buckets_are_log2() {
        assert_eq!(latency_bucket(0), 0);
        assert_eq!(latency_bucket(1), 0);
        assert_eq!(latency_bucket(2), 1);
        assert_eq!(latency_bucket(3), 1);
        assert_eq!(latency_bucket(1024), 10);
        assert_eq!(latency_bucket(u64::MAX), LATENCY_BUCKETS - 1);
    }

    #[test]
    fn metrics_delta_counts_tasks_busy_time_and_latency() {
        let exec = Executor::new(2);
        let before = exec.metrics();
        exec.run_tasks(32, |_, _| std::thread::sleep(Duration::from_micros(200)))
            .unwrap();
        let d = exec.metrics().delta_since(&before);
        assert_eq!(d.width, 2);
        assert_eq!(d.total_tasks(), 32);
        assert_eq!(d.latency.count(), 32);
        assert!(d.busy_secs() > 0.0, "busy time must accumulate: {d:?}");
        // Every task slept ≥200µs, so the median bucket bound is above that.
        assert!(d.latency.quantile_ns(0.5) >= 200_000);
        assert!(d.steal_imbalance() >= 1.0);
        assert!(d.utilization(10.0) > 0.0 && d.utilization(10.0) <= 1.0);
        let m = d.to_metric_set(1.0);
        assert_eq!(m.count("tasks"), 32);
        assert!(m.value("util") > 0.0);
    }

    #[test]
    fn nested_inline_tasks_count_without_double_busy() {
        let exec = Executor::new(2);
        let before = exec.metrics();
        exec.run_tasks(4, |_, _| {
            exec.run_tasks(8, |_, _| {
                std::thread::sleep(Duration::from_micros(100));
            })
            .unwrap();
        })
        .unwrap();
        let d = exec.metrics().delta_since(&before);
        // 4 outer + 32 nested bodies all count as tasks...
        assert_eq!(d.total_tasks(), 36);
        // ...but busy nanos come from the 4 outer windows only, each of
        // which wraps its nested sets — so busy ≲ 4 × 8 × 100µs + slack,
        // never the ~2× a double count would produce.
        assert!(d.busy_secs() < 2.0 * 4.0 * 8.0 * 100e-6 + 0.05, "{}", d.busy_secs());
    }

    #[test]
    fn merged_metrics_sum_fields() {
        let a = ExecMetrics {
            width: 2,
            workers: vec![
                WorkerStats { worker: 0, busy_ns: 5, idle_ns: 1, tasks: 2, steals: 1, injector_takes: 1 },
            ],
            latency: LatencySnapshot { buckets: vec![1, 2] },
        };
        let m = a.merged(&a);
        assert_eq!(m.total_tasks(), 4);
        assert_eq!(m.workers[0].busy_ns, 10);
        assert_eq!(m.latency.buckets, vec![2, 4]);
    }

    #[test]
    fn borrows_caller_stack() {
        let exec = Executor::new(3);
        let data = vec![1u64; 256];
        let sum = AtomicU64::new(0);
        exec.run_tasks(data.len(), |_, i| {
            sum.fetch_add(data[i], Ordering::Relaxed);
        })
        .unwrap();
        assert_eq!(sum.load(Ordering::Relaxed), 256);
    }
}
