//! PJRT client wrapper: load AOT HLO-text artifacts, compile once, execute
//! from the L3 hot path. Python is never involved at runtime — the rust
//! binary is self-contained once `make artifacts` has produced the
//! `.hlo.txt` files.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::Mutex;

use anyhow::{Context, Result};

/// A compiled artifact, ready to execute.
pub struct Executable {
    inner: xla::PjRtLoadedExecutable,
    pub path: PathBuf,
}

impl Executable {
    /// Execute with input literals; returns the tuple elements of the
    /// (tupled) result — aot.py lowers with `return_tuple=True`.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .inner
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.path.display()))?;
        let literal = result[0][0]
            .to_literal_sync()
            .context("device -> host transfer")?;
        literal.to_tuple().context("untupling result")
    }
}

/// Loads and caches compiled artifacts by path.
///
/// NOTE: the underlying PJRT client handle is `Rc`-based, so a `Runtime`
/// (and the executables it hands out) is **thread-local**: construct one
/// per thread that needs the accelerated combiner. The CPU client itself
/// multithreads its compute internally.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, Rc<Executable>>>,
    artifacts_dir: PathBuf,
}

impl Runtime {
    /// `artifacts_dir` is where `make artifacts` wrote the `.hlo.txt`
    /// files (default: `artifacts/` at the repo root).
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
        Ok(Self {
            client: xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
            cache: Mutex::new(HashMap::new()),
            artifacts_dir: artifacts_dir.into(),
        })
    }

    /// Default artifacts location, honoring `BLAZE_ARTIFACTS_DIR`.
    pub fn from_env() -> Result<Self> {
        let dir = std::env::var("BLAZE_ARTIFACTS_DIR").unwrap_or_else(|_| "artifacts".into());
        Self::new(dir)
    }

    /// Check artifact availability without constructing a client.
    pub fn artifacts_available() -> bool {
        let dir = std::env::var("BLAZE_ARTIFACTS_DIR").unwrap_or_else(|_| "artifacts".into());
        std::path::Path::new(&dir).join("manifest.txt").exists()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// True if the artifacts directory looks built.
    pub fn available(&self) -> bool {
        self.artifacts_dir.join("manifest.txt").exists()
    }

    /// Load + compile (cached) an artifact by stem, e.g. `"token_hist"`.
    pub fn load(&self, stem: &str) -> Result<Rc<Executable>> {
        let path = self.artifacts_dir.join(format!("{stem}.hlo.txt"));
        {
            let cache = self.cache.lock().unwrap();
            if let Some(exe) = cache.get(&path) {
                return Ok(Rc::clone(exe));
            }
        }
        let client = &self.client;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        let exe = Rc::new(Executable { inner: exe, path: path.clone() });
        self.cache.lock().unwrap().insert(path, Rc::clone(&exe));
        Ok(exe)
    }

    /// Parse `manifest.txt` (key=value lines) into a map.
    pub fn manifest(&self) -> Result<HashMap<String, i64>> {
        let path = self.artifacts_dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut m = HashMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("bad manifest line {line:?}"))?;
            m.insert(k.trim().to_string(), v.trim().parse::<i64>()?);
        }
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> Option<Runtime> {
        if !Runtime::artifacts_available() {
            eprintln!("skipping runtime test: artifacts/ not built (run `make artifacts`)");
            return None;
        }
        Some(Runtime::from_env().unwrap())
    }

    #[test]
    fn manifest_parses() {
        let Some(rt) = artifacts() else { return };
        let m = rt.manifest().unwrap();
        assert!(m["shard_tokens"] > 0);
        assert!(m["vocab"] > 0);
        assert_eq!(m["pad_id"], -1);
    }

    #[test]
    fn load_compile_execute_token_hist() {
        let Some(rt) = artifacts() else { return };
        let m = rt.manifest().unwrap();
        let n = m["shard_tokens"] as usize;
        let vocab = m["vocab"] as usize;
        let exe = rt.load("token_hist").unwrap();
        // All tokens = id 3, except a padded tail.
        let mut toks = vec![3i32; n];
        for t in toks.iter_mut().skip(n - 100) {
            *t = -1;
        }
        let input = xla::Literal::vec1(&toks);
        let out = exe.run(&[input]).unwrap();
        assert_eq!(out.len(), 1);
        let counts = out.into_iter().next().unwrap().to_vec::<i32>().unwrap();
        assert_eq!(counts.len(), vocab);
        assert_eq!(counts[3] as usize, n - 100);
        assert_eq!(counts.iter().map(|&c| c as i64).sum::<i64>(), (n - 100) as i64);
    }

    #[test]
    fn executable_cache_hits() {
        let Some(rt) = artifacts() else { return };
        let a = rt.load("token_hist").unwrap();
        let b = rt.load("token_hist").unwrap();
        assert!(Rc::ptr_eq(&a, &b));
    }
}
