//! Run reports: the tables the benches print (markdown + CSV) so every
//! figure in EXPERIMENTS.md regenerates from `cargo bench` output.

use std::fmt::Write as _;

/// A rectangular report table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    /// GitHub-flavoured markdown rendering.
    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "### {}\n", self.title);
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let padded: Vec<String> = cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect();
            format!("| {} |", padded.join(" | "))
        };
        let _ = writeln!(out, "{}", fmt_row(&self.headers, &widths));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        let _ = writeln!(out, "{}", fmt_row(&sep, &widths));
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &widths));
        }
        out
    }

    /// CSV rendering (quotes cells containing commas).
    pub fn to_csv(&self) -> String {
        let esc = |c: &String| -> String {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.clone()
            }
        };
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.headers.iter().map(esc).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(esc).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Append the CSV next to the bench run for EXPERIMENTS.md bookkeeping.
    pub fn write_csv(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

/// An ASCII bar chart, for reproducing the paper's figure in terminal
/// output ("converted to words per second").
pub fn ascii_bar_chart(title: &str, bars: &[(String, f64)], unit: &str) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "### {title}\n");
    let max = bars.iter().map(|(_, v)| *v).fold(f64::MIN, f64::max).max(1e-12);
    let label_w = bars.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
    for (label, value) in bars {
        let frac = value / max;
        let n = (frac * 50.0).round() as usize;
        let _ = writeln!(
            out,
            "{label:<label_w$}  {:<50}  {}",
            "#".repeat(n.max(1)),
            crate::util::stats::fmt_rate(*value, unit),
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("demo", &["engine", "words/s"]);
        t.row(&["Blaze".to_string(), "100".to_string()]);
        t.row(&["Spark".to_string(), "10".to_string()]);
        t
    }

    #[test]
    fn markdown_shape() {
        let md = sample().to_markdown();
        assert!(md.contains("### demo"));
        assert!(md.contains("| engine |"));
        assert!(md.lines().filter(|l| l.starts_with('|')).count() == 4);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(&["x,y".to_string(), "he said \"hi\"".to_string()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"he said \"\"hi\"\"\""));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = sample();
        t.row(&["only-one".to_string()]);
    }

    #[test]
    fn bar_chart_renders() {
        let chart = ascii_bar_chart(
            "words per second",
            &[("Blaze".to_string(), 1e8), ("Spark".to_string(), 1e7)],
            "words",
        );
        assert!(chart.contains("Blaze"));
        assert!(chart.contains("#"));
        assert!(chart.contains("100.00 Mwords/s"));
    }
}
