//! Tiered block storage — the hierarchy under the partition cache and
//! the bounded-memory exchange.
//!
//! The paper's comparison (and our PR 3 cache) assumes the working set
//! fits in memory: when it doesn't, the only answer used to be "evict and
//! recompute". This module supplies the missing storage hierarchy:
//!
//! * [`HeapSize`] — the single size-accounting trait (moved here from
//!   `engines::spark`; both engines, the cache, and the spill paths now
//!   share one estimator, mirroring Spark's `SizeEstimator`).
//! * [`BlockStore`] — the byte-level block I/O abstraction: checksummed
//!   `write`/`read`/`read_range` keyed by [`CacheKey`]. Implemented by
//!   [`DiskTier`] (real files in a per-job temp dir) and by in-memory test
//!   doubles; consumed as a trait object by the spill merger and the
//!   Spark-sim shuffle-block persistence.
//! * [`MemoryTier`] — the memory tier: the PR 3 `PartitionCache`
//!   semantics (type-erased values, byte budget, hit/miss/evict
//!   stats) with one addition: evicted entries that carry an encoder are
//!   handed back to the caller as demotion candidates instead of being
//!   dropped.
//! * [`policy`] — pluggable [`EvictionPolicy`]s behind the memory tier:
//!   LRU (the PR 3 behavior and default), SLRU (scan resistance), GDSF
//!   (byte-aware frequency), and a TinyLFU-style admission filter
//!   composable over any of them. [`PolicySpec`] is the `--cache-policy`
//!   knob.
//! * [`trace`] — the trace lab: record real `CacheKey` access traces from
//!   live runs ([`TraceRecorder`]) and replay them through any policy to
//!   measure hit-rates on real machinery (`benches/cache_policies.rs`).
//! * [`TieredStore`] — memory tier over an optional [`DiskTier`]:
//!   **demotes** encodable entries to disk under memory pressure and
//!   **promotes** them back on access. Without a disk tier it behaves
//!   exactly like the PR 3 cache (`crate::cache::PartitionCache` is now an
//!   alias for it).
//! * [`ExternalMerger`] — the bounded-memory exchange: combine in memory
//!   until the byte budget is hit, then sort-and-spill a run to the block
//!   store; `finish` merges every run with a loser-tree external merge
//!   ([`LoserTree`]), combining equal keys. Output is bit-identical to
//!   the all-in-memory fold for any associative+commutative combiner, at
//!   any budget down to zero.
//! * [`compress`] — zero-dep LZ4-style block compression, applied
//!   transparently by [`DiskTier`] on write/read (64 KiB frames so
//!   `read_range` streaming still works; `--compress off` = ablation).
//! * [`StorageStats`] / [`StorageCounters`] — spilled/demoted/promoted
//!   bytes, disk read/write wall, compression and key-dictionary
//!   savings, threaded into
//!   [`JobReport`](crate::mapreduce::JobReport) by both engines.
//!
//! # Logical vs stored bytes
//!
//! Compression makes "bytes" ambiguous, so every stat picks one side and
//! says so:
//!
//! * **Logical bytes** — the encoded payload *before* compression: what
//!   [`BlockStore::write`] returns, what [`BlockMeta::payload_len`] and
//!   the block checksum describe, what `spilled_bytes` /
//!   `shuffle_bytes` count, and the offset space `read_range` addresses.
//!   Shuffle counters stay logical so combine/serialization comparisons
//!   (the paper's subject) are not confounded by the codec.
//! * **Stored bytes** — what actually hits the file system *after*
//!   compression: what [`BlockStore::bytes_stored`],
//!   `disk_bytes_written`/`disk_bytes_read`, and therefore tier budget
//!   enforcement ([`TieredStore`]'s disk footprint) count.
//!   `compress_raw_bytes` vs `compress_stored_bytes` carries the ratio.
//!
//! # Namespace map
//!
//! Several clients can share one [`DiskTier`] (so one job's storage
//! activity lands in one [`StorageCounters`] cell). Block keys are the
//! cache's [`CacheKey`]; namespaces are partitioned so clients can never
//! collide:
//!
//! | namespace range | client |
//! |---|---|
//! | `0 .. 2^32` | partition-cache relation namespaces (relation index) |
//! | `2^32 .. NS_SHUFFLE_BLOCKS` | Spark-sim ad-hoc `persist()` ids |
//! | `NS_SHUFFLE_BLOCKS + shuffle_id` | persisted shuffle blocks |
//! | `NS_SPILL_BASE ..` | spill-run namespaces ([`fresh_spill_namespace`]) |

pub mod compress;
mod disk;
mod memory;
pub mod policy;
mod spill;
mod tiered;
pub mod trace;

pub use disk::DiskTier;
pub use memory::{EncodeFn, MemoryTier, Victim};
pub use policy::{BasePolicy, EvictionPolicy, PolicySpec};
pub use spill::{ExternalMerger, LoserTree};
pub use tiered::TieredStore;
pub use trace::TraceRecorder;

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

use crate::cache::CacheKey;

/// Heap-footprint estimate — what a record "costs" when materialized as
/// objects. Used by the memory tier's budget accounting, the spill
/// merger's in-flight accounting, and the Spark sim's GC model (the JVM
/// `SizeEstimator` role). Estimates are approximate by design; budget
/// invariants are exact with respect to them.
pub trait HeapSize {
    fn heap_bytes(&self) -> usize;
}

impl HeapSize for String {
    #[inline]
    fn heap_bytes(&self) -> usize {
        self.len() + 24
    }
}

macro_rules! impl_heap_prim {
    ($($t:ty),*) => {$(
        impl HeapSize for $t {
            #[inline]
            fn heap_bytes(&self) -> usize {
                16 // boxed primitive: header + value
            }
        }
    )*};
}
impl_heap_prim!(u8, u16, u32, u64, i8, i16, i32, i64, f32, f64, usize, bool);

impl<A: HeapSize, B: HeapSize> HeapSize for (A, B) {
    #[inline]
    fn heap_bytes(&self) -> usize {
        self.0.heap_bytes() + self.1.heap_bytes() + 16 // Tuple2 header
    }
}

impl<T: HeapSize> HeapSize for Vec<T> {
    fn heap_bytes(&self) -> usize {
        24 + self.iter().map(HeapSize::heap_bytes).sum::<usize>()
    }
}

/// First namespace reserved for persisted Spark-sim shuffle blocks
/// (`namespace = NS_SHUFFLE_BLOCKS + shuffle_id`).
pub const NS_SHUFFLE_BLOCKS: u64 = 1 << 41;

/// Spill-run namespaces start here; allocated process-wide so mergers
/// sharing a disk tier (or a temp dir) can never collide.
const NS_SPILL_BASE: u64 = 1 << 42;

static NEXT_SPILL_NS: AtomicU64 = AtomicU64::new(NS_SPILL_BASE);

/// A fresh namespace for one [`ExternalMerger`]'s spill runs.
pub fn fresh_spill_namespace() -> u64 {
    NEXT_SPILL_NS.fetch_add(1, Relaxed)
}

/// FNV-1a over `bytes`, continuing from `state` — the block checksum
/// (delegates to [`crate::hash::fnv1a_with`]: one FNV definition in the
/// crate). Streaming (chunk-by-chunk extension gives the same digest as
/// one pass), so spill-run cursors can verify a file they read in
/// ranges.
pub fn checksum(state: u64, bytes: &[u8]) -> u64 {
    crate::hash::fnv1a_with(state, bytes)
}

/// FNV-1a offset basis — the initial `state` for [`checksum`].
pub const CHECKSUM_SEED: u64 = crate::hash::FNV1A_OFFSET;

/// Size + checksum of one stored block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockMeta {
    /// Payload bytes (excluding the on-disk header).
    pub payload_len: u64,
    /// FNV-1a of the payload.
    pub checksum: u64,
}

/// Byte-level block storage: the interface the spill merger, the tiered
/// store's disk side, and the Spark-sim shuffle-block persistence all
/// write through. Keys are [`CacheKey`]s (see the module-level namespace
/// map). [`DiskTier`] is the production implementation; tests substitute
/// in-memory or failure-injecting doubles.
pub trait BlockStore: Send + Sync {
    /// Store a block, replacing any previous payload under `key`.
    /// Returns the **logical** payload length written (what `read` will
    /// hand back — implementations may store fewer bytes via
    /// compression; that footprint shows up in [`bytes_stored`](BlockStore::bytes_stored)).
    fn write(&self, key: CacheKey, payload: &[u8]) -> std::io::Result<u64>;

    /// Read a whole block back, verifying its checksum (a mismatch is an
    /// error, not a silent short read). `Ok(None)` = no such block.
    fn read(&self, key: &CacheKey) -> std::io::Result<Option<Vec<u8>>>;

    /// Read up to `max_len` payload bytes starting at `offset` —
    /// the streaming path for external-merge cursors. The checksum is
    /// *not* verified here; range readers accumulate it themselves (see
    /// [`checksum`]) and check against [`BlockStore::meta`] at the end.
    fn read_range(
        &self,
        key: &CacheKey,
        offset: u64,
        max_len: usize,
    ) -> std::io::Result<Option<Vec<u8>>>;

    /// Size + checksum of a stored block, if present.
    fn meta(&self, key: &CacheKey) -> Option<BlockMeta>;

    /// Drop one block. Returns whether it existed.
    fn delete(&self, key: &CacheKey) -> bool;

    /// Drop every block of `namespace` with `generation < keep_generation`
    /// — the generation-aware cleanup hook (the iterative driver retires
    /// dead state generations through this). Returns how many blocks were
    /// dropped.
    fn delete_generations_below(&self, namespace: u64, keep_generation: u64) -> usize;

    /// Blocks currently stored.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total **stored** bytes currently on disk (post-compression; the
    /// number tier budgets enforce against). Equals the summed logical
    /// payload lengths only for uncompressed implementations.
    fn bytes_stored(&self) -> u64;
}

/// Atomic accumulation cell for one storage domain (a job's disk tier, a
/// shared cache's spill side). Cheap to share; snapshot with
/// [`StorageCounters::snapshot`].
#[derive(Debug, Default)]
pub struct StorageCounters {
    spilled_bytes: AtomicU64,
    spill_runs: AtomicU64,
    spill_write_failures: AtomicU64,
    demoted_bytes: AtomicU64,
    demotions: AtomicU64,
    promoted_bytes: AtomicU64,
    promotions: AtomicU64,
    disk_bytes_written: AtomicU64,
    disk_bytes_read: AtomicU64,
    disk_write_ns: AtomicU64,
    disk_read_ns: AtomicU64,
    checksum_failures: AtomicU64,
    compress_raw_bytes: AtomicU64,
    compress_stored_bytes: AtomicU64,
    compress_ns: AtomicU64,
    decompress_ns: AtomicU64,
    dict_unique: AtomicU64,
    dict_refs: AtomicU64,
    dict_key_raw_bytes: AtomicU64,
    dict_key_enc_bytes: AtomicU64,
}

impl StorageCounters {
    pub fn record_spill(&self, bytes: u64) {
        self.spilled_bytes.fetch_add(bytes, Relaxed);
        self.spill_runs.fetch_add(1, Relaxed);
    }

    pub fn record_spill_failure(&self) {
        self.spill_write_failures.fetch_add(1, Relaxed);
    }

    pub fn record_demotion(&self, bytes: u64) {
        self.demoted_bytes.fetch_add(bytes, Relaxed);
        self.demotions.fetch_add(1, Relaxed);
    }

    pub fn record_promotion(&self, bytes: u64) {
        self.promoted_bytes.fetch_add(bytes, Relaxed);
        self.promotions.fetch_add(1, Relaxed);
    }

    pub fn record_disk_write(&self, bytes: u64, wall: std::time::Duration) {
        self.disk_bytes_written.fetch_add(bytes, Relaxed);
        self.disk_write_ns.fetch_add(wall.as_nanos() as u64, Relaxed);
    }

    pub fn record_disk_read(&self, bytes: u64, wall: std::time::Duration) {
        self.disk_bytes_read.fetch_add(bytes, Relaxed);
        self.disk_read_ns.fetch_add(wall.as_nanos() as u64, Relaxed);
    }

    pub fn record_checksum_failure(&self) {
        self.checksum_failures.fetch_add(1, Relaxed);
    }

    /// One block compressed on write: `raw` logical bytes became
    /// `stored` on-disk bytes in `wall`.
    pub fn record_compress(&self, raw: u64, stored: u64, wall: std::time::Duration) {
        self.compress_raw_bytes.fetch_add(raw, Relaxed);
        self.compress_stored_bytes.fetch_add(stored, Relaxed);
        self.compress_ns.fetch_add(wall.as_nanos() as u64, Relaxed);
    }

    /// Wall spent decompressing frames on the read path.
    pub fn record_decompress(&self, wall: std::time::Duration) {
        self.decompress_ns.fetch_add(wall.as_nanos() as u64, Relaxed);
    }

    /// Fold one run's/payload's key-dictionary savings in.
    pub fn record_dict(&self, d: &crate::util::ser::DictStats) {
        self.dict_unique.fetch_add(d.unique, Relaxed);
        self.dict_refs.fetch_add(d.refs, Relaxed);
        self.dict_key_raw_bytes.fetch_add(d.key_raw_bytes, Relaxed);
        self.dict_key_enc_bytes.fetch_add(d.key_enc_bytes, Relaxed);
    }

    pub fn snapshot(&self) -> StorageStats {
        StorageStats {
            spilled_bytes: self.spilled_bytes.load(Relaxed),
            spill_runs: self.spill_runs.load(Relaxed),
            spill_write_failures: self.spill_write_failures.load(Relaxed),
            demoted_bytes: self.demoted_bytes.load(Relaxed),
            demotions: self.demotions.load(Relaxed),
            promoted_bytes: self.promoted_bytes.load(Relaxed),
            promotions: self.promotions.load(Relaxed),
            disk_bytes_written: self.disk_bytes_written.load(Relaxed),
            disk_bytes_read: self.disk_bytes_read.load(Relaxed),
            disk_write_secs: self.disk_write_ns.load(Relaxed) as f64 / 1e9,
            disk_read_secs: self.disk_read_ns.load(Relaxed) as f64 / 1e9,
            checksum_failures: self.checksum_failures.load(Relaxed),
            compress_raw_bytes: self.compress_raw_bytes.load(Relaxed),
            compress_stored_bytes: self.compress_stored_bytes.load(Relaxed),
            compress_secs: self.compress_ns.load(Relaxed) as f64 / 1e9,
            decompress_secs: self.decompress_ns.load(Relaxed) as f64 / 1e9,
            dict_unique: self.dict_unique.load(Relaxed),
            dict_refs: self.dict_refs.load(Relaxed),
            dict_key_raw_bytes: self.dict_key_raw_bytes.load(Relaxed),
            dict_key_enc_bytes: self.dict_key_enc_bytes.load(Relaxed),
        }
    }
}

/// Snapshot of one storage domain's counters — what
/// [`JobReport::storage`](crate::mapreduce::JobReport::storage) carries.
/// All counters are cumulative since the cell's creation; job reports
/// hold per-job deltas ([`StorageStats::delta_since`]).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StorageStats {
    /// Bytes written as sorted spill runs by the bounded-memory exchange.
    pub spilled_bytes: u64,
    /// Sorted runs written.
    pub spill_runs: u64,
    /// Spill writes that failed (data stayed in memory; see
    /// [`ExternalMerger`]).
    pub spill_write_failures: u64,
    /// Bytes demoted memory → disk under cache pressure, in **heap
    /// estimate** units (what the memory budget is accounted in; the
    /// encoded on-disk footprint shows up in `disk_bytes_written`).
    pub demoted_bytes: u64,
    pub demotions: u64,
    /// Bytes promoted disk → memory on access (heap-estimate units;
    /// oversized entries served from disk without re-entering memory are
    /// not promotions).
    pub promoted_bytes: u64,
    pub promotions: u64,
    /// Disk-tier traffic in **stored** (post-compression) bytes — spill
    /// runs + demotions + persisted shuffle blocks all land here; this
    /// is what actually hit the file system (see the module docs on
    /// logical vs stored bytes).
    pub disk_bytes_written: u64,
    pub disk_bytes_read: u64,
    /// Wall spent in disk writes / reads (excluding codec wall, which is
    /// `compress_secs`/`decompress_secs`).
    pub disk_write_secs: f64,
    pub disk_read_secs: f64,
    pub checksum_failures: u64,
    /// Logical bytes offered to the block compressor on write.
    pub compress_raw_bytes: u64,
    /// What those bytes became on disk (`stored/raw` = the ratio;
    /// equals `raw` when `--compress off` or a block stayed raw).
    pub compress_stored_bytes: u64,
    /// Wall spent compressing / decompressing blocks.
    pub compress_secs: f64,
    pub decompress_secs: f64,
    /// Distinct keys written inline by shuffle/spill key dictionaries.
    pub dict_unique: u64,
    /// Key occurrences written as dictionary back-references.
    pub dict_refs: u64,
    /// Key bytes as plain encoding would have written (logical) vs as
    /// actually written through the dictionary.
    pub dict_key_raw_bytes: u64,
    pub dict_key_enc_bytes: u64,
}

impl StorageStats {
    /// No storage activity at all?
    pub fn is_zero(&self) -> bool {
        self.spilled_bytes == 0
            && self.spill_runs == 0
            && self.spill_write_failures == 0
            && self.demoted_bytes == 0
            && self.demotions == 0
            && self.promoted_bytes == 0
            && self.promotions == 0
            && self.disk_bytes_written == 0
            && self.disk_bytes_read == 0
            && self.checksum_failures == 0
            && self.compress_raw_bytes == 0
            && self.compress_stored_bytes == 0
            && self.dict_unique == 0
            && self.dict_refs == 0
            && self.dict_key_raw_bytes == 0
            && self.dict_key_enc_bytes == 0
    }

    /// Fold an exchange payload dictionary's savings in (the Blaze
    /// in-memory shuffle has no counters cell; its per-node
    /// [`DictStats`](crate::util::ser::DictStats) merge here).
    pub fn add_dict(&mut self, d: &crate::util::ser::DictStats) {
        self.dict_unique += d.unique;
        self.dict_refs += d.refs;
        self.dict_key_raw_bytes += d.key_raw_bytes;
        self.dict_key_enc_bytes += d.key_enc_bytes;
    }

    /// The dictionary slice of these stats as a [`DictStats`] — what the
    /// per-stage report rows carry.
    pub fn dict_stats(&self) -> crate::util::ser::DictStats {
        crate::util::ser::DictStats {
            unique: self.dict_unique,
            refs: self.dict_refs,
            key_raw_bytes: self.dict_key_raw_bytes,
            key_enc_bytes: self.dict_key_enc_bytes,
        }
    }

    /// Field-wise sum — aggregate stats from several storage domains (a
    /// job's exchange spill tier + the shared cache's spill side) or
    /// several stages/rounds.
    pub fn merged(&self, other: &StorageStats) -> StorageStats {
        StorageStats {
            spilled_bytes: self.spilled_bytes + other.spilled_bytes,
            spill_runs: self.spill_runs + other.spill_runs,
            spill_write_failures: self.spill_write_failures + other.spill_write_failures,
            demoted_bytes: self.demoted_bytes + other.demoted_bytes,
            demotions: self.demotions + other.demotions,
            promoted_bytes: self.promoted_bytes + other.promoted_bytes,
            promotions: self.promotions + other.promotions,
            disk_bytes_written: self.disk_bytes_written + other.disk_bytes_written,
            disk_bytes_read: self.disk_bytes_read + other.disk_bytes_read,
            disk_write_secs: self.disk_write_secs + other.disk_write_secs,
            disk_read_secs: self.disk_read_secs + other.disk_read_secs,
            checksum_failures: self.checksum_failures + other.checksum_failures,
            compress_raw_bytes: self.compress_raw_bytes + other.compress_raw_bytes,
            compress_stored_bytes: self.compress_stored_bytes + other.compress_stored_bytes,
            compress_secs: self.compress_secs + other.compress_secs,
            decompress_secs: self.decompress_secs + other.decompress_secs,
            dict_unique: self.dict_unique + other.dict_unique,
            dict_refs: self.dict_refs + other.dict_refs,
            dict_key_raw_bytes: self.dict_key_raw_bytes + other.dict_key_raw_bytes,
            dict_key_enc_bytes: self.dict_key_enc_bytes + other.dict_key_enc_bytes,
        }
    }

    /// Counters accumulated since `earlier` — one job's (or round's)
    /// activity against a shared cell.
    pub fn delta_since(&self, earlier: &StorageStats) -> StorageStats {
        StorageStats {
            spilled_bytes: self.spilled_bytes - earlier.spilled_bytes,
            spill_runs: self.spill_runs - earlier.spill_runs,
            spill_write_failures: self.spill_write_failures - earlier.spill_write_failures,
            demoted_bytes: self.demoted_bytes - earlier.demoted_bytes,
            demotions: self.demotions - earlier.demotions,
            promoted_bytes: self.promoted_bytes - earlier.promoted_bytes,
            promotions: self.promotions - earlier.promotions,
            disk_bytes_written: self.disk_bytes_written - earlier.disk_bytes_written,
            disk_bytes_read: self.disk_bytes_read - earlier.disk_bytes_read,
            disk_write_secs: self.disk_write_secs - earlier.disk_write_secs,
            disk_read_secs: self.disk_read_secs - earlier.disk_read_secs,
            checksum_failures: self.checksum_failures - earlier.checksum_failures,
            compress_raw_bytes: self.compress_raw_bytes - earlier.compress_raw_bytes,
            compress_stored_bytes: self.compress_stored_bytes - earlier.compress_stored_bytes,
            compress_secs: self.compress_secs - earlier.compress_secs,
            decompress_secs: self.decompress_secs - earlier.decompress_secs,
            dict_unique: self.dict_unique - earlier.dict_unique,
            dict_refs: self.dict_refs - earlier.dict_refs,
            dict_key_raw_bytes: self.dict_key_raw_bytes - earlier.dict_key_raw_bytes,
            dict_key_enc_bytes: self.dict_key_enc_bytes - earlier.dict_key_enc_bytes,
        }
    }
}

impl std::fmt::Display for StorageStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        use crate::util::stats::fmt_bytes;
        write!(
            f,
            "spilled={} in {} run(s), demoted={} promoted={} disk w/r={}/{} \
             ({:.3}s/{:.3}s)",
            fmt_bytes(self.spilled_bytes),
            self.spill_runs,
            fmt_bytes(self.demoted_bytes),
            fmt_bytes(self.promoted_bytes),
            fmt_bytes(self.disk_bytes_written),
            fmt_bytes(self.disk_bytes_read),
            self.disk_write_secs,
            self.disk_read_secs,
        )?;
        if self.compress_raw_bytes > 0 {
            write!(
                f,
                " compress={}→{} ({:.2}x, {:.3}s/{:.3}s)",
                fmt_bytes(self.compress_raw_bytes),
                fmt_bytes(self.compress_stored_bytes),
                self.compress_raw_bytes as f64 / self.compress_stored_bytes.max(1) as f64,
                self.compress_secs,
                self.decompress_secs,
            )?;
        }
        if self.dict_key_raw_bytes > 0 {
            write!(
                f,
                " dict-keys={}→{} ({} uniq, {} refs)",
                fmt_bytes(self.dict_key_raw_bytes),
                fmt_bytes(self.dict_key_enc_bytes),
                self.dict_unique,
                self.dict_refs,
            )?;
        }
        if self.spill_write_failures > 0 || self.checksum_failures > 0 {
            write!(
                f,
                " [spill_failures={} checksum_failures={}]",
                self.spill_write_failures, self.checksum_failures
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checksum_is_streamable() {
        let data = b"the quick brown fox jumps over the lazy dog";
        let whole = checksum(CHECKSUM_SEED, data);
        let mut h = CHECKSUM_SEED;
        for chunk in data.chunks(7) {
            h = checksum(h, chunk);
        }
        assert_eq!(whole, h);
        assert_ne!(whole, checksum(CHECKSUM_SEED, b"the quick brown fox"));
    }

    #[test]
    fn counters_snapshot_and_delta() {
        let c = StorageCounters::default();
        c.record_spill(100);
        c.record_disk_write(100, std::time::Duration::from_millis(2));
        let before = c.snapshot();
        c.record_spill(50);
        c.record_promotion(30);
        let d = c.snapshot().delta_since(&before);
        assert_eq!(d.spilled_bytes, 50);
        assert_eq!(d.spill_runs, 1);
        assert_eq!(d.promoted_bytes, 30);
        assert_eq!(d.disk_bytes_written, 0);
    }

    #[test]
    fn merged_sums_fields() {
        let a = StorageStats { spilled_bytes: 10, spill_runs: 1, ..Default::default() };
        let b = StorageStats { spilled_bytes: 5, demoted_bytes: 7, ..Default::default() };
        let m = a.merged(&b);
        assert_eq!(m.spilled_bytes, 15);
        assert_eq!(m.spill_runs, 1);
        assert_eq!(m.demoted_bytes, 7);
        assert!(!m.is_zero());
        assert!(StorageStats::default().is_zero());
    }

    #[test]
    fn compress_and_dict_counters_flow_through() {
        let c = StorageCounters::default();
        c.record_compress(1000, 250, std::time::Duration::from_millis(1));
        c.record_dict(&crate::util::ser::DictStats {
            unique: 3,
            refs: 7,
            key_raw_bytes: 100,
            key_enc_bytes: 40,
        });
        let s = c.snapshot();
        assert_eq!(s.compress_raw_bytes, 1000);
        assert_eq!(s.compress_stored_bytes, 250);
        assert!(s.compress_secs > 0.0);
        assert_eq!(s.dict_unique, 3);
        assert_eq!(s.dict_refs, 7);
        assert!(!s.is_zero());
        let text = format!("{s}");
        assert!(text.contains("compress="), "{text}");
        assert!(text.contains("dict-keys="), "{text}");
        let mut base = StorageStats::default();
        base.add_dict(&crate::util::ser::DictStats {
            unique: 1,
            refs: 2,
            key_raw_bytes: 10,
            key_enc_bytes: 5,
        });
        let m = s.merged(&base);
        assert_eq!(m.dict_unique, 4);
        assert_eq!(m.dict_refs, 9);
        assert_eq!(m.delta_since(&s).dict_refs, 2);
    }

    #[test]
    fn spill_namespaces_are_fresh_and_reserved() {
        let a = fresh_spill_namespace();
        let b = fresh_spill_namespace();
        assert_ne!(a, b);
        assert!(a >= NS_SPILL_BASE && b >= NS_SPILL_BASE);
        assert!(NS_SHUFFLE_BLOCKS < NS_SPILL_BASE);
    }

    #[test]
    fn display_mentions_spill_volume() {
        let s = StorageStats { spilled_bytes: 2048, spill_runs: 2, ..Default::default() };
        let text = format!("{s}");
        assert!(text.contains("2 run(s)"), "{text}");
    }
}
