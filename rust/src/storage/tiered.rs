//! `TieredStore` — the memory tier backed by an optional disk tier:
//! disk-backed persistence instead of lossy evict-and-recompute.
//!
//! Without a disk tier this is exactly the PR 3 partition cache
//! ([`crate::cache::PartitionCache`] is an alias for this type): typed
//! entries, byte budget, LRU, hit/miss/evict stats. With one attached
//! (see [`TieredStore::with_spill`]):
//!
//! * entries inserted through [`put_encoded`](TieredStore::put_encoded)
//!   carry a serializer; when budget pressure evicts them they are
//!   **demoted** — serialized and written to the [`DiskTier`] — instead
//!   of dropped;
//! * [`get_encoded`](TieredStore::get_encoded) misses in memory fall
//!   through to the disk tier; a disk hit is decoded, **promoted** back
//!   into memory (possibly demoting colder entries), and counted as a
//!   storage hit;
//! * entries too large for the whole memory budget go straight to disk —
//!   nothing is ever rejected for size when a disk tier exists.
//!
//! `CacheBudget::Bytes(0)` still means *storage off entirely* (the
//! recompute ablation): nothing is admitted to either tier, so planners
//! keep eliding cache points exactly as before.
//!
//! Plain [`put`](TieredStore::put)/[`get_typed`](TieredStore::get_typed)
//! entries (no serializer) keep the PR 3 semantics: evicted means gone.
//!
//! # Namespace quotas
//!
//! [`set_namespace_quota`](TieredStore::set_namespace_quota) caps the
//! memory tier's residency over a half-open namespace range — the job
//! service gives each tenant a contiguous namespace range, so this is
//! the per-tenant memory quota. An insert that would push its range
//! over the cap is demoted to the disk tier at birth (or rejected when
//! no disk tier is attached), and promotion out of the disk tier
//! respects the cap too. The global budget and the eviction policy are
//! unchanged — quotas only decide *whose* entries may occupy memory.

use std::any::Any;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering::Relaxed};
use std::sync::{Arc, Mutex};

use crate::cache::{CacheBudget, CacheKey, CacheStats};
use crate::util::ser::{Decode, Encode};

use super::trace::{TraceOp, TraceRecorder};
use super::{BlockStore, DiskTier, EncodeFn, MemoryTier, PolicySpec, StorageStats, Victim};

/// One per-tenant memory cap: at most `bytes` of the memory tier may
/// be occupied by entries whose namespace falls in `[lo, hi)`.
#[derive(Clone, Copy, Debug)]
struct NamespaceQuota {
    lo: u64,
    hi: u64,
    bytes: u64,
}

/// Memory tier + optional disk tier (see module docs).
pub struct TieredStore {
    mem: MemoryTier,
    disk: Option<Arc<DiskTier>>,
    /// Per-namespace-range memory caps (see module docs). The lock is
    /// held across the quota check *and* the memory insert so two racing
    /// writers of one tenant cannot both squeeze under the cap.
    quotas: Mutex<Vec<NamespaceQuota>>,
    /// Original `HeapSize` estimates of entries currently parked on
    /// disk. Promotion re-admits an entry at the estimate it was first
    /// admitted under — wire size and heap estimate are different units,
    /// and mixing them would let a demote/promote cycle silently exceed
    /// the memory budget (encoded payloads are usually much smaller than
    /// their heap form).
    demoted_est: Mutex<HashMap<CacheKey, u64>>,
    /// Optional access-trace sink (the trace lab; see [`super::trace`]).
    trace: Mutex<Option<Arc<TraceRecorder>>>,
    trace_active: AtomicBool,
}

impl std::fmt::Debug for TieredStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TieredStore")
            .field("budget", &self.budget())
            .field("policy", &self.policy())
            .field("stats", &self.stats())
            .field("spill", &self.disk.is_some())
            .finish()
    }
}

impl TieredStore {
    /// Memory-only store — the PR 3 partition cache, verbatim (LRU).
    pub fn new(budget: CacheBudget) -> Self {
        Self::with_policy(budget, PolicySpec::default())
    }

    /// Memory-only store evicting per `policy`.
    pub fn with_policy(budget: CacheBudget, policy: PolicySpec) -> Self {
        Self {
            mem: MemoryTier::with_policy(budget, policy),
            disk: None,
            quotas: Mutex::new(Vec::new()),
            demoted_est: Mutex::new(HashMap::new()),
            trace: Mutex::new(None),
            trace_active: AtomicBool::new(false),
        }
    }

    /// Memory tier over `disk`: encodable entries demote on pressure and
    /// promote on access (LRU eviction).
    pub fn with_spill(budget: CacheBudget, disk: Arc<DiskTier>) -> Self {
        Self::with_spill_policy(budget, disk, PolicySpec::default())
    }

    /// [`Self::with_spill`] with an explicit eviction policy.
    pub fn with_spill_policy(budget: CacheBudget, disk: Arc<DiskTier>, policy: PolicySpec) -> Self {
        Self {
            mem: MemoryTier::with_policy(budget, policy),
            disk: Some(disk),
            quotas: Mutex::new(Vec::new()),
            demoted_est: Mutex::new(HashMap::new()),
            trace: Mutex::new(None),
            trace_active: AtomicBool::new(false),
        }
    }

    pub fn budget(&self) -> CacheBudget {
        self.mem.budget()
    }

    /// The eviction policy the memory tier was built with.
    pub fn policy(&self) -> PolicySpec {
        self.mem.policy()
    }

    /// Cap memory-tier residency for namespaces in `[lo, hi)` at
    /// `bytes` (see module docs — this is the service layer's per-tenant
    /// quota). Replaces an existing quota over the identical range.
    /// Entries already resident are not expelled; the cap binds from the
    /// next insert on.
    pub fn set_namespace_quota(&self, lo: u64, hi: u64, bytes: u64) {
        let mut quotas = self.quotas.lock().unwrap();
        if let Some(q) = quotas.iter_mut().find(|q| q.lo == lo && q.hi == hi) {
            q.bytes = bytes;
        } else {
            quotas.push(NamespaceQuota { lo, hi, bytes });
        }
    }

    /// The quota cap covering `namespace`, if one is set.
    pub fn namespace_quota_bytes(&self, namespace: u64) -> Option<u64> {
        let quotas = self.quotas.lock().unwrap();
        quotas.iter().find(|q| namespace >= q.lo && namespace < q.hi).map(|q| q.bytes)
    }

    /// Estimated memory-tier bytes resident across namespaces `[lo, hi)`
    /// — the usage side of [`set_namespace_quota`](Self::set_namespace_quota).
    pub fn bytes_in_namespace_range(&self, lo: u64, hi: u64) -> u64 {
        self.mem.bytes_in_namespace_range(lo, hi)
    }

    /// Would admitting `est` bytes under `key` keep its namespace range
    /// within quota? Ranges without a quota always pass. An overwrite is
    /// credited the bytes of the entry it replaces.
    fn quota_allows(&self, quotas: &[NamespaceQuota], key: &CacheKey, est: u64) -> bool {
        let Some(q) = quotas.iter().find(|q| key.namespace >= q.lo && key.namespace < q.hi)
        else {
            return true;
        };
        let resident = self.mem.bytes_in_namespace_range(q.lo, q.hi);
        let replaced = self.mem.entry_bytes(key).unwrap_or(0);
        resident.saturating_sub(replaced) + est <= q.bytes
    }

    /// Attach an access-trace recorder: every subsequent `get`/`put`
    /// crossing the store's public surface is logged (tier-internal
    /// demotion/promotion is not — replay regenerates it).
    pub fn attach_recorder(&self, rec: Arc<TraceRecorder>) {
        *self.trace.lock().unwrap() = Some(rec);
        self.trace_active.store(true, Relaxed);
    }

    fn trace(&self, op: TraceOp, key: CacheKey, bytes: u64) {
        if !self.trace_active.load(Relaxed) {
            return;
        }
        if let Some(rec) = self.trace.lock().unwrap().as_ref() {
            rec.record(op, key, bytes);
        }
    }

    /// The disk tier, if one is attached.
    pub fn disk(&self) -> Option<&Arc<DiskTier>> {
        self.disk.as_ref()
    }

    /// `true` when the budget is `Bytes(0)`: storage is off entirely —
    /// nothing is admitted to either tier, so the recompute ablation
    /// measures recomputation and not a caching-shaped detour.
    pub fn is_disabled(&self) -> bool {
        self.mem.is_disabled()
    }

    /// Could an entry of `bytes` estimated size be stored at all? With a
    /// disk tier attached everything fits (oversized entries go straight
    /// to disk); callers use this to skip the deep clone a doomed insert
    /// would need. Does not touch the stats.
    pub fn fits(&self, bytes: u64) -> bool {
        if self.is_disabled() {
            return false;
        }
        if self.disk.is_some() {
            return true;
        }
        self.mem.fits(bytes)
    }

    /// Look up a partition in the **memory tier** (a hit bumps recency
    /// and is counted). Entries demoted to disk are reachable through
    /// [`get_encoded`](Self::get_encoded) only — plain lookups keep the
    /// PR 3 contract.
    pub fn get(&self, key: &CacheKey) -> Option<Arc<dyn Any + Send + Sync>> {
        self.trace(TraceOp::Get, *key, 0);
        self.mem.get(key)
    }

    /// [`get`](Self::get) plus a downcast. A type mismatch behaves — and
    /// is counted — as a **miss** (the caller will recompute).
    pub fn get_typed<T: Any + Send + Sync>(&self, key: &CacheKey) -> Option<Arc<T>> {
        match self.get(key)?.downcast::<T>() {
            Ok(v) => Some(v),
            Err(_) => {
                self.mem.reclassify_hit_as_miss();
                None
            }
        }
    }

    /// Insert a partition with no serializer (the PR 3 entry point):
    /// budget pressure may evict it for good. Returns `false` (and counts
    /// a rejection) when the entry alone exceeds the memory budget.
    /// Victims that *do* carry serializers (inserted via
    /// [`put_encoded`](Self::put_encoded)) still demote to disk. A
    /// successful insert supersedes any demoted disk copy of the same
    /// key — the tiers never hold two versions of one entry.
    pub fn put(&self, key: CacheKey, value: Arc<dyn Any + Send + Sync>, bytes: u64) -> bool {
        self.trace(TraceOp::Put, key, bytes);
        let quotas = self.quotas.lock().unwrap();
        if !self.quota_allows(&quotas, &key, bytes) {
            // No serializer, so there is nothing to demote at birth: an
            // over-quota plain entry is simply refused.
            self.mem.count_rejection();
            return false;
        }
        let (admitted, victims) = self.mem.put(key, value, bytes, None);
        drop(quotas);
        if admitted {
            self.drop_disk_copy(&key);
        }
        self.demote(victims);
        admitted
    }

    /// Retire a (now superseded) demoted copy of `key` from the disk
    /// tier — every write path calls this so the tiers stay coherent.
    fn drop_disk_copy(&self, key: &CacheKey) {
        if let Some(disk) = &self.disk {
            disk.delete(key);
        }
        self.demoted_est.lock().unwrap().remove(key);
    }

    /// Insert a partition that can migrate between tiers: the value's
    /// wire form is captured so eviction demotes it to the disk tier
    /// instead of dropping it. Entries larger than the whole memory
    /// budget are demoted immediately. Returns whether the entry is now
    /// stored in *some* tier.
    pub fn put_encoded<T: Any + Send + Sync + Encode>(
        &self,
        key: CacheKey,
        value: Arc<T>,
        bytes: u64,
    ) -> bool {
        self.trace(TraceOp::Put, key, bytes);
        if self.is_disabled() || self.disk.is_none() {
            // No disk (or storage off): degrade to the memory-only path,
            // keeping the serializer so a later spill attachment — or a
            // plain-put eviction — can still demote it.
            let quotas = self.quotas.lock().unwrap();
            if !self.quota_allows(&quotas, &key, bytes) {
                self.mem.count_rejection();
                return false;
            }
            let encode = self.encoder(&value);
            let erased: Arc<dyn Any + Send + Sync> = value;
            let (admitted, victims) = self.mem.put(key, erased, bytes, Some(encode));
            drop(quotas);
            self.demote(victims);
            return admitted;
        }
        let disk = self.disk.as_ref().unwrap();
        let quotas = self.quotas.lock().unwrap();
        if !self.mem.fits(bytes) || !self.quota_allows(&quotas, &key, bytes) {
            // Too large for the whole memory tier, or the key's namespace
            // range is out of quota headroom: straight to disk (a
            // demotion at birth). Any older in-memory version of the key
            // is superseded — removing it also releases its quota share.
            drop(quotas);
            let payload = value.to_bytes();
            return match disk.write(key, &payload) {
                Ok(_) => {
                    self.mem.remove(&key);
                    self.demoted_est.lock().unwrap().insert(key, bytes);
                    disk.counters().record_demotion(bytes);
                    true
                }
                Err(_) => {
                    disk.counters().record_spill_failure();
                    false
                }
            };
        }
        let encode = self.encoder(&value);
        let erased: Arc<dyn Any + Send + Sync> = value;
        let (admitted, victims) = self.mem.put(key, erased, bytes, Some(Arc::clone(&encode)));
        drop(quotas);
        if admitted {
            // The fresh insert supersedes any demoted copy of this key.
            self.drop_disk_copy(&key);
            self.demote(victims);
            return true;
        }
        // The admission filter refused the newcomer for memory. A disk
        // tier is attached, so the block must not be lost: park it on
        // disk (exactly a demotion-at-birth), superseding older copies.
        debug_assert!(victims.is_empty(), "a rejected insert evicts nothing");
        let payload = encode();
        match disk.write(key, &payload) {
            Ok(_) => {
                self.demoted_est.lock().unwrap().insert(key, bytes);
                disk.counters().record_demotion(bytes);
                true
            }
            Err(_) => {
                disk.counters().record_spill_failure();
                false
            }
        }
    }

    /// Typed lookup that falls through to the disk tier: a memory miss
    /// consults the disk; a disk hit is decoded and counted as a cache
    /// **hit**, and — when it fits — promoted back into the memory tier
    /// at its *original* heap estimate (possibly demoting colder
    /// entries). Entries too large to ever re-enter memory stay on disk
    /// and are served from there without counting promotions. Corrupt
    /// blocks (checksum or decode failure) are dropped and read as
    /// misses — the caller recomputes.
    pub fn get_encoded<T: Any + Send + Sync + Encode + Decode>(
        &self,
        key: &CacheKey,
    ) -> Option<Arc<T>> {
        if let Some(hit) = self.get_typed::<T>(key) {
            return Some(hit);
        }
        // The memory miss is already counted; try the tier below.
        let disk = self.disk.as_ref()?;
        let payload = match disk.read(key) {
            Ok(Some(bytes)) => bytes,
            Ok(None) => return None,
            Err(_) => {
                // Checksum failure was counted by the tier; drop the bad
                // block so the recomputed value can take its place.
                disk.delete(key);
                self.demoted_est.lock().unwrap().remove(key);
                return None;
            }
        };
        let value = match T::from_bytes(&payload) {
            Ok(v) => Arc::new(v),
            Err(_) => {
                disk.counters().record_checksum_failure();
                disk.delete(key);
                self.demoted_est.lock().unwrap().remove(key);
                return None;
            }
        };
        // Re-admit at the estimate the entry was originally admitted
        // under (falling back to the wire size for blocks whose estimate
        // was lost) — the budget invariant stays in one unit.
        let est = self
            .demoted_est
            .lock()
            .unwrap()
            .get(key)
            .copied()
            .unwrap_or(payload.len() as u64);
        self.mem.reclassify_miss_as_hit();
        let quotas = self.quotas.lock().unwrap();
        // Promotion respects the namespace quota too: an out-of-quota
        // tenant's blocks are served from disk without re-entering memory.
        if self.mem.fits(est) && self.quota_allows(&quotas, key, est) {
            let _span = crate::trace::span_arg(crate::trace::SpanCat::Promote, "promote", est);
            let encode = self.encoder(&value);
            let erased: Arc<dyn Any + Send + Sync> = Arc::clone(&value);
            let (admitted, victims) = self.mem.put(*key, erased, est, Some(encode));
            drop(quotas);
            self.demote(victims);
            if admitted {
                // Tiers stay exclusive: the promoted copy owns the entry
                // now (a later demotion re-serializes it).
                disk.delete(key);
                self.demoted_est.lock().unwrap().remove(key);
                disk.counters().record_promotion(est);
            }
        }
        Some(value)
    }

    /// Capture a value's serializer for demotion.
    fn encoder<T: Any + Send + Sync + Encode>(&self, value: &Arc<T>) -> EncodeFn {
        let v = Arc::clone(value);
        Arc::new(move || v.to_bytes())
    }

    /// Write demotable eviction victims to the disk tier (no-op without
    /// one, and for victims that carry no serializer). Demoted bytes are
    /// counted at the victim's heap estimate — the unit promotion
    /// re-admits it under.
    fn demote(&self, victims: Vec<Victim>) {
        let Some(disk) = &self.disk else { return };
        for victim in victims {
            let Some(encode) = victim.encode else { continue };
            let _span =
                crate::trace::span_arg(crate::trace::SpanCat::Demote, "demote", victim.bytes);
            let payload = encode();
            match disk.write(victim.key, &payload) {
                Ok(_) => {
                    self.demoted_est.lock().unwrap().insert(victim.key, victim.bytes);
                    disk.counters().record_demotion(victim.bytes);
                }
                Err(_) => disk.counters().record_spill_failure(),
            }
        }
    }

    /// Is `key` resident in either tier? Does not touch recency or stats.
    pub fn contains(&self, key: &CacheKey) -> bool {
        self.mem.contains(key)
            || self.disk.as_ref().is_some_and(|d| d.meta(key).is_some())
    }

    /// Drop every entry of `namespace` older than `keep_generation`, in
    /// both tiers (the iterative driver's dead-generation hook; on the
    /// disk side this is the generation-aware spill-file cleanup).
    /// Returns how many entries were dropped across tiers.
    pub fn invalidate_generations_below(&self, namespace: u64, keep_generation: u64) -> usize {
        let from_mem = self.mem.invalidate_generations_below(namespace, keep_generation);
        let from_disk = self
            .disk
            .as_ref()
            .map_or(0, |d| d.delete_generations_below(namespace, keep_generation));
        self.demoted_est
            .lock()
            .unwrap()
            .retain(|k, _| k.namespace != namespace || k.generation >= keep_generation);
        from_mem + from_disk
    }

    /// Estimated bytes resident in the memory tier.
    pub fn bytes_cached(&self) -> u64 {
        self.mem.bytes_cached()
    }

    /// Payload bytes currently parked in the disk tier.
    pub fn bytes_spilled(&self) -> u64 {
        self.disk.as_ref().map_or(0, |d| d.bytes_stored())
    }

    /// Entries resident in the memory tier.
    pub fn len(&self) -> usize {
        self.mem.len()
    }

    pub fn is_empty(&self) -> bool {
        self.mem.is_empty()
    }

    /// Drop every entry in both tiers (counters are kept — cumulative).
    pub fn clear(&self) {
        self.mem.clear();
        self.demoted_est.lock().unwrap().clear();
        if let Some(disk) = &self.disk {
            disk.clear_all();
        }
    }

    /// Hit/miss/evict/reject counters (the PR 3 [`CacheStats`] surface;
    /// disk hits count as hits).
    pub fn stats(&self) -> CacheStats {
        self.mem.stats()
    }

    /// Spill-side counters: demoted/promoted bytes, disk read/write wall.
    /// All zeros when no disk tier is attached.
    pub fn storage_stats(&self) -> StorageStats {
        self.disk.as_ref().map_or_else(StorageStats::default, |d| d.counters().snapshot())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CacheBudget;

    fn key(p: u64) -> CacheKey {
        CacheKey { namespace: 0, generation: 0, partition: p, splits: 1 }
    }

    fn store(budget_bytes: u64) -> TieredStore {
        TieredStore::with_spill(CacheBudget::Bytes(budget_bytes), Arc::new(DiskTier::new(None)))
    }

    #[test]
    fn pressure_demotes_and_access_promotes() {
        let s = store(100);
        let a = Arc::new(vec![1u64, 2, 3]);
        let b = Arc::new(vec![4u64]);
        assert!(s.put_encoded(key(1), a, 80));
        assert!(s.put_encoded(key(2), b, 80)); // evicts + demotes key 1
        assert_eq!(s.stats().evictions, 1);
        let st = s.storage_stats();
        assert_eq!(st.demotions, 1);
        assert!(st.demoted_bytes > 0);
        assert!(s.contains(&key(1)), "demoted, not dropped");
        // Access promotes it back (demoting key 2 in turn).
        let back = s.get_encoded::<Vec<u64>>(&key(1)).expect("disk hit");
        assert_eq!(*back, vec![1, 2, 3]);
        let st = s.storage_stats();
        assert_eq!(st.promotions, 1);
        assert_eq!(st.demotions, 2, "promotion displaced the other entry");
        let cs = s.stats();
        assert_eq!(cs.hits, 1, "disk hit counts as a hit: {cs:?}");
        assert_eq!(cs.misses, 0, "{cs:?}");
    }

    #[test]
    fn oversized_entries_go_straight_to_disk() {
        let s = store(64);
        let big = Arc::new(vec![7u64; 100]);
        assert!(s.put_encoded(key(1), big, 1000), "stored on disk");
        assert_eq!(s.len(), 0, "not in memory");
        assert!(s.bytes_spilled() > 0);
        assert_eq!(s.storage_stats().demotions, 1);
        let back = s.get_encoded::<Vec<u64>>(&key(1)).expect("served from disk");
        assert_eq!(back.len(), 100);
        // It can never re-enter memory, so it stays on disk and is not a
        // promotion — no matter how often it is read.
        assert!(s.get_encoded::<Vec<u64>>(&key(1)).is_some());
        let st = s.storage_stats();
        assert_eq!(st.promotions, 0, "{st:?}");
        assert_eq!(s.len(), 0);
        assert!(s.bytes_spilled() > 0);
    }

    #[test]
    fn promotion_readmits_at_the_original_estimate() {
        // Heap estimates (100) are far larger than the wire form of a
        // one-element Vec<u64> (~12 bytes): if promotion re-admitted at
        // wire size, both entries would fit a 150-byte budget at once.
        let s = store(150);
        assert!(s.put_encoded(key(1), Arc::new(vec![1u64]), 100));
        assert!(s.put_encoded(key(2), Arc::new(vec![2u64]), 100)); // demotes 1
        assert!(s.get_encoded::<Vec<u64>>(&key(1)).is_some()); // promotes 1, demotes 2
        assert_eq!(s.len(), 1, "estimates keep the budget to one resident entry");
        assert!(s.bytes_cached() <= 150);
        assert_eq!(s.storage_stats().promoted_bytes, 100, "heap estimate, not wire size");
    }

    #[test]
    fn fits_is_true_with_a_disk_tier() {
        assert!(store(64).fits(1 << 40));
        let memory_only = TieredStore::new(CacheBudget::Bytes(64));
        assert!(!memory_only.fits(65));
        assert!(!store(0).fits(1), "budget 0 = storage off, even with disk");
    }

    #[test]
    fn budget_zero_disables_both_tiers() {
        let s = store(0);
        assert!(s.is_disabled());
        assert!(!s.put_encoded(key(1), Arc::new(vec![1u64]), 1));
        assert!(s.get_encoded::<Vec<u64>>(&key(1)).is_none());
        assert_eq!(s.bytes_spilled(), 0);
        assert_eq!(s.stats().rejected, 1);
    }

    #[test]
    fn generation_invalidation_reaches_the_disk_tier() {
        let s = store(40);
        for generation in 0..3u64 {
            let k = CacheKey { namespace: 5, generation, partition: 0, splits: 1 };
            // 30-byte entries: each insert demotes the previous one.
            assert!(s.put_encoded(k, Arc::new(vec![generation]), 30));
        }
        assert_eq!(s.len(), 1);
        assert!(s.bytes_spilled() > 0, "older generations demoted");
        let dropped = s.invalidate_generations_below(5, 2);
        assert_eq!(dropped, 2);
        assert!(s.contains(&CacheKey { namespace: 5, generation: 2, partition: 0, splits: 1 }));
        assert!(!s.contains(&CacheKey { namespace: 5, generation: 0, partition: 0, splits: 1 }));
    }

    #[test]
    fn clear_empties_both_tiers() {
        let s = store(40);
        s.put_encoded(key(1), Arc::new(vec![1u64]), 30);
        s.put_encoded(key(2), Arc::new(vec![2u64]), 30); // demotes key 1
        s.clear();
        assert!(s.is_empty());
        assert_eq!(s.bytes_spilled(), 0);
        assert!(!s.contains(&key(1)));
    }

    #[test]
    fn overwrites_supersede_demoted_copies() {
        let s = store(100);
        assert!(s.put_encoded(key(1), Arc::new(vec![1u64]), 80));
        assert!(s.put_encoded(key(2), Arc::new(vec![2u64]), 80)); // demotes 1
        assert!(s.contains(&key(1)), "demoted copy on disk");
        // Re-insert key 1 with fresh contents: the stale demoted copy
        // must die (a lookup must never resurrect it).
        assert!(s.put_encoded(key(1), Arc::new(vec![9u64]), 80));
        assert_eq!(*s.get_encoded::<Vec<u64>>(&key(1)).unwrap(), vec![9]);
        // Oversized overwrite of a resident key: the memory copy is
        // superseded by the disk-resident value.
        assert!(s.put_encoded(key(1), Arc::new(vec![7u64; 50]), 500));
        assert_eq!(s.len(), 0, "shadowed memory copy removed");
        assert_eq!(*s.get_encoded::<Vec<u64>>(&key(1)).unwrap(), vec![7u64; 50]);
    }

    #[test]
    fn namespace_quota_demotes_at_birth_and_gates_promotion() {
        let s = store(1000);
        // Tenant A = namespaces [100, 200), capped at 100 bytes.
        s.set_namespace_quota(100, 200, 100);
        let k = |ns, p| CacheKey { namespace: ns, generation: 0, partition: p, splits: 1 };
        assert!(s.put_encoded(k(100, 0), Arc::new(vec![1u64]), 80));
        assert_eq!(s.len(), 1, "within quota: resident in memory");
        // The second insert would put the range at 160 > 100: demoted at
        // birth even though the global budget (1000) has plenty of room.
        assert!(s.put_encoded(k(150, 1), Arc::new(vec![2u64]), 80));
        assert_eq!(s.len(), 1, "over-quota entry parked on disk");
        assert!(s.bytes_in_namespace_range(100, 200) <= 100);
        assert_eq!(s.storage_stats().demotions, 1);
        // A read serves it from disk but must not promote it past quota.
        assert_eq!(*s.get_encoded::<Vec<u64>>(&k(150, 1)).unwrap(), vec![2]);
        assert!(s.bytes_in_namespace_range(100, 200) <= 100);
        assert_eq!(s.storage_stats().promotions, 0);
        // Another tenant's namespaces are unaffected.
        assert!(s.put_encoded(k(300, 2), Arc::new(vec![3u64]), 80));
        assert_eq!(s.len(), 2);
        // Overwriting a resident key at the same size stays in quota.
        assert!(s.put_encoded(k(100, 0), Arc::new(vec![9u64]), 80));
        assert_eq!(*s.get_encoded::<Vec<u64>>(&k(100, 0)).unwrap(), vec![9]);
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn namespace_quota_without_disk_rejects() {
        let s = TieredStore::new(CacheBudget::Bytes(1000));
        s.set_namespace_quota(0, 10, 50);
        assert!(s.put_encoded(key(1), Arc::new(vec![1u64]), 40));
        assert!(!s.put_encoded(key(2), Arc::new(vec![2u64]), 40), "no disk: refused");
        assert_eq!(s.stats().rejected, 1);
        assert!(!s.contains(&key(2)));
    }

    #[test]
    fn memory_only_store_keeps_pr3_semantics() {
        let s = TieredStore::new(CacheBudget::Bytes(100));
        assert!(s.put_encoded(key(1), Arc::new(vec![1u64]), 80));
        assert!(s.put_encoded(key(2), Arc::new(vec![2u64]), 80)); // evicts 1 for good
        assert!(!s.contains(&key(1)), "no disk tier: evicted means gone");
        assert!(s.get_encoded::<Vec<u64>>(&key(1)).is_none());
        assert!(s.storage_stats().is_zero());
    }
}
