//! Pluggable eviction policies for the [`MemoryTier`](super::MemoryTier).
//!
//! PR 3's tier hardcoded LRU — exactly the policy our iterative workloads
//! defeat: every round is a full sweep over the input partitions, so the
//! state relation's once-per-round writes scan-pollute the recency order
//! and the next sweep misses everything (the classic LRU cliff). This
//! module turns the eviction decision into a swappable, measured axis:
//!
//! * [`LruPolicy`] — the PR 3 behavior, bit-for-bit (evict the entry with
//!   the oldest access tick).
//! * [`SlruPolicy`] — segmented LRU: new entries enter a *probation*
//!   segment; a second access promotes to a *protected* segment (~80% of
//!   the byte budget). Victims come from probation first, so a one-pass
//!   scan can only ever churn probation — the proven-hot protected set
//!   survives.
//! * [`GdsfPolicy`] — Greedy-Dual-Size-Frequency: byte-aware priority
//!   `clock + freq × SCALE ⁄ size` (integer fixed-point). Small,
//!   frequently-hit entries are worth more per byte than big cold ones;
//!   the inflation `clock` ages out entries that stop being touched.
//! * [`TinyLfuPolicy`] — a TinyLFU-style **admission filter** composable
//!   over any base policy: a count-min [`FrequencySketch`] estimates each
//!   key's access frequency, and a newcomer is only admitted if it is
//!   more frequent than the entries it would evict.
//!
//! The tier owns the slots and the byte accounting; the policy owns the
//! per-key metadata (recency ticks, segments, priorities, sketches) and
//! makes two decisions: *who to evict* ([`EvictionPolicy::victims`]) and
//! *whether to admit* ([`EvictionPolicy::admits`]). All bookkeeping is
//! integer-based and iteration-order-free (`BTreeMap`/`BTreeSet` keyed on
//! monotonic ticks or `(priority, key)`), so a recorded trace replays to
//! identical decisions every time — the property the trace lab
//! ([`super::trace`]) and the reference-model property suite depend on.
//!
//! [`PolicySpec`] is the serializable knob (`--cache-policy` on the CLI,
//! `JobSpec::eviction_policy`, both engine confs): a base policy plus an
//! optional TinyLFU admission wrapper.

use std::collections::{BTreeMap, BTreeSet, HashMap};

use crate::cache::{CacheBudget, CacheKey};

/// One eviction policy instance, owned by a single `MemoryTier` (called
/// under the tier's lock — no interior synchronization needed).
///
/// Contract: the tier mirrors every residency change into the policy
/// (`record_insert` / `on_evict` / `forget` / `reset`), so the policy's
/// metadata tracks exactly the resident key set. `victims` must only name
/// resident keys, in eviction order, covering at least `need` bytes.
pub trait EvictionPolicy: Send {
    /// Canonical name (matches [`PolicySpec`]'s `Display`).
    fn name(&self) -> &'static str;

    /// A lookup found `key` resident: bump its recency/frequency.
    fn on_hit(&mut self, key: &CacheKey);

    /// A lookup missed. Frequency learners (TinyLFU) count these too;
    /// recency-only policies ignore them.
    fn on_miss(&mut self, _key: &CacheKey) {}

    /// Resident keys to evict, in eviction order, until at least `need`
    /// bytes are covered (empty when `need == 0`). Pure — must not mutate
    /// metadata; the tier reports the outcome via [`Self::on_evict`].
    fn victims(&self, need: u64) -> Vec<CacheKey>;

    /// Admission filter: may `key` (of `bytes` estimated size) be
    /// inserted, given `victims` would be evicted to make room? Policies
    /// without admission control return `true`. The tier never consults
    /// the filter for overwrites of already-resident keys.
    fn admits(&mut self, _key: &CacheKey, _bytes: u64, _victims: &[CacheKey]) -> bool {
        true
    }

    /// `key` is now resident with `bytes` estimated size (any previous
    /// version was already `forget`-ed).
    fn record_insert(&mut self, key: CacheKey, bytes: u64);

    /// `key` was evicted under budget pressure (GDSF inflates its clock
    /// here). Default: plain [`Self::forget`].
    fn on_evict(&mut self, key: &CacheKey) {
        self.forget(key);
    }

    /// `key` left the tier outside eviction (removal / invalidation).
    fn forget(&mut self, key: &CacheKey);

    /// Every resident entry left the tier (`clear`). Learned history
    /// (frequency sketches, aging clocks) may be kept.
    fn reset(&mut self);
}

// ---------------------------------------------------------------------------
// LRU

/// Least-recently-used — the PR 3 tier's behavior, exactly: a monotonic
/// tick is stamped on insert and on every hit; the victim is always the
/// smallest tick. Ticks are unique, so eviction order is deterministic.
#[derive(Default)]
pub struct LruPolicy {
    tick: u64,
    entries: HashMap<CacheKey, (u64, u64)>, // key -> (tick, bytes)
    order: BTreeMap<u64, CacheKey>,         // tick -> key (unique ticks)
}

impl EvictionPolicy for LruPolicy {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn on_hit(&mut self, key: &CacheKey) {
        if !self.entries.contains_key(key) {
            return;
        }
        self.tick += 1;
        let tick = self.tick;
        let entry = self.entries.get_mut(key).unwrap();
        self.order.remove(&entry.0);
        entry.0 = tick;
        self.order.insert(tick, *key);
    }

    fn victims(&self, need: u64) -> Vec<CacheKey> {
        let mut freed = 0;
        let mut out = Vec::new();
        for key in self.order.values() {
            if freed >= need {
                break;
            }
            freed += self.entries[key].1;
            out.push(*key);
        }
        out
    }

    fn record_insert(&mut self, key: CacheKey, bytes: u64) {
        self.tick += 1;
        self.entries.insert(key, (self.tick, bytes));
        self.order.insert(self.tick, key);
    }

    fn forget(&mut self, key: &CacheKey) {
        if let Some((tick, _)) = self.entries.remove(key) {
            self.order.remove(&tick);
        }
    }

    fn reset(&mut self) {
        self.entries.clear();
        self.order.clear();
    }
}

// ---------------------------------------------------------------------------
// SLRU

struct SlruEntry {
    tick: u64,
    bytes: u64,
    protected: bool,
}

/// Segmented LRU: probation + protected segments, both byte-accounted.
/// Inserts land in probation; a hit promotes to protected (capped at 80%
/// of the budget — overflow demotes protected-LRU entries back to
/// probation as most-recently-used). Victims: probation LRU first, then
/// protected LRU. A single sweep over cold keys can therefore only churn
/// probation, never the proven-hot protected set — scan resistance.
pub struct SlruPolicy {
    tick: u64,
    protected_cap: u64,
    protected_bytes: u64,
    entries: HashMap<CacheKey, SlruEntry>,
    probation: BTreeMap<u64, CacheKey>,
    protected: BTreeMap<u64, CacheKey>,
}

impl SlruPolicy {
    /// Protected segment gets 4/5 of the byte budget (unbounded budgets
    /// never evict, so the split is moot there).
    pub fn new(budget: CacheBudget) -> Self {
        let protected_cap = match budget {
            CacheBudget::Unbounded => u64::MAX,
            CacheBudget::Bytes(limit) => (limit / 5).saturating_mul(4),
        };
        Self {
            tick: 0,
            protected_cap,
            protected_bytes: 0,
            entries: HashMap::new(),
            probation: BTreeMap::new(),
            protected: BTreeMap::new(),
        }
    }

    /// Demote protected-LRU entries (as probation-MRU) until the
    /// protected segment fits its cap again.
    fn shrink_protected(&mut self) {
        while self.protected_bytes > self.protected_cap {
            let Some((&tick, &key)) = self.protected.iter().next() else { break };
            self.protected.remove(&tick);
            self.tick += 1;
            let fresh = self.tick;
            let entry = self.entries.get_mut(&key).unwrap();
            entry.tick = fresh;
            entry.protected = false;
            self.protected_bytes -= entry.bytes;
            self.probation.insert(fresh, key);
        }
    }
}

impl EvictionPolicy for SlruPolicy {
    fn name(&self) -> &'static str {
        "slru"
    }

    fn on_hit(&mut self, key: &CacheKey) {
        if !self.entries.contains_key(key) {
            return;
        }
        self.tick += 1;
        let tick = self.tick;
        let entry = self.entries.get_mut(key).unwrap();
        let was_protected = entry.protected;
        if was_protected {
            self.protected.remove(&entry.tick);
        } else {
            self.probation.remove(&entry.tick);
            entry.protected = true;
        }
        let bytes = entry.bytes;
        entry.tick = tick;
        self.protected.insert(tick, *key);
        if !was_protected {
            self.protected_bytes += bytes;
            self.shrink_protected();
        }
    }

    fn victims(&self, need: u64) -> Vec<CacheKey> {
        let mut freed = 0;
        let mut out = Vec::new();
        for key in self.probation.values().chain(self.protected.values()) {
            if freed >= need {
                break;
            }
            freed += self.entries[key].bytes;
            out.push(*key);
        }
        out
    }

    fn record_insert(&mut self, key: CacheKey, bytes: u64) {
        self.tick += 1;
        self.entries.insert(key, SlruEntry { tick: self.tick, bytes, protected: false });
        self.probation.insert(self.tick, key);
    }

    fn forget(&mut self, key: &CacheKey) {
        if let Some(entry) = self.entries.remove(key) {
            if entry.protected {
                self.protected.remove(&entry.tick);
                self.protected_bytes -= entry.bytes;
            } else {
                self.probation.remove(&entry.tick);
            }
        }
    }

    fn reset(&mut self) {
        self.entries.clear();
        self.probation.clear();
        self.protected.clear();
        self.protected_bytes = 0;
    }
}

// ---------------------------------------------------------------------------
// GDSF

/// Fixed-point scale for GDSF priorities: `freq × GDSF_SCALE ⁄ bytes`
/// keeps fractional value-per-byte meaningful in integer arithmetic.
pub const GDSF_SCALE: u64 = 1 << 16;

struct GdsfEntry {
    bytes: u64,
    freq: u64,
    priority: u64,
}

/// Greedy-Dual-Size-Frequency: each entry carries
/// `priority = clock + freq × SCALE ⁄ size`; the minimum priority is
/// evicted and the `clock` inflates to the evicted priority, so resident
/// entries must keep earning hits to stay above newcomers (aging). All
/// integer fixed-point; ties break on the key, so eviction order is
/// deterministic.
#[derive(Default)]
pub struct GdsfPolicy {
    clock: u64,
    entries: HashMap<CacheKey, GdsfEntry>,
    order: BTreeSet<(u64, CacheKey)>, // (priority, key)
}

impl GdsfPolicy {
    fn priority(&self, freq: u64, bytes: u64) -> u64 {
        self.clock.saturating_add(freq.saturating_mul(GDSF_SCALE) / bytes.max(1))
    }
}

impl EvictionPolicy for GdsfPolicy {
    fn name(&self) -> &'static str {
        "gdsf"
    }

    fn on_hit(&mut self, key: &CacheKey) {
        let clock = self.clock;
        let Some(entry) = self.entries.get_mut(key) else { return };
        self.order.remove(&(entry.priority, *key));
        entry.freq += 1;
        entry.priority =
            clock.saturating_add(entry.freq.saturating_mul(GDSF_SCALE) / entry.bytes.max(1));
        self.order.insert((entry.priority, *key));
    }

    fn victims(&self, need: u64) -> Vec<CacheKey> {
        let mut freed = 0;
        let mut out = Vec::new();
        for (_, key) in &self.order {
            if freed >= need {
                break;
            }
            freed += self.entries[key].bytes;
            out.push(*key);
        }
        out
    }

    fn record_insert(&mut self, key: CacheKey, bytes: u64) {
        let priority = self.priority(1, bytes);
        self.entries.insert(key, GdsfEntry { bytes, freq: 1, priority });
        self.order.insert((priority, key));
    }

    fn on_evict(&mut self, key: &CacheKey) {
        if let Some(entry) = self.entries.get(key) {
            // Aging: future insertions start at the level the cache was
            // "worth" when it last had to give something up.
            self.clock = self.clock.max(entry.priority);
        }
        self.forget(key);
    }

    fn forget(&mut self, key: &CacheKey) {
        if let Some(entry) = self.entries.remove(key) {
            self.order.remove(&(entry.priority, *key));
        }
    }

    fn reset(&mut self) {
        self.entries.clear();
        self.order.clear();
        // `clock` is learned history: keep it.
    }
}

// ---------------------------------------------------------------------------
// TinyLFU admission filter

/// Count-min sketch over [`CacheKey`]s: 4 hash rows of `u8` counters,
/// halved every `10 × width` increments so estimates decay toward recent
/// traffic (the TinyLFU "reset" operation). Estimates never undercount;
/// hash collisions can overcount — which only ever admits *more*.
pub struct FrequencySketch {
    rows: Vec<u8>, // 4 rows × width, row-major
    width: usize,  // power of two
    ops: u64,
    sample: u64,
}

impl FrequencySketch {
    const ROWS: usize = 4;

    /// `width` is rounded up to a power of two (min 64).
    pub fn new(width: usize) -> Self {
        let width = width.max(64).next_power_of_two();
        Self {
            rows: vec![0; Self::ROWS * width],
            width,
            ops: 0,
            sample: 10 * width as u64,
        }
    }

    fn index(&self, key: &CacheKey, row: usize) -> usize {
        let mut h = crate::hash::FNV1A_OFFSET;
        for field in [key.namespace, key.generation, key.partition, key.splits] {
            h = crate::hash::fnv1a_with(h, &field.to_le_bytes());
        }
        // Independent-ish row hashes from one base digest.
        let h = crate::hash::mix_u64(h ^ (row as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        row * self.width + (h as usize & (self.width - 1))
    }

    /// Count one access of `key`, halving every counter when the sample
    /// period elapses.
    pub fn increment(&mut self, key: &CacheKey) {
        for row in 0..Self::ROWS {
            let i = self.index(key, row);
            self.rows[i] = self.rows[i].saturating_add(1);
        }
        self.ops += 1;
        if self.ops >= self.sample {
            for c in &mut self.rows {
                *c >>= 1;
            }
            self.ops = 0;
        }
    }

    /// Estimated access count of `key` (min over rows — never an
    /// undercount).
    pub fn estimate(&self, key: &CacheKey) -> u8 {
        (0..Self::ROWS).map(|row| self.rows[self.index(key, row)]).min().unwrap_or(0)
    }
}

/// TinyLFU-style admission filter over any base policy: every lookup and
/// every admission attempt is counted in a [`FrequencySketch`]; when an
/// insert would evict resident entries, the newcomer is admitted only if
/// its estimated frequency strictly beats the *most frequent* would-be
/// victim. One-hit wonders (a scan) lose that contest and are rejected,
/// leaving the resident working set untouched. Eviction order itself is
/// the base policy's.
pub struct TinyLfuPolicy {
    base: Box<dyn EvictionPolicy>,
    sketch: FrequencySketch,
    name: &'static str,
}

impl TinyLfuPolicy {
    /// Default sketch width, in counters per row.
    pub const SKETCH_WIDTH: usize = 1024;

    pub fn new(base: Box<dyn EvictionPolicy>, name: &'static str) -> Self {
        Self { base, sketch: FrequencySketch::new(Self::SKETCH_WIDTH), name }
    }
}

impl EvictionPolicy for TinyLfuPolicy {
    fn name(&self) -> &'static str {
        self.name
    }

    fn on_hit(&mut self, key: &CacheKey) {
        self.sketch.increment(key);
        self.base.on_hit(key);
    }

    fn on_miss(&mut self, key: &CacheKey) {
        self.sketch.increment(key);
        self.base.on_miss(key);
    }

    fn victims(&self, need: u64) -> Vec<CacheKey> {
        self.base.victims(need)
    }

    fn admits(&mut self, key: &CacheKey, bytes: u64, victims: &[CacheKey]) -> bool {
        // The admission attempt itself is an access.
        self.sketch.increment(key);
        if victims.is_empty() {
            return self.base.admits(key, bytes, victims);
        }
        let candidate = self.sketch.estimate(key);
        let strongest_victim =
            victims.iter().map(|v| self.sketch.estimate(v)).max().unwrap_or(0);
        candidate > strongest_victim && self.base.admits(key, bytes, victims)
    }

    fn record_insert(&mut self, key: CacheKey, bytes: u64) {
        self.base.record_insert(key, bytes);
    }

    fn on_evict(&mut self, key: &CacheKey) {
        self.base.on_evict(key);
    }

    fn forget(&mut self, key: &CacheKey) {
        self.base.forget(key);
    }

    fn reset(&mut self) {
        self.base.reset();
    }
}

// ---------------------------------------------------------------------------
// The knob

/// Base replacement policy of a [`PolicySpec`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BasePolicy {
    #[default]
    Lru,
    Slru,
    Gdsf,
}

/// The `--cache-policy` knob: a base replacement policy, optionally under
/// a TinyLFU admission filter. Parses `lru`, `slru`, `gdsf`, `tinylfu`
/// (= `tinylfu-lru`), `tinylfu-slru`, `tinylfu-gdsf`; `Display` round-trips.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PolicySpec {
    pub base: BasePolicy,
    pub tinylfu: bool,
}

impl PolicySpec {
    pub const LRU: PolicySpec = PolicySpec { base: BasePolicy::Lru, tinylfu: false };
    pub const SLRU: PolicySpec = PolicySpec { base: BasePolicy::Slru, tinylfu: false };
    pub const GDSF: PolicySpec = PolicySpec { base: BasePolicy::Gdsf, tinylfu: false };
    pub const TINYLFU: PolicySpec = PolicySpec { base: BasePolicy::Lru, tinylfu: true };

    /// The canonical policy set the trace lab and the benches sweep.
    pub fn all() -> [PolicySpec; 4] {
        [Self::LRU, Self::SLRU, Self::GDSF, Self::TINYLFU]
    }

    pub fn parse(s: &str) -> Option<PolicySpec> {
        match s.trim().to_ascii_lowercase().as_str() {
            "lru" => Some(Self::LRU),
            "slru" => Some(Self::SLRU),
            "gdsf" => Some(Self::GDSF),
            "tinylfu" | "tinylfu-lru" => Some(Self::TINYLFU),
            "tinylfu-slru" => Some(PolicySpec { base: BasePolicy::Slru, tinylfu: true }),
            "tinylfu-gdsf" => Some(PolicySpec { base: BasePolicy::Gdsf, tinylfu: true }),
            _ => None,
        }
    }

    /// Instantiate the policy for a tier with `budget` (SLRU sizes its
    /// protected segment off it).
    pub fn build(&self, budget: CacheBudget) -> Box<dyn EvictionPolicy> {
        let base: Box<dyn EvictionPolicy> = match self.base {
            BasePolicy::Lru => Box::new(LruPolicy::default()),
            BasePolicy::Slru => Box::new(SlruPolicy::new(budget)),
            BasePolicy::Gdsf => Box::new(GdsfPolicy::default()),
        };
        if !self.tinylfu {
            return base;
        }
        let name = match self.base {
            BasePolicy::Lru => "tinylfu-lru",
            BasePolicy::Slru => "tinylfu-slru",
            BasePolicy::Gdsf => "tinylfu-gdsf",
        };
        Box::new(TinyLfuPolicy::new(base, name))
    }
}

impl std::fmt::Display for PolicySpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let base = match self.base {
            BasePolicy::Lru => "lru",
            BasePolicy::Slru => "slru",
            BasePolicy::Gdsf => "gdsf",
        };
        if self.tinylfu {
            write!(f, "tinylfu-{base}")
        } else {
            write!(f, "{base}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(p: u64) -> CacheKey {
        CacheKey { namespace: 0, generation: 0, partition: p, splits: 1 }
    }

    #[test]
    fn spec_parse_display_round_trips() {
        for spec in [
            PolicySpec::LRU,
            PolicySpec::SLRU,
            PolicySpec::GDSF,
            PolicySpec::TINYLFU,
            PolicySpec { base: BasePolicy::Slru, tinylfu: true },
            PolicySpec { base: BasePolicy::Gdsf, tinylfu: true },
        ] {
            assert_eq!(PolicySpec::parse(&spec.to_string()), Some(spec), "{spec}");
            assert_eq!(spec.build(CacheBudget::Bytes(100)).name(), spec.to_string());
        }
        assert_eq!(PolicySpec::parse("tinylfu"), Some(PolicySpec::TINYLFU));
        assert_eq!(PolicySpec::parse(" LRU "), Some(PolicySpec::LRU));
        assert_eq!(PolicySpec::parse("clock"), None);
        assert_eq!(PolicySpec::default(), PolicySpec::LRU);
    }

    #[test]
    fn lru_evicts_oldest_tick_first() {
        let mut p = LruPolicy::default();
        p.record_insert(key(1), 10);
        p.record_insert(key(2), 10);
        p.record_insert(key(3), 10);
        p.on_hit(&key(1)); // 2 is now the oldest
        assert_eq!(p.victims(1), vec![key(2)]);
        assert_eq!(p.victims(15), vec![key(2), key(3)]);
        assert_eq!(p.victims(0), Vec::<CacheKey>::new());
    }

    #[test]
    fn slru_protects_re_accessed_entries_from_scans() {
        // Budget 100 -> protected cap 80. Two hot 30-byte entries get
        // promoted; a scan of cold keys then fills probation.
        let mut p = SlruPolicy::new(CacheBudget::Bytes(100));
        p.record_insert(key(1), 30);
        p.record_insert(key(2), 30);
        p.on_hit(&key(1));
        p.on_hit(&key(2));
        p.record_insert(key(10), 20);
        p.record_insert(key(11), 20);
        // Victims come from probation (the scan), not the hot set.
        assert_eq!(p.victims(40), vec![key(10), key(11)]);
        // Only once probation is exhausted does protected bleed.
        assert_eq!(p.victims(70), vec![key(10), key(11), key(1)]);
    }

    #[test]
    fn slru_protected_overflow_demotes_back_to_probation() {
        // Cap = 8 bytes (budget 10): promoting a second 5-byte entry
        // pushes the first back to probation.
        let mut p = SlruPolicy::new(CacheBudget::Bytes(10));
        p.record_insert(key(1), 5);
        p.record_insert(key(2), 5);
        p.on_hit(&key(1));
        p.on_hit(&key(2)); // protected would be 10 > 8: key 1 demotes
        assert_eq!(p.victims(1), vec![key(1)]);
        assert_eq!(p.protected_bytes, 5);
    }

    #[test]
    fn gdsf_prefers_evicting_large_cold_entries() {
        let mut p = GdsfPolicy::default();
        p.record_insert(key(1), 1000); // big: priority ~ SCALE/1000
        p.record_insert(key(2), 10); // small: priority ~ SCALE/10
        assert_eq!(p.victims(1), vec![key(1)], "worst value-per-byte goes first");
        // Frequency rescues the big entry past the small one.
        for _ in 0..200 {
            p.on_hit(&key(1));
        }
        assert_eq!(p.victims(1), vec![key(2)]);
    }

    #[test]
    fn gdsf_clock_inflates_on_eviction() {
        let mut p = GdsfPolicy::default();
        p.record_insert(key(1), 1);
        p.on_hit(&key(1)); // freq 2: priority = 2 * SCALE
        p.on_evict(&key(1));
        assert_eq!(p.clock, 2 * GDSF_SCALE);
        // Newcomers now start above pre-eviction levels.
        p.record_insert(key(2), 1);
        assert!(p.entries[&key(2)].priority > 2 * GDSF_SCALE);
    }

    #[test]
    fn tinylfu_rejects_one_hit_wonders() {
        let mut p = PolicySpec::TINYLFU.build(CacheBudget::Bytes(100));
        p.record_insert(key(1), 100);
        // Make key 1 hot.
        for _ in 0..5 {
            p.on_hit(&key(1));
        }
        let victims = p.victims(100);
        assert_eq!(victims, vec![key(1)]);
        // A never-seen key must not displace it...
        assert!(!p.admits(&key(9), 100, &victims));
        // ...but a hotter one may.
        for _ in 0..8 {
            p.on_miss(&key(7));
        }
        assert!(p.admits(&key(7), 100, &victims));
        // With room to spare (no victims) everything is admitted.
        assert!(p.admits(&key(9), 10, &[]));
    }

    #[test]
    fn sketch_counts_and_decays() {
        let mut s = FrequencySketch::new(64);
        assert_eq!(s.estimate(&key(1)), 0);
        for _ in 0..6 {
            s.increment(&key(1));
        }
        assert!(s.estimate(&key(1)) >= 6);
        let before = s.estimate(&key(1));
        // Drive past the sample period: counters halve.
        for i in 0..s.sample {
            s.increment(&key(1000 + i));
        }
        assert!(s.estimate(&key(1)) < before, "decay must forget old traffic");
    }

    #[test]
    fn sketch_never_undercounts() {
        let mut s = FrequencySketch::new(64);
        for p in 0..50 {
            s.increment(&key(p));
        }
        for p in 0..50 {
            assert!(s.estimate(&key(p)) >= 1, "partition {p}");
        }
    }
}
